"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps on
the synthetic pipeline, with checkpointing and fault-tolerant stepping.

    PYTHONPATH=src python examples/train_100m.py --steps 200

(Defaults are sized for this CPU container; on a pod, raise batch/seq and
pass --tp/--dp.)
"""
import argparse
import dataclasses
import time

import jax
import numpy as np
from jax.sharding import Mesh

from repro.configs.base import ModelConfig, ParallelConfig
from repro.models.model import count_params_analytic
from repro.runtime import trainer as T


def build_config() -> ModelConfig:
    return ModelConfig(
        name="repro_100m",
        family="dense",
        num_layers=12,
        d_model=640,
        num_heads=10,
        num_kv_heads=10,
        d_ff=2560,
        vocab_size=32768,
        rope_style="rope",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()

    cfg = build_config()
    n = count_params_analytic(cfg)
    print(f"model: {n/1e6:.1f}M params")
    par = ParallelConfig(tp=1, dp=1, overlap_mode="decomposed")
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))

    tc = T.TrainConfig(total_steps=args.steps, warmup_steps=20,
                       base_lr=6e-4, schedule="wsd",
                       checkpoint_dir=args.ckpt_dir, checkpoint_every=100,
                       log_every=10)
    tr = T.Trainer(cfg, par, mesh, tc)
    tr.data_cfg = dataclasses.replace(
        tr.data_cfg, seq_len=args.seq, global_batch=args.batch)

    t0 = time.time()
    params, opt, hist = tr.train(resume=True)
    dt = time.time() - t0
    tok_s = args.steps * args.batch * args.seq / dt
    print(f"\ntrained {len(hist)} steps in {dt:.0f}s ({tok_s:.0f} tok/s)")
    print(f"loss: {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f}")
    print(f"straggler events: {tr.straggler_events}, failures: {tr.failures}")
    assert hist[-1]["loss"] < hist[0]["loss"]
    print("train_100m OK")


if __name__ == "__main__":
    main()
