"""Quickstart: build a tiny model, run the three overlap modes, train a few
steps — the whole public API in 60 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ParallelConfig, get_smoke_config
from repro.core import overlap
from repro.models import model as M
from repro.optim import adamw
from repro.parallel.sharding import TPContext
from repro.runtime import trainer as T


def main():
    # --- 1. the FLUX seams directly (single device: modes coincide) --------
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 64, 128))
    w = jax.random.normal(jax.random.PRNGKey(1), (128, 256)) * 0.1
    for mode in overlap.VALID_MODES:
        y = overlap.FusedOp(kind="ag", mode=mode)(x, w)
        print(f"FusedOp(ag)[{mode:10s}] -> {y.shape}, "
              f"mean={float(y.mean()):+.4f}")

    # --- 2. a reduced architecture from the zoo -----------------------------
    cfg = get_smoke_config("codeqwen15_7b")
    par = ParallelConfig(tp=1, dp=1, overlap_mode="decomposed")
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    print(f"\nmodel: {cfg.name} (reduced) — "
          f"{M.count_params_analytic(cfg):,} params")

    # --- 3. a few train steps ------------------------------------------------
    tc = T.TrainConfig(total_steps=5, warmup_steps=1, base_lr=3e-3,
                       log_every=1)
    tr = T.Trainer(cfg, par, mesh, tc)
    params, opt, hist = tr.train(resume=False)
    for i, h in enumerate(hist):
        print(f"step {i}: loss {h['loss']:.4f}  lr {h['lr']:.2e}")
    assert hist[-1]["loss"] < hist[0]["loss"], "loss should decrease"
    print("quickstart OK")


if __name__ == "__main__":
    main()
