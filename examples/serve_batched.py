"""Batched serving example: continuous batching over a reduced model —
admits a queue of prompts into decode slots, recycles finished slots.

    PYTHONPATH=src python examples/serve_batched.py
"""
import jax
import numpy as np
from jax.sharding import Mesh

from repro.configs.base import ParallelConfig, get_smoke_config
from repro.models import model as M
from repro.runtime.server import Request, ServeConfig, Server


def main():
    cfg = get_smoke_config("phi4_mini_38b")
    par = ParallelConfig(tp=1, dp=1)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    params = M.init_model(jax.random.PRNGKey(0), cfg, par)

    sc = ServeConfig(max_batch=4, max_seq=96, eos_token=-1, max_new_tokens=8)
    server = Server(cfg, par, mesh, params, sc)

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, size=(4 + 2 * i,))
                    .astype(np.int32))
            for i in range(6)]
    done = server.serve(reqs)
    for r in sorted(done, key=lambda r: r.rid):
        print(f"req {r.rid}: prompt[{len(r.prompt)}] -> "
              f"output {r.output[:8]}")
    assert len(done) == 6
    assert all(len(r.output) >= 1 for r in done)
    print("serve_batched OK")


if __name__ == "__main__":
    main()
