"""Sequence-parallel activation residency (the ``scatter_axis`` knob).

Three guarantees of the layout refactor:

1. **Layout equivalence** — the SP train step (sequence-sharded residual
   stream, ``scatter_axis="seq"``) is numerically identical (value AND
   grad) to the replicated-layout step (``"hidden"``) for every mixer
   family: GQA, MLA, Mamba, RWKV, MoE FFN.  Grads of model-replicated
   leaves are compared after the trainer's psum completion (per-rank grads
   are PARTIALS whose partition differs per layout; their sum must not).
2. **Zero standalone collectives** — under ring plans the SP train step's
   jaxpr contains NO ``all_gather``/``psum_scatter`` at all: every
   sequence gather/scatter (seams, backward re-gathers, MLA's shared rope
   key, RWKV's token-shift projections, the embed seam) rides ppermute
   ring transports owned by the seams.
3. **Residency / comm accounting** — ``ect.model_overlap`` reports the
   per-layer resident activation reduced ~1/tp under "seq" with the
   per-layer-pair comm volume unchanged.
"""
import pytest

from repro.core import ect

# family -> the smoke arch exercising it (gqa / mla+moe / mamba / rwkv)
_FAMILY_ARCHS = {
    "gqa": "codeqwen15_7b",
    "mla_moe": "deepseek_v3_671b",
    "mamba": "jamba_v01_52b",
    "rwkv": "rwkv6_3b",
}

_EQUIV = r"""
import dataclasses, functools
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map
from repro.configs.base import get_smoke_config, ParallelConfig
from repro.models import model as M
from repro.optim import adamw
from repro.parallel.sharding import TPContext
from repro.tuning.plans import PlanSet

arch = "%s"
cfg = dataclasses.replace(get_smoke_config(arch), d_ff=512,
                          compute_dtype="float32")
if cfg.moe:
    # capacity high enough that no token drops: the two layouts bucket
    # tokens differently (per-shard vs global cumsum) but a drop-free
    # combine is layout-invariant
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, capacity_factor=16.0))
par = ParallelConfig(tp=4, dp=1)
mesh = Mesh(np.array(jax.devices()).reshape(1, 4), ("data", "model"))

key = jax.random.PRNGKey(0)
B, S = 2, 64
batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
         "labels": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                      cfg.vocab_size)}
params = M.init_model(jax.random.PRNGKey(0), cfg, par)
params = jax.tree.map(
    lambda a: a.astype(jnp.float32) if a.dtype == jnp.bfloat16 else a, params)
specs = M.param_specs(cfg, par, params)
bs = {"tokens": P("data", None), "labels": P("data", None)}
model_rep = adamw.model_replicated_tree(specs)

def loss_and_grads(plans):
    ctx = TPContext(axis="model", dp_axes=("data",),
                    ep_axes=("model",) if cfg.moe else (), plans=plans)
    @jax.jit
    @functools.partial(shard_map, mesh=mesh, in_specs=(specs, bs),
                       out_specs=(P(), specs), check_vma=False)
    def f(p, b):
        l, g = jax.value_and_grad(
            lambda pp: jax.lax.pmean(M.forward_loss(pp, b, ctx, cfg, par),
                                     ("data",)))(p)
        # complete model-replicated leaves exactly as the trainer does
        g = jax.tree.map(
            lambda gr, rep: jax.lax.psum(gr, "model") if rep else gr,
            g, model_rep)
        return l, g
    return f(params, batch)

sp_plans = PlanSet.uniform("decomposed")
l_sp, g_sp = loss_and_grads(sp_plans)
l_rep, g_rep = loss_and_grads(sp_plans.with_scatter_axis("hidden"))

assert abs(float(l_sp) - float(l_rep)) < 2e-5, (float(l_sp), float(l_rep))
for (path, a), b in zip(jax.tree_util.tree_leaves_with_path(g_sp),
                        jax.tree.leaves(g_rep)):
    a, b = np.asarray(a), np.asarray(b)
    rel = np.abs(a - b).max() / (np.abs(a).max() + 1e-9)
    assert rel < 1e-4, (jax.tree_util.keystr(path), rel)
print("SP_EQUIV_OK", arch, float(l_sp))
"""


@pytest.mark.parametrize("family", sorted(_FAMILY_ARCHS))
def test_sp_vs_replicated_value_and_grad(subproc, family):
    """4-device value+grad equivalence of the two activation layouts, per
    mixer family."""
    out = subproc(_EQUIV % _FAMILY_ARCHS[family], n_devices=4, timeout=1800)
    assert "SP_EQUIV_OK" in out


_EQUIV_EP_OVER_DP = r"""
import dataclasses, functools
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map
from repro.configs.base import get_smoke_config, ParallelConfig
from repro.models import model as M
from repro.optim import adamw
from repro.parallel.sharding import TPContext
from repro.tuning.plans import PlanSet

# MoE with experts over ("data","model") jointly on a 2x2 mesh: the
# replicated layout's branch must gather the data-axis tokens, compute
# local experts for the FULL token set, psum over the EP group, and slice
# this data shard's rows back out — the multi-axis path the dp=1 sweep
# never reaches.
cfg = dataclasses.replace(get_smoke_config("deepseek_v3_671b"), d_ff=512,
                          compute_dtype="float32")
cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
    cfg.moe, capacity_factor=16.0))
par = ParallelConfig(tp=2, dp=2, ep_over_dp=True)
mesh = Mesh(np.array(jax.devices()).reshape(2, 2), ("data", "model"))

key = jax.random.PRNGKey(0)
B, S = 4, 32
batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
         "labels": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                      cfg.vocab_size)}
params = M.init_model(jax.random.PRNGKey(0), cfg, par)
params = jax.tree.map(
    lambda a: a.astype(jnp.float32) if a.dtype == jnp.bfloat16 else a, params)
specs = M.param_specs(cfg, par, params)
bs = {"tokens": P("data", None), "labels": P("data", None)}
model_rep = adamw.model_replicated_tree(specs)

def loss_and_grads(plans):
    ctx = TPContext(axis="model", dp_axes=("data",),
                    ep_axes=("data", "model"), plans=plans)
    @jax.jit
    @functools.partial(shard_map, mesh=mesh, in_specs=(specs, bs),
                       out_specs=(P(), specs), check_vma=False)
    def f(p, b):
        l, g = jax.value_and_grad(
            lambda pp: jax.lax.pmean(M.forward_loss(pp, b, ctx, cfg, par),
                                     ("data",)))(p)
        g = jax.tree.map(
            lambda gr, rep: jax.lax.psum(gr, "model") if rep else gr,
            g, model_rep)
        return l, g
    return f(params, batch)

sp_plans = PlanSet.uniform("decomposed")
l_sp, g_sp = loss_and_grads(sp_plans)
assert np.isfinite(float(l_sp))
assert all(np.all(np.isfinite(np.asarray(g))) for g in jax.tree.leaves(g_sp))

# the replicated layout must REFUSE ep_over_dp training: its local-expert
# combine yields EP-group partial router/expert grads that the DP grad
# contract (per-data-shard grads) would silently mis-sum
try:
    loss_and_grads(sp_plans.with_scatter_axis("hidden"))
except NotImplementedError as e:
    assert "ep_over_dp" in str(e)
    print("SP_EP_OVER_DP_OK", float(l_sp))
else:
    raise AssertionError("replicated ep_over_dp MoE training must raise")
"""


def test_moe_ep_over_dp_layouts(subproc):
    """Experts over ("data","model") at dp>1: the SP layout trains (the
    multi-axis all_to_all dispatch), and the replicated layout fails LOUD
    instead of training with mis-summed router gradients."""
    out = subproc(_EQUIV_EP_OVER_DP, n_devices=4, timeout=1800)
    assert "SP_EP_OVER_DP_OK" in out


_CENSUS = r"""
import dataclasses, functools
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.analysis.seamcheck import collective_counts
from repro.compat import shard_map
from repro.configs.base import get_smoke_config, ParallelConfig
from repro.models import model as M
from repro.parallel.sharding import TPContext
from repro.tuning.plans import PlanSet

for arch in ("codeqwen15_7b", "deepseek_v3_671b", "jamba_v01_52b",
             "rwkv6_3b"):
    cfg = dataclasses.replace(get_smoke_config(arch), d_ff=512,
                              compute_dtype="float32")
    par = ParallelConfig(tp=4, dp=1)
    mesh = Mesh(np.array(jax.devices()).reshape(1, 4), ("data", "model"))
    B, S = 2, 64
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(0), (B, S), 0,
                                          cfg.vocab_size),
             "labels": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                          cfg.vocab_size)}
    params = M.init_model(jax.random.PRNGKey(0), cfg, par)
    specs = M.param_specs(cfg, par, params)
    bs = {"tokens": P("data", None), "labels": P("data", None)}
    ctx = TPContext(axis="model", dp_axes=("data",),
                    ep_axes=("model",) if cfg.moe else (),
                    plans=PlanSet.uniform("decomposed"))
    f = functools.partial(shard_map, mesh=mesh, in_specs=(specs, bs),
                          out_specs=(P(), specs), check_vma=False)(
        lambda p, b: jax.value_and_grad(
            lambda pp: jax.lax.pmean(M.forward_loss(pp, b, ctx, cfg, par),
                                     ("data",)))(p))
    cc = collective_counts(jax.make_jaxpr(f)(params, batch))
    # the SP train step (fwd AND bwd) must contain ZERO standalone
    # full-activation collectives between seams: every sequence
    # gather/scatter rides a seam-owned ppermute ring.  (psum remains for
    # the xent/aux reductions and the ar seams; all_to_all is the MoE EP
    # dispatch seam; psum_scatter traces as a reduce_scatter eqn — the
    # old string census looked for the wrong name and was vacuous.)
    n_ag = cc.get("all_gather", 0)
    n_ps = cc.get("reduce_scatter", 0)
    n_pp = cc.get("ppermute", 0)
    assert n_ag == 0, (arch, "all_gather", n_ag)
    assert n_ps == 0, (arch, "reduce_scatter", n_ps)
    assert n_pp > 0, (arch, "expected ppermute rings")
    print("CENSUS_OK", arch, "ppermute", n_pp)
print("ALL_CENSUS_OK")
"""


def test_sp_train_step_census(subproc):
    """jaxpr census: zero standalone full-activation collectives between
    seams in the SP train step (fwd+bwd), for every mixer family."""
    out = subproc(_CENSUS, n_devices=4, timeout=1800)
    assert "ALL_CENSUS_OK" in out


_HIDDEN_OPS = r"""
import functools
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from jax import lax
from repro.compat import shard_map
from repro.core.overlap import Epilogue, FusedOp

mesh = Mesh(np.array(jax.devices()), ("model",))
B, S, D, F = 2, 64, 32, 64
x = jax.random.normal(jax.random.PRNGKey(0), (B, S, D), jnp.float32)
w1 = jax.random.normal(jax.random.PRNGKey(1), (D, F)) / D**0.5
w3 = jax.random.normal(jax.random.PRNGKey(2), (D, F)) / D**0.5
w2 = jax.random.normal(jax.random.PRNGKey(3), (F, D)) / F**0.5
tg = jax.random.normal(jax.random.PRNGKey(4), (B, S, D), jnp.float32)

# replicated-in, replicated-out gated FFN layer through hidden-scatter ops;
# oracle = the same math with plain jnp + psum (native transposes)
def layer(mode):
    ag = FusedOp(kind="ag", axis="model", mode=mode, comm_chunks=8,
                 epilogue=Epilogue(activation="silu", gate="pair"),
                 n_weights=2, scatter_axis="hidden")
    rs = FusedOp(kind="rs", axis="model", mode=mode, comm_chunks=8,
                 scatter_axis="hidden")
    def f(xs, a_, b_, c_):
        y = ag(xs, a_, b_)
        z = rs(y, c_)
        # rank-ASYMMETRIC consumption of the replicated output (the
        # convention stress: partial cotangents must complete inside ops)
        r = lax.axis_index("model").astype(jnp.float32) + 1.0
        return lax.psum(jnp.sum(z * tg) * r, "model") / 10.0
    return f

def oracle(xs, a_, b_, c_):
    y = jax.nn.silu(jnp.einsum("bsd,df->bsf", xs, a_)) \
        * jnp.einsum("bsd,df->bsf", xs, b_)
    z = lax.psum(jnp.einsum("bsf,fd->bsd", y, c_), "model")
    r = lax.axis_index("model").astype(jnp.float32) + 1.0
    return lax.psum(jnp.sum(z * tg) * r, "model") / 10.0

specs = (P(None, None, None), P(None, "model"), P(None, "model"),
         P("model", None))
def grads(fn):
    g = jax.jit(jax.grad(functools.partial(
        shard_map, mesh=mesh, in_specs=specs, out_specs=P(),
        check_vma=False)(fn), argnums=(0, 1, 2, 3)))(x, w1, w3, w2)
    return [np.asarray(a) for a in g]

g_ref = grads(oracle)
for mode in ("xla", "decomposed", "decomposed_bidir"):
    g = grads(layer(mode))
    for i, (a, b) in enumerate(zip(g, g_ref)):
        rel = np.abs(a - b).max() / (np.abs(b).max() + 1e-9)
        assert rel < 1e-4, (mode, i, rel)
    # values too
    f_op = jax.jit(functools.partial(shard_map, mesh=mesh, in_specs=specs,
                                     out_specs=P(), check_vma=False)(
        layer(mode)))
    f_ref = jax.jit(functools.partial(shard_map, mesh=mesh, in_specs=specs,
                                      out_specs=P(), check_vma=False)(oracle))
    assert abs(float(f_op(x, w1, w3, w2)) - float(f_ref(x, w1, w3, w2))) \
        < 1e-3
print("HIDDEN_OPS_OK")
"""


def test_hidden_scatter_ops_4dev(subproc):
    """scatter_axis="hidden" FusedOps: values and grads match the native
    psum oracle, including under rank-asymmetric consumption of the
    replicated output (the partial-cotangent convention)."""
    assert "HIDDEN_OPS_OK" in subproc(_HIDDEN_OPS, n_devices=4, timeout=900)


# ---------------------------------------------------------------------------
# residency / comm-volume accounting (no devices needed)
# ---------------------------------------------------------------------------
def test_model_overlap_residency_and_volume():
    """ect.model_overlap: "seq" keeps 1/tp of the activation resident per
    seam, and the per-layer-pair comm volume is layout-invariant."""
    m, d, f, tp = 4096, 1024, 4096, 8
    for mode in ("xla", "decomposed", "decomposed_bidir"):
        ag_s = ect.model_overlap("ag", m, f, d, tp, mode)
        ag_h = ect.model_overlap("ag", m, f, d, tp, mode,
                                 scatter_axis="hidden")
        rs_s = ect.model_overlap("rs", m, d, f, tp, mode)
        # hidden's RS on the MONOLITHIC ring AllReduce (the chunked-AR
        # transport moves chunks x the bytes and is charged as such)
        rs_h = ect.model_overlap("rs", m, d, f, tp, "xla",
                                 scatter_axis="hidden")
        # activation residency: 1/tp under seq, both seam sides
        assert ag_s["act_bytes"] * tp == ag_h["act_bytes"]
        assert rs_s["act_bytes"] * tp == rs_h["act_bytes"]
        # per-layer-pair comm volume is layout-invariant (AG+RS over the
        # sequence == one ring AllReduce); hidden's AG side is comm-free
        assert ag_h["comm_bytes"] == 0.0
        pair_seq = ag_s["comm_bytes"] + rs_s["comm_bytes"]
        pair_hid = ag_h["comm_bytes"] + rs_h["comm_bytes"]
        assert pair_seq == pytest.approx(pair_hid)
    # the chunked-AR transport is honestly charged chunks x the volume
    ar_mono = ect.model_overlap("ar", m, d, f, tp, "xla")
    ar_chunk = ect.model_overlap("ar", m, d, f, tp, "decomposed",
                                 comm_chunks=4)
    assert ar_chunk["comm_bytes"] == pytest.approx(4 * ar_mono["comm_bytes"])


def test_layout_sweep_prefers_seq_on_ties():
    from repro.configs.base import ParallelConfig, get_smoke_config
    from repro.tuning import autotune
    cfg = get_smoke_config("codeqwen15_7b")
    par = ParallelConfig(tp=4, dp=1)
    sweep = autotune.sweep_model_layout(cfg, par, tokens_per_dp=512)
    assert set(sweep) >= {"seq", "hidden", "winner", "residency_ratio"}
    # equal comm volume is structural; residency strictly favors seq
    assert sweep["seq"]["comm_bytes"] == pytest.approx(
        sweep["hidden"]["comm_bytes"])
    assert sweep["residency_ratio"] == pytest.approx(1.0 / par.tp)
    # equal volume + 1/tp residency: the tuner must deliver SP by default
    assert sweep["winner"] == "seq"


def test_plan_scatter_axis_round_trip():
    from repro.tuning.plans import PlanSet, SeamPlan
    ps = PlanSet(default=SeamPlan(mode="decomposed"),
                 seams={"mlp_ag": SeamPlan(mode="xla")})
    assert ps.residual_layout() == "seq"
    ph = ps.with_scatter_axis("hidden")
    assert ph.residual_layout() == "hidden"
    # JSON round-trip keeps the knob; old profiles (no key) default to seq
    rt = PlanSet.from_json(ph.to_json())
    assert rt.residual_layout() == "hidden"
    assert SeamPlan.from_json({"mode": "decomposed"}).scatter_axis == "seq"
    # incoherent residual layouts are a config error
    bad = ps.override("mlp_rs", SeamPlan(mode="decomposed",
                                         scatter_axis="hidden"))
    with pytest.raises(ValueError):
        bad.residual_layout()


def test_registry_layout_stamp_keeps_profiles_coherent(tmp_path):
    """Cached entries tuned under a different layout decision must not
    persist a mixed-layout profile (which raises at load): the tuner
    stamps the whole registry before saving."""
    import jax
    from repro.configs.base import ParallelConfig
    from repro.tuning.cache import PlanRegistry
    from repro.tuning.plans import (PlanSet, SeamPlan,
                                    plan_set_from_parallel)
    path = str(tmp_path / "prof.json")
    reg = PlanRegistry(n_dev=4, backend=jax.default_backend())
    reg.record("mlp_ag", "ag", 512, 512, 128,
               SeamPlan(mode="decomposed", scatter_axis="seq"))
    reg.record("attn_rs", "rs", 512, 128, 256,
               SeamPlan(mode="decomposed", scatter_axis="hidden"))
    reg.stamp_scatter_axis("hidden")
    reg.save(path)
    par = ParallelConfig(tp=4, dp=1, plan_profile=path,
                         overlap_mode="decomposed")
    ps = plan_set_from_parallel(par)
    # the load adopts the profile's (coherent) layout for the WHOLE set,
    # including residual seams the profile didn't record
    assert ps.residual_layout() == "hidden"
    assert ps.resolve("mlp_ag").scatter_axis == "hidden"
    assert ps.resolve("mlp_rs").scatter_axis == "hidden"   # unrecorded seam
    # forcing via ParallelConfig.scatter_axis stamps everything at load too
    par_forced = ParallelConfig(tp=4, dp=1, plan_profile=path,
                                overlap_mode="decomposed",
                                scatter_axis="hidden")
    assert plan_set_from_parallel(par_forced).residual_layout() == "hidden"


def test_seam_shape_cells():
    """model_seam_shapes keys attention seams per (arch, shape cell):
    MLA's two up-projection widths become distinct cells; GQA's packed
    QKV is one."""
    from repro.configs.base import ParallelConfig, get_smoke_config
    from repro.tuning import autotune
    from repro.tuning.plans import seam_of
    par = ParallelConfig(tp=4, dp=1)
    mla = autotune.model_seam_shapes(get_smoke_config("deepseek_v3_671b"),
                                     par, 512)
    assert "attn_ag@q_up" in mla and "attn_ag@kv_up" in mla
    assert mla["attn_ag@q_up"][1:] != mla["attn_ag@kv_up"][1:]
    assert seam_of("attn_ag@q_up") == "attn_ag"
    gqa = autotune.model_seam_shapes(get_smoke_config("codeqwen15_7b"),
                                     par, 512)
    assert "attn_ag@qkv" in gqa and "attn_ag" not in gqa
