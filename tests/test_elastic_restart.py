"""Elastic restart end-to-end: train on a (2,2) mesh, checkpoint, lose half
the devices, rebuild a (1,2) mesh, restore the checkpoint onto the new
topology, keep training — the core large-scale fault-tolerance story."""
import pytest

_ELASTIC = r"""
import dataclasses
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh
from repro.configs.base import get_smoke_config, ParallelConfig
from repro.launch.mesh import elastic_remesh
from repro.runtime import trainer as T

cfg = dataclasses.replace(get_smoke_config("codeqwen15_7b"), d_ff=512)

def make_trainer(mesh, dp, tp, ckpt, steps):
    par = ParallelConfig(tp=tp, dp=dp, overlap_mode="decomposed")
    tc = T.TrainConfig(total_steps=steps, warmup_steps=1, base_lr=3e-3,
                       checkpoint_dir=ckpt, checkpoint_every=2, log_every=100)
    tr = T.Trainer(cfg, par, mesh, tc)
    tr.data_cfg = dataclasses.replace(tr.data_cfg, seq_len=64, global_batch=4)
    return tr

ckpt = "/tmp/elastic_ck"
import shutil; shutil.rmtree(ckpt, ignore_errors=True)

# phase 1: full fleet (2 data x 2 model), 4 steps, checkpoints at 2 and 4
mesh4 = Mesh(np.array(jax.devices()).reshape(2, 2), ("data", "model"))
tr = make_trainer(mesh4, 2, 2, ckpt, steps=4)
_, _, hist1 = tr.train(resume=False)
assert tr.step == 4

# phase 2: two devices "fail" -> re-mesh the survivors (1 data x 2 model,
# TP group preserved) and RESUME FROM THE CHECKPOINT on the new topology
mesh2 = elastic_remesh(surviving_devices=2, tp=2)
assert mesh2.devices.shape == (1, 2)
tr2 = make_trainer(mesh2, 1, 2, ckpt, steps=6)
_, _, hist2 = tr2.train(resume=True)
assert tr2.step == 6
assert len(hist2) == 2          # resumed at 4, ran 4..6
losses = [h["loss"] for h in hist1] + [h["loss"] for h in hist2]
assert all(np.isfinite(l) for l in losses)
# the resumed loss continues from the trained state, not from init
assert losses[-1] < losses[0], losses
print("ELASTIC_RESTART_OK", [round(l, 3) for l in losses])
"""


def test_elastic_restart(subproc):
    out = subproc(_ELASTIC, n_devices=4, timeout=1800)
    assert "ELASTIC_RESTART_OK" in out
