"""Cross-TP functional equivalence: the SAME model function at tp=1/2/4 in
fp32 — the property that makes checkpoints reshardable across TP degrees
(canonical init + zero-padding + TP-consistent packing)."""
import pytest

_INVARIANCE = r"""
import dataclasses, functools
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map
from repro.configs.base import get_smoke_config, ParallelConfig
from repro.models import model as M
from repro.parallel.sharding import TPContext

arch = "%s"
cfg = dataclasses.replace(get_smoke_config(arch), d_ff=512,
                          compute_dtype="float32")
if cfg.moe:
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, capacity_factor=16.0))

key = jax.random.PRNGKey(0)
B, S = 4, 64
if cfg.frontend:
    batch = {"embeds": jax.random.normal(key, (B, S, cfg.d_model),
                                         jnp.float32),
             "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
else:
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
             "labels": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                          cfg.vocab_size)}

def run(tp, mode):
    par = ParallelConfig(tp=tp, dp=4 // tp)
    mesh = Mesh(np.array(jax.devices()).reshape(4 // tp, tp),
                ("data", "model"))
    params = M.init_model(jax.random.PRNGKey(0), cfg, par)
    params = jax.tree.map(
        lambda a: a.astype(jnp.float32) if a.dtype == jnp.bfloat16 else a,
        params)
    specs = M.param_specs(cfg, par, params)
    ctx = TPContext(axis="model", dp_axes=("data",),
                    ep_axes=("model",) if cfg.moe else (), mode=mode)
    if cfg.frontend:
        bs = {"embeds": P("data", "model", None), "labels": P("data", None)}
    else:
        bs = {"tokens": P("data", None), "labels": P("data", None)}

    @jax.jit
    @functools.partial(shard_map, mesh=mesh, in_specs=(specs, bs),
                       out_specs=P(), check_vma=False)
    def loss_fn(p, b):
        return jax.lax.pmean(M.forward_loss(p, b, ctx, cfg, par), ("data",))
    return float(loss_fn(params, batch))

l1 = run(1, "xla")
l2 = run(2, "decomposed")
l4 = run(4, "decomposed")
l4x = run(4, "xla")
spread = max(l1, l2, l4, l4x) - min(l1, l2, l4, l4x)
assert spread < 2e-4, (l1, l2, l4, l4x)
print("TP_INVARIANT_OK", l1)
"""


@pytest.mark.parametrize("arch", ["codeqwen15_7b", "rwkv6_3b",
                                  "jamba_v01_52b", "deepseek_v3_671b"])
def test_tp_invariance(subproc, arch):
    out = subproc(_INVARIANCE % arch, n_devices=4, timeout=1800)
    assert "TP_INVARIANT_OK" in out
