"""Plan registry / autotuner properties.

- ``plan_seam`` always returns a valid (mode, chunk) combo (hypothesis over
  shapes), and its cache is keyed by ring direction.
- The JSON profile cache round-trips exactly and invalidates on version /
  mesh / backend mismatch.
- Measured tuning on CPU picks a config whose measured time is <= every
  candidate's.
"""
import dataclasses
import json
import os

import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import overlap, planner
from repro.tuning import autotune
from repro.tuning.cache import PROFILE_VERSION, PlanRegistry, entry_key
from repro.tuning.plans import (KNOWN_SEAMS, PlanSet, SeamPlan,
                                plan_set_from_parallel)


# ---------------------------------------------------------------------------
# plan_seam validity (property)
# ---------------------------------------------------------------------------
@settings(max_examples=30, deadline=None)
@given(seam=st.sampled_from(["ag", "rs"]),
       m=st.integers(1, 65536), n=st.integers(1, 65536),
       k=st.integers(1, 16384), n_dev=st.sampled_from([2, 4, 8, 16, 64]),
       allow_flux=st.booleans())
def test_plan_seam_always_valid(seam, m, n, k, n_dev, allow_flux):
    plan = planner.plan_seam(seam, m, n, k, n_dev, allow_flux=allow_flux)
    assert plan.mode in overlap.VALID_MODES
    assert not (plan.mode == "flux" and not allow_flux)
    assert plan.comm_chunks >= 0
    if plan.mode != "decomposed":
        assert plan.comm_chunks == 0        # chunking is a ring-mode knob
    assert len(plan.blocks) == 3 and all(b >= 1 for b in plan.blocks)
    assert plan.predicted_overall_s > 0


@settings(max_examples=20, deadline=None)
@given(kind=st.sampled_from(["ag", "rs", "ar"]),
       m=st.integers(8, 16384), n=st.integers(8, 16384),
       k=st.integers(8, 8192), n_dev=st.sampled_from([2, 4, 8]))
def test_candidate_space_and_analytic_tuner_valid(kind, m, n, k, n_dev):
    res = autotune.tune_seam(kind, m, n, k, n_dev, measure=False)
    assert res.plan.mode in overlap.VALID_MODES
    assert res.plan.validate() is res.plan
    assert res.table, "tuner must enumerate candidates"
    # winner really is the argmin of the analytic table
    assert res.plan.predicted_s <= min(r["predicted_s"] for r in res.table)
    for row in res.table:
        assert row["mode"] in overlap.VALID_MODES
        assert row["predicted_s"] > 0


def test_cache_keyed_by_ring_direction():
    """Regression: a plan cached for one ring direction must never answer
    for the other (the pre-registry cache ignored ``reverse``)."""
    planner._CACHE.clear()
    fwd = planner.plan_seam("ag", 2048, 1024, 512, 4, reverse=False)
    rev = planner.plan_seam("ag", 2048, 1024, 512, 4, reverse=True)
    assert fwd.reverse is False
    assert rev.reverse is True
    # distinct cache entries, not one clobbering the other
    again_fwd = planner.plan_seam("ag", 2048, 1024, 512, 4, reverse=False)
    assert again_fwd.reverse is False
    keys = [k for k in planner._CACHE if k[0] == "ag" and k[1] == 2048]
    assert len(keys) == 2


# ---------------------------------------------------------------------------
# profile cache round-trip + staleness
# ---------------------------------------------------------------------------
def _plan(**kw) -> SeamPlan:
    base = dict(mode="decomposed", comm_chunks=8, reverse=True,
                blocks=(128, 512, 128), source="measured",
                predicted_s=1.5e-4, measured_s=1.2e-4)
    base.update(kw)
    return SeamPlan(**base)


def test_profile_roundtrip(tmp_path):
    path = str(tmp_path / "prof.json")
    reg = PlanRegistry(n_dev=4, backend="cpu")
    reg.record("mlp_ag", "ag", 4096, 1024, 512, _plan())
    reg.record("mlp_rs", "rs", 4096, 512, 1024,
               _plan(mode="decomposed_bidir", reverse=False))
    reg.record("decode_ar", "ar", 8, 512, 1024, _plan(mode="xla",
                                                      comm_chunks=0))
    reg.save(path)

    reg2 = PlanRegistry.open(path, n_dev=4, backend="cpu")
    assert reg2.entries == reg.entries
    assert reg2.lookup("mlp_ag", 4096, 1024, 512) == _plan()
    assert reg2.lookup("mlp_ag", 4096, 1024, 513) is None   # exact shapes
    seams = reg2.seam_plans()
    assert set(seams) == {"mlp_ag", "mlp_rs", "decode_ar"}
    assert seams["mlp_rs"].mode == "decomposed_bidir"


def test_profile_stale_on_version_mismatch(tmp_path):
    path = str(tmp_path / "prof.json")
    reg = PlanRegistry(n_dev=4, backend="cpu")
    reg.record("mlp_ag", "ag", 4096, 1024, 512, _plan())
    reg.save(path)
    doc = json.load(open(path))
    doc["version"] = PROFILE_VERSION + 1
    json.dump(doc, open(path, "w"))
    assert not PlanRegistry.open(path, n_dev=4, backend="cpu").entries


def test_profile_stale_on_mesh_or_backend_mismatch(tmp_path):
    path = str(tmp_path / "prof.json")
    reg = PlanRegistry(n_dev=4, backend="cpu")
    reg.record("mlp_ag", "ag", 4096, 1024, 512, _plan())
    reg.save(path)
    assert not PlanRegistry.open(path, n_dev=8, backend="cpu").entries
    assert not PlanRegistry.open(path, n_dev=4, backend="tpu").entries
    assert PlanRegistry.open(path, n_dev=4, backend="cpu").entries


def test_profile_missing_or_corrupt_is_empty(tmp_path):
    assert not PlanRegistry.open(str(tmp_path / "nope.json"), n_dev=4).entries
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    assert not PlanRegistry.open(str(bad), n_dev=4).entries


def test_plan_set_from_parallel_profile(tmp_path):
    from repro.configs.base import ParallelConfig
    import jax
    path = str(tmp_path / "prof.json")
    reg = PlanRegistry(n_dev=4, backend=jax.default_backend())
    reg.record("mlp_rs", "rs", 4096, 512, 1024, _plan(mode="xla",
                                                      comm_chunks=0,
                                                      reverse=False))
    reg.save(path)
    par = ParallelConfig(tp=4, dp=1, overlap_mode="decomposed",
                         plan_profile=path)
    ps = plan_set_from_parallel(par)
    assert ps.resolve("mlp_rs").mode == "xla"
    assert ps.resolve("mlp_ag").mode == "decomposed"     # default fallback
    # mesh mismatch -> whole profile ignored
    par8 = dataclasses.replace(par, tp=8)
    ps8 = plan_set_from_parallel(par8)
    assert ps8.resolve("mlp_rs").mode == "decomposed"


# ---------------------------------------------------------------------------
# PlanSet resolution semantics
# ---------------------------------------------------------------------------
def test_plan_set_resolution_order():
    ps = PlanSet(default=SeamPlan(mode="xla"),
                 seams={"mlp_ag": SeamPlan(mode="decomposed", comm_chunks=8)},
                 layers={2: {"mlp_ag": SeamPlan(mode="decomposed_bidir")}})
    assert ps.resolve("mlp_ag").mode == "decomposed"
    assert ps.resolve("mlp_ag", layer=2).mode == "decomposed_bidir"
    assert ps.resolve("mlp_ag", layer=1).mode == "decomposed"
    assert ps.resolve("attn_rs", layer=2).mode == "xla"
    assert ps.resolve("totally_unknown_seam").mode == "xla"
    # functional override
    ps2 = ps.override("attn_rs", SeamPlan(mode="decomposed"), layer=0)
    assert ps2.resolve("attn_rs", layer=0).mode == "decomposed"
    assert ps.resolve("attn_rs", layer=0).mode == "xla"   # original untouched
    # JSON round-trip
    ps3 = PlanSet.from_json(ps2.to_json())
    for seam in KNOWN_SEAMS:
        for layer in (None, 0, 2):
            assert ps3.resolve(seam, layer) == ps2.resolve(seam, layer)


def test_seam_plan_validation():
    with pytest.raises(ValueError):
        SeamPlan(mode="not_a_mode").validate()
    with pytest.raises(ValueError):
        SeamPlan(comm_chunks=-1).validate()


# ---------------------------------------------------------------------------
# measured tuning (CPU: still a real timed sweep; single-device fallback)
# ---------------------------------------------------------------------------
def test_candidate_space_sweeps_fusion_knobs():
    """FusedOp fusion knobs are plan-visible tuner candidates: a two-weight
    epilogue seam sweeps shared_gather x fuse_epilogue, the roofline prefers
    the fused/shared corner, and the knobs survive the profile round-trip."""
    cands = autotune.candidate_space("ag", 4096, 1024, 512, 4,
                                     n_weights=2, epilogue=True)
    combos = {(c.shared_gather, c.fuse_epilogue) for c in cands
              if c.mode != "xla"}
    assert combos == {(True, True), (True, False), (False, True),
                      (False, False)}
    # xla's monolithic gather consumes neither knob -> exactly one
    # candidate per (xla, wire_dtype) (no byte-identical duplicate rows)
    xla = [c for c in cands if c.mode == "xla"]
    assert len(xla) == len({c.wire_dtype for c in xla})
    # plain seams don't blow up the candidate table
    plain = autotune.candidate_space("ag", 4096, 1024, 512, 4)
    assert all(c.shared_gather and c.fuse_epilogue for c in plain)
    n_xla = sum(1 for c in plain if c.mode == "xla")
    assert len(cands) == 4 * (len(plain) - n_xla) + n_xla
    # rs/ar epilogues apply once on the reduced output either way: no sweep
    rs_cands = autotune.candidate_space("rs", 4096, 512, 1024, 4,
                                        epilogue=True)
    assert all(c.shared_gather and c.fuse_epilogue for c in rs_cands)

    res = autotune.tune_seam("ag", 4096, 1024, 512, 4, measure=False,
                             n_weights=2, epilogue=True)
    assert res.plan.shared_gather and res.plan.fuse_epilogue
    # the analytic model really discriminates: unshared/unfused rows cost more
    for row in res.table:
        if row["mode"] != res.plan.mode or row["comm_chunks"] != \
                res.plan.comm_chunks or row["reverse"] != res.plan.reverse:
            continue
        if not row["shared_gather"] or not row["fuse_epilogue"]:
            assert row["predicted_s"] > res.plan.predicted_s

    rt = SeamPlan.from_json(res.plan.to_json())
    assert rt == res.plan
    assert SeamPlan.from_json(_plan().to_json()).shared_gather is True


def test_measured_tuning_picks_fastest_candidate():
    res = autotune.tune_seam("ag", 64, 64, 64, 4, measure=True,
                             iters=2, warmup=1)
    assert res.source == "measured"
    assert res.plan.source == "measured"
    assert res.plan.measured_s > 0
    assert res.plan.measured_s <= min(r["measured_s"] for r in res.table)
    # every candidate was actually timed
    assert all(r["measured_s"] > 0 for r in res.table)


def test_measured_tuning_auto_falls_back_to_analytic_on_cpu():
    # this process has ONE device and interpret mode on -> auto == analytic
    res = autotune.tune_seam("rs", 256, 128, 128, 4, measure="auto")
    assert res.source == "analytic"
    assert res.plan.predicted_s > 0


_MEASURED_4DEV = r"""
import jax
from repro.tuning import autotune
for kind, m in (("ag", 128), ("rs", 128), ("ar", 8)):
    res = autotune.tune_seam(kind, m, 128, 128, 4, measure=True,
                             iters=2, warmup=1)
    assert res.source == "measured"
    assert res.plan.measured_s > 0
    assert res.plan.measured_s <= min(r["measured_s"] for r in res.table)
    assert all(r["measured_s"] > 0 for r in res.table)
print("MEASURED_4DEV_OK")
"""


def test_measured_tuning_shard_mapped_4dev(subproc):
    """The measured sweep really runs shard_mapped overlap ops over the
    requested TP degree and returns the argmin of the timing table."""
    assert "MEASURED_4DEV_OK" in subproc(_MEASURED_4DEV, n_devices=4,
                                         timeout=1800)


def test_autotune_model_builds_plan_set_and_persists(tmp_path):
    from repro.configs.base import ParallelConfig, get_smoke_config
    cfg = get_smoke_config("codeqwen15_7b")
    par = ParallelConfig(tp=4, dp=1, overlap_mode="decomposed")
    path = str(tmp_path / "model_prof.json")
    reg = PlanRegistry(n_dev=4)
    ps = autotune.autotune_model(cfg, par, tokens_per_dp=512, measure=False,
                                 registry=reg, save_path=path)
    shapes = autotune.model_seam_shapes(cfg, par, 512)
    assert set(shapes) <= set(ps.seams.keys()) | set(KNOWN_SEAMS)
    for seam in shapes:
        assert ps.resolve(seam).mode in overlap.VALID_MODES
        # lossy wires must not be auto-selected for whole-model plans
        assert ps.resolve(seam).wire_dtype is None
    assert os.path.exists(path)
    # second run is served from the registry (same plans, no re-tune)
    reg2 = PlanRegistry.open(path, n_dev=4)
    ps2 = autotune.autotune_model(cfg, par, tokens_per_dp=512,
                                  measure=False, registry=reg2)
    for seam in shapes:
        assert ps2.resolve(seam) == ps.resolve(seam)
