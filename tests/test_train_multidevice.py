"""Full train-step integration on 4 devices (tp=2 × dp=2): exercises the
ZeRO-1 reduce-scatter/all-gather optimizer paths, model-replicated grad
psums, and hierarchical sync — loss must decrease and match a tp=1 run."""
import pytest

_TRAIN = r"""
import dataclasses
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh
from repro.configs.base import get_smoke_config, ParallelConfig
from repro.optim.adamw import AdamWConfig, init_opt_state
from repro.models import model as M
from repro.runtime import trainer as T
from repro.data.pipeline import batch_at

cfg = dataclasses.replace(get_smoke_config("codeqwen15_7b"), d_ff=512,
                          compute_dtype="float32")

def run(dp, tp, steps=4):
    par = ParallelConfig(tp=tp, dp=dp, overlap_mode="decomposed")
    mesh = Mesh(np.array(jax.devices()[:dp*tp]).reshape(dp, tp),
                ("data", "model"))
    tc = T.TrainConfig(total_steps=steps, warmup_steps=1, base_lr=3e-3,
                       log_every=100)
    tr = T.Trainer(cfg, par, mesh, tc)
    tr.data_cfg = dataclasses.replace(tr.data_cfg, seq_len=64, global_batch=4)
    with mesh:
        params, opt, hist = tr.train(resume=False)
    return [h["loss"] for h in hist]

l_11 = run(1, 1)
l_22 = run(2, 2)
l_14 = run(1, 4)
print("tp1dp1:", l_11)
print("tp2dp2:", l_22)
print("tp4dp1:", l_14)
assert l_22[-1] < l_22[0], "loss did not decrease under dp2xtp2"
# step 0 is pre-update -> layout-exact; later steps drift only via bf16
# param-update rounding (different-but-valid summation layouts)
assert abs(l_11[0] - l_22[0]) < 1e-5, (l_11[0], l_22[0])
assert abs(l_11[0] - l_14[0]) < 1e-5, (l_11[0], l_14[0])
for a, b in zip(l_11, l_22):
    assert abs(a - b) < 5e-2, (l_11, l_22)
for a, b in zip(l_11, l_14):
    assert abs(a - b) < 5e-2, (l_11, l_14)
print("TRAIN_MULTIDEV_OK")
"""


def test_train_step_multidevice(subproc):
    out = subproc(_TRAIN, n_devices=4, timeout=1800)
    assert "TRAIN_MULTIDEV_OK" in out
