"""Runtime substrate tests: checkpoint/restart, fault recovery, straggler
detection, elastic remesh, data pipeline determinism, optimizer."""
import dataclasses
import functools
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st
from jax.sharding import Mesh, PartitionSpec as P

from repro.checkpoint.checkpointer import Checkpointer
from repro.compat import shard_map
from repro.configs.base import ParallelConfig, get_smoke_config
from repro.data.pipeline import DataConfig, DataStream, batch_at
from repro.optim import adamw, schedule
from repro.runtime import trainer as T


def _mesh():
    return Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(step=st.integers(0, 10_000), seed=st.integers(0, 5))
def test_data_deterministic_seekable(step, seed):
    cfg = DataConfig(vocab_size=1000, seq_len=16, global_batch=4, seed=seed)
    a = batch_at(cfg, step)
    b = batch_at(cfg, step)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert a["tokens"].min() >= 0 and a["tokens"].max() < 1000
    # labels are next-token shifted
    np.testing.assert_array_equal(a["labels"][:, :-1], a["tokens"][:, 1:])


def test_data_shards_disjoint():
    cfg = DataConfig(vocab_size=50_000, seq_len=64, global_batch=8)
    s0 = batch_at(cfg, 3, shard=0, num_shards=2)
    s1 = batch_at(cfg, 3, shard=1, num_shards=2)
    assert not np.array_equal(s0["tokens"], s1["tokens"])
    full = batch_at(cfg, 3, shard=0, num_shards=1)
    np.testing.assert_array_equal(full["tokens"][:4], s0["tokens"])
    np.testing.assert_array_equal(full["tokens"][4:], s1["tokens"])


def test_datastream_resume():
    cfg = DataConfig(vocab_size=100, seq_len=8, global_batch=2)
    s = DataStream(cfg)
    batches = [next(s) for _ in range(5)]
    s2 = DataStream(cfg, start_step=3)
    np.testing.assert_array_equal(next(s2)["tokens"], batches[3]["tokens"])


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------
def test_schedules():
    cos = schedule.cosine(jnp.arange(100), base_lr=1.0, warmup=10, total=100)
    assert float(cos[0]) == 0.0
    assert float(cos[9]) <= 1.0
    assert float(cos[99]) < float(cos[50])
    wsd = schedule.wsd(jnp.arange(100), base_lr=1.0, warmup=10, total=100)
    # stable plateau
    assert abs(float(wsd[50]) - 1.0) < 1e-6
    assert float(wsd[99]) < 0.2


# ---------------------------------------------------------------------------
# checkpointer
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(10, dtype=jnp.float32),
            "b": {"c": jnp.ones((3, 4), jnp.bfloat16)}}
    ck.save(10, tree, extra={"foo": 1}, blocking=True)
    got, step, extra = ck.restore(tree)
    assert step == 10 and extra == {"foo": 1}
    np.testing.assert_array_equal(np.asarray(got["a"]), np.arange(10))

    # async save + gc
    for s in (20, 30, 40):
        ck.save(s, tree)
    ck.wait()
    assert ck.all_steps() == [30, 40]


def test_checkpoint_corruption_detected(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = {"w": jnp.ones((4,), jnp.float32)}
    ck.save(1, tree, blocking=True)
    # corrupt the shard
    import numpy as _np
    path = os.path.join(str(tmp_path), "step_1", "shard_0.npz")
    _np.savez(path, w=_np.zeros((4,), _np.float32))
    with pytest.raises(IOError):
        ck.restore(tree)


# ---------------------------------------------------------------------------
# trainer: loss goes down, fault recovery, straggler counter
# ---------------------------------------------------------------------------
def _small_trainer(tmp_path, total_steps=6, arch="minicpm_2b"):
    cfg = get_smoke_config(arch)
    par = ParallelConfig(tp=1, dp=1)
    tc = T.TrainConfig(total_steps=total_steps, warmup_steps=2, base_lr=3e-3,
                       checkpoint_dir=str(tmp_path), checkpoint_every=2,
                       log_every=100)
    return T.Trainer(cfg, par, _mesh(), tc)


def test_trainer_loss_decreases(tmp_path):
    tr = _small_trainer(tmp_path, total_steps=8)
    params, opt, hist = tr.train(resume=False)
    assert len(hist) == 8
    first, last = hist[0]["loss"], hist[-1]["loss"]
    assert np.isfinite(last)
    assert last < first, f"loss did not decrease: {first} -> {last}"


def test_trainer_fault_recovery(tmp_path):
    tr = _small_trainer(tmp_path, total_steps=6)
    boom = {"armed": True}

    def fault_hook(step):
        if step == 4 and boom["armed"]:
            boom["armed"] = False
            raise RuntimeError("simulated device failure")

    params, opt, hist = tr.train(resume=False, fault_hook=fault_hook)
    assert tr.failures == 1
    assert tr.step == 6
    # recovery reloaded from step-4 checkpoint (checkpoint_every=2)
    assert len(hist) >= 2


def test_trainer_resume_from_checkpoint(tmp_path):
    tr = _small_trainer(tmp_path, total_steps=4)
    tr.train(resume=False)
    tr2 = _small_trainer(tmp_path, total_steps=6)
    params, opt, hist = tr2.train(resume=True)
    assert tr2.step == 6
    assert len(hist) == 2          # only steps 4..6 ran


# ---------------------------------------------------------------------------
# elastic remesh
# ---------------------------------------------------------------------------
def test_elastic_remesh_subprocess(subproc):
    code = r"""
import jax
from repro.launch.mesh import elastic_remesh
mesh = elastic_remesh(surviving_devices=3, tp=1)
assert mesh.devices.shape == (3, 1), mesh.devices.shape
mesh = elastic_remesh(surviving_devices=3, tp=2)
assert mesh.devices.shape == (1, 2), mesh.devices.shape
try:
    elastic_remesh(surviving_devices=1, tp=2)
    raise SystemExit("expected failure")
except RuntimeError:
    pass
print("ELASTIC_OK")
"""
    assert "ELASTIC_OK" in subproc(code, n_devices=4)


# ---------------------------------------------------------------------------
# optimizer pieces
# ---------------------------------------------------------------------------
def test_int8_quant_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 5
    q, s = adamw._quantize_int8(x)
    deq = (q.astype(jnp.float32) * s).reshape(-1)[:1000]
    err = float(jnp.max(jnp.abs(deq - x)))
    assert err < 5 * 2 / 127  # block-max / 127 quantization step


def test_adamw_single_device_matches_reference():
    """adamw_update on a 1-device mesh == textbook AdamW."""
    mesh = _mesh()
    p = {"w": jnp.ones((8, 4), jnp.float32)}
    g = {"w": jnp.full((8, 4), 0.5, jnp.float32)}
    specs = {"w": P(None, None)}
    opt = adamw.init_opt_state(p)
    cfg = adamw.AdamWConfig(lr=1e-1, weight_decay=0.0, grad_clip=1e9)

    @jax.jit
    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(specs, specs,
                                 {"mu": specs, "nu": specs, "count": P()}),
                       out_specs=(specs,
                                  {"mu": specs, "nu": specs, "count": P()}),
                       check_vma=False)
    def step(pp, gg, oo):
        return adamw.adamw_update(pp, gg, oo, cfg, jnp.float32(0.1),
                                  specs=specs, dp_axis="data", pod_axis=None)

    newp, newo = step(p, g, opt)
    # textbook first step: m=0.1*g/, v=..., update = lr * m_hat/(sqrt(v_hat)+eps)
    m_hat = 0.5
    v_hat = 0.25
    want = 1.0 - 0.1 * m_hat / (np.sqrt(v_hat) + 1e-8)
    np.testing.assert_allclose(np.asarray(newp["w"]), want, rtol=1e-5)


# ---------------------------------------------------------------------------
# checkpoint property test: arbitrary pytrees roundtrip exactly
# ---------------------------------------------------------------------------
@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), depth=st.integers(1, 3),
       use_bf16=st.booleans())
def test_checkpoint_roundtrip_property(tmp_path_factory, seed, depth,
                                       use_bf16):
    import ml_dtypes
    rng = np.random.default_rng(seed)
    dt = ml_dtypes.bfloat16 if use_bf16 else np.float32

    def make(d):
        if d == 0:
            return jnp.asarray(rng.normal(size=(int(rng.integers(1, 5)),
                                                int(rng.integers(1, 5))))
                               .astype(dt))
        return {f"k{i}": make(d - 1) for i in range(2)}

    tree = make(depth)
    ck = Checkpointer(str(tmp_path_factory.mktemp("ck")))
    ck.save(1, tree, blocking=True)
    got, step, _ = ck.restore(tree)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
