"""Per-kernel allclose sweeps against the ref.py oracles (interpret mode)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops as kops
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention


@pytest.mark.parametrize("m,k,n", [(256, 256, 256), (384, 640, 256),
                                   (128, 1024, 512), (512, 384, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_kernel(m, k, n, dtype):
    key = jax.random.PRNGKey(m + k + n)
    a = jax.random.normal(key, (m, k), dtype)
    b = jax.random.normal(jax.random.PRNGKey(1), (k, n), dtype)
    out = kops.matmul(a, b, interpret=True)
    want = ref.matmul_ref(a, b).astype(dtype)
    tol = 1e-5 if dtype == jnp.float32 else 2e-1
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol * np.sqrt(k), rtol=tol)


@pytest.mark.parametrize("b,hq,hkv,s,d", [
    (2, 4, 2, 256, 64), (1, 8, 8, 512, 32), (1, 4, 1, 128, 64),
    (2, 2, 2, 384, 128),
])
def test_flash_attention(b, hq, hkv, s, d):
    q = jax.random.normal(jax.random.PRNGKey(1), (b, hq, s, d), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(2), (b, hkv, s, d), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(3), (b, hkv, s, d), jnp.float32)
    o = flash_attention(q, k, v, causal=True, bq=128, bkv=128, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o), np.asarray(want), atol=2e-5,
                               rtol=2e-5)


def test_flash_attention_noncausal():
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 2, 256, 64))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 2, 256, 64))
    v = jax.random.normal(jax.random.PRNGKey(3), (1, 2, 256, 64))
    o = flash_attention(q, k, v, causal=False, bq=128, bkv=128,
                        interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(o), np.asarray(want), atol=2e-5,
                               rtol=2e-5)


# ---------------------------------------------------------------------------
# distributed fused kernels: ring AG-GEMM / GEMM-RS on 4 virtual devices
# ---------------------------------------------------------------------------
_RING_TEST = r"""
import functools
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map
from repro.kernels import ops as kops

mesh = Mesh(np.array(jax.devices()), ("tp",))
for (M, K, N, dtype, reverse) in [
        (512, 512, 512, jnp.float32, False),
        (512, 512, 512, jnp.float32, True),
        (1024, 256, 512, jnp.bfloat16, False),
        (512, 768, 1024, jnp.float32, False)]:
    A = jax.random.normal(jax.random.PRNGKey(0), (M, K), dtype)
    B = jax.random.normal(jax.random.PRNGKey(1), (K, N), dtype)

    @jax.jit
    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(P("tp", None), P(None, "tp")),
                       out_specs=P(None, "tp"), check_vma=False)
    def ag(a, b):
        return kops.ag_matmul_fused(a, b, axis_name="tp", reverse=%s)

    out = ag(A, B)
    want = jnp.dot(A.astype(jnp.float32), B.astype(jnp.float32))
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32) - want)))
    tol = 1e-3 * K**0.5 if dtype == jnp.float32 else 0.5 * K**0.5
    assert err < tol, ("ag", M, K, N, dtype, err)

    @jax.jit
    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(P(None, "tp"), P("tp", None)),
                       out_specs=P("tp", None), check_vma=False)
    def rs(a, b):
        return kops.matmul_rs_fused(a, b, axis_name="tp", reverse=%s)

    out = rs(A, B)
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32) - want)))
    assert err < tol, ("rs", M, K, N, dtype, err)
print("RING_OK")
"""


@pytest.mark.parametrize("reverse", [False, True])
def test_fused_ring_kernels_4dev(subproc, reverse):
    out = subproc(_RING_TEST % (reverse, reverse), n_devices=4)
    assert "RING_OK" in out


# ---------------------------------------------------------------------------
# kernel tile-epilogue hook: act(AG(A)@B + bias) / act(RS(A@B) + bias)
# ---------------------------------------------------------------------------
_EPILOGUE_TEST = r"""
import functools
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map
from repro.kernels import ops as kops

mesh = Mesh(np.array(jax.devices()), ("tp",))
M, K, N = 512, 256, 512
A = jax.random.normal(jax.random.PRNGKey(0), (M, K), jnp.float32)
B = jax.random.normal(jax.random.PRNGKey(1), (K, N), jnp.float32)
bias = jax.random.normal(jax.random.PRNGKey(2), (N,), jnp.float32) * 0.5
want = jnp.dot(A, B)
tol = 1e-3 * K**0.5

for act, bias_on in [("silu", True), ("sqrelu", False), (None, True)]:
    bb = bias if bias_on else None
    ref = want + (bias if bias_on else 0.0)
    if act == "silu":
        ref = jax.nn.silu(ref)
    elif act == "sqrelu":
        ref = jnp.square(jax.nn.relu(ref))

    @jax.jit
    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(P("tp", None), P(None, "tp"), P("tp")),
                       out_specs=P(None, "tp"), check_vma=False)
    def ag(a, b, bi):
        return kops.ag_matmul_fused(a, b, axis_name="tp", activation=act,
                                    bias=bi if bias_on else None)
    err = float(jnp.max(jnp.abs(ag(A, B, bias) - ref)))
    assert err < tol, ("ag", act, bias_on, err)

    @jax.jit
    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(P(None, "tp"), P("tp", None), P(None)),
                       out_specs=P("tp", None), check_vma=False)
    def rs(a, b, bi):
        return kops.matmul_rs_fused(a, b, axis_name="tp", activation=act,
                                    bias=bi if bias_on else None)
    err = float(jnp.max(jnp.abs(rs(A, B, bias) - ref)))
    assert err < tol, ("rs", act, bias_on, err)
print("EPILOGUE_OK")
"""


def test_kernel_tile_epilogue_4dev(subproc):
    """bias + activation in the fused kernels' tile epilogue match the
    unfused reference (RS: bias must be applied exactly once, AFTER the
    full cross-rank reduction)."""
    assert "EPILOGUE_OK" in subproc(_EPILOGUE_TEST, n_devices=4)


@pytest.mark.parametrize("b,h,r,dr,s,valid", [
    (2, 4, 64, 16, 256, 200), (1, 8, 128, 32, 512, 512),
    (2, 2, 32, 8, 128, 1),
])
def test_mla_decode_kernel(b, h, r, dr, s, valid):
    from repro.kernels.mla_decode import mla_decode_attention
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    qe = jax.random.normal(ks[0], (b, h, r), jnp.float32)
    qr = jax.random.normal(ks[1], (b, h, dr), jnp.float32)
    c = jax.random.normal(ks[2], (b, s, r), jnp.bfloat16)
    kr = jax.random.normal(ks[3], (b, s, dr), jnp.bfloat16)
    vl = jnp.asarray(valid, jnp.int32)
    out = mla_decode_attention(qe, qr, c, kr, vl, scale=0.1, bs=128,
                               interpret=True)
    want = ref.mla_decode_attention_ref(qe, qr, c, kr, vl, 0.1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_mla_decode_kernel_per_row_lengths():
    """Per-slot decode: each batch row masks at its OWN valid length."""
    from repro.kernels.mla_decode import mla_decode_attention
    b, h, r, dr, s = 3, 4, 64, 16, 256
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    qe = jax.random.normal(ks[0], (b, h, r), jnp.float32)
    qr = jax.random.normal(ks[1], (b, h, dr), jnp.float32)
    c = jax.random.normal(ks[2], (b, s, r), jnp.bfloat16)
    kr = jax.random.normal(ks[3], (b, s, dr), jnp.bfloat16)
    vl = jnp.asarray([17, 200, 256], jnp.int32)
    out = mla_decode_attention(qe, qr, c, kr, vl, scale=0.1, bs=128,
                               interpret=True)
    want = ref.mla_decode_attention_ref(qe, qr, c, kr, vl, 0.1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
    # row b must equal a single-row call at its own length
    for i in range(b):
        solo = mla_decode_attention(qe[i:i + 1], qr[i:i + 1], c[i:i + 1],
                                    kr[i:i + 1], vl[i:i + 1], scale=0.1,
                                    bs=128, interpret=True)
        np.testing.assert_allclose(np.asarray(out[i]), np.asarray(solo[0]),
                                   atol=2e-5, rtol=2e-5)
