"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches must
see the real single CPU device; multi-device tests run in subprocesses that
set --xla_force_host_platform_device_count themselves."""
import os
import re
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")

# Every inline-code snippet run by ``run_subprocess_devices`` gets the repo
# on its path and MUST import JAX version-sensitive symbols (shard_map,
# compiler params, ...) through ``repro.compat`` — the spawned interpreter
# sees the same drifted JAX as the host process.
_FAILED_LINE_RE = re.compile(r'File "<string>", line (\d+)')


def _culprit_lines(code: str, stderr: str, context: int = 1) -> str:
    """Map ``File "<string>", line N`` frames in the traceback back to the
    inline source so failures show the offending snippet line, not just a
    generic assertion."""
    lines = code.splitlines()
    hits = [int(m) for m in _FAILED_LINE_RE.findall(stderr)
            if 1 <= int(m) <= len(lines)]
    if not hits:
        return ""
    ln = hits[-1]                       # innermost <string> frame
    lo, hi = max(1, ln - context), min(len(lines), ln + context)
    shown = "\n".join(f"{'>' if i == ln else ' '} {i:4d} | {lines[i - 1]}"
                      for i in range(lo, hi + 1))
    return f"\nfailing inline code (line {ln}):\n{shown}"


def run_subprocess_devices(code: str, n_devices: int = 4,
                           timeout: int = 900) -> str:
    """Run ``code`` in a fresh python with n forced host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env["REPRO_PALLAS_INTERPRET"] = "1"
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    if out.returncode != 0:
        raise AssertionError(
            f"subprocess exited {out.returncode} "
            f"(n_devices={n_devices}, REPRO_PALLAS_INTERPRET=1)"
            f"{_culprit_lines(code, out.stderr)}\n"
            f"STDOUT:{out.stdout[-4000:]}\n"
            f"STDERR:{out.stderr[-4000:]}")
    return out.stdout


@pytest.fixture(scope="session")
def subproc():
    return run_subprocess_devices


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "multidev: spawns a multi-device subprocess (skipped by "
        "scripts/verify.sh --fast)")


def pytest_collection_modifyitems(config, items):
    """Every test that uses the ``subproc`` fixture is a multi-device
    subprocess sweep — auto-mark so ``verify.sh --fast`` can skip them."""
    for item in items:
        if "subproc" in getattr(item, "fixturenames", ()):
            item.add_marker(pytest.mark.multidev)
