"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches must
see the real single CPU device; multi-device tests run in subprocesses that
set --xla_force_host_platform_device_count themselves."""
import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_subprocess_devices(code: str, n_devices: int = 4,
                           timeout: int = 900) -> str:
    """Run ``code`` in a fresh python with n forced host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env["REPRO_PALLAS_INTERPRET"] = "1"
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    if out.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:{out.stdout[-4000:]}\n"
            f"STDERR:{out.stderr[-4000:]}")
    return out.stdout


@pytest.fixture(scope="session")
def subproc():
    return run_subprocess_devices
