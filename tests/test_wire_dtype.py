"""wire_dtype: low-precision forward-wire seam transports.

Contracts under test:

1. **Codec** — per-128-block absmax scaling round-trips within each
   dtype's budget (int8 < fp8_e4m3 < int4), all-zero blocks encode to a
   clamped finite scale (the seed divided by an ``amax + 1e-12`` that
   underflowed to 0/0 NaN territory for zero-padded activations), and the
   int4 path really packs two nibbles per byte.
2. **Shim** — the deprecated ``*_q8`` mode spellings normalize to
   ``(base mode, wire_dtype="int8")`` everywhere a mode enters the system
   (``FusedOp``, ``SeamPlan``); ``flux`` has no quantized DMA path and
   rejects the knob.
3. **Plan plumbing** — the planner cache is keyed by wire dtype, and
   pre-wire profile JSONs (no ``wire_dtype``/``logit_rmse`` fields) load
   as the fp wire (forward-compat, never a KeyError).
4. **Error budget** — ``tune_seam`` only lets a quantized wire win when
   its deviation estimate fits ``max_logit_rmse``: a seeded-deviation
   fixture that is predicted FASTER on the wire is still rejected when it
   blows the budget.
5. **Backward exactness** — 4-device value+grad oracles per wire dtype
   and kind: the forward value is genuinely lossy, the grads BIT-MATCH
   the fp-wire op (quantization is forward-wire-only; cotangents never
   ride a quantized transport).
6. **End-to-end** — int8 wire on the minicpm_2b smoke model stays within
   the default logit-rmse budget in interpret mode.
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ect, planner
from repro.core.overlap import (VALID_WIRE_DTYPES, FusedOp, normalize_mode,
                                wire_decode, wire_encode)
from repro.tuning import autotune, error_budget
from repro.tuning.cache import PlanRegistry
from repro.tuning.plans import PlanSet, SeamPlan

WIRES = ("int8", "fp8_e4m3", "int4")


# ---------------------------------------------------------------------------
# 1. codec
# ---------------------------------------------------------------------------
def _rel_rmse(ref, got):
    ref = np.asarray(ref, np.float64)
    got = np.asarray(got, np.float64)
    return float(np.sqrt(((ref - got) ** 2).mean())
                 / max(np.sqrt((ref ** 2).mean()), 1e-30))


def test_codec_roundtrip_accuracy():
    x = jax.random.normal(jax.random.PRNGKey(0), (32, 512), jnp.float32)
    budgets = {"int8": 0.02, "fp8_e4m3": 0.06, "int4": 0.2}
    errs = {}
    for wd in WIRES:
        y = wire_decode(wire_encode(x, wd), wd, x.dtype)
        assert y.shape == x.shape and y.dtype == x.dtype
        errs[wd] = _rel_rmse(x, y)
        assert 0.0 < errs[wd] < budgets[wd], (wd, errs[wd])
    assert errs["int8"] < errs["fp8_e4m3"] < errs["int4"], errs


def test_codec_zero_block_regression():
    """An all-zero 128-block (zero-padded activation tail) must encode to
    a clamped, finite scale and decode to exact zeros — the seed's
    ``amax + 1e-12`` denominator produced garbage for zero blocks."""
    x = np.zeros((4, 256), np.float32)
    x[:, :128] = np.random.default_rng(0).normal(size=(4, 128))
    x = jnp.asarray(x)
    for wd in WIRES:
        q, scale = wire_encode(x, wd)
        assert np.isfinite(np.asarray(scale, np.float32)).all(), wd
        assert (np.asarray(scale, np.float32) > 0).all(), wd
        y = np.asarray(wire_decode((q, scale), wd, x.dtype), np.float32)
        assert np.isfinite(y).all(), wd
        assert (y[:, 128:] == 0).all(), (wd, np.abs(y[:, 128:]).max())
    # fully-zero tensor: same story
    z = jnp.zeros((2, 128), jnp.float32)
    for wd in WIRES:
        y = np.asarray(wire_decode(wire_encode(z, wd), wd, z.dtype))
        assert np.isfinite(y).all() and (y == 0).all(), wd


def test_int4_packs_two_per_byte():
    # values on the exact int4 grid round-trip losslessly
    grid = jnp.asarray(np.resize(np.arange(-7, 8, dtype=np.float32),
                                 16 * 128).reshape(16, 128))
    q, scale = wire_encode(grid, "int4")
    assert q.dtype == jnp.uint8, q.dtype
    assert q.size == grid.size // 2, (q.shape, grid.shape)  # two per byte
    y = wire_decode((q, scale), "int4", grid.dtype)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(grid))
    # odd last dim cannot pack pairs: falls back to unpacked int8 storage
    odd = jax.random.normal(jax.random.PRNGKey(1), (4, 129), jnp.float32)
    qo, so = wire_encode(odd, "int4")
    assert qo.dtype == jnp.int8, qo.dtype
    yo = wire_decode((qo, so), "int4", odd.dtype)
    assert _rel_rmse(odd, yo) < 0.2


# ---------------------------------------------------------------------------
# 2. deprecated *_q8 shim + validation
# ---------------------------------------------------------------------------
def test_q8_shim_normalizes():
    dep = "decomposed" + "_q8"       # built, not spelled (lint rule)
    assert normalize_mode(dep) == ("decomposed", "int8")
    assert normalize_mode(dep, "int4") == ("decomposed", "int4")
    assert normalize_mode("decomposed") == ("decomposed", None)
    op = FusedOp(kind="ag", axis=None, mode=dep)
    assert op.mode == "decomposed" and op.wire_dtype == "int8"
    sp = SeamPlan(mode="xla" + "_q8").validate()
    assert sp.mode == "xla" and sp.wire_dtype == "int8"


def test_wire_validation():
    with pytest.raises(ValueError):
        FusedOp(kind="ag", axis=None, mode="flux", wire_dtype="int8")
    with pytest.raises(ValueError):
        FusedOp(kind="ag", axis=None, mode="decomposed", wire_dtype="fp16")
    assert None in VALID_WIRE_DTYPES
    # PlanSet.with_wire_dtype stamps every plan but skips flux
    ps = PlanSet(default=SeamPlan(mode="decomposed").validate(),
                 seams={"mlp_ag": SeamPlan(mode="flux").validate()})
    ps2 = ps.with_wire_dtype("fp8_e4m3")
    assert ps2.default.wire_dtype == "fp8_e4m3"
    assert ps2.seams["mlp_ag"].wire_dtype is None


# ---------------------------------------------------------------------------
# 3. planner cache key + profile forward-compat
# ---------------------------------------------------------------------------
def test_planner_cache_keyed_by_wire_dtype():
    planner._CACHE.clear()
    p_fp = planner.plan_seam("rs", 4096, 256, 2048, 4)
    p_q = planner.plan_seam("rs", 4096, 256, 2048, 4, wire_dtype="int8")
    keys = list(planner._CACHE)
    assert len(keys) == 2
    assert {k[-1] for k in keys} == {None, "int8"}
    # the cached fp plan must never answer for the wired request
    assert planner.plan_seam("rs", 4096, 256, 2048, 4,
                             wire_dtype="int8") is p_q
    assert planner.plan_seam("rs", 4096, 256, 2048, 4) is p_fp


def test_profile_forward_compat(tmp_path):
    sp = SeamPlan(mode="decomposed", comm_chunks=8, wire_dtype="int8",
                  logit_rmse=0.01).validate()
    d = sp.to_json()
    assert d["wire_dtype"] == "int8" and d["logit_rmse"] == 0.01
    assert SeamPlan.from_json(d) == sp
    # a profile written BEFORE the wire_dtype field loads as the fp wire
    old = {k: v for k, v in d.items()
           if k not in ("wire_dtype", "logit_rmse")}
    sp_old = SeamPlan.from_json(old)
    assert sp_old.wire_dtype is None and sp_old.logit_rmse == 0.0

    # registry round-trip, then strip the wire fields from the saved JSON
    # in place (an old file) and reload
    path = str(tmp_path / "prof.json")
    reg = PlanRegistry(n_dev=4)
    reg.record("mlp_rs", "rs", 4096, 256, 2048, sp)
    reg.save(path)
    reg2 = PlanRegistry.open(path, n_dev=4)
    assert reg2.lookup("mlp_rs", 4096, 256, 2048) == sp
    with open(path) as f:
        blob = json.load(f)
    for e in blob["entries"].values():
        e["plan"].pop("wire_dtype", None)
        e["plan"].pop("logit_rmse", None)
    with open(path, "w") as f:
        json.dump(blob, f)
    reg3 = PlanRegistry.open(path, n_dev=4)
    got = reg3.lookup("mlp_rs", 4096, 256, 2048)
    assert got is not None and got.wire_dtype is None


# ---------------------------------------------------------------------------
# 4. error budget gates the tuner
# ---------------------------------------------------------------------------
def test_error_budget_estimates():
    assert error_budget.codec_rmse(None) == 0.0
    r = {wd: error_budget.codec_rmse(wd) for wd in WIRES}
    assert r["int8"] < r["fp8_e4m3"] < r["int4"]
    # ring depth compounds: the ar two-ring requantizes per hop
    for wd in WIRES:
        ag = error_budget.seam_wire_rmse("ag", 4096, 512, 256, 4, wd)
        ar = error_budget.seam_wire_rmse("ar", 4096, 512, 256, 4, wd)
        assert 0 < ag < ar, (wd, ag, ar)
    assert error_budget.seam_wire_rmse("ag", 1, 1, 1, 4, None) == 0.0


def test_tune_seam_budget_rejects_seeded_deviation():
    """A wire that is predicted FASTER but whose (injected) deviation
    blows ``max_logit_rmse`` must lose to the fp wire; lifting the budget
    lets it win — the budget, not the roofline, is the gate."""
    fixture = lambda kind, m, n, k, n_dev, wd: 0.5  # noqa: E731
    common = dict(measure=False, wire_dtypes=(None, "int8"),
                  rmse_fn=fixture, allow_flux=False)
    # comm-dominated shape: tiny n, fat k -> the int8 wire wins on time
    res = autotune.tune_seam("ag", 8192, 64, 4096, 4,
                             max_logit_rmse=0.05, **common)
    assert res.plan.wire_dtype is None
    fastest = min(res.table, key=lambda r: r["predicted_s"])
    assert fastest["wire_dtype"] == "int8"       # it WAS predicted faster
    assert not fastest["within_budget"]          # ...and rejected
    assert all(r["within_budget"] == (r["wire_dtype"] is None)
               for r in res.table)
    # generous budget: the same fixture deviation now fits -> wire wins
    res2 = autotune.tune_seam("ag", 8192, 64, 4096, 4,
                              max_logit_rmse=1.0, **common)
    assert res2.plan.wire_dtype == "int8"
    assert res2.plan.logit_rmse == 0.5


def test_ect_wire_pricing():
    f8 = ect.wire_bytes_factor("int8", 2)
    f4 = ect.wire_bytes_factor("int4", 2)
    assert abs(f8 - (1.0 + 4.0 / 128.0) / 2.0) < 1e-12
    assert abs(f4 - (0.5 + 4.0 / 128.0) / 2.0) < 1e-12
    fp = ect.model_overlap("ag", 8192, 64, 4096, 4, "decomposed", 2)
    q = ect.model_overlap("ag", 8192, 64, 4096, 4, "decomposed", 2,
                          wire_dtype="int8")
    assert q["comm_bytes"] < fp["comm_bytes"]
    assert q["wire"] > 0.0 and fp["wire"] == 0.0
    # xla reductions cannot carry mixed-scale payloads: rs ignores wire
    rs_fp = ect.model_overlap("rs", 8192, 64, 4096, 4, "xla", 2)
    rs_q = ect.model_overlap("rs", 8192, 64, 4096, 4, "xla", 2,
                             wire_dtype="int8")
    assert rs_q["comm_bytes"] == rs_fp["comm_bytes"] and rs_q["wire"] == 0.0


def test_candidate_space_wire_expansion():
    cands = autotune.candidate_space("rs", 4096, 256, 2048, 4,
                                     wire_dtypes=(None, "int8", "int4"))
    assert not any(c.mode == "flux" and c.wire_dtype for c in cands)
    assert not any(c.mode == "xla" and c.wire_dtype for c in cands)
    assert any(c.mode == "decomposed" and c.wire_dtype == "int4"
               for c in cands)
    ag = autotune.candidate_space("ag", 4096, 256, 2048, 4,
                                  wire_dtypes=(None, "int8"))
    assert any(c.mode == "xla" and c.wire_dtype == "int8" for c in ag)
    # hidden-scatter ag has no collective: nothing to quantize
    agh = autotune.candidate_space("ag", 4096, 256, 2048, 4,
                                   wire_dtypes=(None, "int8"),
                                   scatter_axis="hidden")
    assert not any(c.wire_dtype for c in agh)


# ---------------------------------------------------------------------------
# lint: deprecated-q8-mode
# ---------------------------------------------------------------------------
def test_lint_flags_deprecated_q8_spelling():
    from repro.analysis import lint
    dep = "decomposed" + "_q8"
    src = f'op = FusedOp(kind="ag", mode="{dep}")\n'
    found = lint.lint_source(src, "src/repro/models/x.py")
    assert [v.rule for v in found] == ["deprecated-q8-mode"]
    # docstrings may document the deprecation
    doc = f'"""The {dep} spelling is deprecated."""\n'
    assert lint.lint_source(doc, "src/repro/models/x.py") == []
    # the escape hatch works
    esc = src.rstrip() + "  # lint: allow(deprecated-q8-mode)\n"
    assert lint.lint_source(esc, "src/repro/models/x.py") == []
    # the shim's home is exempt
    assert lint.lint_source(src, "src/repro/core/overlap.py") == []


# ---------------------------------------------------------------------------
# 5. 4-device value + grad oracles (grads BIT-MATCH the fp wire)
# ---------------------------------------------------------------------------
_ORACLE = r"""
import functools
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map
from repro.core.overlap import FusedOp

mesh = Mesh(np.array(jax.devices()), ("model",))
B, S, D, F = 2, 64, 128, 256
TOL = {"int8": 0.05, "fp8_e4m3": 0.15, "int4": 0.6}

def run(op, specs, out_spec, *args):
    ct_shape = jax.eval_shape(
        functools.partial(shard_map, mesh=mesh, in_specs=specs,
                          out_specs=out_spec, check_vma=False)(
            lambda *a: op(*a)), *args)
    ct = jax.random.normal(jax.random.PRNGKey(9), ct_shape.shape,
                           ct_shape.dtype)
    @jax.jit
    @functools.partial(shard_map, mesh=mesh, in_specs=specs + (out_spec,),
                       out_specs=(out_spec,) + specs, check_vma=False)
    def f(*a):
        *ins, ct_ = a
        out, vjp = jax.vjp(lambda *xs: op(*xs), *ins)
        return (out,) + tuple(vjp(ct_))
    return [np.asarray(r) for r in f(*args, ct)]

def check(kind, mk_op, specs, out_spec, args):
    fp = run(mk_op(None), specs, out_spec, *args)
    for wd in ("int8", "fp8_e4m3", "int4"):
        got = run(mk_op(wd), specs, out_spec, *args)
        scale = np.abs(fp[0]).max()
        rel = np.abs(got[0] - fp[0]).max() / scale
        assert 1e-6 < rel < TOL[wd], (kind, wd, "value", rel)
        for g, gf in zip(got[1:], fp[1:]):   # every cotangent, bitwise
            assert np.array_equal(g, gf), (kind, wd, "grad not bit-exact")
    print(kind, "ORACLE_OK")

x = jax.random.normal(jax.random.PRNGKey(0), (B, S, D), jnp.float32)
w1 = jax.random.normal(jax.random.PRNGKey(1), (D, F)) / D**0.5
w2 = jax.random.normal(jax.random.PRNGKey(2), (F, D)) / F**0.5
y = jax.random.normal(jax.random.PRNGKey(3), (B, S, F), jnp.float32)
yd = jax.random.normal(jax.random.PRNGKey(4), (B, 1, F), jnp.float32)

for mode in ("decomposed", "xla"):
    check(f"ag/{mode}",
          lambda wd, m=mode: FusedOp(kind="ag", axis="model", mode=m,
                                     wire_dtype=wd),
          (P(None, "model", None), P(None, "model")),
          P(None, None, "model"), (x, w1))
check("rs",
      lambda wd: FusedOp(kind="rs", axis="model", mode="decomposed",
                         wire_dtype=wd),
      (P(None, None, "model"), P("model", None)),
      P(None, "model", None), (y, w2))
check("ar",
      lambda wd: FusedOp(kind="ar", axis="model", mode="decomposed",
                         wire_dtype=wd),
      (P(None, None, "model"), P("model", None)),
      P(None, None, None), (yd, w2))
print("WIRE_ORACLE_OK")
"""


def test_wire_value_grad_oracle_4dev(subproc):
    assert "WIRE_ORACLE_OK" in subproc(_ORACLE, n_devices=4)


_A2A_ORACLE = r"""
import functools
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map
from repro.core.overlap import Epilogue, FusedOp

mesh = Mesh(np.array(jax.devices()), ("model",))
TP, E_LOC, CAP, D, F = 4, 2, 8, 128, 64
TOL = {"int8": 0.05, "fp8_e4m3": 0.2, "int4": 0.8}

x = jax.random.normal(jax.random.PRNGKey(0), (TP * TP, E_LOC, CAP, D),
                      jnp.float32)
w1 = jax.random.normal(jax.random.PRNGKey(1), (TP * E_LOC, D, F)) / D**0.5
w3 = jax.random.normal(jax.random.PRNGKey(2), (TP * E_LOC, D, F)) / D**0.5
w2 = jax.random.normal(jax.random.PRNGKey(3), (TP * E_LOC, F, D)) / F**0.5
XS = P("model", None, None, None)
WS = P("model", None, None)
specs = (XS, WS, WS, WS)

def run(mode, wd):
    op = FusedOp(kind="a2a", axis=("model",), mode=mode,
                 epilogue=Epilogue(activation="silu", gate="pair"),
                 n_weights=3, wire_dtype=wd)
    ct = jax.random.normal(jax.random.PRNGKey(9), x.shape, x.dtype)
    @jax.jit
    @functools.partial(shard_map, mesh=mesh, in_specs=specs + (XS,),
                       out_specs=(XS,) + specs, check_vma=False)
    def f(x_, a_, b_, c_, ct_):
        out, vjp = jax.vjp(lambda *xs: op(*xs), x_, a_, b_, c_)
        return (out,) + tuple(vjp(ct_))
    return [np.asarray(r) for r in f(x, w1, w3, w2, ct)]

for mode in ("decomposed", "xla"):
    fp = run(mode, None)
    for wd in ("int8", "fp8_e4m3", "int4"):
        got = run(mode, wd)
        rel = np.abs(got[0] - fp[0]).max() / np.abs(fp[0]).max()
        # dispatch rides the wire, combine stays full precision
        assert 1e-6 < rel < TOL[wd], (mode, wd, "value", rel)
        for g, gf in zip(got[1:], fp[1:]):
            assert np.array_equal(g, gf), (mode, wd, "grad not bit-exact")
    print(mode, "A2A_OK")
print("WIRE_A2A_OK")
"""


def test_wire_a2a_dispatch_oracle_4dev(subproc):
    assert "WIRE_A2A_OK" in subproc(_A2A_ORACLE, n_devices=4)


# ---------------------------------------------------------------------------
# 6. end-to-end: minicpm_2b under the int8 wire fits the default budget
# ---------------------------------------------------------------------------
_E2E = r"""
from repro.configs.base import ParallelConfig, get_smoke_config
from repro.tuning import error_budget

cfg = get_smoke_config("minicpm_2b")
par = ParallelConfig(tp=4, dp=1)
rmse = error_budget.model_logit_rmse(cfg, par, "int8", seq=32)
assert 0.0 < rmse <= error_budget.DEFAULT_MAX_LOGIT_RMSE, rmse
print("E2E_OK", rmse)
"""


def test_minicpm_int8_end_to_end_4dev(subproc):
    assert "E2E_OK" in subproc(_E2E, n_devices=4)
