"""Overlapped expert-parallel dispatch (``FusedOp(kind="a2a")``).

Four guarantees of the MoE exchange seam:

1. **Overlap equivalence** — the decomposed ring (dispatch/combine as
   ppermute chunks interleaved with per-local-expert GEMMs) is numerically
   identical, value AND grad, to the barrier ``all_to_all`` path, for the
   full MoE train step on a real 4-device mesh (drop-free capacity, so the
   transports are the ONLY difference).
2. **Exchange order** — ``overlap.a2a_exchange`` over a multi-axis EP
   group places block ``j`` of the output at the AXIS-MAJOR flat rank
   ``j``, matching the router's ``ep_rank = ep_rank*size(a)+index(a)``
   expert blocking; it is also an involution.
3. **Dedicated "ep" mesh axis** — a ``("ep", "data", "model")`` trainer run
   (experts on their own axis, which also carries batch) reproduces the
   loss trajectory of the plain DP run of the same global problem: the
   ep-replicated pmean / ep-sharded rescale grad contract is exact.
4. **Aux-loss pad hygiene** — the Switch load-balance loss of a
   right-padded prefill batch equals the exact-length batch's: pad rows
   contribute to neither the numerators nor the token count.  (The seed
   averaged over ALL rows, so padding skewed the router objective.)
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

_ARCH = "deepseek_v3_671b"          # smoke config: MoE with 4 experts, top-2


# ---------------------------------------------------------------------------
# 1. overlapped ring == barrier a2a, value + grad (4 devices)
# ---------------------------------------------------------------------------
_OVERLAP_EQUIV = r"""
import dataclasses, functools
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map
from repro.configs.base import get_smoke_config, ParallelConfig
from repro.models import model as M
from repro.optim import adamw
from repro.parallel.sharding import TPContext
from repro.tuning.plans import PlanSet, SeamPlan

cfg = dataclasses.replace(get_smoke_config("deepseek_v3_671b"), d_ff=512,
                          compute_dtype="float32")
# drop-free capacity: eviction order is transport-independent only when
# nothing drops, which isolates the exchange math itself
cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
    cfg.moe, capacity_factor=16.0))
par = ParallelConfig(tp=4, dp=1)
mesh = Mesh(np.array(jax.devices()).reshape(1, 4), ("data", "model"))

B, S = 2, 64
batch = {"tokens": jax.random.randint(jax.random.PRNGKey(0), (B, S), 0,
                                      cfg.vocab_size),
         "labels": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                      cfg.vocab_size)}
params = M.init_model(jax.random.PRNGKey(0), cfg, par)
params = jax.tree.map(
    lambda a: a.astype(jnp.float32) if a.dtype == jnp.bfloat16 else a, params)
specs = M.param_specs(cfg, par, params)
bs = {"tokens": P("data", None), "labels": P("data", None)}
model_rep = adamw.model_replicated_tree(specs)

def loss_and_grads(plans):
    ctx = TPContext(axis="model", dp_axes=("data",), ep_axes=("model",),
                    plans=plans)
    @jax.jit
    @functools.partial(shard_map, mesh=mesh, in_specs=(specs, bs),
                       out_specs=(P(), specs), check_vma=False)
    def f(p, b):
        l, g = jax.value_and_grad(
            lambda pp: jax.lax.pmean(M.forward_loss(pp, b, ctx, cfg, par),
                                     ("data",)))(p)
        g = jax.tree.map(
            lambda gr, rep: jax.lax.psum(gr, "model") if rep else gr,
            g, model_rep)
        return l, g
    return f(params, batch)

base = PlanSet.uniform("decomposed")
l_ref, g_ref = loss_and_grads(
    base.override("moe_a2a", SeamPlan(mode="xla")))          # barrier a2a
for ring in (SeamPlan(mode="decomposed"),                    # auto chunks
             SeamPlan(mode="decomposed", comm_chunks=8),
             SeamPlan(mode="decomposed", comm_chunks=4, reverse=True)):
    l, g = loss_and_grads(base.override("moe_a2a", ring))
    assert abs(float(l) - float(l_ref)) < 2e-5, (ring, float(l), float(l_ref))
    for (path, a), b in zip(jax.tree_util.tree_leaves_with_path(g),
                            jax.tree.leaves(g_ref)):
        a, b = np.asarray(a), np.asarray(b)
        rel = np.abs(a - b).max() / (np.abs(b).max() + 1e-9)
        assert rel < 1e-4, (ring, jax.tree_util.keystr(path), rel)
print("A2A_OVERLAP_EQUIV_OK", float(l_ref))
"""


def test_a2a_overlapped_matches_barrier(subproc):
    """Ring-decomposed EP exchange (several chunk counts, both directions)
    == barrier all_to_all, value and grad, full MoE train step on 4
    devices."""
    out = subproc(_OVERLAP_EQUIV, n_devices=4, timeout=1800)
    assert "A2A_OVERLAP_EQUIV_OK" in out


# ---------------------------------------------------------------------------
# 2. multi-axis exchange order vs axis-major ep_rank (+ involution)
# ---------------------------------------------------------------------------
_EXCHANGE_ORDER = r"""
import functools
import jax, jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P
from repro import compat
from repro.compat import shard_map
from repro.core import overlap

# EP group spanning BOTH axes of a 2x2 mesh: flat rank must be AXIS-MAJOR
# ("data" major, "model" minor) to match the router's expert blocking
mesh = Mesh(np.array(jax.devices()).reshape(2, 2), ("data", "model"))
EP, C = 4, 3
axes = ("data", "model")

def my_rank():
    r = jnp.zeros((), jnp.int32)
    for a in axes:
        r = r * compat.axis_size(a) + lax.axis_index(a)
    return r

def payload(src, dst):
    return (src * EP + dst).astype(jnp.float32)

def f(_):
    me = my_rank()
    # block j of my buffer is addressed TO flat rank j
    x = payload(me, jnp.arange(EP))[:, None] * jnp.ones((EP, C))
    out = overlap.a2a_exchange(x, axes)
    # block j of the RESULT must be what flat rank j sent to me
    want = payload(jnp.arange(EP), me)[:, None] * jnp.ones((EP, C))
    ok = jnp.all(out == want)
    # involution: exchanging back restores the original buffer
    ok &= jnp.all(overlap.a2a_exchange(out, axes) == x)
    return lax.psum(ok.astype(jnp.int32), axes)

g = jax.jit(functools.partial(
    shard_map, mesh=mesh, in_specs=(P(),), out_specs=P(),
    check_vma=False)(f))
assert int(g(jnp.zeros(()))) == EP
print("A2A_ORDER_OK")
"""


def test_a2a_exchange_axis_major_order(subproc):
    """Multi-axis ``a2a_exchange`` block order agrees with the axis-major
    flat ``ep_rank`` (the expert-blocking contract), and the exchange is an
    involution."""
    assert "A2A_ORDER_OK" in subproc(_EXCHANGE_ORDER, n_devices=4,
                                     timeout=900)


# ---------------------------------------------------------------------------
# 3. dedicated "ep" mesh axis reproduces the plain-DP loss trajectory
# ---------------------------------------------------------------------------
_EP_AXIS_TRAIN = r"""
import dataclasses
import jax, jax.numpy as jnp
import numpy as np
from repro.configs.base import get_smoke_config, ParallelConfig
from repro.launch.mesh import make_mesh
from repro.optim.adamw import AdamWConfig
from repro.runtime import trainer as T

cfg = dataclasses.replace(get_smoke_config("deepseek_v3_671b"), d_ff=512,
                          compute_dtype="float32")
cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
    cfg.moe, capacity_factor=16.0))

def run(par, mesh):
    tc = T.TrainConfig(total_steps=3, warmup_steps=1, base_lr=1e-3,
                       log_every=10)
    tr = T.Trainer(cfg, par, mesh, tc, AdamWConfig(lr=1e-3))
    tr.data_cfg = dataclasses.replace(tr.data_cfg, seq_len=32,
                                      global_batch=4)
    _, _, hist = tr.train(resume=False)
    return [h["loss"] for h in hist]

# same global problem, two meshes over the same 4 devices: batch over
# "data" (experts EP-implied over "model") vs batch over a dedicated "ep"
# axis that also shards the experts (a2a over "ep")
dp = run(ParallelConfig(tp=2, dp=2), make_mesh(1, 2, 2))
ep = run(ParallelConfig(tp=2, dp=1, ep=2), make_mesh(1, 1, 2, ep=2))
assert len(dp) == len(ep) == 3
# step 0 evaluates identical params on the identical global batch
assert abs(dp[0] - ep[0]) < 1e-5, (dp, ep)
# later steps see grads synced through DIFFERENT contracts (dp pmean vs
# ep pmean/rescale): trajectories must still agree to reduction-order noise
for a, b in zip(dp, ep):
    assert abs(a - b) / max(abs(a), 1e-9) < 2e-3, (dp, ep)
print("EP_AXIS_TRAIN_OK", dp[-1], ep[-1])
"""


def test_train_dedicated_ep_axis_matches_dp(subproc):
    """Trainer on ("ep","data","model"): the dedicated EP axis (batch AND
    experts) reproduces the plain-DP loss trajectory — the ep-replicated
    pmean / ep-sharded rescale gradient contract is exact end to end."""
    out = subproc(_EP_AXIS_TRAIN, n_devices=4, timeout=1800)
    assert "EP_AXIS_TRAIN_OK" in out


# ---------------------------------------------------------------------------
# 4. aux loss ignores right-padding (single device, in-process)
# ---------------------------------------------------------------------------
def test_moe_aux_loss_ignores_padding():
    """The load-balance aux loss of a right-padded batch (per-row
    ``lengths``) equals the exact-length batch's over the same valid
    tokens.  Fails on the seed, which averaged router stats over ALL rows
    including padding."""
    import functools

    from jax.sharding import Mesh, PartitionSpec as P

    from repro.compat import shard_map
    from repro.configs.base import get_smoke_config
    from repro.models import ffn
    from repro.parallel.sharding import TPContext

    cfg = get_smoke_config(_ARCH)
    cfg = dataclasses.replace(cfg, compute_dtype="float32")
    dm = cfg.d_model
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    ctx = TPContext(axis="model", dp_axes=("data",), ep_axes=("model",))
    p = ffn.init_moe(jax.random.PRNGKey(0), cfg, ep=1, tp=1,
                     dtype=jnp.float32)

    lengths = np.array([5, 9], np.int32)
    rows = [jax.random.normal(jax.random.PRNGKey(2 + i), (int(n), dm),
                              jnp.float32)
            for i, n in enumerate(lengths)]

    def aux_of(x, lens):
        @jax.jit
        @functools.partial(
            shard_map, mesh=mesh,
            in_specs=(jax.tree.map(lambda _: P(), p), P(None, None, None),
                      P(None)),
            out_specs=P(), check_vma=False)
        def f(pp, xx, ll):
            _, aux = ffn.moe_train(pp, xx, ctx, cfg, lengths=ll)
            return aux
        return float(f(p, x, lens))

    # exact: one row holding precisely the 14 valid tokens
    exact = aux_of(jnp.concatenate(rows)[None, :, :],
                   jnp.asarray([sum(lengths)], jnp.int32))
    # right-padded: two rows, pads filled with adversarial garbage
    s_pad = 16
    padded = jnp.stack([
        jnp.concatenate([rows[i], 37.0 * jnp.ones((s_pad - int(n), dm))])
        for i, n in enumerate(lengths)])
    assert aux_of(padded, jnp.asarray(lengths)) == pytest.approx(
        exact, rel=1e-6)
    # and the mask is live: counting the pads as tokens moves the loss
    assert aux_of(padded, jnp.asarray([s_pad, s_pad], jnp.int32)) \
        != pytest.approx(exact, rel=1e-3)
