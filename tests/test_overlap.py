"""Mode-equivalence of the FLUX overlap ops (the paper's correctness
invariant): xla == decomposed == flux for all shapes/dtypes, values and
gradients — plus hypothesis property tests on the single-device fallback,
the FusedOp epilogue-fusion sweep, and the shared-gather ring census."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import overlap
from repro.core.overlap import Epilogue, FusedOp


def _ag(x, w, axis, mode, chunks=0, reverse=False):
    return FusedOp(kind="ag", axis=axis, mode=mode, comm_chunks=chunks,
                   reverse=reverse)(x, w)


def _rs(y, w, axis, mode, chunks=0, reverse=False):
    return FusedOp(kind="rs", axis=axis, mode=mode, comm_chunks=chunks,
                   reverse=reverse)(y, w)


# shared prelude for the multi-device subprocess scripts: ONE definition of
# the FusedOp convenience wrappers (spliced into every snippet so a future
# FusedOp signature change edits a single place)
_OP_HELPERS = r"""
from repro.core.overlap import Epilogue, FusedOp

def _ag(x, w, axis, mode, chunks=0, reverse=False, wire=None):
    return FusedOp(kind="ag", axis=axis, mode=mode, comm_chunks=chunks,
                   reverse=reverse, wire_dtype=wire)(x, w)

def _rs(y, w, axis, mode, chunks=0, reverse=False, wire=None):
    return FusedOp(kind="rs", axis=axis, mode=mode, comm_chunks=chunks,
                   reverse=reverse, wire_dtype=wire)(y, w)

def _ar(y, w, axis, mode, chunks=0, wire=None):
    return FusedOp(kind="ar", axis=axis, mode=mode, comm_chunks=chunks,
                   wire_dtype=wire)(y, w)
"""


# ---------------------------------------------------------------------------
# single-device fallback == plain einsum (hypothesis over shapes)
# ---------------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(b=st.integers(1, 3), s=st.integers(1, 8), d=st.integers(1, 16),
       f=st.integers(1, 16))
def test_ag_matmul_single_device(b, s, d, f):
    x = jax.random.normal(jax.random.PRNGKey(0), (b, s, d))
    w = jax.random.normal(jax.random.PRNGKey(1), (d, f))
    for mode in overlap.VALID_MODES:
        out = _ag(x, w, None, mode)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(jnp.einsum("bsd,df->bsf", x, w)),
                                   rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(b=st.integers(1, 3), s=st.integers(1, 8), d=st.integers(1, 16),
       f=st.integers(1, 16))
def test_matmul_rs_single_device(b, s, d, f):
    y = jax.random.normal(jax.random.PRNGKey(0), (b, s, f))
    w = jax.random.normal(jax.random.PRNGKey(1), (f, d))
    for mode in overlap.VALID_MODES:
        out = _rs(y, w, None, mode)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(jnp.einsum("bsf,fd->bsd", y, w)),
                                   rtol=1e-5, atol=1e-5)


def test_grad_single_device():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 16))
    w = jax.random.normal(jax.random.PRNGKey(1), (16, 32))

    def loss(mode):
        return lambda xx, ww: jnp.sum(
            _rs(jax.nn.gelu(_ag(xx, ww, None, mode)), ww.T, None, mode) ** 2)

    gx_ref, gw_ref = jax.grad(loss("xla"), argnums=(0, 1))(x, w)
    for mode in ("decomposed", "flux"):
        gx, gw = jax.grad(loss(mode), argnums=(0, 1))(x, w)
        np.testing.assert_allclose(np.asarray(gx), np.asarray(gx_ref),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(gw), np.asarray(gw_ref),
                                   rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# multi-device equivalence (4 virtual devices, subprocess)
# ---------------------------------------------------------------------------
_MODE_EQ = r"""
import functools
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map
from repro.core import overlap
""" + _OP_HELPERS + r"""
mesh = Mesh(np.array(jax.devices()), ("model",))
B, S, D, F = 2, 512, 256, 512
x = jax.random.normal(jax.random.PRNGKey(0), (B, S, D), jnp.float32)
w1 = jax.random.normal(jax.random.PRNGKey(1), (D, F)) / D**0.5
w2 = jax.random.normal(jax.random.PRNGKey(2), (F, D)) / F**0.5

def seam(mode, chunks=0):
    @jax.jit
    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(P(None, "model", None), P(None, "model"),
                                 P("model", None)),
                       out_specs=P(None, "model", None), check_vma=False)
    def f(xs, w1s, w2s):
        y = _ag(xs, w1s, "model", mode, chunks)
        y = jax.nn.gelu(y)
        return _rs(y, w2s, "model", mode, chunks)
    return np.asarray(f(x, w1, w2))

ref = seam("xla")
for mode, chunks in [("decomposed", 0), ("decomposed", 8), ("decomposed", 16),
                     ("flux", 0)]:
    out = seam(mode, chunks)
    err = np.abs(out - ref).max()
    assert err < 1e-3, (mode, chunks, err)

# gradients
def loss(mode):
    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(P(None, "model", None), P(None, "model"),
                                 P("model", None)),
                       out_specs=P(), check_vma=False)
    def f(xs, w1s, w2s):
        y = _ag(xs, w1s, "model", mode)
        z = _rs(jax.nn.gelu(y), w2s, "model", mode)
        return jax.lax.psum(jnp.sum(z * z), "model")
    return lambda a, b, c: f(a, b, c)

g_ref = jax.jit(jax.grad(loss("xla"), argnums=(0, 1, 2)))(x, w1, w2)
for mode in ["decomposed", "flux"]:
    g = jax.jit(jax.grad(loss(mode), argnums=(0, 1, 2)))(x, w1, w2)
    for a, b in zip(g, g_ref):
        err = np.abs(np.asarray(a) - np.asarray(b)).max()
        rel = err / (np.abs(np.asarray(b)).max() + 1e-9)
        assert rel < 1e-3, (mode, rel)

# matmul_ar (decode seam)
y = jax.random.normal(jax.random.PRNGKey(3), (B, 4, F))
@jax.jit
@functools.partial(shard_map, mesh=mesh,
                   in_specs=(P(None, None, "model"), P("model", None)),
                   out_specs=P(None, None, None), check_vma=False)
def ar_dec(ys, ws):
    return _ar(ys, ws, "model", "decomposed")
@jax.jit
@functools.partial(shard_map, mesh=mesh,
                   in_specs=(P(None, None, "model"), P("model", None)),
                   out_specs=P(None, None, None), check_vma=False)
def ar_ref(ys, ws):
    return _ar(ys, ws, "model", "xla")
err = np.abs(np.asarray(ar_dec(y, w2)) - np.asarray(ar_ref(y, w2))).max()
assert err < 1e-3, err
print("MODE_EQ_OK")
"""


def test_mode_equivalence_4dev(subproc):
    out = subproc(_MODE_EQ, n_devices=4)
    assert "MODE_EQ_OK" in out


_Q8 = r"""
import functools
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map
from repro.core import overlap
""" + _OP_HELPERS + r"""
mesh = Mesh(np.array(jax.devices()), ("model",))
B, S, D, F = 2, 256, 256, 512
x = jax.random.normal(jax.random.PRNGKey(0), (B, S, D), jnp.float32)
w = jax.random.normal(jax.random.PRNGKey(1), (D, F)) / D**0.5

def run(mode):
    @jax.jit
    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(P(None, "model", None), P(None, "model")),
                       out_specs=P(None, None, "model"), check_vma=False)
    def f(xs, ws):
        return _ag(xs, ws, "model", mode)
    return np.asarray(f(x, w))

ref = run("xla")
q8 = run("xla_q8")
rel = np.abs(q8 - ref).max() / np.abs(ref).max()
# int8 block quantization: ~0.8% relative error budget
assert rel < 2e-2, rel
assert rel > 1e-5  # it IS lossy — guard against silently testing the exact path
print("Q8_OK", rel)
"""


def test_q8_gather_accuracy(subproc):
    out = subproc(_Q8, n_devices=4)
    assert "Q8_OK" in out


_BIDIR = r"""
import functools
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map
from repro.core import overlap
""" + _OP_HELPERS + r"""
mesh = Mesh(np.array(jax.devices()), ("model",))
B, S, D, F = 2, 256, 128, 256
x = jax.random.normal(jax.random.PRNGKey(0), (B, S, D), jnp.float32)
w1 = jax.random.normal(jax.random.PRNGKey(1), (D, F)) / D**0.5
w2 = jax.random.normal(jax.random.PRNGKey(2), (F, D)) / F**0.5

def seam(mode):
    @jax.jit
    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(P(None, "model", None), P(None, "model"),
                                 P("model", None)),
                       out_specs=P(None, "model", None), check_vma=False)
    def f(xs, w1s, w2s):
        y = _ag(xs, w1s, "model", mode)
        return _rs(jax.nn.gelu(y), w2s, "model", mode)
    return np.asarray(f(x, w1, w2))

ref = seam("xla")
out = seam("decomposed_bidir")
assert np.abs(out - ref).max() < 1e-3
print("BIDIR_OK")
"""


def test_bidirectional_ring(subproc):
    assert "BIDIR_OK" in subproc(_BIDIR, n_devices=4)


# ---------------------------------------------------------------------------
# the previously untested modes: decomposed_bidir / decomposed_q8 values AND
# gradients vs the xla oracle, reverse-direction rings, and the matmul_ar
# (decode seam) mode-equivalence sweep
# ---------------------------------------------------------------------------
_FULL_SWEEP = r"""
import functools
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map
from repro.core import overlap
""" + _OP_HELPERS + r"""
mesh = Mesh(np.array(jax.devices()), ("model",))
B, S, D, F = 2, 256, 128, 256
x = jax.random.normal(jax.random.PRNGKey(0), (B, S, D), jnp.float32)
w1 = jax.random.normal(jax.random.PRNGKey(1), (D, F)) / D**0.5
w2 = jax.random.normal(jax.random.PRNGKey(2), (F, D)) / F**0.5

def seam(mode, chunks=0, reverse=False):
    @jax.jit
    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(P(None, "model", None), P(None, "model"),
                                 P("model", None)),
                       out_specs=P(None, "model", None), check_vma=False)
    def f(xs, w1s, w2s):
        y = _ag(xs, w1s, "model", mode, chunks, reverse)
        return _rs(jax.nn.gelu(y), w2s, "model", mode, chunks,
                                 reverse)
    return np.asarray(f(x, w1, w2))

ref = seam("xla")
scale = np.abs(ref).max()
for mode, chunks, rev, tol in [
        ("decomposed", 0, True, 1e-3),           # reverse ring
        ("decomposed", 8, True, 1e-3),
        ("decomposed_bidir", 0, False, 1e-3),
        ("decomposed_bidir", 16, False, 1e-3),
        ("decomposed_q8", 0, False, 2e-2),       # int8 gather budget
        ("decomposed_q8", 8, True, 2e-2)]:
    out = seam(mode, chunks, rev)
    rel = np.abs(out - ref).max() / scale
    assert rel < tol, (mode, chunks, rev, rel)

# q8 ring must produce EXACTLY the monolithic-gather q8 values (same
# encode/decode path, different transport) ...
def ag_only(mode, chunks=0):
    @jax.jit
    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(P(None, "model", None), P(None, "model")),
                       out_specs=P(None, None, "model"), check_vma=False)
    def f(xs, ws):
        return _ag(xs, ws, "model", mode, chunks)
    return np.asarray(f(x, w1))
assert np.abs(ag_only("xla_q8") - ag_only("decomposed_q8", 8)).max() < 1e-5

# ... and it must actually ride the ring: the forward jaxpr carries
# ppermute hops, no monolithic all_gather (the pre-fix regression)
from repro.analysis.seamcheck import collective_counts
def fwd_counts(mode):
    f = functools.partial(shard_map, mesh=mesh,
                          in_specs=(P(None, "model", None), P(None, "model")),
                          out_specs=P(None, None, "model"), check_vma=False)(
        lambda xs, ws: _ag(xs, ws, "model", mode, 8))
    return collective_counts(jax.make_jaxpr(f)(x, w1))
cq = fwd_counts("decomposed_q8")
assert cq.get("ppermute", 0) > 0 and cq.get("all_gather", 0) == 0, \
    ("q8 lost ring overlap", cq)
assert fwd_counts("xla_q8").get("all_gather", 0) > 0

# gradients vs the xla oracle (bidir is exact; q8's custom_vjp runs the
# interchanged ops on full-precision cotangents so grads stay within the
# quantization budget of the forward)
def loss(mode, chunks=0, reverse=False):
    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(P(None, "model", None), P(None, "model"),
                                 P("model", None)),
                       out_specs=P(), check_vma=False)
    def f(xs, w1s, w2s):
        y = _ag(xs, w1s, "model", mode, chunks, reverse)
        z = _rs(jax.nn.gelu(y), w2s, "model", mode, chunks,
                              reverse)
        return jax.lax.psum(jnp.sum(z * z), "model")
    return f

g_ref = jax.jit(jax.grad(loss("xla"), argnums=(0, 1, 2)))(x, w1, w2)
for mode, chunks, rev, tol in [("decomposed_bidir", 0, False, 1e-3),
                               ("decomposed_bidir", 16, False, 1e-3),
                               ("decomposed", 8, True, 1e-3),
                               ("decomposed_q8", 0, False, 5e-2),
                               ("decomposed_q8", 8, True, 5e-2)]:
    g = jax.jit(jax.grad(loss(mode, chunks, rev), argnums=(0, 1, 2)))(x, w1, w2)
    for a, b in zip(g, g_ref):
        rel = (np.abs(np.asarray(a) - np.asarray(b)).max()
               / (np.abs(np.asarray(b)).max() + 1e-9))
        assert rel < tol, (mode, chunks, rev, rel)
print("FULL_SWEEP_OK")
"""


def test_bidir_q8_reverse_sweep_4dev(subproc):
    assert "FULL_SWEEP_OK" in subproc(_FULL_SWEEP, n_devices=4)


_AR_SWEEP = r"""
import functools
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map
from repro.core import overlap
""" + _OP_HELPERS + r"""
mesh = Mesh(np.array(jax.devices()), ("model",))
B, M, F, D = 2, 4, 256, 128
y = jax.random.normal(jax.random.PRNGKey(0), (B, M, F), jnp.float32)
w = jax.random.normal(jax.random.PRNGKey(1), (F, D)) / F**0.5

def ar(mode, chunks=0):
    @jax.jit
    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(P(None, None, "model"), P("model", None)),
                       out_specs=P(None, None, None), check_vma=False)
    def f(ys, ws):
        return _ar(ys, ws, "model", mode, chunks)
    return np.asarray(f(y, w))

ref = ar("xla")
for mode, chunks in [("decomposed", 0), ("decomposed", 2), ("decomposed", 4),
                     ("decomposed", 7),           # non-dividing chunk count
                     ("decomposed_bidir", 0),
                     ("flux", 0)]:
    out = ar(mode, chunks)
    assert np.abs(out - ref).max() < 1e-3, (mode, chunks)

# the quantized all-reduce (decomposed + int8 wire; the deprecated
# "decomposed_q8" spelling normalizes to exactly this) runs the two-ring
# Flash-Communication path: lossy within the int8 budget, and GENUINELY
# lossy — an exact match would mean the wire silently fell back to psum
def ar_wire(mode, chunks=0, wire=None):
    @jax.jit
    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(P(None, None, "model"), P("model", None)),
                       out_specs=P(None, None, None), check_vma=False)
    def f(ys, ws):
        return _ar(ys, ws, "model", mode, chunks, wire)
    return np.asarray(f(y, w))

scale = np.abs(ref).max()
q = ar_wire("decomposed", 2, "int8")
rel = np.abs(q - ref).max() / scale
assert 1e-5 < rel < 2e-2, rel
shim = ar_wire("decomposed_q8", 2)
assert np.abs(shim - q).max() == 0.0  # shim IS the explicit spelling

# gradients through the decode seam
def loss(mode, chunks=0):
    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(P(None, None, "model"), P("model", None)),
                       out_specs=P(), check_vma=False)
    def f(ys, ws):
        z = _ar(ys, ws, "model", mode, chunks)
        return jnp.sum(z * z)
    return f
g_ref = jax.jit(jax.grad(loss("xla"), argnums=(0, 1)))(y, w)
for mode, chunks in [("decomposed", 0), ("decomposed", 4)]:
    g = jax.jit(jax.grad(loss(mode, chunks), argnums=(0, 1)))(y, w)
    for a, b in zip(g, g_ref):
        rel = (np.abs(np.asarray(a) - np.asarray(b)).max()
               / (np.abs(np.asarray(b)).max() + 1e-9))
        assert rel < 1e-3, (mode, chunks, rel)
print("AR_SWEEP_OK")
"""


def test_matmul_ar_mode_equivalence_4dev(subproc):
    assert "AR_SWEEP_OK" in subproc(_AR_SWEEP, n_devices=4)


# ---------------------------------------------------------------------------
# FusedOp: single-device epilogue semantics, validation, deprecation
# ---------------------------------------------------------------------------
def test_fused_op_epilogue_single_device():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 16))
    w1 = jax.random.normal(jax.random.PRNGKey(1), (16, 32))
    w3 = jax.random.normal(jax.random.PRNGKey(2), (16, 32))
    b = jax.random.normal(jax.random.PRNGKey(3), (32,))
    r = jax.random.normal(jax.random.PRNGKey(4), (2, 8, 32))
    sc = jnp.abs(jax.random.normal(jax.random.PRNGKey(5), (32,))) + 0.5
    y0 = jnp.einsum("bsd,df->bsf", x, w1)
    y3 = jnp.einsum("bsd,df->bsf", x, w3)

    cases = [
        (FusedOp(kind="ag", epilogue=Epilogue(bias=True)),
         dict(bias=b), y0 + b),
        (FusedOp(kind="ag", epilogue=Epilogue(activation="gelu")),
         {}, jax.nn.gelu(y0)),
        (FusedOp(kind="ag", epilogue=Epilogue(scale=True, residual=True)),
         dict(scale=sc, residual=r), y0 * sc + r),
        (FusedOp(kind="ag", epilogue=Epilogue(activation="silu",
                                              gate="pair"), n_weights=2),
         {}, jax.nn.silu(y0) * y3),
    ]
    for op, operands, want in cases:
        ws = (w1, w3) if op.n_weights == 2 else (w1,)
        np.testing.assert_allclose(np.asarray(op(x, *ws, **operands)),
                                   np.asarray(want), rtol=1e-5, atol=1e-5)

    # split-gate: packed [a | g] halves
    op = FusedOp(kind="ag", epilogue=Epilogue(activation="silu",
                                              gate="split"))
    w13 = jnp.concatenate([w1, w3], axis=-1)
    np.testing.assert_allclose(np.asarray(op(x, w13)),
                               np.asarray(jax.nn.silu(y0) * y3),
                               rtol=1e-5, atol=1e-5)

    # multi-output (identity epilogue) returns per-weight outputs
    o1, o2 = FusedOp(kind="ag", n_weights=2)(x, w1, w3)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(y0), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(o2), np.asarray(y3), rtol=1e-5,
                               atol=1e-5)


def test_fused_op_validation():
    with pytest.raises(ValueError):
        FusedOp(kind="nope")
    with pytest.raises(ValueError):
        FusedOp(kind="ag", mode="nope")
    with pytest.raises(ValueError):                 # rs is single-weight
        FusedOp(kind="rs", n_weights=2)
    with pytest.raises(ValueError):                 # pair-gate needs 2 weights
        FusedOp(kind="ag", epilogue=Epilogue(gate="pair"))
    with pytest.raises(ValueError):                 # multi-out must be identity
        FusedOp(kind="ag", n_weights=2, epilogue=Epilogue(bias=True))
    with pytest.raises(ValueError):
        Epilogue(activation="nope")
    op = FusedOp(kind="ag", epilogue=Epilogue(bias=True))
    x = jnp.ones((2, 4, 8))
    w = jnp.ones((8, 8))
    with pytest.raises(ValueError):                 # declared bias not passed
        op(x, w)
    with pytest.raises(ValueError):                 # undeclared operand
        FusedOp(kind="ag")(x, w, bias=jnp.ones((8,)))


def test_legacy_wrappers_removed():
    """The one-release deprecation window (PR 3) is over: the positional
    wrappers are gone; the reference oracles remain for tests."""
    for name in ("ag_matmul", "matmul_rs", "matmul_ar"):
        assert not hasattr(overlap, name), name
    assert callable(overlap.ag_matmul_ref)
    assert callable(overlap.matmul_rs_ref)


def test_scatter_axis_validation():
    with pytest.raises(ValueError):
        FusedOp(kind="ag", scatter_axis="nope")
    # "ar" IS the replicated layout: the knob coerces
    assert FusedOp(kind="ar").scatter_axis == "hidden"
    assert FusedOp(kind="ag").scatter_axis == "seq"


def test_hidden_layout_single_device():
    """scatter_axis="hidden" on one device == the plain GEMM (all modes)."""
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 16))
    w = jax.random.normal(jax.random.PRNGKey(1), (16, 32))
    want_ag = jnp.einsum("bsd,df->bsf", x, w)
    want_rs = jnp.einsum("bsf,fd->bsd", want_ag, w.T)
    for mode in overlap.VALID_MODES:
        got = FusedOp(kind="ag", mode=mode, scatter_axis="hidden")(x, w)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want_ag),
                                   rtol=1e-5, atol=1e-5)
        got = FusedOp(kind="rs", mode=mode, scatter_axis="hidden")(
            want_ag, w.T)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want_rs),
                                   rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# FusedOp epilogue sweep: every Epilogue combination vs the unfused
# reference across ALL modes, values AND gradients, on a 4-device mesh
# ---------------------------------------------------------------------------
_EPILOGUE_SWEEP = r"""
import dataclasses, functools
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map
from repro.core import overlap
from repro.core.overlap import Epilogue, FusedOp

mesh = Mesh(np.array(jax.devices()), ("model",))
B, S, D, F = 2, 256, 128, 256
x = jax.random.normal(jax.random.PRNGKey(0), (B, S, D), jnp.float32)
w1 = jax.random.normal(jax.random.PRNGKey(1), (D, F)) / D**0.5
w3 = jax.random.normal(jax.random.PRNGKey(2), (D, F)) / D**0.5
w2 = jax.random.normal(jax.random.PRNGKey(3), (F, D)) / F**0.5
bias = jax.random.normal(jax.random.PRNGKey(4), (F,)) * 0.3
bias_d = jax.random.normal(jax.random.PRNGKey(5), (D,)) * 0.3
res = jax.random.normal(jax.random.PRNGKey(6), (B, S, D), jnp.float32)
scale = jnp.abs(jax.random.normal(jax.random.PRNGKey(7), (F,))) + 0.5

def smap(fn, in_specs, out_specs):
    return jax.jit(functools.partial(shard_map, mesh=mesh, in_specs=in_specs,
                                     out_specs=out_specs,
                                     check_vma=False)(fn))

AG3 = (P(None, "model", None), P(None, "model"), P(None, "model"))
AG_OUT = P(None, None, "model")
RS3 = (P(None, None, "model"), P("model", None), P(None, "model", None))
RS_OUT = P(None, "model", None)

# (name, kind, build_op(mode), weights, epilogue operands)
def agref(xs, ws):
    return overlap.ag_matmul_ref(xs, ws, "model")
def rsref(ys, ws):
    return overlap.matmul_rs_ref(ys, ws, "model")

CASES = [
    ("ag_bias", "ag", lambda m: FusedOp(kind="ag", axis="model", mode=m,
                                        comm_chunks=8,
                                        epilogue=Epilogue(bias=True)),
     (w1,), dict(bias=bias)),
    ("ag_act", "ag", lambda m: FusedOp(kind="ag", axis="model", mode=m,
                                       epilogue=Epilogue(activation="sqrelu")),
     (w1,), {}),
    ("ag_gate_pair", "ag",
     lambda m: FusedOp(kind="ag", axis="model", mode=m, comm_chunks=8,
                       epilogue=Epilogue(activation="silu", gate="pair"),
                       n_weights=2),
     (w1, w3), {}),
    ("ag_scale", "ag", lambda m: FusedOp(kind="ag", axis="model", mode=m,
                                         epilogue=Epilogue(scale=True)),
     (w1,), dict(scale=scale)),
    ("rs_residual", "rs",
     lambda m: FusedOp(kind="rs", axis="model", mode=m, comm_chunks=8,
                       epilogue=Epilogue(residual=True)),
     (w2,), dict(residual=res)),
    ("rs_bias_act", "rs",
     lambda m: FusedOp(kind="rs", axis="model", mode=m,
                       epilogue=Epilogue(bias=True, activation="gelu")),
     (w2,), dict(bias=bias_d)),
]

y_in = jax.random.normal(jax.random.PRNGKey(8), (B, S, F), jnp.float32)

def reference(name):
    if name == "ag_bias":
        f = smap(lambda xs, ws, b_: agref(xs, ws) + b_,
                 (AG3[0], AG3[1], P("model")), AG_OUT)
        return np.asarray(f(x, w1, bias))
    if name == "ag_act":
        f = smap(lambda xs, ws: jnp.square(jax.nn.relu(agref(xs, ws))),
                 AG3[:2], AG_OUT)
        return np.asarray(f(x, w1))
    if name == "ag_gate_pair":
        f = smap(lambda xs, a_, b_: jax.nn.silu(agref(xs, a_)) * agref(xs, b_),
                 AG3, AG_OUT)
        return np.asarray(f(x, w1, w3))
    if name == "ag_scale":
        f = smap(lambda xs, ws, s_: agref(xs, ws) * s_,
                 (AG3[0], AG3[1], P("model")), AG_OUT)
        return np.asarray(f(x, w1, scale))
    if name == "rs_residual":
        f = smap(lambda ys, ws, r_: rsref(ys, ws) + r_, RS3, RS_OUT)
        return np.asarray(f(y_in, w2, res))
    if name == "rs_bias_act":
        f = smap(lambda ys, ws, b_: jax.nn.gelu(rsref(ys, ws) + b_),
                 (RS3[0], RS3[1], P(None)), RS_OUT)
        return np.asarray(f(y_in, w2, bias_d))
    raise ValueError(name)

def run_case(name, kind, mk_op, ws, operands, mode, shared, fuse):
    op = dataclasses.replace(mk_op(mode), shared_gather=shared,
                             fuse_epilogue=fuse)
    keys = sorted(operands)
    opn = dict(operands)
    if kind == "ag":
        specs = [AG3[0]] + [AG3[1]] * len(ws)
        for k in keys:
            specs.append(P("model") if k in ("bias", "scale")
                         else AG_OUT)
        f = smap(lambda xs, *rest: op(xs, *rest[:len(ws)],
                                      **dict(zip(keys, rest[len(ws):]))),
                 tuple(specs), AG_OUT)
        args = (x, *ws, *[opn[k] for k in keys])
    else:
        specs = [RS3[0], RS3[1]]
        for k in keys:
            specs.append(P(None) if k == "bias" else RS_OUT)
        f = smap(lambda ys, w_, *rest: op(ys, w_,
                                          **dict(zip(keys, rest))),
                 tuple(specs), RS_OUT)
        args = (y_in, w2, *[opn[k] for k in keys])
    return np.asarray(f(*args))

for name, kind, mk_op, ws, operands in CASES:
    ref = reference(name)
    scale_ref = np.abs(ref).max() + 1e-9
    for mode in overlap.VALID_MODES:
        for shared in ((True, False) if len(ws) > 1 else (True,)):
            for fuse in (True, False):
                out = run_case(name, kind, mk_op, ws, operands, mode,
                               shared, fuse)
                tol = 2e-2 if mode.endswith("_q8") else 1e-3
                rel = np.abs(out - ref).max() / scale_ref
                assert rel < tol, (name, mode, shared, fuse, rel)
print("EPI_VALUES_OK")

# gradients: epilogue-transposed backward through the interchanged op,
# including cotangents for the bias/scale/residual operands
def ag_loss(op_or_ref, with_bias):
    def f(xs, a_, b_, bi):
        if op_or_ref == "ref":
            y = jax.nn.silu(agref(xs, a_) + (bi if with_bias else 0.0)) \
                * agref(xs, b_)
        else:
            y = op_or_ref(xs, a_, b_, bias=bi) if with_bias \
                else op_or_ref(xs, a_, b_)
        return jax.lax.psum(jnp.sum(y * y), "model")
    return functools.partial(
        shard_map, mesh=mesh, in_specs=AG3 + (P("model"),), out_specs=P(),
        check_vma=False)(f)

for with_bias in (False, True):
    epi = Epilogue(activation="silu", gate="pair", bias=with_bias)
    g_ref = jax.jit(jax.grad(ag_loss("ref", with_bias),
                             argnums=(0, 1, 2, 3)))(x, w1, w3, bias)
    for mode in ("decomposed", "decomposed_bidir", "xla", "flux"):
        for fuse in (True, False):
            op = FusedOp(kind="ag", axis="model", mode=mode, comm_chunks=8,
                         epilogue=epi, n_weights=2, fuse_epilogue=fuse)
            g = jax.jit(jax.grad(ag_loss(op, with_bias),
                                 argnums=(0, 1, 2, 3)))(x, w1, w3, bias)
            for i, (a_, b_) in enumerate(zip(g, g_ref)):
                if not with_bias and i == 3:
                    continue        # bias unused -> zero grads both ways
                rel = (np.abs(np.asarray(a_) - np.asarray(b_)).max()
                       / (np.abs(np.asarray(b_)).max() + 1e-9))
                assert rel < 1e-3, (mode, fuse, with_bias, i, rel)

def rs_loss(use_op):
    def f(ys, w_, r_):
        z = (oprs(ys, w_, residual=r_) if use_op
             else rsref(ys, w_) + r_)
        return jax.lax.psum(jnp.sum(z * z), "model")
    return functools.partial(shard_map, mesh=mesh, in_specs=RS3,
                             out_specs=P(), check_vma=False)(f)

for mode in ("decomposed", "xla"):
    oprs = FusedOp(kind="rs", axis="model", mode=mode,
                   epilogue=Epilogue(residual=True))
    g_ref = jax.jit(jax.grad(rs_loss(False), argnums=(0, 1, 2)))(y_in, w2, res)
    g = jax.jit(jax.grad(rs_loss(True), argnums=(0, 1, 2)))(y_in, w2, res)
    for a_, b_ in zip(g, g_ref):
        rel = (np.abs(np.asarray(a_) - np.asarray(b_)).max()
               / (np.abs(np.asarray(b_)).max() + 1e-9))
        assert rel < 1e-3, (mode, rel)
print("EPI_GRADS_OK")
"""


def test_fused_epilogue_sweep_4dev(subproc):
    """Every Epilogue combination (bias / activation / pair- and split-gate /
    residual / scale) must match the unfused xla reference across ALL
    VALID_MODES, with the fuse_epilogue and shared_gather knobs in both
    positions; gradients flow through the epilogue-transposed backward."""
    out = subproc(_EPILOGUE_SWEEP, n_devices=4, timeout=1800)
    assert "EPI_VALUES_OK" in out
    assert "EPI_GRADS_OK" in out


# ---------------------------------------------------------------------------
# shared-gather: the gated FFN's w1/w3 pair rides ONE AllGather ring
# (half the ppermute hops, counted via the jaxpr census) with identical
# numerics
# ---------------------------------------------------------------------------
_SHARED_GATHER = r"""
import dataclasses, functools
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.analysis import jaxpr_cost
from repro.compat import shard_map
from repro.core.overlap import Epilogue, FusedOp
from repro.models import ffn
from repro.parallel.sharding import TPContext
from repro.tuning.plans import PlanSet, SeamPlan

mesh = Mesh(np.array(jax.devices()), ("model",))
n_dev = 4
B, S, D = 2, 256, 128
x = jax.random.normal(jax.random.PRNGKey(0), (B, S, D), jnp.float32)
w1 = jax.random.normal(jax.random.PRNGKey(1), (D, 256)) / D**0.5
w3 = jax.random.normal(jax.random.PRNGKey(2), (D, 256)) / D**0.5

# --- op-level census: shared gather halves the ppermute hops EXACTLY ----
def hops(shared, chunks=0):
    op = FusedOp(kind="ag", axis="model", mode="decomposed",
                 comm_chunks=chunks,
                 epilogue=Epilogue(activation="silu", gate="pair"),
                 n_weights=2, shared_gather=shared)
    f = functools.partial(shard_map, mesh=mesh,
                          in_specs=(P(None, "model", None), P(None, "model"),
                                    P(None, "model")),
                          out_specs=P(None, None, "model"), check_vma=False)(
        lambda xs, a_, b_: op(xs, a_, b_))
    jx = jax.make_jaxpr(f)(x, w1, w3)
    c = jaxpr_cost.analyze_jaxpr(jx.jaxpr, {"model": n_dev})
    return c.collective_counts.get("collective_permute", 0)

for chunks in (0, 8):
    hs, hu = hops(True, chunks), hops(False, chunks)
    assert hs > 0 and hu == 2 * hs, (chunks, hs, hu)

# --- ffn_train's double-gather fix: one ring pass end to end -------------
p = ffn.init_ffn(jax.random.PRNGKey(0), D, 256, n_dev, jnp.float32)
fspec = {"w1": P(None, "model"), "w3": P(None, "model"),
         "w2": P("model", None), "norm": P(None)}

def ffn_fwd(plans):
    ctx = TPContext(axis="model", plans=plans)
    return functools.partial(shard_map, mesh=mesh,
                             in_specs=(fspec, P(None, "model", None)),
                             out_specs=P(None, "model", None),
                             check_vma=False)(
        lambda pp, xx: ffn.ffn_train(pp, xx, ctx))

shared_plans = PlanSet.uniform("decomposed")
unshared_plans = PlanSet(
    default=SeamPlan(mode="decomposed"),
    seams={"mlp_ag": SeamPlan(mode="decomposed", shared_gather=False,
                              fuse_epilogue=False)})

def census(plans):
    jx = jax.make_jaxpr(ffn_fwd(plans))(p, x)
    return jaxpr_cost.analyze_jaxpr(jx.jaxpr, {"model": n_dev})

c_s, c_u = census(shared_plans), census(unshared_plans)
h_s = c_s.collective_counts["collective_permute"]
h_u = c_u.collective_counts["collective_permute"]
# both traces carry the SAME mlp_rs ring ((n-1) hops); the AG seam's hops
# halve: shared = (n-1) + (n-1), unshared = 2(n-1) + (n-1)
rs_hops = n_dev - 1
assert h_s - rs_hops == (h_u - rs_hops) / 2, (h_s, h_u)
assert c_s.collective_bytes < c_u.collective_bytes

# numerics: identical result either way (and vs the xla oracle)
out_s = np.asarray(jax.jit(ffn_fwd(shared_plans))(p, x))
out_u = np.asarray(jax.jit(ffn_fwd(unshared_plans))(p, x))
out_x = np.asarray(jax.jit(ffn_fwd(PlanSet.uniform("xla")))(p, x))
assert np.abs(out_s - out_u).max() < 1e-5
assert np.abs(out_s - out_x).max() / (np.abs(out_x).max() + 1e-9) < 1e-3
print("SHARED_GATHER_OK")
"""


def test_shared_gather_halves_ring_hops_4dev(subproc):
    """FusedOp(n_weights=2) fixes ffn_train's double gather: the jaxpr
    census shows half the ppermute hops at the AG seam and lower collective
    bytes, with numerics identical to the per-weight rings and the xla
    oracle."""
    assert "SHARED_GATHER_OK" in subproc(_SHARED_GATHER, n_devices=4,
                                         timeout=1800)
