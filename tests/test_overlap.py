"""Mode-equivalence of the FLUX overlap ops (the paper's correctness
invariant): xla == decomposed == flux for all shapes/dtypes, values and
gradients — plus hypothesis property tests on the single-device fallback."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import overlap


# ---------------------------------------------------------------------------
# single-device fallback == plain einsum (hypothesis over shapes)
# ---------------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(b=st.integers(1, 3), s=st.integers(1, 8), d=st.integers(1, 16),
       f=st.integers(1, 16))
def test_ag_matmul_single_device(b, s, d, f):
    x = jax.random.normal(jax.random.PRNGKey(0), (b, s, d))
    w = jax.random.normal(jax.random.PRNGKey(1), (d, f))
    for mode in overlap.VALID_MODES:
        out = overlap.ag_matmul(x, w, None, mode)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(jnp.einsum("bsd,df->bsf", x, w)),
                                   rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(b=st.integers(1, 3), s=st.integers(1, 8), d=st.integers(1, 16),
       f=st.integers(1, 16))
def test_matmul_rs_single_device(b, s, d, f):
    y = jax.random.normal(jax.random.PRNGKey(0), (b, s, f))
    w = jax.random.normal(jax.random.PRNGKey(1), (f, d))
    for mode in overlap.VALID_MODES:
        out = overlap.matmul_rs(y, w, None, mode)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(jnp.einsum("bsf,fd->bsd", y, w)),
                                   rtol=1e-5, atol=1e-5)


def test_grad_single_device():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 16))
    w = jax.random.normal(jax.random.PRNGKey(1), (16, 32))

    def loss(mode):
        return lambda xx, ww: jnp.sum(
            overlap.matmul_rs(jax.nn.gelu(
                overlap.ag_matmul(xx, ww, None, mode)), ww.T, None, mode) ** 2)

    gx_ref, gw_ref = jax.grad(loss("xla"), argnums=(0, 1))(x, w)
    for mode in ("decomposed", "flux"):
        gx, gw = jax.grad(loss(mode), argnums=(0, 1))(x, w)
        np.testing.assert_allclose(np.asarray(gx), np.asarray(gx_ref),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(gw), np.asarray(gw_ref),
                                   rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# multi-device equivalence (4 virtual devices, subprocess)
# ---------------------------------------------------------------------------
_MODE_EQ = r"""
import functools
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map
from repro.core import overlap

mesh = Mesh(np.array(jax.devices()), ("model",))
B, S, D, F = 2, 512, 256, 512
x = jax.random.normal(jax.random.PRNGKey(0), (B, S, D), jnp.float32)
w1 = jax.random.normal(jax.random.PRNGKey(1), (D, F)) / D**0.5
w2 = jax.random.normal(jax.random.PRNGKey(2), (F, D)) / F**0.5

def seam(mode, chunks=0):
    @jax.jit
    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(P(None, "model", None), P(None, "model"),
                                 P("model", None)),
                       out_specs=P(None, "model", None), check_vma=False)
    def f(xs, w1s, w2s):
        y = overlap.ag_matmul(xs, w1s, "model", mode, chunks)
        y = jax.nn.gelu(y)
        return overlap.matmul_rs(y, w2s, "model", mode, chunks)
    return np.asarray(f(x, w1, w2))

ref = seam("xla")
for mode, chunks in [("decomposed", 0), ("decomposed", 8), ("decomposed", 16),
                     ("flux", 0)]:
    out = seam(mode, chunks)
    err = np.abs(out - ref).max()
    assert err < 1e-3, (mode, chunks, err)

# gradients
def loss(mode):
    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(P(None, "model", None), P(None, "model"),
                                 P("model", None)),
                       out_specs=P(), check_vma=False)
    def f(xs, w1s, w2s):
        y = overlap.ag_matmul(xs, w1s, "model", mode)
        z = overlap.matmul_rs(jax.nn.gelu(y), w2s, "model", mode)
        return jax.lax.psum(jnp.sum(z * z), "model")
    return lambda a, b, c: f(a, b, c)

g_ref = jax.jit(jax.grad(loss("xla"), argnums=(0, 1, 2)))(x, w1, w2)
for mode in ["decomposed", "flux"]:
    g = jax.jit(jax.grad(loss(mode), argnums=(0, 1, 2)))(x, w1, w2)
    for a, b in zip(g, g_ref):
        err = np.abs(np.asarray(a) - np.asarray(b)).max()
        rel = err / (np.abs(np.asarray(b)).max() + 1e-9)
        assert rel < 1e-3, (mode, rel)

# matmul_ar (decode seam)
y = jax.random.normal(jax.random.PRNGKey(3), (B, 4, F))
@jax.jit
@functools.partial(shard_map, mesh=mesh,
                   in_specs=(P(None, None, "model"), P("model", None)),
                   out_specs=P(None, None, None), check_vma=False)
def ar_dec(ys, ws):
    return overlap.matmul_ar(ys, ws, "model", "decomposed")
@jax.jit
@functools.partial(shard_map, mesh=mesh,
                   in_specs=(P(None, None, "model"), P("model", None)),
                   out_specs=P(None, None, None), check_vma=False)
def ar_ref(ys, ws):
    return overlap.matmul_ar(ys, ws, "model", "xla")
err = np.abs(np.asarray(ar_dec(y, w2)) - np.asarray(ar_ref(y, w2))).max()
assert err < 1e-3, err
print("MODE_EQ_OK")
"""


def test_mode_equivalence_4dev(subproc):
    out = subproc(_MODE_EQ, n_devices=4)
    assert "MODE_EQ_OK" in out


_Q8 = r"""
import functools
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map
from repro.core import overlap

mesh = Mesh(np.array(jax.devices()), ("model",))
B, S, D, F = 2, 256, 256, 512
x = jax.random.normal(jax.random.PRNGKey(0), (B, S, D), jnp.float32)
w = jax.random.normal(jax.random.PRNGKey(1), (D, F)) / D**0.5

def run(mode):
    @jax.jit
    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(P(None, "model", None), P(None, "model")),
                       out_specs=P(None, None, "model"), check_vma=False)
    def f(xs, ws):
        return overlap.ag_matmul(xs, ws, "model", mode)
    return np.asarray(f(x, w))

ref = run("xla")
q8 = run("xla_q8")
rel = np.abs(q8 - ref).max() / np.abs(ref).max()
# int8 block quantization: ~0.8% relative error budget
assert rel < 2e-2, rel
assert rel > 1e-5  # it IS lossy — guard against silently testing the exact path
print("Q8_OK", rel)
"""


def test_q8_gather_accuracy(subproc):
    out = subproc(_Q8, n_devices=4)
    assert "Q8_OK" in out


_BIDIR = r"""
import functools
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map
from repro.core import overlap

mesh = Mesh(np.array(jax.devices()), ("model",))
B, S, D, F = 2, 256, 128, 256
x = jax.random.normal(jax.random.PRNGKey(0), (B, S, D), jnp.float32)
w1 = jax.random.normal(jax.random.PRNGKey(1), (D, F)) / D**0.5
w2 = jax.random.normal(jax.random.PRNGKey(2), (F, D)) / F**0.5

def seam(mode):
    @jax.jit
    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(P(None, "model", None), P(None, "model"),
                                 P("model", None)),
                       out_specs=P(None, "model", None), check_vma=False)
    def f(xs, w1s, w2s):
        y = overlap.ag_matmul(xs, w1s, "model", mode)
        return overlap.matmul_rs(jax.nn.gelu(y), w2s, "model", mode)
    return np.asarray(f(x, w1, w2))

ref = seam("xla")
out = seam("decomposed_bidir")
assert np.abs(out - ref).max() < 1e-3
print("BIDIR_OK")
"""


def test_bidirectional_ring(subproc):
    assert "BIDIR_OK" in subproc(_BIDIR, n_devices=4)


# ---------------------------------------------------------------------------
# the previously untested modes: decomposed_bidir / decomposed_q8 values AND
# gradients vs the xla oracle, reverse-direction rings, and the matmul_ar
# (decode seam) mode-equivalence sweep
# ---------------------------------------------------------------------------
_FULL_SWEEP = r"""
import functools
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map
from repro.core import overlap

mesh = Mesh(np.array(jax.devices()), ("model",))
B, S, D, F = 2, 256, 128, 256
x = jax.random.normal(jax.random.PRNGKey(0), (B, S, D), jnp.float32)
w1 = jax.random.normal(jax.random.PRNGKey(1), (D, F)) / D**0.5
w2 = jax.random.normal(jax.random.PRNGKey(2), (F, D)) / F**0.5

def seam(mode, chunks=0, reverse=False):
    @jax.jit
    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(P(None, "model", None), P(None, "model"),
                                 P("model", None)),
                       out_specs=P(None, "model", None), check_vma=False)
    def f(xs, w1s, w2s):
        y = overlap.ag_matmul(xs, w1s, "model", mode, chunks, reverse)
        return overlap.matmul_rs(jax.nn.gelu(y), w2s, "model", mode, chunks,
                                 reverse)
    return np.asarray(f(x, w1, w2))

ref = seam("xla")
scale = np.abs(ref).max()
for mode, chunks, rev, tol in [
        ("decomposed", 0, True, 1e-3),           # reverse ring
        ("decomposed", 8, True, 1e-3),
        ("decomposed_bidir", 0, False, 1e-3),
        ("decomposed_bidir", 16, False, 1e-3),
        ("decomposed_q8", 0, False, 2e-2),       # int8 gather budget
        ("decomposed_q8", 8, True, 2e-2)]:
    out = seam(mode, chunks, rev)
    rel = np.abs(out - ref).max() / scale
    assert rel < tol, (mode, chunks, rev, rel)

# q8 ring must produce EXACTLY the monolithic-gather q8 values (same
# encode/decode path, different transport) ...
def ag_only(mode, chunks=0):
    @jax.jit
    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(P(None, "model", None), P(None, "model")),
                       out_specs=P(None, None, "model"), check_vma=False)
    def f(xs, ws):
        return overlap.ag_matmul(xs, ws, "model", mode, chunks)
    return np.asarray(f(x, w1))
assert np.abs(ag_only("xla_q8") - ag_only("decomposed_q8", 8)).max() < 1e-5

# ... and it must actually ride the ring: the forward jaxpr carries
# ppermute hops, no monolithic all_gather (the pre-fix regression)
def fwd_jaxpr(mode):
    f = functools.partial(shard_map, mesh=mesh,
                          in_specs=(P(None, "model", None), P(None, "model")),
                          out_specs=P(None, None, "model"), check_vma=False)(
        lambda xs, ws: overlap.ag_matmul(xs, ws, "model", mode, 8))
    return str(jax.make_jaxpr(f)(x, w1))
j = fwd_jaxpr("decomposed_q8")
assert "ppermute" in j and "all_gather" not in j, "q8 lost ring overlap"
assert "all_gather" in fwd_jaxpr("xla_q8")

# gradients vs the xla oracle (bidir is exact; q8's custom_vjp runs the
# interchanged ops on full-precision cotangents so grads stay within the
# quantization budget of the forward)
def loss(mode, chunks=0, reverse=False):
    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(P(None, "model", None), P(None, "model"),
                                 P("model", None)),
                       out_specs=P(), check_vma=False)
    def f(xs, w1s, w2s):
        y = overlap.ag_matmul(xs, w1s, "model", mode, chunks, reverse)
        z = overlap.matmul_rs(jax.nn.gelu(y), w2s, "model", mode, chunks,
                              reverse)
        return jax.lax.psum(jnp.sum(z * z), "model")
    return f

g_ref = jax.jit(jax.grad(loss("xla"), argnums=(0, 1, 2)))(x, w1, w2)
for mode, chunks, rev, tol in [("decomposed_bidir", 0, False, 1e-3),
                               ("decomposed_bidir", 16, False, 1e-3),
                               ("decomposed", 8, True, 1e-3),
                               ("decomposed_q8", 0, False, 5e-2),
                               ("decomposed_q8", 8, True, 5e-2)]:
    g = jax.jit(jax.grad(loss(mode, chunks, rev), argnums=(0, 1, 2)))(x, w1, w2)
    for a, b in zip(g, g_ref):
        rel = (np.abs(np.asarray(a) - np.asarray(b)).max()
               / (np.abs(np.asarray(b)).max() + 1e-9))
        assert rel < tol, (mode, chunks, rev, rel)
print("FULL_SWEEP_OK")
"""


def test_bidir_q8_reverse_sweep_4dev(subproc):
    assert "FULL_SWEEP_OK" in subproc(_FULL_SWEEP, n_devices=4)


_AR_SWEEP = r"""
import functools
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map
from repro.core import overlap

mesh = Mesh(np.array(jax.devices()), ("model",))
B, M, F, D = 2, 4, 256, 128
y = jax.random.normal(jax.random.PRNGKey(0), (B, M, F), jnp.float32)
w = jax.random.normal(jax.random.PRNGKey(1), (F, D)) / F**0.5

def ar(mode, chunks=0):
    @jax.jit
    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(P(None, None, "model"), P("model", None)),
                       out_specs=P(None, None, None), check_vma=False)
    def f(ys, ws):
        return overlap.matmul_ar(ys, ws, "model", mode, chunks)
    return np.asarray(f(y, w))

ref = ar("xla")
for mode, chunks in [("decomposed", 0), ("decomposed", 2), ("decomposed", 4),
                     ("decomposed", 7),           # non-dividing chunk count
                     ("decomposed_bidir", 0), ("decomposed_q8", 2),
                     ("flux", 0)]:
    out = ar(mode, chunks)
    assert np.abs(out - ref).max() < 1e-3, (mode, chunks)

# gradients through the decode seam
def loss(mode, chunks=0):
    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(P(None, None, "model"), P("model", None)),
                       out_specs=P(), check_vma=False)
    def f(ys, ws):
        z = overlap.matmul_ar(ys, ws, "model", mode, chunks)
        return jnp.sum(z * z)
    return f
g_ref = jax.jit(jax.grad(loss("xla"), argnums=(0, 1)))(y, w)
for mode, chunks in [("decomposed", 0), ("decomposed", 4)]:
    g = jax.jit(jax.grad(loss(mode, chunks), argnums=(0, 1)))(y, w)
    for a, b in zip(g, g_ref):
        rel = (np.abs(np.asarray(a) - np.asarray(b)).max()
               / (np.abs(np.asarray(b)).max() + 1e-9))
        assert rel < 1e-3, (mode, chunks, rel)
print("AR_SWEEP_OK")
"""


def test_matmul_ar_mode_equivalence_4dev(subproc):
    assert "AR_SWEEP_OK" in subproc(_AR_SWEEP, n_devices=4)
