"""GPipe pipeline substrate: 4-stage correctness vs sequential execution."""
import pytest


_PIPE = r"""
import functools
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map
from repro.parallel.pipeline import pipeline_forward, bubble_fraction

P_STAGES = 4
mesh = Mesh(np.array(jax.devices()).reshape(P_STAGES, 1), ("pod", "model"))
B, S, D = 8, 4, 16
x = jax.random.normal(jax.random.PRNGKey(0), (B, S, D))
# per-stage weights: stage i applies tanh(x @ w[i])
w = jax.random.normal(jax.random.PRNGKey(1), (P_STAGES, D, D)) * 0.3

@jax.jit
@functools.partial(shard_map, mesh=mesh,
                   in_specs=(P(None, None, None), P("pod", None, None)),
                   out_specs=P(None, None, None), check_vma=False)
def piped(xx, ww):
    def stage_fn(h, t):
        return jnp.tanh(jnp.einsum("bsd,de->bse", h, ww[0]))
    out = pipeline_forward(stage_fn, xx, "pod", num_microbatches=4)
    # broadcast last stage's result to all (psum of masked contributions)
    me = jax.lax.axis_index("pod")
    return jax.lax.psum(jnp.where(me == P_STAGES - 1, out, 0), "pod")

got = piped(x, w)
ref = x
for i in range(P_STAGES):
    ref = jnp.tanh(jnp.einsum("bsd,de->bse", ref, w[i]))
err = float(jnp.max(jnp.abs(got - ref)))
assert err < 1e-5, err
assert abs(bubble_fraction(4, 4) - 3/7) < 1e-9
print("PIPE_OK")
"""


def test_pipeline_4stage(subproc):
    assert "PIPE_OK" in subproc(_PIPE, n_devices=4)
