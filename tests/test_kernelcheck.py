"""Seeded-violation fixtures for ``repro.analysis.kernelcheck``.

Every kernel contract class is exercised from BOTH sides: the clean mini
ring kernel (a faithful miniature of ``kernels/ag_gemm.py``'s DMA
protocol) must pass, and one deliberately broken variant per class —
unbalanced semaphore, double-written slot, wrong ring neighbor, missed
output tile, VMEM-overflowing tiling — must be detected WITH step/slot
provenance.  Plus: a green run over the in-tree kernels, the closed-form
footprint model cross-checked against a real captured call, and the proof
that ``autotune`` never prices or times a tiling the budget model rejects.

All tracing is abstract (captured grid programs replayed per rank) — no
devices, no Mosaic, no subprocesses.
"""
import functools

import jax
import jax.numpy as jnp
import pytest
from jax.experimental import pallas as pl

from repro import compat
from repro.analysis import kernelcheck
from repro.analysis.kernelcheck import (
    KernelCase, VMEM_LIMIT_BYTES, check_case, flux_tile_footprint,
    ring_schedules, run_kernel_checks, tile_budget_ok, traced_vmem_bytes,
    _capture_pallas_call)

AXIS = "model"
N = 4
M_SH, K, NN = 8, 8, 8


# ---------------------------------------------------------------------------
# the mini ring kernel: ag_gemm's DMA protocol at one tile per step
# ---------------------------------------------------------------------------
def _mini_kernel(a_ref, b_ref, o_ref, a_agg, acc_ref, a_vmem, b_vmem,
                 o_vmem, local_sem, send_sem, recv_sem, copy_a, copy_b,
                 copy_o, *, axis_name, n_dev, bug=None):
    step = pl.program_id(0)
    me = jax.lax.axis_index(axis_name)
    nbr = (me + 1) % n_dev
    if bug == "wrong-neighbor":
        nbr = (me + 2) % n_dev
    owner = (me - step) % n_dev

    @pl.when(step == 0)
    def _preset_local():
        cp = compat.make_async_copy(a_ref, a_agg.at[me], local_sem)
        cp.start()
        cp.wait()

    @pl.when(step > 0)
    def _wait_arrival():
        compat.make_async_remote_copy(
            src_ref=a_agg.at[owner], dst_ref=a_agg.at[owner],
            send_sem=send_sem, recv_sem=recv_sem,
            device_id=nbr).wait_recv()

    @pl.when(step < n_dev - 1)
    def _forward():
        compat.make_async_remote_copy(
            src_ref=a_agg.at[owner], dst_ref=a_agg.at[owner],
            send_sem=send_sem, recv_sem=recv_sem,
            device_id=nbr).start()

    if bug == "double-write":
        @pl.when(step == 1)
        def _second_writer():
            compat.make_async_remote_copy(
                src_ref=a_agg.at[owner], dst_ref=a_agg.at[owner],
                send_sem=send_sem, recv_sem=recv_sem,
                device_id=nbr).start()

    ca = compat.make_async_copy(a_agg.at[owner], a_vmem, copy_a)
    cb = compat.make_async_copy(b_ref, b_vmem, copy_b)
    ca.start(); cb.start(); ca.wait(); cb.wait()
    acc_ref[...] = jnp.dot(a_vmem[...], b_vmem[...],
                           preferred_element_type=jnp.float32)

    emit = (step > 0) if bug == "missed-tile" else (step >= 0)

    @pl.when(emit)
    def _epilogue():
        o_vmem[...] = acc_ref[...].astype(o_vmem.dtype)
        m_sh = a_vmem.shape[0]
        co = compat.make_async_copy(
            o_vmem, o_ref.at[pl.ds(owner * m_sh, m_sh), :], copy_o)
        co.start(); co.wait()

    drain = (step < n_dev - 1) & (step != 0) if bug == "unbalanced-sem" \
        else (step < n_dev - 1)

    @pl.when(drain)
    def _drain_send():
        compat.make_async_remote_copy(
            src_ref=a_agg.at[owner], dst_ref=a_agg.at[owner],
            send_sem=send_sem, recv_sem=recv_sem,
            device_id=nbr).wait_send()


def _mini_ring(bug=None, acc_shape=None):
    a = jnp.zeros((M_SH, K), jnp.bfloat16)
    b = jnp.zeros((K, NN), jnp.bfloat16)
    kernel = functools.partial(_mini_kernel, axis_name=AXIS, n_dev=N,
                               bug=bug)
    scratch = [
        compat.hbm_scratch((N, M_SH, K), a.dtype),      # a_agg
        compat.VMEM(acc_shape or (M_SH, NN), jnp.float32),
        compat.VMEM((M_SH, K), a.dtype),
        compat.VMEM((K, NN), b.dtype),
        compat.VMEM((M_SH, NN), a.dtype),
    ] + [compat.DMA_SEM] * 6
    return compat.pallas_call(
        kernel, grid=(N,),
        in_specs=[pl.BlockSpec(memory_space=compat.ANY)] * 2,
        out_specs=pl.BlockSpec(memory_space=compat.ANY),
        out_shape=jax.ShapeDtypeStruct((N * M_SH, NN), a.dtype),
        scratch_shapes=scratch)(a, b)


def _case(bug=None, acc_shape=None):
    return KernelCase(label=f"mini[{bug or 'clean'}]",
                      build=lambda: _mini_ring(bug, acc_shape),
                      kind="ag", n_dev=N, reverse=False, slot_rows=M_SH)


# ---------------------------------------------------------------------------
# clean baseline + one seeded violation per contract class
# ---------------------------------------------------------------------------
def test_clean_mini_ring_passes():
    assert check_case(_case()) == []


def test_detects_unbalanced_semaphore():
    # the step-0 drain is skipped; send waits pop FIFO, so the leftover
    # (reported) send is the LAST one started — step n_dev-2
    errs = check_case(_case("unbalanced-sem"))
    hits = [e for e in errs if "unbalanced send" in e]
    assert hits, errs
    assert any(f"step={N - 2}" in e and "sem" in e for e in hits), hits
    assert len(hits) == N                       # one leftover send per rank


def test_detects_double_written_slot():
    # a second unordered DMA lands in the same in-flight a_agg slot
    errs = check_case(_case("double-write"))
    hits = [e for e in errs if "two unordered DMAs" in e]
    assert hits, errs
    # provenance: the duplicated writer fires at step 1, into scratch0
    assert any("step=1" in e and "scratch0" in e for e in hits), hits


def test_detects_wrong_ring_neighbor():
    errs = check_case(_case("wrong-neighbor"))
    hits = [e for e in errs if "ring neighbor" in e]
    assert hits, errs
    # rank 0 targeted rank 2; the forward-ring reference neighbor is 1
    assert any("targets rank 2" in e and "rank 0 is 1" in e
               for e in hits), hits


def test_detects_missed_output_tile():
    # the step-0 tile (each rank's own shard rows) is never stored
    errs = check_case(_case("missed-tile"))
    hits = [e for e in errs if "coverage broken" in e]
    assert hits, errs
    assert any("rank0" in e and "(0, 0)" in e for e in hits), hits


def test_detects_vmem_overflowing_tiling():
    errs = check_case(_case(acc_shape=(3000, 2000)))    # 24 MB fp32 acc
    hits = [e for e in errs if "VMEM footprint" in e and "exceeds" in e]
    assert hits, errs


# ---------------------------------------------------------------------------
# green run over the in-tree kernels + schedule/footprint cross-checks
# ---------------------------------------------------------------------------
def test_in_tree_kernels_green():
    # all four kernels (ag_gemm, gemm_rs, flash_attention, mla_decode) x
    # both ring directions x one config's shape cells
    assert run_kernel_checks(["llama2_70b"]) == []


def test_ring_schedule_matches_overlap_reference():
    # the reference tables are pure consequences of overlap._ring_perm:
    # step-0 owner is the local shard, each hop hands it downstream
    for reverse in (False, True):
        nbr, ag, rs = ring_schedules(N, reverse)
        sgn = -1 if reverse else 1
        for me in range(N):
            assert nbr[me] == (me + sgn) % N
            assert ag[me][0] == me
            assert rs[me][N - 1] == me
            for s in range(1, N):
                assert ag[me][s] == ag[(me - sgn) % N][s - 1]


def test_footprint_model_matches_traced_call():
    # the closed form autotune prunes with must equal the captured VMEM
    # scratch bytes of the real wrappers — the two cannot drift apart
    from repro.kernels.ag_gemm import ag_gemm
    from repro.kernels.gemm_rs import gemm_rs

    box = {}
    a = jnp.zeros((32, 64), jnp.bfloat16)
    b = jnp.zeros((64, 32), jnp.bfloat16)
    bias = jnp.zeros((32,), jnp.bfloat16)
    with _capture_pallas_call(box):
        ag_gemm(a, b, axis_name=AXIS, n_dev=N, bm=16, bk=32, bn=16,
                bias=bias)
    assert traced_vmem_bytes(box["cap"]) == flux_tile_footprint(
        "ag", 16, 32, 16, dtype_bytes=2, has_bias=True)

    box = {}
    a = jnp.zeros((N * 16, 64), jnp.bfloat16)
    with _capture_pallas_call(box):
        gemm_rs(a, b, axis_name=AXIS, n_dev=N, bm=16, bk=32, bn=16)
    assert traced_vmem_bytes(box["cap"]) == flux_tile_footprint(
        "rs", 16, 32, 16, dtype_bytes=2)


def test_tile_budget_rejects_infeasible():
    assert tile_budget_ok("ag", (128, 512, 128))
    # a 4096^2 fp32 accumulator alone is 64 MB — 4x the per-core VMEM
    assert not tile_budget_ok("ag", (4096, 4096, 4096))
    assert flux_tile_footprint("ag", 4096, 4096, 4096) > VMEM_LIMIT_BYTES


# ---------------------------------------------------------------------------
# autotune pruning: infeasible tilings are never priced and never timed
# ---------------------------------------------------------------------------
def test_autotune_prunes_before_pricing(monkeypatch):
    from repro.tuning import autotune

    monkeypatch.setattr(autotune, "_FLUX_BLOCK_PREFS",
                        ((4096, 4096, 4096),))
    priced = []
    real_estimate = autotune.analytic_estimate

    def spy_estimate(kind, m, n, k, n_dev, cand, *a, **kw):
        priced.append(cand)
        return real_estimate(kind, m, n, k, n_dev, cand, *a, **kw)

    monkeypatch.setattr(autotune, "analytic_estimate", spy_estimate)
    res = autotune.tune_seam("ag", 32768, 65536, 32768, 8, measure=False,
                             seam="mlp_ag")
    assert res.pruned == 2                  # both ring directions rejected
    assert all(c.mode != "flux" for c in priced)
    assert all(r["mode"] != "flux" for r in res.table)
    for c in priced:
        if c.blocks is not None:
            assert tile_budget_ok("ag", tuple(c.blocks))


def test_autotune_prunes_before_timing(monkeypatch):
    from repro.core import ect
    from repro.tuning import autotune

    monkeypatch.setattr(autotune, "_FLUX_BLOCK_PREFS",
                        ((4096, 4096, 4096), (64, 64, 64)))
    timed = []

    def fake_bench(kind, m, n, k, n_dev, cand, dtype, **kw):
        timed.append(cand)
        return (lambda: None), ()

    monkeypatch.setattr(autotune, "_bench_callable", fake_bench)
    monkeypatch.setattr(ect, "time_fn", lambda fn, *a, **kw: 1.0)
    res = autotune.tune_seam("ag", 32768, 65536, 32768, 8, measure=True,
                             modes=("xla", "flux"), seam="mlp_ag")
    assert res.source == "measured"
    assert res.pruned == 2
    assert timed, "measured sweep must still time feasible candidates"
    for c in timed:
        if c.mode == "flux":
            assert tile_budget_ok("ag", tuple(c.blocks))


def test_check_cli_kernels_lane():
    from repro.analysis import check
    assert check.main(["--kernels", "--configs", "llama2_70b", "-q"]) == 0
