"""Per-architecture smoke tests: REDUCED same-family config, one forward +
one train step on CPU, asserting output shapes and finite values."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ARCH_IDS, ParallelConfig, get_smoke_config
from repro.compat import shard_map
from repro.models import model as M
from repro.models import serve as S
from repro.optim import adamw
from repro.parallel.sharding import TPContext


def _mesh():
    return Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))


def _batch(cfg, key, b=2, s=32):
    labels = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    if cfg.frontend:
        return {"embeds": jax.random.normal(key, (b, s, cfg.d_model),
                                            jnp.bfloat16),
                "labels": labels}
    return {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
            "labels": labels}


def _bspecs(cfg):
    if cfg.frontend:
        return {"embeds": P("data", "model", None), "labels": P("data", None)}
    return {"tokens": P("data", None), "labels": P("data", None)}


@pytest.mark.parametrize("arch", ARCH_IDS + ["gpt3_175b"])
def test_forward_smoke(arch):
    cfg = get_smoke_config(arch)
    par = ParallelConfig(tp=1, dp=1)
    mesh = _mesh()
    key = jax.random.PRNGKey(0)
    params = M.init_model(key, cfg, par)
    specs = M.param_specs(cfg, par, params)
    ctx = TPContext(axis="model", dp_axes=("data",),
                    ep_axes=("model",) if cfg.moe else ())
    batch = _batch(cfg, key)

    @jax.jit
    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(specs, _bspecs(cfg)), out_specs=P(),
                       check_vma=False)
    def loss_fn(p, b):
        return M.forward_loss(p, b, ctx, cfg, par)

    loss = float(loss_fn(params, batch))
    assert np.isfinite(loss), f"{arch}: non-finite loss {loss}"
    # random-init loss should be near ln(vocab) (generous band)
    assert 0.5 < loss < 4 * np.log(cfg.vocab_size), (arch, loss)


@pytest.mark.parametrize("arch", ["codeqwen15_7b", "jamba_v01_52b",
                                  "deepseek_v3_671b", "rwkv6_3b"])
def test_train_step_smoke(arch):
    """One full train step (grads + AdamW) decreases nothing NaN-y."""
    from repro.runtime import trainer as T
    cfg = get_smoke_config(arch)
    par = ParallelConfig(tp=1, dp=1)
    mesh = _mesh()
    tc = T.TrainConfig(total_steps=5, warmup_steps=1, base_lr=1e-3)
    params_eval = jax.eval_shape(
        lambda: M.init_model(jax.random.PRNGKey(0), cfg, par))
    pspecs = M.param_specs(cfg, par, params_eval)
    step_fn = T.make_train_step(cfg, par, mesh, adamw.AdamWConfig(), tc,
                                pspecs)
    params = M.init_model(jax.random.PRNGKey(0), cfg, par)
    opt = adamw.init_opt_state(params)
    batch = _batch(cfg, jax.random.PRNGKey(1), b=2, s=32)
    params, opt, metrics = step_fn(params, opt, batch,
                                   jnp.asarray(0, jnp.int32))
    assert np.isfinite(float(metrics["loss"]))
    assert int(opt["count"]) == 1
    leaves = jax.tree.leaves(params)
    assert all(np.all(np.isfinite(np.asarray(l, np.float32)))
               for l in leaves), f"{arch}: non-finite params after step"


@pytest.mark.parametrize("arch", ["codeqwen15_7b", "jamba_v01_52b",
                                  "rwkv6_3b", "deepseek_v3_671b"])
def test_decode_step_smoke(arch):
    cfg = get_smoke_config(arch)
    par = ParallelConfig(tp=1, dp=1)
    mesh = _mesh()
    ctx = TPContext(axis="model", dp_axes=("data",),
                    ep_axes=("model",) if cfg.moe else ())
    params = M.init_model(jax.random.PRNGKey(0), cfg, par)
    b, s_max = 2, 64
    cache_sds, cache_spec = S.cache_specs(cfg, par, b, s_max,
                                          dp_axes=("data",))
    caches = jax.tree.map(lambda x: jnp.zeros(x.shape, x.dtype), cache_sds)
    pspecs = M.param_specs(cfg, par, params)

    @jax.jit
    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(pspecs, cache_spec, P("data", None), P()),
                       out_specs=(P("data", None), cache_spec),
                       check_vma=False)
    def dec(p, c, t, pos):
        return S.decode_step(p, c, t, pos, ctx, cfg, par)

    toks = jnp.zeros((b, 1), jnp.int32)
    for pos in range(3):
        toks, caches = dec(params, caches, toks,
                           jnp.asarray(pos, jnp.int32))
    assert toks.shape == (b, 1)
    assert np.all(np.asarray(toks) >= 0)
    assert np.all(np.asarray(toks) < cfg.vocab_size)


@pytest.mark.parametrize("arch", ["codeqwen15_7b", "rwkv6_3b"])
def test_prefill_matches_decode(arch):
    """Prefilling N tokens then decoding must equal token-by-token decode."""
    cfg = get_smoke_config(arch)
    par = ParallelConfig(tp=1, dp=1)
    mesh = _mesh()
    ctx = TPContext(axis="model", dp_axes=("data",))
    params = M.init_model(jax.random.PRNGKey(0), cfg, par)
    pspecs = M.param_specs(cfg, par, params)
    b, s = 2, 16
    prompt = jax.random.randint(jax.random.PRNGKey(5), (b, s), 0,
                                cfg.vocab_size)

    cache_sds, cache_spec = S.cache_specs(cfg, par, b, s, dp_axes=("data",))

    @jax.jit
    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(pspecs, {"tokens": P("data", None)}),
                       out_specs=(P("data", None), cache_spec),
                       check_vma=False)
    def prefill(p, batch):
        return S.prefill_step(p, batch, ctx, cfg, par)

    @jax.jit
    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(pspecs, cache_spec, P("data", None), P()),
                       out_specs=(P("data", None), cache_spec),
                       check_vma=False)
    def dec(p, c, t, pos):
        return S.decode_step(p, c, t, pos, ctx, cfg, par)

    nxt_pre, _ = prefill(params, {"tokens": prompt})

    caches = jax.tree.map(lambda x: jnp.zeros(x.shape, x.dtype), cache_sds)
    nxt = None
    for pos in range(s):
        nxt, caches = dec(params, caches, prompt[:, pos:pos + 1],
                          jnp.asarray(pos, jnp.int32))
    np.testing.assert_array_equal(np.asarray(nxt_pre), np.asarray(nxt))
