"""Dry-run machinery units that don't need 512 devices."""
import re

import pytest

from repro.configs.base import ARCH_IDS, SHAPES, get_config, shape_applicable
from repro.launch.presets import production_parallel


def test_shape_applicability_matrix():
    """32 runnable cells + 8 documented skips per mesh."""
    runnable = sum(
        1 for a in ARCH_IDS for s in SHAPES.values()
        if shape_applicable(get_config(a), s))
    assert runnable == 32
    skipped = 10 * 4 - runnable
    assert skipped == 8
    # only the sub-quadratic archs keep long_500k
    keep = [a for a in ARCH_IDS
            if shape_applicable(get_config(a), SHAPES["long_500k"])]
    assert sorted(keep) == ["jamba_v01_52b", "rwkv6_3b"]


def test_presets_cover_every_arch():
    for a in ARCH_IDS:
        cfg = get_config(a)
        for kind in ("train", "prefill", "decode"):
            for mp in (False, True):
                par = production_parallel(cfg, multi_pod=mp, kind=kind)
                assert par.tp == 16 and par.dp == 16
                assert par.pods == (2 if mp else 1)
                if cfg.moe and cfg.moe.num_experts > 16:
                    assert par.ep_over_dp
                if mp and kind == "train":
                    assert par.grad_compress


def test_hlo_collective_regex():
    # NOTE: never import repro.launch.dryrun in-process (it forces 512
    # devices before jax init); the census lives in analysis for this reason
    from repro.analysis.hlo_census import hlo_collective_counts
    text = """
      %ag = all-gather(...), %ar-start = all-reduce-start(...)
      %rs = reduce-scatter(...), %cp = collective-permute-start(...)
      %a2a = all-to-all(...)
    """
    counts = hlo_collective_counts(text)
    assert counts["all-gather"] == 1
    assert counts["reduce-scatter"] == 1
    assert counts["collective-permute"] == 1
    assert counts["all-to-all"] == 1


def test_param_count_magnitudes():
    """Analytic param counts land near the archs' nameplate sizes."""
    from repro.models.model import count_params_analytic
    expect = {
        "codeqwen15_7b": (6e9, 9e9),
        "qwen15_110b": (95e9, 125e9),
        "deepseek_v3_671b": (600e9, 720e9),
        "jamba_v01_52b": (45e9, 60e9),
        "rwkv6_3b": (2.2e9, 4.5e9),
        "minicpm_2b": (2e9, 3.6e9),
        "phi4_mini_38b": (3e9, 5e9),
        "musicgen_medium": (1.2e9, 2.4e9),
        "qwen2_vl_72b": (62e9, 82e9),
        "llama4_scout_17b_a16e": (95e9, 120e9),
    }
    for a, (lo, hi) in expect.items():
        n = count_params_analytic(get_config(a))
        assert lo < n < hi, (a, n)


def test_moe_active_params():
    from repro.models.model import count_params_analytic
    cfg = get_config("deepseek_v3_671b")
    total = count_params_analytic(cfg)
    active = count_params_analytic(cfg, active_only=True)
    # DeepSeek-V3: 671B total / 37B active nameplate
    assert 25e9 < active < 50e9, active
    assert active < total / 10
