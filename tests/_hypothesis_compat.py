"""Optional-dependency shim for ``hypothesis``.

The tier-1 suite must collect and pass on machines without hypothesis
installed (the CI container does not ship it).  When the real package is
available we re-export it untouched; otherwise a minimal deterministic
stand-in runs each ``@given`` test over a fixed-seed sample of the strategy
space — weaker than real property testing (no shrinking, no coverage-guided
generation) but it keeps the properties exercised instead of skipped.

Usage in tests::

    from _hypothesis_compat import given, settings, strategies as st
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import inspect
    import random

    _DEFAULT_MAX_EXAMPLES = 20

    class _Strategy:
        """A draw rule: ``rng -> value``."""

        def __init__(self, draw):
            self._draw = draw

        def example(self, rng):
            return self._draw(rng)

    class strategies:  # noqa: N801 — mimics the ``hypothesis.strategies`` module
        @staticmethod
        def integers(min_value=0, max_value=2 ** 31 - 1):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def floats(min_value=0.0, max_value=1.0):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda rng: rng.choice(elements))

        @staticmethod
        def lists(elem, min_size=0, max_size=8):
            def draw(rng):
                n = rng.randint(min_size, max_size)
                return [elem.example(rng) for _ in range(n)]
            return _Strategy(draw)

    def settings(max_examples=_DEFAULT_MAX_EXAMPLES, **_ignored):
        """Accepts (and mostly ignores) real-hypothesis knobs like
        ``deadline``; only ``max_examples`` is honoured."""
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(**strats):
        """Keyword-only ``@given``: runs the test over ``max_examples``
        deterministic draws (seed 0) per strategy."""
        def deco(fn):
            sig = inspect.signature(fn)
            passthrough = [p for name, p in sig.parameters.items()
                           if name not in strats]

            def runner(*args, **kwargs):
                rng = random.Random(0)
                for _ in range(getattr(runner, "_max_examples",
                                       _DEFAULT_MAX_EXAMPLES)):
                    drawn = {k: s.example(rng) for k, s in strats.items()}
                    fn(*args, **drawn, **kwargs)

            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            runner.__module__ = fn.__module__
            # pytest must only see the fixture params, not the drawn ones
            runner.__signature__ = sig.replace(parameters=passthrough)
            return runner
        return deco
