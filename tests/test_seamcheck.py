"""Seeded-violation fixtures for repro.analysis.{seamcheck,lint,check}.

Every contract the checker enforces is exercised from BOTH sides: a clean
construct must pass, and a deliberately seeded violation of each rule must
be reported (with an actionable message).  All tracing is abstract
(``make_jaxpr`` + ``axis_env``) — no devices, no subprocesses.
"""
import jax
import jax.numpy as jnp
import pytest
from jax import lax

from repro.analysis import lint, seamcheck

TP = 4
ENV = [("model", TP)]


def _colls(fn, *args):
    return seamcheck.collect_collectives(
        jax.make_jaxpr(fn, axis_env=ENV)(*args))


# ---------------------------------------------------------------------------
# walker
# ---------------------------------------------------------------------------
def test_walker_psum_scatter_traces_as_reduce_scatter():
    x = jax.ShapeDtypeStruct((TP, 8), jnp.float32)
    cs = _colls(  # lint: allow(raw-collective)
        lambda a: lax.psum_scatter(a, "model"), x)
    assert [c.prim for c in cs] == ["reduce_scatter"]


def test_walker_counts_all_to_all():
    x = jax.ShapeDtypeStruct((TP, 8), jnp.float32)
    cs = _colls(  # lint: allow(raw-collective)
        lambda a: lax.all_to_all(a, "model", 0, 0, tiled=True), x)
    assert [c.prim for c in cs] == ["all_to_all"]
    assert "all_to_all" in seamcheck.CENSUS_PRIMS


def test_census_reports_stray_all_to_all():
    x = jax.ShapeDtypeStruct((TP, 16, 64), jnp.float32)
    cs = _colls(  # lint: allow(raw-collective)
        lambda a: lax.all_to_all(a, "model", 0, 0, tiled=True), x)
    errs = seamcheck.census_errors(cs, "model", min_elems=TP * 16 * 64)
    assert len(errs) == 1
    assert "unattributed" in errs[0] and "all_to_all" in errs[0]


def test_walker_counts_scan_trips_weighted():
    x = jax.ShapeDtypeStruct((8,), jnp.float32)

    def f(a):
        def body(c, _):
            return lax.psum(c, "model"), ()
        out, _ = lax.scan(body, a, None, length=5)
        return out

    jx = jax.make_jaxpr(f, axis_env=ENV)(x)
    assert seamcheck.count(jx, "psum") == 1
    assert seamcheck.count(jx, "psum", weighted=True) == 5


def test_walker_scope_survives_transpose():
    x = jax.ShapeDtypeStruct((8,), jnp.float32)

    def f(a):
        with jax.named_scope("seam_fixture"):
            return jnp.sum(lax.psum(a, "model") ** 2)

    cs = _colls(lambda a: jax.grad(f)(a), x)
    assert cs and all(c.seam_tagged for c in cs)


# ---------------------------------------------------------------------------
# contract 1: census (stray full-activation collective)
# ---------------------------------------------------------------------------
def test_census_reports_stray_full_activation_all_gather():
    x = jax.ShapeDtypeStruct((2, 16, 64), jnp.float32)
    cs = _colls(  # lint: allow(raw-collective)
        lambda a: lax.all_gather(a, "model", axis=1, tiled=True), x)
    errs = seamcheck.census_errors(cs, "model", min_elems=2 * 16 * 64)
    assert len(errs) == 1
    assert "unattributed" in errs[0] and "all_gather" in errs[0]
    assert "(2, 16, 64)" in errs[0]          # shapes in the report


def test_census_passes_seam_tagged_and_tiny_collectives():
    x = jax.ShapeDtypeStruct((2, 16, 64), jnp.float32)
    t = jax.ShapeDtypeStruct((2,), jnp.float32)

    def f(a, b):
        with jax.named_scope("seam_fixture"):
            # lint: allow(raw-collective)
            big = lax.all_gather(a, "model", axis=1, tiled=True)
        tiny = lax.psum(b, "model")          # xent-scale: under threshold
        return big, tiny

    errs = seamcheck.census_errors(_colls(f, x, t), "model",
                                   min_elems=2 * 16 * 64)
    assert errs == []


def test_census_ignores_other_axes():
    x = jax.ShapeDtypeStruct((2, 16, 64), jnp.float32)
    cs = seamcheck.collect_collectives(jax.make_jaxpr(
        lambda a: lax.psum(a, "data"),
        axis_env=[("data", 2), ("model", TP)])(x))
    assert seamcheck.census_errors(cs, "model", min_elems=1) == []


# ---------------------------------------------------------------------------
# contract 2: cotangent completion (the PR 5 mamba x_proj bug class)
# ---------------------------------------------------------------------------
def _rank_exclusive_consumer(complete: bool):
    """y = replicated(x) @ w_shard: w is rank-exclusive, so dy arrives as a
    per-rank partial and dx must be psum'd — the buggy variant skips it."""
    @jax.custom_vjp
    def f(x, w):
        return x @ w

    def fwd(x, w):
        return x @ w, (x, w)

    def bwd(res, dy):
        x, w = res
        if complete:
            # the repo convention (_fused_bwd): complete the per-rank
            # partial FIRST, then contract against rank-exclusive operands
            dy = lax.psum(dy, "model")
        return dy @ w.T, x.T @ dy

    f.defvjp(fwd, bwd)
    return f


@pytest.mark.parametrize("complete", [True, False])
def test_cotangent_completion_catches_missing_psum(complete):
    x = jax.ShapeDtypeStruct((8, 16), jnp.float32)
    w = jax.ShapeDtypeStruct((16, 4), jnp.float32)
    f = _rank_exclusive_consumer(complete)
    ct = jax.ShapeDtypeStruct((8, 4), jnp.float32)
    errs = seamcheck.check_cotangent_completion(
        f, (x, w), ct, axis_env=ENV, expect_complete=True,
        label="fixture")
    if complete:
        assert errs == []
    else:
        assert errs and "raw (uncompleted) cotangent contraction" in errs[0]


def test_cotangent_spurious_completion_reported():
    # rank-exclusive output: the cotangent arrives FULL; a psum on its
    # path double-counts and must be flagged
    x = jax.ShapeDtypeStruct((8, 16), jnp.float32)
    w = jax.ShapeDtypeStruct((16, 4), jnp.float32)
    f = _rank_exclusive_consumer(True)
    ct = jax.ShapeDtypeStruct((8, 4), jnp.float32)
    errs = seamcheck.check_cotangent_completion(
        f, (x, w), ct, axis_env=ENV, expect_complete=False,
        label="fixture")
    assert errs and "spurious cotangent completion" in errs[0]


def test_fusedop_cotangent_matrix_clean():
    assert seamcheck.fusedop_cotangent_errors(tp=TP) == []


# ---------------------------------------------------------------------------
# contract 3 + end-to-end: one config, both layouts, in-process
# ---------------------------------------------------------------------------
def test_layout_errors_flag_misplaced_collectives():
    x = jax.ShapeDtypeStruct((2, 16, 64), jnp.float32)
    ag = _colls(  # lint: allow(raw-collective)
        lambda a: lax.all_gather(a, "model", axis=1, tiled=True), x)
    errs = seamcheck.layout_errors(ag, None, "seq", "decomposed")
    assert errs and "standalone all_gather" in errs[0]

    pp = _colls(lambda a: lax.ppermute(  # lint: allow(raw-collective)
        a, "model", [(i, (i + 1) % TP) for i in range(TP)]), x)
    errs = seamcheck.layout_errors(pp, None, "hidden", "decomposed")
    assert errs and "ppermute" in errs[0]
    # decode must stay replicated
    errs = seamcheck.layout_errors([], pp, "hidden", "decomposed")
    assert errs and "decode" in errs[0]


def test_one_config_seam_contracts_clean():
    for layout in ("seq", "hidden"):
        assert seamcheck.check_config("minicpm_2b", layout) == []


def test_chunked_prefill_census_lane():
    # the serving admission path (prefill_chunk_step: [1, C] tokens +
    # traced slot/off/chunk_len scalars over the paged pools) is census'd
    # like decode: replicated layout, every full-chunk collective
    # seam-tagged, no ppermute ring / sequence reduce_scatter
    from repro.configs.base import ParallelConfig, get_smoke_config
    from repro.tuning.plans import PlanSet

    cfg = get_smoke_config("minicpm_2b")
    par = ParallelConfig(tp=TP, dp=1, overlap_mode="decomposed",
                         scatter_axis="hidden")
    plans = PlanSet.uniform("decomposed").with_scatter_axis("hidden")
    chunk = 16
    jx = seamcheck.trace_prefill_chunk(cfg, par, plans, tp=TP, b=2,
                                       s_max=64, chunk=chunk)
    cs = seamcheck.collect_collectives(jx)
    assert cs, "chunked prefill admission must trace collectives"
    big = [c for c in cs if c.elems >= chunk * cfg.d_model]
    assert big, "no full-chunk collective traced (threshold too high?)"
    assert all(c.seam_tagged for c in big), \
        [c.describe() for c in big if not c.seam_tagged]
    assert seamcheck.census_errors(cs, "model", chunk * cfg.d_model) == []
    assert seamcheck.layout_errors([], cs, "hidden", "decomposed") == []


# ---------------------------------------------------------------------------
# lint fixtures
# ---------------------------------------------------------------------------
def _lint(src, path="src/repro/models/fixture.py"):
    return lint.lint_source(src, path)


def test_lint_compat_import_rule():
    vs = _lint("from jax.experimental.shard_map import shard_map\n")
    assert [v.rule for v in vs] == ["compat-import"]
    # exempt inside compat/
    assert _lint("from jax.experimental.shard_map import shard_map\n",
                 "src/repro/compat/shims.py") == []


def test_lint_bare_shard_map_rule():
    assert [v.rule for v in _lint("from jax import shard_map\n")] == \
        ["bare-shard-map"]
    assert [v.rule for v in _lint("f = jax.shard_map(g)\n")] == \
        ["bare-shard-map"]


def test_lint_private_backend_rule():
    vs = _lint("y = overlap._rs_ring(x, w, 'model')\n")
    assert [v.rule for v in vs] == ["private-backend"]
    vs = _lint("from repro.core.overlap import _fused_bwd\n")
    assert [v.rule for v in vs] == ["private-backend"]
    assert _lint("op = overlap.FusedOp(kind='ag', axis='model')\n") == []


def test_lint_removed_wrapper_rule():
    vs = _lint("y = ag_matmul(x, w, 'model')\n")
    assert [v.rule for v in vs] == ["removed-wrapper"]
    # the *_ref oracles and string literals no longer trip it (grep did)
    assert _lint("y = ag_matmul_ref(x, w, 'model')\n") == []
    assert _lint("code = 'ag_matmul(x, w)'\n") == []


def test_lint_raw_collective_rule_and_escape():
    src = "y = lax.ppermute(x, 'model', perm)\n"
    assert [v.rule for v in _lint(src)] == ["raw-collective"]
    # the MoE-exchange blind spot: all_to_all and psum_scatter are seam
    # transports too (PR 7) — a raw call outside the seam layer must trip
    assert [v.rule for v in _lint("y = lax.all_to_all(x, 'model', 0, 0)\n")] \
        == ["raw-collective"]
    assert [v.rule for v in _lint("y = lax.psum_scatter(x, 'data')\n")] == \
        ["raw-collective"]
    # allowed files
    assert _lint(src, "src/repro/core/overlap.py") == []
    assert _lint(src, "src/repro/parallel/sharding.py") == []
    # per-line escape, on the line or the line above
    assert _lint("y = lax.ppermute(x, 'model', p)"
                 "  # lint: allow(raw-collective)\n") == []
    assert _lint("# lint: allow(raw-collective)\n"
                 "y = lax.ppermute(x, 'model', p)\n") == []
    # escape for one rule does not silence another — and since the
    # raw-collective escape suppresses nothing here, it is itself stale
    assert [v.rule for v in _lint(
        "y = ag_matmul(x)  # lint: allow(raw-collective)\n")] == \
        ["removed-wrapper", "stale-allow"]


def test_lint_stale_allow_rule():
    # an escape that suppresses nothing is a violation at its comment line
    vs = _lint("x = 1  # lint: allow(raw-collective)\n")
    assert [v.rule for v in vs] == ["stale-allow"]
    assert vs[0].line == 1 and "suppresses no raw-collective" in vs[0].message
    # unknown rule names can never suppress anything
    vs = _lint("x = 1  # lint: allow(not-a-rule)\n")
    assert [v.rule for v in vs] == ["stale-allow"]
    assert "unknown rule" in vs[0].message
    # a USED escape is not stale (coverage window: its line and the next)
    assert _lint("# lint: allow(raw-collective)\n"
                 "y = lax.ppermute(x, 'model', p)\n") == []
    # escape-shaped text inside a string literal is NOT an escape: it
    # neither suppresses a finding nor counts as stale (tokenize comments)
    assert _lint("s = '# lint: allow(raw-collective)'\n") == []
    vs = _lint("s = 'x  # lint: allow(raw-collective)'\n"
               "y = lax.ppermute(x, 'model', p)\n")
    assert [v.rule for v in vs] == ["raw-collective"]
    # the stale-allow finding itself honors the escape mechanism
    assert _lint(
        "x = 1  # lint: allow(raw-collective, stale-allow)\n") == []


def test_lint_clean_tree():
    assert lint.lint_tree() == []


def test_check_cli_lint_lane():
    from repro.analysis import check
    assert check.main(["--lint", "-q"]) == 0
