"""Continuous-batching server: admission, slot recycling, determinism."""
import jax
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.configs.base import ParallelConfig, get_smoke_config
from repro.models import model as M
from repro.runtime.server import Request, ServeConfig, Server


@pytest.fixture(scope="module")
def server():
    cfg = get_smoke_config("minicpm_2b")
    par = ParallelConfig(tp=1, dp=1)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    params = M.init_model(jax.random.PRNGKey(0), cfg, par)
    sc = ServeConfig(max_batch=2, max_seq=64, eos_token=-1, max_new_tokens=4)
    return Server(cfg, par, mesh, params, sc), cfg


def test_serve_more_requests_than_slots(server):
    srv, cfg = server
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size,
                                               size=(3 + i,)).astype(np.int32))
            for i in range(5)]          # 5 requests, 2 slots
    done = srv.serve(reqs)
    assert len(done) == 5
    for r in done:
        assert r.done
        assert 1 <= len(r.output) <= 4
        assert all(0 <= t < cfg.vocab_size for t in r.output)


def test_greedy_determinism(server):
    srv, cfg = server
    prompt = np.arange(5, dtype=np.int32) % cfg.vocab_size
    a = srv.serve([Request(rid=100, prompt=prompt)])[0].output
    b = srv.serve([Request(rid=101, prompt=prompt)])[0].output
    assert a == b
