"""jaxpr cost-analyzer tests: exact dot FLOPs, scan multiplication,
collective byte accounting — the roofline's foundations."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st
from jax.sharding import Mesh, PartitionSpec as P

from repro.analysis import jaxpr_cost as JC


@settings(max_examples=20, deadline=None)
@given(m=st.integers(1, 64), k=st.integers(1, 64), n=st.integers(1, 64))
def test_dot_flops_exact(m, k, n):
    def f(a, b):
        return a @ b

    cost = JC.analyze_fn(f, jax.ShapeDtypeStruct((m, k), jnp.float32),
                         jax.ShapeDtypeStruct((k, n), jnp.float32))
    assert cost.flops == 2.0 * m * k * n


def test_batched_dot_flops():
    def f(a, b):
        return jnp.einsum("bij,bjk->bik", a, b)

    cost = JC.analyze_fn(f, jax.ShapeDtypeStruct((4, 8, 16), jnp.float32),
                         jax.ShapeDtypeStruct((4, 16, 32), jnp.float32))
    assert cost.flops == 2.0 * 4 * 8 * 16 * 32


def test_scan_multiplies_trip_count():
    def f(x, w):
        def body(c, _):
            return c @ w, None
        c, _ = jax.lax.scan(body, x, None, length=7)
        return c

    cost = JC.analyze_fn(f, jax.ShapeDtypeStruct((8, 8), jnp.float32),
                         jax.ShapeDtypeStruct((8, 8), jnp.float32))
    assert cost.flops == 7 * 2.0 * 8 * 8 * 8


def test_nested_scan():
    def f(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            ci, _ = jax.lax.scan(inner, c, None, length=3)
            return ci, None
        c, _ = jax.lax.scan(outer, x, None, length=5)
        return c

    cost = JC.analyze_fn(f, jax.ShapeDtypeStruct((4, 4), jnp.float32),
                         jax.ShapeDtypeStruct((4, 4), jnp.float32))
    assert cost.flops == 15 * 2.0 * 4 * 4 * 4


def test_collective_bytes_in_shard_map(subproc):
    code = r"""
import functools
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map
from repro.analysis import jaxpr_cost as JC

mesh = Mesh(np.array(jax.devices()).reshape(1, 4), ("data", "model"))

def f(x):
    y = jax.lax.all_gather(x, "model", axis=0, tiled=True)   # operand 32*16*4B
    z = jax.lax.psum(y, "model")                             # operand 128*16*4
    return jax.lax.psum_scatter(z, "model", scatter_dimension=0, tiled=True)

sm = shard_map(f, mesh=mesh, in_specs=P("model", None),
                   out_specs=P("model", None), check_vma=False)
x = jax.ShapeDtypeStruct((128, 16), jnp.float32)
jaxpr = jax.make_jaxpr(jax.jit(sm))(x)
cost = JC.analyze_jaxpr(jaxpr.jaxpr, {})
ag = 32 * 16 * 4      # local shard operand
ar = 128 * 16 * 4
rs = 128 * 16 * 4
assert cost.collective_bytes == ag + ar + rs, cost.collective_bytes
assert cost.collective_counts == {"all_gather": 1, "all_reduce": 1,
                                  "reduce_scatter": 1}, cost.collective_counts
# ring-time model: AG (n-1)*shard/bw, AR 2*(n-1)/n*b/bw, RS (n-1)/n*b/bw
bw = JC.ICI_BW
want = (3 * ag) / bw + 2 * 0.75 * ar / bw + 0.75 * rs / bw
assert abs(cost.ici_time - want) < 1e-12, (cost.ici_time, want)
print("COLL_OK")
"""
    assert "COLL_OK" in subproc(code, n_devices=4)


def test_hlo_census_async_pairs_count_once():
    """An async collective lowers to a -start/-done pair naming ONE
    transfer; the census must not double-count it (the old regex let
    "all-gather-done" fall through to a bare "all-gather" match)."""
    from repro.analysis.hlo_census import hlo_collective_counts

    hlo = """
  %ags = bf16[4,128] all-gather-start(%x), dimensions={0}
  %agd = bf16[4,128] all-gather-done(%ags)
  %ar = f32[128] all-reduce(%y), to_apply=%sum
  %cps = bf16[32] collective-permute-start(%z)
  %cpd = bf16[32] collective-permute-done(%cps)
  %rs = f32[32] reduce-scatter(%w), dimensions={0}
"""
    assert hlo_collective_counts(hlo) == {
        "all-gather": 1, "all-reduce": 1, "collective-permute": 1,
        "reduce-scatter": 1}


def test_roofline_terms_dominance():
    c = JC.Cost(flops=197e12, bytes=0, collective_bytes=0)
    t = JC.roofline_terms(c)
    assert abs(t["compute_s"] - 1.0) < 1e-9
    assert t["dominant"] == "compute"
    c = JC.Cost(flops=0, bytes=819e9, collective_bytes=25e9)
    t = JC.roofline_terms(c)
    assert t["dominant"] == "memory"
