"""Per-seam plan plumbing: a heterogeneous PlanSet (different overlap mode
per layer-seam, incl. a per-layer override) must be numerically equivalent
to the single-mode run — the registry changes SCHEDULING, never numerics.
"""
import pytest

_HETERO = r"""
import dataclasses, functools
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map
from repro.configs.base import get_smoke_config, ParallelConfig
from repro.models import model as M
from repro.parallel.sharding import TPContext
from repro.tuning.plans import PlanSet, SeamPlan

cfg = dataclasses.replace(get_smoke_config("codeqwen15_7b"), d_ff=512,
                          compute_dtype="float32")
par = ParallelConfig(tp=4, dp=1)
mesh = Mesh(np.array(jax.devices()).reshape(1, 4), ("data", "model"))

key = jax.random.PRNGKey(0)
B, S = 2, 64
batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
         "labels": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                      cfg.vocab_size)}

params = M.init_model(jax.random.PRNGKey(0), cfg, par)
params = jax.tree.map(
    lambda a: a.astype(jnp.float32) if a.dtype == jnp.bfloat16 else a, params)
specs = M.param_specs(cfg, par, params)
bs = {"tokens": P("data", None), "labels": P("data", None)}

def loss_and_grads(plans):
    ctx = TPContext(axis="model", dp_axes=("data",), mode="xla", plans=plans)

    @jax.jit
    @functools.partial(shard_map, mesh=mesh, in_specs=(specs, bs),
                       out_specs=(P(), specs), check_vma=False)
    def f(p, b):
        def lf(pp):
            return jax.lax.pmean(M.forward_loss(pp, b, ctx, cfg, par),
                                 ("data",))
        l, g = jax.value_and_grad(lf)(p)
        # TP-replicated leaves keep per-shard partials; complete them so the
        # comparison sees the same quantity either way
        return l, g
    return f(params, batch)

uniform = PlanSet.uniform("xla")
# every seam gets a DIFFERENT lossless schedule, plus a per-layer override
hetero = PlanSet(
    default=SeamPlan(mode="decomposed"),
    seams={
        "mlp_ag": SeamPlan(mode="xla"),
        "mlp_rs": SeamPlan(mode="decomposed", comm_chunks=8, reverse=True),
        "attn_ag": SeamPlan(mode="decomposed_bidir"),
        "attn_rs": SeamPlan(mode="decomposed", comm_chunks=16),
        "head_ag": SeamPlan(mode="xla"),
    },
    layers={0: {"attn_ag": SeamPlan(mode="decomposed", reverse=True)}})

l_ref, g_ref = loss_and_grads(uniform)
l_het, g_het = loss_and_grads(hetero)

assert abs(float(l_ref) - float(l_het)) < 2e-4, (float(l_ref), float(l_het))
flat_ref = jax.tree_util.tree_leaves_with_path(g_ref)
flat_het = jax.tree.leaves(g_het)
for (path, a), b in zip(flat_ref, flat_het):
    a, b = np.asarray(a), np.asarray(b)
    rel = np.abs(a - b).max() / (np.abs(a).max() + 1e-9)
    assert rel < 2e-3, (jax.tree_util.keystr(path), rel)

# evidence the plans actually changed SCHEDULING: the heterogeneous trace
# rides ppermute rings, the uniform-xla trace has none
ctx_u = TPContext(axis="model", dp_axes=("data",), mode="xla", plans=uniform)
ctx_h = TPContext(axis="model", dp_axes=("data",), mode="xla", plans=hetero)
from repro.analysis.seamcheck import count
def fwd_jaxpr(ctx):
    f = functools.partial(shard_map, mesh=mesh, in_specs=(specs, bs),
                          out_specs=P(), check_vma=False)(
        lambda p, b: jax.lax.pmean(M.forward_loss(p, b, ctx, cfg, par),
                                   ("data",)))
    return jax.make_jaxpr(f)(params, batch)
ju, jh = fwd_jaxpr(ctx_u), fwd_jaxpr(ctx_h)
assert count(ju, "ppermute") == 0
assert count(jh, "ppermute") > 0
print("HETERO_PLAN_OK", float(l_ref))
"""


def test_heterogeneous_plan_equivalence(subproc):
    out = subproc(_HETERO, n_devices=4, timeout=1800)
    assert "HETERO_PLAN_OK" in out


_DECODE = r"""
import dataclasses, functools
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map
from repro.configs.base import get_smoke_config, ParallelConfig
from repro.models import ffn
from repro.parallel.sharding import TPContext
from repro.tuning.plans import PlanSet, SeamPlan

cfg = get_smoke_config("codeqwen15_7b")
par = ParallelConfig(tp=4, dp=1)
mesh = Mesh(np.array(jax.devices()), ("model",))

p = ffn.init_ffn(jax.random.PRNGKey(0), cfg.d_model, 512, 4, jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (2, 1, cfg.d_model), jnp.float32)
fspec = {"w1": P(None, "model"), "w3": P(None, "model"),
         "w2": P("model", None), "norm": P(None)}

def run(plans):
    ctx = TPContext(axis="model", mode="xla", plans=plans)
    @jax.jit
    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(fspec, P(None, None, None)),
                       out_specs=P(None, None, None), check_vma=False)
    def f(pp, xx):
        return ffn.ffn_decode(pp, xx, ctx)
    return np.asarray(f(p, x))

ref = run(PlanSet.uniform("xla"))
out = run(PlanSet(default=SeamPlan(mode="xla"),
                  seams={"decode_ar": SeamPlan(mode="decomposed",
                                               comm_chunks=4)}))
assert np.abs(out - ref).max() < 1e-5, np.abs(out - ref).max()
print("DECODE_PLAN_OK")
"""


def test_decode_seam_plan_plumbing(subproc):
    assert "DECODE_PLAN_OK" in subproc(_DECODE, n_devices=4)
