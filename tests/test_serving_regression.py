"""Continuous-batching correctness regressions.

Guards the two cache-corruption bugs of the original Server:

* ``step()`` drove every slot with one global ``pos = max(active)`` — short
  slots RoPE-rotated at the wrong position and attended over never-written
  cache rows.  Fixed by the per-slot ``pos: [B]`` vector.
* ``admit()`` prefilled by looping the FULL-BATCH ``decode_step`` over the
  prompt, silently rewriting every other active slot's KV rows at positions
  ``0..len(prompt)``.  Fixed by batched-prefill admission + per-slot cache
  scatter.

The concurrency test serves staggered-length prompts together and demands
token-identical outputs to serving each request alone — it FAILS on the
original Server.  The per-family test checks pos-vector ``decode_step``
against length-masked ``prefill_step`` cache equivalence.

The paged-runtime tests extend the same identity bar to the block-table
cache: outputs must be bit-identical under CHUNKED prefill, prefix block
REUSE, and LRU EVICTION, and a shared-prefix admission must skip the
reused blocks' recompute entirely (asserted via dispatch + pool counters).
Hybrid archs additionally demand that decode steps interleaved with a
slot's chunked prefill leave its Mamba/RWKV recurrent state untouched
(``decode_step``'s ``active`` row freeze — the dense-state analogue of the
attention null-block redirect).
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.configs.base import ParallelConfig, get_smoke_config
from repro.models import model as M
from repro.models import serve as S
from repro.parallel.sharding import TPContext
from repro.runtime.server import Request, ServeConfig, Server


def _mesh():
    return Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))


# ---------------------------------------------------------------------------
# Server-level: concurrent == isolated (fails on the seed Server)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def served():
    cfg = get_smoke_config("minicpm_2b")
    par = ParallelConfig(tp=1, dp=1)
    mesh = _mesh()
    params = M.init_model(jax.random.PRNGKey(0), cfg, par)
    # block_size/prefill_chunk = 8 so the 9- and 14-token prompts span
    # multiple blocks AND multiple chunks — the staggered-identity bar
    # covers the paged chunked-prefill path, not just decode
    sc = ServeConfig(max_batch=3, max_seq=64, eos_token=-1, max_new_tokens=6,
                     block_size=8, prefill_chunk=8)
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab_size, size=(n,)).astype(np.int32)
               for n in (3, 9, 14)]

    concurrent_srv = Server(cfg, par, mesh, params, sc)
    concurrent = {r.rid: list(r.output) for r in concurrent_srv.serve(
        [Request(rid=i, prompt=p) for i, p in enumerate(prompts)])}
    isolated = {}
    for i, p in enumerate(prompts):
        srv = Server(cfg, par, mesh, params, sc)
        isolated[i] = list(srv.serve([Request(rid=i, prompt=p)])[0].output)
    return cfg, par, mesh, params, sc, prompts, concurrent, isolated


def test_staggered_concurrent_matches_isolated(served):
    """Mixed-length requests share the decode batch; each must get exactly
    the tokens it would get served alone (no cross-slot cache corruption,
    no wrong-position RoPE)."""
    *_, concurrent, isolated = served
    assert concurrent == isolated


def test_admit_is_chunked_prefill_dispatches(served):
    """Admission = ceil(n / prefill_chunk) dispatches of the ONE compiled
    chunk program + zero decode steps, regardless of prompt length (the
    seed looped decode_step per token; the bucketed rewrite recompiled a
    jit per power-of-two length)."""
    cfg, par, mesh, params, sc, prompts, *_ = served
    srv = Server(cfg, par, mesh, params, sc)
    assert srv.admit(Request(rid=0, prompt=prompts[2]))   # 14 tokens, C=8
    assert srv.prefill_dispatches == 2
    assert srv.decode_dispatches == 0
    assert srv.positions[0] == len(prompts[2])


def test_oversized_prompt_rejected_gracefully(served):
    """An unadmittable prompt (>= max_seq) must not crash ``serve`` and
    must not starve the rest of the queue: the bad request drains with
    ``error`` set and every other request completes as if served alone.
    (The seed Server let ``admit``'s ValueError propagate out of the serve
    loop, killing every in-flight request.)"""
    cfg, par, mesh, params, sc, prompts, _, isolated = served
    rng = np.random.default_rng(11)
    too_long = rng.integers(0, cfg.vocab_size,
                            size=(sc.max_seq,)).astype(np.int32)
    srv = Server(cfg, par, mesh, params, sc)
    reqs = [Request(rid=0, prompt=prompts[0]),
            Request(rid=1, prompt=too_long),
            Request(rid=2, prompt=prompts[1])]
    done = srv.serve(reqs)
    by_rid = {r.rid: r for r in done}
    assert set(by_rid) == {0, 1, 2}            # nothing lost, nothing stuck
    assert by_rid[1].error is not None and "prompt length" in by_rid[1].error
    assert by_rid[1].output == []              # rejected before any token
    assert by_rid[0].error is None and by_rid[2].error is None
    assert list(by_rid[0].output) == isolated[0]
    assert list(by_rid[2].output) == isolated[1]
    # empty prompts are the other unadmittable shape
    empty = srv.serve([Request(rid=3, prompt=np.zeros((0,), np.int32))])
    assert empty[0].error is not None


def test_admission_preserves_other_slots(served):
    """Admitting a LONG prompt while a short request is mid-decode must not
    perturb the short request's output (the seed rewrote its rows)."""
    cfg, par, mesh, params, sc, prompts, _, isolated = served
    srv = Server(cfg, par, mesh, params, sc)
    short = Request(rid=0, prompt=prompts[0])
    assert srv.admit(short)
    srv.step()                                   # short is mid-decode
    assert srv.admit(Request(rid=1, prompt=prompts[2]))
    while not short.done:
        srv.step()
    assert list(short.output) == isolated[0]


@pytest.mark.parametrize("arch", ["jamba_v01_52b", "rwkv6_3b"])
def test_hybrid_state_survives_interleaved_decode(arch):
    """Scheduler-path token identity for the STATE families: the
    ChunkScheduler runs one prefill chunk per tick interleaved with a
    full-batch decode of every generating slot, so a Mamba/RWKV slot that
    is BETWEEN prefill chunks sees decode dispatches while its recurrent
    state is threaded across chunks.  Those decodes must not advance the
    mid-prefill slot's dense conv/ssm/wkv/shift state (attention caches
    are null-block protected; the state rows need ``decode_step``'s
    ``active`` freeze — this test fails without it)."""
    cfg = get_smoke_config(arch)
    par = ParallelConfig(tp=1, dp=1)
    mesh = _mesh()
    params = M.init_model(jax.random.PRNGKey(0), cfg, par)
    # chunk=4: the 14-token prompt prefills over 4 ticks, each followed by
    # a decode step of the already-generating 3-token slot
    sc = ServeConfig(max_batch=2, max_seq=64, eos_token=-1, max_new_tokens=5,
                     block_size=4, prefill_chunk=4)
    rng = np.random.default_rng(17)
    prompts = [rng.integers(0, cfg.vocab_size, size=(n,)).astype(np.int32)
               for n in (3, 14)]
    srv = Server(cfg, par, mesh, params, sc)
    concurrent = {r.rid: list(r.output) for r in srv.serve(
        [Request(rid=i, prompt=p) for i, p in enumerate(prompts)])}
    for i, p in enumerate(prompts):
        solo = Server(cfg, par, mesh, params, sc).serve(
            [Request(rid=i, prompt=p)])[0]
        assert concurrent[i] == list(solo.output), f"rid {i} diverged"


# ---------------------------------------------------------------------------
# Paged-cache regressions: prefix reuse, eviction, pool footprint
# ---------------------------------------------------------------------------
def test_shared_prefix_admit_skips_recompute(served):
    """A second admission of an identical prompt must REUSE the registered
    full prompt blocks: prefill resumes at the first unmatched position
    (fewer chunk dispatches), the pool counts the reused tokens, and the
    generated tokens are identical to the cold admission's."""
    cfg, par, mesh, params, sc, *_ = served
    sc2 = dataclasses.replace(sc, block_size=4)
    srv = Server(cfg, par, mesh, params, sc2)
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, cfg.vocab_size, size=(19,)).astype(np.int32)
    first = srv.serve([Request(rid=0, prompt=prompt)])[0]
    d0 = srv.prefill_dispatches
    assert d0 == 3                                 # ceil(19 / 8) cold chunks
    second = srv.serve([Request(rid=1, prompt=prompt.copy())])[0]
    # 4 full blocks (16 tokens) reused -> prefill resumes at off=16:
    # ONE chunk covers the remaining 3 positions
    assert srv.prefill_dispatches - d0 == 1
    assert srv.pool.reuse_hits == 1
    assert srv.pool.reused_tokens == 16
    assert list(second.output) == list(first.output)


def test_reuse_and_eviction_token_identity(served):
    """Token identity must survive reuse AND eviction: a tight pool forces
    freed prefixes out of the cache while later admissions race for the
    space.  Every request — including a repeat of an evicted prompt — must
    match a solo server exactly."""
    cfg, par, mesh, params, sc, *_ = served
    # 10 usable blocks; each 12-token request reserves 5 -> two in flight
    # fill the pool and the third admission must evict freed prefixes
    sc2 = dataclasses.replace(sc, max_batch=2, block_size=4, num_blocks=11)
    rng = np.random.default_rng(13)
    uniq = [rng.integers(0, cfg.vocab_size, size=(12,)).astype(np.int32)
            for _ in range(3)]
    prompts = uniq + [uniq[0].copy()]    # tail repeat: prefix likely evicted
    srv = Server(cfg, par, mesh, params, sc2)
    done = srv.serve([Request(rid=i, prompt=p)
                      for i, p in enumerate(prompts)])
    assert srv.pool.evictions > 0
    concurrent = {r.rid: list(r.output) for r in done}
    solo = {}
    for i, p in enumerate(uniq):
        ref = Server(cfg, par, mesh, params, sc2).serve(
            [Request(rid=0, prompt=p)])[0]
        solo[i] = list(ref.output)
    assert concurrent[0] == solo[0]
    assert concurrent[1] == solo[1]
    assert concurrent[2] == solo[2]
    assert concurrent[3] == solo[0]      # repeat == original, evicted or not


def test_pool_footprint_below_dense(served):
    """The mixed-length workload must pin fewer physical blocks than the
    dense [max_batch, max_seq] cache it replaces."""
    cfg, par, mesh, params, sc, prompts, *_ = served
    srv = Server(cfg, par, mesh, params, sc)
    srv.serve([Request(rid=i, prompt=p) for i, p in enumerate(prompts)])
    assert 0 < srv.pool.peak_blocks_in_use < srv.dense_equiv_blocks


# ---------------------------------------------------------------------------
# Model-level: pos-vector decode_step vs length-masked prefill_step cache
# equivalence, per mixer family (GQA / MLA / Mamba / RWKV)
# ---------------------------------------------------------------------------
def _jit_pair(cfg, par, mesh, pspecs, cache_spec):
    ctx = TPContext(axis="model", dp_axes=("data",),
                    ep_axes=("model",) if cfg.moe else ())

    @jax.jit
    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(pspecs, P("data", None), P("data")),
                       out_specs=(P("data", None), cache_spec),
                       check_vma=False)
    def prefill(p, tokens, lengths):
        return S.prefill_step(p, {"tokens": tokens}, ctx, cfg, par,
                              lengths=lengths)

    @jax.jit
    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(pspecs, cache_spec, P("data", None),
                                 P("data")),
                       out_specs=(P("data", None), cache_spec),
                       check_vma=False)
    def dec(p, c, t, pos):
        return S.decode_step(p, c, t, pos, ctx, cfg, par)

    return prefill, dec


def _row_leaves(tree, row, batch_axis):
    """Leaf list with the batch axis dropped at ``row``."""
    return [jnp.take(l, row, axis=batch_axis) for l in jax.tree.leaves(tree)]


def _assert_caches_match(batched, solo, row):
    """Row ``row`` of the padded batched cache == the solo cache (seq dims
    compared on the solo prefix; pad rows beyond it are dead by masking)."""
    pairs = list(zip(_row_leaves(batched["lead"], row, 0),
                     _row_leaves(solo["lead"], 0, 0)))
    pairs += list(zip(_row_leaves(batched["periods"], row, 1),
                      _row_leaves(solo["periods"], 0, 1)))
    assert pairs
    for bl, sl in pairs:
        crop = bl[tuple(slice(0, d) for d in sl.shape)]
        tol = 2e-2 if sl.dtype == jnp.bfloat16 else 2e-3
        np.testing.assert_allclose(
            np.asarray(crop, np.float32), np.asarray(sl, np.float32),
            atol=tol, rtol=tol)


@pytest.mark.parametrize("arch", ["codeqwen15_7b", "deepseek_v3_671b",
                                  "jamba_v01_52b", "rwkv6_3b"])
def test_pos_vector_decode_matches_padded_prefill(arch):
    """Right-padded batched prefill with per-row lengths must produce the
    same caches and the same continuation as each row prefilled alone at
    its exact length, decoding onward with the pos VECTOR."""
    import dataclasses
    cfg = get_smoke_config(arch)
    if cfg.moe is not None:
        # capacity DROPPING depends on total batch shape by design (cap is
        # a static f(t)); give it headroom so this test isolates the
        # pos-vector / pad-masking machinery, not eviction statistics.
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=64.0))
    par = ParallelConfig(tp=1, dp=1)
    mesh = _mesh()
    params = M.init_model(jax.random.PRNGKey(0), cfg, par)
    pspecs = M.param_specs(cfg, par, params)
    b, s_max = 2, 24
    lengths = np.array([5, 9], np.int32)
    rng = np.random.default_rng(3)
    tokens = np.zeros((b, s_max), np.int32)
    for i, n in enumerate(lengths):
        tokens[i, :n] = rng.integers(0, cfg.vocab_size, size=(n,))

    _, cache_spec = S.cache_specs(cfg, par, b, s_max, dp_axes=("data",))
    prefill, dec = _jit_pair(cfg, par, mesh, pspecs, cache_spec)
    nxt_b, caches_b = prefill(params, jnp.asarray(tokens),
                              jnp.asarray(lengths))

    solo_next = []
    for i, n in enumerate(lengths):
        sds_i, spec_i = S.cache_specs(cfg, par, 1, int(n), dp_axes=("data",))
        prefill_i, _ = _jit_pair(cfg, par, mesh, pspecs, spec_i)
        nxt_i, caches_i = prefill_i(params, jnp.asarray(tokens[i:i+1, :n]),
                                    jnp.asarray(lengths[i:i+1]))
        solo_next.append(int(np.asarray(nxt_i)[0, 0]))
        _assert_caches_match(caches_b, caches_i, i)
    # identical next tokens per row despite staggered right-padding
    np.testing.assert_array_equal(np.asarray(nxt_b)[:, 0],
                                  np.asarray(solo_next))

    # decode onward with the pos VECTOR: rows advance at their own
    # positions; compare against per-row scalar-pos decode on solo caches
    toks, caches, pos = nxt_b, caches_b, jnp.asarray(lengths)
    batched_tail = []
    for _ in range(3):
        toks, caches = dec(params, caches, toks, pos)
        pos = pos + 1
        batched_tail.append(np.asarray(toks)[:, 0].copy())
    for i, n in enumerate(lengths):
        sds_i, spec_i = S.cache_specs(cfg, par, 1, s_max, dp_axes=("data",))
        _, dec_i = _jit_pair(cfg, par, mesh, pspecs, spec_i)
        caches_i = jax.tree.map(lambda x: jnp.zeros(x.shape, x.dtype), sds_i)
        t_i = None
        for t in range(int(n)):
            t_i, caches_i = dec_i(params, caches_i,
                                  jnp.asarray(tokens[i:i+1, t:t+1]),
                                  jnp.asarray([t], jnp.int32))
        assert int(np.asarray(t_i)[0, 0]) == int(np.asarray(nxt_b)[i, 0])
        for step in range(3):
            t_i, caches_i = dec_i(params, caches_i, t_i,
                                  jnp.asarray([int(n) + step], jnp.int32))
            assert int(np.asarray(t_i)[0, 0]) == int(batched_tail[step][i])
