"""KVPool allocator unit tests: refcounts, hash-chain prefix matching, LRU
eviction, and the null-block / capacity invariants the server relies on.
Pure host-side — no jax arrays move through the pool."""
import pytest

from repro.runtime.kvpool import BlockTable, KVPool, PoolExhausted


def test_null_block_reserved():
    pool = KVPool(num_blocks=5, block_size=4)
    got = pool.allocate(4)                 # the whole usable pool
    assert 0 not in got
    assert sorted(got) == [1, 2, 3, 4]
    with pytest.raises(ValueError):
        KVPool(num_blocks=1, block_size=4)


def test_allocate_release_refcounts():
    pool = KVPool(num_blocks=8, block_size=4)
    a = pool.allocate(3)
    assert pool.blocks_in_use == 3
    assert pool.available() == 4
    pool.release(a)
    assert pool.blocks_in_use == 0
    assert pool.available() == 7
    assert pool.peak_blocks_in_use == 3
    with pytest.raises(PoolExhausted):
        pool.allocate(8)


def test_block_table_array_pads_with_null():
    bt = BlockTable([3, 5], n_reused=1)
    arr = bt.as_array(pages=4)
    assert arr.tolist() == [3, 5, 0, 0]
    assert arr.dtype.name == "int32"


def test_longest_prefix_match_and_cap():
    pool = KVPool(num_blocks=16, block_size=4)
    toks = list(range(100, 112))                     # 3 full blocks
    blocks = pool.allocate(3)
    pool.register(blocks, toks)
    # identical prompt: cap at len-1 -> only 2 of 3 blocks match (the last
    # position must recompute so admission emits a first token)
    got, n = pool.match_prefix(toks)
    assert got == blocks[:2] and n == 8
    pool.release(got)
    # longer prompt sharing the prefix: all 3 registered blocks match
    got, n = pool.match_prefix(toks + [7, 8])
    assert got == blocks and n == 12
    pool.release(got)
    # diverging block 2: chain key mismatch stops the walk
    got, n = pool.match_prefix(toks[:8] + [0, 0, 0, 0, 9])
    assert got == blocks[:2] and n == 8
    pool.release(got)
    # no match at all
    got, n = pool.match_prefix([1, 2, 3, 4, 5])
    assert got == [] and n == 0


def test_match_counts_only_on_note_reuse():
    pool = KVPool(num_blocks=8, block_size=2)
    blocks = pool.allocate(2)
    pool.register(blocks, [5, 6, 7, 8])
    got, n = pool.match_prefix([5, 6, 7, 8, 9])
    assert (len(got), n) == (2, 4)
    assert pool.reuse_hits == 0 and pool.reused_tokens == 0
    pool.note_reuse(len(got))
    assert pool.reuse_hits == 1 and pool.reused_tokens == 4
    pool.note_reuse(0)                     # a no-reuse admission: no count
    assert pool.reuse_hits == 1


def test_shared_block_refcount():
    pool = KVPool(num_blocks=8, block_size=2)
    owner = pool.allocate(1)
    pool.register(owner, [1, 2])
    got, _ = pool.match_prefix([1, 2, 3])
    assert got == owner and pool.blocks_in_use == 1
    pool.release(owner)                    # original owner frees
    assert pool.blocks_in_use == 1         # sharer still holds it
    got2, _ = pool.match_prefix([1, 2, 9])
    assert got2 == owner                   # still matchable while shared
    pool.release(got)
    pool.release(got2)
    assert pool.blocks_in_use == 0


def test_release_to_lru_and_resurrection():
    pool = KVPool(num_blocks=4, block_size=2)
    blocks = pool.allocate(2)
    pool.register(blocks, [1, 2, 3, 4])
    pool.release(blocks)
    assert pool.blocks_in_use == 0
    assert pool.available() == 3           # cached prefixes count as free
    got, n = pool.match_prefix([1, 2, 3, 4, 5])    # resurrect from LRU
    assert got == blocks and n == 4
    assert pool.blocks_in_use == 2
    assert pool.evictions == 0


def test_lru_eviction_order_and_unmatchability():
    pool = KVPool(num_blocks=4, block_size=2)      # 3 usable blocks
    a = pool.allocate(1)
    pool.register(a, [1, 2])
    b = pool.allocate(1)
    pool.register(b, [3, 4])
    pool.release(a)                        # a freed first -> evicted first
    pool.release(b)
    c = pool.allocate(2)                   # 1 free + 1 evicted (a)
    assert pool.evictions == 1
    assert a[0] in c
    got, _ = pool.match_prefix([1, 2, 9])  # a's key is gone
    assert got == []
    got, _ = pool.match_prefix([3, 4, 9])  # b survives, resurrectable
    assert got == b
    pool.release(got)
    pool.release(c)


def test_register_dedup_racing_prompts():
    pool = KVPool(num_blocks=8, block_size=2)
    first = pool.allocate(1)
    second = pool.allocate(1)
    pool.register(first, [1, 2])
    pool.register(second, [1, 2])          # same content: first one wins
    got, _ = pool.match_prefix([1, 2, 3])
    assert got == first
    pool.release(got)
    pool.release(first + second)
    # the loser is NOT registered -> releases straight to the free list
    assert pool.available() == 7
