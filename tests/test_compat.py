"""Portability-layer contract: every compat symbol resolves on the installed
JAX, behaves sanely, and no module outside ``repro/compat`` touches the
drifted JAX surface directly (AST lint — ``repro.analysis.lint``)."""
import os
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# (a) every public symbol resolves on the installed JAX
# ---------------------------------------------------------------------------
def test_all_public_symbols_resolve():
    for name in compat.__all__:
        obj = getattr(compat, name)
        assert obj is not None, f"compat.{name} resolved to None"


def test_version_detection():
    assert compat.JAX_VERSION >= compat.MIN_JAX, (
        f"installed {compat.JAX_VERSION} predates supported {compat.MIN_JAX}")
    assert "jax" in compat.version_summary()


def test_shard_map_runs_and_translates_check_kwarg():
    from jax.sharding import Mesh, PartitionSpec as P
    mesh = Mesh(np.array(jax.devices()[:1]), ("x",))
    sm = compat.shard_map(lambda a: a * 2 + compat.axis_size("x") - 1,
                          mesh=mesh, in_specs=P("x"), out_specs=P("x"),
                          check_vma=False)
    np.testing.assert_array_equal(np.asarray(sm(jnp.arange(4.))),
                                  np.arange(4.) * 2)
    with pytest.raises(TypeError):
        compat.shard_map(lambda a: a, mesh=mesh, in_specs=P("x"),
                         out_specs=P("x"), check_vma=False, check_rep=False)


def test_axis_size_outside_mapping():
    assert compat.axis_size(None) == 1


def test_compiler_params_builds_and_drops_unknown():
    cp = compat.pallas_compiler_params(
        dimension_semantics=("parallel", "arbitrary"), collective_id=3)
    assert cp.dimension_semantics == ("parallel", "arbitrary")
    assert cp.collective_id == 3
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        compat.pallas_compiler_params(totally_future_knob=1)
    assert any("totally_future_knob" in str(w.message) for w in caught)


def test_interpret_default_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    assert compat.interpret_default() is True
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    assert compat.interpret_default() is False


def test_memory_space_helpers():
    ref = compat.VMEM((8, 128), jnp.float32)
    assert ref is not None
    hbm = compat.hbm_scratch((2, 8, 128), jnp.float32)
    assert hbm is not None
    assert compat.DMA_SEM is not None


def test_pallas_call_end_to_end():
    """A tiny kernel through compat.pallas_call with dict compiler params."""
    from jax.experimental import pallas as pl

    def kernel(x_ref, o_ref):
        o_ref[...] = x_ref[...] * 2

    x = jnp.arange(8 * 128, dtype=jnp.float32).reshape(8, 128)
    out = compat.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        in_specs=[pl.BlockSpec(x.shape, lambda: (0, 0))],
        out_specs=pl.BlockSpec(x.shape, lambda: (0, 0)),
        compiler_params={"dimension_semantics": ()},
        interpret=True,
    )(x)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x) * 2)


# ---------------------------------------------------------------------------
# (b) AST lint: drifted symbols only inside repro/compat
# ---------------------------------------------------------------------------
# The old grep-based scan lived here; it could not tell an import from a
# string mentioning one (the AST linter's own rule tables tripped it).
# repro.analysis.lint parses the files, so only REAL imports/attributes
# of the drifted surface count.
_COMPAT_RULES = ("compat-import", "bare-shard-map")


def _compat_violations(tops):
    from repro.analysis import lint
    return [v for v in lint.lint_tree(REPO, scope=tops)
            if v.rule in _COMPAT_RULES]


def test_no_drifted_symbols_outside_compat():
    hits = _compat_violations(("src",))
    assert not hits, ("drifted JAX symbols outside repro/compat "
                      "(import through repro.compat instead):\n"
                      + "\n".join(map(str, hits)))


def test_no_drifted_symbols_in_tests():
    hits = _compat_violations(("tests", "benchmarks", "examples"))
    assert not hits, ("drifted JAX symbols in tests "
                      "(import through repro.compat instead):\n"
                      + "\n".join(map(str, hits)))
