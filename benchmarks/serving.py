"""Open-loop serving benchmark — the workload FLUX compares against vLLM.

A Poisson traffic generator submits mixed-length prompts at a fixed arrival
rate (open loop: arrivals don't wait for completions, so queueing delay is
REAL and counts against TTFT) into the paged continuous-batching runtime.
The chunk scheduler interleaves prefill chunks with decode steps, driving
the fused decode-AR seam (every decode) and the prefill AG/RS seams (every
chunk) per overlap mode.

Reported per mode, against an SLO:

* **TTFT** (time to first token, includes queueing) — mean/p50/p95/p99 +
  SLO attainment;
* **per-token latency** (TPOT: inter-token mean after the first token) —
  mean/p50/p95/p99;
* throughput (tokens/s), dispatch counts, and paged-pool stats
  (peak blocks in use vs the dense-cache equivalent, prefix-reuse hits /
  reused tokens / evictions).

The timed run repeats the warmup's prompts, so full prompt blocks
registered during warmup are reusable — warm-cache behavior, reported via
the reuse counter deltas.

CSV: name,us_per_call,derived  (us_per_call = us per generated token;
derived = tokens/s).  Writes ``experiments/BENCH_serving.json``.

At ``--tp 1`` (the CI default) every seam takes the single-shard fallback,
so the mode rows are transport-EQUIVALENT: they gate numerics
(``outputs_match_reference``) and give a serving-loop baseline, not a seam
comparison.  Run with ``--tp > 1`` (real TPU, or
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` on CPU) to actually
time the decode-AR / prefill AG-RS transports against each other.

    PYTHONPATH=src python benchmarks/serving.py --smoke
    PYTHONPATH=src python benchmarks/serving.py --num-requests 16 \\
        --arrival-rate 4
"""
from __future__ import annotations

import argparse
import json
import os
import time

MODES = ("decomposed", "xla")
OUT_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                        "experiments", "BENCH_serving.json")


def _requests(cfg, n_requests, max_prompt, rng):
    import numpy as np
    from repro.runtime.server import Request
    lens = rng.integers(3, max_prompt + 1, size=n_requests)
    return [Request(rid=i, prompt=rng.integers(
        0, cfg.vocab_size, size=(int(n),)).astype(np.int32))
        for i, n in enumerate(lens)]


def _poisson_arrivals(n, rate_rps, rng):
    """Open-loop arrival offsets (seconds from t0): exponential gaps at
    ``rate_rps`` requests/s.  rate <= 0 means all requests arrive at t0
    (closed-batch limit)."""
    import numpy as np
    if rate_rps <= 0:
        return np.zeros(n)
    return np.cumsum(rng.exponential(1.0 / rate_rps, size=n))


def open_loop_serve(server, reqs, offsets):
    """Drive the chunk scheduler with scheduled arrivals.  A request is
    submitted only once its offset elapses — TTFT therefore includes any
    queueing delay behind slower admissions (the open-loop property that
    closed-loop benchmarks hide)."""
    from repro.runtime.scheduler import ChunkScheduler
    sched = ChunkScheduler(server)
    done = []
    nxt = 0
    t0 = time.perf_counter()
    while nxt < len(reqs) or sched.has_work():
        now = time.perf_counter() - t0
        while nxt < len(reqs) and offsets[nxt] <= now:
            reqs[nxt].t_arrival = t0 + offsets[nxt]   # scheduled, not actual
            sched.submit(reqs[nxt])
            nxt += 1
        if not sched.has_work():
            if nxt < len(reqs):                       # idle until next arrival
                time.sleep(min(offsets[nxt] - now, 0.01))
            continue
        done.extend(sched.tick())
    wall = time.perf_counter() - t0
    return done, wall


def _stats(xs):
    import numpy as np
    xs = np.asarray(xs, np.float64)
    return {"mean": float(xs.mean()),
            "p50": float(np.percentile(xs, 50)),
            "p95": float(np.percentile(xs, 95)),
            "p99": float(np.percentile(xs, 99))}


def bench_mode(mode, cfg, params, mesh, sc, reqs_factory, offsets, tp,
               slo_ttft_s, wire_dtype=None):
    from repro.configs.base import ParallelConfig
    from repro.runtime.server import Server

    par = ParallelConfig(tp=tp, dp=1, overlap_mode=mode,
                         wire_dtype=wire_dtype)
    server = Server(cfg, par, mesh, params, sc)
    server.serve(reqs_factory())       # warmup: compiles + registers prefixes
    d0, p0 = server.decode_dispatches, server.prefill_dispatches
    pool = server.pool
    r0 = (pool.reuse_hits, pool.reused_tokens, pool.evictions)

    reqs = reqs_factory()              # same prompts: warm prefix cache
    done, wall = open_loop_serve(server, reqs, offsets)
    ok = [r for r in done if r.error is None]
    new_tokens = sum(len(r.output) for r in done)
    ttfts = [r.ttft_s() for r in ok]
    tpots = [r.per_token_s() for r in ok]
    return {
        "mode": mode,
        "wire_dtype": wire_dtype,
        "tokens_per_s": new_tokens / wall,
        "wall_s": wall,
        "new_tokens": new_tokens,
        "requests": len(done),
        "rejected": len(done) - len(ok),
        "decode_steps": server.decode_dispatches - d0,
        "prefill_dispatches": server.prefill_dispatches - p0,
        "ttft_s": _stats(ttfts),
        "per_token_s": _stats(tpots),
        "slo": {"ttft_s": slo_ttft_s,
                "attainment": sum(t <= slo_ttft_s for t in ttfts)
                / max(1, len(ttfts))},
        "pool": {"block_size": pool.block_size,
                 "num_blocks": pool.num_blocks,
                 "blocks_in_use_peak": pool.peak_blocks_in_use,
                 "dense_equiv_blocks": server.dense_equiv_blocks,
                 "reuse_hits": pool.reuse_hits - r0[0],
                 "reused_tokens": pool.reused_tokens - r0[1],
                 "evictions": pool.evictions - r0[2]},
        "per_request": [{"rid": r.rid, "prompt_len": int(len(r.prompt)),
                         "new_tokens": len(r.output),
                         "ttft_s": r.ttft_s(),
                         "per_token_s": r.per_token_s()}
                        for r in sorted(ok, key=lambda r: r.rid)],
        "outputs": {r.rid: list(r.output) for r in done},
    }


def main(full: bool = False, smoke: bool = False, arch: str = "minicpm_2b",
         tp: int = 1, num_requests: int = 0, arrival_rate: float = -1.0,
         slo_ttft: float = 1.0) -> None:
    import jax
    import numpy as np

    from repro.configs.base import ParallelConfig, get_smoke_config
    from repro.launch.mesh import make_mesh
    from repro.models import model as M
    from repro.runtime.server import ServeConfig

    print("name,us_per_call,derived")
    cfg = get_smoke_config(arch)
    # (n_requests, max_prompt, max_new, max_batch, max_seq, block, chunk,
    #  rate): smoke keeps block/chunk small so the 3..12-token prompts still
    # span multiple blocks — reuse and chunking are exercised, cheaply
    if smoke:
        n_req, max_prompt, max_new, max_batch, max_seq = 4, 12, 4, 2, 64
        block, chunk, rate = 8, 8, 20.0
    elif full:
        n_req, max_prompt, max_new, max_batch, max_seq = 32, 96, 32, 8, 256
        block, chunk, rate = 16, 32, 5.0
    else:
        n_req, max_prompt, max_new, max_batch, max_seq = 8, 24, 8, 4, 128
        block, chunk, rate = 16, 16, 10.0
    if num_requests > 0:
        n_req = num_requests
    if arrival_rate >= 0:
        rate = arrival_rate
    if tp > len(jax.devices()):
        raise SystemExit(f"--tp {tp} > {len(jax.devices())} visible devices "
                         "(set XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=N for a CPU sweep)")
    mesh = make_mesh(1, 1, tp)
    params = M.init_model(jax.random.PRNGKey(0), cfg,
                          ParallelConfig(tp=tp, dp=1))
    sc = ServeConfig(max_batch=max_batch, max_seq=max_seq, eos_token=-1,
                     max_new_tokens=max_new, block_size=block,
                     prefill_chunk=chunk)

    def reqs_factory():
        return _requests(cfg, n_req, max_prompt, np.random.default_rng(0))

    # one arrival schedule shared by every mode (fair comparison)
    offsets = _poisson_arrivals(n_req, rate, np.random.default_rng(1))

    doc = {"smoke": smoke, "full": full, "arch": arch, "tp": tp,
           "max_batch": max_batch, "max_seq": max_seq,
           "max_new_tokens": max_new, "requests": n_req,
           "arrival_rate_rps": rate, "slo_ttft_s": slo_ttft,
           "block_size": block, "prefill_chunk": chunk, "modes": []}
    ref_outputs = None
    # the wire lane rides decomposed with the int8 forward-wire transport:
    # serving has no backward, so the wire IS the whole quantization story
    # there.  Its outputs are allowed to drift (lossy wire); the fp-wire
    # mode lanes must still match each other exactly.
    lanes = [(mode, None) for mode in MODES] + [("decomposed", "int8")]
    for mode, wire in lanes:
        row = bench_mode(mode, cfg, params, mesh, sc, reqs_factory, offsets,
                         tp, slo_ttft, wire_dtype=wire)
        outputs = row.pop("outputs")
        # fp-wire overlap modes are numerics-preserving: serving outputs
        # must not depend on the seam transport
        if wire is None:
            row["outputs_match_reference"] = (ref_outputs is None
                                              or outputs == ref_outputs)
            ref_outputs = ref_outputs or outputs
        else:
            row["outputs_match_fp_wire"] = outputs == ref_outputs
        doc["modes"].append(row)
        tag = f"{mode}_wire-{wire}" if wire else mode
        us_per_tok = 1e6 * row["wall_s"] / max(row["new_tokens"], 1)
        print(f"serving_{tag}_tp{tp}_b{max_batch},{us_per_tok:.0f},"
              f"{row['tokens_per_s']:.1f}")
        print(f"serving_{tag}_ttft_p99,{1e6 * row['ttft_s']['p99']:.0f},"
              f"{row['slo']['attainment']:.2f}")

    os.makedirs(os.path.dirname(OUT_PATH), exist_ok=True)
    with open(OUT_PATH, "w") as f:
        json.dump(doc, f, indent=1)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full problem sizes (use on real hardware)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI workload (verify.sh well-formedness gate)")
    ap.add_argument("--arch", default="minicpm_2b")
    ap.add_argument("--tp", type=int, default=1,
                    help="TP degree; at tp=1 the overlap modes are "
                         "transport-equivalent (single-shard fallback), so "
                         "the mode rows only gate numerics — seam timing "
                         "needs tp > 1 (real TPU, or forced host devices)")
    ap.add_argument("--num-requests", type=int, default=0,
                    help="override the preset request count")
    ap.add_argument("--arrival-rate", type=float, default=-1.0,
                    help="open-loop Poisson arrival rate, requests/s "
                         "(0 = all at t0; default: preset)")
    ap.add_argument("--slo-ttft", type=float, default=1.0,
                    help="TTFT SLO in seconds for the attainment metric")
    main(**vars(ap.parse_args()))
