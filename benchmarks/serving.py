"""Mixed-length serving benchmark — the workload FLUX compares against vLLM.

Continuous batching over staggered-length prompts drives the fused decode-AR
seam (every decode step) and the prefill AG/RS seams (every admission) per
overlap mode, measuring end-to-end serving throughput and per-request
latency — the paper's inference claim (up to 1.66x prefill / 1.30x decode
over vLLM) under the serving loop, not just per-op microbenchmarks.

CSV: name,us_per_call,derived  (us_per_call = us per generated token;
derived = tokens/s).

Writes ``experiments/BENCH_serving.json``: one row per overlap mode with
tokens/s, wall time, dispatch counts, and per-request latency stats.

At ``--tp 1`` (the CI default) every seam takes the single-shard fallback,
so the mode rows are transport-EQUIVALENT: they gate numerics
(``outputs_match_reference``) and give a serving-loop baseline, not a seam
comparison.  Run with ``--tp > 1`` (real TPU, or
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` on CPU) to actually
time the decode-AR / prefill AG-RS transports against each other.

    PYTHONPATH=src python benchmarks/serving.py --smoke
    XLA_FLAGS=--xla_force_host_platform_device_count=2 \
        PYTHONPATH=src python benchmarks/serving.py --smoke --tp 2
"""
from __future__ import annotations

import argparse
import json
import os
import time
from collections import deque

MODES = ("decomposed", "xla")
OUT_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                        "experiments", "BENCH_serving.json")


def _requests(cfg, n_requests, max_prompt, rng):
    import numpy as np
    from repro.runtime.server import Request
    lens = rng.integers(3, max_prompt + 1, size=n_requests)
    return [Request(rid=i, prompt=rng.integers(
        0, cfg.vocab_size, size=(int(n),)).astype(np.int32))
        for i, n in enumerate(lens)]


def _timed_serve(server, reqs):
    """server.serve with per-request admission->finish latency tracking."""
    admit_t, latency = {}, {}
    pending = deque(reqs)
    done = []
    t0 = time.perf_counter()
    while pending or any(s is not None for s in server.slots):
        while pending and server.admit(pending[0]):
            r = pending.popleft()
            admit_t[r.rid] = time.perf_counter()
            if r.done:
                latency[r.rid] = 0.0
                done.append(r)
        for fin in server.step():
            latency[fin.rid] = time.perf_counter() - admit_t[fin.rid]
            done.append(fin)
    wall = time.perf_counter() - t0
    return done, wall, latency


def bench_mode(mode, cfg, params, mesh, sc, reqs_factory, tp):
    import numpy as np
    from repro.configs.base import ParallelConfig
    from repro.runtime.server import Server

    par = ParallelConfig(tp=tp, dp=1, overlap_mode=mode)
    server = Server(cfg, par, mesh, params, sc)
    _timed_serve(server, reqs_factory())          # warmup: compiles all jits
    d0, p0 = server.decode_dispatches, server.prefill_dispatches
    reqs = reqs_factory()
    done, wall, latency = _timed_serve(server, reqs)
    new_tokens = sum(len(r.output) for r in done)
    lats = np.array([latency[r.rid] for r in done])
    return {
        "mode": mode,
        "tokens_per_s": new_tokens / wall,
        "wall_s": wall,
        "new_tokens": new_tokens,
        "requests": len(done),
        "decode_steps": server.decode_dispatches - d0,
        "prefill_dispatches": server.prefill_dispatches - p0,
        "request_latency_s": {"mean": float(lats.mean()),
                              "p50": float(np.percentile(lats, 50)),
                              "max": float(lats.max())},
        "per_request": [{"rid": r.rid, "prompt_len": int(len(r.prompt)),
                         "new_tokens": len(r.output),
                         "latency_s": float(latency[r.rid])}
                        for r in sorted(done, key=lambda r: r.rid)],
        "outputs": {r.rid: list(r.output) for r in done},
    }


def main(full: bool = False, smoke: bool = False,
         arch: str = "minicpm_2b", tp: int = 1) -> None:
    import jax
    import numpy as np

    from repro.configs.base import ParallelConfig, get_smoke_config
    from repro.launch.mesh import make_mesh
    from repro.models import model as M
    from repro.runtime.server import ServeConfig

    print("name,us_per_call,derived")
    cfg = get_smoke_config(arch)
    if smoke:
        n_requests, max_prompt, max_new, max_batch, max_seq = 4, 12, 4, 2, 64
    elif full:
        n_requests, max_prompt, max_new, max_batch, max_seq = 32, 96, 32, 8, 256
    else:
        n_requests, max_prompt, max_new, max_batch, max_seq = 8, 24, 8, 4, 128
    if tp > len(jax.devices()):
        raise SystemExit(f"--tp {tp} > {len(jax.devices())} visible devices "
                         "(set XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=N for a CPU sweep)")
    mesh = make_mesh(1, 1, tp)
    params = M.init_model(jax.random.PRNGKey(0), cfg,
                          ParallelConfig(tp=tp, dp=1))
    sc = ServeConfig(max_batch=max_batch, max_seq=max_seq, eos_token=-1,
                     max_new_tokens=max_new)

    def reqs_factory():
        return _requests(cfg, n_requests, max_prompt,
                         np.random.default_rng(0))

    doc = {"smoke": smoke, "full": full, "arch": arch, "tp": tp,
           "max_batch": max_batch, "max_seq": max_seq,
           "max_new_tokens": max_new, "requests": n_requests, "modes": []}
    ref_outputs = None
    for mode in MODES:
        row = bench_mode(mode, cfg, params, mesh, sc, reqs_factory, tp)
        outputs = row.pop("outputs")
        # overlap modes are numerics-preserving: serving outputs must not
        # depend on the seam transport
        row["outputs_match_reference"] = (ref_outputs is None
                                          or outputs == ref_outputs)
        ref_outputs = ref_outputs or outputs
        doc["modes"].append(row)
        us_per_tok = 1e6 * row["wall_s"] / max(row["new_tokens"], 1)
        print(f"serving_{mode}_tp{tp}_b{max_batch},{us_per_tok:.0f},"
              f"{row['tokens_per_s']:.1f}")

    os.makedirs(os.path.dirname(OUT_PATH), exist_ok=True)
    with open(OUT_PATH, "w") as f:
        json.dump(doc, f, indent=1)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full problem sizes (use on real hardware)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI workload (verify.sh well-formedness gate)")
    ap.add_argument("--arch", default="minicpm_2b")
    ap.add_argument("--tp", type=int, default=1,
                    help="TP degree; at tp=1 the overlap modes are "
                         "transport-equivalent (single-shard fallback), so "
                         "the mode rows only gate numerics — seam timing "
                         "needs tp > 1 (real TPU, or forced host devices)")
    main(**vars(ap.parse_args()))
