"""Kernel micro-benchmarks: interpret-mode wall time (structural) plus the
analytic MXU/VMEM utilization of the chosen block shapes.

CSV: name,us_per_call,derived  (derived = analytic VMEM KiB of working set)
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops


def main(full: bool = False) -> None:
    print("name,us_per_call,derived")
    shapes = [(256, 512, 256), (512, 512, 512)]
    if full:
        shapes += [(2048, 4096, 2048)]
    for m, k, n in shapes:
        a = jnp.ones((m, k), jnp.bfloat16)
        b = jnp.ones((k, n), jnp.bfloat16)
        fn = jax.jit(lambda x, y: kops.matmul(x, y, interpret=True))
        fn(a, b).block_until_ready()
        t0 = time.perf_counter()
        fn(a, b).block_until_ready()
        us = (time.perf_counter() - t0) * 1e6
        bm, bk, bn = kops.plan_blocks(m, k, n)
        vmem_kib = (bm * bk + bk * bn + 2 * bm * bn) * 2 / 1024 \
            + bm * bn * 4 / 1024
        print(f"kernel_matmul_{m}x{k}x{n}_b{bm}.{bk}.{bn},{us:.0f},"
              f"{vmem_kib:.0f}KiB")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    main(**vars(ap.parse_args()))
