"""Model-level benchmark — paper Figs. 1, 16, 17.

For GPT-3 175B and Llama-2 70B (the paper's two models), derive per-step
times for training / prefill / decoding under each overlap mode from the
per-layer roofline terms on the v5e target:

  non-overlap (xla)  : T = compute + memory' + collective      (serial)
  medium (decomposed): T = max-pipelined per chunk with the split-GEMM
                       penalty (paper §2.2's critique)
  FLUX (flux)        : T = max(compute, collective) + one-chunk tail
                       (fused kernel; paper §3.3)

Also prints the communication fraction (Fig. 1 analogue) and the resulting
speedups over the non-overlap baseline (Fig. 16/17 analogue).

CSV: name,us_per_call,derived   (derived = speedup over xla mode)
"""
from __future__ import annotations

import argparse

from repro.configs.base import get_config
from repro.core import ect

N_TP = 8
PHASES = {
    "train": dict(m_tokens=8 * 2048, passes=3.0),   # fwd+bwd
    "prefill": dict(m_tokens=8 * 2048, passes=1.0),
    "decode64": dict(m_tokens=64, passes=1.0),
    "decode512": dict(m_tokens=512, passes=1.0),
}


def layer_seam_times(cfg, m_tokens: int, mode: str):
    """The two MLP seams + two attention seams of one layer under a mode."""
    d, f = cfg.d_model, cfg.d_ff
    seams = [
        ("ag", m_tokens, f, d),          # h -> 4h (AllGather-GEMM)
        ("rs", m_tokens, d, f),          # 4h -> h (GEMM-ReduceScatter)
        ("ag", m_tokens, 3 * d, d),      # qkv
        ("rs", m_tokens, d, d),          # attn out
    ]
    total = dict(overall=0.0, gemm=0.0, comm=0.0, exposed=0.0)
    for seam, m, n, k in seams:
        est = ect.model_overlap(seam, m, n, k, N_TP, mode)
        for kk in total:
            total[kk] += est[kk]
    return total


def main(full: bool = False) -> None:
    print("name,us_per_call,derived")
    for arch in ("gpt3_175b", "llama2_70b"):
        cfg = get_config(arch)
        for phase, ph in PHASES.items():
            base = None
            for mode in ("xla", "decomposed", "flux"):
                t = layer_seam_times(cfg, ph["m_tokens"], mode)
                step_us = t["overall"] * ph["passes"] * cfg.num_layers * 1e6
                if mode == "xla":
                    base = step_us
                    frac = t["comm"] / t["overall"] if t["overall"] else 0
                    print(f"modellevel_{arch}_{phase}_commfrac,"
                          f"{step_us:.0f},{100*frac:.1f}")
                speedup = base / step_us if step_us else 0.0
                print(f"modellevel_{arch}_{phase}_{mode},"
                      f"{step_us:.0f},{speedup:.3f}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    main(**vars(ap.parse_args()))
