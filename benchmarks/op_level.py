"""Op-level AG/RS overlap benchmark — paper Figs. 4, 11, 12, 13, 14.

GEMM shapes from GPT-3 175B exactly as in §5.1: (n,k) = (49152, 12288) for
AllGather-GEMM and (12288, 49152) for GEMM-ReduceScatter, m swept over
{64, 512} (decode, Fig. 14) and {1024..8192} (train/prefill, Figs. 11-13).

Two result sets per row:
  * modeled — v5e roofline projection (core.ect.model_overlap) per mode:
    OverallTime, ECT (Eq. 1), OverlapEfficiency (Eq. 2).  This is the
    apples-to-apples reproduction of the paper's metric on our target HW.
  * measured — μs/call of the jitted seam at REDUCED dims on this host
    (CPU: structural sanity only; pass --full on a real TPU pod).

CSV: name,us_per_call,derived   (derived = modeled overlap efficiency %)
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import ect, overlap

M_SWEEP = [64, 512, 1024, 2048, 4096, 8192]
N_TP = 8                      # paper's single-node TP degree


def measured_us(seam: str, m: int, n: int, k: int, mode: str,
                iters: int = 3) -> float:
    """Single-device structural timing at reduced dims (TP=1 fallback)."""
    x = jnp.zeros((1, m, k), jnp.bfloat16)
    w = jnp.zeros((k, n), jnp.bfloat16)
    op = overlap.FusedOp(kind=seam, mode=mode)
    fn = jax.jit(lambda a, b: op(a, b))
    fn(x, w).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn(x, w).block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def main(full: bool = False) -> None:
    scale = 1 if full else 16       # reduce dims 16x for the CPU timing
    rows = []
    for seam, (n, k) in [("ag", (49152, 12288)), ("rs", (12288, 49152))]:
        for m in M_SWEEP:
            base = ect.model_overlap(seam, m, n, k, N_TP, "xla")
            for mode in ("xla", "decomposed", "flux"):
                est = ect.model_overlap(seam, m, n, k, N_TP, mode)
                eff = 1.0 - est["ect"] / base["ect"] if base["ect"] else 0.0
                us = measured_us(seam, max(m // scale, 8), n // scale,
                                 k // scale, mode if mode != "flux"
                                 else "decomposed")
                rows.append((f"oplevel_{seam}_m{m}_{mode}", us,
                             f"{100*eff:.1f}"))
    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r[0]},{r[1]:.1f},{r[2]}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    main(**vars(ap.parse_args()))
