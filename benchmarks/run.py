"""Benchmark runner: one section per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows (harness contract)."""
from __future__ import annotations

import argparse
import io
from contextlib import redirect_stdout

from benchmarks import (kernel_bench, model_level, op_level, serving, swizzle,
                        tuning)


def _run(name, mod, full):
    print(f"# --- {name} ---")
    buf = io.StringIO()
    with redirect_stdout(buf):
        mod.main(full=full)
    out = buf.getvalue()
    # drop the per-module header; keep one global header
    lines = [l for l in out.splitlines()
             if l and l != "name,us_per_call,derived"]
    print("\n".join(lines))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full problem sizes (use on real hardware)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    _run("op-level AG/RS (paper Figs. 4, 11-14)", op_level, args.full)
    _run("comm-tile + pull/push tuning (Figs. 9, 10)", tuning, args.full)
    _run("tile-coordinate swizzle (Fig. 8)", swizzle, args.full)
    _run("model-level train/prefill/decode (Figs. 1, 16, 17)", model_level,
         args.full)
    _run("mixed-length serving (continuous batching vs vLLM workload)",
         serving, args.full)
    _run("kernel micro-bench", kernel_bench, args.full)


if __name__ == "__main__":
    main()
