"""Tile-coordinate swizzling — paper Fig. 8 (and §4.1).

On GPU, FLUX's swizzle avoids memory-controller contention when N ranks
write/read the same coordinates simultaneously.  Our ring kernels swizzle
STRUCTURALLY: at ring step s, rank r computes output rows of shard
(r - s) mod n (AG) / partial for owner (r + n-1-s) mod n (RS), so the n
in-flight buffers always target n distinct owners and every ICI link is
busy every step (DESIGN.md §2 item 3).

This benchmark verifies the schedule property and quantifies the modeled
contention delta of the naive mapping.

CSV: name,us_per_call,derived
"""
from __future__ import annotations

import argparse


def owners_ag(n: int, step: int):
    return [(r - step) % n for r in range(n)]


def owners_rs(n: int, step: int):
    return [(r + n - 1 - step) % n for r in range(n)]


def main(full: bool = False) -> None:
    print("name,us_per_call,derived")
    for n in (8, 16):
        ag_ok = all(len(set(owners_ag(n, s))) == n for s in range(n))
        rs_ok = all(len(set(owners_rs(n, s))) == n for s in range(n))
        print(f"swizzle_ag_distinct_owners_n{n},0,{ag_ok}")
        print(f"swizzle_rs_distinct_owners_n{n},0,{rs_ok}")
        # naive mapping: all ranks target owner 0 first -> n-way contention
        # on one device's HBM controller; modeled slowdown on the contended
        # step is n x, amortized over n steps: (n-1)/n extra per transfer.
        naive_penalty = 1.0 + (n - 1) / n
        print(f"swizzle_naive_modeled_slowdown_n{n},0,{naive_penalty:.2f}x")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    main(**vars(ap.parse_args()))
