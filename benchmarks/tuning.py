"""Auto-tuning benchmark — paper Figs. 9 (pull/push) and 10 (comm tile size).

Exercises the REAL tuner (``repro.tuning.autotune``): per GEMM shape it
enumerates the full candidate space — every overlap mode (including
``decomposed_bidir``), comm-tile counts, ring directions, and the
wire-precision sweep (fp / int8 / fp8_e4m3 / int4 forward-wire transports
under the default logit-RMSE error budget) — scores each candidate
(measured jit sweeps on real multi-device hardware; ``core.ect`` roofline
on this CI container), and reports the winner.

CSV: name,us_per_call,derived  (derived = modeled overall ms, or the
winning mode for planner-pick rows).

Also writes ``experiments/BENCH_tuning.json``: the machine-readable baseline
(every candidate row + the chosen plan per seam) consumed by later perf PRs.
"""
from __future__ import annotations

import argparse
import json
import os

from repro.core import ect
from repro.tuning import autotune

N_TP = 8
OUT_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                        "experiments", "BENCH_tuning.json")


def main(full: bool = False) -> None:
    print("name,us_per_call,derived")
    n, k = 49152, 12288
    ms = (1024, 4096, 8192) if not full else (1024, 4096, 8192, 32768)
    doc = {"n_tp": N_TP, "seams": []}

    for m in ms:
        # Fig. 10 sweep: communication tile size on the decomposed ring
        for chunks in (N_TP, 2 * N_TP, 4 * N_TP, 8 * N_TP):
            est = ect.model_overlap("ag", m, n, k, N_TP, "decomposed",
                                    comm_chunks=chunks)
            print(f"tuning_commtile_m{m}_c{chunks},"
                  f"{est['overall']*1e6:.0f},{est['overall']*1e3:.3f}")

        # the real tuner over the FULL candidate space (measured when the
        # host has >= N_TP devices, roofline otherwise)
        res = autotune.tune_seam("ag", m, n, k, N_TP, seam="mlp_ag")
        plan = res.plan
        score_s = plan.measured_s or plan.predicted_s
        print(f"tuning_planner_m{m}_pick_{plan.mode}_c{plan.comm_chunks}"
              f"{'_rev' if plan.reverse else ''},"
              f"{score_s*1e6:.0f},{plan.source}")
        doc["seams"].append({
            "seam": "mlp_ag", "kind": res.kind,
            "m": res.m, "n": res.n, "k": res.k, "n_dev": res.n_dev,
            "source": res.source, "pruned": res.pruned,
            "plan": plan.to_json(),
            "candidates": [dict(r, blocks=list(r["blocks"]) if r["blocks"]
                                else None) for r in res.table],
        })

    # FusedOp fusion knobs: shared-gather (one ring pass for the gated
    # FFN's w1/w3 pair) and epilogue fusion (silu-gate inside vs after the
    # overlapped loop) — the PR-3 "what is fused" sweep.
    for m in ms:
        for shared in (True, False):
            est = ect.model_overlap("ag", m, n, k, N_TP, "decomposed",
                                    n_weights=2, shared_gather=shared,
                                    epilogue=True, fuse_epilogue=True)
            tag = "on" if shared else "off"
            print(f"tuning_sharedgather_m{m}_{tag},{est['overall']*1e6:.0f},"
                  f"{est['overall']*1e3:.3f}")
            doc.setdefault("fusion", {}).setdefault("shared_gather", []).append(
                {"m": m, "shared_gather": shared,
                 "overall_s": est["overall"], "comm_s": est["comm"],
                 "overlap_eff": est["overlap_eff"]})
        for fuse in (True, False):
            est = ect.model_overlap("ag", m, n, k, N_TP, "decomposed",
                                    n_weights=2, shared_gather=True,
                                    epilogue=True, fuse_epilogue=fuse)
            tag = "on" if fuse else "off"
            print(f"tuning_epifuse_m{m}_{tag},{est['overall']*1e6:.0f},"
                  f"{est['overall']*1e3:.3f}")
            doc.setdefault("fusion", {}).setdefault("fuse_epilogue", []).append(
                {"m": m, "fuse_epilogue": fuse,
                 "overall_s": est["overall"],
                 "epilogue_s": est["epilogue"]})

    # the tuner over the gated-FFN FusedOp (two weights + silu-gate): the
    # fusion knobs compete inside the candidate table
    m = 4096
    res_g = autotune.tune_seam("ag", m, n, k, N_TP, seam="mlp_ag_gated",
                               n_weights=2, epilogue=True)
    pg = res_g.plan
    print(f"tuning_fusedop_m{m}_pick_{pg.mode}_c{pg.comm_chunks}"
          f"_sg{int(pg.shared_gather)}_fe{int(pg.fuse_epilogue)},"
          f"{(pg.measured_s or pg.predicted_s)*1e6:.0f},{pg.source}")
    doc["seams"].append({
        "seam": "mlp_ag_gated", "kind": res_g.kind, "m": res_g.m,
        "n": res_g.n, "k": res_g.k, "n_dev": res_g.n_dev,
        "n_weights": 2, "epilogue": True,
        "source": res_g.source, "pruned": res_g.pruned,
        "plan": pg.to_json(),
        "candidates": [dict(r, blocks=list(r["blocks"]) if r["blocks"]
                            else None) for r in res_g.table],
    })

    # Activation-layout (scatter_axis) sweep: per m, the PAIRED per-layer
    # seams (AG + RS) under the sequence-sharded vs replicated residual
    # stream.  Comm volume is layout-invariant by construction (AG+RS over
    # seq == one ring AllReduce); "seq" keeps 1/tp of the activation
    # resident between seams — the joint knob autotune_model stamps onto
    # every residual seam plan.
    for m in ms:
        for axis in ("seq", "hidden"):
            # hidden's RS is the monolithic ring AllReduce (the chunked-AR
            # transport would move chunks x the bytes); seq rides the rings
            rs_mode = "xla" if axis == "hidden" else "decomposed"
            ag = ect.model_overlap("ag", m, n, k, N_TP, "decomposed",
                                   scatter_axis=axis)
            rs = ect.model_overlap("rs", m, k, n, N_TP, rs_mode,
                                   scatter_axis=axis)
            overall = ag["overall"] + rs["overall"]
            print(f"tuning_scatteraxis_m{m}_{axis},{overall*1e6:.0f},"
                  f"{(ag['act_bytes']+rs['act_bytes'])/2**20:.2f}MiB")
            doc.setdefault("layout", {}).setdefault("scatter_axis", []).append(
                {"m": m, "scatter_axis": axis, "overall_s": overall,
                 "act_bytes": ag["act_bytes"] + rs["act_bytes"],
                 "comm_bytes": ag["comm_bytes"] + rs["comm_bytes"]})

    # Fig. 9 (pull/push analogue): ring direction.  On a torus both single
    # directions model identically (reverse is still a real knob — measured
    # tuning discriminates them on hardware with asymmetric links); the
    # bidirectional ring rides BOTH full-duplex directions -> comm halves.
    m = 4096
    for name, mode in (("reverse0", "decomposed"), ("reverse1", "decomposed"),
                       ("bidir", "decomposed_bidir")):
        est = ect.model_overlap("ag", m, n, k, N_TP, mode)
        print(f"tuning_ringdir_{name},{est['overall']*1e6:.0f},"
              f"{est['overall']*1e3:.3f}")
        doc.setdefault("ringdir", {})[name] = {
            "mode": mode, "overall_s": est["overall"],
            "comm_s": est["comm"], "overlap_eff": est["overlap_eff"]}

    # decode seam baseline (matmul_ar) — the serving-path tuning record
    res_ar = autotune.tune_seam("ar", 128, 12288, 49152 // N_TP * N_TP, N_TP,
                                seam="decode_ar")
    print(f"tuning_decode_ar_pick_{res_ar.plan.mode}_c"
          f"{res_ar.plan.comm_chunks},"
          f"{(res_ar.plan.measured_s or res_ar.plan.predicted_s)*1e6:.0f},"
          f"{res_ar.source}")
    doc["seams"].append({
        "seam": "decode_ar", "kind": res_ar.kind, "m": res_ar.m,
        "n": res_ar.n, "k": res_ar.k, "n_dev": res_ar.n_dev,
        "source": res_ar.source, "pruned": res_ar.pruned,
        "plan": res_ar.plan.to_json(),
        "candidates": [dict(r, blocks=list(r["blocks"]) if r["blocks"]
                            else None) for r in res_ar.table],
    })

    # MoE EP exchange (kind="a2a"): chunk sweep on the interleaved ring —
    # dispatch/combine ppermute chunks hidden under the per-local-expert
    # GEMMs — plus the tuner's pick over the full a2a candidate space.
    # m = routed rows (tokens x top_k), k = d_model, n = expert_ffn.
    ma, na, ka = 8192, 8192, 12288
    for chunks in (N_TP, 2 * N_TP, 4 * N_TP):
        est = ect.model_overlap("a2a", ma, na, ka, N_TP, "decomposed",
                                comm_chunks=chunks)
        print(f"tuning_a2a_commtile_c{chunks},{est['overall']*1e6:.0f},"
              f"{est['overall']*1e3:.3f}")
        doc.setdefault("moe", {}).setdefault("a2a_chunks", []).append(
            {"m": ma, "n": na, "k": ka, "comm_chunks": chunks,
             "overall_s": est["overall"], "comm_s": est["comm"],
             "comm_bytes": est["comm_bytes"],
             "overlap_eff": est["overlap_eff"]})
    est_bar = ect.model_overlap("a2a", ma, na, ka, N_TP, "xla")
    print(f"tuning_a2a_barrier,{est_bar['overall']*1e6:.0f},"
          f"{est_bar['overall']*1e3:.3f}")
    doc["moe"]["a2a_barrier"] = {
        "m": ma, "n": na, "k": ka, "overall_s": est_bar["overall"],
        "comm_s": est_bar["comm"], "comm_bytes": est_bar["comm_bytes"]}
    res_a2a = autotune.tune_seam("a2a", ma, na, ka, N_TP, seam="moe_a2a")
    pa = res_a2a.plan
    print(f"tuning_moe_a2a_pick_{pa.mode}_c{pa.comm_chunks}"
          f"{'_rev' if pa.reverse else ''},"
          f"{(pa.measured_s or pa.predicted_s)*1e6:.0f},{res_a2a.source}")
    doc["seams"].append({
        "seam": "moe_a2a", "kind": res_a2a.kind, "m": res_a2a.m,
        "n": res_a2a.n, "k": res_a2a.k, "n_dev": res_a2a.n_dev,
        "n_weights": 3, "epilogue": True,
        "source": res_a2a.source, "pruned": res_a2a.pruned,
        "plan": pa.to_json(),
        "candidates": [dict(r, blocks=list(r["blocks"]) if r["blocks"]
                            else None) for r in res_a2a.table],
    })

    # Wire-precision sweep: per seam kind the tuner re-prices every
    # candidate under each wire dtype (bytes-on-wire shrink + scale
    # overhead + pack/unpack cost in the ect roofline) and only lets a
    # quantized wire win when its estimated logit deviation fits the
    # default error budget.  One row per candidate: wire_dtype,
    # comm_bytes (bytes on the wire), predicted/measured time,
    # logit_rmse, within_budget — the machine-readable record verify.sh
    # asserts on (>= 1 seam must show an in-budget low-precision win).
    from repro.tuning.autotune import WIRE_DTYPE_SWEEP
    from repro.tuning.error_budget import DEFAULT_MAX_LOGIT_RMSE
    doc["wire"] = {"max_logit_rmse": DEFAULT_MAX_LOGIT_RMSE, "seams": []}
    wire_sweeps = (
        ("mlp_ag", "ag", 4096, n, k, {}),
        ("mlp_rs", "rs", 4096, k, n, {}),
        ("decode_ar", "ar", 128, 12288, 49152, {}),
        ("moe_a2a", "a2a", ma, na, ka, {}),
    )
    any_win = False
    for seam, kind, wm, wn, wk, extra in wire_sweeps:
        res_w = autotune.tune_seam(kind, wm, wn, wk, N_TP, seam=seam,
                                   wire_dtypes=WIRE_DTYPE_SWEEP,
                                   max_logit_rmse=DEFAULT_MAX_LOGIT_RMSE,
                                   **extra)
        score = lambda r: r["measured_s"] or r["predicted_s"]  # noqa: E731
        for wd in WIRE_DTYPE_SWEEP:
            rows = [r for r in res_w.table if r["wire_dtype"] == wd]
            if not rows:
                continue
            best = min(rows, key=score)
            print(f"tuning_wire_{seam}_{wd or 'fp'},{score(best)*1e6:.0f},"
                  f"rmse={best['logit_rmse']:.4f}"
                  f"{'' if best['within_budget'] else '(REJECTED)'}")
        fp_best = min(score(r) for r in res_w.table
                      if r["wire_dtype"] is None)
        q_rows = [r for r in res_w.table
                  if r["wire_dtype"] and r["within_budget"]]
        win = bool(q_rows) and min(score(r) for r in q_rows) < fp_best
        any_win = any_win or win
        pw = res_w.plan
        print(f"tuning_wire_{seam}_pick_{pw.mode}_{pw.wire_dtype or 'fp'},"
              f"{(pw.measured_s or pw.predicted_s)*1e6:.0f},{res_w.source}")
        doc["wire"]["seams"].append({
            "seam": seam, "kind": kind, "m": wm, "n": wn, "k": wk,
            "n_dev": N_TP, "source": res_w.source,
            "quantized_win_within_budget": win,
            "plan": pw.to_json(),
            "rows": [dict(r, blocks=list(r["blocks"]) if r["blocks"]
                          else None) for r in res_w.table],
        })
    doc["wire"]["any_quantized_win"] = any_win

    os.makedirs(os.path.dirname(OUT_PATH), exist_ok=True)
    with open(OUT_PATH, "w") as f:
        json.dump(doc, f, indent=1)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    main(**vars(ap.parse_args()))
