"""Auto-tuning benchmark — paper Figs. 9 (pull/push) and 10 (comm tile size).

Sweeps the decomposed-mode chunk count (the §4.3 communication-tile knob)
and the ring direction (pull/push analogue) and reports the planner's pick.

CSV: name,us_per_call,derived  (derived = modeled overall ms)
"""
from __future__ import annotations

import argparse

from repro.core import ect, planner

N_TP = 8


def main(full: bool = False) -> None:
    print("name,us_per_call,derived")
    n, k = 49152, 12288
    for m in (1024, 4096, 8192):
        for chunks in (N_TP, 2 * N_TP, 4 * N_TP, 8 * N_TP):
            est = ect.model_overlap("ag", m, n, k, N_TP, "decomposed",
                                    comm_chunks=chunks)
            print(f"tuning_commtile_m{m}_c{chunks},"
                  f"{est['overall']*1e6:.0f},{est['overall']*1e3:.3f}")
        plan = planner.plan_seam("ag", m, n, k, N_TP)
        print(f"tuning_planner_m{m}_pick_{plan.mode}_c{plan.comm_chunks},"
              f"{plan.predicted_overall_s*1e6:.0f},"
              f"{100*plan.predicted_overlap_eff:.1f}")
    # ring direction (pull/push analogue): symmetric on a torus — the knob
    # exists (kernels' reverse=); the WINNING setting is both at once:
    # decomposed_bidir rides both full-duplex link directions (-36% ICI
    # time on the codeqwen train cell, EXPERIMENTS §Perf 1e).
    for mode in ("reverse0", "reverse1", "bidir"):
        note = ("duplex-2x-ring-bw" if mode == "bidir"
                else "same-bandwidth-on-torus")
        print(f"tuning_ringdir_{mode},0,{note}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    main(**vars(ap.parse_args()))
