"""Auto-tuning benchmark — paper Figs. 9 (pull/push) and 10 (comm tile size).

Exercises the REAL tuner (``repro.tuning.autotune``): per GEMM shape it
enumerates the full candidate space — every overlap mode (including
``decomposed_bidir`` and the ``*_q8`` int8-gather variants), comm-tile
counts, and ring directions — scores each candidate (measured jit sweeps on
real multi-device hardware; ``core.ect`` roofline on this CI container), and
reports the winner.

CSV: name,us_per_call,derived  (derived = modeled overall ms, or the
winning mode for planner-pick rows).

Also writes ``experiments/BENCH_tuning.json``: the machine-readable baseline
(every candidate row + the chosen plan per seam) consumed by later perf PRs.
"""
from __future__ import annotations

import argparse
import json
import os

from repro.core import ect
from repro.tuning import autotune

N_TP = 8
OUT_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                        "experiments", "BENCH_tuning.json")


def main(full: bool = False) -> None:
    print("name,us_per_call,derived")
    n, k = 49152, 12288
    ms = (1024, 4096, 8192) if not full else (1024, 4096, 8192, 32768)
    doc = {"n_tp": N_TP, "seams": []}

    for m in ms:
        # Fig. 10 sweep: communication tile size on the decomposed ring
        for chunks in (N_TP, 2 * N_TP, 4 * N_TP, 8 * N_TP):
            est = ect.model_overlap("ag", m, n, k, N_TP, "decomposed",
                                    comm_chunks=chunks)
            print(f"tuning_commtile_m{m}_c{chunks},"
                  f"{est['overall']*1e6:.0f},{est['overall']*1e3:.3f}")

        # the real tuner over the FULL candidate space (measured when the
        # host has >= N_TP devices, roofline otherwise)
        res = autotune.tune_seam("ag", m, n, k, N_TP, seam="mlp_ag")
        plan = res.plan
        score_s = plan.measured_s or plan.predicted_s
        print(f"tuning_planner_m{m}_pick_{plan.mode}_c{plan.comm_chunks}"
              f"{'_rev' if plan.reverse else ''},"
              f"{score_s*1e6:.0f},{plan.source}")
        doc["seams"].append({
            "seam": "mlp_ag", "kind": res.kind,
            "m": res.m, "n": res.n, "k": res.k, "n_dev": res.n_dev,
            "source": res.source, "pruned": res.pruned,
            "plan": plan.to_json(),
            "candidates": [dict(r, blocks=list(r["blocks"]) if r["blocks"]
                                else None) for r in res.table],
        })

    # FusedOp fusion knobs: shared-gather (one ring pass for the gated
    # FFN's w1/w3 pair) and epilogue fusion (silu-gate inside vs after the
    # overlapped loop) — the PR-3 "what is fused" sweep.
    for m in ms:
        for shared in (True, False):
            est = ect.model_overlap("ag", m, n, k, N_TP, "decomposed",
                                    n_weights=2, shared_gather=shared,
                                    epilogue=True, fuse_epilogue=True)
            tag = "on" if shared else "off"
            print(f"tuning_sharedgather_m{m}_{tag},{est['overall']*1e6:.0f},"
                  f"{est['overall']*1e3:.3f}")
            doc.setdefault("fusion", {}).setdefault("shared_gather", []).append(
                {"m": m, "shared_gather": shared,
                 "overall_s": est["overall"], "comm_s": est["comm"],
                 "overlap_eff": est["overlap_eff"]})
        for fuse in (True, False):
            est = ect.model_overlap("ag", m, n, k, N_TP, "decomposed",
                                    n_weights=2, shared_gather=True,
                                    epilogue=True, fuse_epilogue=fuse)
            tag = "on" if fuse else "off"
            print(f"tuning_epifuse_m{m}_{tag},{est['overall']*1e6:.0f},"
                  f"{est['overall']*1e3:.3f}")
            doc.setdefault("fusion", {}).setdefault("fuse_epilogue", []).append(
                {"m": m, "fuse_epilogue": fuse,
                 "overall_s": est["overall"],
                 "epilogue_s": est["epilogue"]})

    # the tuner over the gated-FFN FusedOp (two weights + silu-gate): the
    # fusion knobs compete inside the candidate table
    m = 4096
    res_g = autotune.tune_seam("ag", m, n, k, N_TP, seam="mlp_ag_gated",
                               n_weights=2, epilogue=True)
    pg = res_g.plan
    print(f"tuning_fusedop_m{m}_pick_{pg.mode}_c{pg.comm_chunks}"
          f"_sg{int(pg.shared_gather)}_fe{int(pg.fuse_epilogue)},"
          f"{(pg.measured_s or pg.predicted_s)*1e6:.0f},{pg.source}")
    doc["seams"].append({
        "seam": "mlp_ag_gated", "kind": res_g.kind, "m": res_g.m,
        "n": res_g.n, "k": res_g.k, "n_dev": res_g.n_dev,
        "n_weights": 2, "epilogue": True,
        "source": res_g.source, "pruned": res_g.pruned,
        "plan": pg.to_json(),
        "candidates": [dict(r, blocks=list(r["blocks"]) if r["blocks"]
                            else None) for r in res_g.table],
    })

    # Activation-layout (scatter_axis) sweep: per m, the PAIRED per-layer
    # seams (AG + RS) under the sequence-sharded vs replicated residual
    # stream.  Comm volume is layout-invariant by construction (AG+RS over
    # seq == one ring AllReduce); "seq" keeps 1/tp of the activation
    # resident between seams — the joint knob autotune_model stamps onto
    # every residual seam plan.
    for m in ms:
        for axis in ("seq", "hidden"):
            # hidden's RS is the monolithic ring AllReduce (the chunked-AR
            # transport would move chunks x the bytes); seq rides the rings
            rs_mode = "xla" if axis == "hidden" else "decomposed"
            ag = ect.model_overlap("ag", m, n, k, N_TP, "decomposed",
                                   scatter_axis=axis)
            rs = ect.model_overlap("rs", m, k, n, N_TP, rs_mode,
                                   scatter_axis=axis)
            overall = ag["overall"] + rs["overall"]
            print(f"tuning_scatteraxis_m{m}_{axis},{overall*1e6:.0f},"
                  f"{(ag['act_bytes']+rs['act_bytes'])/2**20:.2f}MiB")
            doc.setdefault("layout", {}).setdefault("scatter_axis", []).append(
                {"m": m, "scatter_axis": axis, "overall_s": overall,
                 "act_bytes": ag["act_bytes"] + rs["act_bytes"],
                 "comm_bytes": ag["comm_bytes"] + rs["comm_bytes"]})

    # Fig. 9 (pull/push analogue): ring direction.  On a torus both single
    # directions model identically (reverse is still a real knob — measured
    # tuning discriminates them on hardware with asymmetric links); the
    # bidirectional ring rides BOTH full-duplex directions -> comm halves.
    m = 4096
    for name, mode in (("reverse0", "decomposed"), ("reverse1", "decomposed"),
                       ("bidir", "decomposed_bidir")):
        est = ect.model_overlap("ag", m, n, k, N_TP, mode)
        print(f"tuning_ringdir_{name},{est['overall']*1e6:.0f},"
              f"{est['overall']*1e3:.3f}")
        doc.setdefault("ringdir", {})[name] = {
            "mode": mode, "overall_s": est["overall"],
            "comm_s": est["comm"], "overlap_eff": est["overlap_eff"]}

    # decode seam baseline (matmul_ar) — the serving-path tuning record
    res_ar = autotune.tune_seam("ar", 128, 12288, 49152 // N_TP * N_TP, N_TP,
                                seam="decode_ar")
    print(f"tuning_decode_ar_pick_{res_ar.plan.mode}_c"
          f"{res_ar.plan.comm_chunks},"
          f"{(res_ar.plan.measured_s or res_ar.plan.predicted_s)*1e6:.0f},"
          f"{res_ar.source}")
    doc["seams"].append({
        "seam": "decode_ar", "kind": res_ar.kind, "m": res_ar.m,
        "n": res_ar.n, "k": res_ar.k, "n_dev": res_ar.n_dev,
        "source": res_ar.source, "pruned": res_ar.pruned,
        "plan": res_ar.plan.to_json(),
        "candidates": [dict(r, blocks=list(r["blocks"]) if r["blocks"]
                            else None) for r in res_ar.table],
    })

    # MoE EP exchange (kind="a2a"): chunk sweep on the interleaved ring —
    # dispatch/combine ppermute chunks hidden under the per-local-expert
    # GEMMs — plus the tuner's pick over the full a2a candidate space.
    # m = routed rows (tokens x top_k), k = d_model, n = expert_ffn.
    ma, na, ka = 8192, 8192, 12288
    for chunks in (N_TP, 2 * N_TP, 4 * N_TP):
        est = ect.model_overlap("a2a", ma, na, ka, N_TP, "decomposed",
                                comm_chunks=chunks)
        print(f"tuning_a2a_commtile_c{chunks},{est['overall']*1e6:.0f},"
              f"{est['overall']*1e3:.3f}")
        doc.setdefault("moe", {}).setdefault("a2a_chunks", []).append(
            {"m": ma, "n": na, "k": ka, "comm_chunks": chunks,
             "overall_s": est["overall"], "comm_s": est["comm"],
             "comm_bytes": est["comm_bytes"],
             "overlap_eff": est["overlap_eff"]})
    est_bar = ect.model_overlap("a2a", ma, na, ka, N_TP, "xla")
    print(f"tuning_a2a_barrier,{est_bar['overall']*1e6:.0f},"
          f"{est_bar['overall']*1e3:.3f}")
    doc["moe"]["a2a_barrier"] = {
        "m": ma, "n": na, "k": ka, "overall_s": est_bar["overall"],
        "comm_s": est_bar["comm"], "comm_bytes": est_bar["comm_bytes"]}
    res_a2a = autotune.tune_seam("a2a", ma, na, ka, N_TP, seam="moe_a2a")
    pa = res_a2a.plan
    print(f"tuning_moe_a2a_pick_{pa.mode}_c{pa.comm_chunks}"
          f"{'_rev' if pa.reverse else ''},"
          f"{(pa.measured_s or pa.predicted_s)*1e6:.0f},{res_a2a.source}")
    doc["seams"].append({
        "seam": "moe_a2a", "kind": res_a2a.kind, "m": res_a2a.m,
        "n": res_a2a.n, "k": res_a2a.k, "n_dev": res_a2a.n_dev,
        "n_weights": 3, "epilogue": True,
        "source": res_a2a.source, "pruned": res_a2a.pruned,
        "plan": pa.to_json(),
        "candidates": [dict(r, blocks=list(r["blocks"]) if r["blocks"]
                            else None) for r in res_a2a.table],
    })

    os.makedirs(os.path.dirname(OUT_PATH), exist_ok=True)
    with open(OUT_PATH, "w") as f:
        json.dump(doc, f, indent=1)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    main(**vars(ap.parse_args()))
