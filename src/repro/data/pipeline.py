"""Deterministic, seekable synthetic token pipeline.

Production shape: each HOST generates only its data shard (host-sharded
loading); the stream is a pure function of (seed, step, shard) so restart
from a checkpoint reproduces the exact batch sequence (fault tolerance
requires a seekable data source — no iterator state in checkpoints, just
the step counter).

The generator is a cheap stateless hash (threefry via jax would force a
device roundtrip; we use a numpy philox-style mix) producing Zipf-ish token
frequencies so MoE routing and vocab losses see a realistic skew.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2


_U64 = np.uint64


def _mix(x: np.ndarray) -> np.ndarray:
    x = (x ^ (x >> 33)) * np.uint64(0xFF51AFD7ED558CCD)
    x = (x ^ (x >> 33)) * np.uint64(0xC4CEB9FE1A85EC53)
    return x ^ (x >> 33)


def batch_at(cfg: DataConfig, step: int, shard: int = 0,
             num_shards: int = 1) -> Dict[str, np.ndarray]:
    """The (step, shard) batch — pure function, O(1) seek."""
    assert cfg.global_batch % num_shards == 0
    b_loc = cfg.global_batch // num_shards
    with np.errstate(over="ignore"):   # wrapping uint64 mixes are intended
        idx = (_U64(cfg.seed) * _U64(0x9E3779B97F4A7C15)
               + _U64(step) * _U64(cfg.global_batch * (cfg.seq_len + 1))
               + (np.arange(b_loc * (cfg.seq_len + 1), dtype=np.uint64)
                  + _U64(shard * b_loc * (cfg.seq_len + 1))))
    u = _mix(idx).astype(np.float64) / float(2 ** 64)
    # inverse-CDF Zipf-ish sampling onto [0, vocab)
    ranks = np.power(u + 1e-12, cfg.zipf_a * 1.8)
    toks = np.minimum((ranks * cfg.vocab_size).astype(np.int64),
                      cfg.vocab_size - 1)
    toks = toks.reshape(b_loc, cfg.seq_len + 1).astype(np.int32)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class DataStream:
    """Stateful convenience wrapper (state == step, nothing else)."""

    def __init__(self, cfg: DataConfig, shard: int = 0, num_shards: int = 1,
                 start_step: int = 0):
        self.cfg = cfg
        self.shard = shard
        self.num_shards = num_shards
        self.step = start_step

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        b = batch_at(self.cfg, self.step, self.shard, self.num_shards)
        self.step += 1
        return b

    def seek(self, step: int) -> None:
        self.step = step
