"""Pure-jnp oracles for every Pallas kernel (single-device semantics).

Distributed kernels (ag_matmul / matmul_rs) have per-device oracles given the
GLOBAL operands; tests run them under shard_map against ``lax`` collectives.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """fp32-accumulating matmul oracle."""
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32),
                   preferred_element_type=jnp.float32)


def ag_matmul_ref_global(a_global: jax.Array, b_local: jax.Array) -> jax.Array:
    """Oracle for the fused AllGather-GEMM given the ALREADY-GATHERED A.
    Per device, output is the full-M product against the local B columns."""
    return matmul_ref(a_global, b_local)


def matmul_rs_ref_global(partials: jax.Array, shard_id: int, n_shards: int) -> jax.Array:
    """Oracle for fused GEMM-ReduceScatter: ``partials`` is [n_dev, M, N] of
    per-device partial products; returns shard ``shard_id`` of the sum."""
    total = jnp.sum(partials.astype(jnp.float32), axis=0)
    m_shard = total.shape[0] // n_shards
    return jax.lax.dynamic_slice_in_dim(total, shard_id * m_shard, m_shard, axis=0)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True, scale: float | None = None) -> jax.Array:
    """Naive softmax attention oracle.  q,k,v: [B, H, S, D] (k/v may have
    fewer heads — GQA — broadcast by repetition)."""
    b, hq, sq, d = q.shape
    hkv = k.shape[1]
    if hkv != hq:
        rep = hq // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        skv = k.shape[2]
        mask = jnp.tril(jnp.ones((sq, skv), bool), k=skv - sq)
        logits = jnp.where(mask, logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))


def mla_decode_attention_ref(q_eff, q_rope, c_cache, kr_cache, valid_len,
                             scale):
    """Oracle for the fused absorbed-MLA decode attention."""
    s = (jnp.einsum("bhr,bsr->bhs", q_eff.astype(jnp.float32),
                    c_cache.astype(jnp.float32))
         + jnp.einsum("bhd,bsd->bhs", q_rope.astype(jnp.float32),
                      kr_cache.astype(jnp.float32))) * scale
    pos = jnp.arange(c_cache.shape[1])
    vl = jnp.broadcast_to(jnp.asarray(valid_len, jnp.int32).reshape(-1),
                          (c_cache.shape[0],))
    s = jnp.where(pos[None, None, :] < vl[:, None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhs,bsr->bhr", w, c_cache.astype(jnp.float32))
