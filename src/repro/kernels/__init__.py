# Pallas TPU kernels for the compute/communication hot-spots:
#   ag_gemm    — fused AllGather-GEMM ring (FLUX prologue fusion)
#   gemm_rs    — fused GEMM-ReduceScatter ring (FLUX epilogue fusion)
#   matmul     — best non-split GEMM (the paper's ECT baseline)
#   flash_attention — causal flash w/ block skipping (prefill hotspot)
#   mla_decode — fused absorbed-MLA decode attention (decode hotspot)
# ops.py holds the jit-ready wrappers; ref.py the pure-jnp oracles.
