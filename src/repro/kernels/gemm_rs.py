"""Fused GEMM-ReduceScatter Pallas TPU kernel (FLUX Algorithm 1, TPU-native).

Per device:  out = shard_me( sum_over_ranks( A @ B ) ),  A: [M, K_sh] (local
K columns), B: [K_sh, N].  The reduction is *fused into the matmul epilogue*
— the fp32 accumulator of each output tile is folded with the partial tile
arriving from the upstream neighbor, then immediately DMA'd downstream
(tile-granular AlltoAll of FLUX §3.1, adapted to the ICI ring so every hop is
a single neighbor link).

Differences vs. the GPU original, by design (DESIGN.md §2):
  - FLUX scatters each tile directly to its owner (1 NVLink hop) and reduces
    with atomics / specialized warps.  On an ICI torus the bandwidth-optimal
    schedule is the ring: partials accumulate as they travel, so the "Reduce
    branch" costs one VPU add per tile and needs no atomics.
  - Tile-coordinate swizzling: rank ``me`` computes the partial for owner
    ``(me + n-1 - s) mod n`` at ring step ``s``, so at any instant the n
    in-flight buffers target n distinct owners — the ring version of FLUX's
    Fig. 7 memory-contention fix (every link busy, no converging writes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro import compat
from repro.kernels.ag_gemm import EPILOGUE_ACTS


def _gemm_rs_kernel(a_ref, b_ref, *rest,           # HBM: [M,K_sh], [K_sh,N], [M/n,N]
                    axis_name: str, n_dev: int, reverse: bool,
                    bm: int, bk: int, bn: int,
                    activation=None, has_bias: bool = False):
    # epilogue hook: bias/activation fold into the FINAL reduction step's
    # tile emit (after all n partials have summed — adding earlier would
    # apply the bias once per rank).
    if has_bias:
        (bias_ref, o_ref, ws, acc_ref, a_vmem, b_vmem, stage, o_stage,
         bias_vmem, send_sem, recv_sem, copy_a, copy_b, copy_o) = rest
    else:
        bias_ref = bias_vmem = None
        (o_ref, ws, acc_ref, a_vmem, b_vmem, stage, o_stage,
         send_sem, recv_sem, copy_a, copy_b, copy_o) = rest
    step = pl.program_id(0)
    mi = pl.program_id(1)
    ni = pl.program_id(2)
    ki = pl.program_id(3)
    n_m, n_n, n_k = pl.num_programs(1), pl.num_programs(2), pl.num_programs(3)

    me = lax.axis_index(axis_name)
    sgn = -1 if reverse else 1
    nbr = lax.rem(me + sgn + n_dev, n_dev)
    # swizzle: owner of the partial we compute at this step
    owner = lax.rem(me + sgn * (n_dev - 1 - step) + 2 * n_dev, n_dev)
    m_sh = n_m * bm

    # ---- contraction: accumulate A[owner rows] @ B for this tile ------------
    ca = compat.make_async_copy(
        a_ref.at[pl.ds(owner * m_sh + mi * bm, bm), pl.ds(ki * bk, bk)],
        a_vmem, copy_a)
    cb = compat.make_async_copy(
        b_ref.at[pl.ds(ki * bk, bk), pl.ds(ni * bn, bn)], b_vmem, copy_b)
    ca.start(); cb.start(); ca.wait(); cb.wait()

    @pl.when(ki == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_vmem[...], b_vmem[...],
                            preferred_element_type=jnp.float32)

    # ---- epilogue: fold incoming partial, forward (or emit) the tile --------
    @pl.when(ki == n_k - 1)
    def _epilogue():
        @pl.when(step > 0)
        def _fold_incoming():
            # WaitSignal for THIS tile of the in-flight buffer, then fuse the
            # reduction into the accumulator (FLUX "Reduce branch").
            compat.make_async_remote_copy(
                src_ref=ws.at[step, pl.ds(mi * bm, bm), pl.ds(ni * bn, bn)],
                dst_ref=ws.at[step, pl.ds(mi * bm, bm), pl.ds(ni * bn, bn)],
                send_sem=send_sem, recv_sem=recv_sem,
                device_id=nbr, device_id_type=compat.LOGICAL_DEVICE_ID,
            ).wait_recv()
            inc = compat.make_async_copy(
                ws.at[step, pl.ds(mi * bm, bm), pl.ds(ni * bn, bn)],
                stage, copy_a)
            inc.start(); inc.wait()
            acc_ref[...] += stage[...].astype(jnp.float32)

        @pl.when(step < n_dev - 1)
        def _forward_tile():
            stage[...] = acc_ref[...].astype(stage.dtype)
            st = compat.make_async_copy(
                stage, ws.at[step, pl.ds(mi * bm, bm), pl.ds(ni * bn, bn)],
                copy_o)
            st.start(); st.wait()
            compat.make_async_remote_copy(
                src_ref=ws.at[step, pl.ds(mi * bm, bm), pl.ds(ni * bn, bn)],
                dst_ref=ws.at[step + 1, pl.ds(mi * bm, bm), pl.ds(ni * bn, bn)],
                send_sem=send_sem, recv_sem=recv_sem,
                device_id=nbr, device_id_type=compat.LOGICAL_DEVICE_ID,
            ).start()

        @pl.when(step == n_dev - 1)
        def _emit():
            # final step computes OUR shard (owner == me): write the reduced
            # tile straight to the output — epilogue fusion, no extra pass.
            acc = acc_ref[...]
            if has_bias:
                cbias = compat.make_async_copy(
                    bias_ref.at[:, pl.ds(ni * bn, bn)], bias_vmem, copy_b)
                cbias.start(); cbias.wait()
                acc = acc + bias_vmem[...].astype(jnp.float32)
            if activation is not None:
                acc = EPILOGUE_ACTS[activation](acc)
            o_stage[...] = acc.astype(o_stage.dtype)
            co = compat.make_async_copy(
                o_stage, o_ref.at[pl.ds(mi * bm, bm), pl.ds(ni * bn, bn)], copy_o)
            co.start(); co.wait()

        # drain one outstanding tile-send per tile from the previous step so
        # the semaphore balances by kernel exit.
        @pl.when(step > 0)
        def _drain_prev_send():
            compat.make_async_remote_copy(
                src_ref=ws.at[step - 1, pl.ds(mi * bm, bm), pl.ds(ni * bn, bn)],
                dst_ref=ws.at[step, pl.ds(mi * bm, bm), pl.ds(ni * bn, bn)],
                send_sem=send_sem, recv_sem=recv_sem,
                device_id=nbr, device_id_type=compat.LOGICAL_DEVICE_ID,
            ).wait_send()


def gemm_rs(a_local: jax.Array, b_local: jax.Array, *, axis_name: str,
            n_dev: int, bm: int = 256, bk: int = 512, bn: int = 256,
            reverse: bool = False, out_dtype=None, partial_dtype=None,
            activation: str | None = None, bias: jax.Array | None = None,
            interpret: bool | None = None, collective_id: int = 1) -> jax.Array:
    """out[M/n, N] = act(ReduceScatter_m(A_local @ B_local) + bias), fused.
    Call inside shard_map; A column(K)-sharded, B row(K)-sharded over
    ``axis_name``.  ``activation``/``bias`` apply in the final reduction
    step's tile emit (bias: [N])."""
    m, k_sh = a_local.shape
    k2, n = b_local.shape
    assert k_sh == k2
    assert m % n_dev == 0, (m, n_dev)
    assert activation is None or activation in EPILOGUE_ACTS, activation
    m_sh = m // n_dev
    out_dtype = out_dtype or a_local.dtype
    partial_dtype = partial_dtype or out_dtype
    bm, bk, bn = min(bm, m_sh), min(bk, k_sh), min(bn, n)
    assert m_sh % bm == 0 and k_sh % bk == 0 and n % bn == 0, (
        f"gemm_rs dims ({m_sh},{k_sh},{n}) vs blocks ({bm},{bk},{bn})")
    grid = (n_dev, m_sh // bm, n // bn, k_sh // bk)
    has_bias = bias is not None
    kernel = functools.partial(
        _gemm_rs_kernel, axis_name=axis_name, n_dev=n_dev, reverse=reverse,
        bm=bm, bk=bk, bn=bn, activation=activation, has_bias=has_bias)
    in_specs = [pl.BlockSpec(memory_space=compat.ANY),
                pl.BlockSpec(memory_space=compat.ANY)]
    operands = [a_local, b_local]
    scratch = [
        compat.hbm_scratch((n_dev, m_sh, n), partial_dtype),    # in-flight partials
        compat.VMEM((bm, bn), jnp.float32),          # accumulator
        compat.VMEM((bm, bk), a_local.dtype),
        compat.VMEM((bk, bn), b_local.dtype),
        compat.VMEM((bm, bn), partial_dtype),        # stage/cast buffer
        compat.VMEM((bm, bn), out_dtype),            # output cast buffer
    ]
    if has_bias:
        assert bias.shape == (n,), (bias.shape, n)
        in_specs.append(pl.BlockSpec(memory_space=compat.ANY))
        operands.append(bias.reshape(1, n))
        scratch.append(compat.VMEM((1, bn), bias.dtype))        # bias tile
    scratch += [
        compat.DMA_SEM, compat.DMA_SEM,
        compat.DMA_SEM, compat.DMA_SEM,
        compat.DMA_SEM,
    ]
    return compat.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec(memory_space=compat.ANY),
        out_shape=jax.ShapeDtypeStruct((m_sh, n), out_dtype),
        scratch_shapes=scratch,
        compiler_params=compat.pallas_compiler_params(collective_id=collective_id),
        interpret=interpret,
    )(*operands)
