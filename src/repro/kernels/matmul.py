"""Baseline tiled MXU matmul Pallas kernel — the "best non-split GEMM".

This is the GEMM_non-split of the paper's Effective-Communication-Time metric
(Eq. 1): all overlap modes are compared against the SAME best matmul.  Block
shapes are MXU-aligned (multiples of 128) and the fp32 accumulator lives in
VMEM across the K-contraction grid axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro import compat


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(ki == n_k - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul(a: jax.Array, b: jax.Array, *,
           bm: int = 256, bk: int = 512, bn: int = 256,
           out_dtype=None, interpret: bool | None = None) -> jax.Array:
    """C = A @ B with fp32 accumulation.  A: [M, K], B: [K, N].
    ``interpret=None`` resolves via ``compat.interpret_default()`` (interpret
    mode on CPU CI, Mosaic on real TPUs)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm, bk, bn = min(bm, m), min(bk, k), min(bn, n)
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, (
        f"matmul dims ({m},{k},{n}) not divisible by blocks ({bm},{bk},{bn})")
    out_dtype = out_dtype or a.dtype
    n_k = k // bk
    grid = (m // bm, n // bn, n_k)
    return compat.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda mi, ni, ki: (mi, ki)),
            pl.BlockSpec((bk, bn), lambda mi, ni, ki: (ki, ni)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda mi, ni, ki: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[compat.VMEM((bm, bn), jnp.float32)],
        compiler_params=compat.pallas_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b)
