"""Fused AllGather-GEMM Pallas TPU kernel (FLUX Algorithm 2/3, TPU-native).

One kernel per device computes  C = AllGather_m(A_shard) @ B_local  while the
gather itself rides the ICI ring *inside* the kernel:

  - ``a_agg`` is the aggregated HBM buffer of FLUX Algorithm 2 (one slot per
    rank; the local slot is "preset" — paper: local signals preset to true).
  - grid axis 0 is the ring step; at step ``s`` the kernel multiplies the
    shard owned by rank ``(me - s) mod n`` (tile-coordinate swizzle: every
    device walks a different output row region each step, §4.1) while the
    NEXT shard is already in flight from the left neighbor.
  - FLUX's host-side ``DataTransfer + SetSignal`` (Algorithm 3) becomes an
    in-kernel ``make_async_remote_copy``; ``WaitSignal`` becomes the DMA recv
    semaphore wait.  No host in the loop, no spin-waiting.
  - each slot is written by exactly one DMA -> no write-after-read hazards,
    no flow-control acks needed (this is why the full A_agg buffer exists in
    FLUX too).

Ring order starts after the local rank (paper §4.3: "ring order starting
after the local rank").  ``reverse=True`` flips the ring direction — the TPU
analogue of the paper's pull/push tuning knob.

Epilogue hook (FLUX thesis: fuse MORE dependent compute into the kernel):
``activation`` / ``bias`` apply to the fp32 accumulator in the TILE epilogue
— bias is DMA'd per output-column tile and added, the activation runs on the
VPU before the cast+store, so the fused elementwise tail costs no extra HBM
pass.  Driven by ``overlap.FusedOp`` via ``kernels.ops``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro import compat
# one activation registry for the whole overlap surface (overlap.Epilogue
# validation and the kernel tile epilogues must never drift apart; overlap
# imports kernels only lazily, so this edge is cycle-free)
from repro.core.overlap import ACTIVATIONS as EPILOGUE_ACTS


def _ag_gemm_kernel(a_ref, b_ref, *rest,           # HBM: [M_sh,K], [K,N], [n*M_sh,N]
                    axis_name: str, n_dev: int, reverse: bool,
                    bm: int, bk: int, bn: int,
                    activation=None, has_bias: bool = False):
    if has_bias:
        (bias_ref, o_ref, a_agg, acc_ref, a_vmem, b_vmem, o_vmem, bias_vmem,
         local_sem, send_sem, recv_sem, copy_a, copy_b, copy_o) = rest
    else:
        bias_ref = bias_vmem = None
        (o_ref, a_agg, acc_ref, a_vmem, b_vmem, o_vmem,
         local_sem, send_sem, recv_sem, copy_a, copy_b, copy_o) = rest
    step = pl.program_id(0)
    mi = pl.program_id(1)
    ni = pl.program_id(2)
    ki = pl.program_id(3)
    n_m, n_n, n_k = pl.num_programs(1), pl.num_programs(2), pl.num_programs(3)
    first_inner = (mi == 0) & (ni == 0) & (ki == 0)

    me = lax.axis_index(axis_name)
    sgn = -1 if reverse else 1
    nbr = lax.rem(me + sgn + n_dev, n_dev)            # downstream neighbor
    owner = lax.rem(me - sgn * step + 2 * n_dev, n_dev)  # whose shard we hold now
    nxt = lax.rem(me - sgn * (step + 1) + 2 * n_dev, n_dev)

    # ---- step 0 bootstrap: stage the local shard into its A_agg slot -------
    @pl.when((step == 0) & first_inner)
    def _preset_local():
        cp = compat.make_async_copy(a_ref, a_agg.at[me], local_sem)
        cp.start()
        cp.wait()

    # ---- ring: forward the shard we hold to the downstream neighbor --------
    @pl.when(first_inner)
    def _ring():
        @pl.when(step > 0)
        def _wait_arrival():
            # WaitSignal: the DMA landing in slot `owner` was issued by the
            # upstream neighbor during its previous step.
            compat.make_async_remote_copy(
                src_ref=a_agg.at[owner], dst_ref=a_agg.at[owner],
                send_sem=send_sem, recv_sem=recv_sem,
                device_id=nbr, device_id_type=compat.LOGICAL_DEVICE_ID,
            ).wait_recv()

        @pl.when(step < n_dev - 1)
        def _forward():
            compat.make_async_remote_copy(
                src_ref=a_agg.at[owner], dst_ref=a_agg.at[owner],
                send_sem=send_sem, recv_sem=recv_sem,
                device_id=nbr, device_id_type=compat.LOGICAL_DEVICE_ID,
            ).start()

    # ---- MXU block matmul over the current shard ---------------------------
    ca = compat.make_async_copy(
        a_agg.at[owner, pl.ds(mi * bm, bm), pl.ds(ki * bk, bk)], a_vmem, copy_a)
    cb = compat.make_async_copy(
        b_ref.at[pl.ds(ki * bk, bk), pl.ds(ni * bn, bn)], b_vmem, copy_b)
    ca.start(); cb.start(); ca.wait(); cb.wait()

    @pl.when(ki == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_vmem[...], b_vmem[...],
                            preferred_element_type=jnp.float32)

    @pl.when(ki == n_k - 1)
    def _epilogue():
        # fused tile epilogue: bias + activation on the fp32 accumulator,
        # then the swizzled store (rows of the shard we currently hold)
        acc = acc_ref[...]
        if has_bias:
            cbias = compat.make_async_copy(
                bias_ref.at[:, pl.ds(ni * bn, bn)], bias_vmem, copy_b)
            cbias.start(); cbias.wait()
            acc = acc + bias_vmem[...].astype(jnp.float32)
        if activation is not None:
            acc = EPILOGUE_ACTS[activation](acc)
        o_vmem[...] = acc.astype(o_vmem.dtype)
        co = compat.make_async_copy(
            o_vmem, o_ref.at[pl.ds(owner * n_m * bm + mi * bm, bm),
                             pl.ds(ni * bn, bn)], copy_o)
        co.start(); co.wait()

    # ---- drain: make sure our forward completed before the kernel exits ----
    @pl.when((step < n_dev - 1) & (mi == n_m - 1) & (ni == n_n - 1)
             & (ki == n_k - 1))
    def _drain_send():
        compat.make_async_remote_copy(
            src_ref=a_agg.at[owner], dst_ref=a_agg.at[owner],
            send_sem=send_sem, recv_sem=recv_sem,
            device_id=nbr, device_id_type=compat.LOGICAL_DEVICE_ID,
        ).wait_send()


def ag_gemm(a_shard: jax.Array, b_local: jax.Array, *, axis_name: str,
            n_dev: int, bm: int = 256, bk: int = 512, bn: int = 256,
            reverse: bool = False, out_dtype=None,
            activation: str | None = None, bias: jax.Array | None = None,
            interpret: bool | None = None, collective_id: int = 0) -> jax.Array:
    """C[n*M_sh, N_local] = act(AllGather(A_shard) @ B_local + bias), fused.
    Call inside shard_map; A row-sharded over ``axis_name``, B
    column-sharded.  ``activation``/``bias`` are the tile-epilogue hook
    (None -> plain GEMM; bias: [N_local])."""
    m_sh, k = a_shard.shape
    k2, n = b_local.shape
    assert k == k2
    assert activation is None or activation in EPILOGUE_ACTS, activation
    out_dtype = out_dtype or a_shard.dtype
    bm, bk, bn = min(bm, m_sh), min(bk, k), min(bn, n)
    assert m_sh % bm == 0 and k % bk == 0 and n % bn == 0, (
        f"ag_gemm dims ({m_sh},{k},{n}) vs blocks ({bm},{bk},{bn})")
    grid = (n_dev, m_sh // bm, n // bn, k // bk)
    has_bias = bias is not None
    kernel = functools.partial(
        _ag_gemm_kernel, axis_name=axis_name, n_dev=n_dev, reverse=reverse,
        bm=bm, bk=bk, bn=bn, activation=activation, has_bias=has_bias)
    in_specs = [pl.BlockSpec(memory_space=compat.ANY),
                pl.BlockSpec(memory_space=compat.ANY)]
    operands = [a_shard, b_local]
    scratch = [
        compat.hbm_scratch((n_dev, m_sh, k), a_shard.dtype),   # A_agg (HBM)
        compat.VMEM((bm, bn), jnp.float32),          # accumulator
        compat.VMEM((bm, bk), a_shard.dtype),
        compat.VMEM((bk, bn), b_local.dtype),
        compat.VMEM((bm, bn), out_dtype),
    ]
    if has_bias:
        assert bias.shape == (n,), (bias.shape, n)
        in_specs.append(pl.BlockSpec(memory_space=compat.ANY))
        operands.append(bias.reshape(1, n))
        scratch.append(compat.VMEM((1, bn), bias.dtype))       # bias tile
    scratch += [
        compat.DMA_SEM, compat.DMA_SEM,
        compat.DMA_SEM, compat.DMA_SEM,
        compat.DMA_SEM, compat.DMA_SEM,
    ]
    return compat.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec(memory_space=compat.ANY),
        out_shape=jax.ShapeDtypeStruct((n_dev * m_sh, n), out_dtype),
        scratch_shapes=scratch,
        compiler_params=compat.pallas_compiler_params(collective_id=collective_id),
        interpret=interpret,
    )(*operands)
