"""jit-ready wrappers around the Pallas kernels.

Handles block-size planning (MXU-aligned where shapes allow), interpret-mode
selection (CPU container -> interpret; real TPU -> Mosaic), and padding.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro import compat
from repro.kernels import ag_gemm as _ag
from repro.kernels import gemm_rs as _rs
from repro.kernels import matmul as _mm

# interpret-mode selection lives in the portability layer (one probe for
# every kernel); kept importable under the old private name.
_interpret_default = compat.interpret_default


def pick_block(dim: int, pref: int) -> int:
    """Largest MXU-friendly block <= pref dividing dim (multiples of 128 when
    possible, else largest divisor <= pref)."""
    b = min(pref, dim)
    b -= b % 128 or 0
    while b >= 128:
        if dim % b == 0:
            return b
        b -= 128
    b = min(pref, dim)
    while b > 1:
        if dim % b == 0:
            return b
        b -= 1
    return 1


def plan_blocks(m: int, k: int, n: int,
                bm: int = 256, bk: int = 512, bn: int = 256):
    return pick_block(m, bm), pick_block(k, bk), pick_block(n, bn)


def matmul(a: jax.Array, b: jax.Array, *, interpret: Optional[bool] = None,
           **kw) -> jax.Array:
    """Best non-split GEMM (the paper's GEMM_non-split baseline)."""
    interpret = _interpret_default() if interpret is None else interpret
    bm, bk, bn = plan_blocks(a.shape[0], a.shape[1], b.shape[1],
                             kw.pop("bm", 256), kw.pop("bk", 512),
                             kw.pop("bn", 256))
    return _mm.matmul(a, b, bm=bm, bk=bk, bn=bn, interpret=interpret, **kw)


def _epilogue_by_hand(y: jax.Array, activation: Optional[str],
                      bias: Optional[jax.Array]) -> jax.Array:
    """Single-device fallback for the kernels' fused tile epilogue (same
    fp32 order as the kernels: bias onto the fp32 accumulator, then the
    activation, then the output cast)."""
    from repro.kernels.ag_gemm import EPILOGUE_ACTS
    if activation is None and bias is None:
        return y
    acc = y.astype(jnp.float32)
    if bias is not None:
        acc = acc + bias.astype(jnp.float32)
    if activation is not None:
        acc = EPILOGUE_ACTS[activation](acc)
    return acc.astype(y.dtype)


def ag_matmul_fused(a_shard: jax.Array, b_local: jax.Array, *, axis_name: str,
                    n_dev: Optional[int] = None, reverse: bool = False,
                    activation: Optional[str] = None,
                    bias: Optional[jax.Array] = None,
                    interpret: Optional[bool] = None, **kw) -> jax.Array:
    """Fused AllGather-GEMM (call inside shard_map).  ``activation``/``bias``
    ride the kernel's tile epilogue."""
    interpret = _interpret_default() if interpret is None else interpret
    n_dev = n_dev or compat.axis_size(axis_name)
    if n_dev == 1:
        return _epilogue_by_hand(matmul(a_shard, b_local, interpret=interpret),
                                 activation, bias)
    bm, bk, bn = plan_blocks(a_shard.shape[0], a_shard.shape[1],
                             b_local.shape[1], kw.pop("bm", 256),
                             kw.pop("bk", 512), kw.pop("bn", 256))
    return _ag.ag_gemm(a_shard, b_local, axis_name=axis_name, n_dev=n_dev,
                       bm=bm, bk=bk, bn=bn, reverse=reverse,
                       activation=activation, bias=bias,
                       interpret=interpret, **kw)


def matmul_rs_fused(a_local: jax.Array, b_local: jax.Array, *, axis_name: str,
                    n_dev: Optional[int] = None, reverse: bool = False,
                    activation: Optional[str] = None,
                    bias: Optional[jax.Array] = None,
                    interpret: Optional[bool] = None, **kw) -> jax.Array:
    """Fused GEMM-ReduceScatter (call inside shard_map).  ``activation``/
    ``bias`` apply in the final reduction step's tile emit."""
    interpret = _interpret_default() if interpret is None else interpret
    n_dev = n_dev or compat.axis_size(axis_name)
    if n_dev == 1:
        return _epilogue_by_hand(matmul(a_local, b_local, interpret=interpret),
                                 activation, bias)
    m_sh = a_local.shape[0] // n_dev
    bm, bk, bn = plan_blocks(m_sh, a_local.shape[1], b_local.shape[1],
                             kw.pop("bm", 256), kw.pop("bk", 512),
                             kw.pop("bn", 256))
    return _rs.gemm_rs(a_local, b_local, axis_name=axis_name, n_dev=n_dev,
                       bm=bm, bk=bk, bn=bn, reverse=reverse,
                       activation=activation, bias=bias,
                       interpret=interpret, **kw)
