"""Fused absorbed-MLA decode attention Pallas kernel.

DeepSeek-V3 decode reads the latent KV cache TWICE in the unfused form
(scores pass + context pass) and materializes fp32 scores in HBM.  This
kernel fuses both passes flash-style: one streaming read of the [S, R]
latent cache per step, online softmax, context accumulated in VMEM.

    scores_s = q_eff · c_s + q_rope · kr_s          (per cached position s)
    ctx      = softmax(scores) · C                   [H, R]

Identified as the deepseek_v3_671b/decode_32k §Perf cell's next lever —
the FLUX idea (fuse the neighboring data movement into the compute kernel)
applied beyond GEMM+collective seams.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro import compat

NEG_INF = -1e30


def _mla_kernel(valid_ref,                     # SMEM [B]: per-row valid length
                qe_ref, qr_ref, c_ref, kr_ref,  # VMEM blocks
                o_ref,
                m_ref, l_ref, acc_ref,
                *, bs: int, scale: float):
    bi = pl.program_id(0)
    sj = pl.program_id(1)
    n_s = pl.num_programs(1)

    @pl.when(sj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qe = qe_ref[0].astype(jnp.float32)          # [H, R]
    qr = qr_ref[0].astype(jnp.float32)          # [H, Dr]
    c = c_ref[0].astype(jnp.float32)            # [bs, R]
    kr = kr_ref[0].astype(jnp.float32)          # [bs, Dr]

    s = (jnp.dot(qe, c.T, preferred_element_type=jnp.float32)
         + jnp.dot(qr, kr.T, preferred_element_type=jnp.float32)) * scale
    pos = sj * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(pos < valid_ref[bi], s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)                      # [H, bs]
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, c, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(sj == n_s - 1)
    def _done():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(
            o_ref.dtype)


def mla_decode_attention(q_eff: jax.Array, q_rope: jax.Array,
                         c_cache: jax.Array, kr_cache: jax.Array,
                         valid_len: jax.Array, *, scale: float,
                         bs: int = 512, interpret: bool | None = None) -> jax.Array:
    """q_eff: [B, H, R]; q_rope: [B, H, Dr]; c_cache: [B, S, R];
    kr_cache: [B, S, Dr]; valid_len: [B] int32 per-row valid lengths (row b
    attends to positions < valid_len[b]); a scalar broadcasts to all rows.
    Returns ctx over the latent: [B, H, R] fp32."""
    b, h, r = q_eff.shape
    valid_len = jnp.broadcast_to(
        jnp.asarray(valid_len, jnp.int32).reshape(-1), (b,))
    s = c_cache.shape[1]
    dr = q_rope.shape[-1]
    bs = min(bs, s)
    while s % bs:
        bs //= 2
    grid = (b, s // bs)
    cost = compat.cost_estimate(
        flops=int(2 * b * h * s * (2 * r + dr)),
        bytes_accessed=int(c_cache.nbytes + kr_cache.nbytes
                           + q_eff.nbytes + q_rope.nbytes + b * h * r * 4),
        transcendentals=int(b * h * s),
    )
    out = compat.pallas_call(
        functools.partial(_mla_kernel, bs=bs, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=compat.SMEM),
            pl.BlockSpec((1, h, r), lambda bi, sj: (bi, 0, 0)),
            pl.BlockSpec((1, h, dr), lambda bi, sj: (bi, 0, 0)),
            pl.BlockSpec((1, bs, r), lambda bi, sj: (bi, sj, 0)),
            pl.BlockSpec((1, bs, dr), lambda bi, sj: (bi, sj, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, r), lambda bi, sj: (bi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, r), jnp.float32),
        scratch_shapes=[
            compat.VMEM((h, 1), jnp.float32),
            compat.VMEM((h, 1), jnp.float32),
            compat.VMEM((h, r), jnp.float32),
        ],
        compiler_params=compat.pallas_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        cost_estimate=cost,
        interpret=interpret,
    )(valid_len, q_eff, q_rope, c_cache, kr_cache)
    return out
