"""Causal flash attention Pallas TPU kernel (prefill hot-spot).

Online-softmax blocked attention: grid (batch*q_heads, q_blocks, kv_blocks),
fp32 running (max, denom, acc) in VMEM across the kv axis.  GQA folds the
q-head -> kv-head mapping into the K/V BlockSpec index maps, so grouped
queries read the same kv block without materializing repeats.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro import compat

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref,
                  *, scale: float, causal: bool, bq: int, bkv: int,
                  seq_kv: int, kv_offset: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)
    n_kv = pl.num_programs(2)
    # causal block-skipping: kv blocks strictly above the diagonal do no
    # work (predicated grid steps are skipped by Mosaic on TPU — this is
    # what makes the causal_frac=0.5 cost estimate real, not cosmetic)
    if causal:
        last_j = jnp.minimum(n_kv - 1, (kv_offset + (qi + 1) * bq - 1) // bkv)
    else:
        last_j = n_kv - 1

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(kj <= last_j)
    def _compute():
        q = q_ref[0].astype(jnp.float32)          # [bq, d]
        k = k_ref[0].astype(jnp.float32)          # [bkv, d]
        v = v_ref[0].astype(jnp.float32)          # [bkv, d]

        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

        if causal:
            q_pos = (qi * bq + kv_offset
                     + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0))
            k_pos = kj * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)

        m_prev = m_ref[...]                        # [bq, 1]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                     # [bq, bkv]

        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(kj == last_j)
    def _finish():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(
            o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, scale: float | None = None,
                    bq: int = 512, bkv: int = 512, kv_offset: int = 0,
                    interpret: bool | None = None) -> jax.Array:
    """q: [B, Hq, Sq, D]; k,v: [B, Hkv, Skv, D] with Hq % Hkv == 0.
    ``kv_offset``: absolute position of q[0] relative to k[0] minus (Sq-1)
    offsetting — used when q is a suffix of a longer kv (chunked prefill)."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    bq = min(bq, sq)
    bkv = min(bkv, skv)
    while sq % bq:
        bq //= 2
    while skv % bkv:
        bkv //= 2
    qf = q.reshape(b * hq, sq, d)
    kf = k.reshape(b * hkv, skv, d)
    vf = v.reshape(b * hkv, skv, d)

    def kv_index(h, qi, kj):
        return (h // group, kj, 0)

    grid = (b * hq, sq // bq, skv // bkv)
    # causal halves the useful score/PV work; K/V stream once per q-block row
    causal_frac = 0.5 if causal else 1.0
    cost = compat.cost_estimate(
        flops=int(4 * b * hq * sq * skv * d * causal_frac),
        bytes_accessed=int(q.nbytes
                           + (k.nbytes + v.nbytes) * (sq // bq) * causal_frac
                           + q.nbytes),
        transcendentals=int(b * hq * sq * skv * causal_frac),
    )
    out = compat.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          bq=bq, bkv=bkv, seq_kv=skv, kv_offset=kv_offset),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda h, qi, kj: (h, qi, 0)),
            pl.BlockSpec((1, bkv, d), kv_index),
            pl.BlockSpec((1, bkv, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda h, qi, kj: (h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq, d), q.dtype),
        scratch_shapes=[
            compat.VMEM((bq, 1), jnp.float32),
            compat.VMEM((bq, 1), jnp.float32),
            compat.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=compat.pallas_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        cost_estimate=cost,
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, hq, sq, d)
