"""Effective Communication Time and Overlap Efficiency (paper §2.3).

  ECT       = OverallTime - GEMM_non-split                     (Eq. 1)
  E_overlap = 1 - ECT_overlap / ECT_non-overlap                (Eq. 2)

A perfect overlap method has ECT == 0 and E_overlap == 100 %.  Negative
efficiency means the "overlap" method is slower than the non-overlapping
baseline — the paper uses this to show TransformerEngine regressing.

Two backends:
  * measured  — wall-clock on the current devices (meaningful on real TPU;
    on this CPU container it is structural evidence only).
  * modeled   — roofline model from analytic FLOPs/bytes and the v5e
    constants; used for the §Perf projections in EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

# TPU v5e constants (per task statement)
PEAK_FLOPS_BF16 = 197e12       # FLOP/s per chip
HBM_BW = 819e9                 # B/s per chip
ICI_BW = 50e9                  # B/s per link (per direction)


@dataclasses.dataclass
class ECTResult:
    name: str
    overall_s: float
    gemm_nonsplit_s: float

    @property
    def ect_s(self) -> float:
        return self.overall_s - self.gemm_nonsplit_s

    def overlap_efficiency(self, baseline: "ECTResult") -> float:
        if baseline.ect_s == 0:
            return float("nan")
        return 1.0 - self.ect_s / baseline.ect_s


def time_fn(fn: Callable, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall time of a jitted callable (blocks on outputs)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


# ---------------------------------------------------------------------------
# Modeled (roofline) ECT for the op-level benchmark tables.
# ---------------------------------------------------------------------------
def gemm_efficiency(m: int, m_half: float = 128.0) -> float:
    """MXU efficiency vs the m (rows) dimension: small-m GEMMs underutilize
    the systolic array (the paper's §2.2 third critique of split GEMMs)."""
    return m / (m + m_half)


def model_gemm_time(m: int, n: int, k: int, dtype_bytes: int = 2,
                    mfu: float = 0.7) -> float:
    """Max of compute and memory roofline terms for one GEMM on one chip."""
    flops = 2.0 * m * n * k
    bytes_ = dtype_bytes * (m * k + k * n + m * n)
    eff = mfu * gemm_efficiency(m)
    return max(flops / (PEAK_FLOPS_BF16 * eff), bytes_ / HBM_BW)


def model_collective_time(shard_bytes: float, n_dev: int,
                          kind: str = "ag", links: int = 1) -> float:
    """Ring-collective time on ICI.  ``shard_bytes`` is the PER-DEVICE shard
    (AG input / RS output); a ring moves (n-1) shards over every link, twice
    for all-reduce."""
    mult = 2.0 if kind in ("ar", "allreduce", "a2a") else 1.0
    return mult * (n_dev - 1) * shard_bytes / (ICI_BW * links)


# wire_dtype payload bytes per element (plus one fp32 scale per 128-block;
# the wire block size in repro/core/overlap.py).  The payload factor
# relative to the native dtype is (qbytes + 4/128) / dtype_bytes.
_WIRE_QBYTES = {"int8": 1.0, "fp8_e4m3": 1.0, "int4": 0.5}
_WIRE_SCALE_OVERHEAD = 4.0 / 128.0
_Q8_BYTES_FACTOR = (_WIRE_QBYTES["int8"] + _WIRE_SCALE_OVERHEAD) / 2.0


def wire_bytes_factor(wire_dtype: str, dtype_bytes: int = 2) -> float:
    """On-wire bytes of a quantized payload relative to the native dtype."""
    return (_WIRE_QBYTES[wire_dtype] + _WIRE_SCALE_OVERHEAD) / dtype_bytes


def model_overlap(seam: str, m: int, n: int, k: int, n_dev: int,
                  mode: str, dtype_bytes: int = 2,
                  comm_chunks: int = 0, *, n_weights: int = 1,
                  shared_gather: bool = True, epilogue: bool = False,
                  fuse_epilogue: bool = True,
                  scatter_axis: str = "seq",
                  wire_dtype: Optional[str] = None) -> Dict[str, float]:
    """Analytic OverallTime for one TP seam under each overlap strategy.

    seam="ag": C = AllGather_m(A[m/n,k]) @ B[k,n/n]   (per-device n_local=n/n_dev)
    seam="rs": C = RS_m(A[m,k/n] @ B[k/n,n])
    seam="ar": C = AllReduce(A[m,k/n] @ B[k/n,n])     (decode row-parallel)
    seam="a2a": MoE EP exchange — m routed rows [m, k=d_model] all_to_all'd
                over the EP group, three per-expert GEMMs (w1/w3 up to
                n=expert_ffn, w2 down), all_to_all back; each direction
                moves the (n_dev-1)/n_dev non-local share of the buffer
                (the ISSUE's 2·t·k·dm payload, per direction)
    Modes: the ``overlap.VALID_MODES`` set — ``decomposed_bidir`` rides
    both full-duplex link directions (2 links); the deprecated ``*_q8``
    spellings price as the base mode with ``wire_dtype="int8"``.

    ``wire_dtype`` (None | "int8" | "fp8_e4m3" | "int4") prices the
    quantized forward wire: the payload shrinks by ``wire_bytes_factor``
    (q bytes + fp32 scale per 128-block), and a pack/unpack term charges
    one extra elementwise HBM pass per encode + decode.  Only transports
    that actually quantize are repriced: AG (seq layout), ring RS/AR
    (``decomposed*``; xla's psum collectives can't carry scales), and the
    a2a dispatch direction.  AR+wire rides the two-ring quantized
    all-reduce, which keeps SINGLE-ring volume (no chunked-psum volume
    multiplier).

    FusedOp knobs (matching ``overlap.FusedOp``):
      n_weights      — N weight GEMMs off one gathered activation (AG only;
                       per-weight width n each, so GEMM time scales by N)
      shared_gather  — one ring pass serves all N GEMMs; False rides N full
                       rings (the pre-FusedOp double-gather)
      epilogue       — an elementwise tail exists (bias/act/gate/residual)
      fuse_epilogue  — the tail runs inside the overlapped loop / tile
                       epilogue (register-resident, ~free); False pays a
                       separate read-modify-write HBM pass over the output.
                       AG only: rs/ar epilogues run once on the reduced
                       output either way, so the knob is a no-op there and
                       is not charged.
      scatter_axis   — activation layout of the residual stream
                       ("seq" | "hidden", matching ``FusedOp``).  "hidden"
                       makes the AG side comm-free (input already
                       replicated) and the RS side a full-output AllReduce;
                       the comm volume of an AG+RS layer pair is
                       layout-invariant, but the per-device RESIDENT
                       activation between seams (``act_bytes``) is 1/n_dev
                       under "seq".
    Returns dict(overall, gemm, comm, comm_bytes, act_bytes, epilogue,
    exposed, ...).
    """
    if mode.endswith("_q8"):              # deprecated spelling shim
        base = mode[:-3]
        wire_dtype = wire_dtype or "int8"
    else:
        base = mode
    links = 2 if mode == "decomposed_bidir" else 1
    if base == "decomposed_bidir":
        base = "decomposed"
    seq = scatter_axis == "seq"
    if seam == "rs" and not seq:
        seam = "ar"                       # rs/hidden IS the all-reduce op
    if seam == "ag":
        gemm = model_gemm_time(m, n // n_dev, k, dtype_bytes) * n_weights
        if seq:
            comm_bytes = (m // n_dev) * k * dtype_bytes
        else:
            comm_bytes = 0.0              # hidden: input already replicated
            base = "xla"                  # nothing to overlap with
        rings = 1 if shared_gather else n_weights   # saved ring hops
        comm = model_collective_time(comm_bytes, n_dev, "ag",
                                     links=links) * rings
        out_elems = m * (n // n_dev) * n_weights
        # residual-stream activation this seam reads (resident between seams)
        act_bytes = ((m // n_dev) if seq else m) * k * dtype_bytes
    elif seam == "a2a":
        # MoE EP exchange: the dispatch buffer is [m, k] routed rows; the
        # gated up-projections (w1, w3) and the down-projection (w2) run
        # batched per local expert between the two exchange directions
        gemm = (2.0 * model_gemm_time(m, n, k, dtype_bytes)
                + model_gemm_time(m, k, n, dtype_bytes))
        comm_bytes = m * k * dtype_bytes / n_dev      # per-direction shard
        comm = model_collective_time(comm_bytes, n_dev, "a2a", links=links)
        out_elems = m * k
        act_bytes = m * k * dtype_bytes
    elif seam == "rs":
        gemm = model_gemm_time(m, n, k // n_dev, dtype_bytes)
        comm_bytes = (m // n_dev) * n * dtype_bytes
        comm = model_collective_time(comm_bytes, n_dev, "rs", links=links)
        out_elems = (m // n_dev) * n
        act_bytes = out_elems * dtype_bytes
    else:                                 # ar: full [m, n] output all-reduced
        gemm = model_gemm_time(m, n, k // n_dev, dtype_bytes)
        # ring all-reduce = reduce-scatter + all-gather of the SHARD: each
        # link moves 2*(n-1) shard-sized hops (not 2*(n-1) full tensors —
        # this is exactly the seq layout's RS+AG volume, which is what makes
        # the scatter_axis knob comm-volume-neutral per layer pair).
        comm_bytes = m * n * dtype_bytes / n_dev
        comm = model_collective_time(comm_bytes, n_dev, "ar", links=links)
        out_elems = m * n
        act_bytes = out_elems * dtype_bytes

    # wire_dtype repricing: only the transports that actually carry a
    # quantized payload (docstring) shrink; everything else keeps the fp
    # wire.  pack/unpack charges one elementwise HBM pass per encode +
    # decode (read fp, write q; read q, write fp).
    wired = False
    wire_s = 0.0
    if wire_dtype is not None and comm_bytes:
        wired = (seam == "a2a" or (seam == "ag" and seq and base != "flux")
                 or (seam in ("rs", "ar") and base == "decomposed"))
        if wired:
            factor = wire_bytes_factor(wire_dtype, dtype_bytes)
            wire_s = 2.0 * comm_bytes * (1.0 + factor) / HBM_BW
            comm_bytes *= factor
            comm *= factor

    launch_overhead = 5e-6          # per extra kernel launch (GPU-ish; the
    #                                 paper's "scheduling overheads" §2.2)
    if base == "xla":               # serial: collective fully exposed
        overall = gemm + comm
    elif base == "decomposed":      # medium-grained: per-chunk pipeline with
        # split-GEMM inefficiency (chunk rows = m/chunks) + launch overheads.
        # AR chunks the CONTRACTION dim (m stays whole — the kind="ar"
        # FusedOp path), so it pays no m-split penalty — but every chunk's
        # psum reduces a FULL [m, n] partial, so the chunked transport
        # MOVES chunks x the volume (the price of hiding AR latency; the
        # monolithic xla AR keeps the single-ring volume).
        chunks = max(comm_chunks or n_dev, 1)
        penalty = (1.0 if seam == "ar" else
                   gemm_efficiency(m) / gemm_efficiency(max(m // chunks, 1)))
        g = gemm * penalty + launch_overhead * chunks
        if seam == "rs":
            # the inter-chunk adds serialize the split GEMMs (paper §2.2
            # second critique): only the hops hide, not the GEMM chunks
            overall = g + comm / chunks
        elif seam == "ar" and wired:
            # quantized two-ring all-reduce (_ar_ring_quant): RS + AG of
            # the shard — single-ring volume, pipelined like the rings
            overall = max(g, comm) + min(g, comm) / chunks
        elif seam == "ar":
            comm = comm * chunks
            comm_bytes = comm_bytes * chunks
            overall = max(g, comm) + min(g / chunks, comm / chunks)
        else:
            overall = max(g, comm) + min(g, comm) / chunks
    else:                           # flux: fused kernel, unsplit GEMM speed;
        # one comm step exposed at the head (AG) / tail (RS) — paper §3.3
        step_c = comm / max(n_dev - 1, 1)
        dma_overhead = 1.02         # fused-kernel bookkeeping
        overall = max(gemm * dma_overhead, comm) + step_c
    # epilogue term: fused -> applied on register-resident chunks/tiles
    # inside the overlapped loop (no extra HBM traffic); unfused -> a
    # separate elementwise pass re-reads and re-writes the output.  Only
    # AG has the per-chunk fusion path to buy back.
    epi_s = 0.0
    if seam == "ag" and epilogue and not fuse_epilogue:
        epi_s = 3.0 * out_elems * dtype_bytes / HBM_BW
        overall += epi_s
    overall += wire_s
    exposed = overall - gemm
    # total bytes each device's link(s) move for this seam (the "volume"
    # the scatter_axis sweep compares: layout-invariant per AG+RS pair)
    rings_f = 1 if (seam != "ag" or shared_gather) else n_weights
    moved_bytes = ((2.0 if seam in ("ar", "a2a") else 1.0) * (n_dev - 1)
                   * comm_bytes * rings_f)
    return dict(overall=overall, gemm=gemm, comm=comm,
                comm_bytes=moved_bytes, act_bytes=float(act_bytes),
                epilogue=epi_s, wire=wire_s, exposed=exposed, ect=exposed,
                overlap_eff=1.0 - exposed / comm if comm else 0.0)
