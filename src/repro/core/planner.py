"""Auto-tuner (paper §4.4): selects overlap mode + knobs per (shape, mesh).

FLUX tunes CUTLASS template parameters, pull/push, and communication tile
size per (GEMM shape, dtype, GPU arch, interconnect).  Our knobs:

  - mode          : overlap.VALID_MODES (xla | decomposed | flux |
                    decomposed_bidir)
  - wire_dtype    : wire precision (None | int8 | fp8_e4m3 | int4) — the
                    roofline prices the reduced payload; the ACCURACY-
                    constrained sweep lives in repro.tuning.autotune
  - comm_chunks   : ring sub-chunking (paper §4.3 "communication tile size")
  - ring reverse  : ring direction (paper's pull/push analogue)
  - (bm, bk, bn)  : MXU block shape — never a function of N_TP (paper §4.4:
                    "regular tiling of GEMM in Flux is not bound to the
                    number of tensor parallelism")

Tuning is analytic-first (napkin-math roofline via core.ect.model_overlap);
``measure=True`` delegates to the measured sweep in ``repro.tuning.autotune``
(timed jit runs on the real devices).  The richer subsystem — candidate
spaces over the full mode set, persistent JSON profiles, per-seam PlanSets —
lives in ``repro.tuning``; this module remains the lightweight analytic core.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from repro.core import ect


@dataclasses.dataclass(frozen=True)
class Plan:
    mode: str
    comm_chunks: int
    reverse: bool
    blocks: Tuple[int, int, int]
    predicted_overall_s: float
    predicted_overlap_eff: float
    measured_s: float = 0.0
    source: str = "analytic"         # analytic | measured


_CACHE: Dict[tuple, Plan] = {}


def plan_seam(seam: str, m: int, n: int, k: int, n_dev: int,
              dtype_bytes: int = 2, allow_flux: bool = True,
              measure: bool = False,
              reverse: Optional[bool] = None,
              wire_dtype: Optional[str] = None) -> Plan:
    """Pick the best strategy for one TP seam.

    ``reverse`` pins the ring direction (None lets the tuner choose; the
    analytic roofline is direction-symmetric on a torus so it keeps the
    pinned value or False).  ``wire_dtype`` pins the wire precision the
    roofline prices (None = fp wire; the accuracy-constrained wire SWEEP
    lives in ``repro.tuning.autotune``).  The cache is keyed by ring
    direction AND wire dtype — a plan priced for one wire must never
    answer for another.
    """
    key = (seam, m, n, k, n_dev, dtype_bytes, allow_flux, bool(measure),
           reverse, wire_dtype)
    if key in _CACHE:
        return _CACHE[key]

    if measure:
        from repro.tuning import autotune
        # quantized wires are lossy: never auto-selected here (opt in via
        # autotune.tune_seam(wire_dtypes=...) under an error budget)
        res = autotune.tune_seam(seam, m, n, k, n_dev,
                                 dtype_bytes=dtype_bytes,
                                 allow_flux=allow_flux, allow_q8=False,
                                 measure=True)
        sp = res.plan
        if reverse is not None and sp.reverse != reverse:
            # pinned direction: keep the best candidate matching it
            rows = [r for r in res.table if r["reverse"] == reverse]
            if rows:
                best = min(rows, key=lambda r: r["measured_s"])
                sp = dataclasses.replace(
                    sp, mode=best["mode"], comm_chunks=best["comm_chunks"],
                    reverse=best["reverse"],
                    blocks=(tuple(best["blocks"]) if best["blocks"]
                            else sp.blocks),
                    measured_s=best["measured_s"],
                    predicted_s=best["predicted_s"])
        plan = Plan(mode=sp.mode, comm_chunks=sp.comm_chunks,
                    reverse=sp.reverse, blocks=tuple(sp.blocks),
                    predicted_overall_s=sp.predicted_s,
                    predicted_overlap_eff=0.0,
                    measured_s=sp.measured_s, source="measured")
        _CACHE[key] = plan
        return plan

    candidates = []
    modes = ["xla", "decomposed"] + (["flux"] if allow_flux else [])
    for mode in modes:
        chunk_opts = [0] if mode != "decomposed" else [n_dev, 2 * n_dev, 4 * n_dev]
        wd = wire_dtype if mode != "flux" else None
        for chunks in chunk_opts:
            est = ect.model_overlap(seam, m, n, k, n_dev, mode,
                                    dtype_bytes, comm_chunks=chunks,
                                    wire_dtype=wd)
            candidates.append((est["overall"], mode, chunks, est))

    candidates.sort(key=lambda c: c[0])
    overall, mode, chunks, est = candidates[0]

    from repro.kernels.ops import plan_blocks
    if seam == "ag":
        blocks = plan_blocks(max(m // n_dev, 1), k, max(n // n_dev, 1))
    else:
        blocks = plan_blocks(max(m // n_dev, 1), max(k // n_dev, 1), n)

    plan = Plan(mode=mode, comm_chunks=chunks, reverse=bool(reverse),
                blocks=blocks, predicted_overall_s=overall,
                predicted_overlap_eff=est["overlap_eff"])
    _CACHE[key] = plan
    return plan


def plan_model(d_model: int, d_ff: int, tokens_per_dp: int, n_dev: int,
               allow_flux: bool = True) -> Dict[str, Plan]:
    """Plans for the two MLP seams of the paper's Fig. 2 (and their backward
    interchanges, which reuse the same plans transposed)."""
    return {
        "mlp_ag": plan_seam("ag", tokens_per_dp, d_ff, d_model, n_dev,
                            allow_flux=allow_flux),
        "mlp_rs": plan_seam("rs", tokens_per_dp, d_model, d_ff, n_dev,
                            allow_flux=allow_flux),
    }
