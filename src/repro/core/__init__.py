# FLUX core: fine-grained communication overlap for tensor parallelism.
from repro.core.overlap import (  # noqa: F401
    Epilogue, FusedOp, VALID_KINDS, VALID_MODES,
    ag_matmul, matmul_rs, matmul_ar,            # deprecated thin wrappers
    ag_matmul_ref, matmul_rs_ref,
)
from repro.core import ect, planner  # noqa: F401
