# FLUX core: fine-grained communication overlap for tensor parallelism.
from repro.core.overlap import (  # noqa: F401
    ag_matmul, matmul_rs, matmul_ar, ag_matmul_ref, matmul_rs_ref,
    VALID_MODES,
)
from repro.core import ect, planner  # noqa: F401
