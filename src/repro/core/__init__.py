# FLUX core: fine-grained communication overlap for tensor parallelism.
from repro.core.overlap import (  # noqa: F401
    Epilogue, FusedOp, VALID_KINDS, VALID_MODES, VALID_SCATTER_AXES,
    gather_seq,
    ag_matmul_ref, matmul_rs_ref,
)
from repro.core import ect, planner  # noqa: F401
