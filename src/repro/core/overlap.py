"""FLUX-style communication/computation overlap ops (the paper's core).

The public surface is ONE declarative op object::

    FusedOp(kind="ag"|"rs"|"ar", axis=..., mode=..., comm_chunks=...,
            reverse=..., blocks=..., epilogue=Epilogue(...), n_weights=N,
            fuse_epilogue=True, shared_gather=True, scatter_axis="seq")

    op(x, *weights, bias=..., scale=..., residual=...) -> Array | tuple

``kind`` names the TP seam collective and ``scatter_axis`` the activation
LAYOUT the seam consumes/produces (paper Fig. 2 shapes; Megatron-SP vs
plain TP):

  scatter_axis="seq"  — the residual stream is SEQUENCE-SHARDED between
  seams ([B, S/N, D]); norms/residual/dropout between seams run on 1/N of
  the activation:

    ag   x[B, S/N, D] , w[D, F/N]  ->  (AllGather S) @ w  = y[B, S, F/N]
    rs   y[B, S, F/N] , w[F/N, D]  ->  ReduceScatter_S(y @ w) = [B, S/N, D]

  scatter_axis="hidden" — the residual stream stays REPLICATED ([B, S, D]);
  the only sharding between the paired seams is the hidden dim of the
  intermediate y, so the AG side needs NO collective (x is already full)
  and the RS side degenerates to GEMM + AllReduce:

    ag   x[B, S, D]   , w[D, F/N]  ->  x @ w               = y[B, S, F/N]
    rs   y[B, S, F/N] , w[F/N, D]  ->  AllReduce(y @ w)    = [B, S, D]

  ar   y[B, m, F/N] , w[F/N, D]  ->  AllReduce(y @ w)       = [B, m, D]
       (decode path: m == 1 new token — "ar" IS the hidden layout and
       always coerces scatter_axis="hidden")

  a2a  x[EP, E/EP, C, D], (w1, w3, w2)[E/EP, ...]  ->  out[EP, E/EP, C, D]
       the MoE expert-parallel token exchange: ``x[j]`` holds the
       capacity-bucketed tokens this rank routes to EP rank j's local
       experts; ``out[j] = E_j(x[j])`` returns them expert-processed.
       ``axis`` is the EP axis TUPLE (possibly multi-axis, e.g.
       ``("data", "model")`` under ep_over_dp; rank order is axis-major).
       Dispatch AND combine ride per-shift ppermute chunks interleaved
       with the per-local-expert gated GEMMs (w1/w3/w2 compute on chunk i
       hides the transfer of chunk i+1); ``xla*`` modes run the two
       barrier ``lax.all_to_all`` exchanges instead.  Epilogue must be the
       pure ``gate="pair"`` spec (silu(x@w1) * (x@w3) @ w2).

  Total comm volume per layer is layout-invariant (AG+RS over seq ==
  one AllReduce), but "seq" keeps 1/N of the activation resident between
  seams — the knob the autotuner sweeps via ``SeamPlan.scatter_axis``.

``mode`` selects the transport (``VALID_MODES``): ``xla`` is the
non-overlapping baseline, ``decomposed`` the chunked ``ppermute`` ring
(``comm_chunks`` = the paper's §4.3 communication tile size, ``reverse``
the pull/push ring direction), ``decomposed_bidir`` counter-rotating
half-rings, and ``flux`` the paper's fused Pallas kernels
(``repro/kernels/``).

``wire_dtype`` (orthogonal to ``mode``) quantizes the FORWARD wire:
``None`` ships the native dtype; ``"int8"`` / ``"fp8_e4m3"`` /
``"int4"`` (packed two nibbles per byte) block-quantize every hop's
payload with per-128-block float32 scales (Flash-Communication-style).
Quantization is forward-only — cotangents always ride the
full-precision transports, so grads are bitwise those of the fp wire.
``flux`` kernels have no quantized DMA path (``wire_dtype`` with
``mode="flux"`` raises); ``xla`` reductions (psum / psum_scatter)
cannot carry mixed-scale payloads, so ``rs``/``ar`` ignore
``wire_dtype`` under ``mode="xla"``.  The legacy ``*_q8`` mode
spellings normalize to ``(base mode, wire_dtype="int8")``.

What makes the op *fused* (paper thesis: push neighboring compute into the
communication loop):

  * ``epilogue`` — a small declarative spec (bias add / activation /
    gate-multiply / residual add / dequant scale).  On the ring transports
    the epilogue is applied PER CHUNK inside the overlapped loop
    (``fuse_epilogue=True``); the flux kernels apply bias+activation in the
    tile epilogue.  ``rs``/``ar`` epilogues run on the reduced output
    (residual adds fuse into the seam's tail).
  * ``n_weights`` — multi-weight AllGather ops share ONE ring pass for N
    weight GEMMs (gather once, multiply N times): the gated-FFN w1/w3 pair
    rides a single AllGather instead of two, halving ring traffic
    (``shared_gather=True``; ``False`` restores one ring per weight — a
    plan-visible autotuner knob, like ``fuse_epilogue``).

``custom_vjp`` is defined ONCE at the ``FusedOp`` level: the backward pass
is the *interchanged* overlapped op (AG <-> RS, paper §2.1) applied to the
epilogue-transposed cotangent, and multi-weight ops share one backward ring
too (dX = RS(sum_i dY_i @ W_i^T) in a single ring pass) plus one activation
re-gather for all dW_i.

All ops must be called inside ``compat.shard_map``; ``axis`` names the TP
mesh axis.  Model code never builds a ``FusedOp`` by hand — it resolves one
through the plan registry: ``ctx.op(seam, epilogue=..., n_weights=...,
scatter_axis=...)`` (i.e. ``ctx.plans.resolve(seam).op(...)``), so "what is
fused" AND "which layout the seam emits" are per-seam ``SeamPlan`` knobs
the autotuner sweeps, not call-site constants.

Non-GEMM sequence payloads that must cross a seam (MLA's shared rope key,
cache tails) ride :func:`gather_seq` — the same ppermute ring transport —
so no standalone full-activation ``all_gather`` remains between seams.

(The pre-FusedOp ``ag_matmul`` / ``matmul_rs`` / ``matmul_ar`` wrappers
finished their one-release deprecation window and are gone.)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro import compat

Array = jax.Array

VALID_MODES = ("xla", "decomposed", "flux", "decomposed_bidir")

VALID_KINDS = ("ag", "rs", "ar", "a2a")

# Low-precision wire transports (module docstring): quantize each hop's
# payload with per-128-block scales; forward-only — the backward pass
# always rides the full-precision transports.
VALID_WIRE_DTYPES = (None, "int8", "fp8_e4m3", "int4")

# The pre-wire_dtype spellings ("xla" / "decomposed" + the q8 suffix) keep
# loading for one deprecation window: they normalize to the base mode with
# wire_dtype="int8".  Built by concatenation so the deprecated-q8-mode lint
# rule has no literal to flag here.
_DEPRECATED_Q8_SUFFIX = "_q8"
_DEPRECATED_Q8_MODES = {m + _DEPRECATED_Q8_SUFFIX: m
                        for m in ("xla", "decomposed")}


def normalize_mode(mode: str, wire_dtype: Optional[str] = None):
    """``(mode, wire_dtype)`` with deprecated ``*_q8`` spellings mapped to
    the base mode + ``wire_dtype="int8"`` (an explicit wire_dtype wins)."""
    base = _DEPRECATED_Q8_MODES.get(mode)
    if base is not None:
        return base, (wire_dtype if wire_dtype is not None else "int8")
    return mode, wire_dtype

# Every collective this module emits is wrapped in a ``jax.named_scope``
# whose name starts with this prefix.  The scope lands on the traced eqn's
# ``source_info.name_stack`` (surviving jvp/transpose wrapping, scan bodies
# and custom_vjp backward rules), which is how ``repro.analysis.seamcheck``
# attributes ring collectives to their owning seam: any full-activation
# collective WITHOUT a seam scope in a traced step is a census violation.
SEAM_SCOPE_PREFIX = "seam"


def _seam_scope(name: str):
    """Provenance marker for one seam-owned collective transport."""
    return jax.named_scope(f"{SEAM_SCOPE_PREFIX}_{name}")

# activation layout a seam consumes/produces (module docstring):
#   "seq"    — sequence-sharded residual stream (Megatron-SP)
#   "hidden" — replicated residual stream; only the intermediate's hidden
#              dim is sharded (classic TP; the decode layout)
VALID_SCATTER_AXES = ("seq", "hidden")


def _axis_size(axis: Optional[str]) -> int:
    if axis is None:
        return 1
    return compat.axis_size(axis)


# ---------------------------------------------------------------------------
# Epilogue: the declarative "what is fused after the GEMM" spec
# ---------------------------------------------------------------------------
def _sqrelu(v):
    return jnp.square(jax.nn.relu(v))


ACTIVATIONS = {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
               "relu": jax.nn.relu, "sqrelu": _sqrelu}


@dataclasses.dataclass(frozen=True)
class Epilogue:
    """Elementwise tail fused into a ``FusedOp``.

    Application order (z starts as the first GEMM/collective output)::

        z = z * scale          (scale=True;   per-column dequant multiply)
        z = z + bias           (bias=True;    broadcast over rows)
        gate == "pair" : z = act(z) * y2     (second weight's output)
        gate == "split": z = act(a) * b      (a, b = split(z, 2, axis=-1))
        else           : z = act(z)          (activation set)
        z = z + residual       (residual=True)

    Flags declare the SHAPE of the fusion (static, hashable — part of the
    op's trace key); the operand ARRAYS (bias / scale / residual) are passed
    at call time and participate in autodiff.
    """
    bias: bool = False
    activation: Optional[str] = None          # ACTIVATIONS key
    gate: Optional[str] = None                # None | "pair" | "split"
    residual: bool = False
    scale: bool = False

    def __post_init__(self):
        if self.activation is not None and self.activation not in ACTIVATIONS:
            raise ValueError(f"unknown activation {self.activation!r}")
        if self.gate not in (None, "pair", "split"):
            raise ValueError(f"unknown gate {self.gate!r}")

    @property
    def is_identity(self) -> bool:
        return not (self.bias or self.activation or self.gate
                    or self.residual or self.scale)

    def apply(self, ys: Sequence[Array], bias=None, scale=None,
              residual=None) -> Array:
        z = ys[0]
        if self.scale:
            z = z * scale
        if self.bias:
            z = z + bias
        act = ACTIVATIONS[self.activation] if self.activation else (lambda v: v)
        if self.gate == "pair":
            z = act(z) * ys[1]
        elif self.gate == "split":
            a, b = jnp.split(z, 2, axis=-1)
            z = act(a) * b
        elif self.activation:
            z = act(z)
        if self.residual:
            z = z + residual
        return z


# ---------------------------------------------------------------------------
# Ring transports, generalized over an arbitrary per-chunk compute
# ---------------------------------------------------------------------------
def _ring_perm(axis: str, reverse: bool = False):
    n = compat.axis_size(axis)
    if reverse:
        return [(i, (i - 1) % n) for i in range(n)]
    return [(i, (i + 1) % n) for i in range(n)]


def _ring_gather(x: Array, axis: str, reverse: bool = False) -> Array:
    """Sequence AllGather implemented as a ppermute ring (shard-exact, same
    assembly order as ``lax.all_gather(tiled=True)``): the transport every
    seam-adjacent gather rides so no standalone collective appears between
    seams.  Gathers along dim -2."""
    n = compat.axis_size(axis)
    me = lax.axis_index(axis)
    s_shard = x.shape[-2]
    out = jnp.zeros((*x.shape[:-2], s_shard * n, x.shape[-1]), x.dtype)
    buf = x
    with _seam_scope("ring_gather"):
        for step in range(n):
            owner = (me + step) % n if reverse else (me - step) % n
            out = lax.dynamic_update_slice_in_dim(out, buf, owner * s_shard,
                                                  axis=out.ndim - 2)
            if step < n - 1:
                buf = lax.ppermute(buf, axis, _ring_perm(axis, reverse))
    return out


def gather_seq(x: Array, axis: Optional[str], mode: str = "decomposed",
               reverse: bool = False) -> Array:
    """Gather a sequence-sharded non-GEMM payload (rope keys, cache tails,
    boundary rows) to full length along dim -2.

    ``mode`` follows the seam plan's transport family: the ring modes ride
    ppermute hops (census-clean: no standalone ``all_gather`` in the
    jaxpr), ``xla*`` uses the monolithic collective.  Values are identical
    either way."""
    if axis is None or _axis_size(axis) == 1:
        return x
    if mode.startswith("decomposed"):
        return _ring_gather(x, axis, reverse)
    with _seam_scope("gather_seq"):
        return lax.all_gather(x, axis, axis=x.ndim - 2, tiled=True)


def scatter_seq_sum(x: Array, axis: Optional[str], mode: str = "decomposed",
                    reverse: bool = False) -> Array:
    """ReduceScatter along dim -2 of a per-rank full-sequence partial (the
    embedding seam's combining collective under the sequence-sharded
    layout): out[rows of my shard] = sum over ranks of x[those rows].

    The ring modes ride ppermute hops (same accumulation order as
    ``_rs_ring``), so BOTH directions of the embed seam stay census-clean:
    the autodiff transpose of the ppermute/slice chain is a ppermute ring
    gather, not a monolithic ``all_gather``."""
    if axis is None or _axis_size(axis) == 1:
        return x
    if not mode.startswith("decomposed"):
        with _seam_scope("scatter_seq"):
            return lax.psum_scatter(x, axis, scatter_dimension=x.ndim - 2,
                                    tiled=True)
    n = compat.axis_size(axis)
    me = lax.axis_index(axis)
    s_shard = x.shape[-2] // n

    def owner_at(s):
        return ((me - (n - 1 - s)) % n if reverse
                else (me + n - 1 - s) % n)

    def part(s):
        return lax.dynamic_slice_in_dim(x, owner_at(s) * s_shard, s_shard,
                                        axis=x.ndim - 2)

    with _seam_scope("scatter_seq"):
        acc = part(0)
        for s in range(1, n):
            acc = lax.ppermute(acc, axis, _ring_perm(axis, reverse))
            acc = acc + part(s)
    return acc


def _sub_chunks(s_shard: int, n: int, comm_chunks: int) -> int:
    sub = max(1, comm_chunks // n) if comm_chunks else 1
    sub = min(sub, s_shard)
    while s_shard % sub:
        sub -= 1
    return sub


def _out_buffers(x: Array, seq_len: int, chunk_len: int,
                 chunk_fn: Callable) -> list:
    """Zero output buffers sized from the chunk_fn's abstract output."""
    probe = jax.ShapeDtypeStruct((*x.shape[:-2], chunk_len, x.shape[-1]),
                                 x.dtype)
    shapes = jax.eval_shape(chunk_fn, probe)
    return [jnp.zeros((*x.shape[:-2], seq_len, sh.shape[-1]), sh.dtype)
            for sh in shapes]


def _ag_ring(x: Array, axis: str, comm_chunks: int, reverse: bool,
             chunk_fn: Callable, encode=None, decode=None) -> Tuple[Array, ...]:
    """Chunked AllGather ring of shard hops: each landed chunk is consumed by
    ``chunk_fn`` ([..., L, D] -> tuple of [..., L, W_b]) as soon as it
    arrives, so the chunk GEMMs (and any fused epilogue) overlap with the
    hops.  ``encode``/``decode`` optionally transform the ring payload
    (int8 block quantization); the GEMM always sees the decoded chunk.
    Ring order starts at the LOCAL shard (paper §4.3)."""
    n = compat.axis_size(axis)
    me = lax.axis_index(axis)
    s_shard = x.shape[-2]
    sub = _sub_chunks(s_shard, n, comm_chunks)
    sub_len = s_shard // sub

    payloads = encode(x) if encode else (x,)
    pieces = [jnp.split(p, sub, axis=-2) if sub > 1 else [p]
              for p in payloads]
    bufs = [tuple(pieces[pi][j] for pi in range(len(payloads)))
            for j in range(sub)]

    ys = _out_buffers(x, s_shard * n, sub_len, chunk_fn)
    with _seam_scope("ag_ring"):
        for step in range(n):
            # step 0 consumes the LOCAL shard ("local signals preset to
            # true"); later steps consume the shard arriving from the
            # neighbor.
            owner = (me + step) % n if reverse else (me - step) % n
            for j, buf in enumerate(bufs):
                piece = decode(buf) if decode else buf[0]
                chunks = chunk_fn(piece)
                start = owner * s_shard + j * sub_len
                for b, ch in enumerate(chunks):
                    ys[b] = lax.dynamic_update_slice_in_dim(
                        ys[b], ch, start, axis=ys[b].ndim - 2)
            if step < n - 1:
                bufs = [tuple(lax.ppermute(p, axis, _ring_perm(axis, reverse))
                              for p in buf) for buf in bufs]
    return tuple(ys)


def _ag_bidir(x: Array, axis: str, comm_chunks: int, chunk_fn: Callable,
              encode=None, decode=None) -> Tuple[Array, ...]:
    """Counter-rotating half-rings (beyond-paper): ICI torus links are
    full-duplex PER DIRECTION, so two opposite half-volume rings halve the
    per-link traffic (~2x on ring-bound seams).  ``encode``/``decode``
    transform each half-ring's payload like ``_ag_ring``'s hooks."""
    n = compat.axis_size(axis)
    me = lax.axis_index(axis)
    s_shard = x.shape[-2]
    half = s_shard // 2
    if half == 0 or s_shard % 2:
        return _ag_ring(x, axis, comm_chunks, False, chunk_fn,
                        encode=encode, decode=decode)
    lo, hi = jnp.split(x, 2, axis=-2)          # top rides right, bottom left

    ys = _out_buffers(x, s_shard * n, half, chunk_fn)
    buf_r = encode(lo) if encode else (lo,)
    buf_l = encode(hi) if encode else (hi,)
    with _seam_scope("ag_bidir"):
        for step in range(n):
            owner_r = (me - step) % n
            owner_l = (me + step) % n
            cr = chunk_fn(decode(buf_r) if decode else buf_r[0])
            cl = chunk_fn(decode(buf_l) if decode else buf_l[0])
            for b in range(len(ys)):
                ys[b] = lax.dynamic_update_slice_in_dim(
                    ys[b], cr[b], owner_r * s_shard, axis=ys[b].ndim - 2)
                ys[b] = lax.dynamic_update_slice_in_dim(
                    ys[b], cl[b], owner_l * s_shard + half,
                    axis=ys[b].ndim - 2)
            if step < n - 1:
                buf_r = tuple(lax.ppermute(p, axis, _ring_perm(axis))
                              for p in buf_r)
                buf_l = tuple(lax.ppermute(p, axis,
                                           _ring_perm(axis, reverse=True))
                              for p in buf_l)
    return tuple(ys)


# ---------------------------------------------------------------------------
# wire_dtype: block-quantized wire codecs (beyond-paper knob)
# ---------------------------------------------------------------------------
_WIRE_BLOCK = 128
_Q8_BLOCK = _WIRE_BLOCK

# symmetric range of each wire dtype (the block scale is amax / qmax)
_WIRE_QMAX = {"int8": 127.0, "fp8_e4m3": 448.0, "int4": 7.0}


def wire_encode(x: Array, wire_dtype: str) -> Tuple[Array, Array]:
    """``(q, scale)`` payload pair for one wire hop: per-128-block absmax
    scales (float32), values quantized to the wire dtype.  ``int4`` packs
    two sign-extended nibbles per uint8 when the feature dim is even
    (decode detects packing by dtype).  All-zero blocks clamp the scale
    away from zero so they decode to exact zeros, never NaN."""
    qmax = _WIRE_QMAX[wire_dtype]
    d = x.shape[-1]
    blocks = d // _WIRE_BLOCK if d % _WIRE_BLOCK == 0 else 1
    xb = x.reshape(*x.shape[:-1], blocks, d // blocks).astype(jnp.float32)
    amax = jnp.max(jnp.abs(xb), axis=-1, keepdims=True)
    scale = jnp.maximum(amax / qmax, jnp.finfo(jnp.float32).tiny)
    v = xb / scale
    if wire_dtype == "int8":
        q = jnp.clip(jnp.round(v), -127, 127).astype(jnp.int8)
        q = q.reshape(*x.shape)
    elif wire_dtype == "fp8_e4m3":
        q = v.astype(jnp.float8_e4m3fn).reshape(*x.shape)
    elif wire_dtype == "int4":
        q4 = jnp.clip(jnp.round(v), -7, 7).astype(jnp.int8).reshape(*x.shape)
        q = _int4_pack(q4)
    else:
        raise ValueError(f"invalid wire_dtype {wire_dtype!r}")
    return q, scale[..., 0].astype(jnp.float32)


def wire_decode(payloads: Sequence[Array], wire_dtype: str, dtype) -> Array:
    """Inverse of :func:`wire_encode` on a ``(q, scale)`` payload pair."""
    q, scale = payloads
    if wire_dtype == "int4" and q.dtype == jnp.uint8:
        q = _int4_unpack(q)
    d = q.shape[-1]
    blocks = scale.shape[-1]
    xb = q.astype(jnp.float32).reshape(*q.shape[:-1], blocks, d // blocks)
    return (xb * scale[..., None]).reshape(*q.shape).astype(dtype)


def _int4_pack(q4: Array) -> Array:
    """Two int4 values per uint8 (even positions low nibble); odd feature
    dims stay int8 — a byte each, still half of bf16."""
    if q4.shape[-1] % 2:
        return q4
    lo = q4[..., 0::2].astype(jnp.int32)
    hi = q4[..., 1::2].astype(jnp.int32)
    return ((lo & 0xF) | ((hi & 0xF) << 4)).astype(jnp.uint8)


def _int4_unpack(q: Array) -> Array:
    b = q.astype(jnp.int32)
    lo = ((b & 0xF) ^ 8) - 8            # sign-extend the nibble
    hi = ((b >> 4) ^ 8) - 8
    return jnp.stack([lo, hi], axis=-1).reshape(
        *q.shape[:-1], q.shape[-1] * 2).astype(jnp.int8)


def _q8_encode(x: Array) -> Tuple[Array, Array]:
    return wire_encode(x, "int8")


def _q8_decode(q: Array, scale: Array, dtype) -> Array:
    return wire_decode((q, scale), "int8", dtype)


def _wire_hop(acc: Array, axis: str, perm, wire_dtype: Optional[str]) -> Array:
    """One ppermute ring hop, optionally quantized on the wire (encode ->
    hop the payload pair -> decode; lossy per hop by design)."""
    if not wire_dtype:
        return lax.ppermute(acc, axis, perm)
    # nested "wire" scope: the census identifies quantized transports by
    # it (a quantized AR ring legitimately ppermutes under the replicated
    # layout — psum cannot carry the per-block scales)
    with _seam_scope("wire"):
        payloads = wire_encode(acc, wire_dtype)
        payloads = tuple(lax.ppermute(p, axis, perm) for p in payloads)
        return wire_decode(payloads, wire_dtype, acc.dtype)


def _gather_full(x: Array, axis: str, wire_dtype: Optional[str]) -> Array:
    """Monolithic (xla-mode) sequence gather, optionally wire-quantized."""
    with _seam_scope("ag_full"):
        if not wire_dtype:
            return lax.all_gather(x, axis, axis=x.ndim - 2, tiled=True)
        q, sc = wire_encode(x, wire_dtype)
        qf = lax.all_gather(q, axis, axis=q.ndim - 2, tiled=True)
        sf = lax.all_gather(sc, axis, axis=sc.ndim - 2, tiled=True)
        return wire_decode((qf, sf), wire_dtype, x.dtype)


# ---------------------------------------------------------------------------
# GEMM-ReduceScatter transports (single ring pass even for multiple pairs)
# ---------------------------------------------------------------------------
def _rs_partial(ys: Tuple[Array, ...], ws: Tuple[Array, ...], owner,
                s_shard: int, length: Optional[int] = None,
                offset: int = 0):
    """sum_i ys_i[owner's seq rows] @ ws_i — the per-owner partial of the
    multi-pair reduce-scatter (one ring carries the SUMMED partial)."""
    length = s_shard if length is None else length
    acc = None
    for y, w in zip(ys, ws):
        ysl = lax.dynamic_slice_in_dim(y, owner * s_shard + offset, length,
                                       axis=y.ndim - 2)
        p = jnp.einsum("...sf,fd->...sd", ysl, w)
        acc = p if acc is None else acc + p
    return acc


def _rs_ring(ys: Tuple[Array, ...], ws: Tuple[Array, ...], axis: str,
             comm_chunks: int, reverse: bool,
             wire_dtype: Optional[str] = None) -> Array:
    """GEMM-ReduceScatter ring: at step s each device computes ONLY the
    output chunk the ring needs next, adds the partial arriving from its
    neighbor, and forwards (paper Fig. 3, medium-grained).  ``wire_dtype``
    quantizes the travelling ACCUMULATOR before each hop (requantized per
    hop — the sum itself stays float)."""
    n = compat.axis_size(axis)
    me = lax.axis_index(axis)
    seq = ys[0].shape[-2]
    assert seq % n == 0, f"seq {seq} not divisible by TP {n}"
    s_shard = seq // n

    def owner_at(s):
        return ((me - (n - 1 - s)) % n if reverse
                else (me + n - 1 - s) % n)

    with _seam_scope("rs_ring"):
        acc = _rs_partial(ys, ws, owner_at(0), s_shard)
        for s in range(1, n):
            acc = _wire_hop(acc, axis, _ring_perm(axis, reverse), wire_dtype)
            acc = acc + _rs_partial(ys, ws, owner_at(s), s_shard)
    return acc


def _rs_bidir(ys: Tuple[Array, ...], ws: Tuple[Array, ...], axis: str,
              comm_chunks: int, wire_dtype: Optional[str] = None) -> Array:
    n = compat.axis_size(axis)
    me = lax.axis_index(axis)
    seq = ys[0].shape[-2]
    s_shard = seq // n
    if s_shard % 2:
        return _rs_ring(ys, ws, axis, comm_chunks, False, wire_dtype)
    half = s_shard // 2

    def partial(owner, top: bool):
        return _rs_partial(ys, ws, owner, s_shard, half,
                           0 if top else half)

    # top halves accumulate rightward, bottom halves leftward
    with _seam_scope("rs_bidir"):
        acc_r = partial((me + n - 1) % n, True)
        acc_l = partial((me - (n - 1)) % n, False)
        for s_ in range(1, n):
            acc_r = _wire_hop(acc_r, axis, _ring_perm(axis), wire_dtype)
            acc_l = _wire_hop(acc_l, axis, _ring_perm(axis, reverse=True),
                              wire_dtype)
            acc_r = acc_r + partial((me + n - 1 - s_) % n, True)
            acc_l = acc_l + partial((me - (n - 1) + s_) % n, False)
    return jnp.concatenate([acc_r, acc_l], axis=acc_r.ndim - 2)


def _rs_core(ys: Tuple[Array, ...], ws: Tuple[Array, ...], axis, mode: str,
             comm_chunks: int, reverse: bool, blocks,
             wire_dtype: Optional[str] = None) -> Array:
    """sum_i ReduceScatter_seq(ys_i @ ws_i) with ONE collective pass.

    ``wire_dtype`` quantizes the ring modes' travelling partials;
    ``xla``'s monolithic ``psum_scatter`` cannot carry mixed-scale
    payloads, so it ignores the knob (documented baseline)."""
    mode, wire_dtype = normalize_mode(mode, wire_dtype)
    if axis is None or _axis_size(axis) == 1:
        acc = None
        for y, w in zip(ys, ws):
            p = jnp.einsum("...sf,fd->...sd", y, w)
            acc = p if acc is None else acc + p
        return acc
    if mode == "flux" and not _flux_available():
        mode = "decomposed"
    if mode == "xla":
        acc = None
        for y, w in zip(ys, ws):
            p = jnp.einsum("...sf,fd->...sd", y, w)
            acc = p if acc is None else acc + p
        with _seam_scope("rs_scatter"):
            return lax.psum_scatter(acc, axis,
                                    scatter_dimension=acc.ndim - 2,
                                    tiled=True)
    if mode == "flux":
        # multi-pair RS == single RS of the concatenated operands (the
        # contraction dim stacks): still one fused kernel / one ring pass.
        y = ys[0] if len(ys) == 1 else jnp.concatenate(ys, axis=-1)
        w = ws[0] if len(ws) == 1 else jnp.concatenate(ws, axis=0)
        return _rs_flux(y, w, axis, reverse, blocks)
    if mode == "decomposed_bidir":
        return _rs_bidir(ys, ws, axis, comm_chunks, wire_dtype)
    return _rs_ring(ys, ws, axis, comm_chunks, reverse, wire_dtype)


def _ar_ring_quant(p: Array, axis: str, wire_dtype: str) -> Array:
    """Ring all-reduce of a per-rank FULL partial with quantized hops
    (Flash-Communication style): ring reduce-scatter over last-dim shards
    (the travelling accumulator is requantized per hop; each rank's OWN
    partial joins in full precision), then a ring all-gather of the
    reduced shards (quantized once each; the locally-reduced shard stays
    float).  ``lax.psum`` cannot carry mixed-scale payloads, which is why
    the quantized all-reduce is spelled as these two rings."""
    n = compat.axis_size(axis)
    me = lax.axis_index(axis)
    d = p.shape[-1]
    shard = d // n

    def owner_at(s):
        return (me + n - 1 - s) % n

    def part(s):
        return lax.dynamic_slice_in_dim(p, owner_at(s) * shard, shard,
                                        axis=p.ndim - 1)

    acc = part(0)
    for s in range(1, n):
        acc = _wire_hop(acc, axis, _ring_perm(axis), wire_dtype)
        acc = acc + part(s)
    # acc = the fully-reduced shard this rank owns; gather the rest
    out = jnp.zeros_like(p)
    out = lax.dynamic_update_slice_in_dim(out, acc.astype(p.dtype),
                                          me * shard, axis=p.ndim - 1)
    with _seam_scope("wire"):
        payloads = wire_encode(acc, wire_dtype)
        for step in range(1, n):
            payloads = tuple(lax.ppermute(pl, axis, _ring_perm(axis))
                             for pl in payloads)
            owner = (me - step) % n
            chunk = wire_decode(payloads, wire_dtype, p.dtype)
            out = lax.dynamic_update_slice_in_dim(out, chunk, owner * shard,
                                                  axis=p.ndim - 1)
    return out


def _ar_core(y: Array, w: Array, axis, mode: str, comm_chunks: int,
             wire_dtype: Optional[str] = None) -> Array:
    """AllReduce(y @ w) — the decode-path row-parallel GEMM, chunked along
    the contraction dim so each partial psum overlaps with the next chunk's
    GEMM (``decomposed*``); xla/flux use one monolithic psum (one-token
    GEMMs are latency- not bandwidth-bound).  ``wire_dtype`` under the
    decomposed modes rides the quantized two-ring all-reduce
    (``_ar_ring_quant``); psum-based paths ignore it."""
    mode, wire_dtype = normalize_mode(mode, wire_dtype)
    if axis is None or _axis_size(axis) == 1:
        return jnp.einsum("...mf,fd->...md", y, w)
    if mode.startswith("decomposed"):
        n = compat.axis_size(axis)
        if wire_dtype and w.shape[-1] % n == 0:
            with _seam_scope("ar"):
                return _ar_ring_quant(jnp.einsum("...mf,fd->...md", y, w),
                                      axis, wire_dtype)
        k = y.shape[-1]
        chunks = comm_chunks if comm_chunks else n
        chunks = max(1, min(chunks, k))
        while k % chunks:
            chunks -= 1
        ck = k // chunks
        parts = []
        with _seam_scope("ar"):
            for c in range(chunks):
                yc = lax.dynamic_slice_in_dim(y, c * ck, ck, axis=y.ndim - 1)
                wc = lax.dynamic_slice_in_dim(w, c * ck, ck, axis=0)
                parts.append(lax.psum(jnp.einsum("...mf,fd->...md", yc, wc),
                                      axis))
        out = parts[0]
        for p in parts[1:]:
            out = out + p
        return out
    with _seam_scope("ar"):
        return lax.psum(jnp.einsum("...mf,fd->...md", y, w), axis)


# ---------------------------------------------------------------------------
# kind="a2a": the MoE expert-parallel token exchange (dispatch + combine)
# ---------------------------------------------------------------------------
def _ep_group_size(axes: Sequence[str]) -> int:
    n = 1
    for a in axes:
        n *= compat.axis_size(a)
    return n


def a2a_exchange(buf: Array, axes: Sequence[str]) -> Array:
    """Barrier all-to-all of ``buf[EP, ...]`` over an EP group spanning one
    or more mesh axes (rank order axis-major, matching the router's
    ``ep_rank = ep_rank * size(a) + axis_index(a)`` computation).  The
    exchange is an involution — and its own transpose — so the same call
    serves dispatch, combine, and both backward directions.  Callers wrap
    it in a ``seam_*`` scope (census provenance)."""
    if len(axes) == 1:
        return lax.all_to_all(buf, axes[0], split_axis=0, concat_axis=0,
                              tiled=True)
    sizes = [compat.axis_size(a) for a in axes]
    shaped = buf.reshape(*sizes, *buf.shape[1:])
    for i, a in enumerate(axes):
        shaped = lax.all_to_all(shaped, a, split_axis=i, concat_axis=i,
                                tiled=True)
    return shaped.reshape(buf.shape)


def _expert_fn(epi: Epilogue, b: Array, w1: Array, w3: Array,
               w2: Array) -> Array:
    """Per-local-expert gated FFN on one (sub-)chunk of the received
    dispatch buffer: b[..., e_loc, c, dm] @ (w1, w3)[e_loc, dm, df] ->
    pair-gate -> @ w2[e_loc, df, dm]."""
    a1 = jnp.einsum("...ecd,edf->...ecf", b, w1)
    a3 = jnp.einsum("...ecd,edf->...ecf", b, w3)
    h = epi.apply([a1, a3])
    return jnp.einsum("...ecf,efd->...ecd", h, w2)


def _ep_shifts(op: FusedOp, axes, sizes):
    """Per-axis shift vectors enumerating every EP partner exactly once
    (mixed-radix digits of the step index; ``reverse`` flips the ring
    direction).  For each shift vector the send map ``idx -> idx + sh``
    (per-axis modular) is a bijection realized by one ppermute per
    involved axis."""
    strides = []
    for k in range(len(sizes)):
        st = 1
        for nj in sizes[k + 1:]:
            st *= nj
        strides.append(st)
    ep = _ep_group_size(axes)
    out = []
    for s in range(ep):
        shs = [(s // st) % nk for st, nk in zip(strides, sizes)]
        if op.reverse:
            shs = [(nk - sh) % nk for sh, nk in zip(shs, sizes)]
        out.append(shs)
    return out, strides


def _ep_flat(idx, shs, sizes, strides, sign: int):
    """Axis-major flat EP rank of (idx +/- shs) per-axis modular."""
    flat = 0
    for ix, sh, nk, st in zip(idx, shs, sizes, strides):
        flat = flat + ((ix + sign * sh) % nk) * st
    return flat


def _a2a_ring(op: FusedOp, x, ws, epi: Epilogue):
    """Over-decomposed EP exchange: per (shift, sub-chunk) stage, the chunk
    destined for the shifted partner hops forward on ppermutes, the local
    experts' gated GEMMs consume what arrived, and the result hops back on
    the inverse ppermutes — chunk i's GEMM is dataflow-independent of chunk
    i+1's hops, so the scheduler overlaps them (paper §4.3, applied to the
    dispatch AND combine directions at once).  Returns ``(out, buf)`` with
    ``buf[i] = x_i[me]`` (the assembled received buffer, the backward's
    saved residual) identical to the barrier path's."""
    axes = op.axis
    sizes = [compat.axis_size(a) for a in axes]
    idx = [lax.axis_index(a) for a in axes]
    shifts, strides = _ep_shifts(op, axes, sizes)
    ep = len(shifts)
    e_loc, cap, dm = x.shape[1:]
    sub = _sub_chunks(cap, ep, op.comm_chunks)
    sub_len = cap // sub

    out = jnp.zeros_like(x)
    buf = jnp.zeros_like(x)
    with _seam_scope("moe_a2a_ring"):
        for shs in shifts:
            dst = _ep_flat(idx, shs, sizes, strides, +1)
            src = _ep_flat(idx, shs, sizes, strides, -1)
            fwd = [(a, [(i, (i + sh) % nk) for i in range(nk)])
                   for a, sh, nk in zip(axes, shs, sizes) if sh]
            inv = [(a, [(i, (i - sh) % nk) for i in range(nk)])
                   for a, sh, nk in zip(axes, shs, sizes) if sh]
            for j in range(sub):
                off = j * sub_len
                chunk = lax.dynamic_slice(x, (dst, 0, off, 0),
                                          (1, e_loc, sub_len, dm))
                if op.wire_dtype:
                    # dispatch tokens quantized on the wire; the expert
                    # GEMM (and the saved buffer) see the decoded chunk.
                    # The combine direction stays full precision — the
                    # expert outputs feed the router-weighted sum.
                    payloads = wire_encode(chunk, op.wire_dtype)
                    for a, perm in fwd:
                        payloads = tuple(lax.ppermute(p, a, perm)
                                         for p in payloads)
                    chunk = wire_decode(payloads, op.wire_dtype, x.dtype)
                else:
                    for a, perm in fwd:
                        chunk = lax.ppermute(chunk, a, perm)
                # arrived = x_src[me]: the partner's tokens for MY experts
                buf = lax.dynamic_update_slice(buf, chunk, (src, 0, off, 0))
                y = _expert_fn(epi, chunk, *ws)
                for a, perm in reversed(inv):
                    y = lax.ppermute(y, a, perm)
                # received = E_dst(x_me[dst]): my tokens, expert-processed
                out = lax.dynamic_update_slice(out, y.astype(out.dtype),
                                               (dst, 0, off, 0))
    return out, buf


def _a2a_impl(op: FusedOp, x, ws):
    """(out, received_buf) of the EP exchange.  ``xla*`` modes run the two
    barrier all_to_alls around the batched expert GEMMs; every other mode
    rides the interleaved ppermute pipeline."""
    epi = op.epilogue
    axes = op.axis
    if not axes or _ep_group_size(axes) == 1:
        return _expert_fn(epi, x, *ws), x
    if op.mode == "xla":
        with _seam_scope("moe_a2a_dispatch"):
            if op.wire_dtype:
                q, sc = wire_encode(x, op.wire_dtype)
                qf = a2a_exchange(q, axes)
                sf = a2a_exchange(sc, axes)
                buf = wire_decode((qf, sf), op.wire_dtype, x.dtype)
            else:
                buf = a2a_exchange(x, axes)
        y = _expert_fn(epi, buf, *ws)
        with _seam_scope("moe_a2a_combine"):
            out = a2a_exchange(y, axes)
        return out.astype(x.dtype), buf
    return _a2a_ring(op, x, ws, epi)


def _a2a_bwd_ring(op: FusedOp, x, ws, buf, g, epi: Epilogue):
    """Backward rides the interchanged op: the combine cotangent chunk hops
    along the DISPATCH perms (pairing it with the saved received buffer for
    the per-chunk expert vjp), and the input cotangent returns on the
    inverse hops.  dW accumulates locally — each rank's experts are
    rank-exclusive, so the sum over arriving chunks IS the full gradient
    (no completing psum; seamcheck expects none)."""
    axes = op.axis
    sizes = [compat.axis_size(a) for a in axes]
    idx = [lax.axis_index(a) for a in axes]
    shifts, strides = _ep_shifts(op, axes, sizes)
    ep = len(shifts)
    e_loc, cap, dm = x.shape[1:]
    sub = _sub_chunks(cap, ep, op.comm_chunks)
    sub_len = cap // sub

    dx = jnp.zeros_like(x)
    dws = None
    with _seam_scope("moe_a2a_ring"):
        for shs in shifts:
            dst = _ep_flat(idx, shs, sizes, strides, +1)
            src = _ep_flat(idx, shs, sizes, strides, -1)
            fwd = [(a, [(i, (i + sh) % nk) for i in range(nk)])
                   for a, sh, nk in zip(axes, shs, sizes) if sh]
            inv = [(a, [(i, (i - sh) % nk) for i in range(nk)])
                   for a, sh, nk in zip(axes, shs, sizes) if sh]
            for j in range(sub):
                off = j * sub_len
                gc = lax.dynamic_slice(g, (dst, 0, off, 0),
                                       (1, e_loc, sub_len, dm))
                for a, perm in fwd:
                    gc = lax.ppermute(gc, a, perm)
                # gc = g_src[me]: cotangent of MY experts' output on the
                # chunk received from src — pair with the saved input
                if op.wire_dtype:
                    # forward-wire-only quantization: the saved buf is
                    # lossy, so rebuild the FULL-precision received chunk
                    # by re-running the fp dispatch hops (ppermute/slice
                    # are exact — grads bit-match the fp wire's)
                    bc = lax.dynamic_slice(x, (dst, 0, off, 0),
                                           (1, e_loc, sub_len, dm))
                    for a, perm in fwd:
                        bc = lax.ppermute(bc, a, perm)
                else:
                    bc = lax.dynamic_slice(buf, (src, 0, off, 0),
                                           (1, e_loc, sub_len, dm))
                _, vjp = jax.vjp(functools.partial(_expert_fn, epi),
                                 bc, *ws)
                db, *dw = vjp(gc.astype(bc.dtype))
                dws = dw if dws is None else [a_ + b_ for a_, b_
                                              in zip(dws, dw)]
                for a, perm in reversed(inv):
                    db = lax.ppermute(db, a, perm)
                dx = lax.dynamic_update_slice(dx, db.astype(dx.dtype),
                                              (dst, 0, off, 0))
    return dx, tuple(d.astype(w.dtype) for d, w in zip(dws, ws))


def _a2a_bwd(op: FusedOp, res, g):
    x, ws, buf, _, _, _ = res
    epi = op.epilogue
    axes = op.axis

    def local_vjp(b, ct):
        _, vjp = jax.vjp(functools.partial(_expert_fn, epi), b, *ws)
        db, *dw = vjp(ct.astype(b.dtype))
        return db, tuple(d.astype(w.dtype) for d, w in zip(dw, ws))

    if not axes or _ep_group_size(axes) == 1:
        dx, dws = local_vjp(x, g)
    elif op.mode == "xla":
        if op.wire_dtype:
            # the saved buf is wire-lossy; rebuild the fp received buffer
            # (exact exchange) so the backward matches the fp wire's
            with _seam_scope("moe_a2a_dispatch"):
                buf = a2a_exchange(x, axes)
        with _seam_scope("moe_a2a_combine"):
            gb = a2a_exchange(g, axes)      # combine's transpose
        db, dws = local_vjp(buf, gb)
        with _seam_scope("moe_a2a_dispatch"):
            dx = a2a_exchange(db, axes)     # dispatch's transpose
    else:
        dx, dws = _a2a_bwd_ring(op, x, ws, buf, g, epi)
    return dx.astype(x.dtype), dws, None, None, None


# ---------------------------------------------------------------------------
# mode="flux": fused Pallas kernels (see repro/kernels/)
# ---------------------------------------------------------------------------
def _flux_available() -> bool:
    """Flux seams compose several remote-DMA kernels into one jitted program
    (fwd AG + bwd RS, or both MLP seams); on JAX generations where the
    interpret-mode DMA discharge cannot compose (see
    ``compat.fused_collective_kernels_composable``) fall back to the
    decomposed ring — same numerics, ``ppermute``-based."""
    return compat.fused_collective_kernels_composable()


def _blocks_kw(blocks) -> dict:
    if blocks is None:
        return {}
    bm, bk, bn = blocks
    return {"bm": bm, "bk": bk, "bn": bn}


def _ag_flux(x: Array, w: Array, axis: str, reverse: bool, blocks,
             activation: Optional[str] = None,
             bias: Optional[Array] = None) -> Array:
    from repro.kernels import ops as kops
    # Kernels operate on [m_shard, k] @ [k, n] 2-D operands and gather along
    # m in SHARD-MAJOR order.  Move the (sharded) sequence dim to the front so
    # shard-major == sequence order, then flatten the batch dims into m.
    n = _axis_size(axis)
    lead = x.shape[:-2]
    xt = jnp.moveaxis(x, -2, 0)                        # [S/N, *lead, D]
    x2 = xt.reshape((-1, x.shape[-1]))                 # [(S/N)*B_flat, D]
    y2 = kops.ag_matmul_fused(x2, w, axis_name=axis, reverse=reverse,
                              activation=activation, bias=bias,
                              **_blocks_kw(blocks))    # [S*B_flat, F/N]
    yt = y2.reshape((x.shape[-2] * n, *lead, w.shape[-1]))
    return jnp.moveaxis(yt, 0, -2)                     # [*lead, S, F/N]


def _rs_flux(y: Array, w: Array, axis: str, reverse: bool, blocks,
             activation: Optional[str] = None,
             bias: Optional[Array] = None) -> Array:
    from repro.kernels import ops as kops
    n = _axis_size(axis)
    lead = y.shape[:-2]
    yt = jnp.moveaxis(y, -2, 0)                        # [S, *lead, F/N]
    y2 = yt.reshape((-1, y.shape[-1]))
    o2 = kops.matmul_rs_fused(y2, w, axis_name=axis, reverse=reverse,
                              activation=activation, bias=bias,
                              **_blocks_kw(blocks))    # [S/N * B_flat, D]
    ot = o2.reshape((y.shape[-2] // n, *lead, w.shape[-1]))
    return jnp.moveaxis(ot, 0, -2)                     # [*lead, S/N, D]


# ---------------------------------------------------------------------------
# FusedOp: the declarative op object
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class FusedOp:
    """One TP-seam collective-matmul with a fused epilogue (module docstring
    for semantics).  Frozen + hashable: the op itself is the custom_vjp's
    static configuration, so equal plans share one trace."""
    kind: str
    axis: Optional[str] = None
    mode: str = "decomposed"
    comm_chunks: int = 0
    reverse: bool = False
    blocks: Optional[Tuple[int, int, int]] = None
    epilogue: Epilogue = Epilogue()
    n_weights: int = 1
    fuse_epilogue: bool = True
    shared_gather: bool = True
    scatter_axis: str = "seq"
    wire_dtype: Optional[str] = None

    def __post_init__(self):
        mode, wd = normalize_mode(self.mode, self.wire_dtype)
        if (mode, wd) != (self.mode, self.wire_dtype):
            object.__setattr__(self, "mode", mode)
            object.__setattr__(self, "wire_dtype", wd)
        if self.kind not in VALID_KINDS:
            raise ValueError(f"invalid kind {self.kind!r}")
        if self.mode not in VALID_MODES:
            raise ValueError(f"invalid overlap mode {self.mode!r}")
        if self.wire_dtype not in VALID_WIRE_DTYPES:
            raise ValueError(f"invalid wire_dtype {self.wire_dtype!r}")
        if self.wire_dtype is not None and self.mode == "flux":
            raise ValueError(
                "wire_dtype is not supported with mode='flux' (the Pallas "
                "kernels have no quantized DMA path); use a decomposed "
                "mode or drop wire_dtype")
        if self.scatter_axis not in VALID_SCATTER_AXES:
            raise ValueError(f"invalid scatter_axis {self.scatter_axis!r}")
        if self.kind == "ar":
            # "ar" IS the replicated layout (one-token decode GEMMs)
            object.__setattr__(self, "scatter_axis", "hidden")
        if self.n_weights < 1:
            raise ValueError("n_weights must be >= 1")
        if self.kind == "a2a":
            # EP exchange: axis is a TUPLE of mesh axes (rank order is
            # axis-major); the op owns the whole expert computation, so it
            # takes the (w1, w3, w2) triple and the pure pair-gate epilogue.
            axes = self.axis
            if axes is None:
                axes = ()
            elif isinstance(axes, str):
                axes = (axes,)
            object.__setattr__(self, "axis", tuple(axes))
            if self.n_weights != 3:
                raise ValueError(
                    'kind="a2a" takes the expert (w1, w3, w2) triple')
            e = self.epilogue
            if e.gate != "pair" or e.bias or e.scale or e.residual:
                raise ValueError(
                    'kind="a2a" needs a pure gate="pair" epilogue')
            if self.blocks is not None:
                object.__setattr__(self, "blocks", tuple(self.blocks))
            return
        if self.kind != "ag" and self.n_weights != 1:
            raise ValueError(f"kind={self.kind!r} ops take exactly one weight")
        if self.epilogue.gate == "pair":
            if self.kind != "ag" or self.n_weights != 2:
                raise ValueError('gate="pair" needs an ag op with n_weights=2')
        elif self.n_weights > 1 and not self.epilogue.is_identity:
            raise ValueError("multi-output ops (n_weights>1 without "
                             'gate="pair") require an identity epilogue')
        if self.blocks is not None:
            object.__setattr__(self, "blocks", tuple(self.blocks))

    @staticmethod
    def from_plan(kind: str, plan, axis: Optional[str] = None,
                  epilogue: Optional[Epilogue] = None,
                  n_weights: int = 1,
                  scatter_axis: Optional[str] = None) -> "FusedOp":
        """Bind a tuning ``SeamPlan`` (duck-typed: anything with
        mode/comm_chunks/...) to a concrete seam op.  ``scatter_axis=None``
        takes the plan's layout knob (the context layer passes the model's
        resolved residual layout explicitly, keeping all seams coherent)."""
        blocks = getattr(plan, "blocks", None)
        return FusedOp(
            kind=kind, axis=axis, mode=plan.mode,
            comm_chunks=plan.comm_chunks,
            reverse=getattr(plan, "reverse", False),
            blocks=tuple(blocks) if blocks else None,
            epilogue=epilogue if epilogue is not None else Epilogue(),
            n_weights=n_weights,
            fuse_epilogue=getattr(plan, "fuse_epilogue", True),
            shared_gather=getattr(plan, "shared_gather", True),
            scatter_axis=(scatter_axis if scatter_axis is not None
                          else getattr(plan, "scatter_axis", "seq")),
            wire_dtype=getattr(plan, "wire_dtype", None))

    @property
    def combines(self) -> bool:
        """True when the op returns ONE array (single weight or pair-gate);
        False -> tuple of per-weight outputs."""
        return self.n_weights == 1 or self.epilogue.gate == "pair"

    def __call__(self, x: Array, *ws: Array, bias=None, scale=None,
                 residual=None):
        if len(ws) != self.n_weights:
            raise ValueError(f"expected {self.n_weights} weights, "
                             f"got {len(ws)}")
        epi = self.epilogue
        for flag, name, val in ((epi.bias, "bias", bias),
                                (epi.scale, "scale", scale),
                                (epi.residual, "residual", residual)):
            if flag != (val is not None):
                raise ValueError(
                    f"epilogue.{name}={flag} but {name} operand "
                    f"{'missing' if flag else 'given'}")
        return _fused(self, x, tuple(ws), bias, scale, residual)


def _apply_epilogue(op: FusedOp, ys: Sequence[Array], bias, scale, residual):
    """Epilogue at the op level: combine to one array, or pass the
    per-weight outputs through as a tuple (identity epilogue)."""
    if op.combines:
        return op.epilogue.apply(ys, bias=bias, scale=scale,
                                 residual=residual)
    return tuple(ys)


# ---------------------------------------------------------------------------
# forward implementations
# ---------------------------------------------------------------------------
def _fused_ag(op: FusedOp, x, ws, bias, scale, residual):
    epi = op.epilogue
    mode = op.mode
    if (op.axis is None or _axis_size(op.axis) == 1
            or op.scatter_axis == "hidden"):
        # hidden layout: x is already the FULL replicated activation — the
        # column-parallel GEMM needs no collective at all (Megatron's "f").
        ys = [jnp.einsum("...sd,df->...sf", x, w) for w in ws]
        return _apply_epilogue(op, ys, bias, scale, residual)

    if mode == "flux":
        if _flux_available():
            return _fused_ag_flux(op, x, ws, bias, scale, residual)
        mode = "decomposed"

    if mode == "xla":
        full = _gather_full(x, op.axis, op.wire_dtype)
        ys = [jnp.einsum("...sd,df->...sf", full, w) for w in ws]
        return _apply_epilogue(op, ys, bias, scale, residual)

    # ring transports: the epilogue fuses PER CHUNK inside the overlapped
    # loop (residual is row-indexed by global position -> applied after
    # assembly; everything else is chunk-local).
    per_chunk = (op.fuse_epilogue and op.combines and not epi.is_identity
                 and (op.shared_gather or op.n_weights == 1))
    epi_chunk = dataclasses.replace(epi, residual=False)

    def chunk_fn(xc):
        ys = [jnp.einsum("...sd,df->...sf", xc, w) for w in ws]
        if per_chunk:
            return (epi_chunk.apply(ys, bias=bias, scale=scale),)
        return tuple(ys)

    wd = op.wire_dtype
    enc = (lambda v: wire_encode(v, wd)) if wd else None
    dec = (lambda buf: wire_decode(buf, wd, x.dtype)) if wd else None

    def run(fn):
        if mode == "decomposed_bidir":
            return _ag_bidir(x, op.axis, op.comm_chunks, fn,
                             encode=enc, decode=dec)
        return _ag_ring(x, op.axis, op.comm_chunks, op.reverse, fn,
                        encode=enc, decode=dec)

    if op.shared_gather or op.n_weights == 1:
        outs = run(chunk_fn)          # ONE ring pass for all weights
    else:
        outs = tuple(run(lambda xc, w=w: (jnp.einsum("...sd,df->...sf",
                                                     xc, w),))[0]
                     for w in ws)     # legacy: one ring per weight

    if per_chunk:
        out = outs[0]
        if epi.residual:
            out = out + residual
        return out
    return _apply_epilogue(op, list(outs), bias, scale, residual)


def _fused_ag_flux(op: FusedOp, x, ws, bias, scale, residual):
    epi = op.epilogue
    # single-weight bias/activation fuse into the kernel's tile epilogue
    if (op.n_weights == 1 and op.fuse_epilogue and not epi.scale
            and epi.gate is None):
        y = _ag_flux(x, ws[0], op.axis, op.reverse, op.blocks,
                     activation=epi.activation,
                     bias=bias if epi.bias else None)
        if epi.residual:
            y = y + residual
        return y
    if op.n_weights > 1 and op.shared_gather:
        # shared gather via one kernel over the column-stacked weights:
        # gather once, one ring of DMA hops, split the local outputs.
        wcat = jnp.concatenate(ws, axis=-1)
        ycat = _ag_flux(x, wcat, op.axis, op.reverse, op.blocks)
        offs, splits = 0, []
        for w in ws[:-1]:
            offs += w.shape[-1]
            splits.append(offs)
        ys = jnp.split(ycat, splits, axis=-1)
    else:
        ys = [_ag_flux(x, w, op.axis, op.reverse, op.blocks) for w in ws]
    return _apply_epilogue(op, ys, bias, scale, residual)


def _fused_z(op: FusedOp, x, ws):
    """Pre-epilogue output of an rs/ar op (the collective's result)."""
    if op.kind == "rs" and op.scatter_axis == "seq":
        return _rs_core((x,), ws, op.axis, op.mode, op.comm_chunks,
                        op.reverse, op.blocks, op.wire_dtype)
    # rs/hidden degenerates to the row-parallel GEMM + AllReduce
    # (Megatron's "g" without the sequence scatter) — exactly the "ar" op.
    return _ar_core(x, ws[0], op.axis, op.mode, op.comm_chunks,
                    op.wire_dtype)


def _fused_impl(op: FusedOp, x, ws, bias, scale, residual):
    if op.kind == "ag":
        return _fused_ag(op, x, ws, bias, scale, residual)
    if op.kind == "a2a":
        return _a2a_impl(op, x, ws)[0]
    z = _fused_z(op, x, ws)
    return op.epilogue.apply([z], bias=bias, scale=scale, residual=residual)


# ---------------------------------------------------------------------------
# custom_vjp — ONCE, at the FusedOp level
# ---------------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _fused(op: FusedOp, x, ws, bias, scale, residual):
    return _fused_impl(op, x, ws, bias, scale, residual)


def _fused_fwd(op: FusedOp, x, ws, bias, scale, residual):
    if op.kind == "ag":
        # pre-epilogue activations are RE-DERIVED in bwd from the dW
        # re-gather (one all_gather serves the epilogue-vjp AND every dW)
        out = _fused_ag(op, x, ws, bias, scale, residual)
        return out, (x, ws, None, bias, scale, residual)
    if op.kind == "a2a":
        # the RECEIVED dispatch buffer rides the z residual slot: backward
        # pairs it with the returning combine cotangent per chunk
        out, buf = _a2a_impl(op, x, ws)
        return out, (x, ws, buf, bias, scale, residual)
    z = _fused_z(op, x, ws)
    out = op.epilogue.apply([z], bias=bias, scale=scale, residual=residual)
    return out, (x, ws, z, bias, scale, residual)


def _fused_bwd(op: FusedOp, res, g):
    if op.kind == "a2a":
        # rides the interchanged exchange (axis is a TUPLE here — before
        # the scalar-axis handling below)
        return _a2a_bwd(op, res, g)
    x, ws, z, bias, scale, residual = res
    epi = op.epilogue
    single = op.axis is None or _axis_size(op.axis) == 1

    hidden = op.scatter_axis == "hidden"
    if op.kind == "ag":
        # the dW contraction needs the gathered activation anyway (a
        # "sequence-partial + psum" variant was tried and REFUTED: each
        # device's g covers different weight columns, so shard-partials
        # cannot be psum-combined; see EXPERIMENTS.md §Perf iteration log).
        # hidden layout: x is already full — no re-gather at all.  seq
        # layout: the re-gather rides the op's own transport (gather_seq:
        # ppermute ring for the ring modes) so no standalone all_gather
        # remains in the step.
        xf = x if (single or hidden) else gather_seq(x, op.axis, op.mode,
                                                     op.reverse)
        ys = tuple(jnp.einsum("...sd,df->...sf", xf, w) for w in ws)

        def epi_fn(ys_, bias_, scale_, residual_):
            if op.combines:
                return epi.apply(ys_, bias=bias_, scale=scale_,
                                 residual=residual_)
            return tuple(ys_)

        _, epi_vjp = jax.vjp(epi_fn, ys, bias, scale, residual)
        dys, dbias, dscale, dres = epi_vjp(g)
        # dX: the interchanged op.  seq — GEMM + ReduceScatter over the
        # sequence cotangent, ONE ring pass for all weights (blocks are
        # tuned for the forward shape; the transposed op auto-plans its
        # own).  hidden — NO collective: under check_rep=False shard_map,
        # a replicated tensor's cotangent is a per-rank PARTIAL that sums
        # to the truth across ranks, and the local sum over this rank's
        # weight columns IS that partial.  (The completing psum happens at
        # whichever op consumes the replicated stream with a rank-exclusive
        # operand — see the rs/ar branch below.)
        wts = tuple(w.T for w in ws)
        if single or hidden:
            dx = None
            for dy, wt in zip(dys, wts):
                p = jnp.einsum("...sf,fd->...sd", dy, wt)
                dx = p if dx is None else dx + p
        else:
            # cotangents never ride a quantized wire (wire_dtype=None)
            dx = _rs_core(dys, wts, op.axis, op.mode, op.comm_chunks,
                          op.reverse, None, None)
        dws = tuple(jnp.einsum("...sd,...sf->df", xf, dy).astype(w.dtype)
                    for w, dy in zip(ws, dys))
        return dx.astype(x.dtype), dws, dbias, dscale, dres

    # rs / ar: epilogue vjp at the saved pre-epilogue output, then the
    # interchanged overlapped op on the transposed cotangent.
    def epi_fn(z_, bias_, scale_, residual_):
        return epi.apply([z_], bias=bias_, scale=scale_, residual=residual_)

    _, epi_vjp = jax.vjp(epi_fn, z, bias, scale, residual)
    dz, dbias, dscale, dres = epi_vjp(g)
    w = ws[0]
    if op.kind == "rs" and not hidden:
        # dY: AllGather + GEMM — interchanged overlapped op.  dz is the
        # cotangent of rank-EXCLUSIVE sequence rows, so it arrives full.
        bwd_op = dataclasses.replace(op, kind="ag", epilogue=Epilogue(),
                                     blocks=None, wire_dtype=None)
        dy = _fused_ag(bwd_op, dz, (w.T,), None, None, None)
        gf = dz if single else gather_seq(dz, op.axis, op.mode, op.reverse)
        dw = jnp.einsum("...sf,...sd->fd", x, gf)
    else:
        # rs/hidden and ar: z is REPLICATED, so its cotangent arrives as a
        # per-rank partial (check_rep=False convention).  This op's x and w
        # are rank-exclusive (hidden/contraction shards), so complete the
        # cotangent with the interchanged collective (psum — the AllReduce
        # backward of the AllReduce forward) BEFORE the local GEMMs.
        if single:
            dzf = dz
        else:
            with _seam_scope("cotangent_ar"):
                dzf = lax.psum(dz, op.axis)
        dy = jnp.einsum("...md,fd->...mf", dzf, w)
        dw = jnp.einsum("...mf,...md->fd", x, dzf)
    return dy.astype(x.dtype), (dw.astype(w.dtype),), dbias, dscale, dres


_fused.defvjp(_fused_fwd, _fused_bwd)


# ---------------------------------------------------------------------------
# Reference (oracle) versions for tests: always the naive collective form.
# ---------------------------------------------------------------------------
def ag_matmul_ref(x: Array, w: Array, axis: Optional[str]) -> Array:
    if axis is None or _axis_size(axis) == 1:
        return jnp.einsum("...sd,df->...sf", x, w)
    full = lax.all_gather(x, axis, axis=x.ndim - 2, tiled=True)
    return jnp.einsum("...sd,df->...sf", full, w)


def matmul_rs_ref(y: Array, w: Array, axis: Optional[str]) -> Array:
    if axis is None or _axis_size(axis) == 1:
        return jnp.einsum("...sf,fd->...sd", y, w)
    partial = jnp.einsum("...sf,fd->...sd", y, w)
    return lax.psum_scatter(partial, axis, scatter_dimension=partial.ndim - 2,
                            tiled=True)
