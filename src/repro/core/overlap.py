"""FLUX-style communication/computation overlap ops (the paper's core).

Three implementations of the two Megatron-TP seams, selectable per call:

  ``mode="xla"``         non-overlapping baseline: one collective + one matmul
                         (the paper's PyTorch+NCCL reference point).
  ``mode="decomposed"``  medium/fine-grained chunked ring via ``ppermute``:
                         the Wang-et-al./TransformerEngine analogue.  The chunk
                         count (``comm_chunks``) is the paper's §4.3
                         "communication tile size" knob; XLA's async
                         collective-permute + latency-hiding scheduler overlap
                         the chunk GEMMs with the ring hops on TPU.
  ``mode="flux"``        the paper's contribution: ONE fused Pallas kernel per
                         (GEMM, collective) pair — tile-granular remote DMA in
                         the prologue (AllGather) / epilogue (ReduceScatter),
                         semaphore waits instead of spin-signals, swizzled tile
                         walk.  See ``repro/kernels/``.

All ops must be called inside ``compat.shard_map``; ``axis`` names the TP mesh
axis.  Every op is differentiable via custom_vjp, and the backward pass uses
the *interchanged* overlapped op (AG <-> RS), exactly as in the paper §2.1.

Shapes follow the paper's Fig. 2 (sequence-sharded activations):

  ag_matmul   : x[B, S/N, D] , w[D, F/N]  ->  (AllGather S) @ w  = y[B, S, F/N]
  matmul_rs   : y[B, S, F/N] , w[F/N, D]  ->  ReduceScatter_S(y @ w) = [B, S/N, D]
  matmul_ar   : y[B, m, F/N] , w[F/N, D]  ->  AllReduce(y @ w)       = [B, m, D]
                (decode path: m == 1 new token, no sequence sharding)
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro import compat

Array = jax.Array

# *_q8 variants quantize the gathered ACTIVATION to int8 with per-128-block
# scales before it rides the ring (ZeRO++-style, applied to the SP seams) —
# halves AllGather bytes; opt-in (accuracy-affecting; see EXPERIMENTS §Perf).
VALID_MODES = ("xla", "decomposed", "flux", "xla_q8", "decomposed_q8",
               "decomposed_bidir")


def _axis_size(axis: Optional[str]) -> int:
    if axis is None:
        return 1
    return compat.axis_size(axis)


def _axis_index(axis: str) -> Array:
    return lax.axis_index(axis)


# ---------------------------------------------------------------------------
# mode="xla": non-overlapping baseline
# ---------------------------------------------------------------------------
def _ag_matmul_xla(x: Array, w: Array, axis: str) -> Array:
    full = lax.all_gather(x, axis, axis=x.ndim - 2, tiled=True)
    return jnp.einsum("...sd,df->...sf", full, w)


def _matmul_rs_xla(y: Array, w: Array, axis: str) -> Array:
    partial = jnp.einsum("...sf,fd->...sd", y, w)
    return lax.psum_scatter(partial, axis, scatter_dimension=partial.ndim - 2,
                            tiled=True)


# ---------------------------------------------------------------------------
# mode="decomposed": chunked ppermute ring (medium-grained; TE analogue)
# ---------------------------------------------------------------------------
def _ring_perm(axis: str, reverse: bool = False):
    n = compat.axis_size(axis)
    if reverse:
        return [(i, (i - 1) % n) for i in range(n)]
    return [(i, (i + 1) % n) for i in range(n)]


def _ag_matmul_decomposed(x: Array, w: Array, axis: str, comm_chunks: int,
                          reverse: bool = False) -> Array:
    """AllGather-GEMM as a ring of shard hops, each hop's GEMM issued as soon
    as its shard lands.  ``comm_chunks`` sub-divides each shard so the ring
    moves smaller messages (finer overlap granularity, more hops);
    ``reverse`` flips the ring direction (the paper's pull/push knob)."""
    n = compat.axis_size(axis)
    me = lax.axis_index(axis)
    s_shard = x.shape[-2]
    sub = max(1, comm_chunks // n) if comm_chunks else 1
    sub = min(sub, s_shard)
    while s_shard % sub:
        sub -= 1
    pieces = jnp.split(x, sub, axis=-2) if sub > 1 else [x]

    out_chunks = []  # (shard_owner_offset, sub_idx, y_chunk)
    # step 0 consumes the LOCAL shard (paper: "signals for local tiles are
    # preset to true"); subsequent steps consume the shard arriving from the
    # left neighbor (ring order = rank+1, rank+2, ... — paper §4.3).
    bufs = list(pieces)
    for step in range(n):
        for j, b in enumerate(bufs):
            out_chunks.append((step, j, jnp.einsum("...sd,df->...sf", b, w)))
        if step < n - 1:
            bufs = [lax.ppermute(b, axis, _ring_perm(axis, reverse))
                    for b in bufs]

    # Assemble: at step k we held the shard of rank (me -+ k) mod n
    # (forward ring receives from the left neighbor, reverse from the right).
    sub_len = s_shard // sub
    y = jnp.zeros((*x.shape[:-2], s_shard * n, w.shape[-1]), out_chunks[0][2].dtype)
    for step, j, chunk in out_chunks:
        owner = (me + step) % n if reverse else (me - step) % n
        start = owner * s_shard + j * sub_len
        y = lax.dynamic_update_slice_in_dim(y, chunk, start, axis=y.ndim - 2)
    return y


def _matmul_rs_decomposed(y: Array, w: Array, axis: str, comm_chunks: int,
                          reverse: bool = False) -> Array:
    """GEMM-ReduceScatter ring: at step s each device computes ONLY the output
    chunk that the ring needs next, adds the partial arriving from its left
    neighbor, and forwards.  The chunk GEMMs interleave with the hops (paper
    Fig. 3, medium-grained)."""
    n = compat.axis_size(axis)
    me = lax.axis_index(axis)
    seq = y.shape[-2]
    assert seq % n == 0, f"seq {seq} not divisible by TP {n}"
    s_shard = seq // n

    def chunk_partial(owner):
        ys = lax.dynamic_slice_in_dim(y, owner * s_shard, s_shard, axis=y.ndim - 2)
        return jnp.einsum("...sf,fd->...sd", ys, w)

    # Ring reduce-scatter: the buffer created by device d at step 0 is for
    # owner (d + n-1) (forward) / (d - (n-1)) (reverse); after each hop the
    # holder adds its own partial for that owner.  After n-1 hops the buffer
    # for owner X lands on device X with all n partials summed.
    def owner_at(s):
        return ((me - (n - 1 - s)) % n if reverse
                else (me + n - 1 - s) % n)

    acc = chunk_partial(owner_at(0))
    for s in range(1, n):
        acc = lax.ppermute(acc, axis, _ring_perm(axis, reverse))
        acc = acc + chunk_partial(owner_at(s))
    return acc


def _matmul_ar_decomposed(y: Array, w: Array, axis: str, comm_chunks: int) -> Array:
    """Decode-path GEMM+AllReduce, chunked along the contraction dim so each
    partial psum overlaps with the next chunk's GEMM."""
    n = compat.axis_size(axis)
    k = y.shape[-1]
    chunks = comm_chunks if comm_chunks else n
    chunks = max(1, min(chunks, k))
    while k % chunks:
        chunks -= 1
    ck = k // chunks
    parts = []
    for c in range(chunks):
        yc = lax.dynamic_slice_in_dim(y, c * ck, ck, axis=y.ndim - 1)
        wc = lax.dynamic_slice_in_dim(w, c * ck, ck, axis=0)
        parts.append(lax.psum(jnp.einsum("...mf,fd->...md", yc, wc), axis))
    out = parts[0]
    for p in parts[1:]:
        out = out + p
    return out


# ---------------------------------------------------------------------------
# decomposed_bidir: counter-rotating half-rings (beyond-paper).  ICI torus
# links are full-duplex PER DIRECTION: splitting the ring into two opposite
# half-volume rings halves the per-link traffic -> ~2x on ring-bound seams.
# ---------------------------------------------------------------------------
def _ag_matmul_bidir(x: Array, w: Array, axis: str, comm_chunks: int) -> Array:
    n = compat.axis_size(axis)
    me = lax.axis_index(axis)
    s_shard = x.shape[-2]
    half = s_shard // 2
    if half == 0 or s_shard % 2:
        return _ag_matmul_decomposed(x, w, axis, comm_chunks)
    lo, hi = jnp.split(x, 2, axis=-2)          # top rides right, bottom left

    y = jnp.zeros((*x.shape[:-2], s_shard * n, w.shape[-1]),
                  jnp.result_type(x.dtype, w.dtype))
    buf_r, buf_l = lo, hi
    for step in range(n):
        owner_r = (me - step) % n
        owner_l = (me + step) % n
        y = lax.dynamic_update_slice_in_dim(
            y, jnp.einsum("...sd,df->...sf", buf_r, w),
            owner_r * s_shard, axis=y.ndim - 2)
        y = lax.dynamic_update_slice_in_dim(
            y, jnp.einsum("...sd,df->...sf", buf_l, w),
            owner_l * s_shard + half, axis=y.ndim - 2)
        if step < n - 1:
            buf_r = lax.ppermute(buf_r, axis, _ring_perm(axis))
            buf_l = lax.ppermute(buf_l, axis, _ring_perm(axis, reverse=True))
    return y


def _matmul_rs_bidir(y: Array, w: Array, axis: str, comm_chunks: int) -> Array:
    n = compat.axis_size(axis)
    me = lax.axis_index(axis)
    seq = y.shape[-2]
    s_shard = seq // n
    if s_shard % 2:
        return _matmul_rs_decomposed(y, w, axis, comm_chunks)
    half = s_shard // 2

    def partial(owner, top: bool):
        off = owner * s_shard + (0 if top else half)
        ys = lax.dynamic_slice_in_dim(y, off, half, axis=y.ndim - 2)
        return jnp.einsum("...sf,fd->...sd", ys, w)

    # top halves accumulate rightward, bottom halves leftward
    acc_r = partial((me + n - 1) % n, True)
    acc_l = partial((me - (n - 1)) % n, False)
    for s_ in range(1, n):
        acc_r = lax.ppermute(acc_r, axis, _ring_perm(axis))
        acc_l = lax.ppermute(acc_l, axis, _ring_perm(axis, reverse=True))
        acc_r = acc_r + partial((me + n - 1 - s_) % n, True)
        acc_l = acc_l + partial((me - (n - 1) + s_) % n, False)
    return jnp.concatenate([acc_r, acc_l], axis=y.ndim - 2)


# ---------------------------------------------------------------------------
# *_q8: int8 block-quantized activation gather (beyond-paper knob)
# ---------------------------------------------------------------------------
_Q8_BLOCK = 128


def _q8_encode(x: Array) -> Tuple[Array, Array]:
    d = x.shape[-1]
    blocks = d // _Q8_BLOCK if d % _Q8_BLOCK == 0 else 1
    xb = x.reshape(*x.shape[:-1], blocks, d // blocks).astype(jnp.float32)
    scale = jnp.max(jnp.abs(xb), axis=-1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    return q.reshape(*x.shape), scale[..., 0].astype(jnp.float32)


def _q8_decode(q: Array, scale: Array, dtype) -> Array:
    d = q.shape[-1]
    blocks = scale.shape[-1]
    xb = q.reshape(*q.shape[:-1], blocks, d // blocks).astype(jnp.float32)
    return (xb * scale[..., None]).reshape(*q.shape).astype(dtype)


def _ag_matmul_q8(x: Array, w: Array, axis: str, base: str, comm_chunks: int,
                  reverse: bool = False) -> Array:
    """Int8-gathered AG-GEMM.  ``base`` selects the transport: ``xla`` issues
    one monolithic all_gather of the quantized payload; ``decomposed`` rides
    the chunked ppermute ring so the per-hop dequant+GEMMs overlap with the
    hops exactly like the fp ring (the int8 payload additionally halves the
    ring bytes)."""
    q, sc = _q8_encode(x)
    if base != "decomposed":
        qf = lax.all_gather(q, axis, axis=q.ndim - 2, tiled=True)
        sf = lax.all_gather(sc, axis, axis=sc.ndim - 2, tiled=True)
        full = _q8_decode(qf, sf, x.dtype)
        return jnp.einsum("...sd,df->...sf", full, w)

    n = compat.axis_size(axis)
    me = lax.axis_index(axis)
    s_shard = x.shape[-2]
    sub = max(1, comm_chunks // n) if comm_chunks else 1
    sub = min(sub, s_shard)
    while s_shard % sub:
        sub -= 1
    q_pieces = jnp.split(q, sub, axis=-2) if sub > 1 else [q]
    s_pieces = jnp.split(sc, sub, axis=-2) if sub > 1 else [sc]

    sub_len = s_shard // sub
    y = jnp.zeros((*x.shape[:-2], s_shard * n, w.shape[-1]),
                  jnp.result_type(x.dtype, w.dtype))
    bufs = list(zip(q_pieces, s_pieces))
    for step in range(n):
        owner = (me + step) % n if reverse else (me - step) % n
        for j, (bq, bs) in enumerate(bufs):
            piece = _q8_decode(bq, bs, x.dtype)
            chunk = jnp.einsum("...sd,df->...sf", piece, w)
            start = owner * s_shard + j * sub_len
            y = lax.dynamic_update_slice_in_dim(y, chunk, start,
                                                axis=y.ndim - 2)
        if step < n - 1:
            bufs = [(lax.ppermute(bq, axis, _ring_perm(axis, reverse)),
                     lax.ppermute(bs, axis, _ring_perm(axis, reverse)))
                    for bq, bs in bufs]
    return y


# ---------------------------------------------------------------------------
# mode="flux": fused Pallas kernels (see repro/kernels/)
# ---------------------------------------------------------------------------
def _blocks_kw(blocks) -> dict:
    if blocks is None:
        return {}
    bm, bk, bn = blocks
    return {"bm": bm, "bk": bk, "bn": bn}


def _ag_matmul_flux(x: Array, w: Array, axis: str, reverse: bool = False,
                    blocks=None) -> Array:
    from repro.kernels import ops as kops
    # Kernels operate on [m_shard, k] @ [k, n] 2-D operands and gather along
    # m in SHARD-MAJOR order.  Move the (sharded) sequence dim to the front so
    # shard-major == sequence order, then flatten the batch dims into m.
    n = _axis_size(axis)
    lead = x.shape[:-2]
    xt = jnp.moveaxis(x, -2, 0)                        # [S/N, *lead, D]
    x2 = xt.reshape((-1, x.shape[-1]))                 # [(S/N)*B_flat, D]
    y2 = kops.ag_matmul_fused(x2, w, axis_name=axis, reverse=reverse,
                              **_blocks_kw(blocks))    # [S*B_flat, F/N]
    yt = y2.reshape((x.shape[-2] * n, *lead, w.shape[-1]))
    return jnp.moveaxis(yt, 0, -2)                     # [*lead, S, F/N]


def _matmul_rs_flux(y: Array, w: Array, axis: str, reverse: bool = False,
                    blocks=None) -> Array:
    from repro.kernels import ops as kops
    n = _axis_size(axis)
    lead = y.shape[:-2]
    yt = jnp.moveaxis(y, -2, 0)                        # [S, *lead, F/N]
    y2 = yt.reshape((-1, y.shape[-1]))
    o2 = kops.matmul_rs_fused(y2, w, axis_name=axis, reverse=reverse,
                              **_blocks_kw(blocks))    # [S/N * B_flat, D]
    ot = o2.reshape((y.shape[-2] // n, *lead, w.shape[-1]))
    return jnp.moveaxis(ot, 0, -2)                     # [*lead, S/N, D]


# ---------------------------------------------------------------------------
# Public, differentiable API
# ---------------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5, 6))
def ag_matmul(x: Array, w: Array, axis: Optional[str] = None,
              mode: str = "decomposed", comm_chunks: int = 0,
              reverse: bool = False,
              blocks: Optional[Tuple[int, int, int]] = None) -> Array:
    """(AllGather along seq) @ w, overlapped per ``mode``.  ``reverse`` flips
    the ring direction (pull/push analogue); ``blocks`` overrides the fused
    kernel's (bm, bk, bn) tile preference (None -> auto)."""
    return _ag_matmul_impl(x, w, axis, mode, comm_chunks, reverse, blocks)


def _flux_available() -> bool:
    """Flux seams compose several remote-DMA kernels into one jitted program
    (fwd AG + bwd RS, or both MLP seams); on JAX generations where the
    interpret-mode DMA discharge cannot compose (see
    ``compat.fused_collective_kernels_composable``) fall back to the
    decomposed ring — same numerics, ``ppermute``-based."""
    return compat.fused_collective_kernels_composable()


def _ag_matmul_impl(x, w, axis, mode, comm_chunks, reverse=False,
                    blocks=None):
    assert mode in VALID_MODES, mode
    if axis is None or _axis_size(axis) == 1:
        return jnp.einsum("...sd,df->...sf", x, w)
    if mode == "xla":
        return _ag_matmul_xla(x, w, axis)
    if mode == "flux":
        if _flux_available():
            return _ag_matmul_flux(x, w, axis, reverse, blocks)
        return _ag_matmul_decomposed(x, w, axis, comm_chunks, reverse)
    if mode.endswith("_q8"):
        return _ag_matmul_q8(x, w, axis, mode[:-3], comm_chunks, reverse)
    if mode == "decomposed_bidir":
        return _ag_matmul_bidir(x, w, axis, comm_chunks)
    return _ag_matmul_decomposed(x, w, axis, comm_chunks, reverse)


def _ag_matmul_fwd(x, w, axis, mode, comm_chunks, reverse, blocks):
    return _ag_matmul_impl(x, w, axis, mode, comm_chunks, reverse,
                           blocks), (x, w)


def _ag_matmul_bwd(axis, mode, comm_chunks, reverse, blocks, res, g):
    x, w = res
    # dX: GEMM + ReduceScatter — the interchanged overlapped op (blocks are
    # tuned for the forward shape; let the transposed op auto-plan its own).
    dx = _matmul_rs_impl(g, w.T, axis, mode, comm_chunks, reverse)
    # dW: contraction over gathered tokens (the re-gather is unavoidable —
    # a "sequence-partial + psum" variant was tried and REFUTED: each
    # device's g covers different weight columns, so shard-partials cannot
    # be psum-combined; see EXPERIMENTS.md §Perf iteration log).
    if axis is None or _axis_size(axis) == 1:
        xf = x
    else:
        xf = lax.all_gather(x, axis, axis=x.ndim - 2, tiled=True)
    dw = jnp.einsum("...sd,...sf->df", xf, g)
    return dx.astype(x.dtype), dw.astype(w.dtype)


ag_matmul.defvjp(_ag_matmul_fwd, _ag_matmul_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5, 6))
def matmul_rs(y: Array, w: Array, axis: Optional[str] = None,
              mode: str = "decomposed", comm_chunks: int = 0,
              reverse: bool = False,
              blocks: Optional[Tuple[int, int, int]] = None) -> Array:
    """ReduceScatter_seq(y @ w), overlapped per ``mode``."""
    return _matmul_rs_impl(y, w, axis, mode, comm_chunks, reverse, blocks)


def _matmul_rs_impl(y, w, axis, mode, comm_chunks, reverse=False,
                    blocks=None):
    assert mode in VALID_MODES, mode
    if mode.endswith("_q8"):
        mode = mode[:-3]     # RS partials keep full precision (they SUM)
    if axis is None or _axis_size(axis) == 1:
        return jnp.einsum("...sf,fd->...sd", y, w)
    if mode == "xla":
        return _matmul_rs_xla(y, w, axis)
    if mode == "flux":
        if _flux_available():
            return _matmul_rs_flux(y, w, axis, reverse, blocks)
        return _matmul_rs_decomposed(y, w, axis, comm_chunks, reverse)
    if mode == "decomposed_bidir":
        return _matmul_rs_bidir(y, w, axis, comm_chunks)
    return _matmul_rs_decomposed(y, w, axis, comm_chunks, reverse)


def _matmul_rs_fwd(y, w, axis, mode, comm_chunks, reverse, blocks):
    return _matmul_rs_impl(y, w, axis, mode, comm_chunks, reverse,
                           blocks), (y, w)


def _matmul_rs_bwd(axis, mode, comm_chunks, reverse, blocks, res, g):
    y, w = res
    # dY: AllGather + GEMM — interchanged overlapped op.
    dy = _ag_matmul_impl(g, w.T, axis, mode, comm_chunks, reverse)
    if axis is None or _axis_size(axis) == 1:
        gf = g
    else:
        gf = lax.all_gather(g, axis, axis=g.ndim - 2, tiled=True)
    dw = jnp.einsum("...sf,...sd->fd", y, gf)
    return dy.astype(y.dtype), dw.astype(w.dtype)


matmul_rs.defvjp(_matmul_rs_fwd, _matmul_rs_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def matmul_ar(y: Array, w: Array, axis: Optional[str] = None,
              mode: str = "decomposed", comm_chunks: int = 0) -> Array:
    """AllReduce(y @ w) — the decode-path row-parallel GEMM."""
    return _matmul_ar_impl(y, w, axis, mode, comm_chunks)


def _matmul_ar_impl(y, w, axis, mode, comm_chunks):
    if axis is None or _axis_size(axis) == 1:
        return jnp.einsum("...mf,fd->...md", y, w)
    if mode.startswith("decomposed"):
        return _matmul_ar_decomposed(y, w, axis, comm_chunks)
    # xla / flux(decode uses XLA AR: one-token GEMMs are latency- not
    # bandwidth-bound; the fused kernel's win is in the big seams)
    return lax.psum(jnp.einsum("...mf,fd->...md", y, w), axis)


def _matmul_ar_fwd(y, w, axis, mode, comm_chunks):
    return _matmul_ar_impl(y, w, axis, mode, comm_chunks), (y, w)


def _matmul_ar_bwd(axis, mode, comm_chunks, res, g):
    y, w = res
    dy = jnp.einsum("...md,fd->...mf", g, w)
    dw = jnp.einsum("...mf,...md->fd", y, g)
    return dy.astype(y.dtype), dw.astype(w.dtype)


matmul_ar.defvjp(_matmul_ar_fwd, _matmul_ar_bwd)


# ---------------------------------------------------------------------------
# Reference (oracle) versions for tests: always the naive collective form.
# ---------------------------------------------------------------------------
def ag_matmul_ref(x: Array, w: Array, axis: Optional[str]) -> Array:
    if axis is None or _axis_size(axis) == 1:
        return jnp.einsum("...sd,df->...sf", x, w)
    return _ag_matmul_xla(x, w, axis)


def matmul_rs_ref(y: Array, w: Array, axis: Optional[str]) -> Array:
    if axis is None or _axis_size(axis) == 1:
        return jnp.einsum("...sf,fd->...sd", y, w)
    return _matmul_rs_xla(y, w, axis)
