"""AdamW with ZeRO-1 optimizer-state sharding and hierarchical, optionally
int8-compressed cross-pod gradient synchronization.

Runs INSIDE shard_map.  Per step (DESIGN.md §6):

  phase 1 — gradient sync:
    * leaves replicated over dp ("rep"): reduce-scatter over the data axis
      (each data-rank owns 1/dp of the gradient — the ZeRO-1 shard), then
      (optionally int8-compressed) all-reduce across the pod axis.
    * leaves already dp-sharded (ZeRO-3 / EP-over-dp): autodiff of their
      gather already produced the dp-reduced local grad; only pod sync.
    * leaves replicated over the model axis get their grads psum'd over
      'model' by the CALLER (train_step) right after jax.grad.
  phase 2 — global grad-norm clip: per-leaf local squared sums are weighted
    so every element counts exactly once under psum over (data, pod)
    (model-replicated leaves carry weight 1/tp).
  phase 3 — AdamW on the owned shard (fp32 moments), then all-gather the
    updated shards back over dp.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro import compat

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # bf16 moments halve optimizer HBM — required to fit expert-dense MoE
    # (DeepSeek-V3 on 512 v5e: each device owns ~2.6B expert params; fp32
    # m+v alone would be 20 GB).  fp32 master update math is kept.
    moment_dtype: str = "float32"


# ---------------------------------------------------------------------------
# spec-derived leaf metadata
# ---------------------------------------------------------------------------
def axis_replicated_tree(specs: Dict, axis: str) -> Dict:
    """True for leaves with no ``axis`` in their PartitionSpec."""
    def rep(spec):
        names = set()
        for part in spec:
            if part is None:
                continue
            if isinstance(part, tuple):
                names |= set(part)
            else:
                names.add(part)
        return axis not in names
    return jax.tree.map(rep, specs, is_leaf=lambda x: isinstance(x, P))


def dp_replicated_tree(specs: Dict) -> Dict:
    """True for leaves with no 'data' in their PartitionSpec."""
    return axis_replicated_tree(specs, "data")


def model_replicated_tree(specs: Dict) -> Dict:
    return axis_replicated_tree(specs, "model")


def _sharddable(p: Array, n: int) -> bool:
    return p.ndim >= 1 and p.shape[0] % n == 0 and p.shape[0] >= n


def _dp_shard(x: Array, axis: str) -> Array:
    n = compat.axis_size(axis)
    if not _sharddable(x, n):
        return x
    sh = x.shape[0] // n
    return lax.dynamic_slice_in_dim(x, lax.axis_index(axis) * sh, sh, axis=0)


# ---------------------------------------------------------------------------
# int8 block-quantized pod all-reduce (ZeRO++ analogue)
# ---------------------------------------------------------------------------
def _quantize_int8(x: Array, block: int = 256) -> Tuple[Array, Array]:
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def pod_allreduce(g: Array, pod_axis: Optional[str],
                  compress: bool = False) -> Array:
    if pod_axis is None:
        return g
    if not compress:
        return lax.pmean(g, pod_axis)
    n = compat.axis_size(pod_axis)
    q, scale = _quantize_int8(g)
    # int8 grad exchange over the POD axis (optimizer, not a TP seam)
    qs = lax.all_gather(q, pod_axis)       # lint: allow(raw-collective)
    ss = lax.all_gather(scale, pod_axis)   # lint: allow(raw-collective)
    deq = jnp.sum(qs.astype(jnp.float32) * ss, axis=0) / n
    return deq.reshape(-1)[:g.size].reshape(g.shape)


# ---------------------------------------------------------------------------
# optimizer state
# ---------------------------------------------------------------------------
def init_opt_state(params: Dict, moment_dtype: str = "float32") -> Dict:
    """Moments in GLOBAL shapes (the ZeRO-1 dp-sharding lives entirely in
    ``opt_state_specs``; inside shard_map each rank sees its owned shard)."""
    zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
    return {"mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32)}


def opt_state_specs(param_specs: Dict, params: Dict, dp: int, tp: int = 1,
                    ep: int = 1, dp_axis: str = "data") -> Dict:
    """PartitionSpecs for the ZeRO-1 moments.  The sharddable test must see
    the LOCAL dim0 (after any 'model'/'ep' sharding) so it matches the
    runtime ``_dp_shard`` decision made inside shard_map."""
    dp_rep = dp_replicated_tree(param_specs)

    def one(spec, rep, p):
        if not rep or dp <= 1 or p.ndim < 1:
            return spec
        parts = list(spec) + [None] * (p.ndim - len(spec))
        dim0 = p.shape[0]
        d0_names = parts[0] if isinstance(parts[0], tuple) else (parts[0],)
        if "model" in d0_names:
            dim0 //= tp
        if "ep" in d0_names:
            dim0 //= max(ep, 1)
        if parts[0] is not None or dim0 % dp or dim0 < dp:
            # dim0 taken (model-sharded) or not divisible: runtime falls back
            # to pmean + replicated moments for model-free dim0; for
            # model-sharded dim0 the runtime ALSO can't dp-shard -> keep spec
            if parts[0] is None:
                return spec
            # model-sharded dim0 that IS locally divisible: shard over both
            if dim0 % dp == 0 and dim0 >= dp and "data" not in d0_names:
                parts[0] = tuple([x for x in d0_names if x is not None]
                                 ) + (dp_axis,)
                return P(*parts)
            return spec
        parts[0] = dp_axis
        return P(*parts)

    moments = jax.tree.map(one, param_specs, dp_rep, params,
                           is_leaf=lambda x: isinstance(x, P))
    return {"mu": moments, "nu": moments, "count": P()}


# ---------------------------------------------------------------------------
# the step
# ---------------------------------------------------------------------------
def adamw_update(params: Dict, grads: Dict, opt: Dict, cfg: AdamWConfig,
                 lr: Array, *, specs: Dict, dp_axis: Optional[str] = "data",
                 pod_axis: Optional[str] = None, ep_axis: Optional[str] = None,
                 grad_compress: bool = False) -> Tuple[Dict, Dict]:
    dp_rep = dp_replicated_tree(specs)
    model_rep = model_replicated_tree(specs)
    ep_rep = (axis_replicated_tree(specs, ep_axis)
              if ep_axis is not None else jax.tree.map(
                  lambda _: True, dp_rep))
    dp_n = compat.axis_size(dp_axis) if dp_axis is not None else 1
    ep_n = compat.axis_size(ep_axis) if ep_axis is not None else 1

    # ---- phase 1: sync ------------------------------------------------------
    def sync(g, rep):
        g = g.astype(jnp.float32)
        if rep and dp_axis is not None and dp_n > 1:
            if _sharddable(g, dp_n):
                # ZeRO-1 grad reduce over the DATA axis (optimizer collective,
                # not a TP seam)
                g = lax.psum_scatter(  # lint: allow(raw-collective)
                    g, dp_axis, scatter_dimension=0, tiled=True) / dp_n
            else:
                g = lax.pmean(g, dp_axis)
        return pod_allreduce(g, pod_axis, grad_compress)

    gsync = jax.tree.map(sync, grads, dp_rep)

    # ---- phase 2: global grad norm ------------------------------------------
    def leaf_sq(g, rep_dp, rep_m, rep_e, p):
        s = jnp.sum(g * g)
        # dp accounting: dp-sharded grads (either via RS or natively) are
        # unique per dp-rank -> count once under psum(dp); leaves that stayed
        # replicated over dp (non-sharddable) would be counted dp times.
        if rep_dp and dp_n > 1 and not _sharddable(p, dp_n):
            s = s / dp_n
        if rep_m:
            s = s / compat.axis_size("model")
        # caller (train_step) already ep-averaged ep-replicated grads, so
        # they are identical across the EP axis -> count once under psum(ep)
        if ep_axis is not None and rep_e:
            s = s / ep_n
        return s

    # note: model-sharded leaves are NOT psum'd over 'model' here; instead
    # every leaf's local sq enters a psum over ('model',) weighted above.
    # grads are already pod-identical after sync -> no pod psum.
    total = sum(jax.tree.leaves(
        jax.tree.map(leaf_sq, gsync, dp_rep, model_rep, ep_rep, params)))
    axes = ["model"]
    if dp_axis is not None:
        axes.append(dp_axis)
    if ep_axis is not None:
        axes.append(ep_axis)
    total = lax.psum(total, tuple(axes))
    gnorm = jnp.sqrt(total)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-6))

    # ---- phase 3: update ------------------------------------------------------
    count = opt["count"] + 1
    c1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, mu, nu, rep):
        g = g * clip
        own = (rep and dp_axis is not None and dp_n > 1
               and _sharddable(p, dp_n))
        p_sh = _dp_shard(p, dp_axis) if own else p
        mdt = mu.dtype
        mu32 = cfg.b1 * mu.astype(jnp.float32) + (1 - cfg.b1) * g
        nu32 = cfg.b2 * nu.astype(jnp.float32) + (1 - cfg.b2) * g * g
        step = (mu32 / c1) / (jnp.sqrt(nu32 / c2) + cfg.eps)
        newp = p_sh.astype(jnp.float32) - lr * (
            step + cfg.weight_decay * p_sh.astype(jnp.float32))
        if own:
            # ZeRO re-assembly over the DATA axis (optimizer, not a TP seam)
            newp = lax.all_gather(  # lint: allow(raw-collective)
                newp, dp_axis, axis=0, tiled=True)
        return newp.astype(p.dtype), mu32.astype(mdt), nu32.astype(mdt)

    flat_p, tdef = jax.tree.flatten(params)
    zipped = zip(flat_p, jax.tree.leaves(gsync), jax.tree.leaves(opt["mu"]),
                 jax.tree.leaves(opt["nu"]), jax.tree.leaves(dp_rep))
    out_p, out_mu, out_nu = [], [], []
    for p, g, mu, nu, rep in zipped:
        a, b, c = upd(p, g, mu, nu, rep)
        out_p.append(a)
        out_mu.append(b)
        out_nu.append(c)
    return (jax.tree.unflatten(tdef, out_p),
            {"mu": jax.tree.unflatten(tdef, out_mu),
             "nu": jax.tree.unflatten(tdef, out_nu),
             "count": count})
