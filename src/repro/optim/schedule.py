"""LR schedules: cosine (default) and WSD (warmup-stable-decay, MiniCPM)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine(step, *, base_lr: float, warmup: int, total: int,
           min_ratio: float = 0.1):
    s = jnp.asarray(step, jnp.float32)
    warm = base_lr * s / max(warmup, 1)
    prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = base_lr * (min_ratio + (1 - min_ratio) * 0.5
                     * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(s < warmup, warm, cos)


def wsd(step, *, base_lr: float, warmup: int, total: int,
        decay_frac: float = 0.1, min_ratio: float = 0.01):
    """Warmup-Stable-Decay (MiniCPM): flat plateau, sharp final decay."""
    s = jnp.asarray(step, jnp.float32)
    decay_start = total * (1.0 - decay_frac)
    warm = base_lr * s / max(warmup, 1)
    stable = jnp.full_like(s, base_lr)
    prog = jnp.clip((s - decay_start) / max(total - decay_start, 1), 0.0, 1.0)
    decay = base_lr * (min_ratio ** prog)      # exponential anneal
    out = jnp.where(s < warmup, warm, jnp.where(s < decay_start, stable, decay))
    return out


def get_schedule(name: str):
    return {"cosine": cosine, "wsd": wsd}[name]
