"""RWKV-6 (Finch) blocks: time-mix with data-dependent decay + channel-mix.

TP mapping: heads (time-mix) / hidden (channel-mix) sharded over the model
axis; the WKV recurrence itself is head-local (no TP collective — partial
FLUX applicability, DESIGN.md §5).  Projections use the overlap seams.

WKV6 recurrence per head (state S: [dh_k, dh_v]):
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
computed CHUNKWISE (flash-linear-attention style): within a chunk the
quadratic form with decay-ratio masking; across chunks the state carries.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.core import overlap
from repro.models import layers
from repro.parallel.sharding import TPContext, ceil_mult

Array = jax.Array


def _dims(cfg: ModelConfig, tp: int):
    rc = cfg.rwkv
    dh = rc.head_dim
    n_heads = ceil_mult(cfg.d_model // dh, tp)          # padded to TP
    d_attn = n_heads * dh
    return n_heads, dh, d_attn


def init_rwkv_time(key, cfg: ModelConfig, tp: int, dtype=jnp.bfloat16) -> Dict:
    rc = cfg.rwkv
    dm = cfg.d_model
    n_heads, dh, d_attn = _dims(cfg, tp)
    from repro.models import init_utils as iu
    ks = jax.random.split(key, 10)
    std = dm ** -0.5
    d_can = (cfg.d_model // dh) * dh                 # canonical head columns
    zc = lambda k, shape, s: iu.zero_pad_cols(
        jax.random.normal(k, shape) * s, d_attn)
    return {
        # token-shift mix coefficients (per projection)
        "mu": (jax.random.uniform(ks[0], (5, dm))).astype(dtype),
        "w_r": zc(ks[1], (dm, d_can), std).astype(dtype),
        "w_k": zc(ks[2], (dm, d_can), std).astype(dtype),
        "w_v": zc(ks[3], (dm, d_can), std).astype(dtype),
        "w_g": zc(ks[4], (dm, d_can), std).astype(dtype),
        # data-dependent decay: low-rank lora on top of a per-channel base
        "w_dec1": (jax.random.normal(ks[5], (dm, rc.decay_lora))
                   * std).astype(dtype),
        "w_dec2": zc(ks[6], (rc.decay_lora, d_can),
                     rc.decay_lora ** -0.5).astype(dtype),
        "dec_base": jnp.full((d_attn,), -6.0, jnp.float32),
        "u_bonus": iu.zero_pad_cols(
            (jax.random.normal(ks[7], (d_can,)) * 0.1)[None], d_attn)[0],
        "w_o": iu.zero_pad_rows(
            jax.random.normal(ks[8], (d_can, dm)) * d_can ** -0.5,
            d_attn).astype(dtype),
        "ln_x": layers.init_rms_norm(dh, dtype),     # per-head group norm
        "norm": layers.init_rms_norm(dm, dtype),
    }


def init_rwkv_channel(key, cfg: ModelConfig, tp: int,
                      dtype=jnp.bfloat16) -> Dict:
    dm = cfg.d_model
    ffp = ceil_mult(cfg.d_ff, tp * 128)
    from repro.models import init_utils as iu
    ks = jax.random.split(key, 4)
    std = dm ** -0.5
    return {
        "mu": (jax.random.uniform(ks[0], (2, dm))).astype(dtype),
        "w_k": iu.zero_pad_cols(
            jax.random.normal(ks[1], (dm, cfg.d_ff)) * std, ffp).astype(dtype),
        "w_v": iu.zero_pad_rows(
            jax.random.normal(ks[2], (cfg.d_ff, dm)) * cfg.d_ff ** -0.5,
            ffp).astype(dtype),
        "w_r": (jax.random.normal(ks[3], (dm, dm)) * std).astype(dtype),
        "norm": layers.init_rms_norm(dm, dtype),
    }


def _wkv_chunk(r, k, v, logw, u, s0):
    """One chunk, one head batch: r,k,v: [B,H,L,dh]; logw: [B,H,L,dh] (<=0);
    u: [H,dh]; s0: [B,H,dh,dh].  Returns (y [B,H,L,dh], s_final)."""
    _, _, L, dh = r.shape
    cw = jnp.cumsum(logw, axis=2)                        # cumulative log decay
    # inter-chunk: y_t += (r_t * exp(cw_{t-1})) @ S_prev ; cw_{t-1} = cw_t - logw_t
    r_dec = r * jnp.exp(cw - logw)
    y = jnp.einsum("bhld,bhde->bhle", r_dec, s0)
    # intra-chunk: A[t,s] = sum_d r[t,d] k[s,d] exp(cw_{t-1,d} - cw_{s,d}) (s<t)
    #              diag  : r·(u⊙k)
    kd = k * jnp.exp(-cw)                                # k / prod decay up to s
    att = jnp.einsum("bhld,bhmd->bhlm", r_dec, kd)
    mask = jnp.tril(jnp.ones((L, L), bool), k=-1)
    att = jnp.where(mask, att, 0.0)
    diag = jnp.einsum("bhld,bhld->bhl", r, u[None, :, None, :] * k)
    y = y + jnp.einsum("bhlm,bhme->bhle", att, v)
    y = y + diag[..., None] * v
    # state update: S_new = diag(exp(cw_L)) S_prev + sum_t exp(cw_L - cw_t) k_t v_t^T
    wtot = jnp.exp(cw[:, :, -1])                         # [B,H,dh]
    k_rem = k * jnp.exp(cw[:, :, -1:] - cw)
    s_new = s0 * wtot[..., None] + jnp.einsum("bhld,bhle->bhde", k_rem, v)
    return y, s_new


def rwkv_time_train(p: Dict, x: Array, ctx: TPContext, cfg: ModelConfig,
                    chunk: int = 64, with_cache: bool = False,
                    lengths=None, cache=None):
    """x: [B, S/TP, D] -> [B, S/TP, D].

    ``lengths`` ([B] int32, optional): per-row true prompt lengths for a
    right-padded batched prefill.  Pad positions get k=0 and logw=0 (decay
    exp(0)=1): ``S_t = diag(1) S_{t-1} + 0`` leaves the WKV state INVARIANT,
    so the returned ``state`` cache is exactly each row's state after its
    true prompt and ``last`` is the true final token's normed input.

    ``cache`` ({state, last}, optional): position-0 recurrent state —
    seeds a CHUNKED prefill continuing a previous chunk (replicated layout
    only: the token-shift boundary is the previous chunk's last token)."""
    n_heads, dh, d_attn = _dims(cfg, ctx.tp)
    hl = n_heads // ctx.tp
    b, s_loc, dm = x.shape
    s = s_loc * ctx.seq_factor
    assert cache is None or not ctx.seq_sharded

    h = layers.rms_norm(x, p["norm"], cfg.norm_eps)
    # token shift needs x_{t-1}: boundary ppermute on the shard (one-token
    # exchange; local shift in the replicated layout)
    prev = layers.shift_tokens_right(h, ctx)
    if cache is not None:
        prev = jnp.concatenate([cache["last"].astype(h.dtype)[:, None, :],
                                prev[:, 1:]], axis=1)

    # ALL FIVE token-shift projections ride ONE shared-gather AG seam: the
    # per-projection mix  mixed_i = (1-mu_i)*h + mu_i*prev  commutes into
    # the weights —  mixed_i @ W = [h | prev] @ [(1-mu_i)*W ; mu_i*W]  — so
    # the concatenated [h, prev] activation is gathered ONCE for r/k/v/g
    # and the decay lora (the pre-refactor code paid two standalone
    # full-activation all_gathers here).
    xcat = jnp.concatenate([h, prev], axis=-1)           # [B, S_loc, 2D]

    def stacked(i, w):
        mu_i = p["mu"][i].astype(w.dtype)
        return jnp.concatenate([(1 - mu_i)[:, None] * w,
                                mu_i[:, None] * w], axis=0)

    r, kk, vv, g, dec_low = ctx.op("attn_ag", n_weights=5)(
        xcat, stacked(0, p["w_r"]), stacked(1, p["w_k"]),
        stacked(2, p["w_v"]), stacked(3, p["w_g"]),
        stacked(4, p["w_dec1"]))
    dec = jnp.einsum("bsr,rf->bsf", jnp.tanh(dec_low), p["w_dec2"])
    logw = -jnp.exp(p["dec_base"] + dec.astype(jnp.float32))  # [B,S,F] (<0)

    def heads(t):
        return t.reshape(b, s, hl, dh).transpose(0, 2, 1, 3).astype(jnp.float32)

    r_, k_, v_, w_ = heads(r), heads(kk), heads(vv), heads(logw)
    if lengths is not None:
        in_prompt = (jnp.arange(s)[None, :]
                     < lengths[:, None])[:, None, :, None]      # [B,1,S,1]
        k_ = jnp.where(in_prompt, k_, 0.0)
        w_ = jnp.where(in_prompt, w_, 0.0)
    # u_bonus / dec_base are head-sharded over TP -> already local here
    u_loc = p["u_bonus"].reshape(hl, dh)

    chunk = min(chunk, s)
    while s % chunk:
        chunk //= 2
    nck = s // chunk

    def step(state, i):
        sl = lambda t: lax.dynamic_slice_in_dim(t, i * chunk, chunk, axis=2)
        y, snew = _wkv_chunk(sl(r_), sl(k_), sl(v_), sl(w_), u_loc, state)
        return snew, y

    s0 = (jnp.zeros((b, hl, dh, dh), jnp.float32) if cache is None
          else cache["state"].astype(jnp.float32))
    sfin, ys = lax.scan(step, s0, jnp.arange(nck))
    y = jnp.moveaxis(ys, 0, 2).reshape(b, hl, s, dh)     # [B,hl,S,dh]
    y = y.transpose(0, 2, 1, 3).reshape(b, s, hl * dh).astype(x.dtype)

    # per-head group norm (pad heads stay zero -> TP-layout invariant)
    y = layers.rms_norm(y.reshape(b, s, hl, dh), p["ln_x"],
                        cfg.norm_eps).reshape(b, s, hl * dh)
    y = y * jax.nn.silu(g)
    out = ctx.op("attn_rs")(y, p["w_o"])
    if with_cache:
        # decode seeds token-shift with the last true token's normed input;
        # cache payloads ride the seam's ring transport (gather_seq)
        if lengths is None:
            last = ctx.gather_seq(h[:, -1:], "attn_ag")[:, -1]
        else:
            last = layers.take_rows(ctx.gather_seq(h, "attn_ag"),
                                    lengths - 1)
        return out, {"state": sfin, "last": last}
    return out


def rwkv_channel_train(p: Dict, x: Array, ctx: TPContext,
                       cfg: ModelConfig, with_cache: bool = False,
                       lengths=None, cache=None):
    """``cache`` ({last}, optional): seeds the token shift for a CHUNKED
    prefill continuing a previous chunk (replicated layout only)."""
    assert cache is None or not ctx.seq_sharded
    h = layers.rms_norm(x, p["norm"], cfg.norm_eps)
    prev = layers.shift_tokens_right(h, ctx)
    if cache is not None:
        prev = jnp.concatenate([cache["last"].astype(h.dtype)[:, None, :],
                                prev[:, 1:]], axis=1)
    delta = prev - h
    xk = h + delta * p["mu"][0]
    xr = h + delta * p["mu"][1]
    # squared-relu fuses into the AllGather seam's per-chunk epilogue
    k = ctx.op("mlp_ag", epilogue=overlap.Epilogue(
        activation="sqrelu"))(xk, p["w_k"])
    kv = ctx.op("mlp_rs")(k, p["w_v"])
    # receptance gate: replicated square weight, computed on the seq-shard
    r = jnp.einsum("bsd,de->bse", xr, p["w_r"])
    out = jax.nn.sigmoid(r) * kv
    if with_cache:
        # last (global) token's normed input: gather the final shard's tail
        # (full gather + per-row take only when ``lengths`` staggers rows)
        if lengths is None:
            hg_last = ctx.gather_seq(h[:, -1:], "attn_ag")[:, -1]
        else:
            hg_last = layers.take_rows(ctx.gather_seq(h, "attn_ag"),
                                       lengths - 1)
        return out, {"last": hg_last}
    return out


def rwkv_time_decode(p: Dict, x: Array, cache: Dict, ctx: TPContext,
                     cfg: ModelConfig) -> Tuple[Array, Dict]:
    """cache = {state: [B, hl, dh, dh] f32, last: [B, D]} — O(1) decode."""
    n_heads, dh, d_attn = _dims(cfg, ctx.tp)
    hl = n_heads // ctx.tp
    b = x.shape[0]

    h = layers.rms_norm(x, p["norm"], cfg.norm_eps)[:, 0]  # [B, D]
    prev = cache["last"]
    delta = prev - h

    def mixed(i):
        return h + delta * p["mu"][i]

    r = mixed(0) @ p["w_r"]
    kk = mixed(1) @ p["w_k"]
    vv = mixed(2) @ p["w_v"]
    g = mixed(3) @ p["w_g"]
    dec = jnp.tanh(mixed(4) @ p["w_dec1"]) @ p["w_dec2"]
    logw = -jnp.exp(p["dec_base"] + dec.astype(jnp.float32))

    hd = lambda t: t.reshape(b, hl, dh).astype(jnp.float32)
    r_, k_, v_, w_ = hd(r), hd(kk), hd(vv), hd(logw)
    u_loc = p["u_bonus"].reshape(hl, dh)

    s_prev = cache["state"]
    kv = jnp.einsum("bhd,bhe->bhde", k_, v_)
    y = jnp.einsum("bhd,bhde->bhe", r_, s_prev + u_loc[None, :, :, None] * kv)
    s_new = s_prev * jnp.exp(w_)[..., None] + kv

    y = y.reshape(b, 1, hl, dh).astype(x.dtype)
    y = layers.rms_norm(y, p["ln_x"], cfg.norm_eps).reshape(b, 1, hl * dh)
    y = y * jax.nn.silu(g.reshape(b, 1, hl * dh))
    out = ctx.op("decode_ar")(y, p["w_o"])
    return out, {"state": s_new, "last": h}


def rwkv_channel_decode(p: Dict, x: Array, cache: Dict, ctx: TPContext,
                        cfg: ModelConfig) -> Tuple[Array, Dict]:
    h = layers.rms_norm(x, p["norm"], cfg.norm_eps)[:, 0]
    prev = cache["last"]
    delta = prev - h
    xk = (h + delta * p["mu"][0])[:, None]
    xr = (h + delta * p["mu"][1])[:, None]
    k = jnp.square(jax.nn.relu(jnp.einsum("bsd,df->bsf", xk, p["w_k"])))
    kv = ctx.op("decode_ar")(k, p["w_v"])
    r = jnp.einsum("bsd,de->bse", xr, p["w_r"])
    return jax.nn.sigmoid(r) * kv, {"last": h}
