"""Attention blocks: GQA (with RoPE / M-RoPE) and DeepSeek MLA.

Train/prefill path (layout per ``ctx.seq_sharded``; Megatron-SP default):
    x[B,S/TP,D] --attn_ag op--> qkv[B,S,local heads] (FLUX prologue seam)
    blocked causal attention (local heads, full sequence)
    attn_out --attn_rs op--> [B,S/TP,D]              (FLUX epilogue seam)
  Replicated layout: the same seams with scatter_axis="hidden" — x stays
  [B,S,D], the AG side is a local GEMM and the RS side an AllReduce.

Decode path (x replicated over TP, batch-sharded over DP):
    local-head QKV projections, KV-cache append, single-token attention,
    output projection via the decode_ar seam (GEMM+AllReduce).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.core import overlap
from repro.models import layers
from repro.parallel.sharding import TPContext, pad_heads, pad_kv_heads

Array = jax.Array


# ---------------------------------------------------------------------------
# Blocked causal attention (pure-jnp flash; differentiable; O(S·block) memory)
# ---------------------------------------------------------------------------
def blocked_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                      block_q: int = 512, block_kv: int = 1024,
                      scale: Optional[float] = None) -> Array:
    """q: [B,H,Sq,Dh], k: [B,Hkv,Skv,Dh], v: [B,Hkv,Skv,Dv] (Dv may differ —
    MLA); GQA via head broadcast."""
    b, hq, sq, dh = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    scale = scale or dh ** -0.5
    group = hq // hkv
    qg = q.reshape(b, hkv, group, sq, dh)

    block_q = min(block_q, sq)
    while sq % block_q:
        block_q //= 2
    block_kv = min(block_kv, skv)
    while skv % block_kv:
        block_kv //= 2
    nq, nkv = sq // block_q, skv // block_kv
    kv_off = skv - sq  # q positions are the suffix of the kv timeline

    qb = qg.reshape(b, hkv, group, nq, block_q, dh)
    kb = k.reshape(b, hkv, nkv, block_kv, dh)
    vb = v.reshape(b, hkv, nkv, block_kv, dv)

    def q_block(qi, qblk):
        # online softmax over kv blocks
        def step(carry, j):
            m, l, acc = carry
            kj = lax.dynamic_index_in_dim(kb, j, axis=2, keepdims=False)
            vj = lax.dynamic_index_in_dim(vb, j, axis=2, keepdims=False)
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qblk.astype(jnp.float32),
                           kj.astype(jnp.float32)) * scale
            if causal:
                qpos = kv_off + qi * block_q + jnp.arange(block_q)
                kpos = j * block_kv + jnp.arange(block_kv)
                mask = qpos[:, None] >= kpos[None, :]
                s = jnp.where(mask, s, -1e30)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
            p = jnp.exp(s - m_new)
            alpha = jnp.exp(m - m_new)
            l = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
            acc = acc * alpha + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p, vj.astype(jnp.float32))
            return (m_new, l, acc), None

        m0 = jnp.full((b, hkv, group, block_q, 1), -1e30, jnp.float32)
        l0 = jnp.zeros((b, hkv, group, block_q, 1), jnp.float32)
        a0 = jnp.zeros((b, hkv, group, block_q, dv), jnp.float32)
        # causal: kv blocks beyond this q block contribute nothing; still
        # scanned (static shapes) but masked out — remat keeps memory flat.
        (m, l, acc), _ = lax.scan(step, (m0, l0, a0), jnp.arange(nkv))
        return (acc / jnp.maximum(l, 1e-30)).astype(q.dtype)

    outs = [q_block(qi, qb[:, :, :, qi]) for qi in range(nq)]
    out = jnp.stack(outs, axis=3)  # [b,hkv,group,nq,bq,dh]
    return out.reshape(b, hq, sq, dv)


# ---------------------------------------------------------------------------
# GQA block
# ---------------------------------------------------------------------------
class AttnDims(NamedTuple):
    h_pad: int
    hkv_pad: int
    dh: int

    @staticmethod
    def of(cfg: ModelConfig, tp: int) -> "AttnDims":
        return AttnDims(pad_heads(cfg.num_heads, tp),
                        pad_kv_heads(cfg.num_kv_heads, tp),
                        cfg.resolved_head_dim)


def init_gqa(key, cfg: ModelConfig, tp: int, dtype=jnp.bfloat16) -> Dict:
    """Canonical (TP-independent) init, packed into the per-device
    interleaved QKV layout; padded heads are ZERO (function-preserving)."""
    from repro.models import init_utils as iu
    d = AttnDims.of(cfg, tp)
    dm = cfg.d_model
    k1, k2, k3, k4 = jax.random.split(key, 4)
    std = dm ** -0.5
    wq = (jax.random.normal(k1, (dm, cfg.num_heads * d.dh)) * std)
    wk = (jax.random.normal(k2, (dm, cfg.num_kv_heads * d.dh)) * std)
    wv = (jax.random.normal(k3, (dm, cfg.num_kv_heads * d.dh)) * std)
    wq = iu.interleave_heads(wq, cfg.num_heads, d.dh, tp, d.h_pad)
    wk = iu.replicate_kv_heads(wk, cfg.num_kv_heads, d.dh, tp, d.hkv_pad)
    wv = iu.replicate_kv_heads(wv, cfg.num_kv_heads, d.dh, tp, d.hkv_pad)
    wqkv = iu.pack_qkv(wq, wk, wv, tp)
    wo = (jax.random.normal(k4, (cfg.num_heads * d.dh, dm)) * std)
    wo = iu.zero_pad_rows(wo, d.h_pad * d.dh)
    p = {
        "wqkv": wqkv.astype(dtype),
        "wo": wo.astype(dtype),
        "norm": layers.init_rms_norm(dm, dtype),
    }
    if cfg.qkv_bias:
        p["bqkv"] = jnp.zeros(((d.h_pad + 2 * d.hkv_pad) * d.dh,), dtype)
    return p


def gqa_train(p: Dict, x: Array, ctx: TPContext, cfg: ModelConfig,
              positions_3d: Optional[Array] = None, with_cache: bool = False):
    """x: [B, S/TP, D] -> [B, S/TP, D] (pre-norm residual block body; the
    replicated layout runs [B, S, D] -> [B, S, D] — same seams, hidden
    scatter).  with_cache=True additionally returns the prefill KV cache."""
    tp = ctx.tp
    d = AttnDims.of(cfg, tp)
    hl, hkvl = d.h_pad // tp, d.hkv_pad // tp
    b, s_loc, _ = x.shape
    s = s_loc * ctx.seq_factor

    h = layers.rms_norm(x, p["norm"], cfg.norm_eps)
    # QKV bias rides the AllGather seam's fused epilogue (per chunk in the
    # ring modes, in the tile epilogue for the flux kernel)
    qkv = ctx.op("attn_ag", epilogue=overlap.Epilogue(bias="bqkv" in p))(
        h, p["wqkv"], bias=p.get("bqkv"))
    q, k, v = jnp.split(qkv, [hl * d.dh, (hl + hkvl) * d.dh], axis=-1)
    q = q.reshape(b, s, hl, d.dh)
    k = k.reshape(b, s, hkvl, d.dh)
    v = v.reshape(b, s, hkvl, d.dh)

    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    if cfg.rope_style == "mrope":
        p3 = positions_3d if positions_3d is not None else \
            jnp.broadcast_to(pos, (3, b, s))
        q = layers.apply_mrope(q, p3, cfg.rope_theta)
        k = layers.apply_mrope(k, p3, cfg.rope_theta)
    elif cfg.rope_style == "rope":
        q = layers.apply_rope(q, pos, cfg.rope_theta)
        k = layers.apply_rope(k, pos, cfg.rope_theta)

    if ctx.use_kernels:
        # fused flash kernel: K/V stream in bf16 once per q-row block, no
        # fp32 score round-trip (4th §Perf iteration — prefill memory)
        from repro.kernels.flash_attention import flash_attention
        attn = flash_attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=True)
    else:
        attn = blocked_attention(q.transpose(0, 2, 1, 3),
                                 k.transpose(0, 2, 1, 3),
                                 v.transpose(0, 2, 1, 3))
    attn = attn.transpose(0, 2, 1, 3).reshape(b, s, hl * d.dh)
    out = ctx.op("attn_rs")(attn, p["wo"])
    if with_cache:
        return out, {"k": k.astype(jnp.bfloat16), "v": v.astype(jnp.bfloat16)}
    return out


def gqa_decode(p: Dict, x: Array, cache: Dict, pos: Array, ctx: TPContext,
               cfg: ModelConfig) -> Tuple[Array, Dict]:
    """x: [B, 1, D] replicated over TP; cache: {k,v: [B, S_max, Hkv_l, Dh]}.
    ``pos``: [B] int32 — each row's own write position (continuous batching
    decodes staggered slots in one step).  Returns (out [B,1,D], new cache)."""
    tp = ctx.tp
    d = AttnDims.of(cfg, tp)
    hl, hkvl = d.h_pad // tp, d.hkv_pad // tp
    b = x.shape[0]

    h = layers.rms_norm(x, p["norm"], cfg.norm_eps)
    qkv = jnp.einsum("bsd,df->bsf", h, p["wqkv"])  # local columns; no comm
    if "bqkv" in p:
        qkv = qkv + p["bqkv"]
    q, k, v = jnp.split(qkv, [hl * d.dh, (hl + hkvl) * d.dh], axis=-1)
    q = q.reshape(b, 1, hl, d.dh)
    k = k.reshape(b, 1, hkvl, d.dh)
    v = v.reshape(b, 1, hkvl, d.dh)

    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (b,))
    pb = pos[:, None]                                    # [B, 1] per-row RoPE
    if cfg.rope_style in ("rope", "mrope"):
        q = layers.apply_rope(q, pb, cfg.rope_theta)
        k = layers.apply_rope(k, pb, cfg.rope_theta)

    ck = layers.cache_update_rows(cache["k"], k, pos)
    cv = layers.cache_update_rows(cache["v"], v, pos)

    # single-token attention over the cache (memory-bound; roofline's decode
    # bottleneck).  per-row mask: row b attends to positions <= pos[b].
    s_max = ck.shape[1]
    group = hl // hkvl
    qg = q.reshape(b, 1, hkvl, group, d.dh)
    scores = jnp.einsum("bohgd,bshd->bhgos", qg.astype(jnp.float32),
                        ck.astype(jnp.float32)) * (d.dh ** -0.5)
    valid = (jnp.arange(s_max)[None, :] <= pos[:, None])[:, None, None, None, :]
    scores = jnp.where(valid, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    attn = jnp.einsum("bhgos,bshd->bohgd", w, cv.astype(jnp.float32))
    attn = attn.reshape(b, 1, hl * d.dh).astype(x.dtype)

    out = ctx.op("decode_ar")(attn, p["wo"])
    return out, {"k": ck, "v": cv}


def gqa_decode_paged(p: Dict, x: Array, cache: Dict, bt: Array, pos: Array,
                     ctx: TPContext, cfg: ModelConfig) -> Tuple[Array, Dict]:
    """``gqa_decode`` through the paged KV pool: identical math, but K/V
    rows live in shared physical blocks addressed through each slot's
    block table.  cache: {k,v: [N_blocks, bs, Hkv_l, Dh]}; bt: [B, P]
    int32 (inactive slots pass all-zero rows — their writes land in the
    null block and their outputs are discarded by the server)."""
    tp = ctx.tp
    d = AttnDims.of(cfg, tp)
    hl, hkvl = d.h_pad // tp, d.hkv_pad // tp
    b = x.shape[0]

    h = layers.rms_norm(x, p["norm"], cfg.norm_eps)
    qkv = jnp.einsum("bsd,df->bsf", h, p["wqkv"])  # local columns; no comm
    if "bqkv" in p:
        qkv = qkv + p["bqkv"]
    q, k, v = jnp.split(qkv, [hl * d.dh, (hl + hkvl) * d.dh], axis=-1)
    q = q.reshape(b, 1, hl, d.dh)
    k = k.reshape(b, 1, hkvl, d.dh)
    v = v.reshape(b, 1, hkvl, d.dh)

    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (b,))
    pb = pos[:, None]
    if cfg.rope_style in ("rope", "mrope"):
        q = layers.apply_rope(q, pb, cfg.rope_theta)
        k = layers.apply_rope(k, pb, cfg.rope_theta)

    ck = layers.pool_update_rows(cache["k"], k, bt, pos)
    cv = layers.pool_update_rows(cache["v"], v, bt, pos)
    kview = layers.pool_view(ck, bt)               # [B, P*bs, Hkv_l, Dh]
    vview = layers.pool_view(cv, bt)

    s_tot = kview.shape[1]
    group = hl // hkvl
    qg = q.reshape(b, 1, hkvl, group, d.dh)
    scores = jnp.einsum("bohgd,bshd->bhgos", qg.astype(jnp.float32),
                        kview.astype(jnp.float32)) * (d.dh ** -0.5)
    valid = (jnp.arange(s_tot)[None, :] <= pos[:, None])[:, None, None, None, :]
    scores = jnp.where(valid, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    attn = jnp.einsum("bhgos,bshd->bohgd", w, vview.astype(jnp.float32))
    attn = attn.reshape(b, 1, hl * d.dh).astype(x.dtype)

    out = ctx.op("decode_ar")(attn, p["wo"])
    return out, {"k": ck, "v": cv}


def gqa_prefill_chunk(p: Dict, x: Array, cache: Dict, bt: Array, off,
                      chunk_len, ctx: TPContext, cfg: ModelConfig
                      ) -> Tuple[Array, Dict]:
    """One fixed-size chunk of an incremental paged prefill.

    x: [B, C, D] REPLICATED (chunked prefill always runs the replicated
    layout — a bounded chunk has no SP residency to win and no tp-divisible
    length constraint); cache: {k,v: [N_blocks, bs, Hkv_l, Dh]} pools;
    bt: [B, P]; off / chunk_len: int32 scalars.  The chunk's K/V rows are
    written through the table FIRST (pad rows past ``chunk_len`` redirect
    to the null block), then scores mask ``kpos <= off + i`` per chunk row
    over the whole gathered view — earlier chunks' and reused prefix
    blocks' K/V participate exactly as in a full prefill, so chunked
    results are independent of the chunk grouping."""
    tp = ctx.tp
    d = AttnDims.of(cfg, tp)
    hl, hkvl = d.h_pad // tp, d.hkv_pad // tp
    b, c_len, _ = x.shape

    h = layers.rms_norm(x, p["norm"], cfg.norm_eps)
    qkv = ctx.op("attn_ag", epilogue=overlap.Epilogue(bias="bqkv" in p))(
        h, p["wqkv"], bias=p.get("bqkv"))
    q, k, v = jnp.split(qkv, [hl * d.dh, (hl + hkvl) * d.dh], axis=-1)
    q = q.reshape(b, c_len, hl, d.dh)
    k = k.reshape(b, c_len, hkvl, d.dh)
    v = v.reshape(b, c_len, hkvl, d.dh)

    off = jnp.asarray(off, jnp.int32)
    qpos = off + jnp.arange(c_len, dtype=jnp.int32)       # absolute positions
    posb = jnp.broadcast_to(qpos, (b, c_len))
    if cfg.rope_style in ("rope", "mrope"):
        q = layers.apply_rope(q, posb, cfg.rope_theta)
        k = layers.apply_rope(k, posb, cfg.rope_theta)

    offv = jnp.broadcast_to(off, (b,))
    lenv = jnp.broadcast_to(jnp.asarray(chunk_len, jnp.int32), (b,))
    ck = layers.pool_update_rows(cache["k"], k, bt, offv, valid=lenv)
    cv = layers.pool_update_rows(cache["v"], v, bt, offv, valid=lenv)
    kview = layers.pool_view(ck, bt)
    vview = layers.pool_view(cv, bt)

    s_tot = kview.shape[1]
    group = hl // hkvl
    qg = q.reshape(b, c_len, hkvl, group, d.dh)
    scores = jnp.einsum("bqhgd,bshd->bhgqs", qg.astype(jnp.float32),
                        kview.astype(jnp.float32)) * (d.dh ** -0.5)
    valid = (jnp.arange(s_tot)[None, :] <= qpos[:, None])[None, None, None]
    scores = jnp.where(valid, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    attn = jnp.einsum("bhgqs,bshd->bqhgd", w, vview.astype(jnp.float32))
    attn = attn.reshape(b, c_len, hl * d.dh).astype(x.dtype)
    out = ctx.op("attn_rs")(attn, p["wo"])
    return out, {"k": ck, "v": cv}


def gqa_cache_spec(cfg: ModelConfig, tp: int, batch_local: int, s_max: int,
                   dtype=jnp.bfloat16) -> Dict:
    d = AttnDims.of(cfg, tp)
    hkvl = d.hkv_pad // tp
    shape = (batch_local, s_max, hkvl, d.dh)
    return {"k": jax.ShapeDtypeStruct(shape, dtype),
            "v": jax.ShapeDtypeStruct(shape, dtype)}


# ---------------------------------------------------------------------------
# DeepSeek-V3 Multi-head Latent Attention
# ---------------------------------------------------------------------------
def init_mla(key, cfg: ModelConfig, tp: int, dtype=jnp.bfloat16) -> Dict:
    m = cfg.mla
    dm = cfg.d_model
    h_pad = pad_heads(cfg.num_heads, tp)
    ks = jax.random.split(key, 6)
    std = dm ** -0.5
    return {
        "w_dq": (jax.random.normal(ks[0], (dm, m.q_lora_rank)) * std).astype(dtype),
        "w_uq": (jax.random.normal(
            ks[1], (m.q_lora_rank,
                    h_pad * (m.qk_nope_head_dim + m.qk_rope_head_dim)))
            * m.q_lora_rank ** -0.5).astype(dtype),
        "w_dkv": (jax.random.normal(
            ks[2], (dm, m.kv_lora_rank + m.qk_rope_head_dim)) * std).astype(dtype),
        "w_ukv": (jax.random.normal(
            ks[3], (m.kv_lora_rank,
                    h_pad * (m.qk_nope_head_dim + m.v_head_dim)))
            * m.kv_lora_rank ** -0.5).astype(dtype),
        "w_o": (jax.random.normal(ks[4], (h_pad * m.v_head_dim, dm))
                * std).astype(dtype),
        "q_norm": layers.init_rms_norm(m.q_lora_rank, dtype),
        "kv_norm": layers.init_rms_norm(m.kv_lora_rank, dtype),
        "norm": layers.init_rms_norm(dm, dtype),
    }


def mla_train(p: Dict, x: Array, ctx: TPContext, cfg: ModelConfig,
              with_cache: bool = False):
    m = cfg.mla
    tp = ctx.tp
    h_pad = pad_heads(cfg.num_heads, tp)
    hl = h_pad // tp
    b, s_loc, _ = x.shape
    s = s_loc * ctx.seq_factor
    dqk = m.qk_nope_head_dim + m.qk_rope_head_dim

    h = layers.rms_norm(x, p["norm"], cfg.norm_eps)
    # latent down-projections: replicated weights, sequence-local compute
    q_lat = layers.rms_norm(jnp.einsum("bsd,dr->bsr", h, p["w_dq"]),
                            p["q_norm"], cfg.norm_eps)
    kv_all = jnp.einsum("bsd,dr->bsr", h, p["w_dkv"])
    kv_lat = layers.rms_norm(kv_all[..., :m.kv_lora_rank], p["kv_norm"],
                             cfg.norm_eps)
    k_rope_s = kv_all[..., m.kv_lora_rank:]             # [B, S/TP, dr] shared

    # RoPE on the shard (positions known locally), then gather sequence
    pos_loc = layers.seq_positions(b, s_loc, ctx)
    k_rope_s = layers.apply_rope(k_rope_s[:, :, None, :], pos_loc,
                                 cfg.rope_theta)[:, :, 0, :]

    # head up-projections: the FLUX AllGather-GEMM seams (distinct input
    # latents -> no gather sharing between them)
    ag_op = ctx.op("attn_ag")
    q = ag_op(q_lat, p["w_uq"]).reshape(b, s, hl, dqk)
    kv = ag_op(kv_lat, p["w_ukv"])
    kv = kv.reshape(b, s, hl, m.qk_nope_head_dim + m.v_head_dim)
    k_nope, v = jnp.split(kv, [m.qk_nope_head_dim], axis=-1)

    # the shared rope key is a non-GEMM seam payload: it rides the seam's
    # ring transport (no standalone all_gather; no-op when replicated)
    k_rope = ctx.gather_seq(k_rope_s, "attn_ag")
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = layers.apply_rope(q_rope, pos, cfg.rope_theta)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (b, s, hl, m.qk_rope_head_dim))], axis=-1)

    attn = blocked_attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                             v.transpose(0, 2, 1, 3),
                             scale=dqk ** -0.5)
    attn = attn.transpose(0, 2, 1, 3).reshape(b, s, hl * m.v_head_dim)
    out = ctx.op("attn_rs")(attn, p["w_o"])
    if with_cache:
        c_full = ctx.gather_seq(kv_lat, "attn_ag")
        return out, {"c": c_full.astype(jnp.bfloat16),
                     "kr": k_rope.astype(jnp.bfloat16)}
    return out


def mla_decode(p: Dict, x: Array, cache: Dict, pos: Array, ctx: TPContext,
               cfg: ModelConfig) -> Tuple[Array, Dict]:
    """Absorbed-form MLA decode: the KV cache stores only the latent
    (kv_lora_rank + rope) per token — DeepSeek's decode memory win.  The
    nope-scores absorb W_uk into the query; values absorb W_uv after the
    weighted latent sum.  ``pos``: [B] int32 per-row write positions."""
    m = cfg.mla
    tp = ctx.tp
    h_pad = pad_heads(cfg.num_heads, tp)
    hl = h_pad // tp
    b = x.shape[0]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (b,))

    h = layers.rms_norm(x, p["norm"], cfg.norm_eps)
    q_lat = layers.rms_norm(jnp.einsum("bsd,dr->bsr", h, p["w_dq"]),
                            p["q_norm"], cfg.norm_eps)
    kv_all = jnp.einsum("bsd,dr->bsr", h, p["w_dkv"])
    kv_lat = layers.rms_norm(kv_all[..., :m.kv_lora_rank], p["kv_norm"],
                             cfg.norm_eps)
    k_rope = kv_all[..., m.kv_lora_rank:]

    pb = pos[:, None]                                    # [B, 1] per-row RoPE
    k_rope = layers.apply_rope(k_rope[:, :, None, :], pb,
                               cfg.rope_theta)[:, :, 0, :]

    dqk = m.qk_nope_head_dim + m.qk_rope_head_dim
    q = jnp.einsum("bsr,rf->bsf", q_lat, p["w_uq"]).reshape(b, 1, hl, dqk)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = layers.apply_rope(q_rope, pb, cfg.rope_theta)

    # absorb W_uk: q_eff[b,1,h,r] = q_nope . W_uk[r, h, dn]
    w_ukv = p["w_ukv"].reshape(m.kv_lora_rank, hl,
                               m.qk_nope_head_dim + m.v_head_dim)
    w_uk = w_ukv[:, :, :m.qk_nope_head_dim]             # [r, h, dn]
    w_uv = w_ukv[:, :, m.qk_nope_head_dim:]             # [r, h, dv]
    q_eff = jnp.einsum("bohd,rhd->bohr", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))

    c_cache = layers.cache_update_rows(cache["c"], kv_lat, pos)
    r_cache = layers.cache_update_rows(cache["kr"], k_rope, pos)

    if ctx.use_kernels:
        # fused flash-style pass over the latent cache: ONE streaming read
        # instead of two + no fp32 score materialization (§Perf cell 3)
        from repro.kernels.mla_decode import mla_decode_attention
        ctx_lat = mla_decode_attention(
            q_eff[:, 0], q_rope[:, 0].astype(jnp.float32), c_cache, r_cache,
            pos + 1, scale=dqk ** -0.5)[:, None]
    else:
        s_max = c_cache.shape[1]
        scores = (jnp.einsum("bohr,bsr->bhos", q_eff,
                             c_cache.astype(jnp.float32))
                  + jnp.einsum("bohd,bsd->bhos", q_rope.astype(jnp.float32),
                               r_cache.astype(jnp.float32))) * (dqk ** -0.5)
        valid = (jnp.arange(s_max)[None, :] <= pos[:, None])[:, None, None, :]
        scores = jnp.where(valid, scores, -1e30)
        w = jax.nn.softmax(scores, axis=-1)
        ctx_lat = jnp.einsum("bhos,bsr->bohr", w,
                             c_cache.astype(jnp.float32))
    attn = jnp.einsum("bohr,rhd->bohd", ctx_lat, w_uv.astype(jnp.float32))
    attn = attn.reshape(b, 1, hl * m.v_head_dim).astype(x.dtype)
    out = ctx.op("decode_ar")(attn, p["w_o"])
    return out, {"c": c_cache, "kr": r_cache}


def mla_decode_paged(p: Dict, x: Array, cache: Dict, bt: Array, pos: Array,
                     ctx: TPContext, cfg: ModelConfig) -> Tuple[Array, Dict]:
    """Absorbed-form MLA decode over the paged latent pool.  cache:
    {c: [N_blocks, bs, rank], kr: [N_blocks, bs, rope_dim]}; bt: [B, P].
    The gathered per-row views are shaped like the dense caches, so the
    fused decode kernel path applies unchanged."""
    m = cfg.mla
    tp = ctx.tp
    h_pad = pad_heads(cfg.num_heads, tp)
    hl = h_pad // tp
    b = x.shape[0]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (b,))

    h = layers.rms_norm(x, p["norm"], cfg.norm_eps)
    q_lat = layers.rms_norm(jnp.einsum("bsd,dr->bsr", h, p["w_dq"]),
                            p["q_norm"], cfg.norm_eps)
    kv_all = jnp.einsum("bsd,dr->bsr", h, p["w_dkv"])
    kv_lat = layers.rms_norm(kv_all[..., :m.kv_lora_rank], p["kv_norm"],
                             cfg.norm_eps)
    k_rope = kv_all[..., m.kv_lora_rank:]

    pb = pos[:, None]
    k_rope = layers.apply_rope(k_rope[:, :, None, :], pb,
                               cfg.rope_theta)[:, :, 0, :]

    dqk = m.qk_nope_head_dim + m.qk_rope_head_dim
    q = jnp.einsum("bsr,rf->bsf", q_lat, p["w_uq"]).reshape(b, 1, hl, dqk)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = layers.apply_rope(q_rope, pb, cfg.rope_theta)

    w_ukv = p["w_ukv"].reshape(m.kv_lora_rank, hl,
                               m.qk_nope_head_dim + m.v_head_dim)
    w_uk = w_ukv[:, :, :m.qk_nope_head_dim]
    w_uv = w_ukv[:, :, m.qk_nope_head_dim:]
    q_eff = jnp.einsum("bohd,rhd->bohr", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))

    cc = layers.pool_update_rows(cache["c"], kv_lat, bt, pos)
    cr = layers.pool_update_rows(cache["kr"], k_rope, bt, pos)
    cview = layers.pool_view(cc, bt)               # [B, P*bs, rank]
    rview = layers.pool_view(cr, bt)

    if ctx.use_kernels:
        from repro.kernels.mla_decode import mla_decode_attention
        ctx_lat = mla_decode_attention(
            q_eff[:, 0], q_rope[:, 0].astype(jnp.float32), cview, rview,
            pos + 1, scale=dqk ** -0.5)[:, None]
    else:
        s_tot = cview.shape[1]
        scores = (jnp.einsum("bohr,bsr->bhos", q_eff,
                             cview.astype(jnp.float32))
                  + jnp.einsum("bohd,bsd->bhos", q_rope.astype(jnp.float32),
                               rview.astype(jnp.float32))) * (dqk ** -0.5)
        valid = (jnp.arange(s_tot)[None, :] <= pos[:, None])[:, None, None, :]
        scores = jnp.where(valid, scores, -1e30)
        w = jax.nn.softmax(scores, axis=-1)
        ctx_lat = jnp.einsum("bhos,bsr->bohr", w, cview.astype(jnp.float32))
    attn = jnp.einsum("bohr,rhd->bohd", ctx_lat, w_uv.astype(jnp.float32))
    attn = attn.reshape(b, 1, hl * m.v_head_dim).astype(x.dtype)
    out = ctx.op("decode_ar")(attn, p["w_o"])
    return out, {"c": cc, "kr": cr}


def mla_prefill_chunk(p: Dict, x: Array, cache: Dict, bt: Array, off,
                      chunk_len, ctx: TPContext, cfg: ModelConfig
                      ) -> Tuple[Array, Dict]:
    """Absorbed-form chunked prefill over the paged latent pool: the same
    math as ``mla_decode_paged`` with C query rows at a time (scores are
    identical to the non-absorbed prefill by associativity — q_nope·(W_uk c)
    = (q_nope W_uk)·c, both in fp32).  x: [B, C, D] replicated."""
    m = cfg.mla
    tp = ctx.tp
    h_pad = pad_heads(cfg.num_heads, tp)
    hl = h_pad // tp
    b, c_len, _ = x.shape

    h = layers.rms_norm(x, p["norm"], cfg.norm_eps)
    q_lat = layers.rms_norm(jnp.einsum("bsd,dr->bsr", h, p["w_dq"]),
                            p["q_norm"], cfg.norm_eps)
    kv_all = jnp.einsum("bsd,dr->bsr", h, p["w_dkv"])
    kv_lat = layers.rms_norm(kv_all[..., :m.kv_lora_rank], p["kv_norm"],
                             cfg.norm_eps)
    k_rope = kv_all[..., m.kv_lora_rank:]

    off = jnp.asarray(off, jnp.int32)
    qpos = off + jnp.arange(c_len, dtype=jnp.int32)
    posb = jnp.broadcast_to(qpos, (b, c_len))
    k_rope = layers.apply_rope(k_rope[:, :, None, :], posb,
                               cfg.rope_theta)[:, :, 0, :]

    dqk = m.qk_nope_head_dim + m.qk_rope_head_dim
    q = jnp.einsum("bsr,rf->bsf", q_lat, p["w_uq"]).reshape(b, c_len, hl, dqk)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = layers.apply_rope(q_rope, posb, cfg.rope_theta)

    w_ukv = p["w_ukv"].reshape(m.kv_lora_rank, hl,
                               m.qk_nope_head_dim + m.v_head_dim)
    w_uk = w_ukv[:, :, :m.qk_nope_head_dim]
    w_uv = w_ukv[:, :, m.qk_nope_head_dim:]
    q_eff = jnp.einsum("bqhd,rhd->bqhr", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))

    offv = jnp.broadcast_to(off, (b,))
    lenv = jnp.broadcast_to(jnp.asarray(chunk_len, jnp.int32), (b,))
    cc = layers.pool_update_rows(cache["c"], kv_lat, bt, offv, valid=lenv)
    cr = layers.pool_update_rows(cache["kr"], k_rope, bt, offv, valid=lenv)
    cview = layers.pool_view(cc, bt)
    rview = layers.pool_view(cr, bt)

    s_tot = cview.shape[1]
    scores = (jnp.einsum("bqhr,bsr->bhqs", q_eff, cview.astype(jnp.float32))
              + jnp.einsum("bqhd,bsd->bhqs", q_rope.astype(jnp.float32),
                           rview.astype(jnp.float32))) * (dqk ** -0.5)
    valid = (jnp.arange(s_tot)[None, :] <= qpos[:, None])[None, None]
    scores = jnp.where(valid, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    ctx_lat = jnp.einsum("bhqs,bsr->bqhr", w, cview.astype(jnp.float32))
    attn = jnp.einsum("bqhr,rhd->bqhd", ctx_lat, w_uv.astype(jnp.float32))
    attn = attn.reshape(b, c_len, hl * m.v_head_dim).astype(x.dtype)
    out = ctx.op("attn_rs")(attn, p["w_o"])
    return out, {"c": cc, "kr": cr}


def mla_cache_spec(cfg: ModelConfig, tp: int, batch_local: int, s_max: int,
                   dtype=jnp.bfloat16) -> Dict:
    m = cfg.mla
    return {"c": jax.ShapeDtypeStruct((batch_local, s_max, m.kv_lora_rank), dtype),
            "kr": jax.ShapeDtypeStruct((batch_local, s_max, m.qk_rope_head_dim),
                                       dtype)}
