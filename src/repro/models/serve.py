"""Serving paths: prefill (cache-building forward) and single-token decode.

Cache layout is GLOBAL (``compat.shard_map`` slices it): per layer-position
trees whose shapes come from ``cache_specs``.  Decode is the paper's
vLLM-style TP pattern: replicated activations, local-head attention over the
sharded KV cache, row-parallel output GEMM + AllReduce (the FLUX decode
seam).

Continuous-batching contract (what the runtime Server relies on):

* ``decode_step`` takes ``pos: [B]`` — a PER-SLOT position vector.  Every
  batch row RoPE-rotates at, masks to, and cache-writes at its OWN
  position (per-row ``dynamic_update_slice``), so slots at staggered
  sequence positions decode together in one fixed-shape dispatch without
  touching each other's cache rows.  A scalar ``pos`` still broadcasts (all
  rows in lockstep — the bench/smoke path).
* ``prefill_step`` takes optional ``lengths: [B]`` — per-row true prompt
  lengths of a RIGHT-PADDED token batch.  Attention families are pad-safe
  by causality; the state families (Mamba SSM/conv, RWKV WKV/token-shift)
  freeze their recurrent state at each row's true length (identity decay +
  zero input on pad positions), and the next-token logits are read at
  ``lengths - 1`` per row.  The returned caches are therefore exactly what
  a token-by-token decode of the unpadded prompt would have produced —
  admission scatters them into a slot's rows in one dispatch.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import (ATTN, DENSE_FFN, MLA, MAMBA, MOE_FFN, RWKV,
                                ModelConfig, ParallelConfig)
from repro.models import attention, ffn, layers, mamba, rwkv
from repro.models.model import (_maybe_gather_zero3, expanded_pattern,
                                n_periods, zero3_flags)
from repro.parallel.sharding import (TPContext, ceil_mult, gather_ranks,
                                     pad_kv_heads, pad_heads, pad_vocab)

Array = jax.Array


# ---------------------------------------------------------------------------
# Cache specs (global shapes + PartitionSpecs)
# ---------------------------------------------------------------------------
def _mixer_cache_spec(kind: str, cfg: ModelConfig, par: ParallelConfig,
                      batch: int, s_max: int, dp_axes: Tuple[str, ...]):
    dp = dp_axes if len(dp_axes) > 1 else (dp_axes[0] if dp_axes else None)
    tp = par.tp
    if kind == ATTN:
        hkv = pad_kv_heads(cfg.num_kv_heads, tp)
        dh = cfg.resolved_head_dim
        sds = {"k": jax.ShapeDtypeStruct((batch, s_max, hkv, dh), jnp.bfloat16),
               "v": jax.ShapeDtypeStruct((batch, s_max, hkv, dh), jnp.bfloat16)}
        spec = {"k": P(dp, None, "model", None), "v": P(dp, None, "model", None)}
        return sds, spec
    if kind == MLA:
        m = cfg.mla
        sds = {"c": jax.ShapeDtypeStruct((batch, s_max, m.kv_lora_rank),
                                         jnp.bfloat16),
               "kr": jax.ShapeDtypeStruct((batch, s_max, m.qk_rope_head_dim),
                                          jnp.bfloat16)}
        spec = {"c": P(dp, None, None), "kr": P(dp, None, None)}
        return sds, spec
    if kind == MAMBA:
        d_in, _, d_state, d_conv = mamba._dims(cfg, tp)
        sds = {"conv": jax.ShapeDtypeStruct((batch, d_conv - 1, d_in),
                                            jnp.bfloat16),
               "ssm": jax.ShapeDtypeStruct((batch, d_in, d_state),
                                           jnp.float32)}
        spec = {"conv": P(dp, None, "model"), "ssm": P(dp, "model", None)}
        return sds, spec
    if kind == RWKV:
        n_heads, dh, _ = rwkv._dims(cfg, tp)
        sds = {"state": jax.ShapeDtypeStruct((batch, n_heads, dh, dh),
                                             jnp.float32),
               "last": jax.ShapeDtypeStruct((batch, cfg.d_model), jnp.bfloat16)}
        spec = {"state": P(dp, "model", None, None), "last": P(dp, None)}
        return sds, spec
    raise ValueError(kind)


def _ffn_cache_spec(kind: str, cfg: ModelConfig, par: ParallelConfig,
                    batch: int, dp_axes: Tuple[str, ...]):
    dp = dp_axes if len(dp_axes) > 1 else (dp_axes[0] if dp_axes else None)
    if kind == RWKV:
        return ({"last": jax.ShapeDtypeStruct((batch, cfg.d_model),
                                              jnp.bfloat16)},
                {"last": P(dp, None)})
    return {}, {}


def cache_specs(cfg: ModelConfig, par: ParallelConfig, batch: int, s_max: int,
                dp_axes: Tuple[str, ...] = ("data",)):
    """Returns (ShapeDtypeStruct tree, PartitionSpec tree) for the full-model
    cache: {"lead": [...], "periods": [stacked per pattern position]}."""
    pat = expanded_pattern(cfg)
    lead = cfg.leading_dense_layers
    reps = n_periods(cfg)

    def one(kind_pair):
        msds, mspec = _mixer_cache_spec(kind_pair[0], cfg, par, batch, s_max,
                                        dp_axes)
        fsds, fspec = _ffn_cache_spec(kind_pair[1], cfg, par, batch, dp_axes)
        return ({"mixer": msds, "ffn": fsds},
                {"mixer": mspec, "ffn": fspec})

    sds: Dict[str, Any] = {"lead": [], "periods": []}
    spec: Dict[str, Any] = {"lead": [], "periods": []}
    for i in range(lead):
        s_, p_ = one(pat[i])
        sds["lead"].append(s_)
        spec["lead"].append(p_)
    for kp in cfg.pattern:
        s_, p_ = one(kp)
        s_ = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct((reps, *x.shape), x.dtype), s_)
        p_ = jax.tree.map(lambda sp: P(*([None] + list(sp))), p_,
                          is_leaf=lambda x: isinstance(x, P))
        sds["periods"].append(s_)
        spec["periods"].append(p_)
    return sds, spec


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------
def _mixer_decode(kind: str, p: Dict, x: Array, cache: Dict, pos, ctx,
                  cfg: ModelConfig):
    if kind == ATTN:
        return attention.gqa_decode(p, x, cache, pos, ctx, cfg)
    if kind == MLA:
        return attention.mla_decode(p, x, cache, pos, ctx, cfg)
    if kind == MAMBA:
        return mamba.mamba_decode(p, x, cache, pos, ctx, cfg)
    if kind == RWKV:
        return rwkv.rwkv_time_decode(p, x, cache, ctx, cfg)
    raise ValueError(kind)


def _ffn_decode(kind: str, p: Dict, x: Array, cache: Dict, ctx,
                cfg: ModelConfig):
    if kind == DENSE_FFN:
        return ffn.ffn_decode(p, x, ctx, cfg.norm_eps), cache
    if kind == MOE_FFN:
        return ffn.moe_decode(p, x, ctx, cfg), cache
    if kind == RWKV:
        return rwkv.rwkv_channel_decode(p, x, cache, ctx, cfg)
    raise ValueError(kind)


def _block_decode(kind_pair, lp: Dict, lc: Dict, x: Array, pos, ctx, cfg,
                  par: ParallelConfig, z3=None, layer=None):
    lp = _maybe_gather_zero3(lp, par, z3)
    ctx = ctx.with_layer(layer)
    dy, mc = _mixer_decode(kind_pair[0], lp["mixer"], x, lc["mixer"], pos,
                           ctx, cfg)
    x = x + dy
    dy, fc = _ffn_decode(kind_pair[1], lp["ffn"], x, lc["ffn"], ctx, cfg)
    return x + dy, {"mixer": mc, "ffn": fc}


def decode_step(params: Dict, caches: Dict, tokens: Array, pos,
                ctx: TPContext, cfg: ModelConfig, par: ParallelConfig):
    """One greedy decode step.  tokens: [B_loc, 1] int32; pos: [B_loc] int32
    per-slot write positions (a scalar broadcasts to all rows).  Returns
    (next_token [B_loc,1], new caches)."""
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1),
                           (tokens.shape[0],))
    # decode ALWAYS runs the replicated activation layout: a one-token
    # "sequence" cannot shard, and the decode seams are kind="ar"
    ctx = ctx.with_layout(False)
    v_pad = pad_vocab(cfg.vocab_size, par.tp)
    x = layers.embed_lookup(params["embed"], tokens, ctx, v_pad)
    x = x.astype(cfg.compute_dtype)

    pat = expanded_pattern(cfg)
    z3 = zero3_flags(cfg, par)
    new_caches: Dict[str, Any] = {"lead": [], "periods": None}
    lead = cfg.leading_dense_layers
    for i in range(lead):
        x, nc = _block_decode(pat[i], params["lead"][i], caches["lead"][i],
                              x, pos, ctx, cfg, par,
                              z3["lead"][i] if z3["lead"] else None, layer=i)
        new_caches["lead"].append(nc)

    def period_body(x, xs):
        stacked_p, stacked_c = xs
        ncs = []
        for p_i, kp in enumerate(cfg.pattern):
            x, nc = _block_decode(kp, stacked_p[p_i], stacked_c[p_i], x, pos,
                                  ctx, cfg, par,
                                  z3["periods"][p_i] if z3["periods"] else None,
                                  layer=lead + p_i)
            ncs.append(nc)
        return x, tuple(ncs)

    x, stacked_new = lax.scan(
        period_body, x, (tuple(params["periods"]), tuple(caches["periods"])))
    new_caches["periods"] = list(stacked_new)

    h = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", h, params["embed"])  # [B,1,V/TP] local
    nxt = vocab_parallel_argmax(logits[:, -1], ctx, v_pad, cfg.vocab_size)
    return nxt[:, None], new_caches


def vocab_parallel_argmax(logits_loc: Array, ctx: TPContext,
                          v_pad: int, vocab_real: Optional[int] = None
                          ) -> Array:
    """Greedy sampling over vocab-sharded logits [B, V/TP] -> [B] int32."""
    v_loc = logits_loc.shape[-1]
    if vocab_real is not None and vocab_real < v_pad:
        col = ctx.tp_index() * v_loc + jnp.arange(v_loc)
        logits_loc = jnp.where(col < vocab_real, logits_loc, -jnp.inf)
    loc_idx = jnp.argmax(logits_loc, axis=-1)
    loc_val = jnp.take_along_axis(logits_loc, loc_idx[:, None], axis=-1)[:, 0]
    if ctx.axis is None or ctx.tp == 1:
        return loc_idx.astype(jnp.int32)
    glob_idx = loc_idx + ctx.tp_index() * v_loc
    vals = gather_ranks(loc_val, ctx.axis)                # [B, TP]
    idxs = gather_ranks(glob_idx, ctx.axis)               # [B, TP]
    best = jnp.argmax(vals, axis=-1)
    return jnp.take_along_axis(idxs, best[:, None], axis=-1)[:, 0].astype(
        jnp.int32)


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------
def _mixer_prefill(kind: str, p, x, ctx, cfg, lengths=None):
    if kind == ATTN:
        # causal mask keeps rows < length independent of right-padding
        return attention.gqa_train(p, x, ctx, cfg, with_cache=True)
    if kind == MLA:
        return attention.mla_train(p, x, ctx, cfg, with_cache=True)
    if kind == MAMBA:
        return mamba.mamba_train(p, x, ctx, cfg, with_cache=True,
                                 lengths=lengths)
    if kind == RWKV:
        return rwkv.rwkv_time_train(p, x, ctx, cfg, with_cache=True,
                                    lengths=lengths)
    raise ValueError(kind)


def _ffn_prefill(kind: str, p, x, ctx, cfg, lengths=None):
    if kind == DENSE_FFN:
        return ffn.ffn_train(p, x, ctx, cfg.norm_eps), {}
    if kind == MOE_FFN:
        y, _ = ffn.moe_train(p, x, ctx, cfg, lengths=lengths)
        return y, {}
    if kind == RWKV:
        return rwkv.rwkv_channel_train(p, x, ctx, cfg, with_cache=True,
                                       lengths=lengths)
    raise ValueError(kind)


def _block_prefill(kind_pair, lp, x, ctx, cfg, par, z3=None, layer=None,
                   lengths=None):
    lp = _maybe_gather_zero3(lp, par, z3)
    ctx = ctx.with_layer(layer)
    dy, mc = _mixer_prefill(kind_pair[0], lp["mixer"], x, ctx, cfg, lengths)
    x = x + dy
    dy, fc = _ffn_prefill(kind_pair[1], lp["ffn"], x, ctx, cfg, lengths)
    return x + dy, {"mixer": mc, "ffn": fc}


def prefill_step(params: Dict, batch: Dict, ctx: TPContext, cfg: ModelConfig,
                 par: ParallelConfig, lengths=None):
    """Full-sequence prefill: returns (next_token [B_loc,1], caches).

    Prefill runs the plan-resolved activation layout (sequence-sharded by
    default — the SP memory win applies to the longest activations in
    serving); decode (``decode_step``) always forces the replicated layout.

    ``lengths`` ([B_loc] int32, optional): per-row true prompt lengths of a
    right-padded batch — caches freeze at each row's length (state
    families) and logits are read at ``lengths - 1`` per row (see module
    docstring)."""
    if lengths is not None:
        lengths = jnp.asarray(lengths, jnp.int32).reshape(-1)
    v_pad = pad_vocab(cfg.vocab_size, par.tp)
    if "embeds" in batch:
        x = batch["embeds"]
    else:
        x = layers.embed_lookup(params["embed"], batch["tokens"], ctx, v_pad)
    x = x.astype(cfg.compute_dtype)

    pat = expanded_pattern(cfg)
    z3 = zero3_flags(cfg, par)
    caches: Dict[str, Any] = {"lead": [], "periods": None}
    lead = cfg.leading_dense_layers
    for i in range(lead):
        x, nc = _block_prefill(pat[i], params["lead"][i], x, ctx, cfg, par,
                               z3["lead"][i] if z3["lead"] else None, layer=i,
                               lengths=lengths)
        caches["lead"].append(nc)

    def period_body(x, stacked_p):
        ncs = []
        for p_i, kp in enumerate(cfg.pattern):
            x, nc = _block_prefill(kp, stacked_p[p_i], x, ctx, cfg, par,
                                   z3["periods"][p_i] if z3["periods"] else None,
                                   layer=lead + p_i, lengths=lengths)
            ncs.append(nc)
        return x, tuple(ncs)

    x, stacked_caches = lax.scan(period_body, x, tuple(params["periods"]))
    caches["periods"] = list(stacked_caches)

    h = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    # only each row's LAST true position's logits feed the next token
    # (gather_seq: no-op in the replicated layout, ring transport under SP)
    if lengths is None:
        h_last = ctx.gather_seq(h[:, -1:], "head_ag")[:, -1:]
    else:
        h_last = layers.take_rows(ctx.gather_seq(h, "head_ag"),
                                  lengths - 1)[:, None]
    logits = jnp.einsum("bsd,vd->bsv", h_last, params["embed"])
    nxt = vocab_parallel_argmax(logits[:, -1], ctx, v_pad, cfg.vocab_size)
    return nxt[:, None], caches
