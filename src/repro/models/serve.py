"""Serving paths: prefill (cache-building forward) and single-token decode.

Cache layout is GLOBAL (``compat.shard_map`` slices it): per layer-position
trees whose shapes come from ``cache_specs``.  Decode is the paper's
vLLM-style TP pattern: replicated activations, local-head attention over the
sharded KV cache, row-parallel output GEMM + AllReduce (the FLUX decode
seam).

Continuous-batching contract (what the runtime Server relies on):

* ``decode_step`` takes ``pos: [B]`` — a PER-SLOT position vector.  Every
  batch row RoPE-rotates at, masks to, and cache-writes at its OWN
  position (per-row ``dynamic_update_slice``), so slots at staggered
  sequence positions decode together in one fixed-shape dispatch without
  touching each other's cache rows.  A scalar ``pos`` still broadcasts (all
  rows in lockstep — the bench/smoke path).  The optional ``active: [B]``
  bool mask freezes the dense recurrent-state rows (Mamba conv/SSM, RWKV
  wkv/shift) of non-generating slots — the server passes its ready mask so
  a slot mid-chunked-prefill survives the interleaved full-batch decodes.
* ``prefill_step`` takes optional ``lengths: [B]`` — per-row true prompt
  lengths of a RIGHT-PADDED token batch.  Attention families are pad-safe
  by causality; the state families (Mamba SSM/conv, RWKV WKV/token-shift)
  freeze their recurrent state at each row's true length (identity decay +
  zero input on pad positions), and the next-token logits are read at
  ``lengths - 1`` per row.  The returned caches are therefore exactly what
  a token-by-token decode of the unpadded prompt would have produced —
  admission scatters them into a slot's rows in one dispatch.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import (ATTN, DENSE_FFN, MLA, MAMBA, MOE_FFN, RWKV,
                                ModelConfig, ParallelConfig)
from repro.models import attention, ffn, layers, mamba, rwkv
from repro.models.model import (_maybe_gather_zero3, expanded_pattern,
                                n_periods, zero3_flags)
from repro.parallel.sharding import (TPContext, ceil_mult, gather_ranks,
                                     pad_kv_heads, pad_heads, pad_vocab)

Array = jax.Array


# ---------------------------------------------------------------------------
# Cache specs (global shapes + PartitionSpecs)
# ---------------------------------------------------------------------------
def _mixer_cache_spec(kind: str, cfg: ModelConfig, par: ParallelConfig,
                      batch: int, s_max: int, dp_axes: Tuple[str, ...],
                      pool: Optional[Tuple[int, int]] = None):
    dp = dp_axes if len(dp_axes) > 1 else (dp_axes[0] if dp_axes else None)
    tp = par.tp
    if kind == ATTN:
        hkv = pad_kv_heads(cfg.num_kv_heads, tp)
        dh = cfg.resolved_head_dim
        if pool is not None:
            nb, bs = pool
            sds = {"k": jax.ShapeDtypeStruct((nb, bs, hkv, dh), jnp.bfloat16),
                   "v": jax.ShapeDtypeStruct((nb, bs, hkv, dh), jnp.bfloat16)}
            spec = {"k": P(None, None, "model", None),
                    "v": P(None, None, "model", None)}
            return sds, spec
        sds = {"k": jax.ShapeDtypeStruct((batch, s_max, hkv, dh), jnp.bfloat16),
               "v": jax.ShapeDtypeStruct((batch, s_max, hkv, dh), jnp.bfloat16)}
        spec = {"k": P(dp, None, "model", None), "v": P(dp, None, "model", None)}
        return sds, spec
    if kind == MLA:
        m = cfg.mla
        if pool is not None:
            nb, bs = pool
            sds = {"c": jax.ShapeDtypeStruct((nb, bs, m.kv_lora_rank),
                                             jnp.bfloat16),
                   "kr": jax.ShapeDtypeStruct((nb, bs, m.qk_rope_head_dim),
                                              jnp.bfloat16)}
            spec = {"c": P(None, None, None), "kr": P(None, None, None)}
            return sds, spec
        sds = {"c": jax.ShapeDtypeStruct((batch, s_max, m.kv_lora_rank),
                                         jnp.bfloat16),
               "kr": jax.ShapeDtypeStruct((batch, s_max, m.qk_rope_head_dim),
                                          jnp.bfloat16)}
        spec = {"c": P(dp, None, None), "kr": P(dp, None, None)}
        return sds, spec
    if kind == MAMBA:
        d_in, _, d_state, d_conv = mamba._dims(cfg, tp)
        sds = {"conv": jax.ShapeDtypeStruct((batch, d_conv - 1, d_in),
                                            jnp.bfloat16),
               "ssm": jax.ShapeDtypeStruct((batch, d_in, d_state),
                                           jnp.float32)}
        spec = {"conv": P(dp, None, "model"), "ssm": P(dp, "model", None)}
        return sds, spec
    if kind == RWKV:
        n_heads, dh, _ = rwkv._dims(cfg, tp)
        sds = {"state": jax.ShapeDtypeStruct((batch, n_heads, dh, dh),
                                             jnp.float32),
               "last": jax.ShapeDtypeStruct((batch, cfg.d_model), jnp.bfloat16)}
        spec = {"state": P(dp, "model", None, None), "last": P(dp, None)}
        return sds, spec
    raise ValueError(kind)


def _ffn_cache_spec(kind: str, cfg: ModelConfig, par: ParallelConfig,
                    batch: int, dp_axes: Tuple[str, ...]):
    dp = dp_axes if len(dp_axes) > 1 else (dp_axes[0] if dp_axes else None)
    if kind == RWKV:
        return ({"last": jax.ShapeDtypeStruct((batch, cfg.d_model),
                                              jnp.bfloat16)},
                {"last": P(dp, None)})
    return {}, {}


def cache_specs(cfg: ModelConfig, par: ParallelConfig, batch: int, s_max: int,
                dp_axes: Tuple[str, ...] = ("data",),
                pool: Optional[Tuple[int, int]] = None):
    """Returns (ShapeDtypeStruct tree, PartitionSpec tree) for the full-model
    cache: {"lead": [...], "periods": [stacked per pattern position]}.

    With ``pool=(num_blocks, block_size)`` the attention-family leaves
    (GQA K/V, MLA latent) become shared ``[num_blocks, block_size, ...]``
    physical pools addressed through per-slot block tables (block ids are
    layer-agnostic: one allocation indexes every layer's pool leaf).  The
    state families (Mamba conv/SSM, RWKV wkv/shift) have no sequence dim
    to page — they stay dense per-slot ``[batch, ...]``."""
    pat = expanded_pattern(cfg)
    lead = cfg.leading_dense_layers
    reps = n_periods(cfg)

    def one(kind_pair):
        msds, mspec = _mixer_cache_spec(kind_pair[0], cfg, par, batch, s_max,
                                        dp_axes, pool)
        fsds, fspec = _ffn_cache_spec(kind_pair[1], cfg, par, batch, dp_axes)
        return ({"mixer": msds, "ffn": fsds},
                {"mixer": mspec, "ffn": fspec})

    sds: Dict[str, Any] = {"lead": [], "periods": []}
    spec: Dict[str, Any] = {"lead": [], "periods": []}
    for i in range(lead):
        s_, p_ = one(pat[i])
        sds["lead"].append(s_)
        spec["lead"].append(p_)
    for kp in cfg.pattern:
        s_, p_ = one(kp)
        s_ = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct((reps, *x.shape), x.dtype), s_)
        p_ = jax.tree.map(lambda sp: P(*([None] + list(sp))), p_,
                          is_leaf=lambda x: isinstance(x, P))
        sds["periods"].append(s_)
        spec["periods"].append(p_)
    return sds, spec


def paged_cache_specs(cfg: ModelConfig, par: ParallelConfig, num_blocks: int,
                      block_size: int, max_batch: int):
    """Cache specs for the paged serving runtime (see ``cache_specs``).
    Paged serving is per-replica — continuous batching fills slots from a
    local queue, so no leaf carries a dp axis."""
    return cache_specs(cfg, par, max_batch, 0, dp_axes=(),
                       pool=(num_blocks, block_size))


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------
def _mixer_decode(kind: str, p: Dict, x: Array, cache: Dict, pos, ctx,
                  cfg: ModelConfig, bt=None):
    if kind == ATTN:
        if bt is not None:
            return attention.gqa_decode_paged(p, x, cache, bt, pos, ctx, cfg)
        return attention.gqa_decode(p, x, cache, pos, ctx, cfg)
    if kind == MLA:
        if bt is not None:
            return attention.mla_decode_paged(p, x, cache, bt, pos, ctx, cfg)
        return attention.mla_decode(p, x, cache, pos, ctx, cfg)
    if kind == MAMBA:
        return mamba.mamba_decode(p, x, cache, pos, ctx, cfg)
    if kind == RWKV:
        return rwkv.rwkv_time_decode(p, x, cache, ctx, cfg)
    raise ValueError(kind)


def _ffn_decode(kind: str, p: Dict, x: Array, cache: Dict, ctx,
                cfg: ModelConfig):
    if kind == DENSE_FFN:
        return ffn.ffn_decode(p, x, ctx, cfg.norm_eps), cache
    if kind == MOE_FFN:
        return ffn.moe_decode(p, x, ctx, cfg), cache
    if kind == RWKV:
        return rwkv.rwkv_channel_decode(p, x, cache, ctx, cfg)
    raise ValueError(kind)


def _freeze_inactive(new: Dict, old: Dict, active) -> Dict:
    """Mask a dense per-slot cache write-back to the ACTIVE rows only.  The
    state families (Mamba conv/SSM, RWKV wkv/token-shift) rewrite every
    batch row unconditionally, so a slot that is mid-prefill (its chunked
    prefill threads state across dispatches) or empty must get its rows
    restored — the dense analogue of the paged attention caches'
    null-block redirect."""
    return jax.tree.map(
        lambda n, o: jnp.where(active.reshape((-1,) + (1,) * (n.ndim - 1)),
                               n, o.astype(n.dtype)),
        new, old)


def _block_decode(kind_pair, lp: Dict, lc: Dict, x: Array, pos, ctx, cfg,
                  par: ParallelConfig, z3=None, layer=None, bt=None,
                  active=None):
    lp = _maybe_gather_zero3(lp, par, z3)
    ctx = ctx.with_layer(layer)
    dy, mc = _mixer_decode(kind_pair[0], lp["mixer"], x, lc["mixer"], pos,
                           ctx, cfg, bt=bt)
    if active is not None and (kind_pair[0] in (MAMBA, RWKV) or bt is None):
        # paged attention pools ([num_blocks, ...]) are already protected
        # by the null-block redirect; every dense [B, ...] cache needs the
        # row mask
        mc = _freeze_inactive(mc, lc["mixer"], active)
    x = x + dy
    dy, fc = _ffn_decode(kind_pair[1], lp["ffn"], x, lc["ffn"], ctx, cfg)
    if active is not None and kind_pair[1] == RWKV:
        fc = _freeze_inactive(fc, lc["ffn"], active)
    return x + dy, {"mixer": mc, "ffn": fc}


def decode_step(params: Dict, caches: Dict, tokens: Array, pos,
                ctx: TPContext, cfg: ModelConfig, par: ParallelConfig,
                block_tables=None, active=None):
    """One greedy decode step.  tokens: [B_loc, 1] int32; pos: [B_loc] int32
    per-slot write positions (a scalar broadcasts to all rows).  With
    ``block_tables`` ([B_loc, pages] int32) the attention caches are paged
    pools and each row reads/writes through its own table (all-zero rows
    redirect to the null block — inactive slots are harmless).

    ``active`` ([B_loc] bool, optional): rows that are actually GENERATING.
    Inactive rows keep their dense per-slot state caches (Mamba conv/SSM,
    RWKV wkv/token-shift ``last``) bit-untouched — without the mask a
    full-batch decode would advance a mid-prefill slot's chunk-threaded
    recurrent state with garbage pad-token input.  Attention pool leaves
    need no masking (null-block redirect); omitting ``active`` keeps the
    legacy all-rows-advance behavior.  Returns (next_token [B_loc,1], new
    caches)."""
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1),
                           (tokens.shape[0],))
    if active is not None:
        active = jnp.asarray(active, bool).reshape(-1)
    # decode ALWAYS runs the replicated activation layout: a one-token
    # "sequence" cannot shard, and the decode seams are kind="ar"
    ctx = ctx.with_layout(False)
    v_pad = pad_vocab(cfg.vocab_size, par.tp)
    x = layers.embed_lookup(params["embed"], tokens, ctx, v_pad)
    x = x.astype(cfg.compute_dtype)

    pat = expanded_pattern(cfg)
    z3 = zero3_flags(cfg, par)
    new_caches: Dict[str, Any] = {"lead": [], "periods": None}
    lead = cfg.leading_dense_layers
    for i in range(lead):
        x, nc = _block_decode(pat[i], params["lead"][i], caches["lead"][i],
                              x, pos, ctx, cfg, par,
                              z3["lead"][i] if z3["lead"] else None, layer=i,
                              bt=block_tables, active=active)
        new_caches["lead"].append(nc)

    def period_body(x, xs):
        stacked_p, stacked_c = xs
        ncs = []
        for p_i, kp in enumerate(cfg.pattern):
            x, nc = _block_decode(kp, stacked_p[p_i], stacked_c[p_i], x, pos,
                                  ctx, cfg, par,
                                  z3["periods"][p_i] if z3["periods"] else None,
                                  layer=lead + p_i, bt=block_tables,
                                  active=active)
            ncs.append(nc)
        return x, tuple(ncs)

    x, stacked_new = lax.scan(
        period_body, x, (tuple(params["periods"]), tuple(caches["periods"])))
    new_caches["periods"] = list(stacked_new)

    h = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", h, params["embed"])  # [B,1,V/TP] local
    nxt = vocab_parallel_argmax(logits[:, -1], ctx, v_pad, cfg.vocab_size)
    return nxt[:, None], new_caches


def vocab_parallel_argmax(logits_loc: Array, ctx: TPContext,
                          v_pad: int, vocab_real: Optional[int] = None
                          ) -> Array:
    """Greedy sampling over vocab-sharded logits [B, V/TP] -> [B] int32."""
    v_loc = logits_loc.shape[-1]
    if vocab_real is not None and vocab_real < v_pad:
        col = ctx.tp_index() * v_loc + jnp.arange(v_loc)
        logits_loc = jnp.where(col < vocab_real, logits_loc, -jnp.inf)
    loc_idx = jnp.argmax(logits_loc, axis=-1)
    loc_val = jnp.take_along_axis(logits_loc, loc_idx[:, None], axis=-1)[:, 0]
    if ctx.axis is None or ctx.tp == 1:
        return loc_idx.astype(jnp.int32)
    glob_idx = loc_idx + ctx.tp_index() * v_loc
    vals = gather_ranks(loc_val, ctx.axis)                # [B, TP]
    idxs = gather_ranks(glob_idx, ctx.axis)               # [B, TP]
    best = jnp.argmax(vals, axis=-1)
    return jnp.take_along_axis(idxs, best[:, None], axis=-1)[:, 0].astype(
        jnp.int32)


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------
def _mixer_prefill(kind: str, p, x, ctx, cfg, lengths=None):
    if kind == ATTN:
        # causal mask keeps rows < length independent of right-padding
        return attention.gqa_train(p, x, ctx, cfg, with_cache=True)
    if kind == MLA:
        return attention.mla_train(p, x, ctx, cfg, with_cache=True)
    if kind == MAMBA:
        return mamba.mamba_train(p, x, ctx, cfg, with_cache=True,
                                 lengths=lengths)
    if kind == RWKV:
        return rwkv.rwkv_time_train(p, x, ctx, cfg, with_cache=True,
                                    lengths=lengths)
    raise ValueError(kind)


def _ffn_prefill(kind: str, p, x, ctx, cfg, lengths=None):
    if kind == DENSE_FFN:
        return ffn.ffn_train(p, x, ctx, cfg.norm_eps), {}
    if kind == MOE_FFN:
        y, _ = ffn.moe_train(p, x, ctx, cfg, lengths=lengths)
        return y, {}
    if kind == RWKV:
        return rwkv.rwkv_channel_train(p, x, ctx, cfg, with_cache=True,
                                       lengths=lengths)
    raise ValueError(kind)


def _block_prefill(kind_pair, lp, x, ctx, cfg, par, z3=None, layer=None,
                   lengths=None):
    lp = _maybe_gather_zero3(lp, par, z3)
    ctx = ctx.with_layer(layer)
    dy, mc = _mixer_prefill(kind_pair[0], lp["mixer"], x, ctx, cfg, lengths)
    x = x + dy
    dy, fc = _ffn_prefill(kind_pair[1], lp["ffn"], x, ctx, cfg, lengths)
    return x + dy, {"mixer": mc, "ffn": fc}


def prefill_step(params: Dict, batch: Dict, ctx: TPContext, cfg: ModelConfig,
                 par: ParallelConfig, lengths=None):
    """Full-sequence prefill: returns (next_token [B_loc,1], caches).

    Prefill runs the plan-resolved activation layout (sequence-sharded by
    default — the SP memory win applies to the longest activations in
    serving); decode (``decode_step``) always forces the replicated layout.

    ``lengths`` ([B_loc] int32, optional): per-row true prompt lengths of a
    right-padded batch — caches freeze at each row's length (state
    families) and logits are read at ``lengths - 1`` per row (see module
    docstring)."""
    if lengths is not None:
        lengths = jnp.asarray(lengths, jnp.int32).reshape(-1)
    v_pad = pad_vocab(cfg.vocab_size, par.tp)
    if "embeds" in batch:
        x = batch["embeds"]
    else:
        x = layers.embed_lookup(params["embed"], batch["tokens"], ctx, v_pad)
    x = x.astype(cfg.compute_dtype)

    pat = expanded_pattern(cfg)
    z3 = zero3_flags(cfg, par)
    caches: Dict[str, Any] = {"lead": [], "periods": None}
    lead = cfg.leading_dense_layers
    for i in range(lead):
        x, nc = _block_prefill(pat[i], params["lead"][i], x, ctx, cfg, par,
                               z3["lead"][i] if z3["lead"] else None, layer=i,
                               lengths=lengths)
        caches["lead"].append(nc)

    def period_body(x, stacked_p):
        ncs = []
        for p_i, kp in enumerate(cfg.pattern):
            x, nc = _block_prefill(kp, stacked_p[p_i], x, ctx, cfg, par,
                                   z3["periods"][p_i] if z3["periods"] else None,
                                   layer=lead + p_i, lengths=lengths)
            ncs.append(nc)
        return x, tuple(ncs)

    x, stacked_caches = lax.scan(period_body, x, tuple(params["periods"]))
    caches["periods"] = list(stacked_caches)

    h = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    # only each row's LAST true position's logits feed the next token
    # (gather_seq: no-op in the replicated layout, ring transport under SP)
    if lengths is None:
        h_last = ctx.gather_seq(h[:, -1:], "head_ag")[:, -1:]
    else:
        h_last = layers.take_rows(ctx.gather_seq(h, "head_ag"),
                                  lengths - 1)[:, None]
    logits = jnp.einsum("bsd,vd->bsv", h_last, params["embed"])
    nxt = vocab_parallel_argmax(logits[:, -1], ctx, v_pad, cfg.vocab_size)
    return nxt[:, None], caches


# ---------------------------------------------------------------------------
# Chunked prefill (paged caches)
# ---------------------------------------------------------------------------
def _slot_state(cache: Dict, slot) -> Dict:
    """Slice one slot's row out of a dense per-slot state cache."""
    return jax.tree.map(
        lambda v: lax.dynamic_slice_in_dim(v, slot, 1, axis=0), cache)


def _store_slot_state(cache: Dict, st: Dict, slot) -> Dict:
    return jax.tree.map(
        lambda v, s: lax.dynamic_update_slice_in_dim(v, s.astype(v.dtype),
                                                     slot, axis=0), cache, st)


def _mixer_chunk(kind: str, p: Dict, x: Array, cache: Dict, bt, slot, off,
                 chunk_len, first, ctx, cfg: ModelConfig):
    if kind == ATTN:
        return attention.gqa_prefill_chunk(p, x, cache, bt, off, chunk_len,
                                           ctx, cfg)
    if kind == MLA:
        return attention.mla_prefill_chunk(p, x, cache, bt, off, chunk_len,
                                           ctx, cfg)
    # state families: thread the slot's recurrent state across chunks.  The
    # first chunk zeroes it (a freed slot's stale state must not leak into
    # the next admission); lengths are chunk-RELATIVE — rows past chunk_len
    # freeze the state exactly like prompt right-padding.
    lenv = jnp.broadcast_to(jnp.asarray(chunk_len, jnp.int32), (x.shape[0],))
    st = _slot_state(cache, slot)
    st = jax.tree.map(lambda v: jnp.where(first, jnp.zeros_like(v), v), st)
    if kind == MAMBA:
        y, ns = mamba.mamba_train(p, x, ctx, cfg, with_cache=True,
                                  lengths=lenv, cache=st)
    elif kind == RWKV:
        y, ns = rwkv.rwkv_time_train(p, x, ctx, cfg, with_cache=True,
                                     lengths=lenv, cache=st)
    else:
        raise ValueError(kind)
    return y, _store_slot_state(cache, ns, slot)


def _ffn_chunk(kind: str, p: Dict, x: Array, cache: Dict, slot, chunk_len,
               first, ctx, cfg: ModelConfig):
    if kind == DENSE_FFN:
        return ffn.ffn_train(p, x, ctx, cfg.norm_eps), cache
    lenv = jnp.broadcast_to(jnp.asarray(chunk_len, jnp.int32), (x.shape[0],))
    if kind == MOE_FFN:
        y, _ = ffn.moe_train(p, x, ctx, cfg, lengths=lenv)
        return y, cache
    if kind == RWKV:
        st = _slot_state(cache, slot)
        st = jax.tree.map(lambda v: jnp.where(first, jnp.zeros_like(v), v), st)
        y, ns = rwkv.rwkv_channel_train(p, x, ctx, cfg, with_cache=True,
                                        lengths=lenv, cache=st)
        return y, _store_slot_state(cache, ns, slot)
    raise ValueError(kind)


def _block_chunk(kind_pair, lp: Dict, lc: Dict, x: Array, bt, slot, off,
                 chunk_len, first, ctx, cfg, par: ParallelConfig, z3=None,
                 layer=None):
    lp = _maybe_gather_zero3(lp, par, z3)
    ctx = ctx.with_layer(layer)
    dy, mc = _mixer_chunk(kind_pair[0], lp["mixer"], x, lc["mixer"], bt, slot,
                          off, chunk_len, first, ctx, cfg)
    x = x + dy
    dy, fc = _ffn_chunk(kind_pair[1], lp["ffn"], x, lc["ffn"], slot,
                        chunk_len, first, ctx, cfg)
    return x + dy, {"mixer": mc, "ffn": fc}


def prefill_chunk_step(params: Dict, caches: Dict, tokens: Array,
                       block_tables: Array, slot, off, chunk_len,
                       ctx: TPContext, cfg: ModelConfig, par: ParallelConfig):
    """One fixed-shape chunk of an incremental paged prefill.

    ONE jit program serves every prompt length: tokens is always ``[1, C]``
    (right-padded past ``chunk_len``) and slot/off/chunk_len are traced
    int32 scalars, so admission cost is O(n/C) dispatches of a single
    compiled program — no per-bucket prefill family, no recompiles.

    Chunked prefill always runs the REPLICATED activation layout (like
    decode): a bounded C-row chunk has no sequence-parallel residency to
    win, and dropping SP removes the tp-divisible length constraint.  The
    attention chunk writes K/V through ``block_tables`` BEFORE computing
    scores, so intra-chunk causality and all earlier chunks (including
    REUSED prefix blocks, which are never rewritten) ride the same gathered
    view — results are bit-identical regardless of chunk grouping or reuse.

    Returns (next_token [1,1] — meaningful only on the FINAL chunk, where
    row ``chunk_len-1`` is the prompt's last token — and the new caches)."""
    slot = jnp.asarray(slot, jnp.int32)
    off = jnp.asarray(off, jnp.int32)
    chunk_len = jnp.asarray(chunk_len, jnp.int32)
    first = off == 0
    ctx = ctx.with_layout(False)
    v_pad = pad_vocab(cfg.vocab_size, par.tp)
    x = layers.embed_lookup(params["embed"], tokens, ctx, v_pad)
    x = x.astype(cfg.compute_dtype)

    pat = expanded_pattern(cfg)
    z3 = zero3_flags(cfg, par)
    new_caches: Dict[str, Any] = {"lead": [], "periods": None}
    lead = cfg.leading_dense_layers
    for i in range(lead):
        x, nc = _block_chunk(pat[i], params["lead"][i], caches["lead"][i], x,
                             block_tables, slot, off, chunk_len, first, ctx,
                             cfg, par, z3["lead"][i] if z3["lead"] else None,
                             layer=i)
        new_caches["lead"].append(nc)

    def period_body(x, xs):
        stacked_p, stacked_c = xs
        ncs = []
        for p_i, kp in enumerate(cfg.pattern):
            x, nc = _block_chunk(kp, stacked_p[p_i], stacked_c[p_i], x,
                                 block_tables, slot, off, chunk_len, first,
                                 ctx, cfg, par,
                                 z3["periods"][p_i] if z3["periods"] else None,
                                 layer=lead + p_i)
            ncs.append(nc)
        return x, tuple(ncs)

    x, stacked_new = lax.scan(
        period_body, x, (tuple(params["periods"]), tuple(caches["periods"])))
    new_caches["periods"] = list(stacked_new)

    h = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    h_last = layers.take_rows(
        h, jnp.broadcast_to(chunk_len - 1, (h.shape[0],)))[:, None]
    logits = jnp.einsum("bsd,vd->bsv", h_last, params["embed"])
    nxt = vocab_parallel_argmax(logits[:, -1], ctx, v_pad, cfg.vocab_size)
    return nxt[:, None], new_caches
