"""Shared layers: norms, rotary embeddings, embedding / LM-head seams.

Everything here runs INSIDE ``compat.shard_map`` (see ``repro/compat``).
The residual-stream activation LAYOUT between TP seams is a plan knob
(``ctx.seq_sharded``, resolved from ``SeamPlan.scatter_axis``):

  * sequence-sharded (Megatron-SP, the default): x is [B, S/TP, D] between
    blocks — norms/residual/dropout touch 1/TP of the activation;
  * replicated (classic TP, and ALWAYS the S=1 decode path): x is
    [B, S, D] on every rank.

The vocabulary-parallel embedding + LM head are two of the paper's TP
seams (the LM head's AllGather-GEMM is the single largest GEMM in most of
the assigned archs); the embedding's combining collective follows the same
layout knob (ReduceScatter over sequence vs AllReduce).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.parallel.sharding import TPContext

Array = jax.Array


def rms_norm(x: Array, gamma: Array, eps: float = 1e-5) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * gamma


def init_rms_norm(d: int, dtype=jnp.bfloat16):
    return jnp.ones((d,), dtype)


# ---------------------------------------------------------------------------
# Rotary embeddings (RoPE and Qwen2-VL M-RoPE)
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: Array, positions: Array, theta: float = 10000.0) -> Array:
    """x: [B, S, H, Dh]; positions: [B, S] absolute token positions."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # [Dh/2]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [B,S,Dh/2]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: Array, positions_3d: Array, theta: float,
                sections: Tuple[int, int, int] = None) -> Array:
    """Qwen2-VL multimodal RoPE: head_dim/2 freq slots are split into
    (temporal, height, width) sections, each rotated by its own position id.
    positions_3d: [3, B, S].  For pure text all three ids are equal (falls
    back to standard RoPE)."""
    dh = x.shape[-1]
    half = dh // 2
    if sections is None:
        t = half // 2
        hw = (half - t) // 2
        sections = (t, hw, half - t - hw)
    freqs = rope_freqs(dh, theta)                       # [half]
    sec_ids = jnp.concatenate([
        jnp.full((s,), i, jnp.int32) for i, s in enumerate(sections)])
    pos = positions_3d[sec_ids]                         # [half, B, S] gathered per slot
    ang = jnp.moveaxis(pos, 0, -1).astype(jnp.float32) * freqs  # [B,S,half]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Vocabulary-parallel embedding (Megatron): table sharded on vocab over TP.
# ---------------------------------------------------------------------------
def embed_lookup(table: Array, tokens: Array, ctx: TPContext,
                 vocab_global: int,
                 scatter_seq: Optional[bool] = None) -> Array:
    """Megatron vocab-parallel embedding.  table: [V/TP, D] local shard;
    tokens: [B, S] REPLICATED over the TP axis.  Out-of-shard tokens
    contribute 0; the combining collective follows the activation layout:
    a ReduceScatter along the sequence (producing the sequence-sharded
    activation directly — the embedding's RS seam) when the residual
    stream is sequence-sharded, a psum (replicated layout / decode)
    otherwise.  ``scatter_seq=None`` resolves from ``ctx.seq_sharded``."""
    if scatter_seq is None:
        scatter_seq = ctx.seq_sharded
    v_loc = table.shape[0]
    start = ctx.tp_index() * v_loc
    local_ids = tokens - start
    in_shard = (local_ids >= 0) & (local_ids < v_loc)
    local_ids = jnp.clip(local_ids, 0, v_loc - 1)
    x = table[local_ids]
    x = jnp.where(in_shard[..., None], x, 0)
    if ctx.axis is not None and ctx.tp > 1:
        if scatter_seq:
            # the embed RS seam rides the plan transport (ring modes:
            # ppermute hops forward AND backward — census-clean)
            x = ctx.scatter_seq(x, "head_ag")
        else:
            with jax.named_scope("seam_embed_ar"):
                x = lax.psum(x, ctx.axis)
    return x


def lm_head_logits(x: Array, table: Array, ctx: TPContext) -> Array:
    """x: [B, S/TP, D] -> logits [B, S, V/TP] via the AllGather-GEMM seam.
    (The LM head is the biggest single GEMM: FLUX prologue fusion applies.)"""
    return ctx.op("head_ag")(x, table.T)


def vocab_parallel_xent(logits: Array, labels: Array, ctx: TPContext,
                        vocab_global: int, vocab_real: Optional[int] = None
                        ) -> Array:
    """Cross-entropy over vocab-sharded logits [B, S, V/TP], labels [B, S]
    (full sequence).  Uses the Megatron vocab-parallel log-softmax (psum of
    max and of exp-sums over the TP axis).  Returns per-token loss [B, S].
    ``vocab_real`` masks the padded vocab tail out of the partition function
    (padding stays function-preserving)."""
    v_loc = logits.shape[-1]
    lf = logits.astype(jnp.float32)
    if vocab_real is not None and vocab_real < vocab_global:
        col = ctx.tp_index() * v_loc + jnp.arange(v_loc)
        lf = jnp.where(col < vocab_real, lf, -1e30)
    # stability shift only — exact to treat as constant (and pmax has no
    # differentiation rule, so stop the gradient BEFORE it)
    mx = lax.stop_gradient(jnp.max(lf, axis=-1, keepdims=True))
    if ctx.axis is not None and ctx.tp > 1:
        mx = lax.pmax(mx, ctx.axis)
    ex = jnp.exp(lf - mx)
    denom = jnp.sum(ex, axis=-1, keepdims=True)
    if ctx.axis is not None and ctx.tp > 1:
        denom = lax.psum(denom, ctx.axis)
    start = ctx.tp_index() * v_loc
    local_ids = labels - start
    in_shard = (local_ids >= 0) & (local_ids < v_loc)
    local_ids = jnp.clip(local_ids, 0, v_loc - 1)
    tgt = jnp.take_along_axis(lf, local_ids[..., None], axis=-1)[..., 0]
    tgt = jnp.where(in_shard, tgt, 0.0)
    if ctx.axis is not None and ctx.tp > 1:
        tgt = lax.psum(tgt, ctx.axis)
    return jnp.log(denom[..., 0]) + mx[..., 0] - tgt


# ---------------------------------------------------------------------------
# Sequence-shard utilities
# ---------------------------------------------------------------------------
def seq_positions(batch: int, s_local: int, ctx: TPContext,
                  offset: int = 0) -> Array:
    """Absolute positions of this device's sequence rows: [B, S_local].
    Sequence-sharded layout adds the shard offset; the replicated layout's
    local rows ARE the global rows."""
    base = offset
    if ctx.seq_sharded:
        base = ctx.tp_index() * s_local + offset
    pos = base + jnp.arange(s_local, dtype=jnp.int32)
    return jnp.broadcast_to(pos, (batch, s_local))


def shift_tokens_right(x: Array, ctx: TPContext) -> Array:
    """x_{t-1} for a (possibly sequence-sharded) [B, S_local, D] tensor:
    shifts within the shard and pulls the boundary column from the left
    neighbor (ppermute of ONE token — the token-shift seam of RWKV).  The
    replicated layout shifts locally (no boundary to exchange)."""
    if ctx.axis is None or ctx.tp == 1 or not ctx.seq_sharded:
        return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    last = x[:, -1:, :]
    n = ctx.tp
    with jax.named_scope("seam_token_shift"):
        # one boundary row between neighbors — the RWKV/mamba token-shift
        # seam, not a transport any FusedOp ring owns
        prev = lax.ppermute(  # lint: allow(raw-collective)
            last, ctx.axis, [(i, (i + 1) % n) for i in range(n)])
    # rank 0's incoming boundary is garbage (wrapped) -> zero it
    is_first = (ctx.tp_index() == 0)
    prev = jnp.where(is_first, jnp.zeros_like(prev), prev)
    return jnp.concatenate([prev, x[:, :-1, :]], axis=1)


# ---------------------------------------------------------------------------
# Per-slot decode-cache utilities (continuous batching)
# ---------------------------------------------------------------------------
def cache_update_rows(cache: Array, new: Array, pos: Array) -> Array:
    """Per-row KV-cache write: ``cache[b, pos[b]:pos[b]+L] = new[b]``.

    cache: [B, S_max, ...]; new: [B, L, ...] (L=1 at decode); pos: [B]
    int32.  Each batch row writes at its OWN position — the continuous-
    batching invariant that slots at staggered sequence positions never
    touch each other's rows."""
    return jax.vmap(
        lambda c, n, p: lax.dynamic_update_slice_in_dim(
            c, n.astype(c.dtype), p, axis=0))(cache, new, pos)


def pool_update_rows(pool: Array, new: Array, bt: Array, start: Array,
                     valid: Optional[Array] = None) -> Array:
    """Paged KV write THROUGH a block table.

    pool: [N_blocks, bs, ...] physical blocks; new: [B, L, ...]; bt: [B, P]
    int32 per-row block tables; start: [B] int32 logical write offsets
    (row b's new[b, i] lands at logical position start[b] + i, i.e.
    physical block ``bt[b, (start[b]+i) // bs]`` row ``(start[b]+i) % bs``).
    ``valid`` ([B] int32, optional): rows with i >= valid[b] are padding —
    they redirect into physical block 0, the pool's reserved NULL block
    (never allocated, never read unmasked).  Inactive decode slots get the
    same treatment for free: their bt rows are all zeros.  Real rows never
    collide (tables are disjoint and block 0 is never in a table)."""
    n_blocks, bs = pool.shape[0], pool.shape[1]
    b, l = new.shape[0], new.shape[1]
    logical = start[:, None] + jnp.arange(l, dtype=jnp.int32)       # [B, L]
    blk = jnp.take_along_axis(
        bt, jnp.clip(logical // bs, 0, bt.shape[1] - 1), axis=1)
    flat = blk * bs + logical % bs                                  # [B, L]
    if valid is not None:
        ok = jnp.arange(l, dtype=jnp.int32)[None, :] < valid[:, None]
        flat = jnp.where(ok, flat, logical % bs)    # null-block rows
    pool_flat = pool.reshape(n_blocks * bs, *pool.shape[2:])
    return pool_flat.at[flat.reshape(-1)].set(
        new.reshape(b * l, *new.shape[2:]).astype(pool.dtype)
    ).reshape(pool.shape)


def pool_view(pool: Array, bt: Array) -> Array:
    """Gather each row's logical K/V timeline through its block table.

    pool: [N_blocks, bs, ...]; bt: [B, P] -> [B, P*bs, ...].  Logical
    position s of row b reads ``pool[bt[b, s // bs], s % bs]``; positions
    past the row's true length land in stale or null-block rows and MUST
    be masked by the caller (attention masks on pos already do)."""
    g = pool[bt]                                   # [B, P, bs, ...]
    return g.reshape(bt.shape[0], bt.shape[1] * pool.shape[1],
                     *pool.shape[2:])


def take_rows(x: Array, idx: Array) -> Array:
    """Per-row gather along the sequence axis: ``x[b, idx[b]]``.

    x: [B, S, ...]; idx: [B] int32 -> [B, ...].  Used to pick each row's
    true last-token entry out of a right-padded batched prefill."""
    idx = jnp.clip(idx, 0, x.shape[1] - 1)
    return jax.vmap(lambda r, i: lax.dynamic_index_in_dim(
        r, i, axis=0, keepdims=False))(x, idx)


def shift_tokens_left(x: Array, ctx: TPContext) -> Array:
    """x_{t+1} for a (possibly sequence-sharded) [B, S_local, D] tensor
    (zero at the end)."""
    if ctx.axis is None or ctx.tp == 1 or not ctx.seq_sharded:
        return jnp.pad(x, ((0, 0), (0, 1), (0, 0)))[:, 1:]
    first = x[:, :1, :]
    n = ctx.tp
    with jax.named_scope("seam_token_shift"):
        nxt = lax.ppermute(  # lint: allow(raw-collective)
            first, ctx.axis, [(i, (i - 1) % n) for i in range(n)])
    is_last = (ctx.tp_index() == n - 1)
    nxt = jnp.where(is_last, jnp.zeros_like(nxt), nxt)
    return jnp.concatenate([x[:, 1:, :], nxt], axis=1)
