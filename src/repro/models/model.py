"""Model assembly: every assigned architecture builds from the same blocks.

A model is ``num_layers / len(pattern)`` repetitions ("periods") of its layer
pattern.  Homogeneous periods are scanned (keeps HLO small at 61+ layers);
positions inside a period are python-unrolled (heterogeneous: Jamba's
mamba/attn interleave, DeepSeek's dense-lead + MoE).

Parameters are GLOBAL arrays; ``param_specs`` returns the matching
PartitionSpec tree; all forward code runs inside ``compat.shard_map``
(the JAX-version-portable wrapper in ``repro/compat``) and sees local
shards.  ``zero3`` additionally shards big weights over the data axis and
gathers them per-layer (the paper §2.1's "easily prefetched" AllGather
pattern — ZeRO-3/FSDP).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import (ATTN, DENSE_FFN, MLA, MAMBA, MOE_FFN, RWKV,
                                ModelConfig, ParallelConfig, ShapeConfig)
from repro.core import overlap
from repro.models import attention, ffn, layers, mamba, rwkv
from repro.parallel.sharding import (TPContext, pad_ff, pad_heads,
                                     pad_kv_heads, pad_vocab)

Array = jax.Array


# ---------------------------------------------------------------------------
# Pattern expansion
# ---------------------------------------------------------------------------
def expanded_pattern(cfg: ModelConfig) -> List[Tuple[str, str]]:
    """Full per-layer (mixer, ffn) list, honoring leading dense layers."""
    period = len(cfg.pattern)
    reps = cfg.num_layers // period
    assert reps * period == cfg.num_layers, (
        f"{cfg.name}: num_layers {cfg.num_layers} not a multiple of pattern "
        f"period {period}")
    out = [cfg.pattern[i % period] for i in range(cfg.num_layers)]
    for i in range(cfg.leading_dense_layers):
        out[i] = (out[i][0], DENSE_FFN)
    return out


def n_periods(cfg: ModelConfig) -> int:
    return (cfg.num_layers - cfg.leading_dense_layers) // len(cfg.pattern)


# ---------------------------------------------------------------------------
# Per-position init / specs / apply dispatch
# ---------------------------------------------------------------------------
def _init_mixer(key, kind: str, cfg: ModelConfig, tp: int, dtype,
                fuse13: bool = False):
    if kind == ATTN:
        return attention.init_gqa(key, cfg, tp, dtype)
    if kind == MLA:
        return attention.init_mla(key, cfg, tp, dtype)
    if kind == MAMBA:
        return mamba.init_mamba(key, cfg, tp, dtype, fuse_xz=fuse13)
    if kind == RWKV:
        return rwkv.init_rwkv_time(key, cfg, tp, dtype)
    raise ValueError(kind)


def _init_ffn(key, kind: str, cfg: ModelConfig, ep: int, tp: int, dtype,
              fuse13: bool = False):
    if kind == DENSE_FFN:
        return ffn.init_ffn(key, cfg.d_model, cfg.d_ff, tp, dtype,
                            fuse13=fuse13)
    if kind == MOE_FFN:
        return ffn.init_moe(key, cfg, ep, tp, dtype, fuse13=fuse13)
    if kind == RWKV:  # rwkv channel-mix plays the ffn role
        return rwkv.init_rwkv_channel(key, cfg, tp, dtype)
    raise ValueError(kind)


_MIXER_SPECS = {
    ATTN: {"wqkv": P(None, "model"), "wo": P("model", None), "norm": P(None),
           "bqkv": P("model")},
    MLA: {"w_dq": P(None, None), "w_uq": P(None, "model"),
          "w_dkv": P(None, None), "w_ukv": P(None, "model"),
          "w_o": P("model", None), "q_norm": P(None), "kv_norm": P(None),
          "norm": P(None)},
    MAMBA: {"w_in_x": P(None, "model"), "w_in_z": P(None, "model"),
            "w_in_xz": P(None, "model"),
            "conv": P(None, "model"), "conv_b": P("model"),
            "w_x": P("model", None), "w_dt": P(None, "model"),
            "dt_bias": P("model"), "a_log": P("model", None),
            "d_skip": P("model"), "w_out": P("model", None), "norm": P(None)},
    RWKV: {"mu": P(None, None), "w_r": P(None, "model"),
           "w_k": P(None, "model"), "w_v": P(None, "model"),
           "w_g": P(None, "model"), "w_dec1": P(None, None),
           "w_dec2": P(None, "model"), "dec_base": P("model"),
           "u_bonus": P("model"), "w_o": P("model", None),
           "ln_x": P(None), "norm": P(None)},
}

_FFN_SPECS = {
    DENSE_FFN: {"w1": P(None, "model"), "w3": P(None, "model"),
                "w13": P(None, "model"), "w2": P("model", None),
                "norm": P(None)},
    RWKV: {"mu": P(None, None), "w_k": P(None, "model"),
           "w_v": P("model", None), "w_r": P(None, None), "norm": P(None)},
}


def _moe_specs(ep_axes: Tuple[str, ...]) -> Dict:
    e = P(ep_axes if len(ep_axes) > 1 else ep_axes[0]) if ep_axes else P(None)
    espec = ep_axes if not ep_axes else (
        tuple(ep_axes) if len(ep_axes) > 1 else ep_axes[0])
    return {
        "router": P(None, None),
        "w1": P(espec or None, None, None),
        "w3": P(espec or None, None, None),
        "w2": P(espec or None, None, None),
        "norm": P(None),
        "shared": {"w1": P(None, "model"), "w3": P(None, "model"),
                   "w13": P(None, "model"), "w2": P("model", None)},
    }


def _specs_for(params: Dict, table: Dict) -> Dict:
    """Prune the spec table to the keys that actually exist."""
    out = {}
    for k, v in params.items():
        if isinstance(v, dict):
            out[k] = _specs_for(v, table[k])
        else:
            out[k] = table[k]
    return out


def _zero3_leaf_flag(spec: P, shape: Tuple[int, ...], dp: int) -> bool:
    """True when a (non-stacked) leaf is ZeRO-3 dim0-sharded over 'data':
    a 2-D+ weight whose dim0 is free in the spec and divisible by dp."""
    if len(shape) < 2 or shape[0] % max(dp, 1) or shape[0] < dp:
        return False
    parts = list(spec) + [None] * (len(shape) - len(spec))
    return parts[0] is None


def zero3_flags(cfg: ModelConfig, par: ParallelConfig) -> Dict:
    """Static bool trees (per layer position) marking ZeRO-3 leaves — shared
    by param_specs (spec building) and the forward pass (per-layer gather).
    Evaluated on the UNSTACKED layer structure."""
    if not par.zero3:
        return {"lead": None, "periods": None}
    pat = expanded_pattern(cfg)

    def one(kind_pair):
        ex = jax.eval_shape(
            lambda: {"mixer": _init_mixer(jax.random.PRNGKey(0), kind_pair[0],
                                          cfg, par.tp, jnp.bfloat16,
                                          par.fuse_w13),
                     "ffn": _init_ffn(jax.random.PRNGKey(0), kind_pair[1],
                                      cfg, _ep_size(cfg, par), par.tp,
                                      jnp.bfloat16, par.fuse_w13)})
        spec = _layer_spec(kind_pair, cfg, par, ex)
        return jax.tree.map(
            lambda sp, pl: _zero3_leaf_flag(sp, pl.shape, par.dp),
            spec, ex, is_leaf=lambda x: isinstance(x, P))

    return {"lead": [one(pat[i]) for i in range(cfg.leading_dense_layers)],
            "periods": [one(kp) for kp in cfg.pattern]}


# ---------------------------------------------------------------------------
# Model init + specs
# ---------------------------------------------------------------------------
def init_model(key, cfg: ModelConfig, par: ParallelConfig,
               dtype=jnp.bfloat16) -> Dict:
    tp = par.tp
    ep = _ep_size(cfg, par)
    v_pad = pad_vocab(cfg.vocab_size, tp)
    keys = jax.random.split(key, cfg.num_layers + 4)

    from repro.models import init_utils as iu
    params: Dict[str, Any] = {
        "embed": iu.zero_pad_rows(
            jax.random.normal(keys[0], (cfg.vocab_size, cfg.d_model))
            * cfg.d_model ** -0.5, v_pad).astype(dtype),
        "final_norm": layers.init_rms_norm(cfg.d_model, dtype),
    }
    pat = expanded_pattern(cfg)
    lead = cfg.leading_dense_layers
    # leading (unstacked) layers
    if lead:
        params["lead"] = [
            {"mixer": _init_mixer(keys[1 + i], pat[i][0], cfg, tp, dtype,
                                  par.fuse_w13),
             "ffn": _init_ffn(keys[1 + i], pat[i][1], cfg, ep, tp, dtype,
                              par.fuse_w13)}
            for i in range(lead)]
    # scanned periods: stack per pattern position
    reps = n_periods(cfg)
    period = cfg.pattern

    def stack_init(pos: int, kind_pair):
        mixer_kind, ffn_kind = kind_pair

        def one(i):
            k = jax.random.fold_in(keys[2 + lead + pos], i)
            km, kf = jax.random.split(k)
            return {"mixer": _init_mixer(km, mixer_kind, cfg, tp, dtype,
                                         par.fuse_w13),
                    "ffn": _init_ffn(kf, ffn_kind, cfg, ep, tp, dtype,
                                     par.fuse_w13)}

        trees = [one(i) for i in range(reps)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)

    params["periods"] = [stack_init(i, kp) for i, kp in enumerate(period)]

    if cfg.mtp_depth:
        params["mtp"] = {
            "mixer": _init_mixer(keys[-2], period[-1][0], cfg, tp, dtype,
                                 par.fuse_w13),
            "ffn": _init_ffn(keys[-2], DENSE_FFN, cfg, ep, tp, dtype,
                             par.fuse_w13),
            "proj": (jax.random.normal(keys[-1], (2 * cfg.d_model, cfg.d_model))
                     * (2 * cfg.d_model) ** -0.5).astype(dtype),
        }
    return params


def _layer_spec(kind_pair, cfg: ModelConfig, par: ParallelConfig,
                params_example: Dict) -> Dict:
    mixer_kind, ffn_kind = kind_pair
    ep_axes = _ep_axes(cfg, par)
    mix = _specs_for(params_example["mixer"], _MIXER_SPECS[mixer_kind])
    if ffn_kind == MOE_FFN:
        f = _specs_for(params_example["ffn"], _moe_specs(ep_axes))
    else:
        f = _specs_for(params_example["ffn"], _FFN_SPECS[ffn_kind])
    return {"mixer": mix, "ffn": f}


def param_specs(cfg: ModelConfig, par: ParallelConfig,
                params: Dict) -> Dict:
    """PartitionSpec tree matching ``init_model`` output (params may be a
    tree of ShapeDtypeStructs from jax.eval_shape)."""
    pat = expanded_pattern(cfg)
    lead = cfg.leading_dense_layers
    specs: Dict[str, Any] = {
        "embed": P("model", None),
        "final_norm": P(None),
    }
    if lead:
        specs["lead"] = [
            _layer_spec(pat[i], cfg, par, params["lead"][i])
            for i in range(lead)]
    specs["periods"] = []
    for pos, kp in enumerate(cfg.pattern):
        ex = params["periods"][pos]
        s = _layer_spec(kp, cfg, par, ex)
        # stacked leading (period) dim
        s = jax.tree.map(
            lambda sp: P(*([None] + list(sp))), s,
            is_leaf=lambda x: isinstance(x, P))
        specs["periods"].append(s)
    if cfg.mtp_depth and "mtp" in params:
        s = _layer_spec((cfg.pattern[-1][0], DENSE_FFN), cfg, par,
                        params["mtp"])
        s["proj"] = P(None, None)
        specs["mtp"] = s
    if par.zero3:
        flags = zero3_flags(cfg, par)

        def apply_z3(spec, flag, stacked):
            if not flag:
                return spec
            parts = list(spec)
            parts[1 if stacked else 0] = "data"
            return P(*parts)

        specs["periods"] = [
            jax.tree.map(lambda sp, fl: apply_z3(sp, fl, True), s_, f_,
                         is_leaf=lambda x: isinstance(x, P))
            for s_, f_ in zip(specs["periods"], flags["periods"])]
        if lead:
            specs["lead"] = [
                jax.tree.map(lambda sp, fl: apply_z3(sp, fl, False), s_, f_,
                             is_leaf=lambda x: isinstance(x, P))
                for s_, f_ in zip(specs["lead"], flags["lead"])]
    return specs


def _ep_axes(cfg: ModelConfig, par: ParallelConfig) -> Tuple[str, ...]:
    if cfg.moe is None:
        return ()
    if par.ep > 1:                   # dedicated first-class EP mesh axis
        return ("ep",)
    return ("data", "model") if par.ep_over_dp else ("model",)


def _ep_size(cfg: ModelConfig, par: ParallelConfig) -> int:
    if cfg.moe is None:
        return 1
    if par.ep > 1:
        return par.ep
    return par.dp * par.tp if par.ep_over_dp else par.tp


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------
def _maybe_gather_zero3(lp: Dict, par: ParallelConfig, flags=None,
                        dp_axis: str = "data"):
    """All-gather the ZeRO-3-sharded leaves over the data axis before use
    (the paper §2.1's easily-overlapped weight AllGather; XLA's latency
    hiding prefetches it across the scan step boundary)."""
    if not par.zero3 or flags is None:
        return lp

    def gather(w, flag):
        if flag:
            # ZeRO-3 weight gather over the DATA axis (not a TP seam)
            return lax.all_gather(  # lint: allow(raw-collective)
                w, dp_axis, axis=0, tiled=True)
        return w

    return jax.tree.map(gather, lp, flags)


def _apply_mixer(kind: str, p: Dict, x: Array, ctx: TPContext,
                 cfg: ModelConfig, collect_cache: bool = False):
    if kind == ATTN:
        return attention.gqa_train(p, x, ctx, cfg)
    if kind == MLA:
        return attention.mla_train(p, x, ctx, cfg)
    if kind == MAMBA:
        return mamba.mamba_train(p, x, ctx, cfg)
    if kind == RWKV:
        return rwkv.rwkv_time_train(p, x, ctx, cfg)
    raise ValueError(kind)


def _apply_ffn(kind: str, p: Dict, x: Array, ctx: TPContext,
               cfg: ModelConfig):
    if kind == DENSE_FFN:
        return ffn.ffn_train(p, x, ctx, cfg.norm_eps), 0.0
    if kind == MOE_FFN:
        return ffn.moe_train(p, x, ctx, cfg)
    if kind == RWKV:
        return rwkv.rwkv_channel_train(p, x, ctx, cfg), 0.0
    raise ValueError(kind)


def _block(kind_pair, lp: Dict, x: Array, ctx: TPContext, cfg: ModelConfig,
           par: ParallelConfig, z3=None,
           layer: Optional[int] = None) -> Tuple[Array, Array]:
    lp = _maybe_gather_zero3(lp, par, z3)
    ctx = ctx.with_layer(layer)        # per-layer plan overrides resolve here
    mixer_kind, ffn_kind = kind_pair
    x = x + _apply_mixer(mixer_kind, lp["mixer"], x, ctx, cfg)
    dy, aux = _apply_ffn(ffn_kind, lp["ffn"], x, ctx, cfg)
    return x + dy, jnp.asarray(aux, jnp.float32)


def backbone(params: Dict, x: Array, ctx: TPContext, cfg: ModelConfig,
             par: ParallelConfig) -> Tuple[Array, Array]:
    """x: [B, S/TP, D] -> (hidden [B, S/TP, D], aux_loss).  Replicated
    layout (``ctx.seq_sharded`` False): [B, S, D] -> [B, S, D] — the same
    seams run with hidden scatter and every between-seam op (norm,
    residual, shift, RoPE offsets) sees the full sequence."""
    pat = expanded_pattern(cfg)
    z3 = zero3_flags(cfg, par)
    lead = cfg.leading_dense_layers
    aux_total = jnp.zeros((), jnp.float32)
    for i in range(lead):
        x, aux = _block(pat[i], params["lead"][i], x, ctx, cfg, par,
                        z3["lead"][i] if z3["lead"] else None, layer=i)
        aux_total = aux_total + aux

    def block_with_flags(pos, lp, x):
        flags = z3["periods"][pos] if z3["periods"] else None
        # scanned periods share one trace: the layer slot is the PATTERN
        # position (offset past the unrolled lead), not the repetition index
        return _block(cfg.pattern[pos], lp, x, ctx, cfg, par, flags,
                      layer=lead + pos)

    remat_block = jax.checkpoint(
        block_with_flags, static_argnums=(0,)) if par.remat != "none" \
        else block_with_flags

    def period_body(carry, stacked):
        x, aux = carry
        for pos in range(len(cfg.pattern)):
            x, a = remat_block(pos, stacked[pos], x)
            aux = aux + a
        return (x, aux), None

    (x, aux_total), _ = lax.scan(period_body, (x, aux_total),
                                 tuple(params["periods"]))
    return x, aux_total


def forward_loss(params: Dict, batch: Dict, ctx: TPContext, cfg: ModelConfig,
                 par: ParallelConfig) -> Array:
    """Training loss (per-device mean; caller psums over DP).

    batch: tokens [B_loc, S] (replicated over TP; the embedding's
    combining collective produces the residual layout) or embeds in the
    residual layout — [B_loc, S/TP, D] sequence-sharded (default) or
    [B_loc, S, D] replicated, per ``ctx.seq_sharded``
    (``sharding.activation_spec``); labels [B_loc, S] (full sequence)."""
    v_pad = pad_vocab(cfg.vocab_size, par.tp)
    if "embeds" in batch:
        x = batch["embeds"]
    else:
        x = layers.embed_lookup(params["embed"], batch["tokens"], ctx, v_pad)
    x = x.astype(cfg.compute_dtype)

    h, aux = backbone(params, x, ctx, cfg, par)
    h = layers.rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = layers.lm_head_logits(h, params["embed"], ctx)  # [B, S, V/TP]

    labels = batch["labels"]
    ce = layers.vocab_parallel_xent(logits, labels, ctx, v_pad,
                                    cfg.vocab_size)  # [B, S]
    mask = (labels >= 0) & (labels < cfg.vocab_size)
    loss = jnp.sum(jnp.where(mask, ce, 0)) / jnp.maximum(jnp.sum(mask), 1)

    if cfg.mtp_depth and "mtp" in params:
        loss = loss + 0.3 * _mtp_loss(params, h, batch, ctx, cfg, par, v_pad)
    if cfg.moe is not None:
        loss = loss + 0.01 * aux
    return loss


def _mtp_loss(params, h, batch, ctx, cfg, par, v_pad):
    """DeepSeek multi-token prediction: one extra block predicts t+2 from the
    final hidden state fused with the (shifted) next-token embedding."""
    mtp = params["mtp"]
    if "embeds" in batch:
        nxt = batch["embeds"]
    else:
        nxt = layers.embed_lookup(params["embed"], batch["tokens"], ctx, v_pad)
    nxt = layers.shift_tokens_left(nxt.astype(h.dtype), ctx)  # emb of t+1
    fused = jnp.concatenate([h, nxt], axis=-1)
    x = jnp.einsum("bsd,dm->bsm", fused, mtp["proj"])
    x, _ = _block((cfg.pattern[-1][0], DENSE_FFN),
                  {"mixer": mtp["mixer"], "ffn": mtp["ffn"]}, x, ctx, cfg, par)
    logits = layers.lm_head_logits(x, params["embed"], ctx)
    # labels shifted one extra step
    labels = batch["labels"]
    lab2 = jnp.concatenate(
        [labels[:, 1:], jnp.full_like(labels[:, :1], -1)], axis=1)
    ce = layers.vocab_parallel_xent(logits, lab2, ctx, v_pad,
                                    cfg.vocab_size)
    mask = (lab2 >= 0) & (lab2 < cfg.vocab_size)
    return jnp.sum(jnp.where(mask, ce, 0)) / jnp.maximum(jnp.sum(mask), 1)


def count_params_analytic(cfg: ModelConfig, active_only: bool = False,
                          par: Optional[ParallelConfig] = None) -> int:
    """Exact parameter count via eval_shape of init (no allocation).
    ``active_only`` scales routed-expert weights by top_k/num_experts
    (MODEL_FLOPS = 6·N_active·D for MoE)."""
    par = par or ParallelConfig(tp=1, dp=1)
    shapes = jax.eval_shape(
        lambda: init_model(jax.random.PRNGKey(0), cfg, par))
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        names = [str(getattr(p, "key", "")) for p in path]
        n = 1
        for d in leaf.shape:
            n *= d
        # routed experts carry an expert dim: 3-D (or 4-D when period-stacked)
        is_expert = (cfg.moe is not None and "ffn" in names
                     and "shared" not in names
                     and any(k in names for k in ("w1", "w2", "w3"))
                     and leaf.ndim >= 3)
        if active_only and is_expert:
            n = int(n * cfg.moe.top_k / cfg.moe.num_experts)
        total += n
    return total
