"""Mamba-1 selective-scan mixer (Jamba's SSM layers), TP over channels.

TP mapping (DESIGN.md §5): in_proj column-parallel (AllGather-GEMM seam),
conv + selective scan channel-local, x_proj row-parallel (GEMM+AllReduce
seam — B/C/dt are shared across channel shards), out_proj row-parallel
(GEMM-ReduceScatter seam).  The scan itself carries no TP collective.

The scan is CHUNKED: lax.scan over sequence chunks carrying the [B, C_loc,
d_state] state, associative_scan within a chunk — O(S·chunk) memory, exact.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.parallel.sharding import TPContext, ceil_mult

Array = jax.Array


def _dims(cfg: ModelConfig, tp: int):
    mc = cfg.mamba
    d_in = ceil_mult(mc.expand * cfg.d_model, tp * 128)
    dt_rank = mc.dt_rank or max(cfg.d_model // 16, 8)
    return d_in, dt_rank, mc.d_state, mc.d_conv


def init_mamba(key, cfg: ModelConfig, tp: int, dtype=jnp.bfloat16,
               fuse_xz: bool = False) -> Dict:
    d_in, dt_rank, d_state, d_conv = _dims(cfg, tp)
    dm = cfg.d_model
    d_in_loc = d_in // tp
    ks = jax.random.split(key, 6)
    std = dm ** -0.5
    from repro.models import init_utils as iu
    d_can = cfg.mamba.expand * cfg.d_model          # canonical channel count
    k_in_x, k_in_z = jax.random.split(ks[5])
    w_in_x = iu.zero_pad_cols(
        jax.random.normal(k_in_x, (dm, d_can)) * std, d_in).astype(dtype)
    w_in_z = iu.zero_pad_cols(
        jax.random.normal(k_in_z, (dm, d_can)) * std, d_in).astype(dtype)
    inproj = ({"w_in_xz": iu.pack_pair(w_in_x, w_in_z, tp)} if fuse_xz
              else {"w_in_x": w_in_x, "w_in_z": w_in_z})
    return {
        # separate (or per-device packed) x/z in-projections, column-sharded
        # over TP with channel-consistent local splits; padded channels ZERO
        **inproj,
        "conv": iu.zero_pad_cols(
            jax.random.normal(ks[1], (d_conv, d_can)) * 0.1, d_in).astype(dtype),
        "conv_b": jnp.zeros((d_in,), dtype),
        # x_proj is ROW-sharded over channels (input d_in): output replicated
        "w_x": iu.zero_pad_rows(
            jax.random.normal(ks[2], (d_can, dt_rank + 2 * d_state))
            * d_can ** -0.5, d_in).astype(dtype),
        "w_dt": iu.zero_pad_cols(
            jax.random.normal(ks[3], (dt_rank, d_can)) * dt_rank ** -0.5,
            d_in).astype(dtype),
        "dt_bias": jnp.full((d_in,), -4.6, dtype),       # softplus^-1(0.01)
        "a_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, d_state + 1, dtype=jnp.float32), (d_in, d_state))),
        "d_skip": jnp.ones((d_in,), jnp.float32),
        "w_out": iu.zero_pad_rows(
            jax.random.normal(ks[4], (d_can, dm)) * d_can ** -0.5,
            d_in).astype(dtype),
        "norm": layers.init_rms_norm(dm, dtype),
    }


def _local(p: Dict, name: str, ctx: TPContext, axis: int) -> Array:
    """Channel-sharded parameters arrive pre-sharded via ``compat.shard_map``
    specs;
    helpers below assume they are already local."""
    return p[name]


def _selective_scan_chunk(x, dt, b_in, c_in, a, h0):
    """One chunk: x,dt: [B,L,C]; b_in,c_in: [B,L,N]; a: [C,N]; h0: [B,C,N].
    Returns (y [B,L,C], h_final).  Associative scan over L in fp32."""
    dta = jnp.einsum("blc,cn->blcn", dt, a)              # dt*A  (negative)
    decay = jnp.exp(dta)                                 # [B,L,C,N]
    inp = jnp.einsum("blc,bln->blcn", dt * x, b_in)      # dt*x*B

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, b1 * a2 + b2

    dec_s, inp_s = lax.associative_scan(combine, (decay, inp), axis=1)
    h = dec_s * h0[:, None] + inp_s                      # [B,L,C,N]
    y = jnp.einsum("blcn,bln->blc", h, c_in)
    return y, h[:, -1]


def mamba_train(p: Dict, x: Array, ctx: TPContext, cfg: ModelConfig,
                chunk: int = 256, with_cache: bool = False,
                lengths=None, cache=None):
    """x: [B, S/TP, D] -> [B, S/TP, D] (replicated layout: [B, S, D] with
    the same seams under hidden scatter; the conv/scan always see the full
    sequence either way).

    ``lengths`` ([B] int32, optional): per-row true prompt lengths for a
    right-padded batched prefill.  Pad positions get dt=0 — decay exp(0)=1
    and zero input leave the SSM state INVARIANT, so the returned ``ssm``
    cache is exactly the state after each row's true prompt; the ``conv``
    tail is sliced per row at its own length.  Outputs at pad positions are
    garbage and must not be read (prefill selects logits at lengths-1).

    ``cache`` ({conv, ssm}, optional): the recurrent state at sequence
    position 0 — seeds a CHUNKED prefill continuing a previous chunk
    (replicated layout only: the chunk is sequence-local)."""
    d_in, dt_rank, d_state, d_conv = _dims(cfg, ctx.tp)
    d_in_loc = d_in // ctx.tp
    b, s_loc, dm = x.shape
    s = s_loc * ctx.seq_factor
    assert cache is None or not ctx.seq_sharded

    h = layers.rms_norm(x, p["norm"], cfg.norm_eps)
    if "w_in_xz" in p:
        xz = ctx.op("attn_ag")(h, p["w_in_xz"])
        xs_raw, z = jnp.split(xz, 2, axis=-1)
    else:
        # separate x/z in-projections share ONE gather ring (multi-output
        # FusedOp: the z gate applies only after the scan, so no epilogue)
        xs_raw, z = ctx.op("attn_ag", n_weights=2)(h, p["w_in_x"],
                                                   p["w_in_z"])

    # causal depthwise conv along the (gathered) sequence; a carried-in
    # cache replaces the leading zero-pad with the previous chunk's tail
    if cache is None:
        xpad = jnp.pad(xs_raw, ((0, 0), (d_conv - 1, 0), (0, 0)))
    else:
        xpad = jnp.concatenate([cache["conv"].astype(xs_raw.dtype), xs_raw],
                               axis=1)
    conv = sum(xpad[:, i:i + s] * p["conv"][i] for i in range(d_conv))
    xs = jax.nn.silu(conv + p["conv_b"])

    # x_proj: row-parallel GEMM + AllReduce (B/C/dt shared across shards)
    xdb = ctx.op("decode_ar")(xs, p["w_x"])
    dt_low, b_in, c_in = jnp.split(xdb, [dt_rank, dt_rank + d_state], axis=-1)
    dt = jax.nn.softplus(jnp.einsum("bsr,rc->bsc", dt_low, p["w_dt"])
                         + p["dt_bias"].astype(jnp.float32))
    if lengths is not None:
        in_prompt = jnp.arange(s)[None, :] < lengths[:, None]    # [B, S]
        dt = jnp.where(in_prompt[:, :, None], dt, 0.0)
    a = -jnp.exp(p["a_log"])                             # [C_loc, N]

    # chunked scan over the sequence
    chunk = min(chunk, s)
    while s % chunk:
        chunk //= 2
    nck = s // chunk
    xs32 = xs.astype(jnp.float32)
    b32, c32 = b_in.astype(jnp.float32), c_in.astype(jnp.float32)

    def step(hprev, i):
        sl = lambda t: lax.dynamic_slice_in_dim(t, i * chunk, chunk, axis=1)
        y, hnew = _selective_scan_chunk(sl(xs32), sl(dt), sl(b32), sl(c32),
                                        a, hprev)
        return hnew, y

    h0 = (jnp.zeros((b, d_in_loc, d_state), jnp.float32) if cache is None
          else cache["ssm"].astype(jnp.float32))
    hfin, ys = lax.scan(step, h0, jnp.arange(nck))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, d_in_loc)

    y = y + xs32 * p["d_skip"]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = ctx.op("attn_rs")(y, p["w_out"])
    if with_cache:
        # conv cache stores the last d_conv-1 PRE-conv projected inputs
        if lengths is None:
            conv_tail = xs_raw[:, s - (d_conv - 1):, :]
        else:
            # per-row tail BEFORE each row's true length; the front zero-pad
            # makes short prompts (len < d_conv-1) resolve to leading zeros,
            # matching a from-scratch token-by-token decode.
            conv_tail = jax.vmap(
                lambda t, l: lax.dynamic_slice_in_dim(t, l, d_conv - 1,
                                                      axis=0))(xpad, lengths)
        return out, {"conv": conv_tail.astype(x.dtype), "ssm": hfin}
    return out


def mamba_decode(p: Dict, x: Array, cache: Dict, pos: Array, ctx: TPContext,
                 cfg: ModelConfig) -> Tuple[Array, Dict]:
    """Single-token state update.  cache = {conv: [B, d_conv-1, C_loc],
    ssm: [B, C_loc, N]}.  O(1) in sequence length (long_500k path)."""
    d_in, dt_rank, d_state, d_conv = _dims(cfg, ctx.tp)
    b = x.shape[0]

    h = layers.rms_norm(x, p["norm"], cfg.norm_eps)
    if "w_in_xz" in p:
        xz = jnp.einsum("bsd,df->bsf", h, p["w_in_xz"])[:, 0]
        xs, z = jnp.split(xz, 2, axis=-1)
    else:
        xs = jnp.einsum("bsd,df->bsf", h, p["w_in_x"])[:, 0]  # local, no comm
        z = jnp.einsum("bsd,df->bsf", h, p["w_in_z"])[:, 0]   # [B, C_loc]

    hist = jnp.concatenate([cache["conv"], xs[:, None]], axis=1)
    conv = jnp.einsum("bkc,kc->bc", hist, p["conv"]) + p["conv_b"]
    xs = jax.nn.silu(conv)
    new_conv = hist[:, 1:]

    ar_op = ctx.op("decode_ar")
    xdb = ar_op(xs[:, None], p["w_x"])[:, 0]
    dt_low, b_in, c_in = jnp.split(xdb, [dt_rank, dt_rank + d_state], axis=-1)
    dt = jax.nn.softplus(jnp.einsum("br,rc->bc", dt_low, p["w_dt"])
                         + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"])

    xs32 = xs.astype(jnp.float32)
    decay = jnp.exp(jnp.einsum("bc,cn->bcn", dt, a))
    hnew = cache["ssm"] * decay + jnp.einsum(
        "bc,bn->bcn", dt * xs32, b_in.astype(jnp.float32))
    y = jnp.einsum("bcn,bn->bc", hnew, c_in.astype(jnp.float32))
    y = y + xs32 * p["d_skip"]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)[:, None]
    out = ar_op(y, p["w_out"])
    return out, {"conv": new_conv, "ssm": hnew}


def mamba_cache_spec(cfg: ModelConfig, tp: int, batch_local: int,
                     dtype=jnp.bfloat16) -> Dict:
    d_in, dt_rank, d_state, d_conv = _dims(cfg, tp)
    d_in_loc = d_in // tp
    return {
        "conv": jax.ShapeDtypeStruct((batch_local, d_conv - 1, d_in_loc), dtype),
        "ssm": jax.ShapeDtypeStruct((batch_local, d_in_loc, d_state),
                                    jnp.float32),
    }
