"""FFN blocks: dense SwiGLU (Megatron TP seams) and expert-parallel MoE.

MoE dispatch is a capacity-bucketed exchange over the EP group (a dedicated
"ep" axis, the "model" axis, or ("data","model") jointly for DeepSeek-scale
expert counts).  The whole middle — dispatch a2a, batched per-local-expert
GEMMs, combine a2a — is ONE ``overlap.FusedOp(kind="a2a")`` seam
(``ctx.op("moe_a2a")``): ring modes decompose both exchanges into ppermute
chunks hidden under the chunked expert compute, the FLUX move applied to
expert parallelism.  The shared-expert path is a regular dense TP FFN.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro import compat
from repro.configs.base import ModelConfig, MoEConfig
from repro.core import overlap
from repro.models import layers
from repro.parallel.sharding import TPContext, pad_ff

Array = jax.Array


# ---------------------------------------------------------------------------
# Dense SwiGLU FFN (the paper's Fig. 2 MLP — both FLUX seams)
# ---------------------------------------------------------------------------
def init_ffn(key, d_model: int, d_ff: int, tp: int, dtype=jnp.bfloat16,
             fuse13: bool = False) -> Dict:
    """Canonical d_ff init, zero-padded to the TP-aligned width (padding is
    function-preserving: silu(0)*0 @ 0-rows contributes nothing).
    ``fuse13`` packs w1|w3 into one per-device-interleaved w13 so the
    forward needs ONE AllGather-GEMM instead of two (§Perf iteration)."""
    from repro.models import init_utils as iu
    ffp = pad_ff(d_ff, tp)
    k1, k2, k3 = jax.random.split(key, 3)
    std = d_model ** -0.5
    w1 = iu.zero_pad_cols(
        jax.random.normal(k1, (d_model, d_ff)) * std, ffp).astype(dtype)
    w3 = iu.zero_pad_cols(
        jax.random.normal(k2, (d_model, d_ff)) * std, ffp).astype(dtype)
    p = {
        "w2": iu.zero_pad_rows(
            jax.random.normal(k3, (d_ff, d_model)) * (d_ff ** -0.5),
            ffp).astype(dtype),
        "norm": layers.init_rms_norm(d_model, dtype),
    }
    if fuse13:
        p["w13"] = iu.pack_pair(w1, w3, tp)
    else:
        p["w1"] = w1
        p["w3"] = w3
    return p


def ffn_train(p: Dict, x: Array, ctx: TPContext, eps: float = 1e-5) -> Array:
    """x: [B, S/TP, D] -> [B, S/TP, D].  w1/w3 column-sharded, w2 row-sharded.

    The SwiGLU gate is a fused epilogue of the AllGather seam, and the
    separate-w1/w3 layout shares ONE gather ring for both GEMMs (the plan's
    ``shared_gather`` knob) — gather once, multiply twice."""
    h = layers.rms_norm(x, p["norm"], eps)
    if "w13" in p:
        # packed per-device [w1_i | w3_i]: one GEMM, gate on the split halves
        y = ctx.op("mlp_ag", epilogue=overlap.Epilogue(
            activation="silu", gate="split"))(h, p["w13"])
    else:
        y = ctx.op("mlp_ag", epilogue=overlap.Epilogue(
            activation="silu", gate="pair"), n_weights=2)(h, p["w1"], p["w3"])
    return ctx.op("mlp_rs")(y, p["w2"])


def ffn_decode(p: Dict, x: Array, ctx: TPContext, eps: float = 1e-5) -> Array:
    """x: [B, 1, D] replicated -> [B, 1, D]; row-parallel AR seam."""
    h = layers.rms_norm(x, p["norm"], eps)
    if "w13" in p:
        a13 = jnp.einsum("bsd,df->bsf", h, p["w13"])
        a, g = jnp.split(a13, 2, axis=-1)
    else:
        a = jnp.einsum("bsd,df->bsf", h, p["w1"])
        g = jnp.einsum("bsd,df->bsf", h, p["w3"])
    y = jax.nn.silu(a) * g
    return ctx.op("decode_ar")(y, p["w2"])


# ---------------------------------------------------------------------------
# Mixture of Experts
# ---------------------------------------------------------------------------
def init_moe(key, cfg: ModelConfig, ep: int, tp: int,
             dtype=jnp.bfloat16, fuse13: bool = False) -> Dict:
    """GLOBAL expert stacks (the EP sharding lives in param_specs; forward
    code sees the local E/ep slice via shard_map)."""
    mc = cfg.moe
    dm = cfg.d_model
    e = mc.num_experts
    ks = jax.random.split(key, 5)
    std = dm ** -0.5
    p = {
        "router": (jax.random.normal(ks[0], (dm, mc.num_experts))
                   * std).astype(jnp.float32),
        "w1": (jax.random.normal(ks[1], (e, dm, mc.expert_ffn))
               * std).astype(dtype),
        "w3": (jax.random.normal(ks[2], (e, dm, mc.expert_ffn))
               * std).astype(dtype),
        "w2": (jax.random.normal(ks[3], (e, mc.expert_ffn, dm))
               * (mc.expert_ffn ** -0.5)).astype(dtype),
        "norm": layers.init_rms_norm(dm, dtype),
    }
    if mc.num_shared_experts:
        p["shared"] = init_ffn(ks[4], dm,
                               mc.shared_ffn * mc.num_shared_experts, tp,
                               dtype, fuse13=fuse13)
        # shared path norm is the same pre-norm; drop its private norm
        del p["shared"]["norm"]
    return p


def _capacity(tokens: int, mc: MoEConfig) -> int:
    per_expert = tokens * mc.top_k / mc.num_experts
    c = int(per_expert * mc.capacity_factor) + 1
    return max(c, 4)


def moe_train(p: Dict, x: Array, ctx: TPContext, cfg: ModelConfig,
              eps: float = 1e-5, lengths=None) -> Tuple[Array, Array]:
    """x: [B, S/TP, D] -> ([B, S/TP, D], aux_loss).

    Stages: router -> capacity-bucketed dispatch (scatter) -> ONE fused
    ``kind="a2a"`` op (EP all_to_all out + batched expert GEMMs + all_to_all
    back, ring modes overlapped; ``ctx.op("moe_a2a")``) -> combine.

    ``lengths`` ([B] int32, optional): per-row true prompt lengths of a
    right-padded prefill batch.  Pad tokens are removed from the capacity
    cumsum, the dispatch, and the combine — without this they would occupy
    expert capacity slots and EVICT real tokens of other rows.

    Layouts: under the sequence-sharded residual stream each rank routes
    its OWN sequence shard and the EP exchange is the capacity-bucketed
    all_to_all.  Under the replicated layout every rank holds the same
    tokens, so an all_to_all over the model axis would dispatch each token
    TP times — instead each rank computes only its LOCAL experts'
    contributions for the full token set and a psum over the EP group
    combines them (the moe_decode strategy, with training capacity
    semantics).

    CAVEATS (where the two layouts are not interchangeable):

    * capacity EVICTION order is layout-dependent — "seq" buckets per
      source shard with a per-shard quota, the replicated branch buckets
      one global arrival order — so WHICH tokens drop at a saturated
      expert differs.  Drop-free (capacity_factor high enough, as the
      equivalence tests pin) the layouts agree exactly; under drops they
      are statistically, not numerically, equivalent.
    * the replicated TRAIN path supports EP over the model axis only:
      with ``ep_over_dp`` each rank's local experts contribute to EVERY
      data shard's tokens, so router/expert grads come out as EP-group
      partials that the DP grad contract (per-data-shard grads, averaged)
      mis-sums — that configuration raises instead of training wrong
      (decode, which is grad-free, keeps the full multi-axis path in
      ``moe_decode``).
    """
    mc = cfg.moe
    b, s_loc, dm = x.shape
    t = b * s_loc
    ep_axes = ctx.ep_axes or ((ctx.axis,) if ctx.axis else ())
    ep = 1
    for a in ep_axes:
        ep = ep * compat.axis_size(a)
    e = mc.num_experts
    e_loc = max(e // ep, 1)
    replicated = ep > 1 and not ctx.seq_sharded

    h = layers.rms_norm(x, p["norm"], eps)
    ht = h.reshape(t, dm)
    if replicated and any(a != ctx.axis for a in ep_axes):
        raise NotImplementedError(
            "replicated activation layout (scatter_axis='hidden') does not "
            "support training MoE with experts over the data axis "
            "(ep_over_dp): the local-expert combine yields EP-group "
            "partial router/expert grads that break the DP grad contract. "
            "Train ep_over_dp MoE under the sequence-sharded layout "
            "(scatter_axis='seq').")

    # ---- router (fp32) ------------------------------------------------------
    logits = jnp.einsum("td,de->te", ht.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = lax.top_k(probs, mc.top_k)             # [t, k]
    gate = gate / jnp.clip(jnp.sum(gate, -1, keepdims=True), 1e-9)

    # pad mask of a right-padded prefill batch: pad rows must not count in
    # the router statistics, the capacity cumsum, the dispatch or the combine
    valid_t = None
    if lengths is not None:
        valid_t = (layers.seq_positions(b, s_loc, ctx)
                   < lengths[:, None]).reshape(b * s_loc)    # [t]

    # load-balance aux loss (Switch-style).  me/ce are GLOBAL VALID-token
    # means: sum masked per-shard contributions and divide by the psum'd
    # valid count.  Per-shard valid counts differ under right-padding, so a
    # pmean of per-shard means would weight shards unequally — and unmasked
    # pad rows would bias the loss toward whatever garbage pads route to.
    vmask = (jnp.ones((t,), probs.dtype) if valid_t is None
             else valid_t.astype(probs.dtype))
    me = jnp.sum(probs * vmask[:, None], axis=0)
    ce = jnp.sum(jax.nn.one_hot(eidx[:, 0], e) * vmask[:, None], axis=0)
    cnt = jnp.sum(vmask)
    for ax in ((ctx.axis,) if ctx.axis else ()) + tuple(ctx.dp_axes):
        if compat.axis_size(ax) > 1:
            me = lax.psum(me, ax)
            ce = lax.psum(ce, ax)
            cnt = lax.psum(cnt, ax)
    cnt = jnp.maximum(cnt, 1.0)
    aux = e * jnp.sum((me / cnt) * (ce / cnt))

    # ---- capacity bucketing --------------------------------------------------
    cap = _capacity(t, mc)                              # per (global) expert here
    flat_e = eidx.reshape(-1)                           # [t*k]
    oh = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)     # [t*k, E]
    if valid_t is not None:
        flat_valid = jnp.repeat(valid_t, mc.top_k)       # [t*k]
        oh = oh * flat_valid[:, None].astype(oh.dtype)   # pads don't count
    pos_in_e = jnp.sum(jnp.cumsum(oh, axis=0) * oh, axis=-1) - 1
    keep = pos_in_e < cap
    if valid_t is not None:
        keep = keep & flat_valid
    slot = jnp.clip(pos_in_e, 0, cap - 1)

    src = jnp.repeat(jnp.arange(t), mc.top_k)
    gates = gate.reshape(-1)
    if replicated:
        # local-experts + psum: every rank holds the same bucketed dispatch
        # (identical cumsum), computes ONLY its e_loc experts, and the EP
        # psum combines — no all_to_all (which would dispatch every token
        # ep times here)
        ep_rank = jnp.zeros((), jnp.int32)
        for a in ep_axes:
            ep_rank = ep_rank * compat.axis_size(a) + lax.axis_index(a)
        e_start = ep_rank * e_loc
        local_e = flat_e - e_start
        is_local = (local_e >= 0) & (local_e < e_loc)
        local_e = jnp.clip(local_e, 0, e_loc - 1)
        keep_loc = keep & is_local
        disp = jnp.zeros((e_loc, cap, dm), ht.dtype)
        disp = disp.at[local_e, slot].add(
            jnp.where(keep_loc[:, None], ht[src], 0))
        a1 = jnp.einsum("ecd,edf->ecf", disp, p["w1"])
        a3 = jnp.einsum("ecd,edf->ecf", disp, p["w3"])
        out = jnp.einsum("ecf,efd->ecd", jax.nn.silu(a1) * a3, p["w2"])
        vals = out[local_e, slot]
        vals = jnp.where(keep_loc[:, None], vals, 0)
        comb = jax.ops.segment_sum(vals * gates[:, None], src,
                                   num_segments=t)
        with jax.named_scope("seam_moe_combine"):
            for a in ep_axes:
                comb = lax.psum(comb, a)
        y = comb.reshape(b, s_loc, dm).astype(x.dtype)
    else:
        disp = jnp.zeros((e, cap, dm), ht.dtype)
        disp = disp.at[flat_e, slot].add(
            jnp.where(keep[:, None], ht[src], 0))

        # ---- overlapped EP exchange + expert GEMMs ---------------------------
        # ONE FusedOp owns the whole middle: the dispatch all_to_all, the
        # batched per-local-expert SwiGLU GEMMs, and the combine all_to_all
        # (kind="a2a"; ring modes decompose both exchanges into ppermute
        # chunks hidden under the chunked expert compute).  Dim 0 of the
        # [ep, e_loc, cap, dm] buffer indexes the DESTINATION EP rank
        # (experts are blocked: global expert = ep_rank * e_loc + local),
        # and the op returns the same layout.
        buf = disp.reshape(ep, e_loc, cap, dm)
        ret = ctx.op("moe_a2a", epilogue=overlap.Epilogue(
            activation="silu", gate="pair"),
            n_weights=3)(buf, p["w1"], p["w3"], p["w2"])
        ret = ret.reshape(e, cap, dm)

        # combine: gather each (token, k) slot's output, weighted by gate
        vals = ret[flat_e, slot]                         # [t*k, dm]
        vals = jnp.where(keep[:, None], vals, 0)
        comb = jax.ops.segment_sum(vals * gates[:, None], src,
                                   num_segments=t)
        y = comb.reshape(b, s_loc, dm).astype(x.dtype)

    if mc.num_shared_experts:
        sh = {"norm": p["norm"], **{k: v for k, v in p["shared"].items()}}
        y = y + ffn_train(sh, x, ctx, eps)
    return y, aux.astype(jnp.float32)


def moe_decode(p: Dict, x: Array, ctx: TPContext, cfg: ModelConfig,
               eps: float = 1e-5) -> Array:
    """Decode MoE.  x: [B, 1, D] REPLICATED over the model axis (decode has
    no sequence sharding).  Tokens that belong to other data shards of the EP
    group are brought in by a (tiny) all_gather; every device computes only
    its LOCAL experts' contributions, and a psum over the EP group combines
    them — no all_to_all needed at one-token scale."""
    mc = cfg.moe
    b = x.shape[0]
    dm = x.shape[-1]
    ep_axes = ctx.ep_axes or ((ctx.axis,) if ctx.axis else ())
    ep = 1
    for a in ep_axes:
        ep = ep * compat.axis_size(a)
    e = mc.num_experts
    e_loc = max(e // ep, 1)

    h = layers.rms_norm(x, p["norm"], eps)
    ht = h.reshape(b, dm)
    # gather tokens across the data portion of the EP group (tokens are
    # already replicated over the model axis)
    gather_axes = tuple(a for a in ep_axes if a != ctx.axis)
    with jax.named_scope("seam_moe_gather"):
        for a in gather_axes:
            # EP-group token exchange over the DATA axes (never the TP
            # axis); one token per data shard at decode scale
            ht = lax.all_gather(  # lint: allow(raw-collective)
                ht, a, axis=0, tiled=True)
    t = ht.shape[0]

    logits = jnp.einsum("td,de->te", ht.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = lax.top_k(probs, mc.top_k)
    gate = gate / jnp.clip(jnp.sum(gate, -1, keepdims=True), 1e-9)

    # rank of this device inside the EP group -> which experts are local
    ep_rank = jnp.zeros((), jnp.int32)
    for a in ep_axes:
        ep_rank = ep_rank * compat.axis_size(a) + lax.axis_index(a)
    e_start = ep_rank * e_loc

    flat_e = eidx.reshape(-1)
    local_e = flat_e - e_start
    is_local = (local_e >= 0) & (local_e < e_loc)
    local_e = jnp.clip(local_e, 0, e_loc - 1)
    # statistical capacity (§Perf iteration, deepseek decode): buckets sized
    # ~8x the mean per-expert load instead of t*k — cuts the batched expert
    # GEMMs ~e/8-fold.  Overflow probability is a Poisson tail (negligible);
    # any overflow drops, matching training-time capacity semantics.
    cap = int(min(t * mc.top_k, max(32, (t * mc.top_k * 8) // e)))
    src = jnp.repeat(jnp.arange(t), mc.top_k)
    oh = jax.nn.one_hot(jnp.where(is_local, local_e, e_loc), e_loc + 1,
                        dtype=jnp.int32)[:, :e_loc]      # [t*k, e_loc]
    pos = jnp.sum(jnp.cumsum(oh, axis=0) * oh, axis=-1) - 1
    keep = is_local & (pos >= 0) & (pos < cap)
    slot = jnp.clip(pos, 0, cap - 1)

    disp = jnp.zeros((e_loc, cap, dm), ht.dtype)
    disp = disp.at[local_e, slot].add(jnp.where(keep[:, None], ht[src], 0))
    a1 = jnp.einsum("ecd,edf->ecf", disp, p["w1"])
    a3 = jnp.einsum("ecd,edf->ecf", disp, p["w3"])
    out = jnp.einsum("ecf,efd->ecd", jax.nn.silu(a1) * a3, p["w2"])

    vals = out[local_e, slot]
    vals = jnp.where(keep[:, None], vals, 0)
    comb = jax.ops.segment_sum(vals * gate.reshape(-1)[:, None], src,
                               num_segments=t)
    with jax.named_scope("seam_moe_combine"):
        for a in ep_axes:
            comb = lax.psum(comb, a)
    # keep this data shard's rows (gather order: axis-major blocks)
    if gather_axes:
        # sequential all_gathers make the LAST gathered axis outermost
        my_off = jnp.zeros((), jnp.int32)
        blk = t
        for a in reversed(gather_axes):
            blk = blk // compat.axis_size(a)
            my_off = my_off + lax.axis_index(a) * blk
        comb = lax.dynamic_slice_in_dim(comb, my_off, b, axis=0)
    y = comb.reshape(b, 1, dm).astype(x.dtype)

    if mc.num_shared_experts:
        sh = {"norm": p["norm"], **{k: v for k, v in p["shared"].items()}}
        y = y + ffn_decode(sh, x, ctx, eps)
    return y
