"""Canonical-init helpers: TP-layout-consistent parameter construction.

Contiguous column sharding of packed projections must slice WHOLE per-device
blocks, and padded dims must be ZERO so padding never changes the function —
this is what makes a checkpoint reshardable across TP degrees (tp=8 and
tp=16 runs compute the same function).
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

Array = jax.Array


def zero_pad_cols(w: Array, to: int) -> Array:
    """Pad the last dim with zeros up to ``to`` columns."""
    if w.shape[-1] == to:
        return w
    pad = [(0, 0)] * (w.ndim - 1) + [(0, to - w.shape[-1])]
    return jnp.pad(w, pad)


def zero_pad_rows(w: Array, to: int) -> Array:
    if w.shape[0] == to:
        return w
    pad = [(0, to - w.shape[0])] + [(0, 0)] * (w.ndim - 1)
    return jnp.pad(w, pad)


def interleave_heads(w: Array, n_heads: int, head_dim: int, tp: int,
                     pad_heads_to: int) -> Array:
    """[D, H*dh] canonical head-major columns -> zero-padded to
    ``pad_heads_to`` heads (pads distributed so each TP shard gets
    heads_pad/tp whole heads, canonical heads in order)."""
    d = w.shape[0]
    w = w.reshape(d, n_heads, head_dim)
    if pad_heads_to != n_heads:
        w = jnp.pad(w, ((0, 0), (0, pad_heads_to - n_heads), (0, 0)))
    return w.reshape(d, pad_heads_to * head_dim)


def replicate_kv_heads(w: Array, n_kv: int, head_dim: int, tp: int,
                       pad_kv_to: int) -> Array:
    """[D, Hkv*dh] canonical -> replicated layout when Hkv < TP: padded kv
    head p serves the TP shard p and maps to canonical head p*Hkv//TP (so
    each shard's kv group matches its q heads)."""
    d = w.shape[0]
    w = w.reshape(d, n_kv, head_dim)
    if pad_kv_to == n_kv:
        return w.reshape(d, n_kv * head_dim)
    if n_kv < tp:
        idx = jnp.arange(pad_kv_to) * n_kv // pad_kv_to
        w = w[:, idx]
    else:
        w = jnp.pad(w, ((0, 0), (0, pad_kv_to - n_kv), (0, 0)))
    return w.reshape(d, pad_kv_to * head_dim)


def pack_qkv(wq: Array, wk: Array, wv: Array, tp: int) -> Array:
    """Interleave per-device blocks: [dev0: q|k|v | dev1: q|k|v | ...] so a
    contiguous column shard holds exactly its own q,k,v."""
    d = wq.shape[0]
    ql = wq.shape[1] // tp
    kl = wk.shape[1] // tp
    vl = wv.shape[1] // tp
    parts = []
    for i in range(tp):
        parts += [wq[:, i * ql:(i + 1) * ql],
                  wk[:, i * kl:(i + 1) * kl],
                  wv[:, i * vl:(i + 1) * vl]]
    return jnp.concatenate(parts, axis=1)


def unpack_qkv_local(qkv_local: Array, ql: int, kl: int, vl: int):
    """Inverse of pack_qkv on ONE device's shard (last dim = ql+kl+vl)."""
    q = qkv_local[..., :ql]
    k = qkv_local[..., ql:ql + kl]
    v = qkv_local[..., ql + kl:]
    return q, k, v


def pack_pair(wa: Array, wb: Array, tp: int) -> Array:
    """Interleave two equally-shaped column-sharded weights per device:
    [dev0: a|b | dev1: a|b | ...] so one contiguous shard holds its own
    (a, b) halves — enables ONE AllGather-GEMM for parallel projections."""
    al = wa.shape[1] // tp
    bl = wb.shape[1] // tp
    parts = []
    for i in range(tp):
        parts += [wa[:, i * al:(i + 1) * al], wb[:, i * bl:(i + 1) * bl]]
    return jnp.concatenate(parts, axis=1)
