"""Per-seam overlap plan registry (paper §4.4, made persistent).

FLUX's speedups come from *tuning*: template parameters, pull/push direction,
and communication tile size are selected per (GEMM shape, dtype, arch,
interconnect) and cached.  This package is that subsystem for our JAX port:

  plans.py     ``SeamPlan`` (one seam's knob settings) and ``PlanSet`` (the
               per-layer-seam resolution table threaded through the model via
               ``TPContext.plans``).
  autotune.py  the tuner: enumerates ``(mode, comm_chunks, reverse, bm/bk/bn)``
               candidates per seam, times them with jitted sweeps on the real
               devices, and falls back to the ``core.ect`` roofline when
               measurement is meaningless (single device, or Pallas interpret
               mode under ``REPRO_PALLAS_INTERPRET=1``).
  cache.py     the persistent JSON profile cache (``experiments/plans/*.json``)
               with save/load round-trip and staleness versioning.
  error_budget.py  deviation estimates (codec / per-seam proxy / end-to-end
               logits) gating the ``wire_dtype`` sweep: a quantized wire may
               only win a seam when its deviation fits ``max_logit_rmse``.

Profile JSON schema (``cache.PROFILE_VERSION`` bumps on breaking change)::

    {
      "version": 1,                    # schema version; mismatch -> stale
      "backend": "cpu" | "tpu" | ..., # jax.default_backend() at tune time
      "mesh": {"n_dev": 4},           # TP degree the plans were tuned for
      "entries": {
        "mlp_ag|m4096,n512,k256,tp4,b2": {
          "seam": "mlp_ag",            # model seam name (plans.KNOWN_SEAMS)
          "kind": "ag",                # collective kind: ag | rs | ar
          "m": 4096, "n": 512, "k": 256,
          "n_dev": 4, "dtype_bytes": 2,
          "plan": {
            "mode": "decomposed",      # overlap.VALID_MODES
            "comm_chunks": 8,          # §4.3 communication tile size (0=auto)
            "reverse": false,          # ring direction (pull/push analogue)
            "blocks": [256, 512, 256], # (bm, bk, bn) MXU tile
            "wire_dtype": null,        # wire precision (null = fp wire;
                                       # absent in pre-wire profiles and
                                       # loaded as the fp wire)
            "source": "measured",      # measured | analytic
            "predicted_s": 1.2e-4,     # roofline OverallTime
            "measured_s": 9.8e-5,      # median wall time (0 when analytic)
            "logit_rmse": 0.0          # deviation estimate the winner was
                                       # admitted under (0 for fp wire)
          }
        }, ...
      }
    }

A profile is *stale* (ignored on load) when its ``version`` differs from
``PROFILE_VERSION`` or its ``mesh``/``backend`` disagree with the requester's.
"""
from repro.tuning.plans import (KNOWN_SEAMS, RESIDUAL_SEAMS,  # noqa: F401
                                PlanSet, SeamPlan,
                                plan_set_from_parallel, seam_of)
from repro.tuning.cache import (PROFILE_VERSION, PlanRegistry,  # noqa: F401
                                default_plans_dir)
from repro.tuning.autotune import (TuneResult, WIRE_DTYPE_SWEEP,  # noqa: F401
                                   autotune_model, candidate_space,
                                   model_seam_shapes, sweep_model_layout,
                                   tune_seam, wire_supported)
from repro.tuning.error_budget import (DEFAULT_MAX_LOGIT_RMSE,  # noqa: F401
                                       codec_rmse, model_logit_rmse,
                                       seam_wire_rmse)
