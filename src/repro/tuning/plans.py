"""SeamPlan / PlanSet: the per-layer-seam overlap plan resolution table.

``TPContext.plans`` holds a ``PlanSet``; every TP seam in the model resolves
its knobs through ``PlanSet.resolve(seam, layer)`` instead of reading one
global ``ctx.mode``/``ctx.comm_chunks``.  Seam names are model-level (what
the layer is doing), not collective-level:

  mlp_ag    FFN up-projection AllGather-GEMM (w1/w3/w13)
  mlp_rs    FFN down-projection GEMM-ReduceScatter (w2)
  attn_ag   mixer input projection AllGather-GEMM (QKV / MLA up / mamba in)
  attn_rs   mixer output projection GEMM-ReduceScatter (wo / w_out)
  decode_ar row-parallel GEMM + AllReduce seams (decode paths of all mixers
            and FFNs, plus mamba's train-path x-projection AR)
  head_ag   LM-head AllGather-GEMM (the biggest single GEMM)
  moe_a2a   MoE expert-parallel token exchange (dispatch + expert GEMMs +
            combine as ONE overlapped op over the EP axis tuple)

Unknown seams fall back to the set's default, so the vocabulary is
extensible without touching this file.

Layer ids: leading (unrolled) layers use their absolute index; scanned
period positions use ``leading_dense_layers + position``.  All repetitions
of a scanned period share one trace, hence one plan per pattern position —
finer per-repetition overrides are structurally impossible under
``lax.scan`` and are rejected nowhere (they simply never match).

Every collective transport a resolved plan schedules runs under a
``seam_*`` ``jax.named_scope`` (``repro.core.overlap.SEAM_SCOPE_PREFIX``);
``repro.analysis.seamcheck`` statically verifies — for every config x both
layouts — that NO full-activation TP collective escapes that provenance
and that ``residual_layout()``'s coherence contract holds in the traced
jaxprs (``python -m repro.analysis.check --seams``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Optional, Tuple

KNOWN_SEAMS: Tuple[str, ...] = ("mlp_ag", "mlp_rs", "attn_ag", "attn_rs",
                                "decode_ar", "head_ag", "moe_a2a")

# collective kind behind each model seam (candidate spaces differ per kind)
SEAM_KINDS: Dict[str, str] = {"mlp_ag": "ag", "mlp_rs": "rs",
                              "attn_ag": "ag", "attn_rs": "rs",
                              "decode_ar": "ar", "head_ag": "ag",
                              "moe_a2a": "a2a"}

# the seams that carry the residual stream between blocks: their
# ``scatter_axis`` plans must AGREE (one activation layout per model) —
# the tuner sweeps the layout jointly and stamps all of them at once.
RESIDUAL_SEAMS: Tuple[str, ...] = ("mlp_ag", "mlp_rs", "attn_ag", "attn_rs",
                                   "head_ag")


def seam_of(key: str) -> str:
    """Model seam behind a (possibly shape-cell-qualified) seam key:
    ``"attn_ag@kv_up" -> "attn_ag"`` (cells mirror the dryrun cell naming —
    one tuning record per real GEMM shape of the seam)."""
    return key.split("@", 1)[0]


@dataclasses.dataclass(frozen=True)
class SeamPlan:
    """Knob settings for ONE seam (the paper's §4.4 tuning record).

    ``fuse_epilogue`` / ``shared_gather`` are the FusedOp fusion knobs
    (apply the epilogue per chunk inside the overlapped loop; one ring pass
    for multi-weight gathers) — plan-visible so the autotuner can sweep
    them per seam.  ``scatter_axis`` is the activation-layout knob
    ("seq" = sequence-sharded residual stream between seams, Megatron-SP;
    "hidden" = replicated residual stream, the decode layout) — swept
    JOINTLY across the residual seams (see ``PlanSet.residual_layout``).
    ``wire_dtype`` (None | "int8" | "fp8_e4m3" | "int4") quantizes the
    seam's FORWARD wire — swept by the tuner under a logit-RMSE budget
    (``repro.tuning.error_budget``); cotangents never ride it.  The
    ``logit_rmse`` field records the budget evidence the tuner measured
    for the chosen wire (0.0 for the fp wire)."""
    mode: str = "decomposed"
    comm_chunks: int = 0
    reverse: bool = False
    blocks: Optional[Tuple[int, int, int]] = None
    fuse_epilogue: bool = True
    shared_gather: bool = True
    scatter_axis: str = "seq"
    wire_dtype: Optional[str] = None
    source: str = "default"          # default | analytic | measured
    predicted_s: float = 0.0
    measured_s: float = 0.0
    logit_rmse: float = 0.0

    def validate(self) -> "SeamPlan":
        from repro.core.overlap import (VALID_MODES, VALID_SCATTER_AXES,
                                        VALID_WIRE_DTYPES, normalize_mode)
        mode, wd = normalize_mode(self.mode, self.wire_dtype)
        if (mode, wd) != (self.mode, self.wire_dtype):
            object.__setattr__(self, "mode", mode)
            object.__setattr__(self, "wire_dtype", wd)
        if self.mode not in VALID_MODES:
            raise ValueError(f"invalid overlap mode {self.mode!r}")
        if self.wire_dtype not in VALID_WIRE_DTYPES:
            raise ValueError(f"invalid wire_dtype {self.wire_dtype!r}")
        if self.comm_chunks < 0:
            raise ValueError(f"comm_chunks must be >= 0, got {self.comm_chunks}")
        if self.scatter_axis not in VALID_SCATTER_AXES:
            raise ValueError(f"invalid scatter_axis {self.scatter_axis!r}")
        return self

    def op(self, kind: str, axis=None, epilogue=None, n_weights: int = 1,
           scatter_axis: Optional[str] = None):
        """Bind this plan to a concrete ``overlap.FusedOp`` for one seam.
        ``scatter_axis`` overrides the plan's layout knob (the context layer
        passes the model-level resolved layout so every seam stays
        coherent)."""
        from repro.core.overlap import FusedOp
        return FusedOp.from_plan(kind, self, axis, epilogue=epilogue,
                                 n_weights=n_weights,
                                 scatter_axis=scatter_axis)

    def to_json(self) -> Dict:
        d = {"mode": self.mode, "comm_chunks": self.comm_chunks,
             "reverse": self.reverse, "source": self.source,
             "fuse_epilogue": self.fuse_epilogue,
             "shared_gather": self.shared_gather,
             "scatter_axis": self.scatter_axis,
             "wire_dtype": self.wire_dtype,
             "predicted_s": self.predicted_s, "measured_s": self.measured_s,
             "logit_rmse": self.logit_rmse}
        d["blocks"] = list(self.blocks) if self.blocks else None
        return d

    @staticmethod
    def from_json(d: Mapping) -> "SeamPlan":
        blocks = d.get("blocks")
        # profiles written before the wire_dtype field load as the fp wire
        return SeamPlan(mode=d["mode"], comm_chunks=int(d.get("comm_chunks", 0)),
                        reverse=bool(d.get("reverse", False)),
                        blocks=tuple(blocks) if blocks else None,
                        fuse_epilogue=bool(d.get("fuse_epilogue", True)),
                        shared_gather=bool(d.get("shared_gather", True)),
                        scatter_axis=d.get("scatter_axis", "seq"),
                        wire_dtype=d.get("wire_dtype"),
                        source=d.get("source", "default"),
                        predicted_s=float(d.get("predicted_s", 0.0)),
                        measured_s=float(d.get("measured_s", 0.0)),
                        logit_rmse=float(d.get("logit_rmse", 0.0))).validate()


@dataclasses.dataclass(frozen=True)
class PlanSet:
    """Per-seam (optionally per-layer) plan table.

    Resolution order: ``layers[layer][seam]`` -> ``seams[seam]`` -> default.
    """
    default: SeamPlan = SeamPlan()
    seams: Mapping[str, SeamPlan] = dataclasses.field(default_factory=dict)
    layers: Mapping[int, Mapping[str, SeamPlan]] = dataclasses.field(
        default_factory=dict)

    def resolve(self, seam: str, layer: Optional[int] = None) -> SeamPlan:
        if layer is not None:
            per_layer = self.layers.get(layer)
            if per_layer is not None and seam in per_layer:
                return per_layer[seam]
        return self.seams.get(seam, self.default)

    def override(self, seam: str, plan: SeamPlan,
                 layer: Optional[int] = None) -> "PlanSet":
        """Functional update (PlanSet is frozen)."""
        if layer is None:
            return dataclasses.replace(
                self, seams={**dict(self.seams), seam: plan})
        layers = {k: dict(v) for k, v in self.layers.items()}
        layers.setdefault(layer, {})[seam] = plan
        return dataclasses.replace(self, layers=layers)

    @staticmethod
    def uniform(mode: str, comm_chunks: int = 0,
                reverse: bool = False) -> "PlanSet":
        """The pre-registry behavior: one global mode for every seam."""
        return PlanSet(default=SeamPlan(mode=mode, comm_chunks=comm_chunks,
                                        reverse=reverse).validate())

    def residual_layout(self) -> str:
        """The model's activation layout ("seq" | "hidden"), resolved from
        the residual-stream seam plans.  All residual seams must agree —
        the RS side of one layer produces exactly the layout the next AG
        side consumes, so a per-seam mismatch would be an incoherent model
        and raises."""
        axes = {s: self.resolve(s).scatter_axis for s in RESIDUAL_SEAMS}
        distinct = set(axes.values())
        if len(distinct) > 1:
            raise ValueError(
                f"incoherent residual-stream layout: {axes} — stamp ONE "
                f"scatter_axis across the residual seams "
                f"(PlanSet.with_scatter_axis)")
        return distinct.pop()

    def with_scatter_axis(self, scatter_axis: str) -> "PlanSet":
        """Stamp one activation layout onto EVERY plan (default, seam and
        per-layer overrides) — the coherent way to flip the residual-stream
        layout ("ar" seams ignore the knob; they are always replicated)."""
        repl = lambda p: dataclasses.replace(  # noqa: E731
            p, scatter_axis=scatter_axis).validate()
        return PlanSet(
            default=repl(self.default),
            seams={s: repl(p) for s, p in self.seams.items()},
            layers={l: {s: repl(p) for s, p in ov.items()}
                    for l, ov in self.layers.items()})

    def with_wire_dtype(self, wire_dtype: Optional[str]) -> "PlanSet":
        """Stamp one wire dtype onto every plan (default, seam and
        per-layer overrides).  Flux plans keep the fp wire — the Pallas
        kernels have no quantized DMA path and would reject the knob."""
        repl = lambda p: (p if p.mode == "flux"  # noqa: E731
                          else dataclasses.replace(
                              p, wire_dtype=wire_dtype).validate())
        return PlanSet(
            default=repl(self.default),
            seams={s: repl(p) for s, p in self.seams.items()},
            layers={l: {s: repl(p) for s, p in ov.items()}
                    for l, ov in self.layers.items()})

    def to_json(self) -> Dict:
        return {"default": self.default.to_json(),
                "seams": {s: p.to_json() for s, p in self.seams.items()},
                "layers": {str(l): {s: p.to_json() for s, p in ov.items()}
                           for l, ov in self.layers.items()}}

    @staticmethod
    def from_json(d: Mapping) -> "PlanSet":
        return PlanSet(
            default=SeamPlan.from_json(d["default"]),
            seams={s: SeamPlan.from_json(p)
                   for s, p in d.get("seams", {}).items()},
            layers={int(l): {s: SeamPlan.from_json(p) for s, p in ov.items()}
                    for l, ov in d.get("layers", {}).items()})


def plan_set_from_parallel(par) -> PlanSet:
    """PlanSet for a ParallelConfig: the uniform ``overlap_mode`` default,
    overlaid with the per-seam plans from ``par.plan_profile`` when that
    profile exists, is fresh, and was tuned for this TP degree/backend.
    (Staleness is version/mesh/backend only — keep one profile per model.)
    ``par.scatter_axis`` ("seq"/"hidden") force-stamps the activation
    layout; "auto" keeps the profile's (or the "seq" default)."""
    base = PlanSet.uniform(par.overlap_mode, par.comm_chunks)
    profile = getattr(par, "plan_profile", None)
    if profile:
        from repro.tuning.cache import PlanRegistry
        reg = PlanRegistry.open(profile, n_dev=par.tp)
        seams = reg.seam_plans()
        if seams:
            base = dataclasses.replace(
                base, seams={**dict(base.seams), **seams})
            # adopt the profile's layout for the WHOLE set: residual seams
            # the profile doesn't record (arch without that seam) would
            # otherwise resolve to the default's "seq" and make
            # residual_layout() raise on a "hidden" profile
            axes = {p.scatter_axis for s, p in seams.items()
                    if seam_of(s) in RESIDUAL_SEAMS}
            if len(axes) == 1:
                base = base.with_scatter_axis(axes.pop())
    forced = getattr(par, "scatter_axis", "auto")
    if forced and forced != "auto":
        base = base.with_scatter_axis(forced)
    wire = getattr(par, "wire_dtype", None)
    if wire:
        base = base.with_wire_dtype(wire)
    return base
