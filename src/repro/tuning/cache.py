"""Persistent JSON profile cache for tuned seam plans.

One profile file = the tuned plans for one (model, mesh, backend) cell, e.g.
``experiments/plans/codeqwen15_7b_tp4.json``.  See the package docstring for
the schema.  Loading applies staleness checks: a file whose ``version``,
``mesh.n_dev`` or ``backend`` disagrees with the requester's is treated as
absent (returns an empty registry) — never half-trusted.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, Mapping, Optional

from repro.tuning.plans import SeamPlan

# v2: attention seams are recorded per (arch, shape cell) under qualified
# keys ("attn_ag@q_up" ...) and plans carry scatter_axis — v1 profiles'
# bare merged-shape attention entries would silently shadow the cell plans,
# so they are stale wholesale.
PROFILE_VERSION = 2


def default_plans_dir() -> str:
    """``experiments/plans/`` at the repo root (next to ``experiments/dryrun``)."""
    return os.path.join(os.path.dirname(__file__), "..", "..", "..",
                        "experiments", "plans")


def entry_key(seam: str, m: int, n: int, k: int, n_dev: int,
              dtype_bytes: int = 2) -> str:
    return f"{seam}|m{m},n{n},k{k},tp{n_dev},b{dtype_bytes}"


@dataclasses.dataclass
class PlanRegistry:
    """In-memory view of one profile file.

    ``entries`` maps :func:`entry_key` strings to dicts carrying the seam
    metadata and the serialized plan (schema in the package docstring).
    """
    n_dev: int
    backend: str = ""
    entries: Dict[str, Dict] = dataclasses.field(default_factory=dict)
    path: Optional[str] = None

    def __post_init__(self):
        if not self.backend:
            import jax
            self.backend = jax.default_backend()

    # ------------------------------------------------------------- access
    def record(self, seam: str, kind: str, m: int, n: int, k: int,
               plan: SeamPlan, dtype_bytes: int = 2) -> None:
        self.entries[entry_key(seam, m, n, k, self.n_dev, dtype_bytes)] = {
            "seam": seam, "kind": kind, "m": m, "n": n, "k": k,
            "n_dev": self.n_dev, "dtype_bytes": dtype_bytes,
            "plan": plan.to_json()}

    def stamp_scatter_axis(self, scatter_axis: str) -> None:
        """Rewrite EVERY entry's plan to one activation layout.  The layout
        is a model-level decision: a profile mixing layouts (e.g. cached
        entries from a run whose sweep picked differently) would make
        ``PlanSet.residual_layout()`` raise at load, so the tuner stamps
        the whole registry before saving."""
        for e in self.entries.values():
            e["plan"] = dict(e["plan"], scatter_axis=scatter_axis)

    def lookup(self, seam: str, m: int, n: int, k: int,
               dtype_bytes: int = 2) -> Optional[SeamPlan]:
        e = self.entries.get(entry_key(seam, m, n, k, self.n_dev, dtype_bytes))
        return SeamPlan.from_json(e["plan"]) if e else None

    def seam_plans(self) -> Dict[str, SeamPlan]:
        """Best-known plan per model seam name (insertion order: last wins).
        Cell-qualified entries (``"attn_ag@kv_up"``) stay resolvable under
        their own key AND alias the bare seam name to the dominant
        (largest-FLOPs) cell's plan, unless an exact bare entry exists.
        Used to build a PlanSet when the caller doesn't re-derive exact
        shapes; exact-shape consumers should use :meth:`lookup`."""
        from repro.tuning.plans import seam_of
        out: Dict[str, SeamPlan] = {}
        alias: Dict[str, tuple] = {}        # base seam -> (flops, plan)
        for e in self.entries.values():
            key = e["seam"]
            plan = SeamPlan.from_json(e["plan"])
            out[key] = plan
            base = seam_of(key)
            if base != key:
                fl = 2 * e["m"] * e["n"] * e["k"]
                if base not in alias or fl > alias[base][0]:
                    alias[base] = (fl, plan)
        for base, (_, plan) in alias.items():
            if base not in out:
                out[base] = plan
        return out

    # ----------------------------------------------------------------- io
    def save(self, path: Optional[str] = None) -> str:
        path = path or self.path
        assert path, "PlanRegistry.save needs a path"
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        doc = {"version": PROFILE_VERSION, "backend": self.backend,
               "mesh": {"n_dev": self.n_dev}, "entries": self.entries}
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
        os.replace(tmp, path)
        self.path = path
        return path

    @classmethod
    def open(cls, path: str, *, n_dev: int,
             backend: Optional[str] = None) -> "PlanRegistry":
        """Load a profile; empty registry when the file is missing or STALE
        (version / mesh / backend mismatch)."""
        if backend is None:
            import jax
            backend = jax.default_backend()
        reg = cls(n_dev=n_dev, backend=backend, path=path)
        try:
            with open(path) as f:
                doc = json.load(f)
        except (OSError, json.JSONDecodeError):
            return reg
        if doc.get("version") != PROFILE_VERSION:
            return reg
        if doc.get("mesh", {}).get("n_dev") != n_dev:
            return reg
        if doc.get("backend") != backend:
            return reg
        entries = doc.get("entries", {})
        if isinstance(entries, Mapping):
            reg.entries = dict(entries)
        return reg
