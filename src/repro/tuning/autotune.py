"""Measured + analytic per-seam autotuner (paper §4.4).

For one seam (collective kind + GEMM shape) the tuner enumerates candidate
``(mode, comm_chunks, reverse, bm/bk/bn)`` settings, scores each one, and
returns the winner as a ``SeamPlan``:

  * **measured** — a jitted sweep of the real overlap op on the current
    devices (shard_mapped over ``n_dev`` devices when available, the
    single-device fallback otherwise); median wall time via ``ect.time_fn``.
  * **analytic** — the ``core.ect`` roofline.  Used when measurement is
    meaningless: fewer devices than ``n_dev``, or Pallas interpret mode
    (``REPRO_PALLAS_INTERPRET=1``), where kernel timings reflect the
    interpreter, not hardware.

``measure="auto"`` picks between the two; ``True``/``False`` force them.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import ect
from repro.tuning.plans import PlanSet, SeamPlan

# candidate modes per collective kind (wire precision is a SEPARATE knob —
# ``Candidate.wire_dtype`` — swept orthogonally over the transports that
# can carry a quantized payload; see ``wire_supported``):
_KIND_MODES: Dict[str, Tuple[str, ...]] = {
    "ag": ("xla", "decomposed", "decomposed_bidir", "flux"),
    "rs": ("xla", "decomposed", "decomposed_bidir", "flux"),
    "ar": ("xla", "decomposed"),
    # MoE EP exchange: barrier all_to_alls vs the interleaved ppermute ring
    # (chunk count x direction swept; no flux kernel)
    "a2a": ("xla", "decomposed"),
}
# flux block-preference sweep (the CUTLASS-template-parameter analogue)
_FLUX_BLOCK_PREFS: Tuple[Tuple[int, int, int], ...] = (
    (256, 512, 256), (128, 512, 128), (512, 512, 512))

# the wire dtypes the tuner sweeps when low precision is allowed
WIRE_DTYPE_SWEEP: Tuple[Optional[str], ...] = (None, "int8", "fp8_e4m3",
                                               "int4")


def wire_supported(kind: str, mode: str, scatter_axis: str = "seq") -> bool:
    """Whether (kind, mode, layout) actually carries a quantized payload:
    flux has no quantized DMA path; xla's psum collectives (rs/ar) cannot
    carry per-block scales; ag/hidden has no collective at all."""
    if mode == "flux":
        return False
    if kind == "ag":
        return scatter_axis != "hidden"
    if kind == "a2a":
        return True
    # rs (incl. rs/hidden == ar) and ar: ring transports only
    return mode.startswith("decomposed")


@dataclasses.dataclass(frozen=True)
class Candidate:
    mode: str
    comm_chunks: int
    reverse: bool
    blocks: Optional[Tuple[int, int, int]] = None
    shared_gather: bool = True        # one ring pass for N-weight gathers
    fuse_epilogue: bool = True        # epilogue inside the overlapped loop
    scatter_axis: str = "seq"         # residual-stream layout (seq | hidden)
    wire_dtype: Optional[str] = None  # forward-wire precision (overlap
    #                                   VALID_WIRE_DTYPES; None = fp wire)


@dataclasses.dataclass
class TuneResult:
    seam: str                         # model seam name (or the kind itself)
    kind: str                         # ag | rs | ar
    m: int
    n: int
    k: int
    n_dev: int
    plan: SeamPlan
    table: List[Dict]                 # one row per candidate (see tune_seam)
    source: str                       # measured | analytic
    pruned: int = 0                   # flux tilings rejected by the static
    #                                   VMEM budget before pricing/timing


def _ring_chunk_options(n_dev: int) -> Tuple[int, ...]:
    # no 0 ("auto"): auto IS n_dev in every ring op, and duplicate
    # candidates would be compiled and timed twice on the measured path
    return (n_dev, 2 * n_dev, 4 * n_dev)


def candidate_space(kind: str, m: int, n: int, k: int, n_dev: int,
                    *, allow_flux: bool = True, allow_q8: bool = True,
                    modes: Optional[Sequence[str]] = None,
                    wire_dtypes: Optional[Sequence[Optional[str]]] = None,
                    n_weights: int = 1,
                    epilogue: bool = False,
                    scatter_axis: str = "seq") -> List[Candidate]:
    """All tunable settings for one seam kind.  ``modes`` restricts the mode
    set (used by the measured path to drop flux under interpret mode).

    ``wire_dtypes`` is the wire-precision sweep (None entries = fp wire);
    the default derives from the deprecated ``allow_q8`` flag — ``True``
    sweeps ``(None, "int8")`` (the old q8-mode pair), ``False`` keeps the
    fp wire only.  Pass ``WIRE_DTYPE_SWEEP`` for the full set.  Quantized
    wires are only emitted for transports that carry them
    (``wire_supported``).

    ``n_weights > 1`` additionally sweeps ``shared_gather`` (one ring pass
    vs one per weight) and ``epilogue=True`` sweeps ``fuse_epilogue``
    (elementwise tail inside vs after the overlapped loop) — the FusedOp
    fusion knobs.  Only the transports that CONSUME a knob sweep it: xla's
    monolithic gather is shared and its epilogue XLA-fused regardless, and
    rs/ar epilogues run once on the reduced output either way, so sweeping
    there would score byte-identical programs under different labels.

    ``scatter_axis`` fixes the residual-stream layout the seam runs under
    (it is swept JOINTLY at the model level by ``autotune_model``, never
    per seam — a per-seam layout split would be incoherent).  Under
    "hidden" an AG seam has NO collective (one candidate) and an RS seam
    behaves like the "ar" kind (contraction-chunked AllReduce)."""
    from repro.core.overlap import normalize_mode
    from repro.kernels.ops import plan_blocks
    if wire_dtypes is None:
        wire_dtypes = (None, "int8") if allow_q8 else (None,)
    hidden = scatter_axis == "hidden"
    if kind == "ag" and hidden:
        # input already replicated: no transport to tune
        return [Candidate("xla", 0, False, scatter_axis="hidden")]
    mode_kind = "ar" if (kind == "rs" and hidden) else kind
    sweep_sg = kind == "ag" and n_weights > 1
    sweep_fe = kind == "ag" and epilogue
    fusion_opts = [(sg, fe)
                   for sg in ((True, False) if sweep_sg else (True,))
                   for fe in ((True, False) if sweep_fe else (True,))]
    out: List[Candidate] = []
    for mode in (modes or _KIND_MODES[mode_kind]):
        mode, _ = normalize_mode(mode)     # accept deprecated spellings
        if mode == "flux" and not allow_flux:
            continue
        if mode == "xla":
            out.append(Candidate(mode, 0, False, scatter_axis=scatter_axis))
            continue
        if mode == "flux":
            # per-device GEMM shape (paper §4.4: tiling is not bound to N_TP)
            if kind == "ag":
                gm, gk, gn = max(m // n_dev, 1), k, max(n // n_dev, 1)
            else:
                gm, gk, gn = max(m // n_dev, 1), max(k // n_dev, 1), n
            for pref in _FLUX_BLOCK_PREFS:
                blocks = plan_blocks(gm, gk, gn, *pref)
                for reverse in (False, True):
                    for sg, fe in fusion_opts:
                        out.append(Candidate(mode, 0, reverse, blocks,
                                             shared_gather=sg,
                                             fuse_epilogue=fe,
                                             scatter_axis=scatter_axis))
            continue
        # ring modes: chunk count x direction (AR chunks the contraction —
        # no ring, so no direction; bidir already rides both directions)
        for chunks in _ring_chunk_options(n_dev):
            for reverse in (False, True):
                if reverse and (mode_kind == "ar"
                                or mode == "decomposed_bidir"):
                    continue
                for sg, fe in fusion_opts:
                    out.append(Candidate(mode, chunks, reverse,
                                         shared_gather=sg, fuse_epilogue=fe,
                                         scatter_axis=scatter_axis))
    # expand over the wire-precision sweep (quantized wires only where the
    # transport actually carries them)
    expanded: List[Candidate] = []
    for c in out:
        for wd in wire_dtypes:
            if wd is not None and not wire_supported(kind, c.mode,
                                                     c.scatter_axis):
                continue
            expanded.append(dataclasses.replace(c, wire_dtype=wd))
    # dedupe (plan_blocks may collapse block prefs on small shapes)
    seen, uniq = set(), []
    for c in expanded:
        key = (c.mode, c.comm_chunks, c.reverse, c.blocks, c.shared_gather,
               c.fuse_epilogue, c.scatter_axis, c.wire_dtype)
        if key not in seen:
            seen.add(key)
            uniq.append(c)
    return uniq


def prune_infeasible(kind: str, cands: List[Candidate],
                     *, dtype_bytes: int = 2, epilogue: bool = False
                     ) -> Tuple[List[Candidate], List[Candidate]]:
    """(kept, pruned): drop flux candidates whose static VMEM footprint the
    ``kernelcheck`` tile-budget model rejects.  Runs BEFORE any pricing or
    timing — ``ect`` never models an infeasible tiling and the measured
    path never compiles one (ISSUE 9 satellite: the sweep previously timed
    arbitrary ``bm/bk/bn`` with no validity filter)."""
    if kind not in ("ag", "rs"):
        return list(cands), []
    from repro.analysis.kernelcheck import tile_budget_ok   # lazy: no cycle
    keep: List[Candidate] = []
    pruned: List[Candidate] = []
    for c in cands:
        if (c.mode == "flux" and c.blocks is not None
                and not tile_budget_ok(kind, tuple(c.blocks),
                                       dtype_bytes=dtype_bytes,
                                       has_bias=epilogue)):
            pruned.append(c)
        else:
            keep.append(c)
    return keep, pruned


def analytic_estimate(kind: str, m: int, n: int, k: int, n_dev: int,
                      cand: Candidate, dtype_bytes: int = 2,
                      n_weights: int = 1, epilogue: bool = False,
                      full: bool = False):
    """Roofline OverallTime for one candidate (``full=True`` returns the
    whole ``ect.model_overlap`` dict — bytes-on-wire etc.)."""
    est = ect.model_overlap(kind, m, n, k, n_dev, cand.mode, dtype_bytes,
                            comm_chunks=cand.comm_chunks,
                            n_weights=n_weights,
                            shared_gather=cand.shared_gather,
                            epilogue=epilogue,
                            fuse_epilogue=cand.fuse_epilogue,
                            scatter_axis=cand.scatter_axis,
                            wire_dtype=cand.wire_dtype)
    return est if full else est["overall"]


# ---------------------------------------------------------------------------
# measured path
# ---------------------------------------------------------------------------
def _round_to(x: int, mult: int) -> int:
    return max(mult, x - x % mult)


def _bench_epilogue(kind: str, n_weights: int, epilogue: bool):
    """The representative Epilogue benched for a seam: the gated-FFN pair
    for two-weight AG seams, a plain activation otherwise."""
    from repro.core.overlap import Epilogue
    if kind == "a2a":
        # the EP exchange op REQUIRES the pure gated pair (its backward
        # differentiates the expert SwiGLU as one closure)
        return Epilogue(activation="silu", gate="pair")
    if not epilogue:
        return Epilogue()
    if kind == "ag" and n_weights == 2:
        return Epilogue(activation="silu", gate="pair")
    return Epilogue(activation="silu")


def _bench_callable(kind: str, m: int, n: int, k: int, n_dev: int,
                    cand: Candidate, dtype, n_weights: int = 1,
                    epilogue: bool = False):
    """(jitted_fn, args) timing one FusedOp under ``cand``'s settings.
    Shard_maps over ``n_dev`` devices when the host has them; otherwise the
    single-device fallback path (still times the real GEMM)."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh, PartitionSpec as P
    from repro import compat
    from repro.core.overlap import FusedOp

    multi = n_dev > 1 and len(jax.devices()) >= n_dev
    axis = "tune" if multi else None
    m = _round_to(m, n_dev)
    n = _round_to(n, n_dev)
    k = _round_to(k, n_dev)
    key = jax.random.PRNGKey(0)

    if kind == "a2a":
        # EP exchange: local [ep, e_loc, cap, k=d_model] dispatch buffer and
        # the global (w1, w3, w2) expert stacks (m routed rows per device,
        # n = expert_ffn).  Global buffer dim 0 carries both the shard and
        # the destination-rank dims (n_dev * n_dev).
        e_loc = 2
        cap = max(m // (n_dev * e_loc), 1)
        x = jax.random.normal(key, (n_dev * n_dev, e_loc, cap, k), dtype)
        ws = (jax.random.normal(jax.random.PRNGKey(1),
                                (n_dev * e_loc, k, n), dtype) / k ** 0.5,
              jax.random.normal(jax.random.PRNGKey(2),
                                (n_dev * e_loc, k, n), dtype) / k ** 0.5,
              jax.random.normal(jax.random.PRNGKey(3),
                                (n_dev * e_loc, n, k), dtype) / n ** 0.5)
        fused = FusedOp(kind="a2a", axis=(axis,) if axis else (),
                        mode=cand.mode, comm_chunks=cand.comm_chunks,
                        reverse=cand.reverse,
                        epilogue=_bench_epilogue(kind, 3, True), n_weights=3,
                        wire_dtype=cand.wire_dtype)
        if not multi:
            return jax.jit(lambda a, *bs: fused(a, *bs)), (x, *ws)
        mesh = Mesh(np.array(jax.devices()[:n_dev]), ("tune",))
        fn = compat.shard_map(lambda a, *bs: fused(a, *bs), mesh=mesh,
                              in_specs=(P(axis),) * 4, out_specs=P(axis),
                              check_vma=False)
        return jax.jit(fn), (x, *ws)

    x = jax.random.normal(key, (1, m, k), dtype)
    nw = n_weights if kind == "ag" else 1
    ws = tuple(jax.random.normal(jax.random.PRNGKey(1 + i), (k, n), dtype)
               / k ** 0.5 for i in range(nw))
    hidden = cand.scatter_axis == "hidden"
    fused = FusedOp(kind=kind, axis=axis, mode=cand.mode,
                    comm_chunks=cand.comm_chunks, reverse=cand.reverse,
                    blocks=cand.blocks,
                    epilogue=_bench_epilogue(kind, nw, epilogue),
                    n_weights=nw, fuse_epilogue=cand.fuse_epilogue,
                    shared_gather=cand.shared_gather,
                    scatter_axis=cand.scatter_axis,
                    wire_dtype=cand.wire_dtype)
    if kind == "ag":
        # hidden layout: the activation arrives replicated (no gather)
        x_spec = P(None, None, None) if hidden else P(None, axis, None)
        in_specs = (x_spec,) + (P(None, axis),) * nw
        out_spec = (P(None, None, axis) if fused.combines
                    else (P(None, None, axis),) * nw)
    else:           # rs / ar share operand sharding; ar (and rs/hidden)
        #             replicate the output
        in_specs = (P(None, None, axis), P(axis, None))
        out_spec = (P(None, axis, None) if kind == "rs" and not hidden
                    else P(None, None, None))

    if not multi:
        return jax.jit(lambda a, *bs: fused(a, *bs)), (x, *ws)

    mesh = Mesh(np.array(jax.devices()[:n_dev]), ("tune",))
    fn = compat.shard_map(lambda a, *bs: fused(a, *bs), mesh=mesh,
                          in_specs=in_specs, out_specs=out_spec,
                          check_vma=False)
    return jax.jit(fn), (x, *ws)


def _measurable_modes(kind: str, allow_flux: bool) -> Tuple[str, ...]:
    from repro import compat
    modes = _KIND_MODES[kind]
    # interpret-mode Pallas timings measure the interpreter, not hardware —
    # keep flux out of the measured sweep there (it still competes via the
    # analytic path on real devices).
    if compat.interpret_default():
        modes = tuple(md for md in modes if md != "flux")
    if not allow_flux:
        modes = tuple(md for md in modes if md != "flux")
    return modes


def tune_seam(kind: str, m: int, n: int, k: int, n_dev: int,
              *, dtype_bytes: int = 2, allow_flux: bool = True,
              allow_q8: bool = True, measure="auto",
              modes: Optional[Sequence[str]] = None,
              wire_dtypes: Optional[Sequence[Optional[str]]] = None,
              max_logit_rmse: Optional[float] = None,
              rmse_fn=None,
              seam: Optional[str] = None, iters: int = 3,
              warmup: int = 1, n_weights: int = 1,
              epilogue: bool = False,
              scatter_axis: str = "seq") -> TuneResult:
    """Tune one seam.  Returns the winning plan plus the full candidate
    table (``table`` rows: mode/comm_chunks/reverse/blocks/shared_gather/
    fuse_epilogue/scatter_axis/wire_dtype/comm_bytes/predicted_s/
    logit_rmse/within_budget and, on the measured path, measured_s).

    Wire precision is tuned under an ERROR BUDGET, not time alone: every
    quantized candidate is scored by ``rmse_fn(kind, m, n, k, n_dev,
    wire_dtype)`` (default: ``error_budget.seam_wire_rmse``, the seeded
    proxy deviation vs the fp wire) and candidates whose deviation exceeds
    ``max_logit_rmse`` are kept in the table (``within_budget=False``) but
    can never win.  ``max_logit_rmse=None`` disables the filter (the fp
    wire scores 0.0 and is always eligible).

    ``n_weights``/``epilogue`` describe the FusedOp the seam will run
    (e.g. the gated FFN's two-weight silu-gate) so the fusion knobs are
    swept too; ``scatter_axis`` fixes the residual layout the seam is
    tuned UNDER (the layout itself is a model-level decision — see
    ``autotune_model``)."""
    assert kind in _KIND_MODES, kind
    if measure == "auto":
        import jax
        from repro import compat
        measure = (n_dev > 1 and len(jax.devices()) >= n_dev
                   and not compat.interpret_default())
    if rmse_fn is None:
        from repro.tuning.error_budget import seam_wire_rmse
        rmse_fn = seam_wire_rmse

    def row(c, measured=0.0):
        est = analytic_estimate(kind, m, n, k, n_dev, c, dtype_bytes,
                                n_weights, epilogue, full=True)
        rmse = (rmse_fn(kind, m, n, k, n_dev, c.wire_dtype)
                if c.wire_dtype else 0.0)
        return {"mode": c.mode, "comm_chunks": c.comm_chunks,
                "reverse": c.reverse, "blocks": c.blocks,
                "shared_gather": c.shared_gather,
                "fuse_epilogue": c.fuse_epilogue,
                "scatter_axis": c.scatter_axis,
                "wire_dtype": c.wire_dtype,
                "comm_bytes": est["comm_bytes"],
                "predicted_s": est["overall"],
                "logit_rmse": rmse,
                "within_budget": (max_logit_rmse is None
                                  or rmse <= max_logit_rmse),
                "measured_s": measured}

    def pick(table, score):
        eligible = [r for r in table if r["within_budget"]]
        return min(eligible or table, key=score)

    mode_kind = "ar" if (kind == "rs" and scatter_axis == "hidden") else kind
    if measure:
        import jax.numpy as jnp
        dtype = jnp.bfloat16 if dtype_bytes == 2 else jnp.float32
        cands = candidate_space(kind, m, n, k, n_dev, allow_flux=allow_flux,
                                allow_q8=allow_q8,
                                modes=modes or _measurable_modes(mode_kind,
                                                                 allow_flux),
                                wire_dtypes=wire_dtypes,
                                n_weights=n_weights, epilogue=epilogue,
                                scatter_axis=scatter_axis)
        cands, dropped = prune_infeasible(kind, cands,
                                          dtype_bytes=dtype_bytes,
                                          epilogue=epilogue)
        table = []
        for c in cands:
            fn, args = _bench_callable(kind, m, n, k, n_dev, c, dtype,
                                       n_weights=n_weights,
                                       epilogue=epilogue)
            t = ect.time_fn(fn, *args, iters=iters, warmup=warmup)
            table.append(row(c, measured=t))
        best = pick(table, lambda r: r["measured_s"])
        source = "measured"
    else:
        cands = candidate_space(kind, m, n, k, n_dev, allow_flux=allow_flux,
                                allow_q8=allow_q8, modes=modes,
                                wire_dtypes=wire_dtypes,
                                n_weights=n_weights, epilogue=epilogue,
                                scatter_axis=scatter_axis)
        cands, dropped = prune_infeasible(kind, cands,
                                          dtype_bytes=dtype_bytes,
                                          epilogue=epilogue)
        table = [row(c) for c in cands]
        best = pick(table, lambda r: r["predicted_s"])
        source = "analytic"

    blocks = best["blocks"]
    if blocks is None:
        from repro.kernels.ops import plan_blocks
        if kind == "ag":
            blocks = plan_blocks(max(m // n_dev, 1), k, max(n // n_dev, 1))
        else:
            blocks = plan_blocks(max(m // n_dev, 1), max(k // n_dev, 1), n)
    plan = SeamPlan(mode=best["mode"], comm_chunks=best["comm_chunks"],
                    reverse=best["reverse"], blocks=tuple(blocks),
                    shared_gather=best["shared_gather"],
                    fuse_epilogue=best["fuse_epilogue"],
                    scatter_axis=best["scatter_axis"],
                    wire_dtype=best["wire_dtype"],
                    source=source, predicted_s=best["predicted_s"],
                    measured_s=best["measured_s"],
                    logit_rmse=best["logit_rmse"]).validate()
    return TuneResult(seam=seam or kind, kind=kind, m=m, n=n, k=k,
                      n_dev=n_dev, plan=plan, table=table, source=source,
                      pruned=len(dropped))


# ---------------------------------------------------------------------------
# whole-model tuning
# ---------------------------------------------------------------------------
def serving_decode_batch() -> int:
    """The decode-AR seam's m dimension under the serving runtime: the
    Server jits ``decode_step`` at ``ServeConfig.max_batch`` rows, so plans
    tuned for any other batch would miss the server's actual signature."""
    from repro.runtime.server import ServeConfig
    return ServeConfig().max_batch


def model_seam_shapes(cfg, par, tokens_per_dp: int = 2048,
                      decode_batch: Optional[int] = None
                      ) -> Dict[str, Tuple[str, int, int, int]]:
    """(kind, m, n, k) per model seam SHAPE CELL, from the arch's padded
    GEMM shapes.

    Keys are seam names, cell-qualified (``"<seam>@<cell>"``, mirroring the
    dryrun cell naming) when one model seam runs several distinct GEMM
    shapes: MLA's attention AG seam drives TWO up-projections with very
    different widths (``attn_ag@q_up``: q_lora_rank -> heads*(nope+rope)
    vs ``attn_ag@kv_up``: kv_lora_rank -> heads*(nope+v)), while GQA's is
    one packed QKV GEMM (``attn_ag@qkv``).  ``tuning.plans.seam_of`` maps a
    cell key back to the model seam; ``autotune_model`` tunes every cell
    and resolves the seam-level plan from its DOMINANT (largest-FLOPs)
    cell.

    ``decode_batch`` defaults to the serving runtime's ``ServeConfig.
    max_batch`` (the server's decode jit batch); pass the actual
    ``--max-batch`` when tuning for a differently-sized deployment."""
    from repro.parallel.sharding import pad_ff, pad_vocab
    if decode_batch is None:
        decode_batch = serving_decode_batch()
    tp = par.tp
    d = cfg.d_model
    ffp = pad_ff(cfg.d_ff, tp)
    shapes: Dict[str, Tuple[str, int, int, int]] = {
        "mlp_ag": ("ag", tokens_per_dp,
                   ffp * (2 if getattr(par, "fuse_w13", False) else 1), d),
        "mlp_rs": ("rs", tokens_per_dp, d, ffp),
        "head_ag": ("ag", tokens_per_dp, pad_vocab(cfg.vocab_size, tp), d),
        "decode_ar": ("ar", decode_batch, d, ffp),
    }
    if cfg.mla is not None:
        from repro.parallel.sharding import pad_heads
        mla = cfg.mla
        h_pad = pad_heads(cfg.num_heads, tp)
        shapes["attn_ag@q_up"] = (
            "ag", tokens_per_dp,
            h_pad * (mla.qk_nope_head_dim + mla.qk_rope_head_dim),
            mla.q_lora_rank)
        shapes["attn_ag@kv_up"] = (
            "ag", tokens_per_dp,
            h_pad * (mla.qk_nope_head_dim + mla.v_head_dim),
            mla.kv_lora_rank)
        shapes["attn_rs"] = ("rs", tokens_per_dp, d, h_pad * mla.v_head_dim)
    elif cfg.num_heads:
        from repro.models.attention import AttnDims
        dims = AttnDims.of(cfg, tp)
        shapes["attn_ag@qkv"] = (
            "ag", tokens_per_dp,
            (dims.h_pad + 2 * dims.hkv_pad) * dims.dh, d)
        shapes["attn_rs"] = ("rs", tokens_per_dp, d, dims.h_pad * dims.dh)
    if cfg.moe is not None:
        # EP exchange seam: m = routed rows (tokens x top_k), k = d_model
        # (the a2a payload width), n = the per-expert FFN width
        shapes["moe_a2a"] = ("a2a", tokens_per_dp * cfg.moe.top_k,
                             cfg.moe.expert_ffn, d)
    return shapes


def sweep_model_layout(cfg, par, *, tokens_per_dp: int = 2048,
                       dtype_bytes: int = 2) -> Dict:
    """Joint residual-layout sweep (the ``scatter_axis`` knob): per layout,
    sum the analytic per-seam OverallTime over the residual-stream seam
    cells and the per-layer resident activation bytes.

    The layout CANNOT be tuned seam-by-seam — a lone hidden-AG seam always
    "wins" (it has no collective) while its paired RS seam silently absorbs
    the full AllReduce, so only the layer-pair totals are comparable.
    Accounting covers the PAIRED per-layer seams (mlp_ag/mlp_rs,
    attn_ag/attn_rs); head_ag is stamped with the winner but excluded from
    the totals (its volume dual is the embed seam's scatter, outside this
    table).  The comm volume is layout-invariant by construction (AG+RS
    over seq == one ring AllReduce); the decider is overlap quality vs
    activation residency, so ties (and near-ties) go to "seq" — 1/tp the
    resident activation between seams."""
    from repro.tuning.plans import seam_of
    layer_seams = ("mlp_ag", "mlp_rs", "attn_ag", "attn_rs")
    shapes = model_seam_shapes(cfg, par, tokens_per_dp)
    out: Dict[str, Dict] = {}
    for axis in ("seq", "hidden"):
        total_s, act, vol = 0.0, 0.0, 0.0
        for key, (kind, m, n, k) in shapes.items():
            if seam_of(key) not in layer_seams:
                continue
            # each layout is scored on its best honest lossless transport
            # per seam (monolithic vs overlapped ring).  Note hidden's RS
            # always resolves to the monolithic ring AllReduce: the
            # chunked-AR transport moves chunks x the bytes (see
            # ect.model_overlap), and its AG side has no collective at all.
            ests = [ect.model_overlap(kind, m, n, k, par.tp, mode,
                                      dtype_bytes, scatter_axis=axis)
                    for mode in ("xla", "decomposed")]
            est = min(ests, key=lambda e: e["overall"])
            total_s += est["overall"]
            act += est["act_bytes"]
            vol += est["comm_bytes"]
        out[axis] = {"overall_s": total_s, "act_bytes": act,
                     "comm_bytes": vol}
    # near-ties (within 2%) resolve to seq: same comm volume, 1/tp residency
    seq_s, hid_s = out["seq"]["overall_s"], out["hidden"]["overall_s"]
    out["winner"] = "seq" if seq_s <= hid_s * 1.02 else "hidden"
    out["residency_ratio"] = (out["seq"]["act_bytes"]
                              / max(out["hidden"]["act_bytes"], 1.0))
    return out


def autotune_model(cfg, par, *, tokens_per_dp: int = 2048,
                   decode_batch: Optional[int] = None, measure="auto",
                   registry=None, save_path: Optional[str] = None,
                   allow_flux: bool = True, allow_q8: bool = False,
                   wire_dtypes: Optional[Sequence[Optional[str]]] = None,
                   max_logit_rmse: Optional[float] = None,
                   sweep_scatter_axis: bool = True) -> PlanSet:
    """Tune every seam of a model and return the resulting PlanSet.

    Attention seams with several GEMM shape cells (MLA q/kv up-projections)
    are tuned PER CELL (``"attn_ag@q_up"`` ...); the seam-level plan model
    code resolves is the dominant (largest-FLOPs) cell's winner, and every
    cell plan stays resolvable under its qualified key.

    ``sweep_scatter_axis`` additionally runs the joint residual-layout
    sweep (``sweep_model_layout``) and stamps the winning ``scatter_axis``
    onto the whole PlanSet — layout is one coherent model-level decision,
    never a per-seam one.

    ``registry`` (a ``cache.PlanRegistry``) short-circuits seams it already
    holds and records fresh results; ``save_path`` persists it afterwards.
    Quantized wires are lossy and therefore an explicit opt-in for
    whole-model plans: pass ``wire_dtypes`` (e.g. ``autotune.
    WIRE_DTYPE_SWEEP``) to sweep them, ideally paired with
    ``max_logit_rmse`` so the per-seam error budget gates the winners.
    ``allow_q8`` is the deprecated spelling of ``wire_dtypes=(None,
    "int8")`` and still works.
    """
    from repro.tuning.plans import seam_of
    if par.tp <= 1:
        return PlanSet.uniform(par.overlap_mode, par.comm_chunks)
    # FusedOp shape of each seam: the gated FFN runs a two-weight silu-gate
    # op off one gather (w13-packed: one weight, split-gate — still an
    # epilogue); QKV projections fuse the bias when the arch has one.
    fused_shape: Dict[str, Dict] = {
        "mlp_ag": {"n_weights": 1 if getattr(par, "fuse_w13", False) else 2,
                   "epilogue": True},
        "attn_ag": {"epilogue": bool(getattr(cfg, "qkv_bias", False))},
        "moe_a2a": {"n_weights": 3, "epilogue": True},
    }
    # the layout decision comes FIRST: every seam is tuned UNDER the
    # winning scatter_axis, so the recorded profile persists the layout
    # (a post-save stamp would leave "auto" loads on the wrong layout)
    scatter_axis = "seq"
    if sweep_scatter_axis:
        scatter_axis = sweep_model_layout(
            cfg, par, tokens_per_dp=tokens_per_dp)["winner"]
    seams: Dict[str, SeamPlan] = {}
    flops: Dict[str, Tuple[int, str]] = {}    # seam -> (dominant flops, cell)
    for cell_key, (kind, m, n, k) in model_seam_shapes(
            cfg, par, tokens_per_dp, decode_batch).items():
        seam_name = seam_of(cell_key)
        cached = registry.lookup(cell_key, m, n, k) if registry else None
        if cached is not None:
            seams[cell_key] = cached
        else:
            res = tune_seam(kind, m, n, k, par.tp, allow_flux=allow_flux,
                            allow_q8=allow_q8, measure=measure,
                            wire_dtypes=wire_dtypes,
                            max_logit_rmse=max_logit_rmse,
                            seam=cell_key, scatter_axis=scatter_axis,
                            **fused_shape.get(seam_name, {}))
            seams[cell_key] = res.plan
            if registry is not None:
                registry.record(cell_key, kind, m, n, k, res.plan)
        # seam-level resolution: the dominant cell's plan
        cell_flops = 2 * m * n * k
        if cell_key != seam_name and (seam_name not in flops
                                      or cell_flops > flops[seam_name][0]):
            flops[seam_name] = (cell_flops, cell_key)
    for seam_name, (_, cell_key) in flops.items():
        seams[seam_name] = seams[cell_key]
    if registry is not None:
        if sweep_scatter_axis:
            # cached entries may predate this run's layout decision: stamp
            # the WHOLE registry so the persisted profile stays coherent
            # (a mixed-layout profile raises at load)
            registry.stamp_scatter_axis(scatter_axis)
        if save_path:
            registry.save(save_path)
    plans = PlanSet(default=SeamPlan(mode=par.overlap_mode,
                                     comm_chunks=par.comm_chunks).validate(),
                    seams=seams)
    if sweep_scatter_axis:
        # coherence stamp (covers cached entries tuned under another layout)
        plans = plans.with_scatter_axis(scatter_axis)
    return plans
