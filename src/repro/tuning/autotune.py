"""Measured + analytic per-seam autotuner (paper §4.4).

For one seam (collective kind + GEMM shape) the tuner enumerates candidate
``(mode, comm_chunks, reverse, bm/bk/bn)`` settings, scores each one, and
returns the winner as a ``SeamPlan``:

  * **measured** — a jitted sweep of the real overlap op on the current
    devices (shard_mapped over ``n_dev`` devices when available, the
    single-device fallback otherwise); median wall time via ``ect.time_fn``.
  * **analytic** — the ``core.ect`` roofline.  Used when measurement is
    meaningless: fewer devices than ``n_dev``, or Pallas interpret mode
    (``REPRO_PALLAS_INTERPRET=1``), where kernel timings reflect the
    interpreter, not hardware.

``measure="auto"`` picks between the two; ``True``/``False`` force them.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import ect
from repro.tuning.plans import PlanSet, SeamPlan

# candidate modes per collective kind.  q8 only changes AllGather payloads
# (RS partials keep full precision; AR treats q8 as its base mode), and the
# bidirectional ring needs an actual ring, so:
_KIND_MODES: Dict[str, Tuple[str, ...]] = {
    "ag": ("xla", "decomposed", "decomposed_bidir", "xla_q8",
           "decomposed_q8", "flux"),
    "rs": ("xla", "decomposed", "decomposed_bidir", "flux"),
    "ar": ("xla", "decomposed"),
}
# flux block-preference sweep (the CUTLASS-template-parameter analogue)
_FLUX_BLOCK_PREFS: Tuple[Tuple[int, int, int], ...] = (
    (256, 512, 256), (128, 512, 128), (512, 512, 512))


@dataclasses.dataclass(frozen=True)
class Candidate:
    mode: str
    comm_chunks: int
    reverse: bool
    blocks: Optional[Tuple[int, int, int]] = None
    shared_gather: bool = True        # one ring pass for N-weight gathers
    fuse_epilogue: bool = True        # epilogue inside the overlapped loop


@dataclasses.dataclass
class TuneResult:
    seam: str                         # model seam name (or the kind itself)
    kind: str                         # ag | rs | ar
    m: int
    n: int
    k: int
    n_dev: int
    plan: SeamPlan
    table: List[Dict]                 # one row per candidate (see tune_seam)
    source: str                       # measured | analytic


def _ring_chunk_options(n_dev: int) -> Tuple[int, ...]:
    # no 0 ("auto"): auto IS n_dev in every ring op, and duplicate
    # candidates would be compiled and timed twice on the measured path
    return (n_dev, 2 * n_dev, 4 * n_dev)


def candidate_space(kind: str, m: int, n: int, k: int, n_dev: int,
                    *, allow_flux: bool = True, allow_q8: bool = True,
                    modes: Optional[Sequence[str]] = None,
                    n_weights: int = 1,
                    epilogue: bool = False) -> List[Candidate]:
    """All tunable settings for one seam kind.  ``modes`` restricts the mode
    set (used by the measured path to drop flux under interpret mode);
    ``allow_q8=False`` drops the lossy int8-gather modes.  ``n_weights > 1``
    additionally sweeps ``shared_gather`` (one ring pass vs one per weight)
    and ``epilogue=True`` sweeps ``fuse_epilogue`` (elementwise tail inside
    vs after the overlapped loop) — the FusedOp fusion knobs.  Only the
    transports that CONSUME a knob sweep it: xla's monolithic gather is
    shared and its epilogue XLA-fused regardless, and rs/ar epilogues run
    once on the reduced output either way, so sweeping there would score
    byte-identical programs under different labels."""
    from repro.kernels.ops import plan_blocks
    sweep_sg = kind == "ag" and n_weights > 1
    sweep_fe = kind == "ag" and epilogue
    fusion_opts = [(sg, fe)
                   for sg in ((True, False) if sweep_sg else (True,))
                   for fe in ((True, False) if sweep_fe else (True,))]
    out: List[Candidate] = []
    for mode in (modes or _KIND_MODES[kind]):
        if mode == "flux" and not allow_flux:
            continue
        if mode.endswith("_q8") and not allow_q8:
            continue
        if mode in ("xla", "xla_q8"):
            out.append(Candidate(mode, 0, False))
            continue
        if mode == "flux":
            # per-device GEMM shape (paper §4.4: tiling is not bound to N_TP)
            if kind == "ag":
                gm, gk, gn = max(m // n_dev, 1), k, max(n // n_dev, 1)
            else:
                gm, gk, gn = max(m // n_dev, 1), max(k // n_dev, 1), n
            for pref in _FLUX_BLOCK_PREFS:
                blocks = plan_blocks(gm, gk, gn, *pref)
                for reverse in (False, True):
                    for sg, fe in fusion_opts:
                        out.append(Candidate(mode, 0, reverse, blocks,
                                             shared_gather=sg,
                                             fuse_epilogue=fe))
            continue
        # ring modes: chunk count x direction (AR chunks the contraction —
        # no ring, so no direction; bidir already rides both directions)
        for chunks in _ring_chunk_options(n_dev):
            for reverse in (False, True):
                if reverse and (kind == "ar" or mode == "decomposed_bidir"):
                    continue
                for sg, fe in fusion_opts:
                    out.append(Candidate(mode, chunks, reverse,
                                         shared_gather=sg, fuse_epilogue=fe))
    # dedupe (plan_blocks may collapse block prefs on small shapes)
    seen, uniq = set(), []
    for c in out:
        key = (c.mode, c.comm_chunks, c.reverse, c.blocks, c.shared_gather,
               c.fuse_epilogue)
        if key not in seen:
            seen.add(key)
            uniq.append(c)
    return uniq


def analytic_estimate(kind: str, m: int, n: int, k: int, n_dev: int,
                      cand: Candidate, dtype_bytes: int = 2,
                      n_weights: int = 1, epilogue: bool = False) -> float:
    est = ect.model_overlap(kind, m, n, k, n_dev, cand.mode, dtype_bytes,
                            comm_chunks=cand.comm_chunks,
                            n_weights=n_weights,
                            shared_gather=cand.shared_gather,
                            epilogue=epilogue,
                            fuse_epilogue=cand.fuse_epilogue)
    return est["overall"]


# ---------------------------------------------------------------------------
# measured path
# ---------------------------------------------------------------------------
def _round_to(x: int, mult: int) -> int:
    return max(mult, x - x % mult)


def _bench_epilogue(kind: str, n_weights: int, epilogue: bool):
    """The representative Epilogue benched for a seam: the gated-FFN pair
    for two-weight AG seams, a plain activation otherwise."""
    from repro.core.overlap import Epilogue
    if not epilogue:
        return Epilogue()
    if kind == "ag" and n_weights == 2:
        return Epilogue(activation="silu", gate="pair")
    return Epilogue(activation="silu")


def _bench_callable(kind: str, m: int, n: int, k: int, n_dev: int,
                    cand: Candidate, dtype, n_weights: int = 1,
                    epilogue: bool = False):
    """(jitted_fn, args) timing one FusedOp under ``cand``'s settings.
    Shard_maps over ``n_dev`` devices when the host has them; otherwise the
    single-device fallback path (still times the real GEMM)."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh, PartitionSpec as P
    from repro import compat
    from repro.core.overlap import FusedOp

    multi = n_dev > 1 and len(jax.devices()) >= n_dev
    axis = "tune" if multi else None
    m = _round_to(m, n_dev)
    n = _round_to(n, n_dev)
    k = _round_to(k, n_dev)
    key = jax.random.PRNGKey(0)

    x = jax.random.normal(key, (1, m, k), dtype)
    nw = n_weights if kind == "ag" else 1
    ws = tuple(jax.random.normal(jax.random.PRNGKey(1 + i), (k, n), dtype)
               / k ** 0.5 for i in range(nw))
    fused = FusedOp(kind=kind, axis=axis, mode=cand.mode,
                    comm_chunks=cand.comm_chunks, reverse=cand.reverse,
                    blocks=cand.blocks,
                    epilogue=_bench_epilogue(kind, nw, epilogue),
                    n_weights=nw, fuse_epilogue=cand.fuse_epilogue,
                    shared_gather=cand.shared_gather)
    if kind == "ag":
        in_specs = (P(None, axis, None),) + (P(None, axis),) * nw
        out_spec = (P(None, None, axis) if fused.combines
                    else (P(None, None, axis),) * nw)
    else:           # rs / ar share operand sharding; ar replicates the out
        in_specs = (P(None, None, axis), P(axis, None))
        out_spec = P(None, axis, None) if kind == "rs" else P(None, None, None)

    if not multi:
        return jax.jit(lambda a, *bs: fused(a, *bs)), (x, *ws)

    mesh = Mesh(np.array(jax.devices()[:n_dev]), ("tune",))
    fn = compat.shard_map(lambda a, *bs: fused(a, *bs), mesh=mesh,
                          in_specs=in_specs, out_specs=out_spec,
                          check_vma=False)
    return jax.jit(fn), (x, *ws)


def _measurable_modes(kind: str, allow_flux: bool) -> Tuple[str, ...]:
    from repro import compat
    modes = _KIND_MODES[kind]
    # interpret-mode Pallas timings measure the interpreter, not hardware —
    # keep flux out of the measured sweep there (it still competes via the
    # analytic path on real devices).
    if compat.interpret_default():
        modes = tuple(md for md in modes if md != "flux")
    if not allow_flux:
        modes = tuple(md for md in modes if md != "flux")
    return modes


def tune_seam(kind: str, m: int, n: int, k: int, n_dev: int,
              *, dtype_bytes: int = 2, allow_flux: bool = True,
              allow_q8: bool = True, measure="auto",
              modes: Optional[Sequence[str]] = None,
              seam: Optional[str] = None, iters: int = 3,
              warmup: int = 1, n_weights: int = 1,
              epilogue: bool = False) -> TuneResult:
    """Tune one seam.  Returns the winning plan plus the full candidate
    table (``table`` rows: mode/comm_chunks/reverse/blocks/shared_gather/
    fuse_epilogue/predicted_s and, on the measured path, measured_s).
    ``n_weights``/``epilogue`` describe the FusedOp the seam will run
    (e.g. the gated FFN's two-weight silu-gate) so the fusion knobs are
    swept too."""
    assert kind in _KIND_MODES, kind
    if measure == "auto":
        import jax
        from repro import compat
        measure = (n_dev > 1 and len(jax.devices()) >= n_dev
                   and not compat.interpret_default())

    def row(c, measured=0.0):
        return {"mode": c.mode, "comm_chunks": c.comm_chunks,
                "reverse": c.reverse, "blocks": c.blocks,
                "shared_gather": c.shared_gather,
                "fuse_epilogue": c.fuse_epilogue,
                "predicted_s": analytic_estimate(kind, m, n, k, n_dev, c,
                                                 dtype_bytes, n_weights,
                                                 epilogue),
                "measured_s": measured}

    if measure:
        import jax.numpy as jnp
        dtype = jnp.bfloat16 if dtype_bytes == 2 else jnp.float32
        cands = candidate_space(kind, m, n, k, n_dev, allow_flux=allow_flux,
                                allow_q8=allow_q8,
                                modes=modes or _measurable_modes(kind,
                                                                 allow_flux),
                                n_weights=n_weights, epilogue=epilogue)
        table = []
        for c in cands:
            fn, args = _bench_callable(kind, m, n, k, n_dev, c, dtype,
                                       n_weights=n_weights,
                                       epilogue=epilogue)
            t = ect.time_fn(fn, *args, iters=iters, warmup=warmup)
            table.append(row(c, measured=t))
        best = min(table, key=lambda r: r["measured_s"])
        source = "measured"
    else:
        cands = candidate_space(kind, m, n, k, n_dev, allow_flux=allow_flux,
                                allow_q8=allow_q8, modes=modes,
                                n_weights=n_weights, epilogue=epilogue)
        table = [row(c) for c in cands]
        best = min(table, key=lambda r: r["predicted_s"])
        source = "analytic"

    blocks = best["blocks"]
    if blocks is None:
        from repro.kernels.ops import plan_blocks
        if kind == "ag":
            blocks = plan_blocks(max(m // n_dev, 1), k, max(n // n_dev, 1))
        else:
            blocks = plan_blocks(max(m // n_dev, 1), max(k // n_dev, 1), n)
    plan = SeamPlan(mode=best["mode"], comm_chunks=best["comm_chunks"],
                    reverse=best["reverse"], blocks=tuple(blocks),
                    shared_gather=best["shared_gather"],
                    fuse_epilogue=best["fuse_epilogue"],
                    source=source, predicted_s=best["predicted_s"],
                    measured_s=best["measured_s"]).validate()
    return TuneResult(seam=seam or kind, kind=kind, m=m, n=n, k=k,
                      n_dev=n_dev, plan=plan, table=table, source=source)


# ---------------------------------------------------------------------------
# whole-model tuning
# ---------------------------------------------------------------------------
def serving_decode_batch() -> int:
    """The decode-AR seam's m dimension under the serving runtime: the
    Server jits ``decode_step`` at ``ServeConfig.max_batch`` rows, so plans
    tuned for any other batch would miss the server's actual signature."""
    from repro.runtime.server import ServeConfig
    return ServeConfig().max_batch


def model_seam_shapes(cfg, par, tokens_per_dp: int = 2048,
                      decode_batch: Optional[int] = None
                      ) -> Dict[str, Tuple[str, int, int, int]]:
    """(kind, m, n, k) per model seam, from the arch's padded GEMM shapes.
    ``decode_batch`` defaults to the serving runtime's ``ServeConfig.
    max_batch`` (the server's decode jit batch); pass the actual
    ``--max-batch`` when tuning for a differently-sized deployment."""
    from repro.parallel.sharding import pad_ff, pad_vocab
    if decode_batch is None:
        decode_batch = serving_decode_batch()
    tp = par.tp
    d = cfg.d_model
    ffp = pad_ff(cfg.d_ff, tp)
    shapes: Dict[str, Tuple[str, int, int, int]] = {
        "mlp_ag": ("ag", tokens_per_dp,
                   ffp * (2 if getattr(par, "fuse_w13", False) else 1), d),
        "mlp_rs": ("rs", tokens_per_dp, d, ffp),
        "head_ag": ("ag", tokens_per_dp, pad_vocab(cfg.vocab_size, tp), d),
        "decode_ar": ("ar", decode_batch, d, ffp),
    }
    if cfg.mla is not None:
        from repro.parallel.sharding import pad_heads
        mla = cfg.mla
        h_pad = pad_heads(cfg.num_heads, tp)
        shapes["attn_ag"] = ("ag", tokens_per_dp,
                             h_pad * (mla.qk_nope_head_dim
                                      + mla.qk_rope_head_dim), mla.q_lora_rank)
        shapes["attn_rs"] = ("rs", tokens_per_dp, d, h_pad * mla.v_head_dim)
    elif cfg.num_heads:
        from repro.models.attention import AttnDims
        dims = AttnDims.of(cfg, tp)
        shapes["attn_ag"] = ("ag", tokens_per_dp,
                             (dims.h_pad + 2 * dims.hkv_pad) * dims.dh, d)
        shapes["attn_rs"] = ("rs", tokens_per_dp, d, dims.h_pad * dims.dh)
    return shapes


def autotune_model(cfg, par, *, tokens_per_dp: int = 2048,
                   decode_batch: Optional[int] = None, measure="auto",
                   registry=None, save_path: Optional[str] = None,
                   allow_flux: bool = True, allow_q8: bool = False) -> PlanSet:
    """Tune every seam of a model and return the resulting PlanSet.

    ``registry`` (a ``cache.PlanRegistry``) short-circuits seams it already
    holds and records fresh results; ``save_path`` persists it afterwards.
    ``allow_q8`` defaults to False here: the int8-gather modes are lossy and
    must be an explicit opt-in for whole-model plans.
    """
    if par.tp <= 1:
        return PlanSet.uniform(par.overlap_mode, par.comm_chunks)
    # FusedOp shape of each seam: the gated FFN runs a two-weight silu-gate
    # op off one gather (w13-packed: one weight, split-gate — still an
    # epilogue); QKV projections fuse the bias when the arch has one.
    fused_shape: Dict[str, Dict] = {
        "mlp_ag": {"n_weights": 1 if getattr(par, "fuse_w13", False) else 2,
                   "epilogue": True},
        "attn_ag": {"epilogue": bool(getattr(cfg, "qkv_bias", False))},
    }
    seams: Dict[str, SeamPlan] = {}
    for seam_name, (kind, m, n, k) in model_seam_shapes(
            cfg, par, tokens_per_dp, decode_batch).items():
        cached = registry.lookup(seam_name, m, n, k) if registry else None
        if cached is not None:
            seams[seam_name] = cached
            continue
        res = tune_seam(kind, m, n, k, par.tp, allow_flux=allow_flux,
                        allow_q8=allow_q8, measure=measure, seam=seam_name,
                        **fused_shape.get(seam_name, {}))
        seams[seam_name] = res.plan
        if registry is not None:
            registry.record(seam_name, kind, m, n, k, res.plan)
    if registry is not None and save_path:
        registry.save(save_path)
    return PlanSet(default=SeamPlan(mode=par.overlap_mode,
                                    comm_chunks=par.comm_chunks).validate(),
                   seams=seams)
