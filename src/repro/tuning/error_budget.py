"""Error budget for low-precision wire transports (ISSUE: wire_dtype).

Quantizing the forward wire (``FusedOp.wire_dtype``) trades accuracy for
bytes-on-wire.  The autotuner must therefore never pick a wire on time
alone — every quantized candidate is scored against an ERROR BUDGET
(``max_logit_rmse``) before it is allowed to win.  This module supplies
the deviation estimates at three costs:

  codec_rmse       pure codec roundtrip deviation — one encode/decode of
                   a seeded activation tensor.  Deviceless, instant.
  seam_wire_rmse   per-seam deviation proxy — SIMULATES what the seam's
                   transport does to the payload (one roundtrip for
                   ag/a2a; hop-by-hop accumulator requantization for the
                   rs/ar rings, which compounds).  Deviceless; this is
                   the default ``rmse_fn`` of ``autotune.tune_seam``.
  model_logit_rmse end-to-end logit deviation of a real model forward,
                   fp wire vs quantized wire, identical params/tokens.
                   Needs >= tp devices (interpret/host-count fine); used
                   by the oracle tests and the tuning benchmark.

All three return a RELATIVE rmse (deviation RMS / signal RMS) so one
``max_logit_rmse`` threshold is meaningful across seams and shapes.  The
backward path never enters the budget: cotangents always ride the
full-precision transports (see core.overlap), so wire_dtype perturbs the
forward value only.
"""
from __future__ import annotations

import functools
from typing import Optional

__all__ = ["codec_rmse", "seam_wire_rmse", "model_logit_rmse",
           "DEFAULT_MAX_LOGIT_RMSE"]

# A permissive default for CLI flows that ask for a wire sweep without
# naming a budget: rejects int4 on deep rings, admits int8/fp8 broadly.
DEFAULT_MAX_LOGIT_RMSE = 0.05

_PROXY_D = 512          # divisible by the 128-block and by n_dev <= 8
_PROXY_ROWS = 32


def _rel_rmse(ref, got):
    import jax.numpy as jnp
    num = jnp.sqrt(jnp.mean((ref - got) ** 2))
    den = jnp.maximum(jnp.sqrt(jnp.mean(ref ** 2)), 1e-30)
    return float(num / den)


def _roundtrip(x, wire_dtype):
    from repro.core.overlap import wire_decode, wire_encode
    return wire_decode(wire_encode(x, wire_dtype), wire_dtype, x.dtype)


def codec_rmse(wire_dtype: Optional[str], *, d: int = _PROXY_D,
               rows: int = _PROXY_ROWS, seed: int = 0) -> float:
    """Relative rmse of one encode/decode roundtrip on seeded N(0,1)
    activations.  The fp wire is exact by definition."""
    if wire_dtype is None:
        return 0.0
    import jax
    x = jax.random.normal(jax.random.PRNGKey(seed), (rows, d), "float32")
    return _rel_rmse(x, _roundtrip(x, wire_dtype))


@functools.lru_cache(maxsize=256)
def _seam_wire_rmse_cached(kind: str, n_dev: int, wire_dtype: str,
                           seed: int) -> float:
    import jax
    import jax.numpy as jnp
    keys = jax.random.split(jax.random.PRNGKey(seed), n_dev)
    parts = [jax.random.normal(k, (_PROXY_ROWS, _PROXY_D), "float32")
             for k in keys]
    if kind in ("ag", "a2a"):
        # one roundtrip per travelling shard; errors are independent so
        # the gathered deviation equals the per-shard deviation
        exact = jnp.concatenate(parts, axis=0)
        got = jnp.concatenate([_roundtrip(p, wire_dtype) for p in parts],
                              axis=0)
        return _rel_rmse(exact, got)
    # rs / ar: the ring requantizes the travelling ACCUMULATOR every hop,
    # so the deviation compounds over the n_dev-1 reduce hops
    exact = sum(parts[1:], parts[0])
    acc = parts[0]
    for p in parts[1:]:
        acc = _roundtrip(acc, wire_dtype) + p
    if kind == "ar":
        # the all-gather phase ships the reduced shard through the wire
        # once more before it lands on every non-owner device
        acc = _roundtrip(acc, wire_dtype)
    return _rel_rmse(exact, acc)


def seam_wire_rmse(kind: str, m: int, n: int, k: int, n_dev: int,
                   wire_dtype: Optional[str], *, seed: int = 0) -> float:
    """Deviation proxy for one seam's wire — the default ``rmse_fn`` of
    ``autotune.tune_seam``.  The proxy is shape-independent (relative
    rmse of the codec is scale- and width-invariant for seeded gaussian
    payloads) but RING-DEPTH dependent: rs/ar compound over n_dev-1 hop
    requantizations, ag/a2a pay a single roundtrip."""
    del m, n, k  # relative rmse is shape-invariant; depth is what matters
    if wire_dtype is None:
        return 0.0
    return _seam_wire_rmse_cached(kind, max(int(n_dev), 2), wire_dtype,
                                  seed)


def model_logit_rmse(cfg, par, wire_dtype: Optional[str], *,
                     mode: str = "decomposed", comm_chunks: int = 0,
                     batch: int = 2, seq: int = 64, seed: int = 0,
                     plans=None) -> float:
    """End-to-end logit deviation: ONE model, ONE token batch, forward
    under the fp wire and under ``wire_dtype``, relative rmse over the
    valid vocab slice.  ``plans`` overrides the fp-wire PlanSet (default:
    ``PlanSet.uniform(mode, comm_chunks)``); the quantized run uses the
    same set stamped via ``with_wire_dtype``.  Requires >= par.tp
    devices; interpret mode is fine (the quantized rings are pure lax)."""
    import functools as _ft

    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.compat import shard_map
    from repro.models import layers
    from repro.models import model as M
    from repro.parallel.sharding import TPContext, pad_vocab
    from repro.tuning.plans import PlanSet

    tp = par.tp
    mesh = Mesh(np.array(jax.devices()[:tp]).reshape(1, tp),
                ("data", "model"))
    params = M.init_model(jax.random.PRNGKey(seed), cfg, par)
    specs = M.param_specs(cfg, par, params)
    tokens = jax.random.randint(jax.random.PRNGKey(seed + 1),
                                (batch, seq), 0, cfg.vocab_size)
    v_pad = pad_vocab(cfg.vocab_size, tp)

    if plans is None:
        plans = PlanSet.uniform(mode, comm_chunks)

    def run(plan_set):
        ctx = TPContext(axis="model", dp_axes=("data",),
                        ep_axes=("model",) if cfg.moe else (),
                        mode=mode, comm_chunks=comm_chunks,
                        plans=plan_set)

        @jax.jit
        @_ft.partial(shard_map, mesh=mesh,
                     in_specs=(specs, P(None, None)),
                     out_specs=P(None, None, "model"), check_vma=False)
        def logits_fn(p, t):
            x = layers.embed_lookup(p["embed"], t, ctx, v_pad)
            x = x.astype(cfg.compute_dtype)
            h, _ = M.backbone(p, x, ctx, cfg, par)
            h = layers.rms_norm(h, p["final_norm"], cfg.norm_eps)
            return layers.lm_head_logits(h, p["embed"], ctx)

        out = logits_fn(params, tokens)
        return jnp.asarray(out, jnp.float32)[..., :cfg.vocab_size]

    ref = run(plans)
    if wire_dtype is None:
        return 0.0
    got = run(plans.with_wire_dtype(wire_dtype))
    return _rel_rmse(ref, got)
