"""Analytic per-device cost model from the jaxpr (roofline inputs).

Why not XLA cost_analysis?  On the CPU backend, dots lower to custom-calls
whose FLOPs report as ~0, and while-loop bodies are counted once — useless
for 61-layer scanned models.  This walker is exact where it matters:

  - dot_general FLOPs from dimension numbers (2·batch·M·N·K),
  - scan bodies multiplied by trip count,
  - collective bytes per primitive type with ring-time models,
  - a fusion-optimistic HBM byte model: every op's OUTPUT is written once;
    dot/conv/gather additionally read their inputs (elementwise chains are
    assumed producer-fused, matching XLA:TPU behavior).

All shapes inside shard_map are per-device, so results are per-device — the
denominators of the roofline terms.  Used by launch/dryrun.py alongside the
XLA numbers (both are recorded; EXPERIMENTS.md documents the discrepancy).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import numpy as np
from jax import core

# v5e constants (task statement)
PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

COLLECTIVES = {
    "psum": "all_reduce",
    "all_gather": "all_gather",
    "reduce_scatter": "reduce_scatter",
    "psum_scatter": "reduce_scatter",
    "all_to_all": "all_to_all",
    "ppermute": "collective_permute",
    "pmax": "all_reduce",
    "pmin": "all_reduce",
}


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0                     # major-op (fusion-optimistic) HBM
    bytes_all: float = 0.0                 # every op output (upper bound)
    collective_bytes: float = 0.0          # summed local operand sizes
    ici_time: float = 0.0                  # ring-model seconds (single-link)
    ici_right: float = 0.0                 # +1-direction ppermute seconds
    ici_left: float = 0.0                  # -1-direction ppermute seconds
    collective_counts: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    collective_bytes_by_type: Dict[str, float] = dataclasses.field(
        default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.bytes_all += other.bytes_all * mult
        self.collective_bytes += other.collective_bytes * mult
        self.ici_time += other.ici_time * mult
        self.ici_right += other.ici_right * mult
        self.ici_left += other.ici_left * mult
        for k, v in other.collective_counts.items():
            self.collective_counts[k] = (
                self.collective_counts.get(k, 0.0) + v * mult)
        for k, v in other.collective_bytes_by_type.items():
            self.collective_bytes_by_type[k] = (
                self.collective_bytes_by_type.get(k, 0.0) + v * mult)


def _nbytes(aval) -> float:
    if not hasattr(aval, "shape"):
        return 0.0
    n = 1
    for d in aval.shape:
        n *= d
    return float(n) * np.dtype(aval.dtype).itemsize


def _size(aval) -> float:
    n = 1
    for d in getattr(aval, "shape", ()):
        n *= d
    return float(n)


def _dot_flops(eqn) -> float:
    dn = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dn
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    batch = 1.0
    for d in lb:
        batch *= lhs.shape[d]
    contract = 1.0
    for d in lc:
        contract *= lhs.shape[d]
    m = 1.0
    for i, d in enumerate(lhs.shape):
        if i not in lc and i not in lb:
            m *= d
    n = 1.0
    for i, d in enumerate(rhs.shape):
        if i not in rc and i not in rb:
            n *= d
    return 2.0 * batch * m * n * contract


def _axis_prod(axes, axis_sizes: Dict[str, int]) -> int:
    if axes is None:
        return 1
    if isinstance(axes, (str,)):
        return axis_sizes.get(axes, 1)
    n = 1
    for a in (axes if isinstance(axes, (tuple, list)) else (axes,)):
        n *= axis_sizes.get(a, 1) if isinstance(a, str) else 1
    return n


def _collective_time(kind: str, local_bytes: float, n: int) -> float:
    """Ring-collective seconds on ICI at 50 GB/s/link."""
    if n <= 1:
        return 0.0
    frac = (n - 1) / n
    if kind == "all_reduce":
        return 2.0 * frac * local_bytes / ICI_BW
    if kind == "all_gather":
        # operand is the shard; each link carries (n-1) shards
        return (n - 1) * local_bytes / ICI_BW
    if kind == "reduce_scatter":
        return frac * local_bytes / ICI_BW
    if kind == "all_to_all":
        return frac * local_bytes / ICI_BW
    if kind == "collective_permute":
        return local_bytes / ICI_BW
    return local_bytes / ICI_BW


def _mesh_axis_sizes(mesh) -> Dict[str, int]:
    if mesh is None:
        return {}
    shape = getattr(mesh, "shape", None)
    if isinstance(shape, dict):
        return {str(k): int(v) for k, v in shape.items()}
    names = getattr(mesh, "axis_names", ())
    try:
        sizes = mesh.devices.shape
    except AttributeError:
        sizes = getattr(mesh, "axis_sizes", ())
    return {str(n): int(s) for n, s in zip(names, sizes)}


def _sub_jaxprs(eqn):
    """Every (Closed)Jaxpr hiding in an eqn's params."""
    out = []
    for v in eqn.params.values():
        if hasattr(v, "eqns"):
            out.append(v)
        elif hasattr(v, "jaxpr"):
            out.append(v.jaxpr)
        elif isinstance(v, (tuple, list)):
            for b in v:
                if hasattr(b, "eqns"):
                    out.append(b)
                elif hasattr(b, "jaxpr"):
                    out.append(b.jaxpr)
    return out


def analyze_jaxpr(jaxpr, axis_sizes: Dict[str, int]) -> Cost:
    cost = Cost()
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name

        # ---- recursion ------------------------------------------------------
        if prim == "scan":
            sub = eqn.params["jaxpr"]
            inner = analyze_jaxpr(getattr(sub, "jaxpr", sub), axis_sizes)
            cost.add(inner, mult=float(eqn.params["length"]))
            continue
        if prim == "while":
            # bounded whiles only appear via fori_loop in kernels; count once
            sub = eqn.params["body_jaxpr"]
            inner = analyze_jaxpr(getattr(sub, "jaxpr", sub), axis_sizes)
            cost.add(inner)
            continue
        if prim == "cond":
            inners = [analyze_jaxpr(getattr(b, "jaxpr", b), axis_sizes)
                      for b in eqn.params["branches"]]
            if inners:
                cost.add(max(inners, key=lambda c: c.flops + c.bytes))
            continue
        if prim == "shard_map":
            new_axes = dict(axis_sizes)
            new_axes.update(_mesh_axis_sizes(eqn.params.get("mesh")))
            sub = eqn.params.get("jaxpr")
            cost.add(analyze_jaxpr(getattr(sub, "jaxpr", sub), new_axes))
            continue
        subs = _sub_jaxprs(eqn)
        if subs and prim not in COLLECTIVES:
            # jit / remat / custom_vjp_call_jaxpr / closed_call / ...
            for sub in subs:
                cost.add(analyze_jaxpr(sub, axis_sizes))
            continue

        # ---- collectives -----------------------------------------------------
        if prim in COLLECTIVES:
            kind = COLLECTIVES[prim]
            axes = (eqn.params.get("axes") or eqn.params.get("axis_name")
                    or eqn.params.get("axis"))
            n = _axis_prod(axes, axis_sizes)
            b = sum(_nbytes(v.aval) for v in eqn.invars
                    if hasattr(v, "aval") and hasattr(v.aval, "shape"))
            cost.collective_bytes += b
            t = _collective_time(kind, b, n)
            cost.ici_time += t
            # per-direction attribution: counter-rotating rings ride
            # independent full-duplex torus links
            if prim == "ppermute":
                perm = eqn.params.get("perm") or ()
                rightward = bool(perm) and (
                    (perm[0][1] - perm[0][0]) % max(n, 1) == 1)
                if rightward:
                    cost.ici_right += t
                else:
                    cost.ici_left += t
            else:
                cost.ici_right += t
                cost.ici_left += t
            cost.collective_counts[kind] = (
                cost.collective_counts.get(kind, 0) + 1)
            cost.collective_bytes_by_type[kind] = (
                cost.collective_bytes_by_type.get(kind, 0) + b)
            # collectives also touch HBM
            hbm = b + sum(_nbytes(v.aval) for v in eqn.outvars)
            cost.bytes += hbm
            cost.bytes_all += hbm
            continue

        # ---- compute ---------------------------------------------------------
        out_bytes = sum(_nbytes(v.aval) for v in eqn.outvars)
        if prim == "dot_general":
            cost.flops += _dot_flops(eqn)
            b = out_bytes + sum(
                _nbytes(v.aval) for v in eqn.invars if hasattr(v, "aval"))
            cost.bytes += b
            cost.bytes_all += b
        elif prim in ("gather", "dynamic_slice", "take"):
            # touched rows only: approximate by output size both ways
            cost.bytes += 2 * out_bytes
            cost.bytes_all += 2 * out_bytes
        elif prim in ("scatter", "scatter-add", "scatter_add",
                      "dynamic_update_slice"):
            # in-place on TPU (buffer donation): traffic = the UPDATE, not
            # the whole destination buffer
            upd = _nbytes(eqn.invars[1].aval) if len(eqn.invars) > 1 else 0
            cost.bytes += 2 * upd
            cost.bytes_all += 2 * upd
        elif prim in ("reduce_sum", "reduce_max", "reduce_min", "reduce_and",
                      "reduce_or", "argmax", "argmin", "reduce_prod"):
            cost.flops += sum(_size(v.aval) for v in eqn.invars
                              if hasattr(v, "aval"))
            cost.bytes += out_bytes     # input assumed fused upstream
            cost.bytes_all += out_bytes
        elif prim in ("cumsum", "cumprod", "cummax", "associative_scan",
                      "cumlogsumexp", "sort"):
            cost.flops += 2 * _size(eqn.outvars[0].aval)
            cost.bytes += 2 * out_bytes
            cost.bytes_all += 2 * out_bytes
        elif prim == "pallas_call":
            ce = eqn.params.get("cost_estimate")
            if ce is not None:
                cost.flops += getattr(ce, "flops", 0) or 0
                cost.bytes += (getattr(ce, "bytes_accessed", 0) or 0)
                cost.bytes_all += (getattr(ce, "bytes_accessed", 0) or 0)
            else:
                cost.bytes += out_bytes
                cost.bytes_all += out_bytes
        else:
            # elementwise & misc: one flop per output element; HBM traffic
            # assumed fused away (major model) but tracked in bytes_all
            cost.flops += _size(eqn.outvars[0].aval) if eqn.outvars else 0
            cost.bytes_all += out_bytes
    return cost


def analyze_fn(fn, *args, axis_sizes: Optional[Dict[str, int]] = None,
               **kwargs) -> Cost:
    """Trace ``fn`` with ShapeDtypeStruct args and analyze."""
    jaxpr = jax.make_jaxpr(fn)(*args, **kwargs)
    return analyze_jaxpr(jaxpr.jaxpr, axis_sizes or {})


def roofline_terms(cost: Cost, chips: int = 1) -> Dict[str, float]:
    """The three §Roofline terms, in seconds (costs are already per-device)."""
    compute = cost.flops / PEAK_FLOPS
    memory = cost.bytes / HBM_BW
    collective = cost.collective_bytes / ICI_BW
    # duplex model: opposite ring directions use independent links
    ici_duplex = max(cost.ici_right, cost.ici_left)
    dominant = max(("compute", compute), ("memory", memory),
                   ("collective", collective), key=lambda kv: kv[1])[0]
    return {
        "compute_s": compute,
        "memory_s": memory,
        "collective_s": collective,
        "ici_model_s": cost.ici_time,
        "ici_duplex_s": ici_duplex,
        "dominant": dominant,
        "flops": cost.flops,
        "bytes": cost.bytes,
        "bytes_all": cost.bytes_all,
        "collective_bytes": cost.collective_bytes,
    }
