"""Static DMA-schedule, race, and tile-budget verifier for the flux kernels.

The FLUX thesis moves the ``DataTransfer -> SetSignal -> WaitSignal``
protocol (paper Algorithms 2/3) *inside* fused Pallas kernels — exactly the
code the jaxpr-level seam checks cannot see: ``make_async_remote_copy``
rings, semaphore waits and the output-tile swizzle live in the kernel body,
and their invariants were, until this module, comments.

``kernelcheck`` executes each registered kernel's grid program ABSTRACTLY
(per grid cell, per logical rank — no devices, no Mosaic, no numerics): the
real wrapper (``ag_gemm`` / ``gemm_rs`` / ...) is called under a patched
``compat.pallas_call`` that captures the kernel body, grid, specs and
scratch shapes from the genuine call site (zero drift), then the body runs
once per (rank, grid cell) against shim Refs with ``pl.program_id`` /
``pl.when`` / ``lax.axis_index`` / ``compat.make_async_*copy`` replaced by
concrete recorders.  The per-rank event streams are replayed by a scheduler
that matches DMA sends to semaphore waits and builds a happens-before order
(vector clocks), giving five machine-checked contract classes:

1. **semaphore balance** — every remote-copy send/recv signal is matched by
   a wait and all semaphores balance by kernel exit (a stuck wait, an
   undrained send, or an unconsumed arrival is reported with its grid cell).
2. **slot race freedom** — an ``a_agg``/scratch slot landing from a DMA is
   never read or written without a happens-before edge through the arriving
   step's recv-semaphore wait, and no slot is written by two unordered DMAs
   (flagged with step/slot provenance).
3. **ring arithmetic** — the remote-copy neighbor and the shard index used
   at step ``s`` must match the decomposed-ring reference schedule, derived
   LIVE from ``core/overlap.py``'s ``_ring_perm`` (the same permutation the
   seam-layer ppermute rings ride) for both ring directions.
4. **tile coverage** — the output-tile swizzle writes every element of the
   output exactly once across the full grid, per rank.
5. **tile budget** — a static VMEM/SMEM footprint model per
   ``(bm, bk, bn, dtype, epilogue)`` rejects infeasible tilings;
   :func:`flux_tile_footprint` is the closed form ``tuning/autotune.py``
   uses to prune flux block candidates before any timed sweep.

Values never matter (backing arrays are zeros; only shapes, indices and
event order are checked), so the trace is cheap: smoke-config shape cells
keep every grid under a few hundred cells.

Registering a new kernel: add a :class:`KernelCase` builder via
:func:`register` (a zero-arg callable that invokes the real wrapper with a
config-derived shape cell; declare ``kind="ag"``/``"rs"`` + ``n_dev`` +
``reverse`` for ring kernels so the ring-arithmetic contract applies).
Escape hatch: there is none on purpose — a kernel that cannot satisfy the
five contracts under this model needs a model extension reviewed here, not
a per-kernel waiver.
"""
from __future__ import annotations

import contextlib
import dataclasses
import itertools
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

#: per-core VMEM on current TPUs (the Pallas guide's ~16 MB figure); the
#: budget model rejects tilings whose static footprint exceeds it.
VMEM_LIMIT_BYTES = 16 * 2 ** 20
#: SMEM holds scalars/descriptors only — a kernel wanting more than this in
#: scalar memory is structurally wrong.
SMEM_LIMIT_BYTES = 16 * 2 ** 10
#: hard per-rank cell cap: shape cells must stay smoke-sized (the contract
#: classes are structural, not size-dependent — same rule as seamcheck).
MAX_GRID_CELLS = 4096

_AXIS = "model"
_TP = 4


# ---------------------------------------------------------------------------
# tile-budget closed form (the autotune pruning model)
# ---------------------------------------------------------------------------
def flux_tile_footprint(kind: str, bm: int, bk: int, bn: int, *,
                        dtype_bytes: int = 2,
                        out_bytes: Optional[int] = None,
                        partial_bytes: Optional[int] = None,
                        has_bias: bool = False) -> int:
    """Static VMEM bytes of one flux kernel instance for blocks (bm,bk,bn).

    Mirrors the ``scratch_shapes`` of ``kernels/ag_gemm.py`` /
    ``kernels/gemm_rs.py`` exactly (the kernelcheck trace cross-checks the
    two stay in sync): fp32 accumulator + A/B input tiles + cast/stage
    buffers + the optional bias tile.  HBM scratch (``a_agg``/``ws``) is
    deliberately excluded — it is compiler-placed, not VMEM.
    """
    assert kind in ("ag", "rs"), kind
    ob = out_bytes or dtype_bytes
    acc = 4 * bm * bn                           # fp32 accumulator
    a = dtype_bytes * bm * bk                   # A tile
    b = dtype_bytes * bk * bn                   # B tile
    bias = dtype_bytes * bn if has_bias else 0
    if kind == "ag":
        return acc + a + b + ob * bm * bn + bias          # + output cast
    pb = partial_bytes or ob
    # rs: partial stage + output cast buffers
    return acc + a + b + pb * bm * bn + ob * bm * bn + bias


def tile_budget_ok(kind: str, blocks: Tuple[int, int, int], *,
                   dtype_bytes: int = 2, out_bytes: Optional[int] = None,
                   partial_bytes: Optional[int] = None,
                   has_bias: bool = False,
                   limit: int = VMEM_LIMIT_BYTES) -> bool:
    """True iff the flux tiling's static VMEM footprint fits ``limit``.

    This is the predicate ``tuning/autotune.py`` applies to every flux
    ``blocks`` candidate BEFORE pricing or timing it.
    """
    bm, bk, bn = blocks
    return flux_tile_footprint(kind, bm, bk, bn, dtype_bytes=dtype_bytes,
                               out_bytes=out_bytes,
                               partial_bytes=partial_bytes,
                               has_bias=has_bias) <= limit


# ---------------------------------------------------------------------------
# ring reference schedule — derived live from core/overlap.py
# ---------------------------------------------------------------------------
def _overlap_ring_perm(n_dev: int, reverse: bool) -> List[Tuple[int, int]]:
    """The (src, dst) ppermute pairs of the seam layer's decomposed ring,
    obtained by probing ``overlap._ring_perm`` under an abstract axis env —
    the kernels are checked against the SAME schedule the jaxpr seams ride,
    so the two ring implementations cannot drift apart silently."""
    from repro.core import overlap
    got: Dict[str, List[Tuple[int, int]]] = {}

    def probe():
        got["perm"] = overlap._ring_perm(_AXIS, reverse)
        return jnp.zeros(())

    jax.make_jaxpr(probe, axis_env=[(_AXIS, n_dev)])()
    return [(int(s), int(d)) for s, d in got["perm"]]


def ring_schedules(n_dev: int, reverse: bool):
    """(nbr, ag_owner, rs_owner) reference tables for one ring direction.

    ``nbr[me]`` — the downstream neighbor every in-kernel remote copy must
    target.  ``ag_owner[me][s]`` — the shard rank ``me`` holds (and
    multiplies) at AllGather-ring step ``s``: step 0 is the local shard,
    then each hop hands the held shard downstream (paper §4.3 ring order).
    ``rs_owner[me][s]`` — the output owner whose partial rank ``me``
    computes at ReduceScatter step ``s``; the recurrence runs backwards
    from the terminal condition ``rs_owner[me][n-1] == me`` (the last step
    emits the local shard).  Both tables are pure consequences of the
    overlap.py permutation — no second copy of the ring arithmetic."""
    perm = _overlap_ring_perm(n_dev, reverse)
    nbr = {src: dst for src, dst in perm}
    ag = [[0] * n_dev for _ in range(n_dev)]
    for r in range(n_dev):
        ag[r][0] = r
    for s in range(1, n_dev):
        for src, dst in perm:
            ag[dst][s] = ag[src][s - 1]
    rs = [[0] * n_dev for _ in range(n_dev)]
    for r in range(n_dev):
        rs[r][n_dev - 1] = r
    for s in range(n_dev - 2, -1, -1):
        for src, dst in perm:
            rs[src][s] = rs[dst][s + 1]
    return nbr, ag, rs


# ---------------------------------------------------------------------------
# capture: grab the kernel/grid/specs from the REAL wrapper call
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Captured:
    kernel: Callable
    grid: Tuple[int, ...]
    in_specs: Sequence
    out_specs: object
    out_shape: jax.ShapeDtypeStruct
    scratch_shapes: Sequence
    operands: Tuple


@contextlib.contextmanager
def _capture_pallas_call(box: Dict):
    """Patch ``compat.pallas_call`` so invoking a kernel wrapper records the
    call instead of executing it (outputs come back as zeros so wrapper
    epilogue code — reshapes etc. — still runs)."""
    from repro import compat

    def fake_pallas_call(kernel, *, grid, in_specs, out_specs, out_shape,
                         scratch_shapes=(), **_kw):
        def call(*operands):
            box["cap"] = Captured(kernel=kernel, grid=tuple(grid),
                                  in_specs=tuple(in_specs),
                                  out_specs=out_specs, out_shape=out_shape,
                                  scratch_shapes=tuple(scratch_shapes),
                                  operands=operands)
            return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                                out_shape)
        return call

    orig = compat.pallas_call
    compat.pallas_call = fake_pallas_call
    try:
        yield box
    finally:
        compat.pallas_call = orig


# ---------------------------------------------------------------------------
# shim refs, regions, events
# ---------------------------------------------------------------------------
def _as_int(x) -> int:
    return int(x)


def _norm_index(shape: Tuple[int, ...], idx) -> Tuple[Tuple[int, int], ...]:
    """Concrete (start, size) per dim for an ``.at[...]``/getitem index."""
    if not isinstance(idx, tuple):
        idx = (idx,)
    if any(i is Ellipsis for i in idx):
        pos = idx.index(Ellipsis)
        fill = len(shape) - (len(idx) - 1)
        idx = idx[:pos] + (slice(None),) * fill + idx[pos + 1:]
    dims: List[Tuple[int, int]] = []
    for d, size in enumerate(shape):
        if d < len(idx):
            i = idx[d]
            if isinstance(i, slice):
                start = 0 if i.start is None else _as_int(i.start)
                stop = size if i.stop is None else _as_int(i.stop)
                dims.append((start, stop - start))
            elif hasattr(i, "start") and hasattr(i, "size"):   # pl.ds
                dims.append((_as_int(i.start), _as_int(i.size)))
            else:
                dims.append((_as_int(i), 1))
        else:
            dims.append((0, size))
    return tuple(dims)


def _np_index(shape, idx):
    """The same index, lowered to plain numpy slicing (ints stay ints so
    reads keep the kernel's expected rank)."""
    if not isinstance(idx, tuple):
        idx = (idx,)
    out = []
    for i in idx:
        if i is Ellipsis or isinstance(i, slice):
            out.append(i if isinstance(i, slice) else Ellipsis)
        elif hasattr(i, "start") and hasattr(i, "size"):
            out.append(slice(_as_int(i.start), _as_int(i.start) + _as_int(i.size)))
        else:
            out.append(_as_int(i))
    return tuple(out)


@dataclasses.dataclass(frozen=True)
class Region:
    dims: Tuple[Tuple[int, int], ...]

    def overlaps(self, other: "Region") -> bool:
        for (s1, n1), (s2, n2) in zip(self.dims, other.dims):
            if s1 + n1 <= s2 or s2 + n2 <= s1:
                return False
        return True

    def size(self) -> int:
        n = 1
        for _, sz in self.dims:
            n *= sz
        return n

    def __str__(self):
        return "[" + ", ".join(f"{s}:{s + n}" for s, n in self.dims) + "]"


@dataclasses.dataclass
class Event:
    kind: str                 # read | write | remote_start | wait_send | wait_recv
    rank: int
    where: str                # provenance: kernel/cell
    buf: str = ""
    region: Optional[Region] = None
    sem: str = ""
    send_sem: str = ""
    nbytes: int = 0
    dst_rank: int = -1
    dst_buf: str = ""
    dst_region: Optional[Region] = None


class _Sem:
    def __init__(self, name: str):
        self.name = name


class _Ref:
    """Shim standing in for one kernel Ref.

    ``space`` is "any" (HBM operand / scratch — race- and ring-tracked),
    "vmem"/"smem" (per-cell private — untracked), or a blocked spec
    (fresh block backing per cell, global coverage mapping for outputs).
    Backing arrays are REAL-shaped zeros so every jnp op in the kernel body
    sees the exact shapes the compiled kernel would.
    """

    def __init__(self, name, shape, dtype, space, rec, *, backing=None,
                 is_output=False, block_origin=None):
        self.name = name
        self.shape = tuple(int(d) for d in shape)
        self.dtype = np.dtype(dtype)
        self.space = space
        self._rec = rec
        self._backing = (backing if backing is not None
                         else jnp.zeros(self.shape, self.dtype))
        self.is_output = is_output
        self.block_origin = block_origin      # global offset of this block

    # -- direct indexing ----------------------------------------------------
    def __getitem__(self, idx):
        if self.space == "any":
            self._rec.access("read", self, Region(_norm_index(self.shape, idx)))
        return self._backing[_np_index(self.shape, idx)]

    def __setitem__(self, idx, _val):
        region = Region(_norm_index(self.shape, idx))
        if self.space == "any":
            self._rec.access("write", self, region)
        if self.is_output:
            self._rec.cover(self, region)

    # -- .at[...] views (copy endpoints) ------------------------------------
    @property
    def at(self):
        return _At(self)


class _At:
    def __init__(self, ref: _Ref):
        self._ref = ref

    def __getitem__(self, idx):
        return _View(self._ref, Region(_norm_index(self._ref.shape, idx)))


@dataclasses.dataclass
class _View:
    ref: _Ref
    region: Region

    @property
    def nbytes(self) -> int:
        return self.region.size() * self.ref.dtype.itemsize


def _as_view(x) -> _View:
    if isinstance(x, _View):
        return x
    return _View(x, Region(tuple((0, d) for d in x.shape)))


class _LocalCopy:
    def __init__(self, rec, src, dst, sem):
        self._rec = rec
        self.src, self.dst = _as_view(src), _as_view(dst)
        self.sem = sem
        self.started = self.waited = False
        self.where = rec.where()
        rec.local_copies.append(self)

    def start(self):
        self.started = True
        if self.src.nbytes != self.dst.nbytes:
            self._rec.err(f"local async copy size mismatch: "
                          f"{self.src.ref.name}{self.src.region} "
                          f"({self.src.nbytes}B) -> {self.dst.ref.name}"
                          f"{self.dst.region} ({self.dst.nbytes}B)")
        self._rec.access_view("read", self.src)
        self._rec.access_view("write", self.dst)

    def wait(self):
        if not self.started:
            self._rec.err("wait() on a local async copy that was never "
                          "started")
        self.waited = True


class _RemoteCopy:
    """Descriptor shim for ``make_async_remote_copy`` — the kernels build
    fresh descriptors to wait on copies started elsewhere, so only the
    events matter, matched by (rank, semaphore) FIFO in the replay."""

    def __init__(self, rec, src_ref, dst_ref, send_sem, recv_sem, device_id):
        self._rec = rec
        self.src, self.dst = _as_view(src_ref), _as_view(dst_ref)
        self.send_sem, self.recv_sem = send_sem, recv_sem
        self.device_id = _as_int(device_id)

    def start(self):
        self._rec.access_view("read", self.src)
        self._rec.event(Event(
            kind="remote_start", rank=self._rec.rank, where=self._rec.where(),
            buf=self.src.ref.name, region=self.src.region,
            sem=self.recv_sem.name, send_sem=self.send_sem.name,
            nbytes=self.src.nbytes,
            dst_rank=self.device_id, dst_buf=self.dst.ref.name,
            dst_region=self.dst.region))

    def wait_send(self):
        self._rec.event(Event(kind="wait_send", rank=self._rec.rank,
                              where=self._rec.where(),
                              sem=self.send_sem.name))

    def wait_recv(self):
        self._rec.event(Event(kind="wait_recv", rank=self._rec.rank,
                              where=self._rec.where(), buf=self.dst.ref.name,
                              region=self.dst.region, sem=self.recv_sem.name,
                              nbytes=self.dst.nbytes))


class _Recorder:
    """Per-rank event stream + output-coverage counters + trace errors."""

    def __init__(self, label: str, rank: int, out_shape):
        self.label = label
        self.rank = rank
        self.cell: Tuple[int, ...] = ()
        self.events: List[Event] = []
        self.errors: List[str] = []
        self.local_copies: List[_LocalCopy] = []
        self.coverage = np.zeros(out_shape.shape, np.int32)

    def where(self) -> str:
        step = f"step={self.cell[0]} " if self.cell else ""
        return f"{self.label} rank{self.rank} {step}cell={self.cell}"

    def err(self, msg: str):
        self.errors.append(f"{self.where()}: {msg}")

    def event(self, e: Event):
        self.events.append(e)

    def access(self, kind: str, ref: _Ref, region: Region):
        self.events.append(Event(kind=kind, rank=self.rank,
                                 where=self.where(), buf=ref.name,
                                 region=region))

    def access_view(self, kind: str, view: _View):
        if view.ref.space == "any":
            self.access(kind, view.ref, view.region)
        if kind == "write" and view.ref.is_output:
            self.cover(view.ref, view.region)

    def cover(self, ref: _Ref, region: Region):
        dims = region.dims
        if ref.block_origin is not None:
            dims = tuple((o + s, n)
                         for o, (s, n) in zip(ref.block_origin, dims))
        self.coverage[tuple(slice(s, s + n) for s, n in dims)] += 1

    def finish_cells(self):
        for cp in self.local_copies:
            if cp.started and not cp.waited:
                self.errors.append(
                    f"{cp.where}: local async copy "
                    f"{cp.src.ref.name}{cp.src.region} -> "
                    f"{cp.dst.ref.name}{cp.dst.region} started but never "
                    "waited (unbalanced local DMA semaphore)")


# ---------------------------------------------------------------------------
# abstract per-rank execution of the captured grid program
# ---------------------------------------------------------------------------
@contextlib.contextmanager
def _patched_primitives(rec: _Recorder, grid: Tuple[int, ...]):
    from jax import lax
    from jax.experimental import pallas as pl
    from repro import compat

    saved = (pl.program_id, pl.num_programs, pl.when,
             compat.make_async_copy, compat.make_async_remote_copy,
             lax.axis_index)

    def program_id(axis):
        return rec.cell[axis]

    def num_programs(axis):
        return grid[axis]

    def when(pred):
        def deco(fn):
            if bool(pred):
                fn()
            return fn
        return deco

    def axis_index(_axis):
        return rec.rank

    def make_async_copy(src, dst, sem):
        return _LocalCopy(rec, src, dst, sem)

    def make_async_remote_copy(*, src_ref, dst_ref, send_sem, recv_sem,
                               device_id, device_id_type=None):
        del device_id_type
        return _RemoteCopy(rec, src_ref, dst_ref, send_sem, recv_sem,
                           device_id)

    pl.program_id, pl.num_programs, pl.when = (program_id, num_programs,
                                               when)
    compat.make_async_copy = make_async_copy
    compat.make_async_remote_copy = make_async_remote_copy
    lax.axis_index = axis_index
    try:
        yield
    finally:
        (pl.program_id, pl.num_programs, pl.when, compat.make_async_copy,
         compat.make_async_remote_copy, lax.axis_index) = saved


def _spec_space(spec) -> str:
    ms = getattr(spec, "memory_space", None)
    s = str(ms).lower() if ms is not None else "any"
    for known in ("smem", "vmem", "any"):
        if known in s:
            return known
    return "any" if spec.block_shape is None else "vmem"


def _build_static_args(cap: Captured, rec: _Recorder):
    """Shims for the non-blocked args (built once per rank): ANY/SMEM
    operands, the unblocked output, and every scratch entry."""
    from repro import compat

    ins = []
    blocked_in: List[Tuple[int, object, object]] = []   # (argpos, spec, op)
    for i, (spec, op) in enumerate(zip(cap.in_specs, cap.operands)):
        if spec.block_shape is None:
            space = _spec_space(spec)
            backing = jnp.asarray(op) if space == "smem" else None
            ins.append(_Ref(f"in{i}", op.shape, op.dtype, space, rec,
                            backing=backing))
        else:
            ins.append(None)
            blocked_in.append((i, spec, op))
    if cap.out_specs.block_shape is None:
        out = _Ref("out", cap.out_shape.shape, cap.out_shape.dtype, "any",
                   rec, is_output=True)
    else:
        out = None
    scratch = []
    for i, entry in enumerate(cap.scratch_shapes):
        if entry is compat.DMA_SEM or isinstance(entry, type(compat.DMA_SEM)):
            scratch.append(_Sem(f"sem{i}"))
        else:
            space = str(getattr(entry, "memory_space", "vmem")).lower()
            space = "any" if "any" in space else (
                "smem" if "smem" in space else "vmem")
            scratch.append(_Ref(f"scratch{i}", entry.shape, entry.dtype,
                                space, rec))
    return ins, blocked_in, out, scratch


def _trace_rank(cap: Captured, label: str, rank: int) -> _Recorder:
    """Run the kernel body for every grid cell on one logical rank."""
    rec = _Recorder(label, rank, cap.out_shape)
    ins, blocked_in, out_static, scratch = _build_static_args(cap, rec)
    out_blocked = cap.out_specs.block_shape is not None

    with _patched_primitives(rec, cap.grid):
        for cell in itertools.product(*(range(g) for g in cap.grid)):
            rec.cell = cell
            args = list(ins)
            for pos, spec, op in blocked_in:
                idx = tuple(_as_int(i) for i in spec.index_map(*cell))
                args[pos] = _Ref(f"in{pos}", spec.block_shape, op.dtype,
                                 "vmem", rec)
            if out_blocked:
                spec = cap.out_specs
                idx = tuple(_as_int(i) for i in spec.index_map(*cell))
                origin = tuple(b * i for b, i in zip(spec.block_shape, idx))
                out = _Ref("out", spec.block_shape, cap.out_shape.dtype,
                           "vmem", rec, is_output=True, block_origin=origin)
            else:
                out = out_static
            cap.kernel(*args, out, *scratch)
    rec.finish_cells()
    return rec


# ---------------------------------------------------------------------------
# contract 1+2 machinery: scheduler replay + vector-clock happens-before
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class _Landed:
    dst_rank: int
    buf: str
    region: Region
    nbytes: int
    start_vc: np.ndarray
    where: str
    sealed_vc: Optional[np.ndarray] = None
    sealed_where: str = ""


@dataclasses.dataclass
class _Access:
    rank: int
    kind: str
    buf: str
    region: Region
    vc: np.ndarray
    where: str


def _vc_leq(a: np.ndarray, b: np.ndarray) -> bool:
    return bool(np.all(a <= b))


def _replay(label: str, n_dev: int, streams: List[List[Event]]):
    """Deterministic scheduler replay of the per-rank event streams.

    Enabledness: reads/writes/remote starts always run; ``wait_send`` needs
    an undrained started send on (rank, sem); ``wait_recv`` needs an
    unconsumed arrival on (rank, sem) — FIFO per semaphore, matching the
    hardware's DMA completion counting.  A global stall is a protocol
    deadlock (a wait whose signal can never arrive).  Returns
    (errors, accesses, landed copies) for the race pass.
    """
    errs: List[str] = []
    pcs = [0] * n_dev
    vcs = [np.zeros(n_dev, np.int64) for _ in range(n_dev)]
    channel: Dict[Tuple[int, str], List[_Landed]] = {}
    sendq: Dict[Tuple[int, str], List[str]] = {}
    accesses: List[_Access] = []
    landed: List[_Landed] = []

    def tick(r: int) -> np.ndarray:
        vcs[r][r] += 1
        return vcs[r].copy()

    while True:
        progressed = False
        done = True
        for r in range(n_dev):
            if pcs[r] >= len(streams[r]):
                continue
            done = False
            e = streams[r][pcs[r]]
            if e.kind in ("read", "write"):
                accesses.append(_Access(r, e.kind, e.buf, e.region, tick(r),
                                        e.where))
            elif e.kind == "remote_start":
                vc = tick(r)
                c = _Landed(dst_rank=e.dst_rank, buf=e.dst_buf,
                            region=e.dst_region, nbytes=e.nbytes,
                            start_vc=vc, where=e.where)
                channel.setdefault((e.dst_rank, e.sem), []).append(c)
                sendq.setdefault((r, e.send_sem), []).append(e.where)
            elif e.kind == "wait_send":
                q = sendq.get((r, e.sem), [])
                if not q:
                    continue                      # blocked
                q.pop(0)
                tick(r)
            elif e.kind == "wait_recv":
                q = channel.get((r, e.sem), [])
                if not q:
                    continue                      # blocked
                c = q.pop(0)
                if c.nbytes != e.nbytes or c.buf != e.buf or \
                        c.region != e.region:
                    errs.append(
                        f"{e.where}: wait_recv descriptor "
                        f"({e.buf}{e.region}, {e.nbytes}B) does not match "
                        f"the arriving copy ({c.buf}{c.region}, "
                        f"{c.nbytes}B) started at {c.where}")
                vcs[r] = np.maximum(vcs[r], c.start_vc)
                c.sealed_vc = tick(r)
                c.sealed_where = e.where
                landed.append(c)
            else:                                  # pragma: no cover
                raise AssertionError(e.kind)
            pcs[r] += 1
            progressed = True
        if done:
            break
        if not progressed:
            for r in range(n_dev):
                if pcs[r] < len(streams[r]):
                    e = streams[r][pcs[r]]
                    errs.append(
                        f"{e.where}: deadlock — {e.kind} on {e.sem!r} can "
                        "never be satisfied (no matching DMA start reaches "
                        "this semaphore)")
            return errs, accesses, landed

    for (rank, sem), q in channel.items():
        for c in q:
            errs.append(f"{c.where}: remote copy into rank{rank} "
                        f"{c.buf}{c.region} arrived but its recv semaphore "
                        f"{sem!r} is never waited (unbalanced recv)")
            landed.append(c)                      # still a write: race-check
    for (rank, sem), q in sendq.items():
        for where in q:
            errs.append(f"{where}: send on {sem!r} never drained by a "
                        "wait_send before kernel exit (unbalanced send)")
    return errs, accesses, landed


def _race_errors(accesses: List[_Access], landed: List[_Landed]) -> List[str]:
    """Contract 2: every DMA landing must be happens-before ordered against
    every local access of its slot (through the recv wait), and no two
    unordered DMAs may write overlapping slots."""
    errs: List[str] = []
    for c in landed:
        for a in accesses:
            if a.rank != c.dst_rank or a.buf != c.buf:
                continue
            if not a.region.overlaps(c.region):
                continue
            before = _vc_leq(a.vc, c.start_vc)
            after = c.sealed_vc is not None and _vc_leq(c.sealed_vc, a.vc)
            if not (before or after):
                errs.append(
                    f"{a.where}: {a.kind} of slot {a.buf}{a.region} races "
                    f"the DMA landing started at {c.where} (no "
                    "happens-before through the arriving step's recv wait)")
    for c1, c2 in itertools.combinations(landed, 2):
        if c1.dst_rank != c2.dst_rank or c1.buf != c2.buf:
            continue
        if not c1.region.overlaps(c2.region):
            continue
        o12 = c1.sealed_vc is not None and _vc_leq(c1.sealed_vc, c2.start_vc)
        o21 = c2.sealed_vc is not None and _vc_leq(c2.sealed_vc, c1.start_vc)
        if not (o12 or o21):
            errs.append(
                f"{c1.where} and {c2.where}: slot {c1.buf}{c1.region} "
                "written by two unordered DMAs (each slot must have exactly "
                "one in-flight writer)")
    return errs


# ---------------------------------------------------------------------------
# contract 3: ring arithmetic vs the overlap.py reference schedule
# ---------------------------------------------------------------------------
def _ring_errors(label: str, kind: str, n_dev: int, reverse: bool,
                 recs: List[_Recorder], slot_rows: int) -> List[str]:
    """``slot_rows``: rows of one ring slot in the buffer the owner index is
    read from (ag: the A_agg slot dim is explicit; rs: the A operand's rows
    per output shard, ``m_sh``)."""
    nbr, ag_owner, rs_owner = ring_schedules(n_dev, reverse)
    errs: List[str] = []
    for rec in recs:
        me = rec.rank
        for e in rec.events:
            step = int(e.where.split("step=")[1].split(" ")[0]) \
                if "step=" in e.where else 0
            if e.kind == "remote_start":
                if e.dst_rank != nbr[me]:
                    errs.append(
                        f"{e.where}: remote copy targets rank {e.dst_rank} "
                        f"but the {'reverse' if reverse else 'forward'} "
                        f"ring neighbor of rank {me} is {nbr[me]} "
                        "(overlap._ring_perm reference)")
                if kind == "ag":
                    slot = e.region.dims[0][0]
                    want = ag_owner[me][step]
                    if slot != want:
                        errs.append(
                            f"{e.where}: forwards A_agg slot {slot} but the "
                            f"reference schedule holds shard {want} at step "
                            f"{step}")
                else:
                    src_slot, dst_slot = (e.region.dims[0][0],
                                          e.dst_region.dims[0][0])
                    if (src_slot, dst_slot) != (step, step + 1):
                        errs.append(
                            f"{e.where}: rs forwards in-flight slot "
                            f"{src_slot}->{dst_slot}; the decomposed ring "
                            f"expects {step}->{step + 1}")
            elif e.kind == "read" and kind == "ag" and e.buf.startswith("scratch"):
                slot = e.region.dims[0][0]
                want = ag_owner[me][step]
                if slot != want:
                    errs.append(
                        f"{e.where}: computes on A_agg slot {slot} but rank "
                        f"{me} holds shard {want} at step {step} "
                        "(overlap.py ring reference)")
            elif e.kind == "read" and kind == "rs" and e.buf == "in0":
                owner = e.region.dims[0][0] // max(slot_rows, 1)
                want = rs_owner[me][step]
                if owner != want:
                    errs.append(
                        f"{e.where}: contracts rows of output owner {owner} "
                        f"but the reference swizzle computes owner {want} "
                        f"at step {step}")
    return errs


# ---------------------------------------------------------------------------
# contract 4+5: coverage and budget
# ---------------------------------------------------------------------------
def _coverage_errors(label: str, recs: List[_Recorder]) -> List[str]:
    errs = []
    for rec in recs:
        cov = rec.coverage
        if (cov == 1).all():
            continue
        missed = int((cov == 0).sum())
        dup = int((cov > 1).sum())
        idx = tuple(int(i) for i in
                    np.argwhere(cov != 1)[0]) if cov.size else ()
        errs.append(
            f"{label} rank{rec.rank}: output tile coverage broken — "
            f"{missed} element(s) never written, {dup} written more than "
            f"once (first bad element at {idx}; every [bm,bn] tile must be "
            "written exactly once across the grid)")
    return errs


def traced_vmem_bytes(cap: Captured) -> int:
    """VMEM footprint of a captured call: VMEM scratch + 2x every blocked
    in/out block (Pallas double-buffers blocked refs across grid steps)."""
    from repro import compat
    total = 0
    for entry in cap.scratch_shapes:
        if entry is compat.DMA_SEM or isinstance(entry, type(compat.DMA_SEM)):
            continue
        if "vmem" in str(getattr(entry, "memory_space", "vmem")).lower():
            total += int(np.prod(entry.shape)) * np.dtype(entry.dtype).itemsize
    for spec, op in list(zip(cap.in_specs, cap.operands)) + [
            (cap.out_specs, cap.out_shape)]:
        if spec.block_shape is not None:
            total += 2 * int(np.prod(spec.block_shape)) * \
                np.dtype(op.dtype).itemsize
    return total


def _budget_errors(label: str, cap: Captured) -> List[str]:
    from repro import compat
    errs = []
    vmem = traced_vmem_bytes(cap)
    if vmem > VMEM_LIMIT_BYTES:
        errs.append(
            f"{label}: static VMEM footprint {vmem / 2**20:.1f} MiB exceeds "
            f"the {VMEM_LIMIT_BYTES / 2**20:.0f} MiB per-core budget — "
            "infeasible tiling (shrink bm/bk/bn)")
    smem = 0
    for spec, op in zip(cap.in_specs, cap.operands):
        if spec.block_shape is None and _spec_space(spec) == "smem":
            smem += op.size * np.dtype(op.dtype).itemsize
    for entry in cap.scratch_shapes:
        if entry is compat.DMA_SEM or isinstance(entry, type(compat.DMA_SEM)):
            continue
        if "smem" in str(getattr(entry, "memory_space", "")).lower():
            smem += int(np.prod(entry.shape)) * np.dtype(entry.dtype).itemsize
    if smem > SMEM_LIMIT_BYTES:
        errs.append(f"{label}: SMEM footprint {smem} B exceeds the "
                    f"{SMEM_LIMIT_BYTES} B scalar-memory budget")
    return errs


# ---------------------------------------------------------------------------
# top level: check one call, the registry, the gate entry point
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class KernelCase:
    """One (kernel, direction, shape cell) to verify.

    ``build`` invokes the REAL wrapper (under the capture patch) — the
    checker never reimplements a call site.  ``kind`` is "ag"/"rs" for ring
    kernels (enables the ring-arithmetic contract; ``n_dev`` ranks are
    traced) and None for single-device grid kernels.  ``slot_rows`` maps
    buffer rows to ring slots for the rs owner check.
    """
    label: str
    build: Callable[[], object]
    kind: Optional[str] = None
    n_dev: int = 1
    reverse: bool = False
    slot_rows: int = 0


def check_case(case: KernelCase) -> List[str]:
    """All five contract classes for one kernel call."""
    box: Dict = {}
    try:
        with _capture_pallas_call(box):
            case.build()
    except Exception as e:                        # a call that cannot build
        return [f"{case.label}: capture failed: {type(e).__name__}: {e}"]
    if "cap" not in box:
        return [f"{case.label}: wrapper never reached compat.pallas_call"]
    cap = box["cap"]

    errs = _budget_errors(case.label, cap)
    cells = int(np.prod(cap.grid)) if cap.grid else 0
    if cells > MAX_GRID_CELLS:
        errs.append(f"{case.label}: grid {cap.grid} has {cells} cells — "
                    f"above the {MAX_GRID_CELLS}-cell static-trace cap; "
                    "use a smaller shape cell (contracts are structural)")
        return errs

    recs = []
    for rank in range(case.n_dev):
        try:
            recs.append(_trace_rank(cap, case.label, rank))
        except Exception as e:
            errs.append(f"{case.label} rank{rank}: abstract execution "
                        f"failed: {type(e).__name__}: {e}")
            return errs
    for rec in recs:
        errs.extend(rec.errors)

    replay_errs, accesses, landed = _replay(
        case.label, case.n_dev, [r.events for r in recs])
    errs.extend(replay_errs)
    errs.extend(_race_errors(accesses, landed))
    if case.kind in ("ag", "rs"):
        errs.extend(_ring_errors(case.label, case.kind, case.n_dev,
                                 case.reverse, recs, case.slot_rows))
    errs.extend(_coverage_errors(case.label, recs))
    return errs


# -- in-tree kernel registry -------------------------------------------------
_REGISTRY: List[Callable[[Optional[Sequence[str]]], List[KernelCase]]] = []


def register(case_builder: Callable[[Optional[Sequence[str]]],
                                    List[KernelCase]]):
    """Register a case builder: ``configs -> [KernelCase]``.  New kernels
    add themselves here so ``--kernels`` picks them up automatically."""
    _REGISTRY.append(case_builder)
    return case_builder


def _ring_shape_cells(config_names: Optional[Sequence[str]]
                      ) -> List[Tuple[str, int, int, int]]:
    """Config-derived per-device GEMM cells (kind, gm, gk, gn), deduped.

    Mirrors ``autotune.candidate_space``'s flux branch: the smoke config's
    ``model_seam_shapes`` give the seam GEMMs, divided onto the tp ring.
    Smoke token counts keep every grid a few dozen cells.
    """
    from repro.analysis.seamcheck import discover_configs
    from repro.configs.base import ParallelConfig, get_smoke_config
    from repro.tuning.autotune import model_seam_shapes

    par = ParallelConfig(tp=_TP, dp=1)
    cells: List[Tuple[str, int, int, int]] = []
    seen = set()
    for name in (config_names or discover_configs()):
        cfg = get_smoke_config(name)
        for _key, (kind, m, n, k) in model_seam_shapes(
                cfg, par, tokens_per_dp=128, decode_batch=8).items():
            if kind == "ag":
                gm, gk, gn = max(m // _TP, 1), k, max(n // _TP, 1)
            elif kind == "rs":
                gm, gk, gn = max(m // _TP, 1), max(k // _TP, 1), n
            else:
                continue
            cell = (kind, gm, gk, gn)
            if cell not in seen:
                seen.add(cell)
                cells.append(cell)
    return cells


def _half_blocks(gm: int, gk: int, gn: int) -> Tuple[int, int, int]:
    """Blocks at half the cell dims: guarantees a multi-tile grid on every
    axis that can afford one, so the swizzle/accumulator logic is actually
    exercised (full-dim blocks would collapse the inner grid to 1x1x1)."""
    from repro.kernels.ops import plan_blocks
    return plan_blocks(gm, gk, gn, max(gm // 2, 1), max(gk // 2, 1),
                       max(gn // 2, 1))


@register
def _flux_ring_cases(config_names=None) -> List[KernelCase]:
    from repro.kernels.ag_gemm import ag_gemm
    from repro.kernels.gemm_rs import gemm_rs

    cases = []
    for kind, gm, gk, gn in _ring_shape_cells(config_names):
        bm, bk, bn = _half_blocks(gm, gk, gn)
        for reverse in (False, True):
            tag = "rev" if reverse else "fwd"
            if kind == "ag":
                a = jnp.zeros((gm, gk), jnp.bfloat16)
                b = jnp.zeros((gk, gn), jnp.bfloat16)
                bias = jnp.zeros((gn,), jnp.bfloat16)

                def build(a=a, b=b, bias=bias, blocks=(bm, bk, bn),
                          reverse=reverse):
                    return ag_gemm(a, b, axis_name=_AXIS, n_dev=_TP,
                                   bm=blocks[0], bk=blocks[1], bn=blocks[2],
                                   reverse=reverse, activation="silu",
                                   bias=bias)

                cases.append(KernelCase(
                    label=f"ag_gemm[{tag}]@({gm}x{gk}x{gn})b({bm},{bk},{bn})",
                    build=build, kind="ag", n_dev=_TP, reverse=reverse,
                    slot_rows=gm))
            else:
                a = jnp.zeros((_TP * gm, gk), jnp.bfloat16)
                b = jnp.zeros((gk, gn), jnp.bfloat16)

                def build(a=a, b=b, blocks=(bm, bk, bn), reverse=reverse):
                    return gemm_rs(a, b, axis_name=_AXIS, n_dev=_TP,
                                   bm=blocks[0], bk=blocks[1], bn=blocks[2],
                                   reverse=reverse)

                cases.append(KernelCase(
                    label=f"gemm_rs[{tag}]@({gm}x{gk}x{gn})b({bm},{bk},{bn})",
                    build=build, kind="rs", n_dev=_TP, reverse=reverse,
                    slot_rows=gm))
    return cases


@register
def _attention_cases(config_names=None) -> List[KernelCase]:
    del config_names        # attention grids are config-shape independent
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.mla_decode import mla_decode_attention

    cases = []
    q = jnp.zeros((1, 4, 128, 32), jnp.bfloat16)
    kv = jnp.zeros((1, 2, 128, 32), jnp.bfloat16)
    cases.append(KernelCase(
        label="flash_attention[causal]@(b1,hq4,hkv2,s128,d32)bq32",
        build=lambda: flash_attention(q, kv, kv, causal=True, bq=32,
                                      bkv=32)))
    qc = jnp.zeros((1, 4, 64, 32), jnp.bfloat16)
    kc = jnp.zeros((1, 2, 128, 32), jnp.bfloat16)
    cases.append(KernelCase(
        label="flash_attention[chunk]@(sq64,skv128,off64)bq32",
        build=lambda: flash_attention(qc, kc, kc, causal=True, bq=32,
                                      bkv=32, kv_offset=64)))
    qe = jnp.zeros((2, 4, 32), jnp.bfloat16)
    qr = jnp.zeros((2, 4, 16), jnp.bfloat16)
    cc = jnp.zeros((2, 128, 32), jnp.bfloat16)
    kr = jnp.zeros((2, 128, 16), jnp.bfloat16)
    vl = jnp.full((2,), 128, jnp.int32)
    cases.append(KernelCase(
        label="mla_decode[absorbed]@(b2,h4,r32,s128)bs32",
        build=lambda: mla_decode_attention(qe, qr, cc, kr, vl, scale=1.0,
                                           bs=32)))
    return cases


def run_kernel_checks(config_names: Optional[Sequence[str]] = None,
                      log=None) -> List[str]:
    """The ``--kernels`` gate: every registered kernel x both ring
    directions x the config-derived shape cells."""
    errs: List[str] = []
    for builder in _REGISTRY:
        for case in builder(config_names):
            case_errs = check_case(case)
            if log:
                log(f"  {case.label}: "
                    + ("OK" if not case_errs else
                       f"{len(case_errs)} violation(s)"))
            errs.extend(case_errs)
    return errs
