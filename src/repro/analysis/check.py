"""``python -m repro.analysis.check`` — the repo's static-contract gate.

Three passes, all CPU-only and execution-free:

- ``--lint``     AST lint over src/ benchmarks/ examples/ tests/
                 (``repro.analysis.lint``) — seconds.
- ``--kernels``  Pallas kernel contracts (``repro.analysis.kernelcheck``):
                 abstract per-rank grid traces of every registered flux /
                 attention kernel x both ring directions x config-derived
                 shape cells — semaphore balance, DMA/slot race freedom,
                 ring arithmetic vs the overlap.py reference schedule,
                 exactly-once tile coverage, VMEM/SMEM tile budgets.
- ``--seams``    jaxpr-level seam contracts (``repro.analysis.seamcheck``):
                 abstract fwd+bwd / prefill / chunked-prefill / decode
                 traces for every config x both residual layouts,
                 collective census with ring provenance,
                 cotangent-completion matrix, layout coherence.

No flags runs all three (lint -> kernels -> seams).  ``--configs a b``
restricts the kernel and seam passes.  Exit status 0 = all contracts hold;
1 = violations (each printed as an actionable report line).
"""
from __future__ import annotations

import argparse
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.check",
        description="static kernel-, seam-contract + lint checker")
    ap.add_argument("--lint", action="store_true",
                    help="run only the AST lint pass")
    ap.add_argument("--kernels", action="store_true",
                    help="run only the Pallas kernel-contract pass")
    ap.add_argument("--seams", action="store_true",
                    help="run only the jaxpr seam-contract pass")
    ap.add_argument("--configs", nargs="*", default=None,
                    help="restrict the kernel/seam passes to these configs")
    ap.add_argument("--layouts", nargs="*", default=("seq", "hidden"),
                    choices=("seq", "hidden"))
    ap.add_argument("--mode", default="decomposed",
                    help="overlap mode for the traced PlanSet")
    ap.add_argument("--tp", type=int, default=4)
    ap.add_argument("-q", "--quiet", action="store_true")
    args = ap.parse_args(argv)

    explicit = args.lint or args.kernels or args.seams
    run_lint = args.lint or not explicit
    run_kernels = args.kernels or not explicit
    run_seams = args.seams or not explicit
    log = (lambda *_: None) if args.quiet else print
    failures = 0

    if run_lint:
        from repro.analysis import lint
        vs = lint.lint_tree()
        log(f"[lint] {len(vs)} violation(s) over {'/'.join(lint.LINT_SCOPE)}")
        for v in vs:
            print(f"  {v}")
        failures += len(vs)

    if run_kernels:
        from repro.analysis import kernelcheck
        log("[kernels] tracing Pallas grid programs (abstract, no devices)"
            "...")
        errs = kernelcheck.run_kernel_checks(config_names=args.configs,
                                             log=log)
        log(f"[kernels] {len(errs)} violation(s)")
        for e in errs:
            print(f"  {e}")
        failures += len(errs)

    if run_seams:
        from repro.analysis import seamcheck
        log("[seams] tracing configs (abstract, no devices)...")
        errs = seamcheck.run_seam_checks(
            config_names=args.configs, layouts=tuple(args.layouts),
            mode=args.mode, tp=args.tp, log=log)
        log(f"[seams] {len(errs)} violation(s)")
        for e in errs:
            print(f"  {e}")
        failures += len(errs)

    if failures:
        print(f"FAILED: {failures} static-contract violation(s)")
        return 1
    log("all static contracts hold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
