"""Seam-contract verifier: jaxpr-level invariants, checked abstractly.

The repo's TP/SP correctness story rests on three contracts that used to be
asserted only for the few mixer×layout combos individual tests happened to
trace.  This module turns them into machine-checked invariants over the
ABSTRACT trace (``jax.make_jaxpr`` with an ``axis_env`` — no devices, no
execution, runs on CPU CI) of every config's train/prefill/decode step:

1. **Collective census with ring provenance.**  Every collective transport
   ``repro.core.overlap`` emits is wrapped in a ``jax.named_scope`` whose
   name starts with ``overlap.SEAM_SCOPE_PREFIX`` ("seam").  The scope
   lands on the eqn's ``source_info.name_stack`` and survives jvp/transpose
   wrapping, scan bodies and custom_vjp backward rules — so any
   full-activation ``psum``/``all_gather``/``psum_scatter``/``ppermute``/
   ``all_to_all`` over the TP axis WITHOUT a seam scope is a standalone
   collective no seam owns: a census violation, reported with the eqn's
   shapes/provenance.

2. **Partial-cotangent completion.**  Under the repo's check_rep=False
   convention a replicated tensor's cotangent arrives as a per-rank
   PARTIAL; it must be completed by a psum exactly where a rank-exclusive
   operand consumes it (the PR 5 mamba x_proj bug class).  A dataflow taint
   walk over the vjp jaxpr verifies every ``dot_general`` contracting the
   cotangent sees a completed value (``expect_complete=True``) — or that NO
   spurious completing psum appears when the cotangent arrives full
   (``expect_complete=False``, the sequence-sharded seams, where a psum
   would double-count).

3. **Layout coherence.**  ``PlanSet.residual_layout()`` must resolve for
   the stamped layout; the sequence-sharded decomposed trace must contain
   ZERO standalone ``all_gather`` eqns (everything rides seam ppermute
   rings); the replicated-layout trace must contain ZERO ``ppermute`` eqns
   (no rings exist to ride); decode always runs the replicated layout (no
   ppermute, no reduce_scatter).

Shared walker: tests use :func:`collect_collectives` / :func:`count` so the
suite and the checker count collectives identically (the ad-hoc string
censuses this replaces disagreed on e.g. ``psum_scatter`` tracing as a
``reduce_scatter`` primitive).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.overlap import SEAM_SCOPE_PREFIX

# primitive names as they appear in traced jaxprs (``lax.psum_scatter``
# traces as a ``reduce_scatter`` eqn; ``pmean`` lowers to psum + div).
# ``all_to_all`` joined the census with the MoE EP exchange seam: a
# full-activation dispatch/combine without a seam scope is exactly the
# unattributed-transport class the census exists to catch.
CENSUS_PRIMS = ("psum", "all_gather", "reduce_scatter", "ppermute",
                "pmax", "pmin", "all_to_all")
ALL_COLLECTIVE_PRIMS = CENSUS_PRIMS


@dataclasses.dataclass(frozen=True)
class Collective:
    """One collective eqn found in a traced jaxpr."""
    prim: str
    axes: Tuple[str, ...]            # named mesh axes it communicates over
    shape: Tuple[int, ...]           # first array operand's shape
    dtype: str
    scope: str                       # str(eqn.source_info.name_stack)
    source: str                      # "file:line (fn)" best-effort
    trips: int = 1                   # scan trip-count multiplier

    @property
    def elems(self) -> int:
        n = 1
        for d in self.shape:
            n *= d
        return n

    @property
    def seam_tagged(self) -> bool:
        return SEAM_SCOPE_PREFIX in self.scope

    def describe(self) -> str:
        tag = self.scope if self.scope else "<no scope>"
        src = f" at {self.source}" if self.source else ""
        return (f"{self.prim} over {self.axes} shape={self.shape} "
                f"dtype={self.dtype} x{self.trips} [{tag}]{src}")


def _axes_of(eqn) -> Tuple[str, ...]:
    axes = (eqn.params.get("axes") or eqn.params.get("axis_name")
            or eqn.params.get("axis"))
    if axes is None:
        return ()
    if isinstance(axes, str):
        return (axes,)
    if not isinstance(axes, (tuple, list)):
        return ()
    return tuple(a for a in axes if isinstance(a, str))


def _source_of(eqn) -> str:
    try:
        from jax._src import source_info_util
        return source_info_util.summarize(eqn.source_info)
    except Exception:
        return ""


def _sub_jaxprs(eqn):
    """Every (Closed)Jaxpr hiding in an eqn's params (jaxpr_cost idiom)."""
    out = []
    for v in eqn.params.values():
        if hasattr(v, "eqns"):
            out.append(v)
        elif hasattr(v, "jaxpr"):
            out.append(v.jaxpr)
        elif isinstance(v, (tuple, list)):
            for b in v:
                if hasattr(b, "eqns"):
                    out.append(b)
                elif hasattr(b, "jaxpr"):
                    out.append(b.jaxpr)
    return out


def collect_collectives(jaxpr, _trips: int = 1) -> List[Collective]:
    """Recursively enumerate every collective eqn in a (Closed)Jaxpr —
    scan bodies annotated with their trip count, shard_map/pjit/custom_vjp
    sub-jaxprs walked through."""
    jaxpr = getattr(jaxpr, "jaxpr", jaxpr)
    out: List[Collective] = []
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim == "scan":
            sub = eqn.params["jaxpr"]
            out.extend(collect_collectives(
                sub, _trips * int(eqn.params["length"])))
            continue
        if prim in ALL_COLLECTIVE_PRIMS:
            aval = next((v.aval for v in eqn.invars
                         if hasattr(v, "aval") and hasattr(v.aval, "shape")),
                        None)
            shape = tuple(aval.shape) if aval is not None else ()
            dtype = str(aval.dtype) if aval is not None else "?"
            out.append(Collective(
                prim=prim, axes=_axes_of(eqn), shape=shape, dtype=dtype,
                scope=str(getattr(eqn.source_info, "name_stack", "")),
                source=_source_of(eqn), trips=_trips))
            continue
        for sub in _sub_jaxprs(eqn):
            out.extend(collect_collectives(sub, _trips))
    return out


def count(jaxpr, prim: str, weighted: bool = False) -> int:
    """Number of ``prim`` collective eqns in the trace (``weighted=True``
    multiplies scan bodies by their trip count)."""
    return sum((c.trips if weighted else 1)
               for c in collect_collectives(jaxpr) if c.prim == prim)


def collective_counts(jaxpr, weighted: bool = False) -> Dict[str, int]:
    out: Dict[str, int] = {}
    for c in collect_collectives(jaxpr):
        out[c.prim] = out.get(c.prim, 0) + (c.trips if weighted else 1)
    return out


# ---------------------------------------------------------------------------
# Contract 1: collective census with ring provenance
# ---------------------------------------------------------------------------
def census_errors(colls: Sequence[Collective], tp_axis: str = "model",
                  min_elems: int = 0) -> List[str]:
    """Every census collective over the TP axis at full-activation scale
    must carry a seam scope.  ``min_elems`` is the full-activation
    threshold (the residual shard's element count) — the tiny reductions
    (xent partition function, loss means, vocab-argmax candidates) ride
    under it by orders of magnitude."""
    errs = []
    for c in colls:
        if c.prim not in CENSUS_PRIMS:
            continue
        if tp_axis not in c.axes:
            continue                      # dp/pod traffic: not a TP seam
        if c.seam_tagged:
            continue
        if c.elems < min_elems:
            continue
        errs.append("unattributed full-activation collective (no seam "
                    f"scope): {c.describe()}")
    return errs


# ---------------------------------------------------------------------------
# Contract 2: partial-cotangent completion (dataflow taint walk)
# ---------------------------------------------------------------------------
def _taint_walk(jaxpr, tainted: set, completed: set, tp_axis: str,
                events: List[Tuple[str, object]]):
    """Propagate cotangent taint through one jaxpr's eqns (topological
    order).  ``tainted``/``completed`` are Var sets mutated in place;
    ``events`` collects ("raw_dot"|"psum", eqn) records."""
    jaxpr = getattr(jaxpr, "jaxpr", jaxpr)
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        in_vars = [v for v in eqn.invars if hasattr(v, "aval")
                   and not isinstance(v, jax.core.Literal)]
        t_in = [v for v in in_vars if v in tainted]
        if not t_in:
            # sub-jaxprs with no tainted inputs can still not introduce
            # taint (taint only enters via invars here)
            continue
        raw_in = [v for v in t_in if v not in completed]

        if prim == "psum" and tp_axis in _axes_of(eqn):
            events.append(("psum", eqn))
            for o in eqn.outvars:
                tainted.add(o)
                completed.add(o)
            continue
        if prim == "dot_general":
            if raw_in:
                events.append(("raw_dot", eqn))
            for o in eqn.outvars:
                tainted.add(o)
                if not raw_in:
                    completed.add(o)
            continue

        subs = _sub_jaxprs(eqn)
        if subs and prim not in ALL_COLLECTIVE_PRIMS:
            mapped = False
            for sub in subs:
                inner = getattr(sub, "jaxpr", sub)
                if len(inner.invars) == len(eqn.invars):
                    # 1:1 call convention (pjit/closed_call/custom_*/scan)
                    for ov, iv in zip(eqn.invars, inner.invars):
                        if hasattr(ov, "aval") and ov in tainted:
                            tainted.add(iv)
                            if ov in completed:
                                completed.add(iv)
                    _taint_walk(inner, tainted, completed, tp_axis, events)
                    if len(inner.outvars) == len(eqn.outvars):
                        for ov, iv in zip(eqn.outvars, inner.outvars):
                            iv = getattr(iv, "val", iv)
                            if iv in tainted:
                                tainted.add(ov)
                                if iv in completed:
                                    completed.add(ov)
                        mapped = True
            if mapped:
                continue
            # unmappable control flow: conservative propagation
            for o in eqn.outvars:
                tainted.add(o)
                if not raw_in:
                    completed.add(o)
            continue

        # default propagation: taint flows; completion survives only if
        # every tainted input was completed
        for o in eqn.outvars:
            tainted.add(o)
            if not raw_in:
                completed.add(o)


def check_cotangent_completion(fn, args: Sequence, ct, *,
                               tp_axis: str = "model",
                               axis_env: Sequence[Tuple[str, int]] = (
                                   ("model", 4),),
                               expect_complete: bool = True,
                               label: str = "") -> List[str]:
    """Trace ``vjp(fn)(ct)`` abstractly and verify the completion contract.

    ``expect_complete=True``: the output is REPLICATED, so its cotangent is
    a per-rank partial — every ``dot_general`` consuming it must be
    dominated by a ``psum`` over ``tp_axis`` (a raw contraction is the PR 5
    bug class).  ``expect_complete=False``: the output is rank-exclusive,
    the cotangent arrives full — any completing psum on its path would
    double-count and is reported instead.
    """
    def bwd(ct_, *args_):
        _, vjp = jax.vjp(fn, *args_)
        return vjp(ct_)

    closed = jax.make_jaxpr(bwd, axis_env=list(axis_env))(ct, *args)
    n_ct = len(jax.tree.leaves(ct))
    seeds = set(closed.jaxpr.invars[:n_ct])
    tainted, completed = set(seeds), set()
    events: List[Tuple[str, object]] = []
    _taint_walk(closed.jaxpr, tainted, completed, tp_axis, events)

    where = f" [{label}]" if label else ""
    errs = []
    dots = [e for k, e in events if k == "raw_dot"]
    psums = [e for k, e in events if k == "psum"]
    if expect_complete:
        for eqn in dots:
            errs.append(
                "raw (uncompleted) cotangent contraction — partial "
                f"cotangent consumed by dot_general without a dominating "
                f"psum over {tp_axis!r}{where}: {_source_of(eqn)}")
        if not dots and not psums and not any(
                k == "raw_dot" or k == "psum" for k, _ in events):
            # nothing on the cotangent path touched a dot or psum at all:
            # the trace did not exercise the backward as expected
            errs.append(f"cotangent check traced no contraction{where} — "
                        "backward not exercised")
    else:
        for eqn in psums:
            errs.append(
                "spurious cotangent completion — full (rank-exclusive) "
                f"cotangent psum'd over {tp_axis!r} (double-counts) "
                f"{where}: {_source_of(eqn)}")
    return errs


def fusedop_cotangent_errors(tp: int = 4, modes: Sequence[str] = (
        "decomposed", "xla"),
        wire_dtypes: Sequence[Optional[str]] = (None, "int8")) -> List[str]:
    """The completion matrix over every FusedOp (kind, layout): replicated
    outputs (ar, rs/hidden) must complete their cotangent; rank-exclusive
    outputs (seq seams, ag/hidden's partial dx, the a2a exchange's routed
    rows and local-expert weights) must not.  The matrix sweeps
    ``wire_dtypes`` too — quantization is forward-wire-only, so a
    quantized transport must keep the SAME completion contract as its fp
    twin (a wire that altered the cotangent path is exactly the bug this
    matrix exists to catch)."""
    from repro.core.overlap import Epilogue, FusedOp

    b, s, d, f = 2, 16, 16, 32
    sl = s // tp
    cases = [
        # (kind, scatter_axis, x_shape, w_shape, expect_complete)
        ("ag", "seq", (b, sl, d), (d, f), False),
        ("ag", "hidden", (b, s, d), (d, f), False),
        ("rs", "seq", (b, s, f // tp), (f // tp, d), False),
        ("rs", "hidden", (b, s, f // tp), (f // tp, d), True),
        ("ar", "hidden", (b, 1, f // tp), (f // tp, d), True),
    ]
    env = [("model", tp)]
    errs: List[str] = []
    for mode in modes:
        for wire in wire_dtypes:
            for kind, lay, xs, wshape, expect in cases:
                op = FusedOp(kind=kind, axis="model", mode=mode,
                             scatter_axis=lay, wire_dtype=wire)
                x = jax.ShapeDtypeStruct(xs, jnp.float32)
                w = jax.ShapeDtypeStruct(wshape, jnp.float32)

                def fn(x_, w_, op=op):
                    return op(x_, w_)

                ct_aval = jax.make_jaxpr(fn, axis_env=env)(x, w).out_avals[0]
                ct = jax.ShapeDtypeStruct(ct_aval.shape, ct_aval.dtype)
                errs.extend(check_cotangent_completion(
                    fn, (x, w), ct, tp_axis="model", axis_env=env,
                    expect_complete=expect,
                    label=(f"FusedOp kind={kind} layout={lay} mode={mode}"
                           f" wire={wire}")))
    # EP exchange op: dispatch a2a + batched expert SwiGLU + combine a2a in
    # one seam.  Its outputs are rank-exclusive on every path — dx is this
    # rank's own routed rows, and dw is the LOCAL experts' full gradient
    # (every EP peer's token contribution arrives through the backward
    # exchange, never through a completing psum) — so any psum over the TP
    # axis on the cotangent path double-counts.
    e_loc, cap = 2, 4
    for mode in modes:
        for wire in wire_dtypes:
            op = FusedOp(kind="a2a", axis=("model",), mode=mode,
                         epilogue=Epilogue(activation="silu", gate="pair"),
                         n_weights=3, wire_dtype=wire)
            x = jax.ShapeDtypeStruct((tp, e_loc, cap, d), jnp.float32)
            w1 = jax.ShapeDtypeStruct((e_loc, d, f), jnp.float32)
            w3 = jax.ShapeDtypeStruct((e_loc, d, f), jnp.float32)
            w2 = jax.ShapeDtypeStruct((e_loc, f, d), jnp.float32)

            def a2a_fn(x_, a_, b_, c_, op=op):
                return op(x_, a_, b_, c_)

            ct_aval = jax.make_jaxpr(a2a_fn, axis_env=env)(
                x, w1, w3, w2).out_avals[0]
            ct = jax.ShapeDtypeStruct(ct_aval.shape, ct_aval.dtype)
            errs.extend(check_cotangent_completion(
                a2a_fn, (x, w1, w3, w2), ct, tp_axis="model", axis_env=env,
                expect_complete=False,
                label=f"FusedOp kind=a2a mode={mode} wire={wire}"))
    return errs


# ---------------------------------------------------------------------------
# Abstract tracing harness (axis_env: no mesh, no devices, no execution)
# ---------------------------------------------------------------------------
def _local_sds(sds_tree, spec_tree, sizes: Dict[str, int]):
    """Per-device ShapeDtypeStructs from global shapes + PartitionSpecs."""
    def one(leaf, spec):
        shape = list(leaf.shape)
        for i, entry in enumerate(spec):
            if entry is None:
                continue
            for a in (entry if isinstance(entry, tuple) else (entry,)):
                if sizes.get(a, 1) and shape[i] % sizes[a] == 0:
                    shape[i] //= sizes[a]
        return jax.ShapeDtypeStruct(tuple(shape), leaf.dtype)

    from jax.sharding import PartitionSpec as P
    return jax.tree.map(one, sds_tree, spec_tree,
                        is_leaf=lambda x: isinstance(
                            x, (jax.ShapeDtypeStruct, P)))


def _batch_sds(cfg, b: int, s: int, tp: int, seq_sharded: bool):
    if getattr(cfg, "frontend", None):
        s_loc = s // tp if seq_sharded else s
        return {"embeds": jax.ShapeDtypeStruct((b, s_loc, cfg.d_model),
                                               jnp.bfloat16),
                "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    return {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
            "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}


def _ctx_for(cfg, par, plans):
    from repro.models import model as M
    from repro.parallel.sharding import TPContext
    return TPContext(axis="model", dp_axes=("data",),
                     ep_axes=M._ep_axes(cfg, par), plans=plans)


def _local_params(cfg, par, sizes):
    from repro.models import model as M
    key = jax.random.PRNGKey(0)
    params = jax.eval_shape(lambda: M.init_model(key, cfg, par))
    specs = M.param_specs(cfg, par, params)
    return _local_sds(params, specs, sizes)


def trace_train(cfg, par, plans, tp: int = 4, b: int = 2, s: int = 64):
    """Abstract fwd+bwd train-step jaxpr (value_and_grad of forward_loss)."""
    from repro.models import model as M
    sizes = {"data": 1, "model": tp}
    params_l = _local_params(cfg, par, sizes)
    seq_sharded = plans.residual_layout() == "seq"
    batch = _batch_sds(cfg, b, s, tp, seq_sharded)
    ctx = _ctx_for(cfg, par, plans)

    def step(p, bt):
        return jax.value_and_grad(
            lambda pp: M.forward_loss(pp, bt, ctx, cfg, par))(p)

    return jax.make_jaxpr(step, axis_env=[("data", 1), ("model", tp)])(
        params_l, batch)


def trace_prefill(cfg, par, plans, tp: int = 4, b: int = 2, s: int = 64):
    from repro.models import serve as S
    sizes = {"data": 1, "model": tp}
    params_l = _local_params(cfg, par, sizes)
    seq_sharded = plans.residual_layout() == "seq"
    batch = _batch_sds(cfg, b, s, tp, seq_sharded)
    batch.pop("labels")
    ctx = _ctx_for(cfg, par, plans)

    def step(p, bt):
        return S.prefill_step(p, bt, ctx, cfg, par)

    return jax.make_jaxpr(step, axis_env=[("data", 1), ("model", tp)])(
        params_l, batch)


def trace_decode(cfg, par, plans, tp: int = 4, b: int = 2, s_max: int = 64,
                 paged: bool = False):
    """``paged=True`` traces block-table decode (``decode_step`` with
    ``block_tables`` over ``paged_cache_specs`` pools) — same seam
    contract as dense decode: kind="ar" only, replicated layout."""
    from repro.models import serve as S
    sizes = {"data": 1, "model": tp}
    params_l = _local_params(cfg, par, sizes)
    if paged:
        bs = 8
        pages = s_max // bs
        csds, cspec = S.paged_cache_specs(cfg, par, b * pages + 1, bs, b)
        bt = jax.ShapeDtypeStruct((b, pages), jnp.int32)
    else:
        csds, cspec = S.cache_specs(cfg, par, b, s_max, ("data",))
        bt = None
    caches_l = _local_sds(csds, cspec, sizes)
    tokens = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((b,), jnp.int32)
    ctx = _ctx_for(cfg, par, plans)

    def step(p, c, t, po, bt_=None):
        return S.decode_step(p, c, t, po, ctx, cfg, par, block_tables=bt_)

    if paged:
        return jax.make_jaxpr(step, axis_env=[("data", 1), ("model", tp)])(
            params_l, caches_l, tokens, pos, bt)
    return jax.make_jaxpr(step, axis_env=[("data", 1), ("model", tp)])(
        params_l, caches_l, tokens, pos)


def trace_prefill_chunk(cfg, par, plans, tp: int = 4, b: int = 2,
                        s_max: int = 64, chunk: int = 16):
    """Chunked-prefill census lane: one fixed-shape ``[1, chunk]`` admission
    chunk through the block-table paged pools (``serve.prefill_chunk_step``
    with traced int32 slot/off/chunk_len scalars — the single jit program
    the serving runtime dispatches O(n/C) times per prompt).

    Chunked admission ALWAYS runs the replicated activation layout (like
    decode): a bounded C-row chunk has no sequence-parallel residency to
    win, so its collectives must be kind="ar" seams only — no ppermute
    rings, no sequence reduce_scatter."""
    from repro.models import serve as S
    sizes = {"data": 1, "model": tp}
    params_l = _local_params(cfg, par, sizes)
    bs = 8
    pages = s_max // bs
    csds, cspec = S.paged_cache_specs(cfg, par, b * pages + 1, bs, b)
    caches_l = _local_sds(csds, cspec, sizes)
    tokens = jax.ShapeDtypeStruct((1, chunk), jnp.int32)
    bt = jax.ShapeDtypeStruct((1, pages), jnp.int32)   # ONE slot's table row
    scal = jax.ShapeDtypeStruct((), jnp.int32)
    ctx = _ctx_for(cfg, par, plans)

    def step(p, c, t, bt_, slot, off, clen):
        return S.prefill_chunk_step(p, c, t, bt_, slot, off, clen,
                                    ctx, cfg, par)

    return jax.make_jaxpr(step, axis_env=[("data", 1), ("model", tp)])(
        params_l, caches_l, tokens, bt, scal, scal, scal)


# ---------------------------------------------------------------------------
# Contract 3: layout coherence
# ---------------------------------------------------------------------------
def layout_errors(train_colls: Sequence[Collective],
                  decode_colls: Optional[Sequence[Collective]],
                  layout: str, mode: str, min_elems: int = 0) -> List[str]:
    """Full-activation transport only — tiny cross-rank exchanges (the
    token-shift boundary, vocab-argmax candidates) are seam-tagged and
    orders of magnitude under ``min_elems``."""
    big = [c for c in train_colls if c.elems >= min_elems]
    errs = []
    # "seam_wire"-scoped hops are the quantized transports: a quantized
    # all-reduce is SPELLED as ppermute rings even under the replicated
    # layout (psum cannot carry the per-block scales), so the no-ring
    # layout rules exempt them — they remain seam-tagged and censused.
    wire_hop = lambda c: "seam_wire" in c.scope  # noqa: E731
    if layout == "hidden":
        pp = [c for c in big if c.prim == "ppermute" and not wire_hop(c)]
        for c in pp:
            errs.append("replicated layout must not ride ppermute rings "
                        f"(nothing is sequence-sharded): {c.describe()}")
    if layout == "seq" and mode.startswith("decomposed"):
        ag = [c for c in big if c.prim == "all_gather"]
        for c in ag:
            errs.append("sequence-sharded decomposed trace contains a "
                        "standalone all_gather (must ride a seam ppermute "
                        f"ring): {c.describe()}")
        rep = [c for c in train_colls
               if "seam_replicated_sum" in c.scope
               or "seam_embed_ar" in c.scope]
        for c in rep:
            errs.append("replicated-combine collective under the "
                        f"sequence-sharded layout: {c.describe()}")
    if decode_colls is not None:
        for c in decode_colls:
            if c.prim == "ppermute" and not wire_hop(c):
                errs.append("decode must run the replicated layout — no "
                            f"ppermute belongs in it: {c.describe()}")
            if c.prim == "reduce_scatter":
                errs.append("decode must not sequence-scatter (one-token "
                            f"activations stay replicated): {c.describe()}")
    return errs


# ---------------------------------------------------------------------------
# Top-level: every config x both layouts
# ---------------------------------------------------------------------------
def discover_configs() -> List[str]:
    """Every module in src/repro/configs/ that defines ``CONFIG``."""
    import importlib
    import pkgutil

    from repro import configs as cpkg
    names = []
    for info in pkgutil.iter_modules(cpkg.__path__):
        mod = importlib.import_module(f"repro.configs.{info.name}")
        if hasattr(mod, "CONFIG"):
            names.append(info.name)
    return sorted(names)


def check_config(name: str, layout: str, mode: str = "decomposed",
                 tp: int = 4, b: int = 2, s: int = 64,
                 wire_dtype: Optional[str] = None,
                 log=None) -> List[str]:
    """All three contract families for one config x layout (smoke shapes —
    the invariants are structural, not size-dependent).  ``wire_dtype``
    stamps a quantized wire onto every plan: the census then runs over the
    quantized transports, which must stay seam-tagged and layout-correct
    exactly like their fp twins."""
    import dataclasses as _dc

    from repro.configs.base import ParallelConfig, get_smoke_config
    from repro.tuning.plans import PlanSet

    cfg = get_smoke_config(name)
    par = ParallelConfig(tp=tp, dp=1, overlap_mode=mode, scatter_axis=layout,
                         wire_dtype=wire_dtype)
    plans = PlanSet.uniform(mode).with_scatter_axis(layout)
    if wire_dtype is not None:
        plans = plans.with_wire_dtype(wire_dtype)
    errs: List[str] = []
    try:
        resolved = plans.residual_layout()
    except ValueError as e:
        return [f"{name}/{layout}: incoherent PlanSet layout: {e}"]
    if resolved != layout:
        errs.append(f"{name}/{layout}: residual_layout() resolved "
                    f"{resolved!r}")

    s_loc = s // tp
    threshold = b * s_loc * cfg.d_model      # the residual shard
    prefix = f"{name}/{layout}"
    if wire_dtype is not None:
        prefix += f"/wire-{wire_dtype}"

    train = trace_train(cfg, par, plans, tp=tp, b=b, s=s)
    tc = collect_collectives(train)
    errs += [f"{prefix}/train: {e}"
             for e in census_errors(tc, "model", threshold)]

    prefill = trace_prefill(cfg, par, plans, tp=tp, b=b, s=s)
    pc = collect_collectives(prefill)
    errs += [f"{prefix}/prefill: {e}"
             for e in census_errors(pc, "model", threshold)]

    dc = None
    if layout == "hidden":
        # decode ALWAYS forces the replicated layout — trace it once, on
        # the hidden pass (the layout knob cannot change its jaxpr).
        # Both lanes: dense per-slot caches AND block-table paged pools
        # (the serving runtime runs the paged lane exclusively).
        par_d = _dc.replace(par, scatter_axis="hidden")
        decode = trace_decode(cfg, par_d, plans, tp=tp, b=b, s_max=s)
        dc = collect_collectives(decode)
        errs += [f"{prefix}/decode: {e}"
                 for e in census_errors(dc, "model", threshold)]
        paged = trace_decode(cfg, par_d, plans, tp=tp, b=b, s_max=s,
                             paged=True)
        pgc = collect_collectives(paged)
        errs += [f"{prefix}/decode-paged: {e}"
                 for e in census_errors(pgc, "model", threshold)]
        # chunked-prefill admission rides the SAME replicated-layout
        # contract as decode: census over one [1, chunk] chunk dispatch
        # (threshold = the full chunk activation), then the decode-side
        # layout rules (no ppermute, no sequence reduce_scatter)
        chunk = max(s // 4, 1)
        ckc = collect_collectives(trace_prefill_chunk(
            cfg, par_d, plans, tp=tp, b=b, s_max=s, chunk=chunk))
        errs += [f"{prefix}/prefill-chunk: {e}"
                 for e in census_errors(ckc, "model",
                                        chunk * cfg.d_model)]
        dc = list(dc) + list(pgc) + list(ckc)

    errs += [f"{prefix}: {e}"
             for e in layout_errors(tc, dc, layout, mode, threshold)]
    errs += [f"{prefix}/prefill: {e}"
             for e in layout_errors(pc, None, layout, mode, threshold)]
    if log:
        log(f"  {prefix}: {len(tc)} train / {len(pc)} prefill"
            + (f" / {len(dc)} decode" if dc is not None else "")
            + " collectives — "
            + ("OK" if not errs else f"{len(errs)} violation(s)"))
    return errs


def run_seam_checks(config_names: Optional[Sequence[str]] = None,
                    layouts: Sequence[str] = ("seq", "hidden"),
                    mode: str = "decomposed", tp: int = 4,
                    log=None) -> List[str]:
    """The full seam-contract pass: every config x every layout, plus the
    FusedOp cotangent-completion matrix (config-independent)."""
    names = list(config_names) if config_names else discover_configs()
    errs: List[str] = []
    for name in names:
        for layout in layouts:
            try:
                errs.extend(check_config(name, layout, mode=mode, tp=tp,
                                         log=log))
            except Exception as e:       # a config that cannot trace IS
                errs.append(             # a finding, not a crash
                    f"{name}/{layout}: trace failed: "
                    f"{type(e).__name__}: {e}")
    # quantized-wire census spot-check: one representative config, BOTH
    # layouts, int8 wire — the quantized transports must stay seam-tagged
    # and layout-correct (structural contracts are wire-invariant, so one
    # config suffices; the full matrix above stays fp)
    for layout in layouts:
        try:
            errs.extend(check_config(names[0], layout, mode=mode, tp=tp,
                                     wire_dtype="int8", log=log))
        except Exception as e:
            errs.append(f"{names[0]}/{layout}/wire-int8: trace failed: "
                        f"{type(e).__name__}: {e}")
    cot = fusedop_cotangent_errors(tp=tp)
    if log:
        log(f"  cotangent-completion matrix: "
            + ("OK" if not cot else f"{len(cot)} violation(s)"))
    errs.extend(cot)
    return errs
