"""Roofline report generator: reads experiments/dryrun/*.json and emits the
EXPERIMENTS.md §Dry-run / §Roofline tables.

``--static`` instead runs the three static-contract passes (AST lint,
Pallas kernel contracts, jaxpr seam contracts) and emits the one-table
summary — kernelcheck results alongside lint/seamcheck."""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

GB = 2 ** 30


def load_cells(dirpath: str) -> List[Dict]:
    cells = []
    for f in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        with open(f) as fh:
            cells.append(json.load(fh))
    return cells


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def roofline_rows(cells: List[Dict], mesh: str = "pod16x16",
                  mode: str = "decomposed", opt: str = "") -> List[str]:
    rows = []
    for c in cells:
        if (c.get("mesh") != mesh
                or c.get("overlap_mode", "decomposed") != mode
                or c.get("opt", "") != opt):
            continue
        if "skipped" in c:
            rows.append(f"| {c['arch']} | {c['shape']} | — | — | — | — | "
                        f"skip | — | (sub-quadratic only) |")
            continue
        a = c.get("analyzer")
        if not a:
            continue
        dom = a["dominant"]
        terms = {"compute": a["compute_term_s"], "memory": a["memory_term_s"],
                 "collective": a["collective_term_s"]}
        bound = max(terms.values())
        frac = terms["compute"] / bound if bound else 0.0
        rows.append(
            f"| {c['arch']} | {c['shape']} | {fmt_s(a['compute_term_s'])} | "
            f"{fmt_s(a['memory_term_s'])} | {fmt_s(a['collective_term_s'])} | "
            f"{c['useful_ratio']:.2f} | **{dom}** | {frac:.2f} | "
            f"{c['memory_analysis']['temp_bytes']/GB:.1f} GB |")
    return rows


def summary(cells: List[Dict]) -> Dict:
    ok = [c for c in cells if "error" not in c and "skipped" not in c
          and "analyzer" in c]
    skips = [c for c in cells if "skipped" in c]
    doms: Dict[str, int] = {}
    for c in ok:
        d = c["analyzer"]["dominant"]
        doms[d] = doms.get(d, 0) + 1
    return {"ok": len(ok), "skipped": len(skips), "dominant": doms}


def static_contracts_summary(config_names=None) -> Dict:
    """Run all three static passes and return per-pass scope + counts.

    The kernel pass is the new first-class citizen: every registered
    Pallas kernel x both ring directions x config-derived shape cells,
    checked on abstract per-rank grid traces (semaphore balance, DMA/slot
    races, ring arithmetic, tile coverage, VMEM/SMEM budgets)."""
    from repro.analysis import kernelcheck, lint, seamcheck
    lint_vs = lint.lint_tree()
    cases = [c for b in kernelcheck._REGISTRY for c in b(config_names)]
    kern_errs: List[str] = []
    for c in cases:
        kern_errs.extend(kernelcheck.check_case(c))
    seam_errs = seamcheck.run_seam_checks(config_names=config_names)
    n_cfg = len(config_names if config_names
                else seamcheck.discover_configs())
    return {
        "lint": {"scope": f"{'/'.join(lint.LINT_SCOPE)} "
                          f"({len(lint.RULES)} rules)",
                 "violations": [str(v) for v in lint_vs]},
        "kernels": {"scope": f"{len(cases)} kernel cases "
                             "(kernels x ring dirs x shape cells)",
                    "violations": kern_errs},
        "seams": {"scope": f"{n_cfg} configs x seq/hidden layouts",
                  "violations": seam_errs},
    }


def static_rows(summary: Dict) -> List[str]:
    return [f"| {name} | {s['scope']} | {len(s['violations'])} |"
            for name, s in summary.items()]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="pod16x16")
    ap.add_argument("--static", action="store_true",
                    help="summarize the static-contract passes "
                         "(lint / kernels / seams) instead of the roofline")
    ap.add_argument("--configs", nargs="*", default=None,
                    help="restrict the kernel/seam passes (with --static)")
    args = ap.parse_args()
    if args.static:
        s = static_contracts_summary(args.configs)
        print("| pass | scope | violations |")
        print("|---|---|---|")
        for r in static_rows(s):
            print(r)
        for name, sec in s.items():
            for e in sec["violations"]:
                print(f"  [{name}] {e}")
        return
    cells = load_cells(args.dir)
    print("| arch | shape | compute | memory | collective | useful | "
          "dominant | comp/roof | XLA temp/dev |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in roofline_rows(cells, args.mesh):
        print(r)
    print()
    print(summary(cells))


if __name__ == "__main__":
    main()
