"""Roofline report generator: reads experiments/dryrun/*.json and emits the
EXPERIMENTS.md §Dry-run / §Roofline tables."""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

GB = 2 ** 30


def load_cells(dirpath: str) -> List[Dict]:
    cells = []
    for f in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        with open(f) as fh:
            cells.append(json.load(fh))
    return cells


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def roofline_rows(cells: List[Dict], mesh: str = "pod16x16",
                  mode: str = "decomposed", opt: str = "") -> List[str]:
    rows = []
    for c in cells:
        if (c.get("mesh") != mesh
                or c.get("overlap_mode", "decomposed") != mode
                or c.get("opt", "") != opt):
            continue
        if "skipped" in c:
            rows.append(f"| {c['arch']} | {c['shape']} | — | — | — | — | "
                        f"skip | — | (sub-quadratic only) |")
            continue
        a = c.get("analyzer")
        if not a:
            continue
        dom = a["dominant"]
        terms = {"compute": a["compute_term_s"], "memory": a["memory_term_s"],
                 "collective": a["collective_term_s"]}
        bound = max(terms.values())
        frac = terms["compute"] / bound if bound else 0.0
        rows.append(
            f"| {c['arch']} | {c['shape']} | {fmt_s(a['compute_term_s'])} | "
            f"{fmt_s(a['memory_term_s'])} | {fmt_s(a['collective_term_s'])} | "
            f"{c['useful_ratio']:.2f} | **{dom}** | {frac:.2f} | "
            f"{c['memory_analysis']['temp_bytes']/GB:.1f} GB |")
    return rows


def summary(cells: List[Dict]) -> Dict:
    ok = [c for c in cells if "error" not in c and "skipped" not in c
          and "analyzer" in c]
    skips = [c for c in cells if "skipped" in c]
    doms: Dict[str, int] = {}
    for c in ok:
        d = c["analyzer"]["dominant"]
        doms[d] = doms.get(d, 0) + 1
    return {"ok": len(ok), "skipped": len(skips), "dominant": doms}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="pod16x16")
    args = ap.parse_args()
    cells = load_cells(args.dir)
    print("| arch | shape | compute | memory | collective | useful | "
          "dominant | comp/roof | XLA temp/dev |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in roofline_rows(cells, args.mesh):
        print(r)
    print()
    print(summary(cells))


if __name__ == "__main__":
    main()
