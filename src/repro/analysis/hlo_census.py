"""Collective-op census over optimized HLO text (dry-run cross-check)."""
from __future__ import annotations

import re
from typing import Dict

# Async collectives appear as a -start/-done pair naming ONE transfer; the
# old pattern's optional suffix let "all-gather-done" fall through to a bare
# "all-gather" match, double-counting every async collective.  Capture the
# suffix and count only the -start (or the bare synchronous form).
_COLL_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\b")


def hlo_collective_counts(hlo_text: str) -> Dict[str, int]:
    counts: Dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        if m.group(2) == "-done":
            continue
        k = m.group(1)
        counts[k] = counts.get(k, 0) + 1
    return counts
