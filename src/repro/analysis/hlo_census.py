"""Collective-op census over optimized HLO text (dry-run cross-check)."""
from __future__ import annotations

import re
from typing import Dict

_COLL_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\b")


def hlo_collective_counts(hlo_text: str) -> Dict[str, int]:
    counts: Dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        k = m.group(1)
        counts[k] = counts.get(k, 0) + 1
    return counts
