"""AST-based repo lint: the standing source rules as machine checks.

Replaces the three ``grep -E`` gates that used to live in
``scripts/verify.sh`` (compat-import, private-backend, removed-wrapper)
and adds two rules greps could not express without false positives:

- ``compat-import``     backend-version-dependent JAX APIs (shard_map,
                        CompilerParams, pallas tpu import, lax.axis_size)
                        must route through ``repro.compat``.
- ``private-backend``   ``repro.core.overlap``'s underscore backends are an
                        implementation detail; call ``FusedOp`` / the
                        ``*_ref`` oracles.
- ``removed-wrapper``   the pre-FusedOp wrappers (``ag_matmul``,
                        ``matmul_rs``, ``matmul_ar``) no longer exist —
                        the AST sees CALLS, so the ``*_ref`` oracles and
                        string literals in subprocess-driving tests no
                        longer trip it (both were grep escapes).
- ``raw-collective``    raw ``lax.ppermute`` / ``lax.all_gather`` /
                        ``lax.all_to_all`` / ``lax.psum_scatter`` calls
                        belong to the seam layer (``core/overlap.py``,
                        ``parallel/sharding.py``); anywhere else they are
                        invisible to the seam census.  (all_to_all and
                        psum_scatter were blind spots until the MoE a2a
                        seam landed — exactly the transports the EP
                        exchange and the ZeRO-1 reduce use.)
- ``bare-shard-map``    ``shard_map`` obtained from ``jax`` directly
                        instead of ``repro.compat`` (signature moved
                        across jax versions).
- ``deprecated-q8-mode`` the legacy ``*_q8`` mode spellings ("xla_q8",
                        "decomposed_q8") are a compatibility shim — spell
                        the wire as ``wire_dtype="int8"`` on the base mode
                        instead.  Docstring constants are exempt (prose may
                        document the deprecation); ``core/overlap.py`` owns
                        the shim itself.
- ``stale-allow``       a ``# lint: allow(<rule>)`` escape that suppresses
                        NOTHING (the violation moved or was fixed, or the
                        rule name is unknown).  Stale escapes rot silently
                        as code moves and then mask real violations later;
                        each one is reported at its comment line.

Per-line escape: ``# lint: allow(<rule>)`` on the offending line or the
line directly above it.  Escapes are extracted from real COMMENT tokens
(``tokenize``), so escape-shaped text inside string literals — docstrings,
subprocess source in tests — neither suppresses a finding nor counts as a
stale escape.
"""
from __future__ import annotations

import ast
import dataclasses
import io
import re
import tokenize
from pathlib import Path
from typing import List, Optional, Sequence, Set, Tuple

RULES = ("compat-import", "private-backend", "removed-wrapper",
         "raw-collective", "bare-shard-map", "deprecated-q8-mode",
         "stale-allow")

LINT_SCOPE = ("src", "benchmarks", "examples", "tests")

# files exempt per rule (relative path substrings)
_ALLOWED = {
    "compat-import": ("src/repro/compat/",),
    "private-backend": ("src/repro/core/overlap.py",),
    "removed-wrapper": (),
    "raw-collective": ("src/repro/core/overlap.py",
                       "src/repro/parallel/sharding.py"),
    "bare-shard-map": ("src/repro/compat/",),
    "deprecated-q8-mode": ("src/repro/core/overlap.py",),
    "stale-allow": (),
}

_PRIVATE_BACKENDS = {
    "_ag_ring", "_ag_bidir", "_rs_ring", "_rs_bidir", "_rs_core",
    "_ar_core", "_ar_ring_quant", "_fused_impl", "_fused_ag", "_fused_bwd",
    "_gather_full", "_ring_gather", "_q8_encode", "_q8_decode",
    "_wire_hop", "_int4_pack", "_int4_unpack",
}
# built without spelling the deprecated suffix as one literal (this file
# lints itself)
_Q8_SUFFIX = "_q" + "8"
_Q8_BASES = ("xla", "decomposed")
_PRIVATE_BACKEND_RE = re.compile(
    r"^_(ag_matmul|matmul_ar|matmul_rs)_(xla|decomposed|bidir|flux|impl)")
_REMOVED_WRAPPERS = {"ag_matmul", "matmul_rs", "matmul_ar"}
_RAW_COLLECTIVES = {"ppermute", "all_gather", "all_to_all", "psum_scatter"}
_COMPILER_PARAMS = {"TPUCompilerParams", "CompilerParams"}
_ESCAPE_RE = re.compile(r"#\s*lint:\s*allow\(([a-z0-9-]+(?:\s*,\s*[a-z0-9-]+)*)\)")


@dataclasses.dataclass(frozen=True)
class Violation:
    path: str
    line: int
    rule: str
    message: str

    def __str__(self):
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


def _is_private_backend(name: str) -> bool:
    return name in _PRIVATE_BACKENDS or bool(_PRIVATE_BACKEND_RE.match(name))


def _escape_comments(source: str) -> List[Tuple[int, Set[str]]]:
    """One ``(line, {rules})`` entry per ACTUAL escape comment.

    Extracted from ``tokenize`` COMMENT tokens so escape-shaped text inside
    string literals (docstrings, subprocess source embedded in tests) is
    invisible — it neither suppresses a finding nor shows up as a stale
    escape.  Unparseable sources fall back to the line regex (the AST pass
    reports them separately anyway)."""
    entries: List[Tuple[int, Set[str]]] = []
    try:
        for tok in tokenize.generate_tokens(io.StringIO(source).readline):
            if tok.type == tokenize.COMMENT:
                m = _ESCAPE_RE.search(tok.string)
                if m:
                    entries.append((tok.start[0],
                                    {r.strip()
                                     for r in m.group(1).split(",")}))
    except (tokenize.TokenError, IndentationError, SyntaxError):
        for i, text in enumerate(source.splitlines(), start=1):
            m = _ESCAPE_RE.search(text)
            if m:
                entries.append((i, {r.strip()
                                    for r in m.group(1).split(",")}))
    return entries


def _escapes(source: str):
    """line -> set of escaped rules (an escape covers its line AND the
    next one, so it can sit above a long call)."""
    out: dict = {}
    for i, rules in _escape_comments(source):
        out.setdefault(i, set()).update(rules)
        out.setdefault(i + 1, set()).update(rules)
    return out


class _Visitor(ast.NodeVisitor):
    def __init__(self, relpath: str):
        self.relpath = relpath
        self.found: List[Violation] = []
        self._doc_nodes: Set[int] = set()

    def _hit(self, node, rule: str, message: str):
        if any(a in self.relpath for a in _ALLOWED.get(rule, ())):
            return
        self.found.append(Violation(self.relpath, node.lineno, rule, message))

    # ---- docstrings (exempt from the constant rules) ----------------------
    def _mark_docstring(self, node):
        body = getattr(node, "body", [])
        if (body and isinstance(body[0], ast.Expr)
                and isinstance(body[0].value, ast.Constant)
                and isinstance(body[0].value.value, str)):
            self._doc_nodes.add(id(body[0].value))

    def visit_Module(self, node):
        self._mark_docstring(node)
        self.generic_visit(node)

    def visit_FunctionDef(self, node):
        self._mark_docstring(node)
        self.generic_visit(node)

    def visit_AsyncFunctionDef(self, node):
        self._mark_docstring(node)
        self.generic_visit(node)

    def visit_ClassDef(self, node):
        self._mark_docstring(node)
        self.generic_visit(node)

    # ---- imports ----------------------------------------------------------
    def visit_Import(self, node):
        for alias in node.names:
            if alias.name.startswith("jax.experimental.shard_map"):
                self._hit(node, "compat-import",
                          "import jax.experimental.shard_map — use "
                          "repro.compat.shard_map")
        self.generic_visit(node)

    def visit_ImportFrom(self, node):
        mod = node.module or ""
        names = {a.name for a in node.names}
        if mod == "jax.experimental.shard_map" or (
                mod == "jax" and "shard_map" in names):
            rule = ("bare-shard-map" if mod == "jax"
                    else "compat-import")
            self._hit(node, rule,
                      f"shard_map imported from {mod!r} — use "
                      "repro.compat.shard_map")
        if mod.startswith("jax.experimental.pallas") and "tpu" in names:
            self._hit(node, "compat-import",
                      "pallas tpu backend import — use repro.compat.pltpu")
        if names & _COMPILER_PARAMS:
            self._hit(node, "compat-import",
                      "CompilerParams import — use "
                      "repro.compat.compiler_params")
        if mod == "repro.core.overlap" or mod.endswith(".core.overlap"):
            for a in node.names:
                if _is_private_backend(a.name):
                    self._hit(node, "private-backend",
                              f"import of private backend {a.name!r} from "
                              "repro.core.overlap")
        self.generic_visit(node)

    # ---- attributes -------------------------------------------------------
    def visit_Attribute(self, node):
        base = node.value
        base_name = base.id if isinstance(base, ast.Name) else (
            base.attr if isinstance(base, ast.Attribute) else None)
        if node.attr == "shard_map" and base_name == "jax":
            self._hit(node, "bare-shard-map",
                      "jax.shard_map — use repro.compat.shard_map")
        if node.attr in _COMPILER_PARAMS:
            self._hit(node, "compat-import",
                      f"{node.attr} attribute — use "
                      "repro.compat.compiler_params")
        if node.attr == "axis_size" and base_name == "lax":
            self._hit(node, "compat-import",
                      "lax.axis_size — use repro.compat.axis_size")
        if base_name == "overlap" and _is_private_backend(node.attr):
            self._hit(node, "private-backend",
                      f"overlap.{node.attr} — private backend; go through "
                      "FusedOp")
        self.generic_visit(node)

    # ---- calls ------------------------------------------------------------
    def visit_Call(self, node):
        fn = node.func
        name = None
        base_name = None
        if isinstance(fn, ast.Name):
            name = fn.id
        elif isinstance(fn, ast.Attribute):
            name = fn.attr
            b = fn.value
            base_name = b.id if isinstance(b, ast.Name) else (
                b.attr if isinstance(b, ast.Attribute) else None)
        if name in _REMOVED_WRAPPERS:
            self._hit(node, "removed-wrapper",
                      f"call to removed wrapper {name!r} — use "
                      "overlap.FusedOp (or the *_ref oracle)")
        if name in _RAW_COLLECTIVES and base_name in ("lax", "jax"):
            self._hit(node, "raw-collective",
                      f"raw {base_name}.{name} outside the seam layer — "
                      "route through core/overlap.py or "
                      "parallel/sharding.py (or tag + escape)")
        self.generic_visit(node)

    # ---- constants --------------------------------------------------------
    def visit_Constant(self, node):
        v = node.value
        if (isinstance(v, str) and v.endswith(_Q8_SUFFIX)
                and v[:-len(_Q8_SUFFIX)] in _Q8_BASES
                and id(node) not in self._doc_nodes):
            base = v[:-len(_Q8_SUFFIX)]
            self._hit(node, "deprecated-q8-mode",
                      f"deprecated mode spelling {v!r} — use "
                      f"mode={base!r} with wire_dtype='int8'")
        self.generic_visit(node)


def _stale_escape_violations(relpath: str, source: str,
                             raw: List[Violation]) -> List[Violation]:
    """``stale-allow``: escape comments that suppress nothing.

    An escape rule at comment line ``i`` is USED iff some raw finding of
    that rule sits on line ``i`` or ``i+1`` (the escape's coverage
    window).  Unknown rule names are always stale — they can never
    suppress anything.  ``stale-allow`` itself is exempt from the
    staleness check (it exists only to suppress findings OF this rule,
    which are emitted at the comment line and filtered by the normal
    escape pass)."""
    hit_lines = {(f.line, f.rule) for f in raw}
    out: List[Violation] = []
    for line, rules in _escape_comments(source):
        for rule in sorted(rules):
            if rule == "stale-allow":
                continue
            if rule not in RULES:
                out.append(Violation(
                    relpath, line, "stale-allow",
                    f"# lint: allow({rule}) names an unknown rule — "
                    f"known rules: {', '.join(RULES)}"))
            elif not ((line, rule) in hit_lines
                      or (line + 1, rule) in hit_lines):
                out.append(Violation(
                    relpath, line, "stale-allow",
                    f"# lint: allow({rule}) suppresses no {rule} "
                    "violation — stale escape; remove it"))
    return out


def lint_source(source: str, relpath: str) -> List[Violation]:
    try:
        tree = ast.parse(source, filename=relpath)
    except SyntaxError as e:
        return [Violation(relpath, e.lineno or 0, "compat-import",
                          f"unparseable: {e.msg}")]
    v = _Visitor(relpath)
    v.visit(tree)
    esc = _escapes(source)
    found = v.found + _stale_escape_violations(relpath, source, v.found)
    return [f for f in found if f.rule not in esc.get(f.line, ())]


def lint_file(path: Path, root: Path) -> List[Violation]:
    rel = str(path.relative_to(root))
    return lint_source(path.read_text(), rel)


def lint_tree(root: Optional[Path] = None,
              scope: Sequence[str] = LINT_SCOPE) -> List[Violation]:
    root = Path(root) if root else _repo_root()
    out: List[Violation] = []
    for top in scope:
        base = root / top
        if not base.is_dir():
            continue
        for path in sorted(base.rglob("*.py")):
            out.extend(lint_file(path, root))
    return out


def _repo_root() -> Path:
    # src/repro/analysis/lint.py -> repo root
    return Path(__file__).resolve().parents[3]
