"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch codeqwen15_7b \
      --smoke --steps 20          # reduced config, CPU
  python -m repro.launch.train --arch qwen15_110b --tp 16 --dp 16 \
      --steps 1000 --mode flux    # production mesh (TPU pod)
"""
import argparse
import dataclasses
import logging

import jax
import numpy as np
from jax.sharding import Mesh

from repro.configs.base import ParallelConfig, get_config, get_smoke_config
from repro.launch.mesh import make_mesh
from repro.optim.adamw import AdamWConfig
from repro.runtime import trainer as T


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--dp", type=int, default=1)
    ap.add_argument("--pods", type=int, default=1)
    ap.add_argument("--ep", type=int, default=0,
                    help="dedicated expert-parallel mesh axis size (0 = no "
                         "'ep' axis; EP implied over 'model' or, for big "
                         "expert counts, ('data','model'))")
    from repro.core.overlap import VALID_MODES
    ap.add_argument("--mode", default="decomposed", choices=list(VALID_MODES))
    ap.add_argument("--comm-chunks", type=int, default=0,
                    help="ring sub-chunking (0 = auto)")
    ap.add_argument("--wire-dtype", default=None,
                    choices=["int8", "fp8_e4m3", "int4"],
                    help="forward-wire precision for the TP seams (lossy "
                         "on the forward value only; cotangents always "
                         "ride the full-precision transports)")
    ap.add_argument("--max-logit-rmse", type=float, default=None,
                    help="error budget for the --autotune wire_dtype "
                         "sweep: a quantized wire may only win a seam "
                         "when its estimated logit deviation fits")
    ap.add_argument("--plan-profile", default=None,
                    help="tuned per-seam profile JSON (repro.tuning)")
    ap.add_argument("--scatter-axis", default="auto",
                    choices=["auto", "seq", "hidden"],
                    help="residual-stream activation layout between TP "
                         "seams: seq = sequence-sharded (Megatron-SP, "
                         "~1/tp activation residency), hidden = "
                         "replicated; auto = tuned profile / default")
    ap.add_argument("--autotune", action="store_true",
                    help="tune every seam before training and save the "
                         "profile to experiments/plans/ (measured on real "
                         "devices, roofline fallback otherwise)")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--schedule", default=None,
                    help="cosine|wsd (default: per-arch)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--zero3", action="store_true")
    ap.add_argument("--grad-compress", action="store_true")
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO)
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    par = ParallelConfig(tp=args.tp, dp=args.dp, pods=args.pods,
                         ep=args.ep,
                         overlap_mode=args.mode, zero3=args.zero3,
                         wire_dtype=args.wire_dtype,
                         max_logit_rmse=args.max_logit_rmse,
                         comm_chunks=args.comm_chunks,
                         plan_profile=args.plan_profile,
                         scatter_axis=args.scatter_axis,
                         grad_compress=args.grad_compress,
                         ep_over_dp=(args.ep <= 1
                                     and cfg.moe is not None
                                     and cfg.moe.num_experts > 16),
                         fuse_w13=True)
    if args.autotune and args.tp > 1:
        import os
        from repro.tuning import (WIRE_DTYPE_SWEEP, PlanRegistry,
                                  autotune_model, default_plans_dir)
        path = args.plan_profile or os.path.join(
            default_plans_dir(), f"{args.arch}_tp{args.tp}.json")
        reg = PlanRegistry.open(path, n_dev=args.tp)
        # a budget opts the sweep into quantized wires; a pinned
        # --wire-dtype restricts it to (fp, that wire)
        wire_sweep = None
        if args.wire_dtype:
            wire_sweep = (None, args.wire_dtype)
        elif args.max_logit_rmse is not None:
            wire_sweep = WIRE_DTYPE_SWEEP
        autotune_model(cfg, par, tokens_per_dp=args.batch * args.seq // args.dp,
                       registry=reg, save_path=path,
                       wire_dtypes=wire_sweep,
                       max_logit_rmse=args.max_logit_rmse)
        par = dataclasses.replace(par, plan_profile=path)
        logging.info("autotuned seam plans -> %s", path)
    mesh = make_mesh(args.pods, args.dp, args.tp, ep=max(args.ep, 1))

    schedule = args.schedule or (
        "wsd" if args.arch.startswith("minicpm") else "cosine")
    tc = T.TrainConfig(total_steps=args.steps, warmup_steps=args.steps // 10,
                       base_lr=args.lr, schedule=schedule,
                       checkpoint_dir=args.ckpt_dir, log_every=10)
    tr = T.Trainer(cfg, par, mesh, tc, AdamWConfig(lr=args.lr))
    tr.data_cfg = dataclasses.replace(
        tr.data_cfg, seq_len=args.seq, global_batch=args.batch)
    params, opt, hist = tr.train(resume=args.ckpt_dir is not None)
    print(f"final loss: {hist[-1]['loss']:.4f} "
          f"(start {hist[0]['loss']:.4f}); straggler events "
          f"{tr.straggler_events}; failures {tr.failures}")


if __name__ == "__main__":
    main()
