import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: AOT lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any jax import (jax locks the device
count on first init); 512 placeholder CPU devices back the production
meshes 16x16 (single pod) and 2x16x16 (two pods).

Per cell this script records, into experiments/dryrun/<cell>.json:
  - compiled.memory_analysis()  (bytes per device: args/outputs/temps)
  - compiled.cost_analysis()    (XLA's numbers — undercounts on CPU, kept
                                 for reference)
  - the jaxpr-analyzer's per-device FLOPs / HBM bytes / collective bytes
    (exact; scan-aware — the roofline inputs, see analysis/jaxpr_cost.py)
  - collective-op counts from the optimized HLO text (cross-check)
  - MODEL_FLOPS = 6·N·D (train) or 2·N_active·D (serve) and the
    usefulness ratio MODEL_FLOPS / analyzer FLOPs.

Usage:
  python -m repro.launch.dryrun --arch codeqwen15_7b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--force]
"""
import argparse
import json
import re
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.analysis import jaxpr_cost as JC
from repro.configs.base import (ARCH_IDS, SHAPES, ModelConfig, ParallelConfig,
                                ShapeConfig, get_config, shape_applicable)
from repro.launch.mesh import make_production_mesh
from repro.launch.presets import production_parallel
from repro.models import model as M
from repro.models import serve as S
from repro.optim import adamw
from repro.parallel.sharding import TPContext
from repro.runtime import trainer as T

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStructs — never allocated)
# ---------------------------------------------------------------------------
def batch_sds(cfg: ModelConfig, shape: ShapeConfig, par: ParallelConfig,
              mesh) -> Tuple[Dict, Dict]:
    b, s = shape.global_batch, shape.seq_len
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_total = par.dp * par.pods
    dp = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    if b % dp_total:
        dp = None                      # tiny batches: replicate over data
    if cfg.frontend:
        sds = {"embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                              jnp.bfloat16),
               "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        spec = {"embeds": P(dp, "model", None), "labels": P(dp, None)}
    else:
        sds = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
               "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        spec = {"tokens": P(dp, None), "labels": P(dp, None)}
    return sds, spec


# named optimization sets for §Perf hillclimbing (dryrun --opt <name>)
OPT_SETS = {
    "fusedproj": {"fuse_w13": True},
    "mlakernel": {"kernel_decode": True},
    "kernels": {"kernel_decode": True},
    "rematdots": {"remat": "selective"},
    "norematfull": {"remat": "none"},
}
# cells where fp32 moments cannot fit (EXPERIMENTS §Dry-run memory finding)
BF16_MOMENT_ARCHS = {"deepseek_v3_671b"}


def input_specs(arch: str, shape_name: str, *, multi_pod: bool = False,
                overlap_mode: str = "decomposed", opt: str = "",
                plan_profile: str = None, wire_dtype: str = None):
    """Public entry: (cfg, shape, par, mesh) for a cell."""
    import dataclasses as _dc
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    par = production_parallel(cfg, multi_pod=multi_pod, kind=shape.kind,
                              overlap_mode=overlap_mode,
                              plan_profile=plan_profile)
    if wire_dtype:
        par = _dc.replace(par, wire_dtype=wire_dtype)
    for name in [o for o in opt.split("+") if o]:
        par = _dc.replace(par, **OPT_SETS[name])
    mesh = make_production_mesh(multi_pod=multi_pod)
    return cfg, shape, par, mesh


# ---------------------------------------------------------------------------
# per-kind step builders
# ---------------------------------------------------------------------------
def build_train(cfg, shape, par, mesh):
    tc = T.TrainConfig(total_steps=1000, base_lr=3e-4)
    moment_dtype = ("bfloat16" if cfg.name in BF16_MOMENT_ARCHS
                    else "float32")
    params_eval = jax.eval_shape(
        lambda: M.init_model(jax.random.PRNGKey(0), cfg, par))
    pspecs = M.param_specs(cfg, par, params_eval)
    opt_eval = jax.eval_shape(
        lambda p: adamw.init_opt_state(p, moment_dtype), params_eval)
    step_fn = T.make_train_step(cfg, par, mesh, adamw.AdamWConfig(), tc,
                                pspecs)
    bsds, bspec = batch_sds(cfg, shape, par, mesh)
    # shard_map requires batch specs to match; rebuild with the cell's specs
    ctx = T.make_ctx(cfg, par, mesh)
    pod_axis = "pod" if "pod" in mesh.axis_names else None
    model_rep = adamw.model_replicated_tree(pspecs)
    opt_specs = adamw.opt_state_specs(pspecs, params_eval, par.dp, par.tp)
    from repro.optim import schedule as sched
    schedule_fn = sched.get_schedule(tc.schedule)

    def step_fn_inner(params, opt, batch, step):
        loss, grads = jax.value_and_grad(
            lambda p: M.forward_loss(p, batch, ctx, cfg, par))(params)
        grads = jax.tree.map(
            lambda g, rep: jax.lax.psum(g, "model") if rep else g,
            grads, model_rep)
        loss = jax.lax.pmean(loss, ctx.dp_axes)
        lr = schedule_fn(step, base_lr=tc.base_lr, warmup=tc.warmup_steps,
                         total=tc.total_steps)
        params, opt = adamw.adamw_update(
            params, grads, opt, adamw.AdamWConfig(), lr, specs=pspecs,
            dp_axis="data", pod_axis=pod_axis, grad_compress=par.grad_compress)
        return params, opt, loss

    sm = compat.shard_map(step_fn_inner, mesh=mesh,
                       in_specs=(pspecs, opt_specs, bspec, P()),
                       out_specs=(pspecs, opt_specs, P()),
                       check_vma=False)
    fn = jax.jit(sm, donate_argnums=(0, 1))
    args = (params_eval, opt_eval, bsds,
            jax.ShapeDtypeStruct((), jnp.int32))
    return fn, args


def build_decode(cfg, shape, par, mesh):
    params_eval = jax.eval_shape(
        lambda: M.init_model(jax.random.PRNGKey(0), cfg, par))
    pspecs = M.param_specs(cfg, par, params_eval)
    ctx = T.make_ctx(cfg, par, mesh)
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    b = shape.global_batch
    dp_total = par.dp * par.pods
    dpax = dp_axes if b % dp_total == 0 else ()
    cache_sds, cache_spec = S.cache_specs(cfg, par, b, shape.seq_len,
                                          dp_axes=dpax)
    dp = dpax if len(dpax) > 1 else (dpax[0] if dpax else None)

    def fn(params, caches, tokens, pos):
        return S.decode_step(params, caches, tokens, pos, ctx, cfg, par)

    sm = compat.shard_map(fn, mesh=mesh,
                       in_specs=(pspecs, cache_spec, P(dp, None), P()),
                       out_specs=(P(dp, None), cache_spec),
                       check_vma=False)
    jf = jax.jit(sm, donate_argnums=(1,))
    args = (params_eval, cache_sds,
            jax.ShapeDtypeStruct((b, 1), jnp.int32),
            jax.ShapeDtypeStruct((), jnp.int32))
    return jf, args


def build_prefill(cfg, shape, par, mesh):
    params_eval = jax.eval_shape(
        lambda: M.init_model(jax.random.PRNGKey(0), cfg, par))
    pspecs = M.param_specs(cfg, par, params_eval)
    ctx = T.make_ctx(cfg, par, mesh)
    bsds, bspec = batch_sds(cfg, shape, par, mesh)
    b = shape.global_batch
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_total = par.dp * par.pods
    dpax = dp_axes if b % dp_total == 0 else ()
    _, cache_spec = S.cache_specs(cfg, par, b, shape.seq_len,
                                  dp_axes=dpax)
    dp = dpax if len(dpax) > 1 else (dpax[0] if dpax else None)

    def fn(params, batch):
        return S.prefill_step(params, batch, ctx, cfg, par)

    sm = compat.shard_map(fn, mesh=mesh,
                       in_specs=(pspecs, bspec),
                       out_specs=(P(dp, None), cache_spec),
                       check_vma=False)
    jf = jax.jit(sm)
    bsds.pop("labels", None)
    bspec.pop("labels", None)
    return jf, (params_eval, bsds)


BUILDERS = {"train": build_train, "prefill": build_prefill,
            "decode": build_decode}


# collective parsing from compiled HLO lives in analysis (importable
# without touching jax device state)
from repro.analysis.hlo_census import hlo_collective_counts  # noqa: E402


# ---------------------------------------------------------------------------
# cell runner
# ---------------------------------------------------------------------------
def reanalyze_cell(path: str) -> None:
    """Refresh the analyzer fields of a cached cell JSON (fast: no compile)."""
    with open(path) as f:
        result = json.load(f)
    if "skipped" in result or "error" in result:
        return
    cfg, shape, par, mesh = input_specs(
        result["arch"], result["shape"],
        multi_pod=result["mesh"] != "pod16x16",
        overlap_mode=result.get("overlap_mode", "decomposed"),
        opt=result.get("opt", ""),
        plan_profile=result.get("plan_profile") or None)
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    with mesh:
        fnw, argsw = BUILDERS[shape.kind](cfg, shape, par, mesh)
        traced = jax.make_jaxpr(fnw)(*argsw)
    cost = JC.analyze_jaxpr(traced.jaxpr, axis_sizes)
    terms = JC.roofline_terms(cost)
    n_params = M.count_params_analytic(cfg)
    n_active = M.count_params_analytic(cfg, active_only=True)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    model_flops = (6.0 if shape.kind == "train" else 2.0) * n_active * tokens
    chips = result["chips"]
    result.update({
        "analyzer": {
            "flops_per_device": cost.flops,
            "bytes_per_device": cost.bytes,
            "bytes_all_per_device": cost.bytes_all,
            "collective_bytes_per_device": cost.collective_bytes,
            "collective_bytes_by_type": cost.collective_bytes_by_type,
            "collective_counts": cost.collective_counts,
            "compute_term_s": terms["compute_s"],
            "memory_term_s": terms["memory_s"],
            "collective_term_s": terms["collective_s"],
            "ici_model_s": terms["ici_model_s"],
            "ici_duplex_s": terms.get("ici_duplex_s", 0.0),
            "dominant": terms["dominant"],
        },
        "model_flops_global": model_flops,
        "model_flops_per_device": model_flops / chips,
        "useful_ratio": (model_flops / chips) / max(cost.flops, 1.0),
        "params": n_params,
        "active_params": n_active,
    })
    with open(path, "w") as f:
        json.dump(result, f, indent=1)


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             overlap_mode: str = "decomposed", force: bool = False,
             out_dir: Optional[str] = None, opt: str = "",
             plan_profile: str = None, wire_dtype: str = None,
             extra_tag: str = "") -> Dict[str, Any]:
    out_dir = out_dir or OUT_DIR
    os.makedirs(out_dir, exist_ok=True)
    mesh_tag = "pod2x16x16" if multi_pod else "pod16x16"
    tag = f"{mesh_tag}_{arch}_{shape_name}"
    if overlap_mode != "decomposed":
        tag += f"_{overlap_mode}"
    if wire_dtype:
        tag += f"_wire-{wire_dtype}"
    if opt:
        tag += f"_opt-{opt}"
    if extra_tag:
        tag += f"_{extra_tag}"
    path = os.path.join(out_dir, tag + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    cfg, shape, par, mesh = input_specs(arch, shape_name,
                                        multi_pod=multi_pod,
                                        overlap_mode=overlap_mode, opt=opt,
                                        plan_profile=plan_profile,
                                        wire_dtype=wire_dtype)
    result: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_tag,
        "overlap_mode": overlap_mode, "kind": shape.kind, "opt": opt,
        "wire_dtype": wire_dtype or "",
        "plan_profile": plan_profile or "",
        "chips": int(np.prod(mesh.devices.shape)),
    }
    if not shape_applicable(cfg, shape):
        result["skipped"] = ("long_500k requires sub-quadratic attention; "
                             f"{arch} is full-attention (DESIGN.md §5)")
        with open(path, "w") as f:
            json.dump(result, f, indent=1)
        return result

    t0 = time.time()
    try:
        with mesh:
            fn, args = BUILDERS[shape.kind](cfg, shape, par, mesh)
            lowered = fn.lower(*args)
            t_lower = time.time() - t0
            t1 = time.time()
            compiled = lowered.compile()
            t_compile = time.time() - t1
            mem = compiled.memory_analysis()
            ca = compat.cost_analysis(compiled)
            hlo = compiled.as_text()
        result.update({
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "memory_analysis": {
                "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
                "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
                "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
                "alias_bytes": int(getattr(mem, "alias_size_in_bytes", 0)),
            },
            "xla_cost": {"flops": float(ca.get("flops", 0)),
                         "bytes_accessed": float(ca.get("bytes accessed", 0))},
            "hlo_collectives": hlo_collective_counts(hlo),
            "hlo_chars": len(hlo),
        })
    except Exception as e:  # noqa: BLE001
        result["error"] = f"{type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc()[-4000:]
        with open(path, "w") as f:
            json.dump(result, f, indent=1)
        raise

    # jaxpr analyzer (separately traced, same step function + args)
    try:
        axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        with mesh:
            fnw, argsw = BUILDERS[shape.kind](cfg, shape, par, mesh)
            traced = jax.make_jaxpr(fnw)(*argsw)
        cost = JC.analyze_jaxpr(traced.jaxpr, axis_sizes)
        terms = JC.roofline_terms(cost)
        n_params = M.count_params_analytic(cfg)
        n_active = M.count_params_analytic(cfg, active_only=True)
        tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                       else 1)
        if shape.kind == "train":
            # 6·N·D (dense) / 6·N_active·D (MoE) per task statement
            model_flops = 6.0 * n_active * tokens
        else:
            model_flops = 2.0 * n_active * tokens
        chips = result["chips"]
        result.update({
            "analyzer": {
                "flops_per_device": cost.flops,
                "bytes_per_device": cost.bytes,
                "collective_bytes_per_device": cost.collective_bytes,
                "collective_bytes_by_type": cost.collective_bytes_by_type,
                "collective_counts": cost.collective_counts,
                "compute_term_s": terms["compute_s"],
                "memory_term_s": terms["memory_s"],
                "collective_term_s": terms["collective_s"],
                "ici_model_s": terms["ici_model_s"],
                "ici_duplex_s": terms.get("ici_duplex_s", 0.0),
                "dominant": terms["dominant"],
            },
            "model_flops_global": model_flops,
            "model_flops_per_device": model_flops / chips,
            "useful_ratio": (model_flops / chips) / max(cost.flops, 1.0),
            "params": n_params,
            "active_params": n_active,
        })
    except Exception as e:  # noqa: BLE001
        result["analyzer_error"] = f"{type(e).__name__}: {e}"

    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--mode", default="decomposed",
                    choices=["xla", "decomposed", "flux",
                             "decomposed_bidir"])
    ap.add_argument("--wire-dtype", default=None,
                    choices=["int8", "fp8_e4m3", "int4"],
                    help="forward-wire precision for the TP seams "
                         "(lossy; cotangents stay full precision)")
    ap.add_argument("--opt", default="", help="named opt set(s), '+'-joined")
    ap.add_argument("--plan-profile", default=None,
                    help="tuned per-seam plan JSON (repro.tuning)")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--reanalyze", action="store_true",
                    help="retrace + refresh analyzer fields of cached cells "
                         "(no recompile)")
    args = ap.parse_args()

    if args.reanalyze:
        import glob as _glob
        for path in sorted(_glob.glob(os.path.join(OUT_DIR, "*.json"))):
            try:
                reanalyze_cell(path)
                print("[re]", os.path.basename(path))
            except Exception as e:  # noqa: BLE001
                print("[re-FAIL]", os.path.basename(path), e)
        return

    cells = []
    archs = ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for mp in meshes:
        for a in archs:
            for s in shapes:
                cells.append((a, s, mp))

    failures = 0
    for a, s, mp in cells:
        tag = f"{'2x16x16' if mp else '16x16'} {a} {s}"
        try:
            r = run_cell(a, s, multi_pod=mp, overlap_mode=args.mode,
                         opt=args.opt, plan_profile=args.plan_profile,
                         wire_dtype=args.wire_dtype, force=args.force)
            if "skipped" in r:
                print(f"[skip] {tag}: {r['skipped']}")
            elif "error" in r:
                print(f"[FAIL] {tag}: {r['error']}")
                failures += 1
            else:
                dom = r.get("analyzer", {}).get("dominant", "?")
                print(f"[ok]   {tag}: compile={r['compile_s']}s "
                      f"dominant={dom}")
        except Exception as e:  # noqa: BLE001
            print(f"[FAIL] {tag}: {e}")
            failures += 1
    print(f"done; {failures} failures")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
