"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing this
module never touches jax device state.  The dry-run launcher sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before importing jax.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(pods: int, dp: int, tp: int, ep: int = 1) -> Mesh:
    """General mesh: drops the pod axis when pods == 1 and dp axis when dp == 1?

    No — axes are kept stable ("pod","data","model") whenever pods > 1, and
    ("data","model") otherwise, so PartitionSpecs in the model code can always
    address "data" and "model"; the pod axis only appears at multi-pod scale.
    ``ep > 1`` inserts a dedicated expert-parallel axis ("ep") between "pod"
    and "data" — outermost short of pods, so an EP group spans adjacent DPxTP
    blocks and the a2a ring maps onto neighboring slices.
    """
    if ep > 1:
        if pods > 1:
            return jax.make_mesh((pods, ep, dp, tp),
                                 ("pod", "ep", "data", "model"))
        return jax.make_mesh((ep, dp, tp), ("ep", "data", "model"))
    if pods > 1:
        return jax.make_mesh((pods, dp, tp), ("pod", "data", "model"))
    return jax.make_mesh((dp, tp), ("data", "model"))


def smoke_mesh() -> Mesh:
    """1-device mesh with the standard axis names, for CPU smoke tests."""
    devs = np.array(jax.devices()[:1]).reshape(1, 1)
    return Mesh(devs, ("data", "model"))


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Axes that carry data parallelism (batch).  A dedicated "ep" axis
    still shards the batch — tokens live on their own EP slice and the MoE
    a2a seam is the only thing that crosses it."""
    return tuple(a for a in ("pod", "ep", "data") if a in mesh.axis_names)


def elastic_remesh(surviving_devices: int, tp: int) -> Mesh:
    """Rebuild a mesh after failures: keep TP intact (a TP group dies with any
    of its members), shrink DP to what still forms full TP groups."""
    usable = (surviving_devices // tp) * tp
    if usable == 0:
        raise RuntimeError(
            f"cannot form a single {tp}-way TP group from {surviving_devices} devices")
    dp = usable // tp
    devs = np.array(jax.devices()[:usable]).reshape(dp, tp)
    return Mesh(devs, ("data", "model"))
