"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing this
module never touches jax device state.  The dry-run launcher sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before importing jax.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(pods: int, dp: int, tp: int) -> Mesh:
    """General mesh: drops the pod axis when pods == 1 and dp axis when dp == 1?

    No — axes are kept stable ("pod","data","model") whenever pods > 1, and
    ("data","model") otherwise, so PartitionSpecs in the model code can always
    address "data" and "model"; the pod axis only appears at multi-pod scale.
    """
    if pods > 1:
        return jax.make_mesh((pods, dp, tp), ("pod", "data", "model"))
    return jax.make_mesh((dp, tp), ("data", "model"))


def smoke_mesh() -> Mesh:
    """1-device mesh with the standard axis names, for CPU smoke tests."""
    devs = np.array(jax.devices()[:1]).reshape(1, 1)
    return Mesh(devs, ("data", "model"))


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Axes that carry data parallelism (batch)."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def elastic_remesh(surviving_devices: int, tp: int) -> Mesh:
    """Rebuild a mesh after failures: keep TP intact (a TP group dies with any
    of its members), shrink DP to what still forms full TP groups."""
    usable = (surviving_devices // tp) * tp
    if usable == 0:
        raise RuntimeError(
            f"cannot form a single {tp}-way TP group from {surviving_devices} devices")
    dp = usable // tp
    devs = np.array(jax.devices()[:usable]).reshape(dp, tp)
    return Mesh(devs, ("data", "model"))
