"""Serving launcher: continuous batching over a model checkpoint (or random
init for smoke runs).

  PYTHONPATH=src python -m repro.launch.serve --arch phi4_mini_38b --smoke \
      --requests 8
"""
import argparse

import jax
import numpy as np
from jax.sharding import Mesh

from repro.configs.base import ParallelConfig, get_config, get_smoke_config
from repro.launch.mesh import make_mesh
from repro.models import model as M
from repro.runtime.server import Request, ServeConfig, Server


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--dp", type=int, default=1)
    ap.add_argument("--mode", default="decomposed")
    ap.add_argument("--wire-dtype", default=None,
                    choices=["int8", "fp8_e4m3", "int4"],
                    help="forward-wire precision for the TP seams (lossy; "
                         "serving has no backward, so this is the full "
                         "quantization story here)")
    ap.add_argument("--max-logit-rmse", type=float, default=None,
                    help="error budget for the --autotune wire_dtype sweep")
    ap.add_argument("--plan-profile", default=None,
                    help="tuned per-seam profile JSON (repro.tuning)")
    ap.add_argument("--autotune", action="store_true",
                    help="tune seam plans first (decode_ar at --max-batch, "
                         "matching the server's decode jit signature); "
                         "requires --tp > 1 — there are no seams to tune "
                         "on a single shard")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--max-batch", type=int, default=4,
                    help="concurrent decode slots (the server's jit batch)")
    ap.add_argument("--eos", type=int, default=-1,
                    help="EOS token id (-1: never stop early)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="KV pool block (page) size in tokens")
    ap.add_argument("--prefill-chunk", type=int, default=32,
                    help="chunked-prefill rows per dispatch (bounds how "
                         "long a long prompt stalls running decodes)")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    par = ParallelConfig(tp=args.tp, dp=args.dp, overlap_mode=args.mode,
                         wire_dtype=args.wire_dtype,
                         max_logit_rmse=args.max_logit_rmse,
                         plan_profile=args.plan_profile)
    if args.autotune and args.tp <= 1:
        print("warning: --autotune skipped (tp=1 has no TP seams to tune); "
              "pass --tp > 1 to tune the serving plans")
    if args.autotune and args.tp > 1:
        import dataclasses
        import os

        from repro.tuning import (WIRE_DTYPE_SWEEP, PlanRegistry,
                                  autotune_model, default_plans_dir)
        path = args.plan_profile or os.path.join(
            default_plans_dir(), f"{args.arch}_tp{args.tp}.json")
        reg = PlanRegistry.open(path, n_dev=args.tp)
        wire_sweep = None
        if args.wire_dtype:
            wire_sweep = (None, args.wire_dtype)
        elif args.max_logit_rmse is not None:
            wire_sweep = WIRE_DTYPE_SWEEP
        autotune_model(cfg, par, decode_batch=args.max_batch,
                       registry=reg, save_path=path,
                       wire_dtypes=wire_sweep,
                       max_logit_rmse=args.max_logit_rmse)
        par = dataclasses.replace(par, plan_profile=path)
    mesh = make_mesh(1, args.dp, args.tp)
    params = M.init_model(jax.random.PRNGKey(0), cfg, par)

    sc = ServeConfig(max_batch=args.max_batch, max_seq=args.max_seq,
                     eos_token=args.eos, max_new_tokens=args.max_new,
                     block_size=args.block_size,
                     prefill_chunk=args.prefill_chunk)
    server = Server(cfg, par, mesh, params, sc)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(
        0, cfg.vocab_size, size=(8 + i,)).astype(np.int32))
        for i in range(args.requests)]
    done = server.serve(reqs)
    for r in sorted(done, key=lambda x: x.rid):
        ttft = r.ttft_s()
        ttft_ms = f"{ttft * 1e3:.1f}ms" if ttft is not None else "n/a"
        print(f"req {r.rid}: +{len(r.output)} tokens ttft={ttft_ms}: "
              f"{r.output[:12]}")
    pool = server.pool
    print(f"pool: peak {pool.peak_blocks_in_use}/{pool.num_blocks - 1} "
          f"blocks (dense equiv {server.dense_equiv_blocks}), "
          f"reuse_hits={pool.reuse_hits} reused_tokens={pool.reused_tokens} "
          f"evictions={pool.evictions}")


if __name__ == "__main__":
    main()
