"""Serving launcher: continuous batching over a model checkpoint (or random
init for smoke runs).

  PYTHONPATH=src python -m repro.launch.serve --arch phi4_mini_38b --smoke \
      --requests 8
"""
import argparse

import jax
import numpy as np
from jax.sharding import Mesh

from repro.configs.base import ParallelConfig, get_config, get_smoke_config
from repro.launch.mesh import make_mesh
from repro.models import model as M
from repro.runtime.server import Request, ServeConfig, Server


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--dp", type=int, default=1)
    ap.add_argument("--mode", default="decomposed")
    ap.add_argument("--plan-profile", default=None,
                    help="tuned per-seam profile JSON (repro.tuning)")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=256)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    par = ParallelConfig(tp=args.tp, dp=args.dp, overlap_mode=args.mode,
                         plan_profile=args.plan_profile)
    mesh = make_mesh(1, args.dp, args.tp)
    params = M.init_model(jax.random.PRNGKey(0), cfg, par)

    sc = ServeConfig(max_batch=4, max_seq=args.max_seq, eos_token=-1,
                     max_new_tokens=args.max_new)
    server = Server(cfg, par, mesh, params, sc)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(
        0, cfg.vocab_size, size=(8 + i,)).astype(np.int32))
        for i in range(args.requests)]
    done = server.serve(reqs)
    for r in sorted(done, key=lambda x: x.rid):
        print(f"req {r.rid}: +{len(r.output)} tokens: {r.output[:12]}")


if __name__ == "__main__":
    main()
