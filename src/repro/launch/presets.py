"""Per-architecture parallelism presets for the production meshes."""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig, ParallelConfig


def production_parallel(cfg: ModelConfig, *, multi_pod: bool = False,
                        kind: str = "train",
                        overlap_mode: str = "decomposed",
                        plan_profile: str = None) -> ParallelConfig:
    """ParallelConfig for the (2,)16x16 meshes, sized per arch family.
    ``plan_profile`` points at a tuned per-seam plan JSON (repro.tuning);
    stale or mesh-mismatched profiles fall back to ``overlap_mode``."""
    pods = 2 if multi_pod else 1
    big = cfg.name in ("deepseek_v3_671b", "qwen15_110b", "qwen2_vl_72b",
                       "gpt3_175b", "llama4_scout_17b_a16e", "jamba_v01_52b")
    zero3 = big and kind == "train"
    ep_over_dp = (cfg.moe is not None
                  and cfg.moe.num_experts > 16)          # deepseek: 256e
    remat = "full" if (big and kind == "train") else (
        "selective" if kind == "train" else "none")
    return ParallelConfig(
        tp=16, dp=16, pods=pods,
        ep_over_dp=ep_over_dp,
        zero3=zero3,
        remat=remat,
        overlap_mode=overlap_mode,
        plan_profile=plan_profile,
        grad_compress=multi_pod,        # compress the slow cross-pod hop
    )
