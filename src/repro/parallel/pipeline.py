"""Pipeline parallelism (GPipe-style) over the pod axis.

At 1000+ nodes the pod axis can be reinterpreted as pipeline stages: each
stage holds a contiguous slice of layers; microbatches stream through via
``ppermute`` boundary transfers.  This composes with the TP/SP seams inside
each stage (paper §7: "Flux can be applied in addition").

The schedule is GPipe (fill-drain): with M microbatches and P stages the
bubble fraction is (P-1)/(M+P-1); the boundary transfer per microbatch is a
[B_micro, S/TP, D] activation — tiny next to the in-stage TP rings, and
XLA overlaps it with the next microbatch's compute.
"""
from __future__ import annotations

import functools
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro import compat

Array = jax.Array


def pipeline_forward(stage_fn: Callable[[Array, int], Array], x: Array,
                     axis: str, num_microbatches: int) -> Array:
    """Run ``stage_fn`` (this device's layer slice) as one stage of a GPipe
    pipeline over mesh axis ``axis``.

    x: [B_loc, S, D] — the stage-0 input (other stages ignore their x).
    Returns the LAST stage's output (valid on the last stage; callers
    typically psum-select or ppermute it back).
    """
    p = compat.axis_size(axis)
    stage = lax.axis_index(axis)
    b = x.shape[0]
    assert b % num_microbatches == 0
    mb = b // num_microbatches
    micro = x.reshape(num_microbatches, mb, *x.shape[1:])

    fwd_perm = [(i, i + 1) for i in range(p - 1)]

    n_ticks = num_microbatches + p - 1
    out = jnp.zeros_like(micro)

    def tick(carry, t):
        buf, out = carry
        # which microbatch enters stage 0 at this tick
        idx = jnp.clip(t, 0, num_microbatches - 1)
        inject = lax.dynamic_index_in_dim(micro, idx, axis=0, keepdims=False)
        x_in = jnp.where(stage == 0, inject, buf)
        active = (t - stage >= 0) & (t - stage < num_microbatches)
        y = stage_fn(x_in, t)
        y = jnp.where(active, y, jnp.zeros_like(y))
        # emit at the last stage
        mb_idx = jnp.clip(t - (p - 1), 0, num_microbatches - 1)
        emit = (stage == p - 1) & active
        out = lax.dynamic_update_index_in_dim(
            out, jnp.where(emit, y, lax.dynamic_index_in_dim(
                out, mb_idx, axis=0, keepdims=False)),
            mb_idx, axis=0)
        # forward the activation to the next stage (the PIPELINE axis, not
        # a TP seam ring)
        buf = (lax.ppermute(y, axis, fwd_perm)  # lint: allow(raw-collective)
               if p > 1 else y)
        return (buf, out), None

    buf0 = jnp.zeros((mb, *x.shape[1:]), x.dtype)
    (_, out), _ = lax.scan(tick, (buf0, out), jnp.arange(n_ticks))
    return out.reshape(b, *x.shape[1:])


def bubble_fraction(num_microbatches: int, stages: int) -> float:
    return (stages - 1) / (num_microbatches + stages - 1)
