"""Parallelism context + helpers threaded through the model code.

All model code runs inside ``compat.shard_map``; ``TPContext`` carries the mesh
axis names and the FLUX overlap settings so every TP seam in every
architecture routes through ``repro.core.overlap``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
from jax import lax

from repro import compat


@dataclasses.dataclass(frozen=True)
class TPContext:
    """How the current shard_map region is parallelized.

    axis      : TP/SP mesh axis name (None -> single device / no TP)
    dp_axes   : data-parallel axes (batch sharding; grad sync)
    ep_axes   : expert-parallel axes for MoE dispatch
    mode      : overlap mode for the TP seams (xla | decomposed | flux)
    """
    axis: Optional[str] = None
    dp_axes: Tuple[str, ...] = ()
    ep_axes: Tuple[str, ...] = ()
    mode: str = "decomposed"
    comm_chunks: int = 0
    use_kernels: bool = False        # Pallas fused kernels on hot paths
    #                                  (MLA decode; interpret on CPU)

    @property
    def tp(self) -> int:
        return 1 if self.axis is None else compat.axis_size(self.axis)

    @property
    def ep(self) -> int:
        n = 1
        for a in self.ep_axes:
            n *= compat.axis_size(a)
        return n

    def tp_index(self):
        if self.axis is None:
            return 0
        return lax.axis_index(self.axis)


def ceil_mult(x: int, m: int) -> int:
    """Round x up to a multiple of m."""
    return ((x + m - 1) // m) * m


def pad_heads(num_heads: int, tp: int) -> int:
    """Heads padded so TP divides them (padding waste shows up honestly in
    the roofline's MODEL_FLOPS/HLO_FLOPS ratio)."""
    if num_heads == 0:
        return 0
    return ceil_mult(num_heads, tp)


def pad_kv_heads(num_kv_heads: int, tp: int) -> int:
    """KV heads: replicate up to TP when fewer than TP, else pad to multiple."""
    if num_kv_heads == 0:
        return 0
    if num_kv_heads < tp:
        return tp
    return ceil_mult(num_kv_heads, tp)


def pad_ff(d_ff: int, tp: int, align: int = 128) -> int:
    return ceil_mult(d_ff, tp * align)


def pad_vocab(vocab: int, tp: int, align: int = 128) -> int:
    return ceil_mult(vocab, tp * align)
