"""Parallelism context + helpers threaded through the model code.

All model code runs inside ``compat.shard_map``; ``TPContext`` carries the mesh
axis names and the FLUX overlap settings so every TP seam in every
architecture routes through ``repro.core.overlap``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
from jax import lax

from repro import compat


@dataclasses.dataclass(frozen=True)
class TPContext:
    """How the current shard_map region is parallelized.

    axis      : TP/SP mesh axis name (None -> single device / no TP)
    dp_axes   : data-parallel axes (batch sharding; grad sync)
    ep_axes   : expert-parallel axes for MoE dispatch
    mode      : fallback overlap mode for TP seams without a plan
    plans     : per-layer-seam PlanSet (repro.tuning); when set, every seam
                resolves its knobs via ``self.plan(seam)`` instead of the
                global mode/comm_chunks pair
    layer     : current layer slot (absolute index for unrolled leading
                layers; leading_dense_layers + position for scanned pattern
                positions) — threaded by model.py/serve.py for per-layer
                plan overrides
    seq_shard : the residual-stream activation layout.  None resolves from
                the plans' joint ``scatter_axis`` knob (default: True —
                sequence-sharded [B, S/TP, D] between seams, Megatron-SP);
                True/False force it (decode forces False: one-token
                activations stay replicated).  Model code consults
                ``seq_sharded`` / ``seq_factor`` — never the raw field.
    """
    axis: Optional[str] = None
    dp_axes: Tuple[str, ...] = ()
    ep_axes: Tuple[str, ...] = ()
    mode: str = "decomposed"
    comm_chunks: int = 0
    use_kernels: bool = False        # Pallas fused kernels on hot paths
    #                                  (MLA decode; interpret on CPU)
    plans: Optional[object] = None   # tuning.plans.PlanSet (kept loose to
    #                                  avoid a hard import edge)
    layer: Optional[int] = None
    seq_shard: Optional[bool] = None

    def plan(self, seam: str):
        """Resolve the overlap plan for one model seam (tuning.KNOWN_SEAMS);
        falls back to the global mode/comm_chunks when no PlanSet is set."""
        if self.plans is not None:
            return self.plans.resolve(seam, self.layer)
        from repro.tuning.plans import SeamPlan
        return SeamPlan(mode=self.mode, comm_chunks=self.comm_chunks)

    @property
    def seq_sharded(self) -> bool:
        """True when the residual stream between TP seams is sequence-
        sharded ([B, S/TP, D]); False when it is replicated ([B, S, D])."""
        if self.seq_shard is not None:
            return self.seq_shard
        if self.plans is not None and hasattr(self.plans, "residual_layout"):
            return self.plans.residual_layout() == "seq"
        return True

    @property
    def seq_factor(self) -> int:
        """Global sequence length = local length * seq_factor."""
        return self.tp if self.seq_sharded else 1

    def with_layout(self, seq_shard: Optional[bool]) -> "TPContext":
        """Force (True/False) or unpin (None) the activation layout —
        decode paths force the replicated layout for S=1."""
        if seq_shard == self.seq_shard:
            return self
        return dataclasses.replace(self, seq_shard=seq_shard)

    def op(self, seam: str, epilogue=None, n_weights: int = 1,
           scatter_axis: Optional[str] = None):
        """The resolved ``overlap.FusedOp`` for one model seam: plan knobs
        (mode/chunks/direction/blocks + fuse_epilogue/shared_gather) come
        from the registry, the collective kind from the seam name, and the
        epilogue/weight-count from the call site.  ``scatter_axis`` defaults
        to the context's resolved residual layout (all seams coherent); an
        explicit value overrides per call site.  This is the ONLY way model
        code should reach the overlap seams."""
        from repro.tuning.plans import SEAM_KINDS
        kind = SEAM_KINDS.get(seam, seam.rsplit("_", 1)[-1])
        if scatter_axis is None and kind in ("ag", "rs"):
            scatter_axis = "seq" if self.seq_sharded else "hidden"
        # the EP exchange runs over the context's EP group (a TUPLE of mesh
        # axes — multi-axis under ep_over_dp), not the scalar TP axis
        axis = (tuple(self.ep_axes) or ((self.axis,) if self.axis else ())
                if kind == "a2a" else self.axis)
        return self.plan(seam).op(kind, axis, epilogue=epilogue,
                                  n_weights=n_weights,
                                  scatter_axis=scatter_axis)

    def gather_seq(self, x, seam: str = "attn_ag"):
        """Full-sequence view of a (possibly) sequence-sharded non-GEMM
        payload (MLA's shared rope key, cache tails).  No-op in the
        replicated layout; rides ``seam``'s plan transport otherwise (ring
        modes: ppermute hops — no standalone all_gather between seams)."""
        if self.axis is None or self.tp == 1 or not self.seq_sharded:
            return x
        from repro.core import overlap
        plan = self.plan(seam)
        return overlap.gather_seq(x, self.axis, mode=plan.mode,
                                  reverse=getattr(plan, "reverse", False))

    def scatter_seq(self, x, seam: str = "head_ag"):
        """ReduceScatter a per-rank full-sequence partial into this rank's
        sequence shard (the embedding seam's combining collective) — dual
        of :meth:`gather_seq`, riding the same plan transport.  psum
        (replicated combine) when the residual stream is not
        sequence-sharded."""
        from jax import lax as _lax
        if self.axis is None or self.tp == 1:
            return x
        if not self.seq_sharded:
            with jax.named_scope("seam_replicated_sum"):
                return _lax.psum(x, self.axis)
        from repro.core import overlap
        plan = self.plan(seam)
        return overlap.scatter_seq_sum(x, self.axis, mode=plan.mode,
                                       reverse=getattr(plan, "reverse",
                                                       False))

    def with_layer(self, layer: Optional[int]) -> "TPContext":
        if layer == self.layer:
            return self
        return dataclasses.replace(self, layer=layer)

    @property
    def tp(self) -> int:
        return 1 if self.axis is None else compat.axis_size(self.axis)

    @property
    def ep(self) -> int:
        n = 1
        for a in self.ep_axes:
            n *= compat.axis_size(a)
        return n

    def tp_index(self):
        if self.axis is None:
            return 0
        return lax.axis_index(self.axis)


def gather_ranks(x, axis: Optional[str]):
    """Stack every rank's copy of ``x`` along a NEW trailing dim:
    [...] -> [..., TP].  The tiny cross-rank reduction seam (vocab-parallel
    argmax candidates, per-rank stats) — lives here so model code never
    emits a raw ``lax.all_gather`` (the seamcheck raw-collective rule)."""
    if axis is None or compat.axis_size(axis) == 1:
        return x[..., None]
    with jax.named_scope("seam_rank_gather"):
        return lax.all_gather(x, axis, axis=-1)


def ceil_mult(x: int, m: int) -> int:
    """Round x up to a multiple of m."""
    return ((x + m - 1) // m) * m


def pad_heads(num_heads: int, tp: int) -> int:
    """Heads padded so TP divides them (padding waste shows up honestly in
    the roofline's MODEL_FLOPS/HLO_FLOPS ratio)."""
    if num_heads == 0:
        return 0
    return ceil_mult(num_heads, tp)


def pad_kv_heads(num_kv_heads: int, tp: int) -> int:
    """KV heads: replicate up to TP when fewer than TP, else pad to multiple."""
    if num_kv_heads == 0:
        return 0
    if num_kv_heads < tp:
        return tp
    return ceil_mult(num_kv_heads, tp)


def pad_ff(d_ff: int, tp: int, align: int = 128) -> int:
    return ceil_mult(d_ff, tp * align)


def pad_vocab(vocab: int, tp: int, align: int = 128) -> int:
    return ceil_mult(vocab, tp * align)


def activation_spec(dp_axes: Tuple[str, ...], seq_sharded: bool = True,
                    tp_axis: str = "model"):
    """PartitionSpec of a [B, S, D] residual-stream activation at the
    shard_map boundary under each layout: sequence dim on the TP axis when
    sequence-sharded, replicated otherwise.  The single place batch/embed
    specs derive the layout from (trainer, pipelines, tests)."""
    from jax.sharding import PartitionSpec as P
    dp = dp_axes if len(dp_axes) > 1 else (dp_axes[0] if dp_axes else None)
    return P(dp, tp_axis if seq_sharded else None, None)
