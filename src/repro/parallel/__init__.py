from repro.parallel.sharding import TPContext  # noqa: F401
