"""GPT-3 175B — the paper's own evaluation model; its MLP GEMMs give the
(n,k) = (49152, 12288) / (12288, 49152) shapes of the op-level benchmarks
(paper §5.1).  RoPE stands in for learned positions (irrelevant to the
communication study)."""
from repro.configs.base import ModelConfig, shrink

CONFIG = ModelConfig(
    name="gpt3_175b",
    family="dense",
    num_layers=96,
    d_model=12288,
    num_heads=96,
    num_kv_heads=96,
    d_ff=49152,
    vocab_size=50304,
    rope_style="rope",
    sub_quadratic=False,
)

SMOKE_CONFIG = shrink(CONFIG)
