"""Qwen1.5 110B: dense with QKV bias.  [hf:Qwen/Qwen1.5-110B; hf]"""
from repro.configs.base import ModelConfig, shrink

CONFIG = ModelConfig(
    name="qwen15_110b",
    family="dense",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=49152,
    vocab_size=152064,
    rope_style="rope",
    qkv_bias=True,
    sub_quadratic=False,
)

SMOKE_CONFIG = shrink(CONFIG)
