"""Config system: model / parallelism / run configs for the whole framework.

Every assigned architecture gets a module in ``repro/configs/<id>.py`` exposing
``CONFIG: ModelConfig``.  Shapes (train_4k / prefill_32k / decode_32k / long_500k)
are defined here once and attached per-arch via ``input_specs``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Layer-pattern vocabulary.  A model is a sequence of blocks; homogeneous runs
# are scanned (keeps HLO small), heterogeneous periods are python-unrolled
# inside a scanned "period".
# ---------------------------------------------------------------------------
ATTN = "attn"          # softmax attention (GQA)
MLA = "mla"            # DeepSeek multi-head latent attention
MAMBA = "mamba"        # Mamba-1 selective-scan mixer
RWKV = "rwkv6"         # RWKV-6 (Finch) time-mix
DENSE_FFN = "ffn"      # SwiGLU / GeGLU dense FFN
MOE_FFN = "moe"        # routed expert FFN


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    expert_ffn: int                  # d_ff of each routed expert
    num_shared_experts: int = 0      # DeepSeek-style shared expert(s)
    shared_ffn: int = 0              # d_ff of the shared expert path
    capacity_factor: float = 1.25
    router_dtype: str = "float32"


@dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0                 # 0 -> ceil(d_model/16)


@dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64
    decay_lora: int = 64             # LoRA rank of the data-dependent decay
    token_shift: bool = True


@dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int                   # query heads (0 for attention-free archs)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads
    # Per-layer pattern: list of (mixer, ffn) tuples describing ONE period,
    # repeated num_layers/len(pattern) times.  Default: [(ATTN, DENSE_FFN)].
    pattern: Tuple[Tuple[str, str], ...] = ((ATTN, DENSE_FFN),)
    # How many leading layers override the pattern (DeepSeek: 3 dense first).
    leading_dense_layers: int = 0
    moe: Optional[MoEConfig] = None
    mamba: Optional[MambaConfig] = None
    rwkv: Optional[RWKVConfig] = None
    mla: Optional[MLAConfig] = None
    # attention details
    rope_theta: float = 10000.0
    rope_style: str = "rope"         # rope | mrope | none
    qkv_bias: bool = False
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    # modality frontend stub: None | "audio_frames" | "vision_patches"
    frontend: Optional[str] = None
    num_codebooks: int = 1           # musicgen EnCodec codebooks
    # training-time specifics
    mtp_depth: int = 0               # DeepSeek multi-token-prediction heads
    max_seq_len: int = 524288
    sub_quadratic: bool = False      # True -> long_500k cell is runnable
    compute_dtype: str = "bfloat16"  # activation dtype (fp32 for num. tests)

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS = 6*N*D)."""
        from repro.models.model import count_params_analytic
        return count_params_analytic(self)

    def active_param_count(self) -> int:
        from repro.models.model import count_params_analytic
        return count_params_analytic(self, active_only=True)


# ---------------------------------------------------------------------------
# Parallelism / run configuration
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ParallelConfig:
    """How a model maps onto the device mesh.

    mesh axes are ("pod", "ep", "data", "model"); single-pod meshes drop
    "pod" and ep == 0 (the default) drops "ep".
    - dp axes: ("pod", "ep", "data") -> batch (a dedicated EP axis still
      carries batch outside the MoE seam — tokens are sharded over it and
      the expert exchange is what crosses it)
    - tp/sp axis: "model"      -> Megatron TP with sequence sharding
    - ep: EITHER a dedicated "ep" mesh axis (``ep > 0``: experts sharded
      over it, first-class factor of total_devices) OR implied — experts
      over "model" by default, over ("data","model") jointly when
      ``ep_over_dp`` (DeepSeek-scale expert counts)
    """
    tp: int = 1
    dp: int = 1
    pods: int = 1
    ep: int = 0                      # dedicated EP axis size (0 -> no axis;
    #                                  EP implied by ep_over_dp / "model")
    ep_over_dp: bool = False         # experts sharded over (data, model) jointly
    zero3: bool = False              # FSDP-style param gather per layer
    pp: int = 1                      # pipeline stages (reinterprets pod axis)
    remat: str = "none"              # none | selective | full
    overlap_mode: str = "decomposed" # default seam mode (overlap.VALID_MODES)
    wire_dtype: Optional[str] = None # forward-wire precision for TP seams
    #                                  (None | int8 | fp8_e4m3 | int4);
    #                                  lossy — cotangents never quantized
    max_logit_rmse: Optional[float] = None  # error budget gating the
    #                                  autotuner's wire_dtype sweep
    comm_chunks: int = 0             # 0 -> auto (=tp); medium-grained chunking
    plan_profile: Optional[str] = None  # tuned per-seam profile JSON
    #                                  (repro.tuning; stale files are ignored)
    scatter_axis: str = "auto"       # residual-stream activation layout:
    #                                  "auto" (profile/default), "seq"
    #                                  (Megatron-SP) or "hidden" (replicated)
    grad_compress: bool = False      # int8 cross-pod gradient all-reduce
    seq_shard_attn: bool = False     # shard sequence (ring attn) when heads don't divide
    fuse_w13: bool = False           # fuse parallel input projections (w1|w3,
    #                                  mamba x|z) into ONE AllGather-GEMM seam
    kernel_decode: bool = False      # fused Pallas MLA-decode attention

    @property
    def total_devices(self) -> int:
        return self.tp * self.dp * self.pods * max(self.ep, 1)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(model: ModelConfig, shape: ShapeConfig) -> bool:
    """long_500k only runs for sub-quadratic archs (SSM / hybrid)."""
    if shape.name == "long_500k" and not model.sub_quadratic:
        return False
    return True


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
ARCH_IDS: List[str] = [
    "jamba_v01_52b",
    "llama4_scout_17b_a16e",
    "deepseek_v3_671b",
    "codeqwen15_7b",
    "phi4_mini_38b",
    "qwen15_110b",
    "minicpm_2b",
    "musicgen_medium",
    "qwen2_vl_72b",
    "rwkv6_3b",
]

# the paper's own eval model (GPT-3 175B GEMM shapes come from this config)
PAPER_ARCH_IDS: List[str] = ["gpt3_175b"]


def get_config(arch: str) -> ModelConfig:
    import importlib

    arch = arch.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    import importlib

    arch = arch.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{arch}")
    if hasattr(mod, "SMOKE_CONFIG"):
        return mod.SMOKE_CONFIG
    return shrink(mod.CONFIG)


def shrink(cfg: ModelConfig, **overrides: Any) -> ModelConfig:
    """Generic reduction used for smoke testing: tiny dims, same family/pattern."""
    period = len(cfg.pattern)
    small: Dict[str, Any] = dict(
        num_layers=max(2 * period, 2),
        d_model=128,
        num_heads=4 if cfg.num_heads else 0,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads else 0,
        d_ff=256,
        vocab_size=512,
        head_dim=32 if cfg.num_heads else 0,
        leading_dense_layers=min(cfg.leading_dense_layers, 1),
        max_seq_len=4096,
    )
    if cfg.moe is not None:
        small["moe"] = dataclasses.replace(
            cfg.moe, num_experts=4, top_k=min(cfg.moe.top_k, 2), expert_ffn=128,
            shared_ffn=128 if cfg.moe.shared_ffn else 0)
    if cfg.mla is not None:
        small["mla"] = MLAConfig(q_lora_rank=64, kv_lora_rank=32,
                                 qk_nope_head_dim=32, qk_rope_head_dim=16,
                                 v_head_dim=32)
    if cfg.rwkv is not None:
        small["rwkv"] = RWKVConfig(head_dim=32, decay_lora=16)
    if cfg.mamba is not None:
        small["mamba"] = MambaConfig(d_state=8, d_conv=4, expand=2)
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
