"""Phi-4-mini 3.8B: dense, RoPE + SwiGLU + GQA.  [arXiv:2412.08905; hf]"""
from repro.configs.base import ModelConfig, shrink

CONFIG = ModelConfig(
    name="phi4_mini_38b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=200064,
    rope_style="rope",
    sub_quadratic=False,
)

SMOKE_CONFIG = shrink(CONFIG)
