"""DeepSeek-V3 671B: MLA, 1 shared + 256 routed experts top-8, MTP.
[arXiv:2412.19437; hf]

d_ff=2048 per the assigned table (the routed-expert width; the 3 leading
dense layers use the same width to honor the table exactly).
"""
from repro.configs.base import (MLA, MOE_FFN, MLAConfig, ModelConfig,
                                MoEConfig, shrink)

CONFIG = ModelConfig(
    name="deepseek_v3_671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,            # MLA: heads share the latent cache
    d_ff=2048,
    vocab_size=129280,
    head_dim=128,
    pattern=((MLA, MOE_FFN),),
    leading_dense_layers=3,
    moe=MoEConfig(num_experts=256, top_k=8, expert_ffn=2048,
                  num_shared_experts=1, shared_ffn=2048),
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    rope_style="rope",
    mtp_depth=1,
    sub_quadratic=False,
)

SMOKE_CONFIG = shrink(CONFIG)
