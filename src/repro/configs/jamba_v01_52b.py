"""Jamba-v0.1 52B: hybrid Mamba+attention 1:7 interleave, MoE 16e top-2
every other layer.  [arXiv:2403.19887; hf]"""
from repro.configs.base import (ATTN, DENSE_FFN, MAMBA, MOE_FFN, MambaConfig,
                                ModelConfig, MoEConfig, shrink)

CONFIG = ModelConfig(
    name="jamba_v01_52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    # period of 8: attention at position 4 (1:7), MoE every other layer
    pattern=(
        (MAMBA, DENSE_FFN), (MAMBA, MOE_FFN),
        (MAMBA, DENSE_FFN), (MAMBA, MOE_FFN),
        (ATTN, DENSE_FFN), (MAMBA, MOE_FFN),
        (MAMBA, DENSE_FFN), (MAMBA, MOE_FFN),
    ),
    moe=MoEConfig(num_experts=16, top_k=2, expert_ffn=14336),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
    rope_style="rope",
    sub_quadratic=True,          # mamba-dominant -> long_500k cell runs
)

SMOKE_CONFIG = shrink(CONFIG)
