"""Llama-2 70B — the paper's second model-level evaluation target
(Figs. 16/17: Megatron-LLaMA training, vLLM inference)."""
from repro.configs.base import ModelConfig, shrink

CONFIG = ModelConfig(
    name="llama2_70b",
    family="dense",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=32000,
    rope_style="rope",
    sub_quadratic=False,
)

SMOKE_CONFIG = shrink(CONFIG)
