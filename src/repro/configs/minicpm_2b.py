"""MiniCPM 2B: llama-like dense; trained with the WSD schedule (the schedule
lives in repro/optim/schedule.py and is selected by the launcher for this
arch).  [arXiv:2404.06395; hf]"""
from repro.configs.base import ModelConfig, shrink

CONFIG = ModelConfig(
    name="minicpm_2b",
    family="dense",
    num_layers=40,
    d_model=2304,
    num_heads=36,
    num_kv_heads=36,
    d_ff=5760,
    vocab_size=122753,
    rope_style="rope",
    tie_embeddings=True,
    sub_quadratic=False,
)

TRAIN_SCHEDULE = "wsd"

SMOKE_CONFIG = shrink(CONFIG)
