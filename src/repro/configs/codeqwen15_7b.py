"""CodeQwen1.5 7B: dense qwen1.5 arch.  [hf:Qwen/CodeQwen1.5-7B; hf]"""
from repro.configs.base import ModelConfig, shrink

CONFIG = ModelConfig(
    name="codeqwen15_7b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    d_ff=13440,
    vocab_size=92416,
    rope_style="rope",
    qkv_bias=True,               # qwen1.5 family uses QKV bias
    sub_quadratic=False,
)

SMOKE_CONFIG = shrink(CONFIG)
