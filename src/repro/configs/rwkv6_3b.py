"""RWKV-6 (Finch) 3B: attention-free, data-dependent decay.
[arXiv:2404.05892; hf]"""
from repro.configs.base import RWKV, ModelConfig, RWKVConfig, shrink

CONFIG = ModelConfig(
    name="rwkv6_3b",
    family="ssm",
    num_layers=32,
    d_model=2560,
    num_heads=0,                 # attention-free
    num_kv_heads=0,
    d_ff=8960,
    vocab_size=65536,
    pattern=((RWKV, RWKV),),     # time-mix + channel-mix
    rwkv=RWKVConfig(head_dim=64, decay_lora=64),
    rope_style="none",
    sub_quadratic=True,          # O(1) state decode -> long_500k runs
)

SMOKE_CONFIG = shrink(CONFIG)
