"""MusicGen-medium: decoder-only transformer over EnCodec tokens (4
codebooks).  The EnCodec frontend is a STUB: input_specs provides
precomputed frame embeddings [B, S, D].  [arXiv:2306.05284; hf]"""
from repro.configs.base import ModelConfig, shrink

CONFIG = ModelConfig(
    name="musicgen_medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    rope_style="rope",           # positional stand-in for sinusoidal
    frontend="audio_frames",
    num_codebooks=4,
    sub_quadratic=False,
)

SMOKE_CONFIG = shrink(CONFIG)
