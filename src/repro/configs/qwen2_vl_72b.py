"""Qwen2-VL 72B backbone: M-RoPE, dynamic resolution.  The vision tower is a
STUB: input_specs provides precomputed patch embeddings + 3-D position ids.
[arXiv:2409.12191; hf]"""
from repro.configs.base import ModelConfig, shrink

CONFIG = ModelConfig(
    name="qwen2_vl_72b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    rope_style="mrope",
    qkv_bias=True,
    frontend="vision_patches",
    sub_quadratic=False,
)

SMOKE_CONFIG = shrink(CONFIG)
