"""Llama-4 Scout 17B-active/16E: MoE top-1 + shared expert, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from repro.configs.base import (ATTN, MOE_FFN, ModelConfig, MoEConfig, shrink)

CONFIG = ModelConfig(
    name="llama4_scout_17b_a16e",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    pattern=((ATTN, MOE_FFN),),
    moe=MoEConfig(num_experts=16, top_k=1, expert_ffn=8192,
                  num_shared_experts=1, shared_ffn=8192),
    rope_style="rope",
    sub_quadratic=False,         # full attention -> long_500k skipped
)

SMOKE_CONFIG = shrink(CONFIG)
