"""Continuous-batching chunk scheduler: prefill chunks interleaved with decode.

Bounds head-of-line TTFT: a long prompt never monopolizes the server while
it prefills.  ``Server.begin_admission`` only RESERVES a slot + KV blocks
(O(1), no dispatch); the scheduler then runs AT MOST ONE fixed-size prefill
chunk per tick — round-robin across in-flight admissions — followed by one
decode step for every already-generating slot.  Decode therefore stalls for
at most one chunk's latency per tick regardless of prompt length, and
concurrent long prompts share the prefill lane fairly.

Timing is stamped here and in the server (the server OWNS request timing):
``t_arrival`` on submit (unless the traffic generator pre-stamped a
scheduled arrival — open-loop TTFT then includes queueing delay),
``t_first_token`` when the final prefill chunk emits token 0, ``t_finish``
on completion.
"""
from __future__ import annotations

import time
from collections import deque
from typing import TYPE_CHECKING, Deque, List

if TYPE_CHECKING:                      # avoid a runtime import cycle
    from repro.runtime.server import PrefillJob, Request, Server


class ChunkScheduler:
    def __init__(self, server: "Server"):
        self.srv = server
        self.pending: "Deque[Request]" = deque()   # FIFO admission queue
        self.jobs: "Deque[PrefillJob]" = deque()   # in-flight chunked prefills

    def submit(self, req: "Request") -> None:
        if req.t_arrival is None:
            req.t_arrival = time.perf_counter()
        self.pending.append(req)

    def has_work(self) -> bool:
        return bool(self.pending or self.jobs
                    or any(s is not None for s in self.srv.slots))

    def tick(self) -> List["Request"]:
        """One scheduling round.  Returns the requests that finished (or
        were rejected) during this tick."""
        srv = self.srv
        out: List["Request"] = []

        # 1) admissions: reserve slots + blocks for whatever fits (FIFO —
        #    a stuck head request must not be overtaken forever)
        while self.pending:
            try:
                job = srv.begin_admission(self.pending[0])
            except ValueError as e:
                req = self.pending.popleft()
                req.done = True
                req.error = str(e)
                req.t_finish = time.perf_counter()
                out.append(req)
                continue
            if job is None:
                break
            self.pending.popleft()
            self.jobs.append(job)

        # 2) ONE prefill chunk this tick (round-robin over admissions)
        if self.jobs:
            job = self.jobs.popleft()
            if srv.prefill_chunk(job):
                if job.req.done:       # finished at admission (EOS / max=1)
                    out.append(job.req)
            else:
                self.jobs.append(job)

        # 3) one decode step for every generating slot
        out.extend(srv.step())

        # Deadlock guard: nothing progressed, nothing is in flight, and
        # every slot is free — the head request needs more KV blocks than
        # the pool can EVER free.  Reject it so the queue keeps moving.
        if (not out and self.pending and not self.jobs
                and not any(s is not None for s in srv.slots)):
            req = self.pending.popleft()
            req.done = True
            req.error = (f"pool exhausted: rid {req.rid} needs "
                         f"{srv._blocks_needed(len(req.prompt))} KV blocks, "
                         f"pool holds {srv.pool.num_blocks - 1}")
            req.t_finish = time.perf_counter()
            out.append(req)
        return out
