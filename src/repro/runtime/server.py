"""Paged serving runtime: block-table KV cache + chunked-prefill batching.

vLLM-shaped but TPU/JAX-idiomatic, built on TWO fixed-shape jit programs
total (the per-bucket prefill family is gone):

* **Per-slot paged decode** — ONE ``decode_step`` dispatch advances every
  generating slot at its OWN position (``pos: [B]``), reading and writing
  K/V through each slot's block table over the shared physical pool.
  Inactive slots pass all-zero table rows: their writes land in the
  reserved null block and their outputs are discarded here.
* **Chunked prefill** — admission reserves a slot plus enough pool blocks
  for the whole request up front (prefill can never die mid-flight), then
  the prompt streams through ONE compiled ``prefill_chunk_step`` program in
  fixed ``[1, C]`` chunks with traced slot/offset/length scalars.  Cost is
  O(n/C) dispatches of a single program — no recompiles, no O(n) decode
  loop — and the scheduler interleaves chunks with decode steps so a long
  prompt cannot head-of-line-block running generations.

Prefix reuse (pure-attention archs): full prompt blocks register in the
pool's hash-chain cache; a later admission sharing a prefix acquires those
blocks instead of recomputing them and starts prefilling at the first
unmatched position.  Shared blocks are never written — copy-on-write at
the block boundary — and freed prefixes stay matchable on an LRU until the
allocator actually needs the space.

``serve`` runs the queue through the ChunkScheduler; the server OWNS
request timing (t_arrival / t_first_token / t_finish — see ``Request``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs.base import ATTN, MLA, RWKV, ModelConfig, ParallelConfig
from repro.models import model as M
from repro.models import serve as S
from repro.models.model import expanded_pattern
from repro.parallel.sharding import TPContext
from repro.runtime.kvpool import BlockTable, KVPool


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 8            # decode slots
    max_seq: int = 512
    eos_token: int = 1
    max_new_tokens: int = 64
    block_size: int = 16          # tokens per KV pool block (page)
    num_blocks: Optional[int] = None   # pool size; default guarantees
    #                                    max_batch full-length sequences
    prefill_chunk: int = 32       # chunked-prefill rows per dispatch
    prefix_reuse: bool = True     # hash-chain prefix cache (attention archs)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # [S_prompt] int32
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    error: Optional[str] = None   # set when the server rejected the request
    # timing is OWNED by the serving runtime: t_arrival at submit (or the
    # traffic generator's scheduled arrival — TTFT then includes queueing),
    # t_first_token when the final prefill chunk emits token 0, t_finish
    # at completion.  perf_counter seconds.
    t_arrival: Optional[float] = None
    t_first_token: Optional[float] = None
    t_finish: Optional[float] = None

    def ttft_s(self) -> Optional[float]:
        if self.t_arrival is None or self.t_first_token is None:
            return None
        return self.t_first_token - self.t_arrival

    def per_token_s(self) -> Optional[float]:
        """Mean inter-token latency after the first token (TPOT)."""
        if self.t_first_token is None or self.t_finish is None:
            return None
        return ((self.t_finish - self.t_first_token)
                / max(1, len(self.output) - 1))


@dataclasses.dataclass
class PrefillJob:
    """An admitted request mid-prefill: ``off`` is the next unprefilled
    prompt position (reused prefix blocks are skipped entirely)."""
    req: Request
    slot: int
    table: BlockTable
    off: int


def _arch_supports_reuse(cfg: ModelConfig) -> bool:
    """Prefix blocks are reusable only when EVERY layer's sequence memory
    lives in the paged pool.  Recurrent families (Mamba SSM/conv, RWKV
    wkv/token-shift) fold history into dense states that are not
    block-addressable, so hybrids keep paging + eviction but skip the
    prefix cache."""
    return all(mk in (ATTN, MLA) and fk != RWKV
               for mk, fk in expanded_pattern(cfg))


class Server:
    def __init__(self, cfg: ModelConfig, par: ParallelConfig, mesh,
                 params, sc: ServeConfig):
        self.cfg = cfg
        self.par = par
        self.mesh = mesh
        self.sc = sc
        self.params = params
        from repro.tuning import plan_set_from_parallel
        # paged serving is PER-REPLICA (slots fill from a local queue), so
        # the context carries no dp axes and every program spec is
        # model-axis only; both programs force the replicated activation
        # layout internally (decode: S=1; chunk prefill: bounded C).
        self.ctx = TPContext(axis="model", dp_axes=(),
                             ep_axes=M._ep_axes(cfg, par),
                             mode=par.overlap_mode,
                             plans=plan_set_from_parallel(par))
        params_eval = jax.eval_shape(
            lambda: M.init_model(jax.random.PRNGKey(0), cfg, par))
        self.pspecs = M.param_specs(cfg, par, params_eval)

        self.pages = -(-sc.max_seq // sc.block_size)   # table width
        nb = sc.num_blocks or (sc.max_batch * self.pages + 1)
        self.pool = KVPool(nb, sc.block_size)
        # what a dense [max_batch, max_seq] cache would pin, in blocks —
        # the paged footprint baseline for benchmarks/tests
        self.dense_equiv_blocks = sc.max_batch * self.pages
        cache_sds, self.cache_specs = S.paged_cache_specs(
            cfg, par, nb, sc.block_size, sc.max_batch)
        self.caches = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                                   cache_sds)
        self.positions = np.zeros((sc.max_batch,), np.int32)
        self.slots: List[Optional[Request]] = [None] * sc.max_batch
        self.ready: List[bool] = [False] * sc.max_batch  # prefill complete
        self.tables: List[Optional[BlockTable]] = [None] * sc.max_batch
        self._decode = self._make_decode()
        self._chunk = self._make_chunk()
        self._reuse_ok = sc.prefix_reuse and _arch_supports_reuse(cfg)
        self.prefill_dispatches = 0                 # observability/tests
        self.decode_dispatches = 0

    def _make_decode(self):
        ctx, cfg, par = self.ctx, self.cfg, self.par

        def fn(params, caches, tokens, pos, bt, active):
            return S.decode_step(params, caches, tokens, pos, ctx, cfg, par,
                                 block_tables=bt, active=active)

        sm = compat.shard_map(
            fn, mesh=self.mesh,
            in_specs=(self.pspecs, self.cache_specs, P(None, None), P(None),
                      P(None, None), P(None)),
            out_specs=(P(None, None), self.cache_specs),
            check_vma=False)
        return jax.jit(sm, donate_argnums=(1,))

    def _make_chunk(self):
        """The ONE prefill program: tokens [1, C], table row [1, pages],
        traced int32 slot/off/chunk_len scalars — every prompt length and
        every slot runs the same compiled signature."""
        ctx, cfg, par = self.ctx, self.cfg, self.par

        def fn(params, caches, tokens, bt, slot, off, chunk_len):
            return S.prefill_chunk_step(params, caches, tokens, bt, slot,
                                        off, chunk_len, ctx, cfg, par)

        sm = compat.shard_map(
            fn, mesh=self.mesh,
            in_specs=(self.pspecs, self.cache_specs, P(None, None),
                      P(None, None), P(), P(), P()),
            out_specs=(P(None, None), self.cache_specs),
            check_vma=False)
        return jax.jit(sm, donate_argnums=(1,))

    # ------------------------------------------------------------ admission
    def _blocks_needed(self, n: int) -> int:
        """Blocks reserved at admission: the whole request horizon (prompt
        + generation, clipped to max_seq) so decode NEVER allocates — a
        running request cannot die to pool pressure mid-flight."""
        horizon = min(n + self.sc.max_new_tokens, self.sc.max_seq)
        return min(-(-horizon // self.sc.block_size), self.pages)

    def begin_admission(self, req: Request) -> Optional[PrefillJob]:
        """Reserve a slot + KV blocks for a request (no dispatch).  Returns
        None when no slot is free or the pool cannot cover the request;
        raises ValueError for prompts that can never be served.  On
        success the returned job's ``off`` skips any reused prefix."""
        slot = next((i for i, cur in enumerate(self.slots) if cur is None),
                    None)
        if slot is None:
            return None
        n = len(req.prompt)
        if not 0 < n < self.sc.max_seq:
            raise ValueError(f"prompt length {n} outside (0, "
                             f"{self.sc.max_seq}) for rid {req.rid}")
        if req.t_arrival is None:
            req.t_arrival = time.perf_counter()
        matched: List[int] = []
        n_cached = 0
        if self._reuse_ok:
            matched, n_cached = self.pool.match_prefix(req.prompt)
        need = self._blocks_needed(n) - len(matched)
        if not self.pool.can_allocate(need):
            self.pool.release(matched)       # registered -> back to the LRU
            return None
        blocks = matched + self.pool.allocate(need)
        self.pool.note_reuse(len(matched))
        table = BlockTable(blocks, n_reused=len(matched))
        self.slots[slot] = req
        self.ready[slot] = False
        self.positions[slot] = 0
        self.tables[slot] = table
        return PrefillJob(req=req, slot=slot, table=table, off=n_cached)

    def prefill_chunk(self, job: PrefillJob) -> bool:
        """Dispatch ONE fixed-shape prefill chunk.  Returns True when the
        prompt is fully prefilled (first token emitted, slot generating)."""
        req, slot = job.req, job.slot
        n = len(req.prompt)
        c = self.sc.prefill_chunk
        clen = min(c, n - job.off)
        toks = np.zeros((1, c), np.int32)
        toks[0, :clen] = req.prompt[job.off:job.off + clen]
        bt = job.table.as_array(self.pages)[None]
        nxt, self.caches = self._chunk(
            self.params, self.caches, jnp.asarray(toks), jnp.asarray(bt),
            jnp.asarray(slot, jnp.int32), jnp.asarray(job.off, jnp.int32),
            jnp.asarray(clen, jnp.int32))
        self.prefill_dispatches += 1
        job.off += clen
        if job.off < n:
            return False
        # final chunk: its row clen-1 is the prompt's last position
        self.positions[slot] = n
        self.ready[slot] = True
        req.output.append(int(np.asarray(nxt)[0, 0]))
        req.t_first_token = time.perf_counter()
        if self._reuse_ok:
            # now-immutable FULL prompt blocks become reusable by later
            # admissions (the trailing partial block keeps growing under
            # decode — never shared)
            self.pool.register(
                job.table.blocks[:n // self.sc.block_size], req.prompt)
        self._finish_if_done(slot)
        return True

    def admit(self, req: Request) -> bool:
        """Synchronous admission: reserve, then run every prefill chunk
        back-to-back (O(n/C) dispatches of the one chunk program).  The
        scheduler path (``serve``) interleaves chunks with decode instead.
        Returns False when no slot or insufficient pool blocks are free."""
        job = self.begin_admission(req)
        if job is None:
            return False
        while not self.prefill_chunk(job):
            pass
        return True

    # --------------------------------------------------------------- decode
    def _finish_if_done(self, i: int) -> Optional[Request]:
        req = self.slots[i]
        if req is None:
            return None
        if (req.output[-1] == self.sc.eos_token
                or len(req.output) >= self.sc.max_new_tokens
                or self.positions[i] >= self.sc.max_seq - 1):
            req.done = True
            req.t_finish = time.perf_counter()
            self.pool.release(self.tables[i].blocks)
            self.tables[i] = None
            self.ready[i] = False
            self.slots[i] = None
            self.positions[i] = 0
            return req
        return None

    def step(self) -> List[Request]:
        """One decode step for every GENERATING slot — each at its own
        position through its own block-table row.  Mid-prefill slots pass
        zero rows (attention writes land in the null block) and a False
        ``active`` flag (their dense Mamba/RWKV state rows — threaded
        across prefill chunks — stay frozen), and are skipped on
        readback."""
        if not any(self.ready):
            return []
        b = self.sc.max_batch
        toks = np.zeros((b, 1), np.int32)
        bts = np.zeros((b, self.pages), np.int32)
        active = np.zeros((b,), bool)
        for i, req in enumerate(self.slots):
            if req is not None and self.ready[i]:
                active[i] = True
                toks[i, 0] = req.output[-1]
                bts[i] = self.tables[i].as_array(self.pages)
        nxt, self.caches = self._decode(self.params, self.caches,
                                        jnp.asarray(toks),
                                        jnp.asarray(self.positions),
                                        jnp.asarray(bts),
                                        jnp.asarray(active))
        self.decode_dispatches += 1
        nxt = np.asarray(nxt)
        finished: List[Request] = []
        for i, req in enumerate(self.slots):
            if req is None or not self.ready[i]:
                continue
            req.output.append(int(nxt[i, 0]))
            self.positions[i] += 1
            fin = self._finish_if_done(i)
            if fin is not None:
                finished.append(fin)
        return finished

    def serve(self, requests: List[Request]) -> List[Request]:
        """Run a request queue to completion through the chunk scheduler.
        Completion is tracked by rid (each finished request drains exactly
        once)."""
        from repro.runtime.scheduler import ChunkScheduler
        sched = ChunkScheduler(self)
        for req in requests:
            sched.submit(req)
        done: List[Request] = []
        done_rids = set()
        while sched.has_work():
            for fin in sched.tick():
                if fin.rid not in done_rids:
                    done_rids.add(fin.rid)
                    done.append(fin)
        return done
