"""Batched serving runtime: continuous batching over prefill/decode steps.

vLLM-shaped but TPU/JAX-idiomatic, built on two fixed-shape jit programs:

* **Per-slot decode** — ONE ``decode_step`` dispatch advances every active
  slot at its OWN position (``pos: [B]`` vector; per-row RoPE, per-row
  causal mask, per-row KV writes).  Slots at staggered sequence positions
  never touch each other's cache rows, so continuous batching of
  mixed-length requests is numerically identical to serving each request
  alone.
* **Batched-prefill admission** — ``admit`` pads the prompt into a
  power-of-two length bucket, runs ONE ``prefill_step`` dispatch (per-row
  ``lengths`` keep the caches exact under right-padding, including the
  Mamba/RWKV recurrent states), and scatters the resulting cache tree into
  the target slot's rows with one donated ``dynamic_update_slice`` program.
  Admission is O(1) dispatches — never an O(prompt_len) decode loop — and
  never writes another slot's rows.

Finished slots (EOS or max_len) are recycled; ``serve`` tracks completion
by request id and drains each finished request exactly once.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs.base import ModelConfig, ParallelConfig
from repro.models import model as M
from repro.models import serve as S
from repro.parallel.sharding import TPContext


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 8            # decode slots
    max_seq: int = 512
    eos_token: int = 1
    max_new_tokens: int = 64


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # [S_prompt] int32
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    error: Optional[str] = None   # set when the server rejected the request


def _prefill_bucket(n: int, max_seq: int, tp: int = 1) -> int:
    """Power-of-two length bucket (>= 8) for the admission prefill jit —
    bounds recompiles to O(log max_seq) signatures.  The bucket must divide
    by ``tp`` (sequence-sharded prefill: embed psum_scatter / seam gathers)
    and fit the server cache (<= max_seq)."""
    b = 8
    while b < n:
        b *= 2
    if b % tp:
        b = -(-b // tp) * tp
    if b > max_seq:
        b = (max_seq // tp) * tp          # largest tp-divisible pad length
    if b < n:
        raise ValueError(
            f"prompt length {n} does not fit a tp={tp}-divisible prefill "
            f"pad within max_seq={max_seq}")
    return b


class Server:
    def __init__(self, cfg: ModelConfig, par: ParallelConfig, mesh,
                 params, sc: ServeConfig):
        self.cfg = cfg
        self.par = par
        self.mesh = mesh
        self.sc = sc
        self.params = params
        dp_axes = tuple(a for a in ("pod", "ep", "data")
                        if a in mesh.axis_names)
        from repro.tuning import plan_set_from_parallel
        # ONE context for both dispatch programs: prefill runs the plans'
        # resolved activation layout (sequence-sharded by default — the SP
        # residency win applies to the longest activations the server
        # touches), while decode_step internally forces the replicated
        # layout (S=1 cannot shard).
        self.ctx = TPContext(axis="model", dp_axes=dp_axes,
                             ep_axes=M._ep_axes(cfg, par),
                             mode=par.overlap_mode,
                             plans=plan_set_from_parallel(par))
        params_eval = jax.eval_shape(
            lambda: M.init_model(jax.random.PRNGKey(0), cfg, par))
        self.pspecs = M.param_specs(cfg, par, params_eval)
        cache_sds, self.cache_specs = S.cache_specs(
            cfg, par, sc.max_batch, sc.max_seq, dp_axes=dp_axes or ("data",))
        self.caches = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                                   cache_sds)
        self.positions = np.zeros((sc.max_batch,), np.int32)
        self.slots: List[Optional[Request]] = [None] * sc.max_batch
        self._decode = self._make_decode()
        self._prefill_fns: Dict[int, object] = {}   # bucket len -> jit
        self._scatter = jax.jit(self._scatter_impl, donate_argnums=(0,))
        self.prefill_dispatches = 0                 # observability/tests
        self.decode_dispatches = 0

    def _dp_spec(self):
        dp = self.ctx.dp_axes
        return dp if len(dp) > 1 else (dp[0] if dp else None)

    def _make_decode(self):
        ctx, cfg, par = self.ctx, self.cfg, self.par
        dp_spec = self._dp_spec()

        def fn(params, caches, tokens, pos):
            return S.decode_step(params, caches, tokens, pos, ctx, cfg, par)

        sm = compat.shard_map(
            fn, mesh=self.mesh,
            in_specs=(self.pspecs, self.cache_specs, P(dp_spec, None),
                      P(dp_spec)),
            out_specs=(P(dp_spec, None), self.cache_specs),
            check_vma=False)
        return jax.jit(sm, donate_argnums=(1,))

    def _make_prefill(self, s_pad: int):
        """One-request prefill program for a prompt-length bucket: tokens
        [1, s_pad] (replicated over DP — batch 1 cannot shard), per-row
        ``lengths`` masking the right-padding."""
        ctx, cfg, par = self.ctx, self.cfg, self.par
        _, cspecs = S.cache_specs(cfg, par, 1, s_pad, dp_axes=())

        def fn(params, tokens, lengths):
            return S.prefill_step(params, {"tokens": tokens}, ctx, cfg, par,
                                  lengths=lengths)

        sm = compat.shard_map(
            fn, mesh=self.mesh,
            in_specs=(self.pspecs, P(None, None), P(None)),
            out_specs=(P(None, None), cspecs),
            check_vma=False)
        return jax.jit(sm)

    @staticmethod
    def _scatter_impl(caches, pcaches, slot):
        """Write a batch-1 prefill cache tree into one slot's rows.  Seq
        dims shorter than the server cache update only the prefix (rows
        beyond the prompt stay untouched and masked until decode overwrites
        them).  Other slots' rows are never written."""
        zero = jnp.asarray(0, jnp.int32)

        def at(axis):
            def leaf(c, pc):
                starts = [zero] * c.ndim
                starts[axis] = slot
                return jax.lax.dynamic_update_slice(
                    c, pc.astype(c.dtype), starts)
            return leaf

        # lead leaves are [B, ...]; scanned period leaves carry a leading
        # repetition axis: [reps, B, ...]
        return {"lead": jax.tree.map(at(0), caches["lead"], pcaches["lead"]),
                "periods": jax.tree.map(at(1), caches["periods"],
                                        pcaches["periods"])}

    # ------------------------------------------------------------------ API
    def admit(self, req: Request) -> bool:
        """Prefill a request into a free slot: ONE batched ``prefill_step``
        dispatch on the bucket-padded prompt + one cache scatter into the
        slot's rows.  Returns False when no slot is free."""
        slot = next((i for i, cur in enumerate(self.slots) if cur is None),
                    None)
        if slot is None:
            return False
        n = len(req.prompt)
        if not 0 < n < self.sc.max_seq:
            raise ValueError(f"prompt length {n} outside (0, "
                             f"{self.sc.max_seq}) for rid {req.rid}")
        s_pad = _prefill_bucket(n, self.sc.max_seq, self.par.tp)
        toks = np.zeros((1, s_pad), np.int32)
        toks[0, :n] = req.prompt
        fn = self._prefill_fns.get(s_pad)
        if fn is None:
            fn = self._prefill_fns[s_pad] = self._make_prefill(s_pad)
        nxt, pcaches = fn(self.params, jnp.asarray(toks),
                          jnp.asarray([n], jnp.int32))
        self.prefill_dispatches += 1
        self.caches = self._scatter(self.caches, pcaches,
                                    jnp.asarray(slot, jnp.int32))
        self.slots[slot] = req
        self.positions[slot] = n
        req.output.append(int(np.asarray(nxt)[0, 0]))
        self._finish_if_done(slot)
        return True

    def _finish_if_done(self, i: int) -> Optional[Request]:
        req = self.slots[i]
        if req is None:
            return None
        if (req.output[-1] == self.sc.eos_token
                or len(req.output) >= self.sc.max_new_tokens
                or self.positions[i] >= self.sc.max_seq - 1):
            req.done = True
            self.slots[i] = None
            self.positions[i] = 0
            return req
        return None

    def step(self) -> List[Request]:
        """One decode step for every active slot — each at its OWN position.
        Returns the requests that finished on this step."""
        if not any(s is not None for s in self.slots):
            return []
        toks = np.zeros((self.sc.max_batch, 1), np.int32)
        for i, req in enumerate(self.slots):
            if req is not None and req.output:
                toks[i, 0] = req.output[-1]
        nxt, self.caches = self._decode(self.params, self.caches,
                                        jnp.asarray(toks),
                                        jnp.asarray(self.positions))
        self.decode_dispatches += 1
        nxt = np.asarray(nxt)
        finished: List[Request] = []
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            req.output.append(int(nxt[i, 0]))
            self.positions[i] += 1
            fin = self._finish_if_done(i)
            if fin is not None:
                finished.append(fin)
        return finished

    def serve(self, requests: List[Request]) -> List[Request]:
        """Run a request queue to completion.  Completion is tracked by rid
        (each finished request drains exactly once — O(1) per step, no
        full-queue rescans)."""
        pending = deque(requests)
        done: List[Request] = []
        done_rids = set()

        def drain(req: Optional[Request]) -> None:
            if req is not None and req.rid not in done_rids:
                done_rids.add(req.rid)
                done.append(req)

        while pending or any(s is not None for s in self.slots):
            while pending:
                try:
                    admitted = self.admit(pending[0])
                except ValueError as e:
                    # unadmittable request (e.g. prompt >= max_seq): reject
                    # it gracefully and keep serving — one bad prompt must
                    # not kill every other in-flight request
                    req = pending.popleft()
                    req.done = True
                    req.error = str(e)
                    drain(req)
                    continue
                if not admitted:
                    break
                req = pending.popleft()
                if req.done:                  # finished at admission (EOS /
                    drain(req)                # max_new_tokens == 1)
            for fin in self.step():
                drain(fin)
        return done
