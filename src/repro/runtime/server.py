"""Batched serving runtime: continuous batching over prefill/decode steps.

vLLM-shaped but TPU/JAX-idiomatic: fixed-shape decode batches (static jit
signatures), slot-based KV cache with per-slot position counters, greedy
sampling.  Requests are admitted into free slots after a prefill; finished
slots (EOS or max_len) are recycled.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs.base import ModelConfig, ParallelConfig
from repro.models import model as M
from repro.models import serve as S
from repro.parallel.sharding import TPContext


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 8            # decode slots
    max_seq: int = 512
    eos_token: int = 1
    max_new_tokens: int = 64


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # [S_prompt] int32
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class Server:
    def __init__(self, cfg: ModelConfig, par: ParallelConfig, mesh,
                 params, sc: ServeConfig):
        self.cfg = cfg
        self.par = par
        self.mesh = mesh
        self.sc = sc
        self.params = params
        dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        from repro.tuning import plan_set_from_parallel
        self.ctx = TPContext(axis="model", dp_axes=dp_axes,
                             ep_axes=("model",) if cfg.moe else (),
                             mode=par.overlap_mode,
                             plans=plan_set_from_parallel(par))
        params_eval = jax.eval_shape(
            lambda: M.init_model(jax.random.PRNGKey(0), cfg, par))
        self.pspecs = M.param_specs(cfg, par, params_eval)
        cache_sds, self.cache_specs = S.cache_specs(
            cfg, par, sc.max_batch, sc.max_seq, dp_axes=dp_axes or ("data",))
        self.caches = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                                   cache_sds)
        self.positions = np.zeros((sc.max_batch,), np.int32)
        self.slots: List[Optional[Request]] = [None] * sc.max_batch
        self._decode = self._make_decode()
        self._prefill_cache: Dict[int, object] = {}

    def _make_decode(self):
        ctx, cfg, par = self.ctx, self.cfg, self.par
        dp = self.ctx.dp_axes
        dp_spec = dp if len(dp) > 1 else (dp[0] if dp else None)

        def fn(params, caches, tokens, pos):
            return S.decode_step(params, caches, tokens, pos, ctx, cfg, par)

        sm = compat.shard_map(
            fn, mesh=self.mesh,
            in_specs=(self.pspecs, self.cache_specs, P(dp_spec, None), P()),
            out_specs=(P(dp_spec, None), self.cache_specs),
            check_vma=False)
        return jax.jit(sm, donate_argnums=(1,))

    # ------------------------------------------------------------------ API
    def admit(self, req: Request) -> bool:
        """Prefill a request into a free slot (single-slot prefill: feeds the
        prompt token-by-token through decode_step — correct for every arch
        family; batched flash prefill is the prefill_step path used at
        scale)."""
        for slot, cur in enumerate(self.slots):
            if cur is None:
                self.slots[slot] = req
                toks = np.zeros((self.sc.max_batch, 1), np.int32)
                for t_idx, tok in enumerate(req.prompt):
                    toks[slot, 0] = tok
                    nxt, self.caches = self._decode(
                        self.params, self.caches, jnp.asarray(toks),
                        jnp.asarray(t_idx, jnp.int32))
                self.positions[slot] = len(req.prompt)
                req.output.append(int(np.asarray(nxt)[slot, 0]))
                return True
        return False

    def step(self) -> None:
        """One decode step for every active slot."""
        if not any(s is not None for s in self.slots):
            return
        toks = np.zeros((self.sc.max_batch, 1), np.int32)
        for i, req in enumerate(self.slots):
            if req is not None and req.output:
                toks[i, 0] = req.output[-1]
        pos = int(max(self.positions[i] for i, r in enumerate(self.slots)
                      if r is not None))
        nxt, self.caches = self._decode(self.params, self.caches,
                                        jnp.asarray(toks),
                                        jnp.asarray(pos, jnp.int32))
        nxt = np.asarray(nxt)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            tok = int(nxt[i, 0])
            req.output.append(tok)
            self.positions[i] += 1
            if (tok == self.sc.eos_token
                    or len(req.output) >= self.sc.max_new_tokens
                    or self.positions[i] >= self.sc.max_seq - 1):
                req.done = True
                self.slots[i] = None

    def serve(self, requests: List[Request]) -> List[Request]:
        pending = list(requests)
        done: List[Request] = []
        while pending or any(s is not None for s in self.slots):
            while pending and self.admit(pending[0]):
                pending.pop(0)
            self.step()
            for r in requests:
                if r.done and r not in done:
                    done.append(r)
        return done
