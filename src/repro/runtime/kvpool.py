"""Paged KV-cache block pool: fixed-size blocks, refcounts, prefix reuse.

Host-side allocator for the serving runtime (no device arrays move through
here): physical KV storage lives in ``[num_blocks, block_size, ...]`` pool
leaves, and each decode slot owns a BLOCK TABLE — logical block ``i`` of
the slot's sequence maps to physical block ``table.blocks[i]``.  Block ids
are layer-agnostic: one allocation addresses every layer's pool leaf.

Reuse contract (vLLM-style; copy-on-write reduces to the block boundary):

* Only FULL, immutable prompt blocks are ever shared.  Blocks register
  under a TOKEN-HASH CHAIN key — nested ``(parent_key, block_tokens)``
  tuples — so a lookup hit guarantees the ENTIRE prefix matches by exact
  tuple equality (python dict hashing; no hash-collision false positives).
* ``match_prefix`` acquires the longest registered chain, capped at the
  prompt length minus one token: the final position always recomputes so
  admission still produces the first generated token's logits.
* A shared block is never written — writes continue in freshly allocated
  blocks from the first unmatched position.  That IS copy-on-write at the
  block boundary: there is no partial-block sharing to copy.
* ``release`` drops a reference.  Refcount-0 registered blocks move to an
  LRU of evictable prefixes (still matchable — a later admission
  resurrects them for free); eviction recycles the least-recently-freed
  one only when the free list runs dry.

Physical block 0 is the reserved NULL block: never allocated, the write
target for masked pad rows and inactive decode slots
(``layers.pool_update_rows`` redirects there).
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict, deque
from typing import Dict, List, Sequence, Tuple

import numpy as np


class PoolExhausted(RuntimeError):
    """No free or evictable block available for an allocation."""


@dataclasses.dataclass
class BlockTable:
    """A slot's logical -> physical block mapping."""
    blocks: List[int]
    n_reused: int = 0          # leading blocks acquired from the prefix cache

    def as_array(self, pages: int) -> np.ndarray:
        """Fixed-width [pages] int32 row for the decode/chunk programs;
        unassigned logical blocks point at the null block (0)."""
        arr = np.zeros((pages,), np.int32)
        arr[:len(self.blocks)] = self.blocks
        return arr


class KVPool:
    """Ref-counted block allocator with hash-chain prefix reuse + LRU
    eviction.  ``blocks_in_use`` counts referenced blocks only — cached
    refcount-0 prefixes are reclaimable and excluded (they are free
    capacity that happens to still be matchable)."""

    NULL = 0

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 2:
            raise ValueError(f"num_blocks={num_blocks}: need >= 2 (block 0 "
                             "is the reserved null block)")
        if block_size < 1:
            raise ValueError(f"block_size={block_size}")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._free = deque(range(1, num_blocks))
        self._ref: Dict[int, int] = {}             # bid -> refcount (>= 1)
        self._key_of: Dict[int, tuple] = {}        # registered bid -> chain key
        self._by_key: Dict[tuple, int] = {}        # chain key -> bid
        self._lru: "OrderedDict[int, None]" = OrderedDict()  # evictable bids
        # counters (benchmarks / regression tests read these)
        self.reuse_hits = 0            # admissions that reused >= 1 block
        self.reused_tokens = 0         # prompt tokens skipped via reuse
        self.evictions = 0
        self.peak_blocks_in_use = 0

    # ------------------------------------------------------------ accounting
    @property
    def blocks_in_use(self) -> int:
        return len(self._ref)

    def available(self) -> int:
        return len(self._free) + len(self._lru)

    def can_allocate(self, n: int) -> bool:
        return n <= self.available()

    def _track_peak(self) -> None:
        if len(self._ref) > self.peak_blocks_in_use:
            self.peak_blocks_in_use = len(self._ref)

    # --------------------------------------------------------------- hashing
    def chain_keys(self, tokens: Sequence[int]) -> List[tuple]:
        """One key per FULL block prefix of ``tokens``: key_i embeds
        key_{i-1}, so equal keys imply equal full prefixes."""
        bs = self.block_size
        keys: List[tuple] = []
        parent: tuple = ()
        for i in range(len(tokens) // bs):
            parent = (parent, tuple(int(t) for t in tokens[i * bs:(i + 1) * bs]))
            keys.append(parent)
        return keys

    # ----------------------------------------------------------- reuse paths
    def match_prefix(self, tokens: Sequence[int]) -> Tuple[List[int], int]:
        """Longest-prefix-match against registered blocks: returns the
        acquired block ids (refcount bumped; caller owns a reference) and
        the number of prompt tokens they cover.  Capped at ``len(tokens) -
        1`` so at least one position always recomputes.  Counters are NOT
        updated here — call ``note_reuse`` once the admission commits
        (a failed admission releases the blocks without counting)."""
        cap = max(0, (len(tokens) - 1) // self.block_size)
        got: List[int] = []
        for key in self.chain_keys(tokens)[:cap]:
            bid = self._by_key.get(key)
            if bid is None:
                break
            got.append(bid)
        for bid in got:
            self._acquire(bid)
        # peak_blocks_in_use is NOT updated here: a failed admission
        # releases these blocks again, and counting them would overstate
        # the concurrent footprint.  ``allocate`` / ``note_reuse`` track
        # the peak once the admission's full block set is committed.
        return got, len(got) * self.block_size

    def note_reuse(self, n_blocks: int) -> None:
        """Count a committed admission's reuse (see ``match_prefix``)."""
        if n_blocks > 0:
            self.reuse_hits += 1
            self.reused_tokens += n_blocks * self.block_size
        self._track_peak()

    def _acquire(self, bid: int) -> None:
        if bid in self._ref:
            self._ref[bid] += 1
        else:                          # cached refcount-0 prefix: resurrect
            self._lru.pop(bid)
            self._ref[bid] = 1

    def register(self, blocks: Sequence[int], tokens: Sequence[int]) -> None:
        """Hash-register a freshly prefilled table's FULL prompt blocks so
        later admissions can reuse them.  Already-registered ids keep
        their key; a key another block already owns is left to that block
        (two racing identical prompts dedup to the first)."""
        for bid, key in zip(blocks, self.chain_keys(tokens)):
            if bid in self._key_of or key in self._by_key:
                continue
            self._key_of[bid] = key
            self._by_key[key] = bid

    # ---------------------------------------------------------- alloc / free
    def allocate(self, n: int) -> List[int]:
        """Take ``n`` fresh blocks (refcount 1), evicting least-recently-
        freed cached prefixes if the free list runs dry."""
        if not self.can_allocate(n):
            raise PoolExhausted(f"need {n} blocks, "
                                f"{self.available()} available")
        out: List[int] = []
        for _ in range(n):
            if not self._free:
                self._evict_one()
            bid = self._free.popleft()
            self._ref[bid] = 1
            out.append(bid)
        self._track_peak()
        return out

    def _evict_one(self) -> None:
        bid, _ = self._lru.popitem(last=False)     # least recently freed
        del self._by_key[self._key_of.pop(bid)]
        self._free.append(bid)
        self.evictions += 1

    def release(self, blocks: Sequence[int]) -> None:
        """Drop one reference per block.  Registered blocks whose refcount
        hits 0 stay matchable on the eviction LRU; unregistered ones
        return to the free list immediately."""
        for bid in blocks:
            r = self._ref[bid] - 1
            if r > 0:
                self._ref[bid] = r
                continue
            del self._ref[bid]
            if bid in self._key_of:
                self._lru[bid] = None
                self._lru.move_to_end(bid)         # most recently freed
            else:
                self._free.append(bid)
