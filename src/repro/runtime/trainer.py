"""Fault-tolerant training runtime.

- one jitted shard_map'd train step (model fwd+bwd, hierarchical grad sync,
  ZeRO-1 AdamW) with donated params/opt-state,
- checkpoint/restart (async sharded saves; exact data-stream reseek),
- step retry + reload-on-failure,
- straggler detection (step-time EWMA watchdog),
- elastic restart hook (rebuild mesh from survivors, reshard from the last
  checkpoint) — exercised by tests via simulated failures.
"""
from __future__ import annotations

import dataclasses
import functools
import logging
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.base import ModelConfig, ParallelConfig
from repro.data.pipeline import DataConfig, batch_at
from repro.models import model as M
from repro.optim import adamw, schedule as sched
from repro.parallel.sharding import TPContext

log = logging.getLogger("repro.trainer")


@dataclasses.dataclass
class TrainConfig:
    total_steps: int = 100
    warmup_steps: int = 10
    base_lr: float = 3e-4
    schedule: str = "cosine"
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 50
    log_every: int = 10
    straggler_factor: float = 3.0      # step slower than EWMA*factor -> flag
    max_retries: int = 2
    seed: int = 0


def make_ctx(cfg: ModelConfig, par: ParallelConfig, mesh,
             plans=None) -> TPContext:
    # a dedicated "ep" axis also carries batch: tokens live on their own EP
    # slice and only the moe_a2a seam crosses it
    dp_axes = tuple(a for a in ("pod", "ep", "data") if a in mesh.axis_names)
    ep_axes = M._ep_axes(cfg, par)
    if plans is None:
        # uniform PlanSet from overlap_mode, overlaid with par.plan_profile
        # (the tuned per-seam profile) when present and fresh
        from repro.tuning import plan_set_from_parallel
        plans = plan_set_from_parallel(par)
    return TPContext(axis="model", dp_axes=dp_axes, ep_axes=ep_axes,
                     mode=par.overlap_mode, comm_chunks=par.comm_chunks,
                     use_kernels=par.kernel_decode, plans=plans)


def batch_pspecs(cfg: ModelConfig, mesh, seq_sharded: bool = True) -> Dict:
    """Batch specs at the shard_map boundary.  Frontend embeds arrive in
    the residual-stream layout (``sharding.activation_spec``): sequence on
    the model axis under SP, replicated otherwise; tokens/labels are always
    full-sequence (the embedding's collective produces the layout)."""
    from repro.parallel.sharding import activation_spec
    dp_axes = tuple(a for a in ("pod", "ep", "data") if a in mesh.axis_names)
    dp = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    if cfg.frontend:
        return {"embeds": activation_spec(dp_axes, seq_sharded),
                "labels": P(dp, None)}
    return {"tokens": P(dp, None), "labels": P(dp, None)}


def make_train_step(cfg: ModelConfig, par: ParallelConfig, mesh,
                    opt_cfg: adamw.AdamWConfig, train_cfg: TrainConfig,
                    param_spec_tree) -> Callable:
    """Returns jitted (params, opt, batch, step) -> (params, opt, metrics)."""
    ctx = make_ctx(cfg, par, mesh)
    pod_axis = "pod" if "pod" in mesh.axis_names else None
    ep_axis = "ep" if "ep" in mesh.axis_names else None
    ep_n = dict(zip(mesh.axis_names, mesh.devices.shape)).get("ep", 1)
    model_rep = adamw.model_replicated_tree(param_spec_tree)
    ep_rep = (adamw.axis_replicated_tree(param_spec_tree, "ep")
              if ep_axis else None)
    schedule_fn = sched.get_schedule(train_cfg.schedule)
    # batch layout follows the plans' resolved residual layout (the
    # trainer's backward rides the interchanged seam ops either way)
    bspecs = batch_pspecs(cfg, mesh, ctx.seq_sharded)

    params_eval = jax.eval_shape(
        lambda: M.init_model(jax.random.PRNGKey(0), cfg, par))
    opt_specs = adamw.opt_state_specs(param_spec_tree, params_eval,
                                      par.dp, par.tp, ep=max(par.ep, 1))

    def step_fn(params, opt, batch, step):
        loss, grads = jax.value_and_grad(
            lambda p: M.forward_loss(p, batch, ctx, cfg, par))(params)
        # model-replicated leaves: complete their grads over the TP axis
        grads = jax.tree.map(
            lambda g, rep: lax.psum(g, "model") if rep else g,
            grads, model_rep)
        if ep_axis is not None:
            # dedicated EP axis: ep-replicated leaves carry per-EP-shard
            # partial grads (the EP axis shards the batch) -> average them;
            # the EP-sharded expert leaves already SUM every EP rank's token
            # contribution through the a2a backward -> rescale that sum into
            # the same per-shard average
            grads = jax.tree.map(
                lambda g, rep: lax.pmean(g, ep_axis) if rep else g / ep_n,
                grads, ep_rep)
        loss = lax.pmean(loss, ctx.dp_axes)
        lr = schedule_fn(step, base_lr=train_cfg.base_lr,
                         warmup=train_cfg.warmup_steps,
                         total=train_cfg.total_steps)
        params, opt = adamw.adamw_update(
            params, grads, opt, opt_cfg, lr, specs=param_spec_tree,
            dp_axis="data", pod_axis=pod_axis, ep_axis=ep_axis,
            grad_compress=par.grad_compress)
        metrics = {"loss": loss, "lr": lr,
                   "grad_count": opt["count"].astype(jnp.float32)}
        return params, opt, metrics

    sm = compat.shard_map(
        step_fn, mesh=mesh,
        in_specs=(param_spec_tree, opt_specs, bspecs, P()),
        out_specs=(param_spec_tree, opt_specs, {"loss": P(), "lr": P(),
                                                "grad_count": P()}),
        check_vma=False)
    return jax.jit(sm, donate_argnums=(0, 1))


class Trainer:
    def __init__(self, cfg: ModelConfig, par: ParallelConfig, mesh,
                 train_cfg: TrainConfig,
                 opt_cfg: Optional[adamw.AdamWConfig] = None):
        self.cfg = cfg
        self.par = par
        self.mesh = mesh
        self.tc = train_cfg
        self.oc = opt_cfg or adamw.AdamWConfig(lr=train_cfg.base_lr)
        self.step = 0
        self.failures = 0
        self.straggler_events = 0
        self._ewma: Optional[float] = None

        params_eval = jax.eval_shape(
            lambda: M.init_model(jax.random.PRNGKey(train_cfg.seed), cfg, par))
        self.pspecs = M.param_specs(cfg, par, params_eval)
        self.step_fn = make_train_step(cfg, par, mesh, self.oc, train_cfg,
                                       self.pspecs)
        self.ckpt = (Checkpointer(train_cfg.checkpoint_dir)
                     if train_cfg.checkpoint_dir else None)

        self.data_cfg = DataConfig(
            vocab_size=cfg.vocab_size, seq_len=256, global_batch=8,
            seed=train_cfg.seed)

    # ------------------------------------------------------------------ setup
    def init_state(self):
        with self.mesh:
            shardings = jax.tree.map(
                lambda s: NamedSharding(self.mesh, s), self.pspecs,
                is_leaf=lambda x: isinstance(x, P))
            # sharded_init (not jit+out_shardings): init values must not
            # depend on the mesh layout — see compat.sharded_init.
            params = compat.sharded_init(
                functools.partial(M.init_model, cfg=self.cfg, par=self.par),
                shardings)(jax.random.PRNGKey(self.tc.seed))
            params_eval = jax.eval_shape(
                lambda: M.init_model(jax.random.PRNGKey(0), self.cfg, self.par))
            opt_specs = adamw.opt_state_specs(self.pspecs, params_eval,
                                              self.par.dp, self.par.tp,
                                              ep=max(self.par.ep, 1))
            opt_shardings = jax.tree.map(
                lambda s: NamedSharding(self.mesh, s), opt_specs,
                is_leaf=lambda x: isinstance(x, P))
            opt = jax.jit(functools.partial(
                adamw.init_opt_state, moment_dtype=self.oc.moment_dtype),
                out_shardings=opt_shardings)(params)
        return params, opt

    def _data(self, step: int) -> Dict[str, np.ndarray]:
        return batch_at(self.data_cfg, step)

    # ------------------------------------------------------------------ loop
    def train(self, params=None, opt=None, resume: bool = True,
              fault_hook: Optional[Callable[[int], None]] = None):
        """Run to total_steps.  ``fault_hook(step)`` may raise to simulate
        failures (tests); recovery reloads the last checkpoint and reseeks
        the data stream."""
        if params is None:
            params, opt = self.init_state()
        if self.ckpt and resume and self.ckpt.latest_step() is not None:
            state, self.step, _ = self.ckpt.restore(
                {"params": params, "opt": opt})
            params, opt = state["params"], state["opt"]
            log.info("resumed at step %d", self.step)

        metrics_hist = []
        while self.step < self.tc.total_steps:
            t0 = time.perf_counter()
            batch = self._data(self.step)
            try:
                if fault_hook is not None:
                    fault_hook(self.step)
                params, opt, metrics = self.step_fn(
                    params, opt, batch, jnp.asarray(self.step, jnp.int32))
                jax.block_until_ready(metrics["loss"])
            except Exception as e:  # noqa: BLE001 — any failure triggers recovery
                self.failures += 1
                if self.failures > self.tc.max_retries:
                    raise
                log.warning("step %d failed (%s); recovering", self.step, e)
                params, opt = self._recover()
                continue

            dt = time.perf_counter() - t0
            if self._ewma is None:
                self._ewma = dt
            elif dt > self.tc.straggler_factor * self._ewma:
                self.straggler_events += 1
                log.warning("straggler: step %d took %.3fs (ewma %.3fs)",
                            self.step, dt, self._ewma)
            self._ewma = 0.9 * self._ewma + 0.1 * dt if self._ewma else dt

            self.step += 1
            metrics_hist.append(
                {k: float(v) for k, v in metrics.items()})
            if self.ckpt and self.step % self.tc.checkpoint_every == 0:
                self.ckpt.save(self.step, {"params": params, "opt": opt},
                               extra={"step": self.step})
            if self.step % self.tc.log_every == 0:
                log.info("step %d loss %.4f", self.step,
                         metrics_hist[-1]["loss"])
        if self.ckpt:
            self.ckpt.wait()
        return params, opt, metrics_hist

    # ------------------------------------------------------------- recovery
    def _recover(self):
        """Reload the last checkpoint (or re-init) after a failure."""
        params, opt = self.init_state()
        if self.ckpt and self.ckpt.latest_step() is not None:
            state, step, _ = self.ckpt.restore({"params": params, "opt": opt})
            params, opt = state["params"], state["opt"]
            self.step = step
        else:
            self.step = 0
        return params, opt
