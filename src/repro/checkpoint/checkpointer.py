"""Sharded, async, integrity-checked checkpointing (no orbax dependency).

Layout:  <dir>/step_<N>/
           manifest.json       — tree structure, shapes, dtypes, step, hashes
           shard_<host>.npz    — this host's param/opt leaves (per-host
                                 sharded save: each host writes only the
                                 arrays it owns; on CPU single-host, all)
Writes are atomic (tmp dir + rename) and asynchronous (background thread) so
the train loop never blocks on IO; ``wait()`` joins before the next save.
Restores verify per-leaf checksums — a truncated file fails loudly, not with
silently corrupt weights (fault-tolerance requirement).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import ml_dtypes
import numpy as np

# dtypes numpy can't natively serialize: stored as raw uint views
_VIEW_DTYPES = {"bfloat16": (np.uint16, ml_dtypes.bfloat16)}


def _flatten(tree) -> Tuple[List[Tuple[str, Any]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    items = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        items.append((key, leaf))
    return items, treedef


class Checkpointer:
    def __init__(self, directory: str, host_id: int = 0, num_hosts: int = 1,
                 keep: int = 3):
        self.dir = directory
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Dict, extra: Optional[Dict] = None,
             blocking: bool = False) -> None:
        self.wait()
        items, _ = _flatten(tree)
        # materialize to host numpy BEFORE the async thread (device buffers
        # may be donated/overwritten by the next step)
        host_items = []
        for k, v in items:
            arr = np.asarray(v)
            if arr.dtype.name in _VIEW_DTYPES:
                arr = arr.view(_VIEW_DTYPES[arr.dtype.name][0])
                host_items.append((k, arr, True))
            else:
                host_items.append((k, arr, False))

        def _write():
            tmp = os.path.join(self.dir, f".tmp_step_{step}_{self.host_id}")
            final = os.path.join(self.dir, f"step_{step}")
            os.makedirs(tmp, exist_ok=True)
            arrays = {k.replace("/", "__"): v for k, v, _ in host_items}
            np.savez(os.path.join(tmp, f"shard_{self.host_id}.npz"), **arrays)
            manifest = {
                "step": step,
                "extra": extra or {},
                "num_hosts": self.num_hosts,
                "leaves": {
                    k: {"shape": list(v.shape), "dtype": str(v.dtype),
                        "viewed": viewed,
                        "sha256_16": hashlib.sha256(
                            v.tobytes()).hexdigest()[:16]}
                    for k, v, viewed in host_items},
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, tree_like: Dict, step: Optional[int] = None
                ) -> Tuple[Dict, int, Dict]:
        """Restore into the structure of ``tree_like``; verifies checksums.
        Returns (tree, step, extra)."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(path, f"shard_{self.host_id}.npz"))
        items, treedef = _flatten(tree_like)
        leaves = []
        for k, like in items:
            arr = data[k.replace("/", "__")]
            meta = manifest["leaves"][k]
            digest = hashlib.sha256(arr.tobytes()).hexdigest()[:16]
            if digest != meta["sha256_16"]:
                raise IOError(f"checkpoint corruption in leaf {k} "
                              f"(checksum mismatch)")
            if meta.get("viewed"):
                want = str(np.dtype(getattr(like, "dtype", "bfloat16")))
                for name, (view_t, real_t) in _VIEW_DTYPES.items():
                    if arr.dtype == view_t and (want == name
                                                or want.startswith(name)):
                        arr = arr.view(real_t)
                        break
                else:
                    arr = arr.view(ml_dtypes.bfloat16)
            if list(arr.shape) != list(like.shape):
                raise ValueError(f"leaf {k}: checkpoint shape {arr.shape} != "
                                 f"expected {like.shape}")
            leaves.append(arr)
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        return tree, manifest["step"], manifest.get("extra", {})
