"""AOT-compilation (``jit(...).lower().compile()``) result helpers.

``compiled.cost_analysis()`` drifted across JAX generations: newer releases
return a flat ``dict`` of metrics, 0.4.x returns a one-element ``list`` of
dicts (one per partition program).  Normalize to a dict so callers can
``.get(...)`` regardless of generation.
"""
from __future__ import annotations

from typing import Any, Dict


def cost_analysis(compiled) -> Dict[str, Any]:
    """Flat metrics dict from a compiled executable, or {} if unavailable."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca) if ca else {}
