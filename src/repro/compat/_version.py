"""Installed-JAX version detection for the portability layer.

``REPRO_COMPAT_ASSUME_JAX=<version>`` caps the detected version (never
raises it): the ``--jax-min`` CI lane sets it to the 0.4.30 floor so the
compat contract tests exercise the OLDEST-generation code paths (psum
axis-size spelling, no fused-collective composition, old compiler-params
fields) on whatever JAX the container actually ships.
"""
from __future__ import annotations

import os

import jax

#: Oldest JAX generation the shim is written against.
MIN_JAX = (0, 4, 30)
#: Newest JAX the shim has been exercised on (CI pin).
MAX_TESTED_JAX = (0, 4, 37)


def _parse(version: str) -> tuple:
    parts = []
    for piece in version.split(".")[:3]:
        digits = "".join(ch for ch in piece if ch.isdigit())
        if not digits:
            break
        parts.append(int(digits))
    return tuple(parts)


_INSTALLED = _parse(jax.__version__)
_ASSUMED = os.environ.get("REPRO_COMPAT_ASSUME_JAX")

JAX_VERSION = (min(_INSTALLED, _parse(_ASSUMED)) if _ASSUMED
               else _INSTALLED)


def assumed_floor() -> bool:
    """True when ``REPRO_COMPAT_ASSUME_JAX`` downgrades the detected
    version — feature-probed newer spellings must then be IGNORED so the
    floor-generation code paths actually run."""
    return JAX_VERSION < _INSTALLED


def jax_at_least(*version: int) -> bool:
    """True when the (possibly capped) JAX is at least ``version``."""
    return JAX_VERSION >= tuple(version)


def version_summary() -> str:
    """One-line provenance string for logs and error messages."""
    lo = ".".join(map(str, MIN_JAX))
    hi = ".".join(map(str, MAX_TESTED_JAX))
    assumed = (f"; assumed {'.'.join(map(str, JAX_VERSION))} via "
               f"REPRO_COMPAT_ASSUME_JAX" if assumed_floor() else "")
    return (f"jax {jax.__version__}{assumed} (compat range: {lo} .. {hi}; "
            f"newer releases resolved best-effort)")
