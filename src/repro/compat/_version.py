"""Installed-JAX version detection for the portability layer."""
from __future__ import annotations

import jax

#: Oldest JAX generation the shim is written against.
MIN_JAX = (0, 4, 30)
#: Newest JAX the shim has been exercised on (CI pin).
MAX_TESTED_JAX = (0, 4, 37)


def _parse(version: str) -> tuple:
    parts = []
    for piece in version.split(".")[:3]:
        digits = "".join(ch for ch in piece if ch.isdigit())
        if not digits:
            break
        parts.append(int(digits))
    return tuple(parts)


JAX_VERSION = _parse(jax.__version__)


def jax_at_least(*version: int) -> bool:
    """True when the installed JAX is at least ``version`` (e.g. (0, 5))."""
    return JAX_VERSION >= tuple(version)


def version_summary() -> str:
    """One-line provenance string for logs and error messages."""
    lo = ".".join(map(str, MIN_JAX))
    hi = ".".join(map(str, MAX_TESTED_JAX))
    return (f"jax {jax.__version__} (compat range: {lo} .. {hi}; "
            f"newer releases resolved best-effort)")
