"""Pallas TPU portability: compiler params, memory spaces, DMA helpers.

Drift handled here:
  - ``pltpu.TPUCompilerParams`` (0.4.x) was renamed ``pltpu.CompilerParams``;
    field sets also differ between generations, so
    ``pallas_compiler_params`` filters kwargs to what the installed class
    accepts instead of exploding on a newer-generation knob.
  - HBM ("ANY"-space) scratch buffers: callable ``pl.ANY(shape, dtype)`` on
    newer JAX, only ``pltpu.ANY(shape, dtype)`` on 0.4.x
    (``pl.ANY`` there is a plain enum member and not callable).
  - ``interpret=`` defaults: CPU CI machines have no Mosaic toolchain, so
    every kernel defaults to interpret mode unless a real TPU backend is
    present; ``REPRO_PALLAS_INTERPRET`` overrides in both directions.
"""
from __future__ import annotations

import dataclasses
import inspect
import os
import warnings
from typing import Any, Callable, Optional

import jax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# --------------------------------------------------------------------------
# compiler params
# --------------------------------------------------------------------------
_COMPILER_PARAMS_CLS = (getattr(pltpu, "CompilerParams", None)
                        or getattr(pltpu, "TPUCompilerParams"))
_CP_FIELDS = {f.name for f in dataclasses.fields(_COMPILER_PARAMS_CLS)}


def pallas_compiler_params(**kwargs):
    """Build the installed generation's TPU compiler-params object.

    Accepts the union of knobs across generations
    (``dimension_semantics``, ``collective_id``, ``vmem_limit_bytes``, ...)
    and drops — with a warning — any the installed class does not know, so
    kernels can be written once against the newest surface.
    """
    kept = {k: v for k, v in kwargs.items() if k in _CP_FIELDS}
    dropped = sorted(set(kwargs) - set(kept))
    if dropped:
        warnings.warn(
            f"compat.pallas_compiler_params: {_COMPILER_PARAMS_CLS.__name__} "
            f"on this JAX does not support {dropped}; dropping", stacklevel=2)
    return _COMPILER_PARAMS_CLS(**kept)


# --------------------------------------------------------------------------
# pallas_call with portable defaults
# --------------------------------------------------------------------------
def interpret_default() -> bool:
    """Mosaic lowering needs a TPU toolchain; interpret everywhere else.
    ``REPRO_PALLAS_INTERPRET`` (1/0) force-overrides the backend probe."""
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


def fused_collective_kernels_composable() -> bool:
    """Can several remote-DMA (ring) Pallas kernels share one jitted program?

    On real TPUs (Mosaic lowering): always.  In interpret mode on older JAX,
    ``make_async_remote_copy`` discharges into ``all_gather``/``argmax``
    collectives nested inside the kernel's ``pl.when`` conditionals; XLA
    CPU's sharding propagation then hard-crashes (``Array::Reshape`` check
    failure, observed on jax 0.4.37) once certain pairs of such kernels
    appear in the same program — a single kernel per program compiles and
    runs correctly.  Callers composing fused kernels (e.g. the flux overlap
    seams) must fall back to a collective-equivalent path when this returns
    False.
    """
    from repro.compat._version import jax_at_least
    if not interpret_default():
        return True
    return jax_at_least(0, 6)


_PALLAS_CALL_PARAMS = frozenset(inspect.signature(pl.pallas_call).parameters)


def pallas_call(kernel: Callable, *, interpret: Optional[bool] = None,
                compiler_params: Any = None, **kwargs):
    """``pl.pallas_call`` with version-portable defaults.

    - ``interpret=None`` resolves via :func:`interpret_default` so every
      kernel runs on CPU CI without each call site re-implementing the probe.
    - ``compiler_params`` may be a plain dict of knobs; it is routed through
      :func:`pallas_compiler_params` to the installed params class.
    - kwargs the installed ``pl.pallas_call`` does not know (e.g.
      ``cost_estimate`` on very old releases) are dropped with a warning
      rather than raising.
    """
    if interpret is None:
        interpret = interpret_default()
    if isinstance(compiler_params, dict):
        compiler_params = pallas_compiler_params(**compiler_params)
    if compiler_params is not None:
        kwargs["compiler_params"] = compiler_params
    unsupported = [k for k in kwargs
                   if k not in _PALLAS_CALL_PARAMS and kwargs[k] is not None]
    for k in unsupported:
        warnings.warn(f"compat.pallas_call: pl.pallas_call on this JAX does "
                      f"not support {k!r}; dropping", stacklevel=2)
    kwargs = {k: v for k, v in kwargs.items()
              if k in _PALLAS_CALL_PARAMS and v is not None}
    return pl.pallas_call(kernel, interpret=interpret, **kwargs)


def cost_estimate(*, flops: int, bytes_accessed: int,
                  transcendentals: int = 0):
    """Portable ``pl.CostEstimate`` (None when the release predates it)."""
    ce_cls = getattr(pl, "CostEstimate", None)
    if ce_cls is None:
        return None
    return ce_cls(flops=flops, bytes_accessed=bytes_accessed,
                  transcendentals=transcendentals)


# --------------------------------------------------------------------------
# memory spaces & scratch shapes
# --------------------------------------------------------------------------
#: VMEM scratch allocator: ``VMEM(shape, dtype)`` (stable across generations).
VMEM = pltpu.VMEM
#: SMEM memory space (BlockSpec ``memory_space=`` and scratch allocator).
SMEM = pltpu.SMEM
#: "ANY" (compiler-placed / HBM) memory space for ``pl.BlockSpec``.
ANY = getattr(pl, "ANY", None)
if ANY is None:                                      # pragma: no cover
    ANY = pltpu.ANY


def hbm_scratch(shape: tuple, dtype):
    """HBM-resident scratch buffer spec (``scratch_shapes=`` entry).

    Newer JAX spells this ``pl.ANY(shape, dtype)``; on 0.4.x only the TPU
    enum ``pltpu.ANY`` is callable.
    """
    for space in (getattr(pltpu, "ANY", None), getattr(pl, "ANY", None)):
        if callable(space):
            return space(shape, dtype)
    raise NotImplementedError(
        "no callable ANY/HBM memory space on this JAX; cannot allocate "
        "HBM scratch for fused collective kernels")


# --------------------------------------------------------------------------
# async-copy / semaphore (in-kernel DMA) helpers
# --------------------------------------------------------------------------
def _require(name: str):
    obj = getattr(pltpu, name, None)
    if obj is None:                                  # pragma: no cover
        raise NotImplementedError(
            f"pltpu.{name} is unavailable on this JAX; the fused "
            f"communication kernels need it")
    return obj


SemaphoreType = _require("SemaphoreType")
#: DMA-semaphore scratch spec (``scratch_shapes=`` entry).
DMA_SEM = SemaphoreType.DMA
make_async_copy = _require("make_async_copy")
make_async_remote_copy = _require("make_async_remote_copy")
#: ``device_id_type=`` value for logical (mesh-coordinate) addressing.
LOGICAL_DEVICE_ID = _require("DeviceIdType").LOGICAL
