"""``shard_map`` and mesh-axis helpers across JAX generations.

Newest JAX exposes ``jax.shard_map`` with a ``check_vma`` kwarg; the
generation this repo pins in CI (0.4.x) ships it as
``jax.experimental.shard_map.shard_map`` with the same knob named
``check_rep``.  ``compat.shard_map`` accepts either spelling and forwards to
whichever implementation is installed.
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax
from jax import lax  # noqa: F401  (axis_size fallback)

from repro.compat._version import assumed_floor

if hasattr(jax, "shard_map") and not assumed_floor():   # jax >= 0.6
    _shard_map_impl: Callable = jax.shard_map
    _CHECK_KWARG = "check_vma"
else:                                               # jax 0.4.x / 0.5.x
    from jax.experimental.shard_map import shard_map as _shard_map_impl
    _CHECK_KWARG = "check_rep"


def shard_map(f: Callable, *, mesh, in_specs, out_specs,
              check_vma: Optional[bool] = None,
              check_rep: Optional[bool] = None,
              auto: Any = None) -> Callable:
    """Version-portable ``shard_map``.

    ``check_vma`` (new spelling) and ``check_rep`` (old spelling) are
    interchangeable; pass at most one.  ``auto`` is forwarded only when
    given, so each generation keeps its own default.
    """
    if check_vma is not None and check_rep is not None:
        raise TypeError("pass either check_vma or check_rep, not both")
    check = check_vma if check_vma is not None else check_rep
    kwargs = {}
    if check is not None:
        kwargs[_CHECK_KWARG] = check
    if auto is not None:
        kwargs["auto"] = auto
    return _shard_map_impl(f, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, **kwargs)


def sharded_init(fn: Callable, shardings) -> Callable:
    """Run an RNG-based initializer and place the results per ``shardings``,
    with layout-invariant values.

    Jitting an initializer with ``out_shardings`` looks equivalent but is
    NOT on the 0.4.x generation: the SPMD partitioner miscompiles
    partitionable-threefry bits flowing into ``concatenate`` on a >=2-D
    mesh (observed on jax 0.4.37, CPU, (2, 2) mesh: the packed QKV weights
    differ from every 1-D mesh and from the eager run — same PRNG key).
    Computing unsharded and resharding via ``device_put`` keeps the RNG out
    of the partitioner, so the same seed yields the same parameters on every
    mesh layout.  Cost: the full tree is materialized unsharded before the
    reshard — fine for tests/CPU; revisit (per-leaf init or a fixed JAX)
    before very-large-scale runs.
    """
    def run(*args, **kwargs):
        out = jax.jit(fn)(*args, **kwargs)
        return jax.device_put(out, shardings)
    return run


def axis_size(axis_name) -> int:
    """Size of a mapped mesh axis, callable inside ``shard_map``.

    ``None`` means "not parallelized" and returns 1.  Older JAX has no
    ``lax.axis_size``; there ``lax.psum(1, axis)`` is the canonical spelling
    and returns a static int for a constant operand.
    """
    if axis_name is None:
        return 1
    if hasattr(lax, "axis_size") and not assumed_floor():
        return lax.axis_size(axis_name)
    return lax.psum(1, axis_name)
