"""JAX/Pallas portability layer — the ONLY module allowed to touch
version-drifted JAX symbols.

The repo targets a range of JAX generations (see ``MIN_JAX`` /
``MAX_TESTED_JAX``) whose public surface moved underneath us:

  ===========================  ==============================  ==================
  symbol (newest generation)   older generation                compat entry point
  ===========================  ==============================  ==================
  ``jax.shard_map``            ``jax.experimental.shard_map``  ``shard_map``
  ``shard_map(check_vma=)``    ``shard_map(check_rep=)``       ``shard_map``
  ``pltpu.CompilerParams``     ``pltpu.TPUCompilerParams``     ``pallas_compiler_params``
  ``lax.axis_size``            ``lax.psum(1, axis)``           ``axis_size``
  ``pl.ANY(shape, dtype)``     ``pltpu.ANY(shape, dtype)``     ``hbm_scratch``
  ===========================  ==============================  ==================

Everything else (``pl.BlockSpec``, ``pl.when``, ``pl.ds``, ``lax``
collectives, ...) has been stable across the supported range and is imported
directly by consumers.

Rule (enforced by ``tests/test_compat.py``): no module outside
``repro/compat/`` may reference ``jax.shard_map``,
``jax.experimental.shard_map``, or ``pltpu.*CompilerParams`` directly —
import through this package instead.
"""
import jax as _jax

from repro.compat._version import (JAX_VERSION, MAX_TESTED_JAX, MIN_JAX,
                                   jax_at_least, version_summary)

# Normalize RNG semantics across generations: newer JAX defaults
# ``jax_threefry_partitionable=True`` (random bits independent of how the
# computation is sharded).  Older releases default to False, where
# ``jax.random.*`` inside a jit with sharded outputs produces DIFFERENT
# values than the same call unsharded — breaking cross-layout determinism
# (same seed, different init at dp=2).  Opt in to the new semantics
# everywhere so parameter initialization is layout-invariant.
if hasattr(_jax.config, "jax_threefry_partitionable"):
    _jax.config.update("jax_threefry_partitionable", True)
from repro.compat._aot import cost_analysis
from repro.compat._sharding import axis_size, shard_map, sharded_init
from repro.compat._pallas import (ANY, DMA_SEM, SMEM, VMEM,
                                  LOGICAL_DEVICE_ID, SemaphoreType,
                                  cost_estimate,
                                  fused_collective_kernels_composable,
                                  hbm_scratch, interpret_default,
                                  make_async_copy, make_async_remote_copy,
                                  pallas_call, pallas_compiler_params)

__all__ = [
    "JAX_VERSION", "MIN_JAX", "MAX_TESTED_JAX", "jax_at_least",
    "version_summary",
    "shard_map", "axis_size", "sharded_init",
    "pallas_call", "pallas_compiler_params", "interpret_default",
    "cost_estimate", "cost_analysis",
    "fused_collective_kernels_composable",
    "VMEM", "SMEM", "ANY", "hbm_scratch",
    "SemaphoreType", "DMA_SEM",
    "make_async_copy", "make_async_remote_copy", "LOGICAL_DEVICE_ID",
]
