#!/usr/bin/env bash
# Tier-1 gate: compat grep-lint + full correctness suite.
#
# Usage:  scripts/verify.sh [--fast|--jax-min] [extra pytest args]
#
#   --fast     skip the multi-device subprocess sweeps (tests marked
#              ``multidev`` — everything that spawns a fresh python with
#              forced host devices).  Quick iteration tier; the FULL suite
#              remains the default and the PR gate.
#   --jax-min  run ONLY the compat contract tests with the detected JAX
#              capped to the 0.4.30 floor of the supported range
#              (REPRO_COMPAT_ASSUME_JAX) — exercises the oldest-generation
#              code paths (psum axis-size spelling, no fused-collective
#              composition) — plus the BENCH_tuning.json layout-sweep
#              well-formedness check.
#
# Runs on CPU CI machines (no TPU): kernels execute in Pallas interpret mode
# (REPRO_PALLAS_INTERPRET=1).  Every PR must pass this before review.
set -euo pipefail
cd "$(dirname "$0")/.."

FAST=0
JAX_MIN=0
if [[ "${1:-}" == "--fast" ]]; then
  FAST=1
  shift
elif [[ "${1:-}" == "--jax-min" ]]; then
  JAX_MIN=1
  shift
fi

export REPRO_PALLAS_INTERPRET="${REPRO_PALLAS_INTERPRET:-1}"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== compat grep-lint (drifted JAX symbols must live in repro/compat) =="
if grep -rn --include='*.py' -E \
     'jax\.shard_map|jax\.experimental\.shard_map|CompilerParams|jax\.experimental\.pallas import tpu|lax\.axis_size' \
     src/ | grep -v '^src/repro/compat/'; then
  echo "FAIL: drifted JAX symbols used outside src/repro/compat/ (see above);" >&2
  echo "      import them through repro.compat instead." >&2
  exit 1
fi
echo "ok"

echo "== overlap API lint (seams go through FusedOp / ctx.op) =="
# 1. overlap's private backends (rings, cores, q8 codecs, ...) are an
#    implementation detail of src/repro/core/overlap.py — nothing else may
#    reach into them.
if grep -rn --include='*.py' -E \
     'overlap\._|_ag_matmul_|_matmul_rs_(xla|decomposed|bidir|flux|impl)|_matmul_ar_|_ag_ring|_ag_bidir|_rs_ring|_rs_bidir|_rs_core|_ar_core|_fused_impl|_fused_ag|_q8_encode|_q8_decode' \
     src/ benchmarks/ | grep -v '^src/repro/core/overlap.py'; then
  echo "FAIL: private overlap backends referenced outside" >&2
  echo "      src/repro/core/overlap.py (see above); use overlap.FusedOp" >&2
  echo "      (model code: ctx.op(seam, epilogue=..., n_weights=...))." >&2
  exit 1
fi
# 2. the pre-FusedOp positional wrappers are GONE (their one-release
#    deprecation window ended): any call to ag_matmul/matmul_rs/matmul_ar
#    is an error everywhere — no carve-outs.  (ag_matmul_ref /
#    matmul_rs_ref / *_fused kernel entry points do not match: the regex
#    requires the bare name directly before the call paren.)
if grep -rn --include='*.py' -E \
     '(^|[^_[:alnum:]])(ag_matmul|matmul_rs|matmul_ar)\(' \
     src/ benchmarks/ examples/ tests/; then
  echo "FAIL: the removed overlap wrappers (ag_matmul/matmul_rs/matmul_ar)" >&2
  echo "      are referenced (see above); build an overlap.FusedOp" >&2
  echo "      (model code: ctx.op(seam, epilogue=..., n_weights=...))." >&2
  exit 1
fi
echo "ok"

if [[ "$JAX_MIN" == 1 ]]; then
  echo "== compat contract tests at the 0.4.30 floor (REPRO_COMPAT_ASSUME_JAX) =="
  REPRO_COMPAT_ASSUME_JAX=0.4.30 python -m pytest -x -q tests/test_compat.py "$@"
  REPRO_COMPAT_ASSUME_JAX=0.4.30 python - <<'EOF'
from repro import compat
# the cap never RAISES the version: with jax==0.4.30 actually installed
# this equals the native detection (and version_summary carries no
# "assumed" marker — the floor paths run natively there)
assert compat.JAX_VERSION == (0, 4, 30), compat.JAX_VERSION
# the floor generation cannot compose fused collective kernels in
# interpret mode: flux seams must report the decomposed fallback
assert not compat.fused_collective_kernels_composable()
print("compat floor assumptions ok:", compat.version_summary())
EOF
  echo "== BENCH_tuning.json scatter_axis sweep rows =="
  python - <<'EOF'
import json
doc = json.load(open("experiments/BENCH_tuning.json"))
rows = doc.get("layout", {}).get("scatter_axis", [])
assert rows, "BENCH_tuning.json has no scatter_axis sweep rows"
axes = {r["scatter_axis"] for r in rows}
assert axes == {"seq", "hidden"}, axes
for r in rows:
    assert {"m", "overall_s", "act_bytes", "comm_bytes"} <= set(r), r
by_m = {}
for r in rows:
    by_m.setdefault(r["m"], {})[r["scatter_axis"]] = r
for m, pair in by_m.items():
    seq, hid = pair["seq"], pair["hidden"]
    assert abs(seq["comm_bytes"] - hid["comm_bytes"]) < 1e-6 * max(
        seq["comm_bytes"], 1.0), (m, "layer-pair comm volume must be "
                                  "layout-invariant")
    assert seq["act_bytes"] < hid["act_bytes"], (m, "seq must reduce "
                                                 "activation residency")
print(f"BENCH_tuning.json scatter_axis sweep ok: {len(rows)} rows")
EOF
  exit 0
fi

echo "== tier-1 test suite =="
if [[ "$FAST" == 1 ]]; then
  # the serving regressions run FIRST in the fast lane: they guard the
  # continuous-batching cache-corruption bugs (per-slot positions, batched
  # prefill admission) and fail in seconds when the serving path breaks.
  python -m pytest -x -q tests/test_serving_regression.py
  python -m pytest -x -q -m "not multidev" --ignore=tests/test_serving_regression.py "$@"
else
  python -m pytest -x -q "$@"
fi

echo "== serving smoke bench (BENCH_serving.json well-formedness) =="
python benchmarks/serving.py --smoke
python - <<'EOF'
import json
doc = json.load(open("experiments/BENCH_serving.json"))
rows = doc["modes"]
assert len(rows) >= 2, f"need >= 2 overlap modes, got {len(rows)}"
for r in rows:
    assert r["tokens_per_s"] > 0 and r["new_tokens"] > 0, r
    assert r["prefill_dispatches"] == r["requests"], \
        f"admission must be ONE prefill dispatch per request: {r}"
    assert {"mean", "p50", "max"} <= set(r["request_latency_s"]), r
    assert r["outputs_match_reference"], \
        f"overlap mode {r['mode']} changed serving outputs"
print("BENCH_serving.json ok:",
      ", ".join(f"{r['mode']}={r['tokens_per_s']:.0f} tok/s" for r in rows))
EOF
