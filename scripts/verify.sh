#!/usr/bin/env bash
# Tier-1 gate: static contracts (lint + kernel + jaxpr seam checks) + full
# correctness suite.
#
# Usage:  scripts/verify.sh [--lint|--fast|--jax-min] [extra pytest args]
#
#   --lint     run ONLY the static-contract checker
#              (python -m repro.analysis.check) — AST lint over
#              src/ benchmarks/ examples/ tests/, the Pallas kernel
#              contracts (repro.analysis.kernelcheck: semaphore balance,
#              DMA/slot races, ring arithmetic, tile coverage, VMEM
#              budgets — every kernel x both ring directions), plus the
#              jaxpr seam contracts for every config x both residual
#              layouts.  No pytest; finishes in well under a minute.
#   --fast     skip the multi-device subprocess sweeps (tests marked
#              ``multidev`` — everything that spawns a fresh python with
#              forced host devices).  Quick iteration tier; the FULL suite
#              remains the default and the PR gate.
#   --jax-min  run ONLY the compat contract tests with the detected JAX
#              capped to the 0.4.30 floor of the supported range
#              (REPRO_COMPAT_ASSUME_JAX) — exercises the oldest-generation
#              code paths (psum axis-size spelling, no fused-collective
#              composition) — plus the BENCH_tuning.json layout-sweep
#              well-formedness check.
#
# The static checker replaced the old grep-lint gates: the standing source
# rules (compat-import, private-backend, removed-wrapper, raw-collective,
# bare-shard-map, stale-allow) are AST checks in repro.analysis.lint, the
# in-kernel DMA/semaphore/ring/coverage/budget protocol is verified on
# abstract per-rank grid traces in repro.analysis.kernelcheck, and the seam
# invariants (collective census with ring provenance, partial-cotangent
# completion, layout coherence) are verified on ABSTRACT jaxpr traces in
# repro.analysis.seamcheck — no devices, no execution.
#
# Runs on CPU CI machines (no TPU): kernels execute in Pallas interpret mode
# (REPRO_PALLAS_INTERPRET=1).  Every PR must pass this before review.
set -euo pipefail
cd "$(dirname "$0")/.."

LINT_ONLY=0
FAST=0
JAX_MIN=0
if [[ "${1:-}" == "--lint" ]]; then
  LINT_ONLY=1
  shift
elif [[ "${1:-}" == "--fast" ]]; then
  FAST=1
  shift
elif [[ "${1:-}" == "--jax-min" ]]; then
  JAX_MIN=1
  shift
fi

export REPRO_PALLAS_INTERPRET="${REPRO_PALLAS_INTERPRET:-1}"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "$LINT_ONLY" == 1 ]]; then
  echo "== static contracts (repro.analysis.check: lint + kernel + seam invariants) =="
  python -m repro.analysis.check "$@"
  exit 0
fi

echo "== static contracts (repro.analysis.check: lint + kernel + seam invariants) =="
python -m repro.analysis.check

echo "== MoE a2a seam: census provenance on both transports =="
# runs in EVERY lane (incl. --fast): abstractly trace one MoE config's
# train step under the barrier and ring a2a transports and demand the EP
# exchange shows up seam-tagged — the all_to_all census blind spot stays
# closed even when the multi-device sweeps are skipped.
python - <<'EOF'
from repro.analysis import seamcheck
from repro.configs.base import ParallelConfig, get_smoke_config
from repro.tuning.plans import PlanSet, SeamPlan

cfg = get_smoke_config("deepseek_v3_671b")
par = ParallelConfig(tp=4, dp=1)
for layout in ("seq", "hidden"):
    for a2a_mode in ("xla", "decomposed"):
        plans = PlanSet.uniform("decomposed").override(
            "moe_a2a", SeamPlan(mode=a2a_mode)).with_scatter_axis(layout)
        colls = seamcheck.collect_collectives(
            seamcheck.trace_train(cfg, par, plans))
        a2a = [c for c in colls if c.prim == "all_to_all"]
        assert all(c.seam_tagged for c in a2a), \
            [c.describe() for c in a2a if not c.seam_tagged]
        if layout == "seq" and a2a_mode == "xla":
            assert a2a, "barrier plan must trace all_to_all dispatch/combine"
        if layout == "seq" and a2a_mode == "decomposed":
            assert not a2a, "ring plan must decompose the a2a into ppermute"
            assert any(c.prim == "ppermute" and "seam_moe" in c.scope
                       for c in colls), "no seam_moe ppermute ring traced"
print("moe a2a census ok: both layouts x both transports")
EOF

if [[ "$JAX_MIN" == 1 ]]; then
  echo "== Pallas kernel contracts (repro.analysis.check --kernels) =="
  # first gate of the floor lane too: the kernel protocol (semaphore
  # balance, DMA races, ring arithmetic, coverage, budgets) is
  # JAX-version independent — it must hold before any compat test runs
  python -m repro.analysis.check --kernels -q

  echo "== compat contract tests at the 0.4.30 floor (REPRO_COMPAT_ASSUME_JAX) =="
  REPRO_COMPAT_ASSUME_JAX=0.4.30 python -m pytest -x -q tests/test_compat.py "$@"
  REPRO_COMPAT_ASSUME_JAX=0.4.30 python - <<'EOF'
from repro import compat
# the cap never RAISES the version: with jax==0.4.30 actually installed
# this equals the native detection (and version_summary carries no
# "assumed" marker — the floor paths run natively there)
assert compat.JAX_VERSION == (0, 4, 30), compat.JAX_VERSION
# the floor generation cannot compose fused collective kernels in
# interpret mode: flux seams must report the decomposed fallback
assert not compat.fused_collective_kernels_composable()
print("compat floor assumptions ok:", compat.version_summary())
EOF
  echo "== BENCH_tuning.json scatter_axis sweep rows =="
  python - <<'EOF'
import json
doc = json.load(open("experiments/BENCH_tuning.json"))
rows = doc.get("layout", {}).get("scatter_axis", [])
assert rows, "BENCH_tuning.json has no scatter_axis sweep rows"
axes = {r["scatter_axis"] for r in rows}
assert axes == {"seq", "hidden"}, axes
for r in rows:
    assert {"m", "overall_s", "act_bytes", "comm_bytes"} <= set(r), r
by_m = {}
for r in rows:
    by_m.setdefault(r["m"], {})[r["scatter_axis"]] = r
for m, pair in by_m.items():
    seq, hid = pair["seq"], pair["hidden"]
    assert abs(seq["comm_bytes"] - hid["comm_bytes"]) < 1e-6 * max(
        seq["comm_bytes"], 1.0), (m, "layer-pair comm volume must be "
                                  "layout-invariant")
    assert seq["act_bytes"] < hid["act_bytes"], (m, "seq must reduce "
                                                 "activation residency")
print(f"BENCH_tuning.json scatter_axis sweep ok: {len(rows)} rows")
EOF
  echo "== BENCH_tuning.json MoE a2a rows =="
  python - <<'EOF'
import json
doc = json.load(open("experiments/BENCH_tuning.json"))
chunks = doc.get("moe", {}).get("a2a_chunks", [])
assert chunks, "BENCH_tuning.json has no a2a chunk-sweep rows"
assert len({r["comm_chunks"] for r in chunks}) >= 3, chunks
for r in chunks:
    assert {"m", "n", "k", "overall_s", "comm_bytes"} <= set(r), r
    assert r["comm_bytes"] > 0, r
a2a_seams = [s for s in doc["seams"] if s["seam"] == "moe_a2a"]
assert a2a_seams, "no moe_a2a planner row in BENCH_tuning.json"
modes = {c["mode"] for c in a2a_seams[0]["candidates"]}
assert {"xla", "decomposed"} <= modes, modes
print(f"BENCH_tuning.json moe a2a ok: {len(chunks)} chunk rows, "
      f"pick={a2a_seams[0]['plan']['mode']}")
EOF
  echo "== BENCH_tuning.json static tile-budget pruning rows =="
  python - <<'EOF'
import json
from repro.analysis.kernelcheck import tile_budget_ok
doc = json.load(open("experiments/BENCH_tuning.json"))
assert doc["seams"], "no planner rows in BENCH_tuning.json"
for s in doc["seams"]:
    # every planner row reports how many flux tilings the static VMEM
    # budget rejected before pricing, and no surviving candidate carries
    # an infeasible tiling (autotune never times what kernelcheck rejects)
    assert "pruned" in s, f"seam row missing pruned count: {s['seam']}"
    assert s["pruned"] >= 0, s
    for c in s["candidates"]:
        if c["mode"] == "flux" and c.get("blocks"):
            assert tile_budget_ok(s["kind"], tuple(c["blocks"])), \
                (s["seam"], c["blocks"], "infeasible tiling in the table")
print(f"BENCH_tuning.json pruning ok: {len(doc['seams'])} seam rows, "
      f"pruned={[s['pruned'] for s in doc['seams']]}")
EOF
  echo "== BENCH_tuning.json wire-precision sweep rows =="
  python - <<'EOF'
import json
doc = json.load(open("experiments/BENCH_tuning.json"))
wire = doc.get("wire", {})
seams = wire.get("seams", [])
assert seams, "BENCH_tuning.json has no wire-precision sweep rows"
budget = wire["max_logit_rmse"]
assert budget > 0, wire
kinds = {s["kind"] for s in seams}
assert {"ag", "rs", "ar", "a2a"} <= kinds, kinds
for s in seams:
    dtypes = {r["wire_dtype"] for r in s["rows"]}
    assert None in dtypes and "int8" in dtypes, (s["seam"], dtypes)
    for r in s["rows"]:
        # every row: bytes on the wire, a time estimate, and its
        # deviation vs the accuracy budget
        assert r["comm_bytes"] >= 0, (s["seam"], r)
        assert (r["measured_s"] or r["predicted_s"]) > 0, (s["seam"], r)
        assert r["logit_rmse"] >= 0, (s["seam"], r)
        assert r["within_budget"] == (r["logit_rmse"] <= budget), \
            (s["seam"], r, "within_budget disagrees with the budget")
        if r["wire_dtype"] is None:
            assert r["logit_rmse"] == 0.0, (s["seam"], r)
    # the CHOSEN plan never violates its accuracy budget
    assert s["plan"]["logit_rmse"] <= budget, (s["seam"], s["plan"])
    # quantized rows shrink bytes-on-wire vs the fp wire of the same mode
    for r in s["rows"]:
        if r["wire_dtype"] is None or r["comm_bytes"] == 0:
            continue
        fp = [f for f in s["rows"] if f["wire_dtype"] is None
              and f["mode"] == r["mode"]
              and f["comm_chunks"] == r["comm_chunks"]
              and f["reverse"] == r["reverse"]
              and f["scatter_axis"] == r["scatter_axis"]]
        assert fp and r["comm_bytes"] < fp[0]["comm_bytes"], (s["seam"], r)
assert wire["any_quantized_win"], \
    "no seam shows an in-budget low-precision wire beating the fp wire"
picks = {s["seam"]: (s["plan"]["mode"], s["plan"]["wire_dtype"])
         for s in seams}
print(f"BENCH_tuning.json wire sweep ok: {len(seams)} seams, "
      f"budget={budget}, picks={picks}")
EOF
  exit 0
fi

echo "== tier-1 test suite =="
if [[ "$FAST" == 1 ]]; then
  # the serving regressions run FIRST in the fast lane: they guard the
  # continuous-batching cache-corruption bugs (per-slot positions, batched
  # prefill admission) and fail in seconds when the serving path breaks.
  python -m pytest -x -q tests/test_serving_regression.py
  python -m pytest -x -q -m "not multidev" --ignore=tests/test_serving_regression.py "$@"
else
  python -m pytest -x -q "$@"
fi

echo "== serving smoke bench (BENCH_serving.json well-formedness) =="
# open-loop Poisson traffic through the paged runtime: TTFT / per-token
# percentiles must be finite, the paged pool must beat the dense-cache
# footprint, and overlap modes must not change outputs
python benchmarks/serving.py --smoke
python - <<'EOF'
import json
import math
doc = json.load(open("experiments/BENCH_serving.json"))
assert doc["arrival_rate_rps"] > 0, "smoke bench must run open-loop traffic"
assert doc["slo_ttft_s"] > 0, doc
rows = doc["modes"]
assert len(rows) >= 2, f"need >= 2 overlap modes, got {len(rows)}"
assert any(r.get("wire_dtype") for r in rows), \
    "serving bench must include a quantized-wire lane"
for r in rows:
    assert r["tokens_per_s"] > 0 and r["new_tokens"] > 0, r
    # chunked admission: at least one chunk dispatch per request, never a
    # per-token decode loop (<= ceil(max_seq / chunk) chunks per request)
    assert r["requests"] <= r["prefill_dispatches"], r
    assert r["prefill_dispatches"] < r["requests"] * doc["max_seq"], r
    for key in ("ttft_s", "per_token_s"):
        stats = r[key]
        assert {"mean", "p50", "p95", "p99"} <= set(stats), (key, stats)
        assert all(math.isfinite(v) and v >= 0 for v in stats.values()), \
            (key, stats)
        assert stats["p50"] <= stats["p95"] <= stats["p99"], (key, stats)
    assert 0 <= r["slo"]["attainment"] <= 1, r["slo"]
    pool = r["pool"]
    assert 0 < pool["blocks_in_use_peak"] < pool["dense_equiv_blocks"], \
        f"paged pool must beat the dense-cache footprint: {pool}"
    if r.get("wire_dtype"):
        # lossy wire: outputs may drift at tp > 1; at tp = 1 every seam
        # takes the single-shard fallback so nothing rides the wire
        assert "outputs_match_fp_wire" in r, r["mode"]
        if doc["tp"] == 1:
            assert r["outputs_match_fp_wire"], \
                "tp=1 has no wire transport — outputs must match"
    else:
        assert r["outputs_match_reference"], \
            f"overlap mode {r['mode']} changed serving outputs"
print("BENCH_serving.json ok:",
      ", ".join(f"{r['mode']}={r['tokens_per_s']:.0f} tok/s "
                f"ttft_p99={r['ttft_s']['p99'] * 1e3:.1f}ms" for r in rows))
EOF
