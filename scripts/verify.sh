#!/usr/bin/env bash
# Tier-1 gate: compat grep-lint + full correctness suite.
#
# Usage:  scripts/verify.sh [--fast] [extra pytest args]
#
#   --fast   skip the multi-device subprocess sweeps (tests marked
#            ``multidev`` — everything that spawns a fresh python with
#            forced host devices).  Quick iteration tier; the FULL suite
#            remains the default and the PR gate.
#
# Runs on CPU CI machines (no TPU): kernels execute in Pallas interpret mode
# (REPRO_PALLAS_INTERPRET=1).  Every PR must pass this before review.
set -euo pipefail
cd "$(dirname "$0")/.."

FAST=0
if [[ "${1:-}" == "--fast" ]]; then
  FAST=1
  shift
fi

export REPRO_PALLAS_INTERPRET="${REPRO_PALLAS_INTERPRET:-1}"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== compat grep-lint (drifted JAX symbols must live in repro/compat) =="
if grep -rn --include='*.py' -E \
     'jax\.shard_map|jax\.experimental\.shard_map|CompilerParams|jax\.experimental\.pallas import tpu|lax\.axis_size' \
     src/ | grep -v '^src/repro/compat/'; then
  echo "FAIL: drifted JAX symbols used outside src/repro/compat/ (see above);" >&2
  echo "      import them through repro.compat instead." >&2
  exit 1
fi
echo "ok"

echo "== tier-1 test suite =="
if [[ "$FAST" == 1 ]]; then
  python -m pytest -x -q -m "not multidev" "$@"
else
  python -m pytest -x -q "$@"
fi
