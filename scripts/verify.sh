#!/usr/bin/env bash
# Tier-1 gate: compat grep-lint + full correctness suite.
#
# Usage:  scripts/verify.sh [--fast] [extra pytest args]
#
#   --fast   skip the multi-device subprocess sweeps (tests marked
#            ``multidev`` — everything that spawns a fresh python with
#            forced host devices).  Quick iteration tier; the FULL suite
#            remains the default and the PR gate.
#
# Runs on CPU CI machines (no TPU): kernels execute in Pallas interpret mode
# (REPRO_PALLAS_INTERPRET=1).  Every PR must pass this before review.
set -euo pipefail
cd "$(dirname "$0")/.."

FAST=0
if [[ "${1:-}" == "--fast" ]]; then
  FAST=1
  shift
fi

export REPRO_PALLAS_INTERPRET="${REPRO_PALLAS_INTERPRET:-1}"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== compat grep-lint (drifted JAX symbols must live in repro/compat) =="
if grep -rn --include='*.py' -E \
     'jax\.shard_map|jax\.experimental\.shard_map|CompilerParams|jax\.experimental\.pallas import tpu|lax\.axis_size' \
     src/ | grep -v '^src/repro/compat/'; then
  echo "FAIL: drifted JAX symbols used outside src/repro/compat/ (see above);" >&2
  echo "      import them through repro.compat instead." >&2
  exit 1
fi
echo "ok"

echo "== overlap API lint (seams go through FusedOp / ctx.op) =="
# 1. overlap's private backends (rings, cores, q8 codecs, ...) are an
#    implementation detail of src/repro/core/overlap.py — nothing else may
#    reach into them.
if grep -rn --include='*.py' -E \
     'overlap\._|_ag_matmul_|_matmul_rs_(xla|decomposed|bidir|flux|impl)|_matmul_ar_|_ag_ring|_ag_bidir|_rs_ring|_rs_bidir|_rs_core|_ar_core|_fused_impl|_fused_ag|_q8_encode|_q8_decode' \
     src/ benchmarks/ | grep -v '^src/repro/core/overlap.py'; then
  echo "FAIL: private overlap backends referenced outside" >&2
  echo "      src/repro/core/overlap.py (see above); use overlap.FusedOp" >&2
  echo "      (model code: ctx.op(seam, epilogue=..., n_weights=...))." >&2
  exit 1
fi
# 2. no legacy positional mode-threading: passing plan attributes
#    (.mode/.comm_chunks/...) into the deprecated ag_matmul/matmul_rs/
#    matmul_ar wrappers — seams resolve a FusedOp via ctx.op(seam) instead.
if grep -rn --include='*.py' -E \
     '(ag_matmul|matmul_rs|matmul_ar)\([^)]*\.(mode|comm_chunks|reverse|blocks)' \
     src/ | grep -v '^src/repro/core/overlap.py'; then
  echo "FAIL: legacy positional (mode, comm_chunks, ...) threading into the" >&2
  echo "      deprecated overlap wrappers; resolve a FusedOp via" >&2
  echo "      ctx.op(seam, ...) instead." >&2
  exit 1
fi
echo "ok"

echo "== tier-1 test suite =="
if [[ "$FAST" == 1 ]]; then
  # the serving regressions run FIRST in the fast lane: they guard the
  # continuous-batching cache-corruption bugs (per-slot positions, batched
  # prefill admission) and fail in seconds when the serving path breaks.
  python -m pytest -x -q tests/test_serving_regression.py
  python -m pytest -x -q -m "not multidev" --ignore=tests/test_serving_regression.py "$@"
else
  python -m pytest -x -q "$@"
fi

echo "== serving smoke bench (BENCH_serving.json well-formedness) =="
python benchmarks/serving.py --smoke
python - <<'EOF'
import json
doc = json.load(open("experiments/BENCH_serving.json"))
rows = doc["modes"]
assert len(rows) >= 2, f"need >= 2 overlap modes, got {len(rows)}"
for r in rows:
    assert r["tokens_per_s"] > 0 and r["new_tokens"] > 0, r
    assert r["prefill_dispatches"] == r["requests"], \
        f"admission must be ONE prefill dispatch per request: {r}"
    assert {"mean", "p50", "max"} <= set(r["request_latency_s"]), r
    assert r["outputs_match_reference"], \
        f"overlap mode {r['mode']} changed serving outputs"
print("BENCH_serving.json ok:",
      ", ".join(f"{r['mode']}={r['tokens_per_s']:.0f} tok/s" for r in rows))
EOF
